//! Vendored minimal subset of the `rand_core` 0.6 trait surface.
//!
//! The fastsvdd build is fully offline, so instead of pulling the real
//! crate from crates.io this tiny in-tree package provides exactly the
//! items the library uses: the [`RngCore`] / [`SeedableRng`] traits,
//! the opaque [`Error`] type referenced by `try_fill_bytes`, and the
//! [`impls`] helpers. The trait contracts match upstream, so swapping
//! the real `rand_core` back in is a one-line Cargo.toml change.

use std::fmt;

/// Error type for fallible RNG operations (never produced by the
/// in-tree generators, which are infallible).
#[derive(Debug)]
pub struct Error {
    msg: &'static str,
}

impl Error {
    pub fn new(msg: &'static str) -> Error {
        Error { msg }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "rng error: {}", self.msg)
    }
}

impl std::error::Error for Error {}

/// The core of a random number generator: uniform pseudo-random words.
pub trait RngCore {
    fn next_u32(&mut self) -> u32;
    fn next_u64(&mut self) -> u64;
    fn fill_bytes(&mut self, dest: &mut [u8]);
    fn try_fill_bytes(&mut self, dest: &mut [u8]) -> Result<(), Error>;
}

/// A generator that can be instantiated from a fixed-size seed.
pub trait SeedableRng: Sized {
    type Seed: Sized + Default + AsMut<[u8]>;

    fn from_seed(seed: Self::Seed) -> Self;

    /// Expand a `u64` into a full seed via SplitMix64 (same scheme as
    /// upstream `rand_core`).
    fn seed_from_u64(mut state: u64) -> Self {
        let mut seed = Self::Seed::default();
        for chunk in seed.as_mut().chunks_mut(8) {
            state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = state;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^= z >> 31;
            let bytes = z.to_le_bytes();
            let n = chunk.len();
            chunk.copy_from_slice(&bytes[..n]);
        }
        Self::from_seed(seed)
    }
}

/// Helper implementations for `RngCore` methods, as in upstream.
pub mod impls {
    use super::RngCore;

    /// Implement `fill_bytes` in terms of `next_u64` (little-endian).
    pub fn fill_bytes_via_next<R: RngCore + ?Sized>(rng: &mut R, dest: &mut [u8]) {
        let mut left = dest;
        while left.len() >= 8 {
            let (chunk, rest) = left.split_at_mut(8);
            left = rest;
            chunk.copy_from_slice(&rng.next_u64().to_le_bytes());
        }
        let n = left.len();
        if n > 0 {
            left.copy_from_slice(&rng.next_u64().to_le_bytes()[..n]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Lcg(u64);

    impl RngCore for Lcg {
        fn next_u32(&mut self) -> u32 {
            (self.next_u64() >> 32) as u32
        }
        fn next_u64(&mut self) -> u64 {
            self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1);
            self.0
        }
        fn fill_bytes(&mut self, dest: &mut [u8]) {
            impls::fill_bytes_via_next(self, dest)
        }
        fn try_fill_bytes(&mut self, dest: &mut [u8]) -> Result<(), Error> {
            self.fill_bytes(dest);
            Ok(())
        }
    }

    impl SeedableRng for Lcg {
        type Seed = [u8; 8];
        fn from_seed(seed: Self::Seed) -> Self {
            Lcg(u64::from_le_bytes(seed))
        }
    }

    #[test]
    fn fill_bytes_covers_partial_chunks() {
        let mut rng = Lcg(1);
        let mut buf = [0u8; 13];
        rng.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }

    #[test]
    fn seed_from_u64_is_deterministic() {
        let mut a = Lcg::seed_from_u64(7);
        let mut b = Lcg::seed_from_u64(7);
        assert_eq!(a.next_u64(), b.next_u64());
    }
}
