//! Compile-time stub of the `xla` (PJRT) binding surface.
//!
//! The real crate wraps the `xla_extension` C++ library, which is not
//! available in the offline build environment. This stub exposes the
//! exact API `fastsvdd::runtime` consumes so the crate type-checks and
//! links without it; [`PjRtClient::cpu`] fails at *runtime* with a
//! descriptive error, which every caller in fastsvdd already treats as
//! "no accelerator available" and falls back to the native engines
//! (scoring, gram) or skips (XLA integration tests, which guard on the
//! artifact manifest).
//!
//! To enable real PJRT execution, replace this package's contents with
//! the actual bindings — no `fastsvdd/src` change is required.

use std::fmt;
use std::path::Path;

/// Error produced by every fallible stub entry point.
#[derive(Debug)]
pub struct Error {
    what: String,
}

impl Error {
    fn unavailable(what: &str) -> Error {
        Error {
            what: format!(
                "{what}: built against the stub xla crate (no PJRT runtime); \
                 native engines remain available"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.what)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Stub PJRT client. [`PjRtClient::cpu`] always errors, so no other
/// stub method is reachable in practice; they exist to type-check.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Stub HLO module proto (normally parsed from AOT-lowered HLO text).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// Stub XLA computation.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Stub compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Stub device buffer.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Stub host literal.
#[derive(Debug, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_stub() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"), "{err}");
    }

    #[test]
    fn literal_constructors_typecheck() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2, 1]).is_err());
        assert!(l.to_vec::<f32>().is_err());
    }
}
