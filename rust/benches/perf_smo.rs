//! SMO solver-path perf: second-order working-set selection + active-set
//! shrinking against the first-order unshrunk reference, and
//! warm-started sampling iterations (`SamplingConfig::warm_alpha`)
//! against cold starts.
//!
//! Two paper-scale workloads:
//!
//! - a **full SVDD solve** on Tennessee-Eastman-sized telemetry
//!   (41-dim): pair-iteration count and wall time for
//!   `wss=first, shrinking=off` vs the default `wss=second,
//!   shrinking=on`, with the solutions checked to agree (both
//!   eps-KKT, `R^2` within tolerance);
//! - an **Algorithm-1 sampling run** on banana (the paper's headline
//!   data set), fixed iteration budget so warm and cold do the same
//!   number of union solves: total SMO iterations and wall time with
//!   `warm_alpha` on vs off.
//!
//! Emits the usual table plus `results/BENCH_perf_smo.json` — the file
//! the CI `bench-smoke` job gates against
//! `ci/baselines/BENCH_perf_smo.json` (see ci/check_perf.py and
//! ci/baselines/README.md): iteration-reduction ratios are
//! machine-independent floors; the agreement booleans must be true.

use fastsvdd::bench::{emit, emit_text, measure, scaled};
use fastsvdd::data::banana::Banana;
use fastsvdd::data::tennessee::TennesseePlant;
use fastsvdd::data::Generator;
use fastsvdd::sampling::{SamplingConfig, SamplingTrainer};
use fastsvdd::svdd::bandwidth::median_heuristic;
use fastsvdd::svdd::smo::{solve, LazyKernel, SmoOptions};
use fastsvdd::svdd::{Kernel, SvddParams, Wss};
use fastsvdd::util::json::{num, obj, s, Json};
use fastsvdd::util::tables::{f, Table};

fn main() {
    // ---- full-solve ablation: WSS2 + shrinking vs first-order ----
    let plant = TennesseePlant::default();
    let rows = scaled(1_600, 400);
    let data = plant.training(rows, 42);
    let dim = data.cols();
    let bw = median_heuristic(&data, 20_000, 1);
    let kernel = Kernel::gaussian(bw);
    let c = 1.0 / (rows as f64 * 0.05);

    let first_opts = SmoOptions { wss: Wss::First, shrinking: false, ..Default::default() };
    let fast_opts = SmoOptions::default();
    let run_solve = |opts: &SmoOptions| {
        let mut kp = LazyKernel::new(&data, kernel, 256 << 20);
        solve(&mut kp, c, opts).unwrap()
    };

    let first_sol = run_solve(&first_opts);
    let fast_sol = run_solve(&fast_opts);
    let m_first = measure(0, 2, || run_solve(&first_opts));
    let m_fast = measure(0, 2, || run_solve(&fast_opts));

    let mut t = Table::new(
        &format!("Perf: SMO solver paths ({rows}x{dim} tennessee full solve)"),
        &["path", "iterations", "shrinks", "unshrinks", "mean_ms", "r2"],
    );
    t.row(vec![
        "first-order, unshrunk (reference)".into(),
        first_sol.iterations.to_string(),
        "0".into(),
        "0".into(),
        f(m_first.mean * 1e3, 1),
        f(first_sol.r2, 6),
    ]);
    t.row(vec![
        "second-order + shrinking (default)".into(),
        fast_sol.iterations.to_string(),
        fast_sol.shrink_events.to_string(),
        fast_sol.unshrink_events.to_string(),
        f(m_fast.mean * 1e3, 1),
        f(fast_sol.r2, 6),
    ]);

    let wss2_iter_reduction =
        first_sol.iterations as f64 / fast_sol.iterations.max(1) as f64;
    let wss2_speedup = m_first.mean / m_fast.mean.max(1e-12);
    let r2_scale = first_sol.r2.abs().max(fast_sol.r2.abs()).max(1e-9);
    let full_r2_rel_gap = (first_sol.r2 - fast_sol.r2).abs() / r2_scale;
    let solutions_agree =
        full_r2_rel_gap < 1e-3 && first_sol.gap < 1e-4 && fast_sol.gap < 1e-4;
    assert!(
        solutions_agree,
        "solver paths disagree: r2 {} vs {} (rel {full_r2_rel_gap:.3e}), \
         gaps {:.3e}/{:.3e}",
        first_sol.r2, fast_sol.r2, first_sol.gap, fast_sol.gap
    );

    // ---- sampling: warm-started vs cold union solves ----
    let b_rows = scaled(20_000, 4_000);
    let bdata = Banana::default().generate(b_rows, 7);
    let params = SvddParams::gaussian(0.35, 0.001);
    // fixed iteration budget: warm and cold run the same number of
    // sample + union solves, so total SMO iterations compare 1:1
    let cold_cfg = SamplingConfig {
        sample_size: 6,
        max_iter: 30,
        consecutive: 100, // unreachable: always run the full budget
        ..Default::default()
    };
    let warm_cfg = SamplingConfig { warm_alpha: true, ..cold_cfg };
    let cold_out = SamplingTrainer::new(params, cold_cfg).train(&bdata, 11).unwrap();
    let warm_out = SamplingTrainer::new(params, warm_cfg).train(&bdata, 11).unwrap();
    let m_cold =
        measure(0, 2, || SamplingTrainer::new(params, cold_cfg).train(&bdata, 11).unwrap());
    let m_warm =
        measure(0, 2, || SamplingTrainer::new(params, warm_cfg).train(&bdata, 11).unwrap());

    let mut ts = Table::new(
        &format!("Perf: warm-started sampling ({b_rows} banana rows, 30 iterations)"),
        &["init", "total_smo_iters", "solver_calls", "mean_ms", "r2"],
    );
    ts.row(vec![
        "cold (1/n init)".into(),
        cold_out.solver.smo_iterations.to_string(),
        cold_out.solver_calls.to_string(),
        f(m_cold.mean * 1e3, 1),
        f(cold_out.model.r2(), 6),
    ]);
    ts.row(vec![
        "warm (alpha carry)".into(),
        warm_out.solver.smo_iterations.to_string(),
        warm_out.solver_calls.to_string(),
        f(m_warm.mean * 1e3, 1),
        f(warm_out.model.r2(), 6),
    ]);

    let warm_iter_reduction = cold_out.solver.smo_iterations as f64
        / warm_out.solver.smo_iterations.max(1) as f64;
    let warm_r2_rel_gap = (warm_out.model.r2() - cold_out.model.r2()).abs()
        / cold_out.model.r2().abs().max(1e-9);
    let warm_matches_cold_r2 = warm_r2_rel_gap < 0.05;
    assert!(
        warm_matches_cold_r2,
        "warm sampling drifted: r2 {} vs {} (rel {warm_r2_rel_gap:.3e})",
        warm_out.model.r2(),
        cold_out.model.r2()
    );

    emit("perf_smo", &t);
    emit("perf_smo_sampling", &ts);
    println!(
        "WSS2+shrinking vs first-order: {:.2}x fewer iterations, {:.2}x wall time \
         ({} -> {} iters; {} shrink / {} unshrink events)",
        wss2_iter_reduction,
        wss2_speedup,
        first_sol.iterations,
        fast_sol.iterations,
        fast_sol.shrink_events,
        fast_sol.unshrink_events
    );
    println!(
        "warm vs cold sampling: {:.2}x fewer total SMO iterations ({} -> {})",
        warm_iter_reduction, cold_out.solver.smo_iterations, warm_out.solver.smo_iterations
    );

    let mut pairs = vec![
        ("bench", s("perf_smo")),
        ("full_rows", num(rows as f64)),
        ("full_dim", num(dim as f64)),
        ("first_order_iterations", num(first_sol.iterations as f64)),
        ("wss2_iterations", num(fast_sol.iterations as f64)),
        ("wss2_iter_reduction", num(wss2_iter_reduction)),
        ("wss2_speedup", num(wss2_speedup)),
        ("wss2_shrink_events", num(fast_sol.shrink_events as f64)),
        ("wss2_unshrink_events", num(fast_sol.unshrink_events as f64)),
        ("first_order_solve_s", num(m_first.mean)),
        ("wss2_solve_s", num(m_fast.mean)),
        ("full_r2_rel_gap", num(full_r2_rel_gap)),
        ("solutions_agree", Json::Bool(solutions_agree)),
        ("sampling_rows", num(b_rows as f64)),
        ("cold_smo_iterations", num(cold_out.solver.smo_iterations as f64)),
        ("warm_smo_iterations", num(warm_out.solver.smo_iterations as f64)),
        ("warm_iter_reduction", num(warm_iter_reduction)),
        ("cold_run_s", num(m_cold.mean)),
        ("warm_run_s", num(m_warm.mean)),
        ("warm_r2_rel_gap", num(warm_r2_rel_gap)),
        ("warm_matches_cold_r2", Json::Bool(warm_matches_cold_r2)),
    ];
    pairs.extend(fastsvdd::bench::isa_provenance());
    let json = obj(pairs);
    emit_text("BENCH_perf_smo.json", &json.to_string_pretty());
    println!("wrote results/BENCH_perf_smo.json");
}
