//! Distributed-training bench: shard-count ladder, flat vs tree
//! combine, the wall-clock cost of surviving a worker fault, and a
//! large-row scaling row.
//!
//! The correctness flags ride along with the timings: tree combine
//! must land within 5% relative R^2 of flat (gated in CI), and the
//! faulted TCP run must recover to the exact clean-run model — retries
//! are free of model drift by construction (shard-keyed results,
//! per-shard seeds), so the bench proves the fault path pays only in
//! wall-clock, never in accuracy.
//!
//! Emits the usual table plus `results/BENCH_perf_distributed.json`.

use std::time::Duration;

use fastsvdd::bench::{emit, emit_text, scaled};
use fastsvdd::data::{donut::TwoDonut, Generator};
use fastsvdd::distributed::{
    train_local_cluster, train_tcp_cluster, CombineMode, DistributedConfig, DistributedOutcome,
    FaultPlan, WorkerServer,
};
use fastsvdd::sampling::SamplingConfig;
use fastsvdd::svdd::SvddParams;
use fastsvdd::util::json::{num, obj, s, Json};
use fastsvdd::util::matrix::Matrix;
use fastsvdd::util::tables::{f, Table};
use fastsvdd::util::timer::Stopwatch;

fn cfg(workers: usize, combine: CombineMode) -> DistributedConfig {
    DistributedConfig {
        workers,
        sampling: SamplingConfig { sample_size: 10, ..Default::default() },
        seed: 7,
        combine,
        worker_timeout: Duration::from_secs(2),
        ..Default::default()
    }
}

fn timed_local(
    data: &Matrix,
    params: &SvddParams,
    c: &DistributedConfig,
) -> (DistributedOutcome, f64) {
    let sw = Stopwatch::start();
    let out = train_local_cluster(data, params, c).unwrap();
    (out, sw.elapsed_secs() * 1e3)
}

/// One TCP run against a single worker carrying `plan`, timed.
fn timed_tcp(
    data: &Matrix,
    params: &SvddParams,
    c: &DistributedConfig,
    plan: Option<FaultPlan>,
) -> (DistributedOutcome, f64) {
    let mut w = WorkerServer::spawn_with_faults("127.0.0.1:0", plan).unwrap();
    let sw = Stopwatch::start();
    let out = train_tcp_cluster(data, params, c, &[w.addr()]).unwrap();
    let ms = sw.elapsed_secs() * 1e3;
    w.stop();
    (out, ms)
}

fn main() {
    let rows = scaled(24_000, 2_400);
    let data = TwoDonut::default().generate(rows, 42);
    let params = SvddParams::gaussian(0.4, 0.001);

    let mut t = Table::new(
        "Perf: distributed training (local transport unless noted)",
        &["case", "shards", "wall_ms", "R^2"],
    );

    // ---- shard-count ladder (flat combine) ----
    let mut ladder = Vec::new();
    for p in [2usize, 4, 8] {
        let (out, ms) = timed_local(&data, &params, &cfg(p, CombineMode::Flat));
        t.row(vec![format!("ladder p={p}"), p.to_string(), f(ms, 1), f(out.model.r2(), 4)]);
        ladder.push((p, ms));
    }

    // ---- flat vs tree combine at a wide shard count ----
    let wide = 16usize;
    let (flat, flat_ms) = timed_local(&data, &params, &cfg(wide, CombineMode::Flat));
    let tree_mode = CombineMode::Tree { fanout: 4 };
    let (tree, tree_ms) = timed_local(&data, &params, &cfg(wide, tree_mode));
    let rel = (tree.model.r2() - flat.model.r2()).abs() / flat.model.r2();
    let tree_matches_flat = rel < 0.05;
    t.row(vec!["combine flat".into(), wide.to_string(), f(flat_ms, 1), f(flat.model.r2(), 4)]);
    t.row(vec![
        format!("combine {tree_mode} ({} solves)", tree.combine_solves),
        wide.to_string(),
        f(tree_ms, 1),
        f(tree.model.r2(), 4),
    ]);

    // ---- fault-recovery overhead (TCP, deterministic corrupt reply) ----
    let small = TwoDonut::default().generate(scaled(6_000, 600), 43);
    let c2 = cfg(2, CombineMode::Flat);
    let (clean, clean_ms) = timed_tcp(&small, &params, &c2, None);
    let plan = FaultPlan::parse("corrupt_at=1").unwrap();
    let (faulted, faulted_ms) = timed_tcp(&small, &params, &c2, Some(plan));
    let retries_recovered = faulted.retry.shard_retries >= 1
        && (faulted.model.r2() - clean.model.r2()).abs() < 1e-9;
    t.row(vec!["tcp clean".into(), "2".into(), f(clean_ms, 1), f(clean.model.r2(), 4)]);
    t.row(vec![
        format!("tcp corrupt_at=1 ({} retry)", faulted.retry.shard_retries),
        "2".into(),
        f(faulted_ms, 1),
        f(faulted.model.r2(), 4),
    ]);

    // ---- large-row scaling row ----
    let large_rows = scaled(60_000, 6_000);
    let large = TwoDonut::default().generate(large_rows, 44);
    let (lout, large_ms) = timed_local(&large, &params, &cfg(8, CombineMode::Flat));
    let large_rows_per_s = large_rows as f64 / (large_ms / 1e3);
    t.row(vec![
        format!("large {large_rows} rows"),
        "8".into(),
        f(large_ms, 1),
        f(lout.model.r2(), 4),
    ]);

    emit("perf_distributed", &t);

    let mut pairs = vec![
        ("bench", s("perf_distributed")),
        ("rows", num(rows as f64)),
        ("wall_p2_ms", num(ladder[0].1)),
        ("wall_p4_ms", num(ladder[1].1)),
        ("wall_p8_ms", num(ladder[2].1)),
        ("flat_wall_ms", num(flat_ms)),
        ("tree_wall_ms", num(tree_ms)),
        ("tree_fanout", num(4.0)),
        ("tree_combine_solves", num(tree.combine_solves as f64)),
        ("r2_flat", num(flat.model.r2())),
        ("r2_tree", num(tree.model.r2())),
        ("tree_vs_flat_rel_diff", num(rel)),
        ("tree_matches_flat_r2", Json::Bool(tree_matches_flat)),
        ("retry_clean_wall_ms", num(clean_ms)),
        ("retry_faulted_wall_ms", num(faulted_ms)),
        ("retry_overhead_ratio", num(faulted_ms / clean_ms)),
        ("shard_retries", num(faulted.retry.shard_retries as f64)),
        ("retries_recovered", Json::Bool(retries_recovered)),
        ("large_rows", num(large_rows as f64)),
        ("large_wall_ms", num(large_ms)),
        ("large_rows_per_s", num(large_rows_per_s)),
    ];
    pairs.extend(fastsvdd::bench::isa_provenance());
    let json = obj(pairs);
    emit_text("BENCH_perf_distributed.json", &json.to_string_pretty());
    println!("wrote results/BENCH_perf_distributed.json");
    assert!(tree_matches_flat, "tree combine drifted {rel} relative R^2 from flat");
    assert!(retries_recovered, "faulted run did not recover the clean model");
}
