//! Figs 9 & 10 — Shuttle-like data: F1-measure ratio
//! (sampling / full) and processing time vs training-set size.
//!
//! Paper protocol (section V-A): train on class-1 rows only, score a
//! held-out mix, sample size = #variables + 1 = 10, training sizes
//! 3 000..40 000. Expected shape: ratio ~ 1 flat; full time grows with
//! n while sampling time stays flat.

use fastsvdd::baselines::train_full;
use fastsvdd::bench::{emit, scaled};
use fastsvdd::data::shuttle::{Shuttle, DIM};
use fastsvdd::sampling::{SamplingConfig, SamplingTrainer};
use fastsvdd::scoring::{F1Score, Scorer};
use fastsvdd::svdd::bandwidth::median_heuristic;
use fastsvdd::svdd::SvddParams;
use fastsvdd::util::tables::{f, i, Table};
use fastsvdd::util::timer::Stopwatch;

fn main() {
    let sizes: Vec<usize> = [3_000, 5_000, 10_000, 15_000, 20_000, 30_000, 40_000]
        .iter()
        .map(|&n| scaled(n, 1000))
        .collect();
    let scoring = Shuttle.scoring(scaled(20_000, 2000), 99);
    // bandwidth from the data scale (paper does not state s); fixed
    // across sizes so the ratio is apples-to-apples
    let bw = median_heuristic(&Shuttle.training(2000, 1), 20_000, 1);
    let params = SvddParams::gaussian(bw, 0.005);
    println!("shuttle: bw={bw:.2} f=0.005 sample_size={}", DIM + 1);

    let mut t = Table::new(
        "Figs 9+10: Shuttle — F1 ratio & processing time vs training size",
        &["#train", "F1_full", "F1_sampling", "ratio", "t_full_s", "t_sampling_s", "speedup"],
    );
    for &n in &sizes {
        let train_data = Shuttle.training(n, 42);

        let sw = Stopwatch::start();
        let full = train_full(&train_data, &params).unwrap().model;
        let t_full = sw.elapsed_secs();
        let f1_full = F1Score::compute(
            &scoring.labels,
            &Scorer::native(&full).inside_batch(&scoring.data).unwrap(),
        );

        let cfg = SamplingConfig { sample_size: DIM + 1, ..Default::default() };
        let sw = Stopwatch::start();
        let samp = SamplingTrainer::new(params, cfg).train(&train_data, 7).unwrap().model;
        let t_samp = sw.elapsed_secs();
        let f1_samp = F1Score::compute(
            &scoring.labels,
            &Scorer::native(&samp).inside_batch(&scoring.data).unwrap(),
        );

        t.row(vec![
            i(n),
            f(f1_full.f1, 4),
            f(f1_samp.f1, 4),
            f(f1_samp.f1 / f1_full.f1.max(1e-12), 4),
            f(t_full, 3),
            f(t_samp, 3),
            f(t_full / t_samp.max(1e-9), 1),
        ]);
    }
    emit("fig910_shuttle", &t);
}
