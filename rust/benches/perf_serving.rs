//! Serving-edge saturation bench: req/s and p99 latency for persistent
//! native connections against one server, micro-batched readiness-loop
//! edge vs the legacy thread-per-connection mode, at a moderate and a
//! high connection count.
//!
//! Both modes feed the same dynamic batcher, so this isolates the cost
//! of *connection handling*: one multiplexer thread vs one OS thread
//! per client. Every reply is checked bit-identical against a local
//! `dist2_batch`, so the speed comparison is also a correctness sweep.
//!
//! Emits the usual table plus `results/BENCH_perf_serving.json`
//! (gated in CI: the edge must stay at least at parity with
//! thread-per-connection at the high connection count, and scores must
//! be bit-identical).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier};

use fastsvdd::bench::{emit, emit_text, scaled};
use fastsvdd::data::{banana::Banana, Generator};
use fastsvdd::sampling::{SamplingConfig, SamplingTrainer};
use fastsvdd::scoring::{BatchPolicy, ScoreClient, ScoreServer};
use fastsvdd::svdd::{SvddModel, SvddParams};
use fastsvdd::util::json::{num, obj, s, Json};
use fastsvdd::util::matrix::Matrix;
use fastsvdd::util::stats::quantile;
use fastsvdd::util::tables::{f, Table};
use fastsvdd::util::timer::Stopwatch;

/// Saturate one server mode: `conns` persistent clients each send
/// `reqs` 8-row score requests. Returns (req/s, per-request latencies,
/// all replies bit-identical).
fn saturate(
    edge: bool,
    conns: usize,
    reqs: usize,
    model: &SvddModel,
    zs: &Matrix,
) -> (f64, Vec<f64>, bool) {
    let mut server = ScoreServer::builder("127.0.0.1:0")
        .model(model.clone())
        .policy(BatchPolicy::default())
        .edge(edge)
        .max_conns(conns * 2 + 8)
        .spawn(|m, zs| Ok(m.dist2_batch(zs)))
        .unwrap();
    let addr = server.addr();
    let expected = Arc::new(model.dist2_batch(zs));
    let identical = Arc::new(AtomicBool::new(true));
    // connect everyone first, then start the clock on a barrier so the
    // connect storm is not measured
    let barrier = Arc::new(Barrier::new(conns + 1));
    let workers: Vec<_> = (0..conns)
        .map(|_| {
            let zs = zs.clone();
            let expected = expected.clone();
            let identical = identical.clone();
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                let client = ScoreClient::connect(addr).unwrap();
                barrier.wait();
                let mut lat = Vec::with_capacity(reqs);
                for _ in 0..reqs {
                    let sw = Stopwatch::start();
                    let (dist2, _) = client.score(&zs).unwrap();
                    lat.push(sw.elapsed_secs());
                    if dist2 != *expected {
                        identical.store(false, Ordering::Relaxed);
                    }
                }
                client.close();
                lat
            })
        })
        .collect();
    barrier.wait();
    let sw = Stopwatch::start();
    let mut lat = Vec::new();
    for w in workers {
        lat.extend(w.join().unwrap());
    }
    let wall = sw.elapsed_secs();
    server.stop();
    let rps = (conns * reqs) as f64 / wall;
    (rps, lat, identical.load(Ordering::Relaxed))
}

fn main() {
    let rows = scaled(6_000, 600);
    let data = Banana::default().generate(rows, 42);
    let params = SvddParams::gaussian(0.35, 0.001);
    let cfg = SamplingConfig { sample_size: 6, ..Default::default() };
    let model = SamplingTrainer::new(params, cfg).train(&data, 7).unwrap().model;
    let zs = Banana::default().generate(8, 9);

    let conns_lo = scaled(256, 16);
    let conns_hi = scaled(1024, 64);
    let reqs = scaled(40, 8);

    let (rps_edge_lo, lat_edge_lo, ok1) = saturate(true, conns_lo, reqs, &model, &zs);
    let (rps_thr_lo, lat_thr_lo, ok2) = saturate(false, conns_lo, reqs, &model, &zs);
    let (rps_edge_hi, lat_edge_hi, ok3) = saturate(true, conns_hi, reqs, &model, &zs);
    let (rps_thr_hi, lat_thr_hi, ok4) = saturate(false, conns_hi, reqs, &model, &zs);
    let identical = ok1 && ok2 && ok3 && ok4;

    let p99 = |xs: &[f64]| quantile(xs, 0.99) * 1e6; // -> us
    let mut t = Table::new(
        "Perf: serving edge vs thread-per-connection",
        &["mode", "conns", "req/s", "p99_us"],
    );
    for (mode, conns, rps, lat) in [
        ("edge (micro-batched)", conns_lo, rps_edge_lo, &lat_edge_lo),
        ("thread-per-conn", conns_lo, rps_thr_lo, &lat_thr_lo),
        ("edge (micro-batched)", conns_hi, rps_edge_hi, &lat_edge_hi),
        ("thread-per-conn", conns_hi, rps_thr_hi, &lat_thr_hi),
    ] {
        t.row(vec![mode.into(), conns.to_string(), f(rps, 0), f(p99(lat), 1)]);
    }
    emit("perf_serving", &t);

    let mut pairs = vec![
        ("bench", s("perf_serving")),
        ("conns_lo", num(conns_lo as f64)),
        ("conns_hi", num(conns_hi as f64)),
        ("requests_per_conn", num(reqs as f64)),
        ("rps_edge_lo", num(rps_edge_lo)),
        ("p99_edge_lo_us", num(p99(&lat_edge_lo))),
        ("rps_threaded_lo", num(rps_thr_lo)),
        ("p99_threaded_lo_us", num(p99(&lat_thr_lo))),
        ("rps_edge_hi", num(rps_edge_hi)),
        ("p99_edge_hi_us", num(p99(&lat_edge_hi))),
        ("rps_threaded_hi", num(rps_thr_hi)),
        ("p99_threaded_hi_us", num(p99(&lat_thr_hi))),
        ("edge_vs_threaded_hi", num(rps_edge_hi / rps_thr_hi)),
        ("scores_bit_identical", Json::Bool(identical)),
    ];
    pairs.extend(fastsvdd::bench::isa_provenance());
    let json = obj(pairs);
    emit_text("BENCH_perf_serving.json", &json.to_string_pretty());
    println!("wrote results/BENCH_perf_serving.json");
    assert!(identical, "a served score diverged from the local engine");
}
