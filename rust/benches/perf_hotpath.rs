//! Hot-path micro-benchmarks feeding EXPERIMENTS.md section Perf:
//!
//! - L3 solver: small-union SMO solve rate (the Algorithm-1 inner loop)
//! - L3 trainer: sampling iterations/second end-to-end
//! - scoring: native rows/s vs XLA rows/s per bucket
//! - runtime: gram-artifact executions/second
//! - kernel cache: solve time with vs without cache on a mid-size solve

use std::path::Path;

use fastsvdd::bench::{emit, emit_text, measure, paper, scaled};
use fastsvdd::runtime::SharedRuntime;
use fastsvdd::sampling::{GramBackend, SamplingConfig, SamplingTrainer};
use fastsvdd::scoring::Scorer;
use fastsvdd::svdd::{train, Kernel};
use fastsvdd::util::json::{num, obj, s};
use fastsvdd::util::tables::{f, Table};

fn main() {
    let d = paper::BANANA;
    let rows = scaled(20_000, 2_000);
    let data = d.generate(rows, 42);
    let params = d.params();
    let mut t = Table::new(
        "Perf: hot paths (mean over measured iters)",
        &["path", "mean_ms", "min_ms", "throughput"],
    );

    // L3: small-union solve (typical Algorithm-1 union: ~40 rows)
    let union = data.gather(&(0..40).collect::<Vec<_>>());
    let m_solve = measure(3, 30, || train(&union, &params).unwrap());
    t.row(vec![
        "smo solve, 40-row union".into(),
        f(m_solve.mean * 1e3, 3),
        f(m_solve.min * 1e3, 3),
        format!("{:.0} solves/s", 1.0 / m_solve.mean),
    ]);

    // L3: one full sampling train
    let cfg = SamplingConfig { sample_size: d.sample_size, ..Default::default() };
    let m_train = measure(1, 5, || SamplingTrainer::new(params, cfg).train(&data, 7).unwrap());
    let iters = SamplingTrainer::new(params, cfg).train(&data, 7).unwrap().iterations;
    t.row(vec![
        format!("sampling train, banana {rows}"),
        f(m_train.mean * 1e3, 1),
        f(m_train.min * 1e3, 1),
        format!("{:.0} iters/s", iters as f64 / m_train.mean),
    ]);

    // scoring: native
    let model = train(
        &data.gather(&(0..scaled(3_000, 600).min(rows)).collect::<Vec<_>>()),
        &params,
    )
    .unwrap();
    let zs = d.generate(8192, 9);
    let m_score = measure(2, 10, || Scorer::native(&model).dist2_batch(&zs).unwrap());
    t.row(vec![
        format!("native scoring ({} SVs)", model.num_sv()),
        f(m_score.mean * 1e3, 2),
        f(m_score.min * 1e3, 2),
        format!("{:.0} rows/s", zs.rows() as f64 / m_score.mean),
    ]);

    // scoring + gram: XLA (if artifacts are built)
    match SharedRuntime::new(Path::new("artifacts")) {
        Ok(rt) => {
            let scorer = Scorer::xla(&model, &rt);
            assert!(scorer.is_accelerated());
            let m = measure(2, 10, || scorer.dist2_batch(&zs).unwrap());
            t.row(vec![
                "xla scoring (b4096 bucket)".into(),
                f(m.mean * 1e3, 2),
                f(m.min * 1e3, 2),
                format!("{:.0} rows/s", zs.rows() as f64 / m.mean),
            ]);

            let small = d.generate(256, 3);
            let m = measure(2, 20, || scorer.dist2_batch(&small).unwrap());
            t.row(vec![
                "xla scoring (b256 bucket)".into(),
                f(m.mean * 1e3, 3),
                f(m.min * 1e3, 3),
                format!("{:.0} rows/s", small.rows() as f64 / m.mean),
            ]);

            let sample = d.generate(48, 5);
            let m = measure(2, 20, || rt.gram(&sample, Kernel::gaussian(d.bw)).unwrap());
            t.row(vec![
                "xla gram (n64 bucket, 48 rows)".into(),
                f(m.mean * 1e3, 3),
                f(m.min * 1e3, 3),
                format!("{:.0} grams/s", 1.0 / m.mean),
            ]);
        }
        Err(_) => println!("(no artifacts/ — XLA rows skipped; run `make artifacts`)"),
    }

    // kernel cache ablation: mid-size full solve, tiny vs large cache
    let mid = data.gather(&(0..scaled(4_000, 800).min(rows)).collect::<Vec<_>>());
    let mut p_small = params;
    p_small.cache_bytes = 1; // one column only
    let m_nocache = measure(1, 3, || train(&mid, &p_small).unwrap());
    let m_cache = measure(1, 3, || train(&mid, &params).unwrap());
    t.row(vec![
        "full solve 4k rows, 1-col cache".into(),
        f(m_nocache.mean * 1e3, 1),
        f(m_nocache.min * 1e3, 1),
        "-".into(),
    ]);
    t.row(vec![
        "full solve 4k rows, 256MB cache".into(),
        f(m_cache.mean * 1e3, 1),
        f(m_cache.min * 1e3, 1),
        format!("{:.2}x faster", m_nocache.mean / m_cache.mean),
    ]);

    emit("perf_hotpath", &t);

    // machine-readable summary for the CI bench-smoke artifacts
    let mut pairs = vec![
        ("bench", s("perf_hotpath")),
        ("rows", num(rows as f64)),
        ("smo_solve_ms", num(m_solve.mean * 1e3)),
        ("sampling_train_ms", num(m_train.mean * 1e3)),
        ("sampling_iters", num(iters as f64)),
        ("native_score_rows_per_s", num(zs.rows() as f64 / m_score.mean)),
        ("cache_speedup", num(m_nocache.mean / m_cache.mean)),
    ];
    pairs.extend(fastsvdd::bench::isa_provenance());
    let json = obj(pairs);
    emit_text("BENCH_perf_hotpath.json", &json.to_string_pretty());
    println!("wrote results/BENCH_perf_hotpath.json");
}
