//! Table I — SVDD training using the full SVDD method.
//!
//! Paper columns: Data, #Obs, R^2, #SV, Time. We run the same three
//! data sets; the Two-Donut full solve is capped (env
//! `FASTSVDD_FULL_CAP`, default 40 000 — the 1.33 M-row solve would
//! take hours on this substrate; Fig 1 extrapolates the full curve and
//! Table II runs sampling on the full 1.33 M). Paper numbers are
//! printed alongside for comparison.

use fastsvdd::baselines::train_full;
use fastsvdd::bench::{emit, paper};
use fastsvdd::util::tables::{f, i, Table};
use fastsvdd::util::timer::fmt_duration;

fn main() {
    let cap: usize = std::env::var("FASTSVDD_FULL_CAP")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(400_000);

    let mut t = Table::new(
        "Table I: SVDD training, full method (paper values in [brackets])",
        &["Data", "#Obs", "[#Obs]", "R^2", "[R^2]", "#SV", "[#SV]", "Time", "[Time]"],
    );
    for d in paper::ALL {
        let rows = d.full_rows_scaled(cap);
        let data = d.generate(rows, 42);
        let out = train_full(&data, &d.params()).expect("full training failed");
        t.row(vec![
            d.name.into(),
            i(rows),
            i(d.full_rows),
            f(out.model.r2(), 4),
            f(d.paper_r2_full, 4),
            i(out.model.num_sv()),
            i(d.paper_sv_full),
            fmt_duration(out.seconds),
            d.paper_time_full.into(),
        ]);
    }
    emit("table1_full_svdd", &t);
}
