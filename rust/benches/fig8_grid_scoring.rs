//! Fig 8 — scoring a 200x200 grid with the full-method model vs the
//! sampling-method model, per data set. The paper eyeballs the two
//! inside/outside maps; we write both PGM images *and* report the
//! agreement fraction (plus the XLA-vs-native engine cross-check when
//! artifacts are present).

use std::path::Path;

use fastsvdd::baselines::train_full;
use fastsvdd::bench::{emit, paper, results_dir, scaled};
use fastsvdd::data::grid::{agreement, Grid};
use fastsvdd::runtime::SharedRuntime;
use fastsvdd::sampling::{SamplingConfig, SamplingTrainer};
use fastsvdd::scoring::Scorer;
use fastsvdd::util::tables::{f, i, Table};

fn main() {
    let runtime = SharedRuntime::new(Path::new("artifacts")).ok();
    if runtime.is_none() {
        println!("(no artifacts/ — grid scoring will use the native engine)");
    }
    let mut t = Table::new(
        "Fig 8: 200x200 grid scoring, full vs sampling",
        &["Data", "inside_full_%", "inside_sampling_%", "agreement_%", "engine"],
    );
    for d in paper::ALL {
        let rows = scaled(d.full_rows.min(20_000), 3000);
        let data = d.generate(rows, 42);
        let full = train_full(&data, &d.params()).unwrap().model;
        let cfg = SamplingConfig { sample_size: d.sample_size, ..Default::default() };
        let samp = SamplingTrainer::new(d.params(), cfg).train(&data, 7).unwrap().model;

        let grid = Grid::covering(&data, 200, 200, 0.15);
        let pts = grid.points();

        let (full_inside, samp_inside, engine) = match &runtime {
            Some(rt) => {
                let fs = Scorer::xla(&full, rt);
                let ss = Scorer::xla(&samp, rt);
                let engine = if fs.is_accelerated() { "xla" } else { "native" };
                (fs.inside_batch(&pts).unwrap(), ss.inside_batch(&pts).unwrap(), engine)
            }
            None => (
                Scorer::native(&full).inside_batch(&pts).unwrap(),
                Scorer::native(&samp).inside_batch(&pts).unwrap(),
                "native",
            ),
        };

        let dir = results_dir();
        grid.write_pgm(&full_inside, &dir.join(format!("fig8_{}_full.pgm", d.name)))
            .unwrap();
        grid.write_pgm(&samp_inside, &dir.join(format!("fig8_{}_sampling.pgm", d.name)))
            .unwrap();

        let pct = |v: &[bool]| 100.0 * v.iter().filter(|&&b| b).count() as f64 / v.len() as f64;
        t.row(vec![
            d.name.into(),
            f(pct(&full_inside), 2),
            f(pct(&samp_inside), 2),
            f(100.0 * agreement(&full_inside, &samp_inside), 2),
            engine.into(),
        ]);
        let _ = i(rows); // rows recorded in the emitted CSV name context
    }
    emit("fig8_grid_scoring", &t);
    println!("PGM maps written to results/fig8_*.pgm");
}
