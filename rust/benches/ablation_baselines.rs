//! Ablation: the paper's method vs the two prior sampling baselines it
//! criticizes (section III) and the full method, on the same data —
//! time, quality, and the structural costs (scoring passes / rows
//! touched) that motivate the paper's design.
//!
//! Also ablates the paper's design choices: sampling WITHOUT the master
//! set union (naive resampling) and convergence WITHOUT the center
//! criterion (R^2 only).

use fastsvdd::baselines::{train_full, train_kim, train_luo, KimConfig, LuoConfig};
use fastsvdd::bench::{emit, paper, scaled};
use fastsvdd::sampling::{SamplingConfig, SamplingTrainer};
use fastsvdd::svdd::trainer::train;
use fastsvdd::util::tables::{f, i, Table};
use fastsvdd::util::timer::Stopwatch;

fn main() {
    for d in [paper::BANANA, paper::TWO_DONUT] {
        let rows = scaled(d.full_rows.min(20_000), 4000);
        let data = d.generate(rows, 42);
        let params = d.params();
        let mut t = Table::new(
            format!("Ablation: methods on {} (rows={rows})", d.name),
            &["method", "time_s", "R^2", "#SV", "notes"],
        );

        let sw = Stopwatch::start();
        let full = train_full(&data, &params).unwrap();
        t.row(vec![
            "full".into(),
            f(sw.elapsed_secs(), 3),
            f(full.model.r2(), 4),
            i(full.model.num_sv()),
            "all rows, one solve".into(),
        ]);

        let cfg = SamplingConfig { sample_size: d.sample_size, ..Default::default() };
        let sw = Stopwatch::start();
        let samp = SamplingTrainer::new(params, cfg).train(&data, 7).unwrap();
        t.row(vec![
            "sampling (paper)".into(),
            f(sw.elapsed_secs(), 3),
            f(samp.model.r2(), 4),
            i(samp.model.num_sv()),
            format!("iters={} rows_touched={}", samp.iterations, samp.rows_touched),
        ]);

        let sw = Stopwatch::start();
        let luo = train_luo(&data, &params, &LuoConfig::default()).unwrap();
        t.row(vec![
            "luo (decomp+comb)".into(),
            f(sw.elapsed_secs(), 3),
            f(luo.model.r2(), 4),
            i(luo.model.num_sv()),
            format!("{} full-data scoring passes", luo.scoring_passes),
        ]);

        let sw = Stopwatch::start();
        let kim = train_kim(&data, &params, &KimConfig::default()).unwrap();
        t.row(vec![
            "kim (k-means)".into(),
            f(sw.elapsed_secs(), 3),
            f(kim.model.r2(), 4),
            i(kim.model.num_sv()),
            format!("pooled_svs={}, touches every row", kim.pooled_svs),
        ]);

        // --- ablation: no master-set union (train on one big sample of
        // equal total budget instead of iterating) ---
        let budget = samp.rows_touched.min(rows);
        let sw = Stopwatch::start();
        let idx: Vec<usize> = (0..budget).collect();
        let one_shot = train(&data.gather(&idx), &params).unwrap();
        t.row(vec![
            "one big sample (no iteration)".into(),
            f(sw.elapsed_secs(), 3),
            f(one_shot.r2(), 4),
            i(one_shot.num_sv()),
            format!("single solve on {budget} rows (same row budget)"),
        ]);

        // --- ablation: R^2-only convergence (paper notes it often
        // suffices) ---
        let cfg_r2only = SamplingConfig {
            sample_size: d.sample_size,
            eps_center: f64::INFINITY, // disable the center check
            ..Default::default()
        };
        let sw = Stopwatch::start();
        let r2only = SamplingTrainer::new(params, cfg_r2only).train(&data, 7).unwrap();
        t.row(vec![
            "sampling, R^2-only convergence".into(),
            f(sw.elapsed_secs(), 3),
            f(r2only.model.r2(), 4),
            i(r2only.model.num_sv()),
            format!("iters={}", r2only.iterations),
        ]);

        emit(&format!("ablation_{}", d.name), &t);
    }
}
