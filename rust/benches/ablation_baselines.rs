//! Ablation: the paper's method vs the two prior sampling baselines it
//! criticizes (section III), the full method, and the streaming
//! snapshot, on the same data — time, quality, and the structural
//! costs (scoring passes / rows touched) that motivate the paper's
//! design. Every method runs through the unified `Engine` facade, so
//! this harness iterates trainers generically instead of special-casing
//! each entry point.
//!
//! Also ablates the paper's design choices: sampling WITHOUT the master
//! set union (naive resampling) and convergence WITHOUT the center
//! criterion (R^2 only).

use fastsvdd::bench::{emit, paper, scaled};
use fastsvdd::config::Method;
use fastsvdd::engine::Engine;
use fastsvdd::sampling::{SamplingConfig, SamplingTrainer};
use fastsvdd::svdd::trainer::train;
use fastsvdd::util::tables::{f, i, Table};
use fastsvdd::util::timer::Stopwatch;

const METHODS: [Method; 5] = [
    Method::Full,
    Method::Sampling,
    Method::Luo,
    Method::Kim,
    Method::Streaming,
];

fn main() {
    for d in [paper::BANANA, paper::TWO_DONUT] {
        let rows = scaled(d.full_rows.min(20_000), 4000);
        let data = d.generate(rows, 42);
        let params = d.params();
        let mut t = Table::new(
            format!("Ablation: methods on {} (rows={rows})", d.name),
            &["method", "time_s", "R^2", "#SV", "notes"],
        );

        // one loop over every registered method — the Engine facade
        // makes them interchangeable
        let mut sampling_budget = rows;
        for method in METHODS {
            let cfg = d.run_config(method, rows, 7);
            let report = Engine::from_config(&cfg).unwrap().train(&data).unwrap();
            if method == Method::Sampling {
                sampling_budget = report.rows_touched.min(rows);
            }
            t.row(vec![
                method.name().into(),
                f(report.seconds, 3),
                f(report.model.r2(), 4),
                i(report.model.num_sv()),
                report.extras_line(),
            ]);
        }

        // --- ablation: no master-set union (train on one big sample of
        // equal total budget instead of iterating) ---
        let sw = Stopwatch::start();
        let idx: Vec<usize> = (0..sampling_budget).collect();
        let one_shot = train(&data.gather(&idx), &params).unwrap();
        t.row(vec![
            "one big sample (no iteration)".into(),
            f(sw.elapsed_secs(), 3),
            f(one_shot.r2(), 4),
            i(one_shot.num_sv()),
            format!("single solve on {sampling_budget} rows (same row budget)"),
        ]);

        // --- ablation: R^2-only convergence (paper notes it often
        // suffices) ---
        let cfg_r2only = SamplingConfig {
            sample_size: d.sample_size,
            eps_center: f64::INFINITY, // disable the center check
            ..Default::default()
        };
        let sw = Stopwatch::start();
        let r2only = SamplingTrainer::new(params, cfg_r2only).train(&data, 7).unwrap();
        t.row(vec![
            "sampling, R^2-only convergence".into(),
            f(sw.elapsed_secs(), 3),
            f(r2only.model.r2(), 4),
            i(r2only.model.num_sv()),
            format!("iters={}", r2only.iterations),
        ]);

        emit(&format!("ablation_{}", d.name), &t);
    }
}
