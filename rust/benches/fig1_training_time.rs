//! Fig 1 — full-SVDD training time vs training-set size (Two-Donut).
//!
//! The paper shows the cost curve climbing to ~32 min at 1.33 M rows.
//! We measure the solver on a doubling ladder of sizes, fit a power law
//! `time = c * n^p` (log-log least squares), and report the
//! extrapolation to the paper's 1.33 M alongside the paper's value —
//! absolute numbers differ (different solver + hardware), the *shape*
//! (superlinear growth, prohibitive at millions of rows) is the claim
//! under test.

use fastsvdd::baselines::train_full;
use fastsvdd::bench::{emit, emit_text, paper, scaled};
use fastsvdd::util::stats::power_fit;
use fastsvdd::util::tables::{f, i, Table};
use fastsvdd::util::timer::fmt_duration;

fn main() {
    let d = paper::TWO_DONUT;
    let max: usize = std::env::var("FASTSVDD_FIG1_MAX")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(160_000);
    let mut sizes = vec![];
    let mut n = 5_000usize;
    while n <= max {
        sizes.push(scaled(n, 1000));
        n *= 2;
    }

    let mut t = Table::new(
        "Fig 1: full-SVDD training time vs size (Two-Donut)",
        &["#Obs", "Time", "R^2", "#SV"],
    );
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for &rows in &sizes {
        let data = d.generate(rows, 42);
        let out = train_full(&data, &d.params()).expect("train failed");
        xs.push(rows as f64);
        ys.push(out.seconds);
        t.row(vec![
            i(rows),
            fmt_duration(out.seconds),
            f(out.model.r2(), 4),
            i(out.model.num_sv()),
        ]);
    }
    emit("fig1_training_time", &t);

    let (c, p) = power_fit(&xs, &ys);
    let extrapolated = c * (d.full_rows as f64).powf(p);
    let summary = format!(
        "power fit: time ~ {c:.3e} * n^{p:.2}\n\
         extrapolated full solve at n={}: {}  (paper's LIBSVM: {})\n\
         shape check: full-method cost grows with n while Table II's\n\
         sampling run on the same n is measured in milliseconds — the\n\
         gap the paper's Fig 1 motivates (exponent p depends on the\n\
         solver; LIBSVM's was superlinear, our WSS2+cache SMO fits\n\
         p = {p:.2} over this range).\n",
        d.full_rows,
        fmt_duration(extrapolated),
        d.paper_time_full,
    );
    print!("{summary}");
    emit_text("fig1_extrapolation.txt", &summary);
}
