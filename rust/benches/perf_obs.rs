//! Observability overhead bench: pins the cost of the tracing
//! instrumentation threaded through the hot paths (engine, sampling,
//! SMO, Gram, scoring) in both states:
//!
//! - tracing OFF (the default): a disabled span is one relaxed atomic
//!   load — the bench measures that cost directly (ns/span) and bounds
//!   the end-to-end overhead on the perf_hotpath sampling-train
//!   workload at well under 1% (`overhead_lt_1pct`, gated in CI);
//! - tracing ON: the same workload with the ring + a JSONL sink live,
//!   reported for information (and the run log doubles as the CI
//!   `bench-json` artifact's example trace).

use fastsvdd::bench::{emit, emit_text, measure, paper, results_dir, scaled};
use fastsvdd::obs;
use fastsvdd::sampling::{SamplingConfig, SamplingTrainer};
use fastsvdd::util::json::{num, obj, s, Json};
use fastsvdd::util::tables::{f, Table};

fn main() {
    let d = paper::BANANA;
    let rows = scaled(20_000, 2_000);
    let data = d.generate(rows, 42);
    let params = d.params();
    let cfg = SamplingConfig { sample_size: d.sample_size, ..Default::default() };
    let mut t = Table::new(
        "Perf: observability overhead (mean over measured iters)",
        &["path", "mean_ms", "min_ms", "note"],
    );

    // 1. raw disabled-span cost: enter + two field setters + drop.
    //    With tracing off the whole thing is one relaxed atomic load,
    //    so this is the unit cost every instrumented call site pays.
    obs::disable();
    const SPAN_LOOPS: usize = 1_000_000;
    let m_span = measure(1, 5, || {
        for i in 0..SPAN_LOOPS {
            let mut span = obs::Span::enter("bench.noop");
            if span.is_live() {
                span.u64("i", i as u64);
                span.u64("rows", 1);
            }
            std::hint::black_box(&span);
        }
    });
    let disabled_span_ns = m_span.mean * 1e9 / SPAN_LOOPS as f64;
    t.row(vec![
        format!("disabled span x{SPAN_LOOPS}"),
        f(m_span.mean * 1e3, 3),
        f(m_span.min * 1e3, 3),
        format!("{disabled_span_ns:.1} ns/span"),
    ]);

    // 2. the perf_hotpath sampling-train workload, tracing off
    let m_off = measure(1, 5, || SamplingTrainer::new(params, cfg).train(&data, 7).unwrap());
    t.row(vec![
        format!("sampling train, banana {rows} (obs off)"),
        f(m_off.mean * 1e3, 1),
        f(m_off.min * 1e3, 1),
        "-".into(),
    ]);

    // 3. count the events one train produces (ring drain + drop
    //    counter delta) so the disabled-path overhead can be bounded
    //    from measured quantities instead of guessed
    obs::drain();
    let dropped_before = obs::dropped();
    obs::enable();
    SamplingTrainer::new(params, cfg).train(&data, 7).unwrap();
    obs::disable();
    let events_per_train = obs::drain().len() as u64 + (obs::dropped() - dropped_before);

    // 4. same workload, tracing on with a JSONL sink (the worst case a
    //    user can configure); the log rides along in the CI artifacts
    let log_path = results_dir().join("perf_obs_run.jsonl");
    obs::install_sink(&log_path).expect("sink in results dir");
    obs::enable();
    let m_on = measure(1, 5, || SamplingTrainer::new(params, cfg).train(&data, 7).unwrap());
    obs::disable();
    obs::remove_sink();
    obs::drain();
    t.row(vec![
        format!("sampling train, banana {rows} (obs on + sink)"),
        f(m_on.mean * 1e3, 1),
        f(m_on.min * 1e3, 1),
        format!("{events_per_train} events/train"),
    ]);

    // The gated number: what the instrumentation costs when tracing is
    // off. Computed as events-per-train x measured ns-per-disabled-span
    // over the tracing-off train time — an upper bound built from two
    // measured quantities, immune to the run-to-run noise that an
    // off-vs-off A/B at the millisecond scale cannot resolve.
    let overhead_frac = events_per_train as f64 * disabled_span_ns * 1e-9 / m_off.mean;
    let on_frac = m_on.mean / m_off.mean - 1.0;
    t.row(vec![
        "tracing-off overhead bound".into(),
        "-".into(),
        "-".into(),
        format!("{:.4}% (on: {:+.1}%)", overhead_frac * 1e2, on_frac * 1e2),
    ]);

    emit("perf_obs", &t);

    // machine-readable summary for the CI bench-smoke gate
    let mut pairs = vec![
        ("bench", s("perf_obs")),
        ("rows", num(rows as f64)),
        ("disabled_span_ns", num(disabled_span_ns)),
        ("events_per_train", num(events_per_train as f64)),
        ("train_off_ms", num(m_off.mean * 1e3)),
        ("train_on_ms", num(m_on.mean * 1e3)),
        ("overhead_frac", num(overhead_frac)),
        ("overhead_lt_1pct", Json::Bool(overhead_frac < 0.01)),
    ];
    pairs.extend(fastsvdd::bench::isa_provenance());
    let json = obj(pairs);
    emit_text("BENCH_perf_obs.json", &json.to_string_pretty());
    println!("wrote results/BENCH_perf_obs.json");
    println!("wrote {} (example run log)", log_path.display());
}
