//! Figs 11 & 12 — Tennessee-Eastman-like data: F1-measure ratio and
//! processing time vs training size (paper section V-B).
//!
//! Paper protocol: 41 variables, sample size 42 (= #vars + 1), training
//! sizes 10 000..100 000 in steps of 5 000, fixed scoring mix of normal
//! + 20 fault modes. We run a coarser ladder and cap the *full* solves
//! (env FASTSVDD_TE_FULL_CAP, default 30 000 — the paper's own point is
//! that full training at 100 k takes minutes; sampling runs at every
//! size). Expected shape: ratio ~ 1 flat; full time grows, sampling flat.

use fastsvdd::baselines::train_full;
use fastsvdd::bench::{emit, scaled};
use fastsvdd::data::tennessee::{TennesseePlant, DIM};
use fastsvdd::sampling::{SamplingConfig, SamplingTrainer};
use fastsvdd::scoring::{F1Score, Scorer};
use fastsvdd::svdd::bandwidth::median_heuristic;
use fastsvdd::svdd::SvddParams;
use fastsvdd::util::tables::{f, i, Table};
use fastsvdd::util::timer::Stopwatch;

fn main() {
    let full_cap: usize = std::env::var("FASTSVDD_TE_FULL_CAP")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(100_000);
    let sizes: Vec<usize> = [10_000, 20_000, 40_000, 70_000, 100_000]
        .iter()
        .map(|&n| scaled(n, 2000))
        .collect();
    let plant = TennesseePlant::default();
    let scoring = plant.scoring(scaled(10_000, 1000), scaled(10_000, 1000), 99);
    let bw = median_heuristic(&plant.training(2000, 1), 20_000, 1);
    let params = SvddParams::gaussian(bw, 0.005);
    println!("tennessee: bw={bw:.2} f=0.005 sample_size={}", DIM + 1);

    let mut t = Table::new(
        "Figs 11+12: Tennessee Eastman — F1 ratio & time vs training size",
        &["#train", "F1_full", "F1_sampling", "ratio", "t_full_s", "t_sampling_s", "speedup"],
    );
    for &n in &sizes {
        let train_data = plant.training(n, 42);

        let cfg = SamplingConfig { sample_size: DIM + 1, ..Default::default() };
        let sw = Stopwatch::start();
        let samp = SamplingTrainer::new(params, cfg).train(&train_data, 7).unwrap().model;
        let t_samp = sw.elapsed_secs();
        let f1_samp = F1Score::compute(
            &scoring.labels,
            &Scorer::native(&samp).inside_batch(&scoring.data).unwrap(),
        );

        let (f1_full_s, t_full_s, ratio_s, speedup_s) = if n <= full_cap {
            let sw = Stopwatch::start();
            let full = train_full(&train_data, &params).unwrap().model;
            let t_full = sw.elapsed_secs();
            let f1_full = F1Score::compute(
                &scoring.labels,
                &Scorer::native(&full).inside_batch(&scoring.data).unwrap(),
            );
            (
                f(f1_full.f1, 4),
                f(t_full, 3),
                f(f1_samp.f1 / f1_full.f1.max(1e-12), 4),
                f(t_full / t_samp.max(1e-9), 1),
            )
        } else {
            ("(capped)".into(), "(capped)".into(), "-".into(), "-".into())
        };

        t.row(vec![
            i(n),
            f1_full_s,
            f(f1_samp.f1, 4),
            ratio_s,
            t_full_s,
            f(t_samp, 3),
            speedup_s,
        ]);
    }
    emit("fig1112_tennessee", &t);
}
