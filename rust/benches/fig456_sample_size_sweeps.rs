//! Figs 4, 5, 6 — sampling-method run time and iteration count vs
//! sample size n (x-axis 3..=20), one figure per data set (Banana,
//! Star, Two-Donut). The paper marks the minimum-time sample size with
//! a reference line; we print it per table.
//!
//! Expected shape: time has a U-ish curve (tiny n -> many iterations;
//! large n -> costlier solves), iterations decrease in n.

use fastsvdd::bench::{emit, paper, scaled};
use fastsvdd::sampling::{SamplingConfig, SamplingTrainer};
use fastsvdd::util::stats::mean;
use fastsvdd::util::tables::{f, i, Table};
use fastsvdd::util::timer::Stopwatch;

fn main() {
    let reps: usize = std::env::var("FASTSVDD_SWEEP_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(5);
    for (fig, d) in [(4, paper::BANANA), (5, paper::STAR), (6, paper::TWO_DONUT)] {
        let rows = scaled(d.full_rows.min(100_000), 5000);
        let data = d.generate(rows, 42);
        let mut t = Table::new(
            format!("Fig {fig}: {} — run time & iterations vs sample size (rows={rows}, reps={reps})", d.name),
            &["n", "time_mean_s", "time_min_s", "iters_mean", "R2_mean", "SV_mean"],
        );
        let mut best = (f64::INFINITY, 0usize);
        for n in 3..=20 {
            let mut times = Vec::new();
            let mut iters = Vec::new();
            let mut r2s = Vec::new();
            let mut svs = Vec::new();
            for rep in 0..reps {
                let cfg = SamplingConfig { sample_size: n, ..Default::default() };
                let sw = Stopwatch::start();
                let out = SamplingTrainer::new(d.params(), cfg)
                    .train(&data, 1000 + rep as u64)
                    .expect("sampling failed");
                times.push(sw.elapsed_secs());
                iters.push(out.iterations as f64);
                r2s.push(out.model.r2());
                svs.push(out.model.num_sv() as f64);
            }
            let tm = mean(&times);
            if tm < best.0 {
                best = (tm, n);
            }
            t.row(vec![
                i(n),
                f(tm, 4),
                f(times.iter().cloned().fold(f64::INFINITY, f64::min), 4),
                f(mean(&iters), 1),
                f(mean(&r2s), 4),
                f(mean(&svs), 1),
            ]);
        }
        emit(&format!("fig{fig}_{}_sweep", d.name), &t);
        println!(
            "minimum-time sample size for {}: n={} ({:.3}s)  [paper: n={}]\n",
            d.name, best.1, best.0, d.sample_size
        );
    }
}
