//! Table II — SVDD results using the sampling method, at the paper's
//! per-dataset sample sizes (Banana 6, Two-Donut 11, Star 11), run on
//! the paper's *full* training sizes (sampling never materializes more
//! than the drawn rows per solve, so the 1.33 M-row Two-Donut is fine).

use fastsvdd::bench::{emit, paper, scaled};
use fastsvdd::sampling::{SamplingConfig, SamplingTrainer};
use fastsvdd::util::tables::{f, i, Table};
use fastsvdd::util::timer::{fmt_duration, Stopwatch};

fn main() {
    let mut t = Table::new(
        "Table II: sampling method (sample size in parens; paper values in [brackets])",
        &["Data(n)", "#Obs", "Iters", "[Iters]", "R^2", "[R^2]", "#SV", "[#SV]", "Time", "[Time]"],
    );
    for d in paper::ALL {
        let rows = scaled(d.full_rows, 5000);
        let data = d.generate(rows, 42);
        let cfg = SamplingConfig { sample_size: d.sample_size, ..Default::default() };
        let sw = Stopwatch::start();
        let out = SamplingTrainer::new(d.params(), cfg)
            .train(&data, 7)
            .expect("sampling training failed");
        let secs = sw.elapsed_secs();
        t.row(vec![
            format!("{}({})", d.name, d.sample_size),
            i(rows),
            i(out.iterations),
            i(d.paper_iters_sampling),
            f(out.model.r2(), 4),
            f(d.paper_r2_sampling, 3),
            i(out.model.num_sv()),
            i(d.paper_sv_sampling),
            fmt_duration(secs),
            d.paper_time_sampling.into(),
        ]);
    }
    emit("table2_sampling", &t);
}
