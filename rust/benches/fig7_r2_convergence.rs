//! Fig 7 — threshold R^2 vs iteration for the Banana data at sample
//! size 6: the paper's convergence illustration (R^2 rises from the
//! first small sample's value and plateaus at the full-data value).

use fastsvdd::baselines::train_full;
use fastsvdd::bench::{emit_text, paper, scaled};
use fastsvdd::sampling::{SamplingConfig, SamplingTrainer};

fn main() {
    let d = paper::BANANA;
    let rows = scaled(d.full_rows, 3000);
    let data = d.generate(rows, 42);
    let cfg = SamplingConfig {
        sample_size: d.sample_size,
        record_trace: true,
        ..Default::default()
    };
    let out = SamplingTrainer::new(d.params(), cfg).train(&data, 7).unwrap();

    let mut csv = String::from("iteration,r2,num_sv,center_delta\n");
    for t in &out.trace {
        csv.push_str(&format!("{},{},{},{}\n", t.iteration, t.r2, t.num_sv, t.center_delta));
    }
    emit_text("fig7_r2_trace.csv", &csv);

    // ASCII sparkline of R^2 over iterations
    let r2s: Vec<f64> = out.trace.iter().map(|t| t.r2).collect();
    let (lo, hi) = (
        r2s.iter().cloned().fold(f64::INFINITY, f64::min),
        r2s.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    );
    let glyphs = ['_', '.', '-', '=', '^', '#'];
    let line: String = r2s
        .iter()
        .map(|&v| glyphs[(((v - lo) / (hi - lo).max(1e-12)) * 5.0).round() as usize])
        .collect();
    println!("Fig 7: R^2 trace (banana, n={}):", d.sample_size);
    println!("  iter 0..{}  R^2 {lo:.4} -> {hi:.4}", out.iterations);
    println!("  {line}");

    let full = train_full(&data, &d.params()).unwrap();
    println!(
        "  final sampling R^2 = {:.4}, full R^2 = {:.4} (ratio {:.3}), converged={} at iter {}",
        out.model.r2(),
        full.model.r2(),
        out.model.r2() / full.model.r2(),
        out.converged,
        out.iterations
    );
}
