//! Parallel execution subsystem perf: single- vs multi-thread
//! throughput of the pooled hot paths, on a Tennessee-Eastman-sized
//! workload (41-dim plant telemetry).
//!
//! - Gram matrix: `parallel::gram` rows/blocks at 1/2/4/auto threads,
//!   entries/s + speedup vs 1 thread, with a bit-identity check against
//!   the single-thread block-path reference;
//! - batch scoring: `SvddModel::dist2_batch_pooled` rows/s at 1 vs
//!   multi threads, bit-identity across thread counts;
//! - multi-candidate training: `candidates_per_iter` K=4 vs the
//!   sequential K=1 Algorithm 1 (wall time + iterations to converge).
//!
//! Emits the usual table plus `results/BENCH_perf_parallel.json` — the
//! file the CI `bench-smoke` job diffs against
//! `ci/baselines/BENCH_perf_parallel.json` (see ci/check_perf.py).

use fastsvdd::bench::{emit, emit_text, measure, measure_once, scaled};
use fastsvdd::data::tennessee::TennesseePlant;
use fastsvdd::parallel::{gram, Pool};
use fastsvdd::sampling::{SamplingConfig, SamplingTrainer};
use fastsvdd::svdd::bandwidth::median_heuristic;
use fastsvdd::svdd::smo::DenseKernel;
use fastsvdd::svdd::{train, SvddParams};
use fastsvdd::util::json::{num, obj, s, Json};
use fastsvdd::util::tables::{f, Table};

fn main() {
    let plant = TennesseePlant::default();
    let rows = scaled(1_500, 384);
    let data = plant.training(rows, 42);
    let dim = data.cols();
    let bw = median_heuristic(&data, 20_000, 1);
    let kernel = fastsvdd::svdd::Kernel::gaussian(bw);

    let auto = Pool::auto().threads();
    // thread ladder: 1, then 2/4 where the machine has them, then auto.
    // The last entry is the "mt" number the CI gate consumes, so never
    // oversubscribe a tiny runner into a meaningless mt measurement —
    // on a single core the ladder is just [1] and threads_mt = 1 tells
    // ci/check_perf.py to skip the speedup floor.
    let mut counts = vec![1usize];
    for t in [2usize, 4] {
        if t <= auto {
            counts.push(t);
        }
    }
    if auto > 4 {
        counts.push(auto);
    }

    let mut t = Table::new(
        &format!("Perf: parallel subsystem ({rows}x{dim} tennessee, {auto} cores)"),
        &["path", "threads", "mean_ms", "throughput", "vs 1 thread"],
    );

    // ---- Gram matrix: parallel row blocks ----
    // bit-identity reference: the block path at one thread (the scalar
    // `from_data_serial` reference agrees to tolerance only — that gap
    // is gated by the perf_kernel bench, not here)
    let entries = (rows * rows) as f64;
    let serial_ref = DenseKernel::from_data_pooled(&data, kernel, Pool::serial());
    let mut gram_tp = Vec::new(); // (threads, entries/s)
    let mut gram_identical = true;
    for &threads in &counts {
        let pool = Pool::new(threads);
        let g = gram(&data, kernel, pool);
        gram_identical &= g == serial_ref.as_slice();
        let m = measure(1, 3, || gram(&data, kernel, pool));
        let tp = entries / m.mean;
        gram_tp.push((threads, tp));
        let base = gram_tp[0].1;
        t.row(vec![
            "gram (row blocks)".into(),
            threads.to_string(),
            f(m.mean * 1e3, 1),
            format!("{:.2}M entries/s", tp / 1e6),
            format!("{:.2}x", tp / base),
        ]);
    }
    assert!(gram_identical, "parallel gram diverged from serial reference");

    // ---- batch scoring: parallel row chunks ----
    let model = train(
        &data.gather(&(0..rows.min(600)).collect::<Vec<_>>()),
        &SvddParams::gaussian(bw, 0.01),
    )
    .unwrap();
    let zs = plant.training(scaled(16_384, 4_096), 9);
    let score_serial = model.dist2_batch_pooled(&zs, Pool::serial());
    let mut score_tp = Vec::new();
    let mut score_identical = true;
    for &threads in &counts {
        let pool = Pool::new(threads);
        score_identical &= model.dist2_batch_pooled(&zs, pool) == score_serial;
        let m = measure(1, 5, || model.dist2_batch_pooled(&zs, pool));
        let tp = zs.rows() as f64 / m.mean;
        score_tp.push((threads, tp));
        let base = score_tp[0].1;
        t.row(vec![
            format!("scoring ({} SVs)", model.num_sv()),
            threads.to_string(),
            f(m.mean * 1e3, 2),
            format!("{:.0}k rows/s", tp / 1e3),
            format!("{:.2}x", tp / base),
        ]);
    }
    assert!(score_identical, "parallel scoring diverged from serial");

    // ---- multi-candidate training: K=4 concurrent samples/iter ----
    let params = SvddParams::gaussian(bw, 0.005);
    let cfg1 = SamplingConfig { sample_size: dim + 1, ..Default::default() };
    let cfg4 = SamplingConfig { candidates_per_iter: 4, ..cfg1 };
    let (k1, t_k1) = measure_once(|| SamplingTrainer::new(params, cfg1).train(&data, 7).unwrap());
    let (k4, t_k4) = measure_once(|| SamplingTrainer::new(params, cfg4).train(&data, 7).unwrap());
    t.row(vec![
        "sampling train K=1".into(),
        "1".into(),
        f(t_k1 * 1e3, 1),
        format!("{} iters", k1.iterations),
        "1.00x".into(),
    ]);
    t.row(vec![
        "sampling train K=4 (best R^2)".into(),
        auto.to_string(),
        f(t_k4 * 1e3, 1),
        format!("{} iters", k4.iterations),
        format!("{:.2}x iters", k1.iterations as f64 / k4.iterations.max(1) as f64),
    ]);

    emit("perf_parallel", &t);

    let mt = *gram_tp.last().unwrap();
    let mt_score = *score_tp.last().unwrap();
    let mut fields = vec![
        ("bench", s("perf_parallel")),
        ("rows", num(rows as f64)),
        ("dim", num(dim as f64)),
        ("cores", num(auto as f64)),
        ("threads_mt", num(mt.0 as f64)),
        ("gram_entries_per_s_1t", num(gram_tp[0].1)),
    ];
    // only emit the 4-thread rung if it actually ran — re-baselines copy
    // this file verbatim, so no mislabeled fallbacks
    if let Some(&(_, tp4)) = gram_tp.iter().find(|(th, _)| *th == 4) {
        fields.push(("gram_entries_per_s_4t", num(tp4)));
    }
    fields.extend([
        ("gram_entries_per_s_mt", num(mt.1)),
        ("gram_speedup_mt", num(mt.1 / gram_tp[0].1)),
        ("gram_bit_identical", Json::Bool(gram_identical)),
        ("score_rows_per_s_1t", num(score_tp[0].1)),
        ("score_rows_per_s_mt", num(mt_score.1)),
        ("score_speedup_mt", num(mt_score.1 / score_tp[0].1)),
        ("score_bit_identical", Json::Bool(score_identical)),
        ("k1_iterations", num(k1.iterations as f64)),
        ("k4_iterations", num(k4.iterations as f64)),
        ("k1_train_ms", num(t_k1 * 1e3)),
        ("k4_train_ms", num(t_k4 * 1e3)),
        ("k1_r2", num(k1.model.r2())),
        ("k4_r2", num(k4.model.r2())),
    ]);
    fields.extend(fastsvdd::bench::isa_provenance());
    let json = obj(fields);
    emit_text("BENCH_perf_parallel.json", &json.to_string_pretty());
    println!("wrote results/BENCH_perf_parallel.json");
}
