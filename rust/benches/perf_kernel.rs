//! Batched kernel-compute layer perf: the norm-cached, tile-blocked
//! block path (`linalg` + `Kernel::eval_block`) against the scalar
//! per-pair `Kernel::eval` reference, on a Tennessee-Eastman-sized
//! workload (41-dim plant telemetry).
//!
//! - Gram matrix: block path (`parallel::gram`) vs the scalar serial
//!   triangle (`DenseKernel::from_data_serial`) at 1 thread — the
//!   single-core speedup the layer exists for — plus the block path at
//!   auto threads, with bit-identity asserted across thread counts
//!   {1, 2, 8} and block-vs-scalar closeness checked to tight
//!   tolerance;
//! - ISA arms: the same block Gram and the raw `linalg::dot_block`
//!   panel microkernel are timed twice, once forced onto the scalar
//!   arm and once on the host's best SIMD arm, with per-entry
//!   **bitwise** equality asserted between the two (the fixed
//!   summation-order contract — skipped only if `FASTSVDD_ISA=fma`
//!   opted into fused rounding). The dot-panel ratio is the pure
//!   microkernel speedup (target >= 4x on AVX2); the Gram ratio is
//!   smaller because per-entry `exp` stays scalar by design;
//! - batch scoring: `SvddModel::dist2_batch_pooled` (block panels) at 1
//!   and auto threads, bit-identity across thread counts, plus the
//!   opt-in f32 panel path (`ModelF32`) at 1 thread.
//!
//! Emits the usual table plus `results/BENCH_perf_kernel.json` — the
//! file the CI `bench-smoke` job gates against
//! `ci/baselines/BENCH_perf_kernel.json` (see ci/check_perf.py and
//! ci/baselines/README.md for the capture procedure). The JSON carries
//! `isa`/`arch` so the gate can prove dispatch engaged on the runner.

use fastsvdd::bench::{emit, emit_text, isa_provenance, measure, scaled};
use fastsvdd::data::tennessee::TennesseePlant;
use fastsvdd::linalg::{self, isa, Isa};
use fastsvdd::parallel::{gram, Pool};
use fastsvdd::svdd::bandwidth::median_heuristic;
use fastsvdd::svdd::smo::DenseKernel;
use fastsvdd::svdd::{train, Kernel, SvddParams};
use fastsvdd::util::json::{num, obj, s, Json};
use fastsvdd::util::tables::{f, Table};

fn main() {
    let plant = TennesseePlant::default();
    let rows = scaled(1_200, 384);
    let data = plant.training(rows, 42);
    let dim = data.cols();
    let bw = median_heuristic(&data, 20_000, 1);
    let kernel = Kernel::gaussian(bw);
    let auto = Pool::auto().threads();
    let entries = (rows * rows) as f64;
    // the arm Auto resolves to on this host (honours FASTSVDD_ISA)
    let best = isa::install(Isa::Auto).expect("auto is always installable");

    let mut t = Table::new(
        &format!(
            "Perf: kernel compute layer ({rows}x{dim} tennessee, {auto} cores, isa {best})"
        ),
        &["path", "threads", "mean_ms", "throughput", "vs scalar 1t"],
    );

    // ---- correctness before timing: block bit-identity + scalar gap ----
    let block_1t = gram(&data, kernel, Pool::serial());
    let mut block_identical = true;
    for threads in [2usize, 8] {
        block_identical &= gram(&data, kernel, Pool::new(threads)) == block_1t;
    }
    assert!(block_identical, "block gram diverged across thread counts");
    let scalar_ref = DenseKernel::from_data_serial(&data, kernel);
    let mut block_vs_scalar_close = true;
    let mut max_gap = 0.0f64;
    for (b, sc) in block_1t.iter().zip(scalar_ref.as_slice()) {
        let gap = (b - sc).abs() / sc.abs().max(1.0);
        max_gap = max_gap.max(gap);
        block_vs_scalar_close &= gap <= 1e-10;
    }
    assert!(
        block_vs_scalar_close,
        "block path drifted from the scalar reference (max rel gap {max_gap:.3e})"
    );

    // ---- per-arm: scalar arm first, then the best arm (left installed
    // for the rest of the bench, matching the default dispatch) ----
    let a_rows = 256usize.min(rows);
    let mut panel = vec![0.0f64; a_rows * rows];
    let panel_dots = (a_rows * rows) as f64;

    isa::install(Isa::Scalar).expect("scalar is always available");
    let gram_scalar_arm = gram(&data, kernel, Pool::serial());
    let m_gram_scal = measure(1, 3, || gram(&data, kernel, Pool::serial()));
    let gram_tp_scalar_arm = entries / m_gram_scal.mean;
    let m_dot_scal = measure(1, 5, || {
        linalg::dot_block(&data, 0..a_rows, &data, 0..rows, &mut panel)
    });
    let dot_tp_scalar = panel_dots / m_dot_scal.mean;
    let panel_scalar_arm = panel.clone();

    isa::install(best).expect("best arm came from detection");
    let gram_simd_arm = gram(&data, kernel, Pool::serial());
    let m_dot_simd = measure(1, 5, || {
        linalg::dot_block(&data, 0..a_rows, &data, 0..rows, &mut panel)
    });
    let dot_tp_simd = panel_dots / m_dot_simd.mean;
    let dot_speedup = dot_tp_simd / dot_tp_scalar;

    // every arm except opt-in FMA honours the fixed summation order
    // bit for bit — equality here proves dispatch preserves results
    let gram_simd_bit_identical = if best == Isa::Fma {
        gram_simd_arm
            .iter()
            .zip(&gram_scalar_arm)
            .all(|(a, b)| (a - b).abs() <= 1e-12 * b.abs().max(1.0))
    } else {
        gram_simd_arm == gram_scalar_arm && panel == panel_scalar_arm
    };
    assert!(
        gram_simd_bit_identical,
        "{} arm diverged from the scalar arm",
        best
    );

    // ---- Gram throughput: scalar reference vs block, 1 thread ----
    let m_scalar = measure(1, 3, || DenseKernel::from_data_serial(&data, kernel));
    let scalar_tp = entries / m_scalar.mean;
    t.row(vec![
        "gram scalar (eval reference)".into(),
        "1".into(),
        f(m_scalar.mean * 1e3, 1),
        format!("{:.2}M entries/s", scalar_tp / 1e6),
        "1.00x".into(),
    ]);

    t.row(vec![
        "gram block (scalar arm)".into(),
        "1".into(),
        f(m_gram_scal.mean * 1e3, 1),
        format!("{:.2}M entries/s", gram_tp_scalar_arm / 1e6),
        format!("{:.2}x", gram_tp_scalar_arm / scalar_tp),
    ]);

    let m_block1 = measure(1, 3, || gram(&data, kernel, Pool::serial()));
    let block_tp_1t = entries / m_block1.mean;
    let speedup_1t = block_tp_1t / scalar_tp;
    let gram_arm_speedup = block_tp_1t / gram_tp_scalar_arm;
    t.row(vec![
        format!("gram block ({best} arm)"),
        "1".into(),
        f(m_block1.mean * 1e3, 1),
        format!("{:.2}M entries/s", block_tp_1t / 1e6),
        format!("{speedup_1t:.2}x"),
    ]);

    for (arm, m, tp) in [
        (Isa::Scalar, &m_dot_scal, dot_tp_scalar),
        (best, &m_dot_simd, dot_tp_simd),
    ] {
        t.row(vec![
            format!("dot_block panel ({arm} arm)"),
            "1".into(),
            f(m.mean * 1e3, 2),
            format!("{:.1}M dots/s", tp / 1e6),
            format!("{:.2}x", tp / dot_tp_scalar),
        ]);
    }

    // ---- Gram throughput: block, all cores ----
    let threads_mt = auto;
    let pool_mt = Pool::new(threads_mt);
    let m_blockmt = measure(1, 3, || gram(&data, kernel, pool_mt));
    let block_tp_mt = entries / m_blockmt.mean;
    t.row(vec![
        format!("gram block ({best} arm)"),
        threads_mt.to_string(),
        f(m_blockmt.mean * 1e3, 1),
        format!("{:.2}M entries/s", block_tp_mt / 1e6),
        format!("{:.2}x", block_tp_mt / scalar_tp),
    ]);

    // ---- batch scoring on the block path ----
    let model = train(
        &data.gather(&(0..rows.min(600)).collect::<Vec<_>>()),
        &SvddParams::gaussian(bw, 0.01),
    )
    .unwrap();
    let zs = plant.training(scaled(16_384, 4_096), 9);
    let score_1t = model.dist2_batch_pooled(&zs, Pool::serial());
    let mut score_identical = true;
    for threads in [2usize, 8] {
        score_identical &= model.dist2_batch_pooled(&zs, Pool::new(threads)) == score_1t;
    }
    assert!(score_identical, "block scoring diverged across thread counts");
    let mut score_tp = Vec::new();
    for threads in [1usize, threads_mt] {
        let pool = Pool::new(threads);
        let m = measure(1, 5, || model.dist2_batch_pooled(&zs, pool));
        let tp = zs.rows() as f64 / m.mean;
        score_tp.push(tp);
        t.row(vec![
            format!("scoring block ({} SVs)", model.num_sv()),
            threads.to_string(),
            f(m.mean * 1e3, 2),
            format!("{:.0}k rows/s", tp / 1e3),
            format!("{:.2}x", tp / score_tp[0]),
        ]);
    }

    // ---- opt-in f32 panel path (--precision f32) ----
    let f32m = model.to_f32();
    let m_f32 = measure(1, 5, || f32m.dist2_batch_pooled(&zs, Pool::serial()));
    let score_tp_f32 = zs.rows() as f64 / m_f32.mean;
    t.row(vec![
        format!("scoring f32 panels ({} SVs)", model.num_sv()),
        "1".into(),
        f(m_f32.mean * 1e3, 2),
        format!("{:.0}k rows/s", score_tp_f32 / 1e3),
        format!("{:.2}x", score_tp_f32 / score_tp[0]),
    ]);

    emit("perf_kernel", &t);
    println!(
        "dot_block panel, {best} vs scalar arm at 1 thread: {dot_speedup:.2}x \
         (target >= 4x on AVX2; gram end-to-end {gram_arm_speedup:.2}x — \
         per-entry exp stays scalar by design)"
    );
    println!(
        "block vs scalar gram at 1 thread: {speedup_1t:.2}x \
         (max rel gap {max_gap:.2e}; target >= 2x)"
    );

    let mut pairs = vec![
        ("bench", s("perf_kernel")),
        ("rows", num(rows as f64)),
        ("dim", num(dim as f64)),
        ("cores", num(auto as f64)),
        ("threads_mt", num(threads_mt as f64)),
        ("gram_scalar_entries_per_s_1t", num(scalar_tp)),
        ("gram_block_entries_per_s_scalar_1t", num(gram_tp_scalar_arm)),
        ("gram_block_entries_per_s_1t", num(block_tp_1t)),
        ("gram_block_vs_scalar_1t", num(speedup_1t)),
        ("gram_simd_vs_scalar_block_1t", num(gram_arm_speedup)),
        ("gram_block_entries_per_s_mt", num(block_tp_mt)),
        ("gram_block_identical", Json::Bool(block_identical)),
        ("gram_simd_bit_identical", Json::Bool(gram_simd_bit_identical)),
        ("gram_block_vs_scalar_close", Json::Bool(block_vs_scalar_close)),
        ("gram_block_vs_scalar_max_rel_gap", num(max_gap)),
        ("dot_block_dots_per_s_scalar_1t", num(dot_tp_scalar)),
        ("dot_block_dots_per_s_simd_1t", num(dot_tp_simd)),
        ("dot_block_simd_vs_scalar_1t", num(dot_speedup)),
        ("score_rows_per_s_1t", num(score_tp[0])),
        ("score_rows_per_s_mt", num(score_tp[1])),
        ("score_rows_per_s_f32_1t", num(score_tp_f32)),
        ("score_bit_identical", Json::Bool(score_identical)),
    ];
    pairs.extend(isa_provenance());
    let json = obj(pairs);
    emit_text("BENCH_perf_kernel.json", &json.to_string_pretty());
    println!("wrote results/BENCH_perf_kernel.json");
}
