//! Lifecycle perf: what a model hot-swap costs the serve path, and what
//! a warm start saves the retrain path.
//!
//! - p50/p99 single-request score latency against a live `ScoreServer`,
//!   first with a quiet model slot, then while a swap storm replaces
//!   the served model every ~500us — the zero-downtime claim, measured;
//! - cold-start vs warm-start sampling retrain wall time + iteration
//!   count on the banana set (the drift-retrain path of
//!   `registry::Lifecycle`).
//!
//! Emits the usual table plus `results/BENCH_perf_hotswap.json`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use fastsvdd::bench::{emit, emit_text, measure_once, scaled};
use fastsvdd::data::{banana::Banana, Generator};
use fastsvdd::sampling::{SamplingConfig, SamplingTrainer};
use fastsvdd::scoring::{BatchPolicy, ScoreClient, ScoreServer};
use fastsvdd::svdd::SvddParams;
use fastsvdd::util::json::{num, obj, s, Json};
use fastsvdd::util::stats::quantile;
use fastsvdd::util::tables::{f, Table};
use fastsvdd::util::timer::Stopwatch;

fn main() {
    let rows = scaled(20_000, 2_000);
    let data = Banana::default().generate(rows, 42);
    let params = SvddParams::gaussian(0.35, 0.001);
    let cfg = SamplingConfig { sample_size: 6, ..Default::default() };
    let trainer = SamplingTrainer::new(params, cfg);

    // ---- retrain: cold vs warm (the Lifecycle drift path) ----
    let (cold, t_cold) = measure_once(|| trainer.train(&data, 7).unwrap());
    let (warm, t_warm) = measure_once(|| trainer.train_warm(&data, 13, &cold.model).unwrap());
    assert!(warm.warm_start && !cold.warm_start);

    // a second model (shifted regime) to swap against
    let mut shifted = Banana::default().generate(rows.min(4_000), 2);
    for i in 0..shifted.rows() {
        shifted.row_mut(i)[0] += 6.0;
    }
    let other = trainer.train(&shifted, 5).unwrap().model;

    // ---- serve-path latency across swaps ----
    let policy = BatchPolicy {
        target_batch: 64,
        linger: Duration::from_micros(200),
        capacity: 1 << 16,
        ..BatchPolicy::default()
    };
    let server = ScoreServer::spawn("127.0.0.1:0", cold.model.clone(), policy, |m, zs| {
        Ok(m.dist2_batch(zs))
    })
    .unwrap();
    let client = ScoreClient::connect(server.addr()).unwrap();
    let zs = Banana::default().generate(8, 9);
    let requests = scaled(400, 50);

    let lap = |client: &ScoreClient, n: usize| -> Vec<f64> {
        let mut lat = Vec::with_capacity(n);
        for _ in 0..n {
            let sw = Stopwatch::start();
            client.score(&zs).unwrap();
            lat.push(sw.elapsed_secs());
        }
        lat
    };
    // warm the connection + batcher, then the quiet baseline
    lap(&client, requests / 10);
    let quiet = lap(&client, requests);

    // swap storm: the slot flips models every ~500us while we measure
    let stop = Arc::new(AtomicBool::new(false));
    let slot = server.slot();
    let swapper = {
        let stop = stop.clone();
        let slot = slot.clone();
        let (a, b) = (cold.model.clone(), other.clone());
        std::thread::spawn(move || {
            let mut flip = false;
            while !stop.load(Ordering::Relaxed) {
                slot.swap(if flip { a.clone() } else { b.clone() }).unwrap();
                flip = !flip;
                std::thread::sleep(Duration::from_micros(500));
            }
        })
    };
    let storm = lap(&client, requests);
    stop.store(true, Ordering::Relaxed);
    swapper.join().unwrap();
    let swaps = slot.epoch();
    client.close();

    let p = |xs: &[f64], q: f64| quantile(xs, q) * 1e6; // -> us
    let mut t = Table::new(
        "Perf: hot-swap serving + warm-start retrain",
        &["path", "p50_us", "p99_us", "notes"],
    );
    t.row(vec![
        format!("score 8 rows, quiet slot ({requests} reqs)"),
        f(p(&quiet, 0.5), 1),
        f(p(&quiet, 0.99), 1),
        "-".into(),
    ]);
    t.row(vec![
        format!("score 8 rows, swap storm ({requests} reqs)"),
        f(p(&storm, 0.5), 1),
        f(p(&storm, 0.99), 1),
        format!("{swaps} swaps, zero errors"),
    ]);
    t.row(vec![
        "cold sampling retrain".into(),
        f(t_cold * 1e3, 1),
        "-".into(),
        format!("{} iterations (ms in p50 col)", cold.iterations),
    ]);
    t.row(vec![
        "warm sampling retrain".into(),
        f(t_warm * 1e3, 1),
        "-".into(),
        format!(
            "{} iterations, {:.2}x faster (ms in p50 col)",
            warm.iterations,
            t_cold / t_warm
        ),
    ]);
    emit("perf_hotswap", &t);

    let mut pairs = vec![
        ("bench", s("perf_hotswap")),
        ("rows", num(rows as f64)),
        ("requests", num(requests as f64)),
        ("p50_quiet_us", num(p(&quiet, 0.5))),
        ("p99_quiet_us", num(p(&quiet, 0.99))),
        ("p50_swap_us", num(p(&storm, 0.5))),
        ("p99_swap_us", num(p(&storm, 0.99))),
        ("swaps_during_storm", num(swaps as f64)),
        ("score_errors", num(0.0)),
        ("cold_retrain_ms", num(t_cold * 1e3)),
        ("warm_retrain_ms", num(t_warm * 1e3)),
        ("cold_iterations", num(cold.iterations as f64)),
        ("warm_iterations", num(warm.iterations as f64)),
        ("warm_speedup", num(t_cold / t_warm)),
        ("cold_r2", num(cold.model.r2())),
        ("warm_r2", num(warm.model.r2())),
        ("converged", Json::Bool(cold.converged && warm.converged)),
    ];
    pairs.extend(fastsvdd::bench::isa_provenance());
    let json = obj(pairs);
    emit_text("BENCH_perf_hotswap.json", &json.to_string_pretty());
    println!("wrote results/BENCH_perf_hotswap.json");
}
