//! Fig 3 — scatter plots of the three data sets. Emits a CSV per data
//! set (for plotting) plus a coarse ASCII render so the shapes can be
//! eyeballed directly in the bench log.

use fastsvdd::bench::{emit_text, paper};
use fastsvdd::data::grid::Grid;
use fastsvdd::util::matrix::Matrix;

fn ascii_render(data: &Matrix, w: usize, h: usize) -> String {
    let g = Grid::covering(data, w, h, 0.05);
    let mut cells = vec![false; w * h];
    for r in 0..data.rows() {
        let (x, y) = (data.get(r, 0), data.get(r, 1));
        let jx = (((x - g.x0) / (g.x1 - g.x0)) * (w - 1) as f64).round() as usize;
        let jy = (((y - g.y0) / (g.y1 - g.y0)) * (h - 1) as f64).round() as usize;
        cells[jy.min(h - 1) * w + jx.min(w - 1)] = true;
    }
    let mut s = String::new();
    for row in (0..h).rev() {
        for col in 0..w {
            s.push(if cells[row * w + col] { '*' } else { ' ' });
        }
        s.push('\n');
    }
    s
}

fn main() {
    for d in paper::ALL {
        let data = d.generate(4000, 42);
        let mut csv = String::from("x,y\n");
        for i in 0..data.rows() {
            csv.push_str(&format!("{},{}\n", data.get(i, 0), data.get(i, 1)));
        }
        emit_text(&format!("fig3_scatter_{}.csv", d.name), &csv);
        let art = ascii_render(&data, 72, 28);
        println!("--- Fig 3: {} ---\n{art}", d.name);
        emit_text(&format!("fig3_scatter_{}.txt", d.name), &art);
    }
    println!("scatter CSVs written to results/");
}
