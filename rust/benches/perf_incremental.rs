//! Online-learning bench: the cost of keeping the model fresh after
//! every new observation, incremental state machine vs snapshot
//! retrain of the sliding window.
//!
//! The gated number is `incremental_speedup_vs_retrain`: per-event
//! wall-clock of one exact add/remove slide (including the amortized
//! staleness resyncs) against one full SMO solve on the same-width
//! window — the snapshot spelling of "model fresh after each event".
//! Both paths run back to back on the same machine and the same seeded
//! drift stream, so the ratio is machine-independent and gated as an
//! absolute floor in CI (>= 10x).
//!
//! The correctness flags ride along: `incremental_matches_batch`
//! (after the whole drift stream, the incremental model's R^2 agrees
//! with a batch solve on the final window within 1%) and
//! `add_remove_roundtrip` (adding a point and removing it again
//! restores the optimum) — the machine must be *exact*, not a decay
//! approximation, or the speedup is meaningless.
//!
//! Emits the usual table plus `results/BENCH_perf_incremental.json`.

use std::collections::VecDeque;

use fastsvdd::bench::{emit, emit_text, scaled};
use fastsvdd::data::{banana::Banana, Generator};
use fastsvdd::incremental::{IncrementalConfig, IncrementalSvdd};
use fastsvdd::sampling::{DriftStatus, StreamingConfig, StreamingSvdd};
use fastsvdd::svdd::{train, SvddParams};
use fastsvdd::util::json::{num, obj, s, Json};
use fastsvdd::util::matrix::Matrix;
use fastsvdd::util::tables::{f, Table};
use fastsvdd::util::timer::Stopwatch;

const WINDOW: usize = 256;

/// The drifted regime: the same banana translated in x, so the shift
/// is invisible to the per-point scale but moves the whole description.
fn shifted_banana(n: usize, seed: u64) -> Matrix {
    let mut m = Banana::default().generate(n, seed);
    for i in 0..m.rows() {
        m.row_mut(i)[0] += 8.0;
    }
    m
}

fn main() {
    let params = SvddParams::gaussian(0.35, 0.001);
    let events = scaled(2048, 512);
    let regime_a = Banana::default().generate(WINDOW, 42);
    let regime_b = shifted_banana(events, 43);

    let mut t = Table::new(
        "Perf: online learning (per-event model freshness)",
        &["case", "events", "wall_ms", "per_event_us"],
    );

    // ---- incremental: seed the window, then slide per event ----
    // stale_budget 512 spreads the forced full re-solves ~256 slides
    // apart (each slide is add+remove = 2 updates); divergence resyncs
    // stay at their default and fire whenever exactness demands one.
    let scfg = StreamingConfig {
        window: WINDOW,
        sample_size: 6,
        drift_threshold: 0.02,
        drift_patience: 1,
        incremental: true,
        stale_budget: 512,
    };
    let mut stream = StreamingSvdd::new(params, scfg, 7);
    let sw = Stopwatch::start();
    stream.push_batch(&regime_a).unwrap();
    let seed_ms = sw.elapsed_secs() * 1e3;
    let mut saw_drift = false;
    let sw = Stopwatch::start();
    for i in 0..regime_b.rows() {
        if let Some(DriftStatus::Drifted) = stream.push(regime_b.row(i)).unwrap() {
            saw_drift = true;
        }
    }
    let inc_ms = sw.elapsed_secs() * 1e3;
    let inc_per_event_us = inc_ms * 1e3 / events as f64;
    let inc = stream.incremental_state().expect("seeded");
    let inc_resyncs = inc.resyncs();
    t.row(vec!["seed window solve".into(), "1".into(), f(seed_ms, 1), f(seed_ms * 1e3, 1)]);
    t.row(vec![
        format!("incremental slide ({inc_resyncs} resyncs)"),
        events.to_string(),
        f(inc_ms, 1),
        f(inc_per_event_us, 1),
    ]);

    // ---- exactness: the slid model vs a batch solve on the final window ----
    let tail: Vec<Vec<f64>> = (regime_b.rows() - WINDOW..regime_b.rows())
        .map(|i| regime_b.row(i).to_vec())
        .collect();
    let final_window = Matrix::from_rows(&tail).unwrap();
    let batch = train(&final_window, &params).unwrap();
    let batch_rel = (inc.r2() - batch.r2()).abs() / batch.r2();
    let incremental_matches_batch = batch_rel < 0.01;

    // ---- snapshot alternative: full solve on the window per event ----
    // (a subset of events is enough — the per-event cost is flat)
    let snap_events = scaled(64, 16).min(events);
    let mut window: VecDeque<Vec<f64>> =
        (0..WINDOW).map(|i| regime_a.row(i).to_vec()).collect();
    let sw = Stopwatch::start();
    let mut snap_r2 = 0.0;
    for i in 0..snap_events {
        window.pop_front();
        window.push_back(regime_b.row(i).to_vec());
        let rows: Vec<Vec<f64>> = window.iter().cloned().collect();
        let m = train(&Matrix::from_rows(&rows).unwrap(), &params).unwrap();
        snap_r2 = m.r2();
    }
    let snap_ms = sw.elapsed_secs() * 1e3;
    let snap_per_event_us = snap_ms * 1e3 / snap_events as f64;
    let speedup = snap_per_event_us / inc_per_event_us;
    t.row(vec![
        "snapshot retrain".into(),
        snap_events.to_string(),
        f(snap_ms, 1),
        f(snap_per_event_us, 1),
    ]);
    t.row(vec![format!("speedup {:.1}x", speedup), "".into(), "".into(), "".into()]);

    // ---- roundtrip: add a probe, remove it, land back on the optimum ----
    let icfg = IncrementalConfig { stale_budget: 0, ..Default::default() };
    let mut rt = IncrementalSvdd::with_data(params, icfg, &regime_a).unwrap();
    let before = rt.r2();
    rt.add_point(&[9.0, -9.0]).unwrap();
    let slot = rt.len() - 1;
    rt.remove_point(slot).unwrap();
    let roundtrip_rel = (rt.r2() - before).abs() / before;
    let add_remove_roundtrip = roundtrip_rel < 1e-4;

    emit("perf_incremental", &t);

    let mut pairs = vec![
        ("bench", s("perf_incremental")),
        ("window", num(WINDOW as f64)),
        ("events", num(events as f64)),
        ("seed_wall_ms", num(seed_ms)),
        ("inc_wall_ms", num(inc_ms)),
        ("inc_per_event_us", num(inc_per_event_us)),
        ("inc_resyncs", num(inc_resyncs as f64)),
        ("snap_events", num(snap_events as f64)),
        ("snap_wall_ms", num(snap_ms)),
        ("snap_per_event_us", num(snap_per_event_us)),
        ("incremental_speedup_vs_retrain", num(speedup)),
        ("r2_incremental", num(inc.r2())),
        ("r2_batch_final_window", num(batch.r2())),
        ("r2_snapshot_last", num(snap_r2)),
        ("batch_rel_diff", num(batch_rel)),
        ("incremental_matches_batch", Json::Bool(incremental_matches_batch)),
        ("roundtrip_rel_diff", num(roundtrip_rel)),
        ("add_remove_roundtrip", Json::Bool(add_remove_roundtrip)),
        ("saw_drift", Json::Bool(saw_drift)),
    ];
    pairs.extend(fastsvdd::bench::isa_provenance());
    let json = obj(pairs);
    emit_text("BENCH_perf_incremental.json", &json.to_string_pretty());
    println!("wrote results/BENCH_perf_incremental.json");
    assert!(
        incremental_matches_batch,
        "incremental drifted {batch_rel} relative R^2 from the batch solve"
    );
    assert!(
        add_remove_roundtrip,
        "add/remove roundtrip moved R^2 by {roundtrip_rel}"
    );
}
