//! Figs 13–16 — the random-polygon simulation study (paper section VI).
//!
//! Protocol: for each vertex count k in {5..30}, generate random
//! polygons (paper: 20 per k), sample 600 interior training points,
//! label the 200x200 grid of the bounding box by true polygon
//! membership, train full + sampling (n = 5) for each Gaussian
//! bandwidth s in the paper's list, compute F1 of "inside", and report
//! box-whisker stats of the ratio F1_sampling / F1_full:
//!
//! - Fig 13: two example polygons (ASCII + CSV)
//! - Fig 14: ratio of the *best-s* F1 per polygon
//! - Fig 15: ratio per fixed s (six panels)
//! - Fig 16: pooled over all s

use fastsvdd::baselines::train_full;
use fastsvdd::bench::{emit, emit_text, scaled};
use fastsvdd::data::grid::Grid;
use fastsvdd::data::polygon::Polygon;
use fastsvdd::sampling::{SamplingConfig, SamplingTrainer};
use fastsvdd::scoring::{F1Score, Scorer};
use fastsvdd::svdd::{SvddModel, SvddParams};
use fastsvdd::util::stats::BoxStats;
use fastsvdd::util::tables::{f, i, Table};

const S_VALUES: [f64; 10] = [1.0, 1.44, 1.88, 2.33, 2.77, 3.22, 3.66, 4.11, 4.55, 5.0];
const VERTEX_COUNTS: [usize; 6] = [5, 10, 15, 20, 25, 30];
const TRAIN_POINTS: usize = 600;
const OUTLIER_FRACTION: f64 = 0.01;
const SAMPLE_SIZE: usize = 5;

fn f1_on_grid(model: &SvddModel, grid: &Grid, truth: &[bool]) -> f64 {
    let inside = Scorer::native(model).inside_batch(&grid.points()).unwrap();
    F1Score::compute(truth, &inside).f1
}

fn boxstats_row(label: String, xs: &[f64]) -> Vec<String> {
    let b = BoxStats::from(xs);
    vec![
        label,
        f(b.min, 3),
        f(b.q1, 3),
        f(b.median, 3),
        f(b.q3, 3),
        f(b.max, 3),
        f(b.mean, 3),
        i(b.n),
    ]
}

const BOX_HEADERS: [&str; 8] = ["group", "min", "q1", "median", "q3", "max", "mean", "n"];

fn main() {
    let polys_per_k: usize = std::env::var("FASTSVDD_POLY_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| scaled(20, 3));
    // grid matches the paper's 200x200; can be shrunk for smoke runs
    let grid_n: usize = std::env::var("FASTSVDD_POLY_GRID")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200);

    // ---- Fig 13: example polygons ----
    for (idx, k) in [(0u64, 7usize), (1u64, 25usize)] {
        let p = Polygon::random(k, 3.0, 5.0, 1000 + idx);
        let mut csv = String::from("x,y\n");
        for &(x, y) in p.vertices() {
            csv.push_str(&format!("{x},{y}\n"));
        }
        emit_text(&format!("fig13_polygon_k{k}.csv"), &csv);
    }
    println!("Fig 13: example polygon vertex CSVs written to results/");

    // ---- the sweep ----
    // ratios[k_index][s_index][poly] = F1_sampling / F1_full
    let mut ratios = vec![vec![Vec::new(); S_VALUES.len()]; VERTEX_COUNTS.len()];
    let mut best_ratio = vec![Vec::new(); VERTEX_COUNTS.len()]; // Fig 14

    for (ki, &k) in VERTEX_COUNTS.iter().enumerate() {
        for poly_idx in 0..polys_per_k {
            let seed = (k * 1000 + poly_idx) as u64;
            let poly = Polygon::random(k, 3.0, 5.0, seed);
            let train = poly.sample_interior(TRAIN_POINTS, seed ^ 0xABCD);
            let ((x0, y0), (x1, y1)) = poly.bbox();
            let grid = Grid { nx: grid_n, ny: grid_n, x0, x1, y0, y1 };
            let truth = grid.labels_from(|x, y| poly.contains(x, y));

            let mut best_full = f64::NEG_INFINITY;
            let mut best_samp = f64::NEG_INFINITY;
            for (si, &s) in S_VALUES.iter().enumerate() {
                let params = SvddParams::gaussian(s, OUTLIER_FRACTION);
                let full = train_full(&train, &params).unwrap().model;
                let cfg = SamplingConfig { sample_size: SAMPLE_SIZE, ..Default::default() };
                let samp = SamplingTrainer::new(params, cfg)
                    .train(&train, seed ^ 0x5A5A)
                    .unwrap()
                    .model;
                let f1f = f1_on_grid(&full, &grid, &truth);
                let f1s = f1_on_grid(&samp, &grid, &truth);
                ratios[ki][si].push(f1s / f1f.max(1e-12));
                best_full = best_full.max(f1f);
                best_samp = best_samp.max(f1s);
            }
            best_ratio[ki].push(best_samp / best_full.max(1e-12));
        }
    }

    // ---- Fig 14: best-s ratio ----
    let mut t14 = Table::new(
        format!("Fig 14: ratio of max-F1 (best s) vs #vertices ({polys_per_k} polygons/k)"),
        &BOX_HEADERS,
    );
    for (ki, &k) in VERTEX_COUNTS.iter().enumerate() {
        t14.row(boxstats_row(format!("k={k}"), &best_ratio[ki]));
    }
    emit("fig14_poly_best_s", &t14);

    // ---- Fig 15: per fixed s (the paper shows six panels) ----
    for (si, &s) in S_VALUES.iter().enumerate() {
        // paper panels: s = 1, 1.4, 2.3, 3.2(?), 4.1, 5 — we emit all 10
        let mut t15 = Table::new(
            format!("Fig 15 panel: F1 ratio vs #vertices at s={s}"),
            &BOX_HEADERS,
        );
        for (ki, &k) in VERTEX_COUNTS.iter().enumerate() {
            t15.row(boxstats_row(format!("k={k}"), &ratios[ki][si]));
        }
        emit(&format!("fig15_poly_s{si}"), &t15);
    }

    // ---- Fig 16: pooled over s ----
    let mut t16 = Table::new("Fig 16: F1 ratio vs #vertices pooled over all s", &BOX_HEADERS);
    let mut all_ratios = Vec::new();
    for (ki, &k) in VERTEX_COUNTS.iter().enumerate() {
        let pooled: Vec<f64> = ratios[ki].iter().flatten().copied().collect();
        all_ratios.extend_from_slice(&pooled);
        t16.row(boxstats_row(format!("k={k}"), &pooled));
    }
    emit("fig16_poly_overall", &t16);

    let frac_above_09 =
        all_ratios.iter().filter(|&&r| r > 0.9).count() as f64 / all_ratios.len() as f64;
    println!(
        "overall: {:.1}% of F1 ratios > 0.9 (paper: all but one outlier)  n={}",
        frac_above_09 * 100.0,
        all_ratios.len()
    );
}
