//! Integration: the PJRT runtime executing the AOT Pallas artifacts
//! must agree with the native Rust scorer/kernel evaluation.
//!
//! These tests need `artifacts/` (run `make artifacts` first); they
//! skip with a message when the manifest is missing so `cargo test`
//! stays green on a fresh checkout.

use std::path::{Path, PathBuf};

use fastsvdd::data::shuttle::Shuttle;
use fastsvdd::data::tennessee::TennesseePlant;
use fastsvdd::data::{banana::Banana, donut::TwoDonut, Generator};
use fastsvdd::runtime::SharedRuntime;
use fastsvdd::sampling::{GramBackend, SamplingConfig, SamplingTrainer};
use fastsvdd::scoring::Scorer;
use fastsvdd::svdd::{train, Kernel, SvddParams};
use fastsvdd::util::matrix::Matrix;

fn artifact_dir() -> Option<PathBuf> {
    let dir = std::env::var_os("FASTSVDD_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| {
            Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        });
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        None
    }
}

#[test]
fn xla_scorer_matches_native_m2() {
    let Some(dir) = artifact_dir() else { return };
    let rt = SharedRuntime::new(&dir).unwrap();
    let data = Banana::default().generate(2000, 1);
    let model = train(&data, &SvddParams::gaussian(0.35, 0.001)).unwrap();
    let zs = Banana::default().generate(777, 2); // odd size: forces padding
    let native = Scorer::native(&model).dist2_batch(&zs).unwrap();
    let scorer = Scorer::xla(&model, &rt);
    assert!(scorer.is_accelerated());
    let xla = scorer.dist2_batch(&zs).unwrap();
    assert_eq!(native.len(), xla.len());
    for (i, (a, b)) in native.iter().zip(&xla).enumerate() {
        assert!(
            (a - b).abs() < 5e-5,
            "row {i}: native={a} xla={b}"
        );
    }
}

#[test]
fn xla_scorer_matches_native_m9_and_m41() {
    let Some(dir) = artifact_dir() else { return };
    let rt = SharedRuntime::new(&dir).unwrap();

    // m=9 (shuttle-like)
    let data = Shuttle.training(1500, 3);
    let model = train(&data, &SvddParams::gaussian(8.0, 0.01)).unwrap();
    let zs = Shuttle.scoring(500, 4).data;
    let native = Scorer::native(&model).dist2_batch(&zs).unwrap();
    let xla = Scorer::xla(&model, &rt).dist2_batch(&zs).unwrap();
    for (a, b) in native.iter().zip(&xla) {
        assert!((a - b).abs() < 5e-4, "m9: native={a} xla={b}");
    }

    // m=41 (TE-like)
    let plant = TennesseePlant::default();
    let data = plant.training(1200, 5);
    let model = train(&data, &SvddParams::gaussian(12.0, 0.01)).unwrap();
    let zs = plant.scoring(200, 200, 6).data;
    let native = Scorer::native(&model).dist2_batch(&zs).unwrap();
    let xla = Scorer::xla(&model, &rt).dist2_batch(&zs).unwrap();
    for (a, b) in native.iter().zip(&xla) {
        assert!((a - b).abs() < 5e-3, "m41: native={a} xla={b}");
    }
}

#[test]
fn gram_backend_matches_native_kernel() {
    let Some(dir) = artifact_dir() else { return };
    let rt = SharedRuntime::new(&dir).unwrap();
    let kernel = Kernel::gaussian(0.7);
    for n in [3, 17, 64] {
        let data = TwoDonut::default().generate(n, 7);
        let gram = rt.gram(&data, kernel).expect("bucket must cover n<=64, m=2");
        assert_eq!(gram.len(), n * n);
        for i in 0..n {
            for j in 0..n {
                let want = kernel.eval(data.row(i), data.row(j));
                let got = gram[i * n + j];
                assert!(
                    (want - got).abs() < 1e-5,
                    "K[{i},{j}]: native={want} xla={got}"
                );
            }
        }
    }
}

#[test]
fn gram_backend_declines_oversized_or_unknown_shapes() {
    let Some(dir) = artifact_dir() else { return };
    let rt = SharedRuntime::new(&dir).unwrap();
    let kernel = Kernel::gaussian(1.0);
    // 65 rows exceeds the n=64 bucket
    let big = TwoDonut::default().generate(65, 1);
    assert!(rt.gram(&big, kernel).is_none());
    // m=3 has no artifact
    let odd = Matrix::from_rows(&[vec![0.0; 3], vec![1.0; 3]]).unwrap();
    assert!(rt.gram(&odd, kernel).is_none());
    // linear kernel is not covered
    assert!(rt
        .gram(&TwoDonut::default().generate(8, 2), Kernel::Linear)
        .is_none());
}

#[test]
fn sampling_trainer_via_xla_backend_matches_native() {
    let Some(dir) = artifact_dir() else { return };
    let rt = SharedRuntime::new(&dir).unwrap();
    let data = Banana::default().generate(3000, 11);
    let params = SvddParams::gaussian(0.35, 0.001);
    let cfg = SamplingConfig { sample_size: 6, ..Default::default() };
    let native = SamplingTrainer::new(params, cfg).train(&data, 99).unwrap();
    let xla = SamplingTrainer::new(params, cfg)
        .with_backend(&rt)
        .train(&data, 99)
        .unwrap();
    // f32 gram vs f64 native: same trajectory, near-identical result
    assert_eq!(native.iterations, xla.iterations);
    assert!(
        (native.model.r2() - xla.model.r2()).abs() < 1e-4,
        "native={} xla={}",
        native.model.r2(),
        xla.model.r2()
    );
    // the runtime must actually have executed gram artifacts
    let execs = rt.with(|r| r.exec_count("gram_n64_m2"));
    assert!(execs > 0, "gram artifact never executed");
}

#[test]
fn scorer_exec_counts_and_bucket_choice() {
    let Some(dir) = artifact_dir() else { return };
    let rt = SharedRuntime::new(&dir).unwrap();
    let data = Banana::default().generate(500, 13);
    let model = train(&data, &SvddParams::gaussian(0.35, 0.01)).unwrap();
    let scorer = Scorer::xla(&model, &rt);
    // 100 rows -> latency bucket (256)
    scorer.dist2_batch(&Banana::default().generate(100, 1)).unwrap();
    assert_eq!(rt.with(|r| r.exec_count("score_m2_s512_b256")), 1);
    // 5000 rows -> one 4096 batch + one 256-padded tail... the tail
    // (904 rows) exceeds 256 so it reuses the 4096 bucket
    scorer.dist2_batch(&Banana::default().generate(5000, 2)).unwrap();
    assert_eq!(rt.with(|r| r.exec_count("score_m2_s512_b4096")), 2);
}
