//! Property-based tests over the core invariants, driven by the
//! in-house `testutil::prop` framework (seeded, reproducible, failure
//! messages carry the case seed).

use fastsvdd::data::polygon::Polygon;
use fastsvdd::distributed::message::Message;
use fastsvdd::linalg::NormCache;
use fastsvdd::registry::VersionMeta;
use fastsvdd::sampling::{ConvergenceCriteria, ConvergenceTracker};
use fastsvdd::scoring::F1Score;
use fastsvdd::svdd::smo::{solve, DenseKernel, SmoOptions};
use fastsvdd::svdd::{Kernel, SvddModel, SvddParams};
use fastsvdd::testutil::prop::{forall, Gen};
use fastsvdd::util::json::Json;
use fastsvdd::util::matrix::Matrix;
use fastsvdd::util::stats::{quantile, BoxStats};

fn random_points(g: &mut Gen, n: usize, m: usize, scale: f64) -> Matrix {
    let rows: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..m).map(|_| g.normal() * scale).collect())
        .collect();
    Matrix::from_rows(&rows).unwrap()
}

/// SMO solutions satisfy the dual feasibility + eps-KKT conditions for
/// arbitrary point clouds, bandwidths and box bounds.
#[test]
fn prop_smo_kkt_and_feasibility() {
    forall("smo kkt", 40, |g| {
        let n = g.usize_in(3, 40);
        let m = g.usize_in(1, 5);
        let bw = g.f64_in(0.2, 3.0);
        let f = g.f64_in(0.02, 0.5);
        let data = random_points(g, n, m, 1.5);
        let c = 1.0 / (n as f64 * f);
        let kernel = Kernel::gaussian(bw);
        let mut kp = DenseKernel::from_data(&data, kernel);
        let sol = solve(&mut kp, c, &SmoOptions::default()).unwrap();

        // feasibility
        let sum: f64 = sol.alpha.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "sum={sum}");
        for &a in &sol.alpha {
            assert!((-1e-12..=c + 1e-9).contains(&a), "alpha={a} outside [0,{c}]");
        }
        // R^2 sane
        assert!(sol.r2 >= 0.0 && sol.r2 <= 2.0, "r2={}", sol.r2);
        // eps-KKT via the final gap
        assert!(sol.gap < 1e-4, "gap={}", sol.gap);
    });
}

/// Training-point classification respects the f-budget: at most ~f*n
/// points end up strictly outside (plus solver slack).
#[test]
fn prop_outlier_budget() {
    forall("outlier budget", 15, |g| {
        let n = g.usize_in(50, 250);
        let f = *g.choose(&[0.05, 0.1, 0.2]);
        let data = random_points(g, n, 2, 1.0);
        let params = SvddParams::gaussian(g.f64_in(0.5, 2.0), f);
        let model = fastsvdd::svdd::train(&data, &params).unwrap();
        // Outside points carry alpha = C (eq. 10) and sum(alpha) = 1, so
        // in exact arithmetic #outside <= 1/C = n*f. The solver is
        // eps-KKT (gap < 1e-6), which lets near-boundary points sit
        // O(tol) outside — use a kernel-scale slack, not 1e-9.
        let outside = (0..n)
            .filter(|&i| model.dist2(data.row(i)) > model.r2() + 1e-4)
            .count();
        let budget = (n as f64 * f).ceil() as usize + 1;
        assert!(outside <= budget, "{outside} outside > budget {budget}");
    });
}

/// Scoring identity: dist2 is invariant under permutation of the SV
/// rows (the model is a set, not a sequence).
#[test]
fn prop_model_permutation_invariance() {
    forall("sv permutation", 20, |g| {
        let n = g.usize_in(20, 60);
        let data = random_points(g, n, 3, 1.0);
        let params = SvddParams::gaussian(1.0, 0.1);
        let model = fastsvdd::svdd::train(&data, &params).unwrap();
        let z: Vec<f64> = (0..3).map(|_| g.normal()).collect();
        let d = model.dist2(&z);
        // rebuild with rows reversed
        let k = model.num_sv();
        let rev_idx: Vec<usize> = (0..k).rev().collect();
        let sv2 = model.support_vectors().gather(&rev_idx);
        let alpha2: Vec<f64> = rev_idx.iter().map(|&i| model.alpha()[i]).collect();
        let model2 = fastsvdd::svdd::SvddModel::new(
            sv2,
            alpha2,
            model.kernel(),
            model.r2(),
            model.w(),
        )
        .unwrap();
        assert!((model2.dist2(&z) - d).abs() < 1e-12);
    });
}

/// The message codec is total on its domain: encode . decode == id.
#[test]
fn prop_message_codec_roundtrip() {
    forall("message codec", 50, |g| {
        let rows = g.usize_in(0, 12);
        let cols = g.usize_in(1, 6);
        let m = if rows == 0 {
            Matrix::zeros(0, cols)
        } else {
            random_points(g, rows, cols, 100.0)
        };
        let msg = if g.bool() {
            Message::Train {
                shard: m,
                bw: g.f64_in(1e-6, 1e6),
                outlier_fraction: g.f64_in(0.0, 1.0),
                sample_size: g.usize_in(0, 1 << 20) as u32,
                max_iter: g.usize_in(0, 1 << 30) as u32,
                seed: (g.usize_in(0, usize::MAX / 2)) as u64,
            }
        } else {
            Message::TrainDone {
                sv: m,
                r2: g.normal() * 10.0,
                iterations: g.usize_in(0, 10_000) as u32,
                converged: g.bool(),
            }
        };
        let back = Message::decode(&msg.encode()).unwrap();
        assert_eq!(msg, back);
    });
}

/// JSON writer output always re-parses to the same value.
#[test]
fn prop_json_roundtrip() {
    fn random_json(g: &mut Gen, depth: usize) -> Json {
        let pick = if depth == 0 { g.usize_in(0, 3) } else { g.usize_in(0, 5) };
        match pick {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num((g.normal() * 1e6).round() / 64.0),
            3 => Json::Str(format!("s{}-\"q\"-\n-{}", g.usize_in(0, 99), g.usize_in(0, 99))),
            4 => Json::Arr((0..g.usize_in(0, 4)).map(|_| random_json(g, depth - 1)).collect()),
            _ => Json::Obj(
                (0..g.usize_in(0, 4))
                    .map(|i| (format!("k{i}"), random_json(g, depth - 1)))
                    .collect(),
            ),
        }
    }
    forall("json roundtrip", 100, |g| {
        let v = random_json(g, 3);
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    });
}

/// Registry version metadata survives the manifest JSON round-trip
/// exactly (including the full-width u64 fingerprint, which is stored
/// as hex because f64 cannot carry 64 bits), and the non-finite guard
/// rejects metadata that cannot describe a servable model.
#[test]
fn prop_version_meta_json_roundtrip() {
    forall("version meta roundtrip", 60, |g| {
        let meta = VersionMeta {
            r2: g.f64_in(1e-6, 2.0),
            num_sv: g.usize_in(1, 500),
            dim: g.usize_in(1, 64),
            rows: g.usize_in(0, 1 << 20),
            sample_size: g.usize_in(0, 64),
            iterations: g.usize_in(0, 1000),
            converged: g.bool(),
            warm_start: g.bool(),
            bandwidth: if g.bool() { Some(g.f64_in(0.01, 10.0)) } else { None },
            data_fingerprint: ((g.usize_in(0, u32::MAX as usize) as u64) << 32)
                | g.usize_in(0, u32::MAX as usize) as u64,
            created_unix: g.usize_in(0, 1 << 40) as u64,
        };
        let pretty = meta.to_json().to_string_pretty();
        let back = VersionMeta::from_json(&Json::parse(&pretty).unwrap()).unwrap();
        assert_eq!(back, meta);
        let compact = meta.to_json().to_string();
        assert_eq!(VersionMeta::from_json(&Json::parse(&compact).unwrap()).unwrap(), meta);
        // non-finite R^2 / bandwidth can never be published
        let mut bad = meta.clone();
        bad.r2 = *g.choose(&[f64::NAN, f64::INFINITY, f64::NEG_INFINITY]);
        assert!(bad.validate().is_err());
        let mut bad = meta;
        bad.bandwidth = Some(f64::NAN);
        assert!(bad.validate().is_err());
    });
}

/// Registry model files round-trip bit-exactly: the JSON spelling of a
/// model reloads to the same content hash and the same scores, so a
/// content-addressed id names the same boundary forever. Non-finite
/// alphas are refused at construction (they would poison every score).
#[test]
fn prop_registry_model_json_roundtrip() {
    forall("registry model roundtrip", 25, |g| {
        let n = g.usize_in(2, 12);
        let m = g.usize_in(1, 4);
        let sv = random_points(g, n, m, 2.0);
        let mut alpha: Vec<f64> = (0..n).map(|_| g.f64_in(1e-3, 1.0)).collect();
        let sum: f64 = alpha.iter().sum();
        for a in &mut alpha {
            *a /= sum;
        }
        let kernel = Kernel::gaussian(g.f64_in(0.1, 3.0));
        let model =
            SvddModel::new(sv, alpha, kernel, g.f64_in(0.01, 1.5), g.f64_in(0.0, 1.0)).unwrap();
        let text = model.to_json().to_string_pretty();
        let back = SvddModel::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.content_hash(), model.content_hash());
        assert_eq!(back.content_id(), model.content_id());
        let z: Vec<f64> = (0..m).map(|_| g.normal()).collect();
        assert_eq!(back.dist2(&z).to_bits(), model.dist2(&z).to_bits());
        // non-finite guard: NaN alphas / thresholds never construct
        let sv2 = back.support_vectors().clone();
        assert!(SvddModel::new(sv2.clone(), vec![f64::NAN; n], kernel, 0.5, 0.5).is_err());
        assert!(
            SvddModel::new(sv2, back.alpha().to_vec(), kernel, f64::INFINITY, 0.5).is_err()
        );
    });
}

/// Random polygons: simple, area-consistent triangulation, interior
/// samples contained (the Fig 13-16 substrate invariants).
#[test]
fn prop_polygon_invariants() {
    forall("polygon invariants", 25, |g| {
        let k = g.usize_in(3, 30);
        let seed = g.usize_in(0, 1 << 30) as u64;
        let p = Polygon::random(k, 3.0, 5.0, seed);
        assert!(p.is_simple());
        let tris = p.triangulate();
        assert_eq!(tris.len(), p.num_vertices() - 2);
        let tri_area: f64 = tris
            .iter()
            .map(|t| {
                0.5 * ((t[1].0 - t[0].0) * (t[2].1 - t[0].1)
                    - (t[1].1 - t[0].1) * (t[2].0 - t[0].0))
                    .abs()
            })
            .sum();
        assert!((tri_area - p.area()).abs() < 1e-6 * p.area());
        let pts = p.sample_interior(50, seed ^ 1);
        for i in 0..pts.rows() {
            assert!(p.contains(pts.get(i, 0), pts.get(i, 1)));
        }
    });
}

/// Convergence tracker: converged() fires iff `t` consecutive stable
/// observations occur, for arbitrary interleavings.
#[test]
fn prop_convergence_streaks() {
    forall("convergence streaks", 50, |g| {
        let t = g.usize_in(1, 6);
        let mut tracker = ConvergenceTracker::new(ConvergenceCriteria {
            eps_center: 1e-6,
            eps_r2: 1e-6,
            consecutive: t,
            scale_floor: 0.0,
        });
        let mut streak = 0usize;
        let mut r2 = 1.0;
        tracker.observe(r2, &[1.0]);
        let mut expect_converged = false;
        for _ in 0..30 {
            let stable = g.bool();
            if !stable {
                r2 += 1.0; // huge jump resets
            }
            tracker.observe(r2, &[1.0]);
            streak = if stable { streak + 1 } else { 0 };
            if streak >= t {
                expect_converged = true;
            }
            assert_eq!(
                tracker.converged(),
                expect_converged,
                "streak={streak} t={t}"
            );
            if expect_converged {
                break;
            }
        }
    });
}

/// F1 is bounded and symmetric under swapping prediction with truth.
#[test]
fn prop_f1_bounds_and_symmetry() {
    forall("f1 bounds", 100, |g| {
        let n = g.usize_in(1, 50);
        let truth: Vec<bool> = (0..n).map(|_| g.bool()).collect();
        let pred: Vec<bool> = (0..n).map(|_| g.bool()).collect();
        let a = F1Score::compute(&truth, &pred);
        assert!((0.0..=1.0).contains(&a.f1));
        assert!((0.0..=1.0).contains(&a.precision));
        assert!((0.0..=1.0).contains(&a.recall));
        // F1 is symmetric in (truth, pred): swapping transposes FP/FN
        let b = F1Score::compute(&pred, &truth);
        assert!((a.f1 - b.f1).abs() < 1e-12);
    });
}

/// Quantiles are monotone in q and bounded by min/max.
#[test]
fn prop_quantile_monotone() {
    forall("quantile monotone", 50, |g| {
        let n = g.usize_in(1, 60);
        let xs = g.vec_f64(n, -100.0, 100.0);
        let qs = [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 1.0];
        let mut prev = f64::NEG_INFINITY;
        for &q in &qs {
            let v = quantile(&xs, q);
            assert!(v >= prev);
            prev = v;
        }
        let b = BoxStats::from(&xs);
        assert!(b.min <= b.q1 && b.q1 <= b.median && b.median <= b.q3 && b.q3 <= b.max);
        assert!(b.mean >= b.min - 1e-12 && b.mean <= b.max + 1e-12);
    });
}

/// Matrix dedup/gather algebra: dedup is idempotent; gather(idx) keeps
/// row content; vstack length adds.
#[test]
fn prop_matrix_algebra() {
    forall("matrix algebra", 60, |g| {
        let n = g.usize_in(1, 30);
        let m = g.usize_in(1, 5);
        // draw from a tiny value set to force duplicates
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..m).map(|_| *g.choose(&[0.0, 1.0, 2.0])).collect())
            .collect();
        let mat = Matrix::from_rows(&rows).unwrap();
        let d1 = mat.dedup_rows();
        let d2 = d1.dedup_rows();
        assert_eq!(d1, d2, "dedup not idempotent");
        assert!(d1.rows() <= mat.rows());
        let idx: Vec<usize> = (0..g.usize_in(1, 10)).map(|_| g.usize_in(0, n - 1)).collect();
        let gathered = mat.gather(&idx);
        for (out_row, &src) in idx.iter().enumerate() {
            assert_eq!(gathered.row(out_row), mat.row(src));
        }
        let stacked = mat.vstack(&d1).unwrap();
        assert_eq!(stacked.rows(), mat.rows() + d1.rows());
    });
}

/// Block-path kernel evaluation (`Kernel::eval_block` over the
/// norm-cached, tile-blocked `linalg` layer) agrees with the scalar
/// `Kernel::eval` reference to tight relative tolerance, for all three
/// kernel variants and arbitrary panel shapes — including the ragged
/// ones (1x1, 1xn, panels that are no multiple of the tile size).
#[test]
fn prop_block_vs_scalar_kernel_agreement() {
    forall("block vs scalar", 40, |g| {
        let m = g.usize_in(1, 13); // feature dims around the 4-wide unroll
        let (na, nb) = (g.usize_in(1, 30), g.usize_in(1, 30));
        let a = random_points(g, na, m, 3.0);
        let b = random_points(g, nb, m, 3.0);
        let (an, bn) = (NormCache::new(&a), NormCache::new(&b));
        let kernels = [
            Kernel::gaussian(g.f64_in(0.3, 3.0)),
            Kernel::Linear,
            Kernel::polynomial(g.usize_in(1, 4) as u32, g.f64_in(0.0, 2.0)),
        ];
        // panel shapes: full, single pair, single row, ragged sub-panel
        let (i0, j0) = (g.usize_in(0, na - 1), g.usize_in(0, nb - 1));
        let panels = [
            (0..na, 0..nb),
            (i0..i0 + 1, j0..j0 + 1),
            (i0..i0 + 1, 0..nb),
            (0..na, j0..nb),
        ];
        for kernel in kernels {
            for (ar, br) in panels.clone() {
                let mut out = vec![f64::NAN; ar.len() * br.len()];
                kernel.eval_block(&a, &an, ar.clone(), &b, &bn, br.clone(), &mut out);
                for (ia, i) in ar.clone().enumerate() {
                    for (jb, j) in br.clone().enumerate() {
                        let got = out[ia * br.len() + jb];
                        let want = kernel.eval(a.row(i), b.row(j));
                        assert!(
                            (got - want).abs() <= 1e-9 * want.abs().max(1.0),
                            "{kernel} panel ({ar:?},{br:?}) entry ({i},{j}): \
                             block {got} vs scalar {want}"
                        );
                    }
                }
            }
        }
    });
}

/// Block entries are a pure function of the two rows: any sub-panel of
/// the full block evaluation reproduces the same bits, so tiling and
/// chunk geometry can never leak into results.
#[test]
fn prop_block_entries_independent_of_panel_shape() {
    forall("block panel purity", 30, |g| {
        let m = g.usize_in(1, 9);
        let n = g.usize_in(2, 25);
        let a = random_points(g, n, m, 2.0);
        let an = NormCache::new(&a);
        let kernel = match g.usize_in(0, 2) {
            0 => Kernel::gaussian(g.f64_in(0.3, 2.0)),
            1 => Kernel::Linear,
            _ => Kernel::polynomial(g.usize_in(1, 3) as u32, 1.0),
        };
        let mut full = vec![0.0; n * n];
        kernel.eval_block(&a, &an, 0..n, &a, &an, 0..n, &mut full);
        // symmetry is exact on the block path
        for i in 0..n {
            for j in 0..n {
                assert_eq!(
                    full[i * n + j].to_bits(),
                    full[j * n + i].to_bits(),
                    "asymmetric at ({i},{j})"
                );
            }
        }
        // a random ragged sub-panel carries identical bits
        let (i0, i1) = {
            let x = g.usize_in(0, n - 1);
            (x, g.usize_in(x + 1, n))
        };
        let (j0, j1) = {
            let x = g.usize_in(0, n - 1);
            (x, g.usize_in(x + 1, n))
        };
        let (li, lj) = (i1 - i0, j1 - j0);
        let mut sub = vec![0.0; li * lj];
        kernel.eval_block(&a, &an, i0..i1, &a, &an, j0..j1, &mut sub);
        for ia in 0..li {
            for jb in 0..lj {
                assert_eq!(
                    sub[ia * lj + jb].to_bits(),
                    full[(i0 + ia) * n + (j0 + jb)].to_bits(),
                    "sub-panel ({i0}..{i1},{j0}..{j1}) diverged at ({ia},{jb})"
                );
            }
        }
    });
}

/// Degenerate and extreme inputs: empty panels are no-ops, and the
/// norm-cache formulation keeps every intermediate finite for
/// coordinates up to +-1e150 (where `||x||^2` itself is ~1e300 but
/// still representable) — no overflow sneaks in before the Gaussian
/// saturates.
#[test]
fn block_kernel_empty_and_extreme_inputs() {
    // empty matrices and empty ranges
    let empty = Matrix::zeros(0, 3);
    let en = NormCache::new(&empty);
    assert!(en.is_empty());
    let some = Matrix::from_rows(&[vec![1.0, 2.0, 3.0]]).unwrap();
    let sn = NormCache::new(&some);
    let mut out: Vec<f64> = Vec::new();
    for kernel in [Kernel::gaussian(1.0), Kernel::Linear, Kernel::polynomial(2, 1.0)] {
        kernel.eval_block(&empty, &en, 0..0, &some, &sn, 0..1, &mut out);
        kernel.eval_block(&some, &sn, 0..1, &empty, &en, 0..0, &mut out);
        kernel.eval_block(&empty, &en, 0..0, &empty, &en, 0..0, &mut out);
        assert!(out.is_empty());
    }

    // extreme coordinates: +-1e150, mixed with moderate rows
    let a = Matrix::from_rows(&[
        vec![1e150, -1e150, 1e150],
        vec![-1e150, 1e150, -1e150],
        vec![1e150, 1e150, 1e150],
        vec![1.0, -2.0, 0.5],
    ])
    .unwrap();
    let an = NormCache::new(&a);
    for i in 0..4 {
        assert!(an.get(i).is_finite(), "norm {i} overflowed");
    }
    let kernel = Kernel::gaussian(1.0);
    let mut k = vec![f64::NAN; 16];
    kernel.eval_block(&a, &an, 0..4, &a, &an, 0..4, &mut k);
    for i in 0..4 {
        for j in 0..4 {
            let v = k[i * 4 + j];
            assert!(v.is_finite(), "K({i},{j}) not finite: {v}");
            assert!((0.0..=1.0).contains(&v), "K({i},{j}) out of range: {v}");
            // scalar reference agrees: identical rows give exactly 1,
            // astronomically distant rows give exactly 0
            let want = kernel.eval(a.row(i), a.row(j));
            assert_eq!(v, want, "extreme K({i},{j})");
        }
    }
    assert_eq!(k[0], 1.0);
    assert_eq!(k[1], 0.0); // exp(-~1e300) underflows to zero exactly
}

/// Pool chunking covers every output index exactly once, for arbitrary
/// buffer lengths, chunk sizes and thread counts: chunk starts are
/// aligned, no index is skipped, no index is written twice.
#[test]
fn prop_pool_chunking_covers_every_index_exactly_once() {
    use fastsvdd::parallel::Pool;
    use std::sync::atomic::{AtomicUsize, Ordering};
    forall("pool chunk cover", 60, |g| {
        let len = g.usize_in(0, 700);
        let chunk = g.usize_in(1, 80);
        let threads = *g.choose(&[1usize, 2, 3, 8]);
        let touched: Vec<AtomicUsize> = (0..len).map(|_| AtomicUsize::new(0)).collect();
        let mut out = vec![usize::MAX; len];
        Pool::new(threads).run_chunks(&mut out, chunk, |start, c| {
            assert_eq!(start % chunk, 0, "unaligned chunk start {start}");
            assert!(
                c.len() == chunk || start + c.len() == len,
                "short chunk not at the tail: start={start} len={}",
                c.len()
            );
            for (off, slot) in c.iter_mut().enumerate() {
                touched[start + off].fetch_add(1, Ordering::Relaxed);
                *slot = start + off;
            }
        });
        for (i, t) in touched.iter().enumerate() {
            assert_eq!(t.load(Ordering::Relaxed), 1, "index {i} touched != once");
        }
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i, "index {i} holds {v}");
        }
        // the weighted variant must produce the same coverage for any
        // weight function (only worker scheduling may differ)
        let skew = g.usize_in(0, 3);
        let mut out_w = vec![usize::MAX; len];
        Pool::new(threads).run_chunks_weighted(
            &mut out_w,
            chunk,
            |ci| ci.wrapping_mul(31).wrapping_add(skew) % 7,
            |start, c| {
                for (off, slot) in c.iter_mut().enumerate() {
                    *slot = start + off;
                }
            },
        );
        assert_eq!(out, out_w, "weighted coverage diverged");
    });
}
