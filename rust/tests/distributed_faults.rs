//! Chaos tests for the fault-tolerant distributed controller: every
//! scenario runs real TCP workers on loopback with a deterministic
//! [`FaultPlan`] and a hard wall-clock deadline, so a regression that
//! reintroduces an unbounded wait fails the suite instead of hanging
//! CI. The load-bearing property throughout: retries and reassignment
//! never change the model — results are keyed by shard index with
//! per-shard seeds, so a run that survives failures is bit-identical
//! to a clean run of the same configuration.

use std::time::{Duration, Instant};

use fastsvdd::data::{donut::TwoDonut, Generator};
use fastsvdd::distributed::{
    train_local_cluster, train_tcp_cluster, train_tcp_cluster_stream, CombineMode,
    DistributedConfig, FaultPlan, RetryStats, WorkerServer,
};
use fastsvdd::sampling::SamplingConfig;
use fastsvdd::svdd::SvddParams;
use fastsvdd::Error;

/// Run `f` on a helper thread and panic if it exceeds `secs` — the
/// explicit no-hang deadline every chaos scenario must meet.
fn with_deadline<T: Send + 'static>(secs: u64, f: impl FnOnce() -> T + Send + 'static) -> T {
    use std::sync::mpsc::RecvTimeoutError;
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(v) => {
            handle.join().expect("deadline thread panicked");
            v
        }
        Err(RecvTimeoutError::Timeout) => {
            panic!("distributed call exceeded the {secs}s deadline (hang)")
        }
        Err(RecvTimeoutError::Disconnected) => {
            // the closure panicked before sending; propagate its message
            handle.join().expect("deadline thread panicked");
            unreachable!("sender dropped without sending or panicking")
        }
    }
}

fn spawn_workers(n: usize, plans: &[(usize, &str)]) -> Vec<WorkerServer> {
    (0..n)
        .map(|i| {
            let plan = plans
                .iter()
                .find(|(w, _)| *w == i)
                .map(|(_, spec)| FaultPlan::parse(spec).unwrap());
            WorkerServer::spawn_with_faults("127.0.0.1:0", plan).unwrap()
        })
        .collect()
}

fn stop_all(workers: &mut [WorkerServer]) {
    for w in workers {
        w.stop();
    }
}

/// Kill 1 of 3 workers after its first shard: the controller must
/// detect the death, requeue the lost shard on a surviving worker, and
/// converge to the exact model a clean run produces.
#[test]
fn killed_worker_is_detected_and_its_shard_reassigned() {
    let data = TwoDonut::default().generate(6000, 17);
    let params = SvddParams::gaussian(0.4, 0.001);
    let cfg = DistributedConfig {
        workers: 8,
        sampling: SamplingConfig { sample_size: 10, ..Default::default() },
        seed: 13,
        max_retries: 3,
        worker_timeout: Duration::from_secs(2),
        ..Default::default()
    };

    let mut workers = spawn_workers(3, &[(0, "kill_after=1")]);
    let addrs: Vec<_> = workers.iter().map(|w| w.addr()).collect();
    let d = data.clone();
    let out = with_deadline(60, move || train_tcp_cluster(&d, &params, &cfg, &addrs)).unwrap();
    stop_all(&mut workers);

    assert_eq!(out.reports.len(), 8, "every shard accounted for");
    assert_eq!(
        out.retry,
        RetryStats {
            shard_retries: 1,
            shards_reassigned: 1,
            worker_failures: 1,
            workers_lost: 1,
            shards_local_fallback: 0,
        },
        "exactly one shard lost with the killed worker and re-run elsewhere"
    );

    // failure-surviving run == clean run, bit for bit
    let clean = train_local_cluster(&data, &params, &cfg).unwrap();
    assert_eq!(out.union_rows, clean.union_rows);
    assert!((out.model.r2() - clean.model.r2()).abs() < 1e-12);
}

/// Every worker dead on arrival: the run must fail with a clean
/// [`Error::Distributed`] in bounded time — never hang.
#[test]
fn all_workers_dead_fails_fast_with_distributed_error() {
    let data = TwoDonut::default().generate(800, 3);
    let params = SvddParams::gaussian(0.4, 0.001);
    let cfg = DistributedConfig {
        workers: 4,
        sampling: SamplingConfig { sample_size: 8, ..Default::default() },
        seed: 2,
        max_retries: 2,
        worker_timeout: Duration::from_millis(500),
        ..Default::default()
    };

    let mut workers =
        spawn_workers(3, &[(0, "kill_after=0"), (1, "kill_after=0"), (2, "kill_after=0")]);
    let addrs: Vec<_> = workers.iter().map(|w| w.addr()).collect();
    let started = Instant::now();
    let err = with_deadline(30, move || train_tcp_cluster(&data, &params, &cfg, &addrs))
        .expect_err("all workers dead must fail the run");
    let elapsed = started.elapsed();
    stop_all(&mut workers);

    match &err {
        Error::Distributed(msg) => {
            assert!(msg.contains("dead"), "error should name the cause: {msg}")
        }
        other => panic!("expected Error::Distributed, got {other:?}"),
    }
    // dead sockets answer with EOF, not silence: detection is far
    // faster than the per-attempt deadline, let alone the test deadline
    assert!(elapsed < Duration::from_secs(10), "took {elapsed:?}");
}

/// A corrupted training reply is indistinguishable from line noise;
/// the controller must fail the attempt, keep the worker (it still
/// answers heartbeats), and recover the shard by retrying.
#[test]
fn corrupt_reply_is_retried_to_success() {
    let data = TwoDonut::default().generate(2400, 29);
    let params = SvddParams::gaussian(0.4, 0.001);
    let cfg = DistributedConfig {
        workers: 2,
        sampling: SamplingConfig { sample_size: 9, ..Default::default() },
        seed: 31,
        max_retries: 2,
        worker_timeout: Duration::from_secs(2),
        ..Default::default()
    };

    let mut workers = spawn_workers(1, &[(0, "corrupt_at=1")]);
    let addrs: Vec<_> = workers.iter().map(|w| w.addr()).collect();
    let d = data.clone();
    let out = with_deadline(60, move || train_tcp_cluster(&d, &params, &cfg, &addrs)).unwrap();
    stop_all(&mut workers);

    assert_eq!(out.retry.shard_retries, 1);
    assert_eq!(out.retry.worker_failures, 1);
    assert_eq!(out.retry.workers_lost, 0, "a heartbeat-answering worker stays in the pool");
    let clean = train_local_cluster(&data, &params, &cfg).unwrap();
    assert!((out.model.r2() - clean.model.r2()).abs() < 1e-12);
}

/// A dropped connection mid-reply is recovered the same way.
#[test]
fn dropped_reply_is_retried_to_success() {
    let data = TwoDonut::default().generate(2400, 41);
    let params = SvddParams::gaussian(0.4, 0.001);
    let cfg = DistributedConfig {
        workers: 2,
        sampling: SamplingConfig { sample_size: 9, ..Default::default() },
        seed: 43,
        max_retries: 2,
        worker_timeout: Duration::from_secs(2),
        ..Default::default()
    };

    let mut workers = spawn_workers(1, &[(0, "drop_at=1")]);
    let addrs: Vec<_> = workers.iter().map(|w| w.addr()).collect();
    let d = data.clone();
    let out = with_deadline(60, move || train_tcp_cluster(&d, &params, &cfg, &addrs)).unwrap();
    stop_all(&mut workers);

    assert_eq!(out.retry.shard_retries, 1);
    assert_eq!(out.retry.workers_lost, 0);
    let clean = train_local_cluster(&data, &params, &cfg).unwrap();
    assert!((out.model.r2() - clean.model.r2()).abs() < 1e-12);
}

/// A worker slower than the socket deadline but still alive must not
/// be declared dead: the heartbeat grace loop extends the wait as long
/// as liveness probes are answered, so the run finishes with zero
/// retries.
#[test]
fn slow_worker_survives_via_heartbeat_grace() {
    let data = TwoDonut::default().generate(1600, 53);
    let params = SvddParams::gaussian(0.4, 0.001);
    let cfg = DistributedConfig {
        workers: 2,
        sampling: SamplingConfig { sample_size: 8, ..Default::default() },
        seed: 59,
        max_retries: 1,
        worker_timeout: Duration::from_millis(250),
        ..Default::default()
    };

    // every training reply arrives ~3 socket deadlines late
    let mut workers = spawn_workers(1, &[(0, "delay_ms=700")]);
    let addrs: Vec<_> = workers.iter().map(|w| w.addr()).collect();
    let d = data.clone();
    let out = with_deadline(60, move || train_tcp_cluster(&d, &params, &cfg, &addrs)).unwrap();
    stop_all(&mut workers);

    assert_eq!(out.retry, RetryStats::default(), "slow is not dead");
    let clean = train_local_cluster(&data, &params, &cfg).unwrap();
    assert!((out.model.r2() - clean.model.r2()).abs() < 1e-12);
}

/// Once the live worker pool falls below `min_workers` the controller
/// degrades to in-process execution — which runs the identical
/// per-shard algorithm, so the model is still bit-identical.
#[test]
fn min_workers_degradation_falls_back_to_local() {
    let data = TwoDonut::default().generate(2000, 61);
    let params = SvddParams::gaussian(0.4, 0.001);
    let cfg = DistributedConfig {
        workers: 3,
        sampling: SamplingConfig { sample_size: 8, ..Default::default() },
        seed: 67,
        min_workers: 2, // one live worker < 2 -> degraded from the start
        worker_timeout: Duration::from_secs(2),
        ..Default::default()
    };

    let mut workers = spawn_workers(1, &[]);
    let addrs: Vec<_> = workers.iter().map(|w| w.addr()).collect();
    let d = data.clone();
    let out = with_deadline(60, move || train_tcp_cluster(&d, &params, &cfg, &addrs)).unwrap();
    stop_all(&mut workers);

    assert_eq!(out.retry.shards_local_fallback, 3, "every shard ran locally");
    assert_eq!(out.retry.shard_retries, 0);
    let clean = train_local_cluster(&data, &params, &cfg).unwrap();
    assert!((out.model.r2() - clean.model.r2()).abs() < 1e-12);
}

/// Tree combine is deterministic and tolerance-equivalent to flat: the
/// paper's decision boundary survives hierarchical combining, it just
/// trades one large solve for several bounded ones.
#[test]
fn tree_combine_matches_flat_within_tolerance() {
    let data = TwoDonut::default().generate(6000, 71);
    let params = SvddParams::gaussian(0.4, 0.001);
    let flat_cfg = DistributedConfig {
        workers: 8,
        sampling: SamplingConfig { sample_size: 10, ..Default::default() },
        seed: 73,
        ..Default::default()
    };
    let tree_cfg = DistributedConfig { combine: CombineMode::Tree { fanout: 2 }, ..flat_cfg };

    let flat = train_local_cluster(&data, &params, &flat_cfg).unwrap();
    let tree = train_local_cluster(&data, &params, &tree_cfg).unwrap();
    let tree2 = train_local_cluster(&data, &params, &tree_cfg).unwrap();

    assert_eq!(flat.combine_solves, 1);
    assert_eq!(tree.combine_solves, 7, "8 leaves at fanout 2: 4 + 2 + 1 solves");
    let rel = (tree.model.r2() - flat.model.r2()).abs() / flat.model.r2();
    assert!(rel < 0.05, "tree vs flat relative R^2 gap {rel} too large");
    assert!(
        (tree.model.r2() - tree2.model.r2()).abs() < 1e-15,
        "tree combine must be deterministic"
    );
}

/// Fault plans are deterministic end to end: replaying the same chaos
/// scenario yields the same model and the same failure accounting.
#[test]
fn fault_plan_replays_identically() {
    let data = TwoDonut::default().generate(3000, 83);
    let params = SvddParams::gaussian(0.4, 0.001);
    let cfg = DistributedConfig {
        workers: 6,
        sampling: SamplingConfig { sample_size: 9, ..Default::default() },
        seed: 89,
        max_retries: 3,
        worker_timeout: Duration::from_secs(2),
        ..Default::default()
    };

    let run = |data: &fastsvdd::util::matrix::Matrix| {
        let mut workers = spawn_workers(2, &[(0, "kill_after=1")]);
        let addrs: Vec<_> = workers.iter().map(|w| w.addr()).collect();
        let d = data.clone();
        let out = with_deadline(60, move || train_tcp_cluster(&d, &params, &cfg, &addrs)).unwrap();
        stop_all(&mut workers);
        out
    };
    let a = run(&data);
    let b = run(&data);
    assert_eq!(a.retry, b.retry, "failure accounting must replay identically");
    assert_eq!(a.union_rows, b.union_rows);
    assert!((a.model.r2() - b.model.r2()).abs() < 1e-15);
}

/// Streaming ingestion: chunks of exactly `rows / p` rows reproduce
/// the in-memory sharding bit for bit, without the controller ever
/// materializing the dataset.
#[test]
fn streamed_csv_matches_in_memory_sharding() {
    let data = TwoDonut::default().generate(1000, 97);
    let params = SvddParams::gaussian(0.4, 0.001);
    let cfg = DistributedConfig {
        workers: 4, // 4 shards of 250 rows == 4 streamed chunks of 250
        sampling: SamplingConfig { sample_size: 8, ..Default::default() },
        seed: 101,
        ..Default::default()
    };

    let dir = std::env::temp_dir().join("fastsvdd_stream_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("stream.csv");
    fastsvdd::data::csv::write_matrix(&path, &["x", "y"], &data).unwrap();

    let mut workers = spawn_workers(2, &[]);
    let addrs: Vec<_> = workers.iter().map(|w| w.addr()).collect();
    let p = path.clone();
    let a2 = addrs.clone();
    let streamed = with_deadline(60, move || {
        train_tcp_cluster_stream(&p, true, 250, &params, &cfg, &a2)
    })
    .unwrap();
    let in_memory = train_tcp_cluster(&data, &params, &cfg, &addrs).unwrap();
    stop_all(&mut workers);

    assert_eq!(streamed.reports.len(), 4);
    assert_eq!(streamed.union_rows, in_memory.union_rows);
    assert!((streamed.model.r2() - in_memory.model.r2()).abs() < 1e-12);

    // streaming cannot honor a pre-shuffle: it never sees the full data
    let shuffled = DistributedConfig { shuffle_seed: Some(1), ..cfg };
    let err = train_tcp_cluster_stream(&path, true, 250, &params, &shuffled, &addrs);
    assert!(matches!(err, Err(Error::Config(_))));
    std::fs::remove_file(&path).ok();
}
