//! Solver-path regression suite for the SMO engine:
//!
//! 1. a **golden byte-for-byte pin** of legacy mode ([`Wss::Legacy`]):
//!    `golden_solve` below is a verbatim copy of the solver loop as it
//!    existed before the `Solver` refactor (first-order `i`-scan fused
//!    into the gradient update, gain-based `j` pick, no shrinking,
//!    cold init). `solve` with `SmoOptions::legacy()` must reproduce
//!    its trajectory bit-for-bit on every problem — if the legacy path
//!    ever drifts, seeded historical runs change and this fails;
//! 2. **property tests** that the fast path (WSS2 + shrinking) matches
//!    the first-order unshrunk reference (objective, `R^2`, gap, SV
//!    set) within tolerance across all three kernels, random box
//!    bounds and degenerate inputs (duplicate rows, n=1,
//!    all-interior).

use fastsvdd::svdd::smo::{solve, solve_with_init, DenseKernel, KernelProvider, SmoOptions};
use fastsvdd::svdd::smo::LazyKernel;
use fastsvdd::svdd::{Kernel, Wss};
use fastsvdd::testutil::prop::{forall, Gen};
use fastsvdd::util::matrix::Matrix;

// ---------------------------------------------------------------------
// Golden reference: the pre-Solver loop, copied verbatim.
// ---------------------------------------------------------------------

struct GoldenSolution {
    alpha: Vec<f64>,
    quad: f64,
    r2: f64,
    iterations: usize,
    gap: f64,
}

/// The solver exactly as it shipped before the `Solver` refactor.
/// Do not modify — its whole purpose is to be the frozen historical
/// trajectory.
fn golden_solve(kp: &mut dyn KernelProvider, c: f64, opts: &SmoOptions) -> GoldenSolution {
    let n = kp.n();
    assert!(n > 0 && c * (n as f64) >= 1.0 - 1e-12);
    const UNIFORM_INIT_MAX_N: usize = 256;
    let mut alpha = vec![0.0; n];
    if n <= UNIFORM_INIT_MAX_N {
        for a in &mut alpha {
            *a = 1.0 / n as f64;
        }
    } else {
        let mut remaining: f64 = 1.0;
        let mut i = 0;
        while remaining > 0.0 && i < n {
            let a = remaining.min(c);
            alpha[i] = a;
            remaining -= a;
            i += 1;
        }
    }

    let mut g: Vec<f64> = (0..n).map(|i| -kp.diag(i)).collect();
    let mut col = vec![0.0; n];
    for j in 0..n {
        if alpha[j] <= 0.0 {
            continue;
        }
        kp.col_into(j, &mut col);
        let two_aj = 2.0 * alpha[j];
        for k in 0..n {
            g[k] += two_aj * col[k];
        }
    }

    let mut pos: Vec<usize> = (0..n).filter(|&k| alpha[k] > 0.0).collect();
    let mut pos_slot: Vec<usize> = vec![usize::MAX; n];
    for (slot, &k) in pos.iter().enumerate() {
        pos_slot[k] = slot;
    }

    let max_iter = if opts.max_iter > 0 {
        opts.max_iter
    } else {
        (100 * n).max(10_000)
    };

    let mut col_i = vec![0.0; n];
    let mut col_j = vec![0.0; n];
    let mut iterations = 0;
    let mut gap = f64::INFINITY;

    let mut i_sel = usize::MAX;
    let mut g_min = f64::INFINITY;
    for k in 0..n {
        if alpha[k] < c - 1e-14 && g[k] < g_min {
            g_min = g[k];
            i_sel = k;
        }
    }

    for it in 0..max_iter {
        iterations = it;
        let mut g_max = f64::NEG_INFINITY;
        for &k in &pos {
            if g[k] > g_max {
                g_max = g[k];
            }
        }
        gap = g_max - g_min;
        if i_sel == usize::MAX || pos.is_empty() || gap < opts.tol {
            break;
        }

        kp.col_into(i_sel, &mut col_i);
        let diag_i = kp.diag(i_sel);
        let mut j_sel = usize::MAX;
        let mut best_gain = 0.0;
        for &k in &pos {
            if k == i_sel {
                continue;
            }
            let d = g[k] - g_min;
            if d <= 0.0 {
                continue;
            }
            let eta = (2.0 * (diag_i + kp.diag(k) - 2.0 * col_i[k])).max(1e-12);
            let gain = d * d / eta;
            if gain > best_gain {
                best_gain = gain;
                j_sel = k;
            }
        }
        if j_sel == usize::MAX {
            break;
        }

        kp.col_into(j_sel, &mut col_j);
        let eta = (2.0 * (diag_i + kp.diag(j_sel) - 2.0 * col_i[j_sel])).max(1e-12);
        let raw = (g[j_sel] - g_min) / eta;
        let delta = raw.min(c - alpha[i_sel]).min(alpha[j_sel]);
        if delta <= 0.0 {
            break;
        }
        let was_zero = alpha[i_sel] <= 1e-14;
        alpha[i_sel] += delta;
        alpha[j_sel] -= delta;
        if was_zero {
            pos_slot[i_sel] = pos.len();
            pos.push(i_sel);
        }
        if alpha[j_sel] <= 1e-14 {
            alpha[j_sel] = 0.0;
            let slot = pos_slot[j_sel];
            let last = *pos.last().unwrap();
            pos.swap_remove(slot);
            if slot < pos.len() {
                pos_slot[last] = slot;
            }
            pos_slot[j_sel] = usize::MAX;
        }

        let two_d = 2.0 * delta;
        g_min = f64::INFINITY;
        i_sel = usize::MAX;
        for k in 0..n {
            let gk = g[k] + two_d * (col_i[k] - col_j[k]);
            g[k] = gk;
            if gk < g_min && alpha[k] < c - 1e-14 {
                g_min = gk;
                i_sel = k;
            }
        }
    }

    let sum: f64 = alpha.iter().sum();
    if (sum - 1.0).abs() > 1e-9 {
        for a in &mut alpha {
            *a /= sum;
        }
    }

    let quad: f64 = (0..n)
        .map(|i| alpha[i] * (g[i] + kp.diag(i)) * 0.5)
        .sum();

    let mut r2_sum = 0.0;
    let mut r2_cnt = 0usize;
    for k in 0..n {
        if alpha[k] > opts.sv_eps && alpha[k] < c - opts.sv_eps {
            r2_sum += quad - g[k];
            r2_cnt += 1;
        }
    }
    if r2_cnt == 0 {
        for k in 0..n {
            if alpha[k] > opts.sv_eps {
                r2_sum += quad - g[k];
                r2_cnt += 1;
            }
        }
    }
    let r2 = if r2_cnt > 0 { (r2_sum / r2_cnt as f64).max(0.0) } else { 0.0 };

    GoldenSolution { alpha, quad, r2, iterations, gap }
}

// ---------------------------------------------------------------------
// Shared generators / helpers
// ---------------------------------------------------------------------

fn seeded_points(seed: u64, n: usize, m: usize, scale: f64) -> Matrix {
    let mut g = Gen::new(seed);
    let rows: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..m).map(|_| g.normal() * scale).collect())
        .collect();
    Matrix::from_rows(&rows).unwrap()
}

fn objective(k: &DenseKernel, alpha: &[f64]) -> f64 {
    let n = k.n();
    let ks = k.as_slice();
    let mut q = 0.0;
    for i in 0..n {
        for j in 0..n {
            q += alpha[i] * alpha[j] * ks[i * n + j];
        }
    }
    let lin: f64 = (0..n).map(|i| alpha[i] * k.diag(i)).sum();
    q - lin
}

/// One of the three kernel families plus a data scale that keeps its
/// values well-conditioned (high-degree polynomials over wide clouds
/// push kernel entries to 1e4+, where an absolute 1e-6 gap means
/// asymptotically slow tail convergence — a conditioning problem, not
/// a solver property under test here).
fn three_kernels(g: &mut Gen) -> (Kernel, f64) {
    match g.usize_in(0, 2) {
        0 => (Kernel::gaussian(g.f64_in(0.3, 2.0)), 1.5),
        1 => (Kernel::Linear, 1.0),
        _ => (Kernel::polynomial(g.usize_in(1, 3) as u32, g.f64_in(0.5, 2.0)), 0.5),
    }
}

// ---------------------------------------------------------------------
// Golden byte-for-byte pin of legacy mode
// ---------------------------------------------------------------------

#[test]
fn legacy_mode_reproduces_golden_trajectory_bitwise() {
    // n spans both init regimes (uniform <= 256 < concentrated); the
    // three kernels and several box bounds cover the selection logic.
    for (seed, n, m) in [(1u64, 40, 2), (2, 120, 3), (3, 300, 2), (4, 57, 1)] {
        let data = seeded_points(seed, n, m, 1.5);
        for kernel in [Kernel::gaussian(0.9), Kernel::Linear, Kernel::polynomial(2, 1.0)] {
            for f in [0.05, 0.25] {
                let c = 1.0 / (n as f64 * f);
                let opts = SmoOptions::legacy();
                let mut golden_kp = DenseKernel::from_data(&data, kernel);
                let want = golden_solve(&mut golden_kp, c, &opts);
                let mut kp = DenseKernel::from_data(&data, kernel);
                let got = solve(&mut kp, c, &opts).unwrap();
                assert_eq!(got.iterations, want.iterations, "seed {seed} {kernel:?} f={f}");
                assert_eq!(got.r2.to_bits(), want.r2.to_bits(), "seed {seed} {kernel:?} f={f}");
                assert_eq!(got.quad.to_bits(), want.quad.to_bits());
                assert_eq!(got.gap.to_bits(), want.gap.to_bits());
                for (a, b) in got.alpha.iter().zip(&want.alpha) {
                    assert_eq!(a.to_bits(), b.to_bits(), "alpha drift: seed {seed}");
                }
                assert_eq!(got.shrink_events, 0, "legacy mode must never shrink");
                assert_eq!(got.unshrink_events, 0);
            }
        }
    }
}

#[test]
fn legacy_mode_lazy_kernel_matches_golden_dense_bitwise() {
    // lazy columns carry the same bits as the dense block gram, so the
    // legacy trajectory is identical through either provider
    let data = seeded_points(9, 150, 3, 1.2);
    let kernel = Kernel::gaussian(0.8);
    let c = 1.0 / (150.0 * 0.1);
    let opts = SmoOptions::legacy();
    let mut golden_kp = DenseKernel::from_data(&data, kernel);
    let want = golden_solve(&mut golden_kp, c, &opts);
    let mut lazy = LazyKernel::new(&data, kernel, 64 << 20);
    let got = solve(&mut lazy, c, &opts).unwrap();
    assert_eq!(got.iterations, want.iterations);
    assert_eq!(got.r2.to_bits(), want.r2.to_bits());
    for (a, b) in got.alpha.iter().zip(&want.alpha) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

// ---------------------------------------------------------------------
// Fast path (WSS2 + shrinking) vs first-order unshrunk reference
// ---------------------------------------------------------------------

#[test]
fn prop_wss2_shrinking_matches_first_order_reference() {
    forall("wss2+shrinking vs wss1 unshrunk", 30, |g| {
        let n = g.usize_in(2, 60);
        let m = g.usize_in(1, 4);
        let (kernel, scale) = three_kernels(g);
        let f = g.f64_in(0.05, 0.9);
        let c = 1.0 / (n as f64 * f);
        let mut data = seeded_points(g.usize_in(0, 1 << 30) as u64, n, m, scale);
        // degenerate flavor: duplicate a block of rows exactly
        if g.bool() && n >= 4 {
            let k = g.usize_in(1, n / 2);
            let dup_idx: Vec<usize> = (0..n).map(|i| if i < k { 0 } else { i }).collect();
            data = data.gather(&dup_idx);
        }
        let fast_opts = SmoOptions { shrink_every: 5, ..Default::default() };
        let ref_opts = SmoOptions { wss: Wss::First, shrinking: false, ..Default::default() };
        let dense = DenseKernel::from_data(&data, kernel);
        let mut a = DenseKernel::from_data(&data, kernel);
        let mut b = DenseKernel::from_data(&data, kernel);
        let fast = solve(&mut a, c, &fast_opts).unwrap();
        let refr = solve(&mut b, c, &ref_opts).unwrap();

        // both epsilon-KKT on the full set
        assert!(fast.gap < 1e-4, "fast gap {}", fast.gap);
        assert!(refr.gap < 1e-4, "reference gap {}", refr.gap);
        // the optimal objective is unique (convex problem): both paths
        // must land on it within solver tolerance, even when alpha
        // itself is not unique (duplicate rows, rank-deficient kernels)
        let (oa, ob) = (objective(&dense, &fast.alpha), objective(&dense, &refr.alpha));
        let scale = oa.abs().max(ob.abs()).max(1e-3);
        assert!(
            (oa - ob).abs() <= 1e-4 * scale,
            "objective mismatch: fast {oa} vs reference {ob}"
        );
        assert!(
            (fast.r2 - refr.r2).abs() <= 1e-3 * fast.r2.abs().max(refr.r2.abs()).max(1e-3),
            "r2 mismatch: {} vs {}",
            fast.r2,
            refr.r2
        );
        // feasibility of the fast path
        assert!((fast.alpha.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(fast.alpha.iter().all(|&x| (-1e-12..=c + 1e-9).contains(&x)));
    });
}

#[test]
fn prop_sv_sets_agree_on_well_posed_problems() {
    // Gaussian kernels over distinct points give a strictly convex dual
    // => unique alpha; there the two paths must agree point-by-point
    // and produce the same SV set.
    forall("sv set equality", 20, |g| {
        let n = g.usize_in(5, 50);
        let data = seeded_points(g.usize_in(0, 1 << 30) as u64, n, 2, 2.0);
        let kernel = Kernel::gaussian(g.f64_in(0.5, 1.5));
        let f = g.f64_in(0.1, 0.5);
        let c = 1.0 / (n as f64 * f);
        let fast_opts = SmoOptions { shrink_every: 5, ..Default::default() };
        let ref_opts = SmoOptions { wss: Wss::First, shrinking: false, ..Default::default() };
        let mut a = DenseKernel::from_data(&data, kernel);
        let mut b = DenseKernel::from_data(&data, kernel);
        let fast = solve(&mut a, c, &fast_opts).unwrap();
        let refr = solve(&mut b, c, &ref_opts).unwrap();
        for i in 0..n {
            assert!(
                (fast.alpha[i] - refr.alpha[i]).abs() < 1e-2,
                "alpha[{i}]: {} vs {}",
                fast.alpha[i],
                refr.alpha[i]
            );
            // membership at a firm threshold implies membership at a
            // loose one in the other solution
            if fast.alpha[i] > 1e-2 {
                assert!(refr.alpha[i] > 1e-5, "SV {i} missing from reference");
            }
            if refr.alpha[i] > 1e-2 {
                assert!(fast.alpha[i] > 1e-5, "SV {i} missing from fast path");
            }
        }
    });
}

#[test]
fn prop_warm_start_equivalent_to_cold() {
    // warm-starting from an arbitrary feasible (or infeasible —
    // projected) guess must land on the same objective as cold start
    forall("warm start equivalence", 20, |g| {
        let n = g.usize_in(3, 40);
        let (kernel, scale) = three_kernels(g);
        let data = seeded_points(g.usize_in(0, 1 << 30) as u64, n, 2, scale);
        let f = g.f64_in(0.1, 0.6);
        let c = 1.0 / (n as f64 * f);
        let guess = g.vec_f64(n, 0.0, 2.0 * c.min(10.0));
        let dense = DenseKernel::from_data(&data, kernel);
        let mut a = DenseKernel::from_data(&data, kernel);
        let mut b = DenseKernel::from_data(&data, kernel);
        let cold = solve(&mut a, c, &SmoOptions::default()).unwrap();
        let warm =
            solve_with_init(&mut b, c, &SmoOptions::default(), Some(&guess[..])).unwrap();
        assert!(warm.gap < 1e-4);
        assert!((warm.alpha.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(warm.alpha.iter().all(|&x| (-1e-12..=c + 1e-9).contains(&x)));
        let (oc, ow) = (objective(&dense, &cold.alpha), objective(&dense, &warm.alpha));
        let scale = oc.abs().max(ow.abs()).max(1e-3);
        assert!((oc - ow).abs() <= 1e-4 * scale, "cold {oc} vs warm {ow}");
    });
}

// ---------------------------------------------------------------------
// Deterministic degenerate inputs across every mode
// ---------------------------------------------------------------------

#[test]
fn degenerate_inputs_solve_in_every_mode() {
    let modes = [
        SmoOptions::default(),
        SmoOptions { wss: Wss::First, shrinking: false, ..Default::default() },
        SmoOptions { shrink_every: 2, ..Default::default() },
        SmoOptions::legacy(),
    ];
    // n = 1
    for opts in modes {
        let one = Matrix::from_rows(&[vec![1.0, -2.0]]).unwrap();
        let mut kp = DenseKernel::from_data(&one, Kernel::gaussian(1.0));
        let sol = solve(&mut kp, 1.5, &opts).unwrap();
        assert_eq!(sol.alpha, vec![1.0]);
        assert!(sol.r2.abs() < 1e-12);
    }
    // all rows identical: every feasible alpha is optimal, R^2 = 0
    for opts in modes {
        let same = Matrix::from_rows(&vec![vec![0.5, 0.5]; 6]).unwrap();
        let mut kp = DenseKernel::from_data(&same, Kernel::gaussian(1.0));
        let sol = solve(&mut kp, 0.5, &opts).unwrap();
        assert!((sol.alpha.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(sol.r2.abs() < 1e-9, "r2={}", sol.r2);
    }
    // all-interior: a tight cluster with C >= 1 (box never binds); the
    // solution exists and scores the cluster center inside
    for opts in modes {
        let pts: Vec<Vec<f64>> = (0..12)
            .map(|i| {
                let t = i as f64 * 0.524;
                vec![t.cos() * 0.01, t.sin() * 0.01]
            })
            .collect();
        let tight = Matrix::from_rows(&pts).unwrap();
        let mut kp = DenseKernel::from_data(&tight, Kernel::gaussian(2.0));
        let sol = solve(&mut kp, 2.0, &opts).unwrap();
        assert!(sol.gap < 1e-4);
        assert!(sol.r2 >= 0.0 && sol.r2 < 1e-4, "tiny cluster r2={}", sol.r2);
    }
}
