//! End-to-end model-lifecycle test: a live scoring server keeps
//! answering concurrent clients with zero errors while a
//! drift-triggered warm-start retrain produces a new version, the
//! registry promotes it and hot-swaps it into the serve path — then an
//! operator rollback restores the previous champion, all without a
//! single dropped connection.

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use fastsvdd::data::{banana::Banana, Generator};
use fastsvdd::registry::{Lifecycle, Registry};
use fastsvdd::sampling::{SamplingConfig, StreamingConfig, StreamingSvdd};
use fastsvdd::scoring::{BatchPolicy, ScoreClient, ScoreServer};
use fastsvdd::svdd::SvddParams;
use fastsvdd::util::matrix::Matrix;

fn shifted_banana(n: usize, seed: u64) -> Matrix {
    let mut m = Banana::default().generate(n, seed);
    for i in 0..m.rows() {
        m.row_mut(i)[0] += 8.0;
    }
    m
}

#[test]
fn lifecycle_drift_retrain_swap_and_rollback_under_load() {
    let params = SvddParams::gaussian(0.35, 0.001);
    let cfg = SamplingConfig { sample_size: 6, ..Default::default() };
    let dir = std::env::temp_dir().join(format!(
        "fastsvdd_e2e_lifecycle_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();

    // ---- v1: bootstrap a champion from regime A ----
    let regime_a = Banana::default().generate(3000, 1);
    let mut boot = Lifecycle::new(Registry::open(&dir).unwrap(), params, cfg);
    let r1 = boot.retrain(&regime_a, 7).unwrap();
    assert!(!r1.warm_start, "empty registry must cold-start");
    let (id1, v1) = boot.registry().champion_model().unwrap().unwrap();
    assert_eq!(id1, r1.id);
    drop(boot);

    // ---- serve v1, wire the lifecycle to the server's slot ----
    let policy = BatchPolicy {
        target_batch: 32,
        linger: Duration::from_micros(200),
        capacity: 1 << 16,
        ..BatchPolicy::default()
    };
    let mut server =
        ScoreServer::spawn("127.0.0.1:0", v1.clone(), policy, |m, zs| Ok(m.dist2_batch(zs)))
            .unwrap();
    let mut lifecycle = Lifecycle::new(Registry::open(&dir).unwrap(), params, cfg)
        .with_slot(server.slot())
        .with_metrics(server.metrics.clone());

    // ---- concurrent clients hammer the server across the swap ----
    let stop = Arc::new(AtomicBool::new(false));
    let errors = Arc::new(AtomicU64::new(0));
    let addr = server.addr();
    let zs = Banana::default().generate(8, 9);
    let clients: Vec<_> = (0..3)
        .map(|_| {
            let stop = stop.clone();
            let errors = errors.clone();
            let zs = zs.clone();
            std::thread::spawn(move || {
                let mut seen_r2 = HashSet::new();
                let mut replies = 0u64;
                match ScoreClient::connect(addr) {
                    Ok(client) => {
                        while !stop.load(Ordering::Relaxed) {
                            match client.score(&zs) {
                                Ok((dist2, r2)) => {
                                    assert_eq!(dist2.len(), zs.rows());
                                    seen_r2.insert(r2.to_bits());
                                    replies += 1;
                                }
                                Err(_) => {
                                    errors.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        }
                        client.close();
                    }
                    Err(_) => {
                        errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
                (replies, seen_r2)
            })
        })
        .collect();

    // ---- drift on regime B triggers a warm-start retrain ----
    let monitor_cfg = StreamingConfig {
        window: 128,
        sample_size: 6,
        drift_threshold: 0.02,
        drift_patience: 1,
        ..Default::default()
    };
    let mut monitor = StreamingSvdd::new(params, monitor_cfg, 11);
    let warmup = regime_a.gather(&(0..512).collect::<Vec<_>>());
    monitor.push_batch(&warmup).unwrap();
    let regime_b = shifted_banana(3000, 2);
    let mut report = None;
    for i in 0..regime_b.rows() {
        if let Some(status) = monitor.push(regime_b.row(i)).unwrap() {
            if let Some(rep) = lifecycle.observe(status, &regime_b, 1234).unwrap() {
                report = Some(rep);
                break;
            }
        }
    }
    let r2rep = report.expect("regime change never reported Drifted");
    assert!(r2rep.warm_start, "champion existed, retrain must warm-start");
    assert_ne!(r2rep.id, r1.id, "new regime must produce a new version");
    assert!(r2rep.epoch.is_some(), "retrain must hot-swap the serving slot");

    // let the clients observe v2, then stop them: zero errors end to end
    std::thread::sleep(Duration::from_millis(100));
    stop.store(true, Ordering::Relaxed);
    let mut total_replies = 0u64;
    let mut seen_r2 = HashSet::new();
    for t in clients {
        let (replies, seen) = t.join().unwrap();
        total_replies += replies;
        seen_r2.extend(seen);
    }
    assert_eq!(
        errors.load(Ordering::Relaxed),
        0,
        "clients saw errors across the hot-swap"
    );
    assert!(total_replies > 0, "clients never scored");
    // every reply carried exactly one of the two promoted thresholds
    let allowed: HashSet<u64> = [v1.r2().to_bits(), r2rep.r2.to_bits()].into();
    assert!(
        seen_r2.is_subset(&allowed),
        "a reply carried a threshold of neither version"
    );

    // ---- subsequent replies reflect v2 ----
    let probe = ScoreClient::connect(addr).unwrap();
    let (_, r2_now) = probe.score(&zs).unwrap();
    assert_eq!(r2_now.to_bits(), r2rep.r2.to_bits());
    let info = probe.model_info().unwrap();
    assert_eq!(info.version, r2rep.id.as_str());
    assert!(info.epoch >= 1);

    // ---- the registry lists both versions, champion = v2 ----
    let entries = lifecycle.registry().list().unwrap();
    assert_eq!(entries.len(), 2);
    let ids: Vec<_> = entries.iter().map(|e| e.id.clone()).collect();
    assert!(ids.contains(&r1.id) && ids.contains(&r2rep.id));
    for e in &entries {
        assert_eq!(e.meta.warm_start, e.id == r2rep.id);
    }
    assert_eq!(lifecycle.registry().champion().unwrap().unwrap().id, r2rep.id);

    // ---- rollback restores v1 on the live serve path ----
    let back = lifecycle.rollback().unwrap();
    assert_eq!(back, r1.id);
    let (_, r2_back) = probe.score(&zs).unwrap();
    assert_eq!(r2_back.to_bits(), v1.r2().to_bits());
    assert_eq!(probe.model_info().unwrap().version, r1.id.as_str());
    probe.close();

    assert!(server.metrics.model_swaps.get() >= 2, "retrain + rollback swaps");
    assert_eq!(server.metrics.retrains_warm.get(), 1);
    server.stop();
    std::fs::remove_dir_all(&dir).ok();
}

/// One HTTP/1.1 GET against the scoring listener; returns (head, body).
fn http_get(addr: std::net::SocketAddr, path: &str) -> (String, String) {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: test\r\nConnection: close\r\n\r\n").unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    let (head, body) = buf.split_once("\r\n\r\n").expect("no header/body separator");
    (head.to_string(), body.to_string())
}

/// Prometheus scrapes share the listener with native scoring clients:
/// 20 scrapes interleave with live scoring traffic and every one must
/// return a complete, well-formed exposition while not a single score
/// request errors.
#[test]
fn metrics_scrape_is_concurrent_with_scoring() {
    let params = SvddParams::gaussian(0.35, 0.001);
    let data = Banana::default().generate(600, 3);
    let model = fastsvdd::svdd::train(&data, &params).unwrap();
    let policy = BatchPolicy {
        target_batch: 16,
        linger: Duration::from_micros(200),
        capacity: 1 << 12,
        ..BatchPolicy::default()
    };
    let mut server =
        ScoreServer::spawn("127.0.0.1:0", model, policy, |m, zs| Ok(m.dist2_batch(zs)))
            .unwrap();
    let addr = server.addr();

    let stop = Arc::new(AtomicBool::new(false));
    let errors = Arc::new(AtomicU64::new(0));
    let zs = Banana::default().generate(8, 9);
    let clients: Vec<_> = (0..2)
        .map(|_| {
            let stop = stop.clone();
            let errors = errors.clone();
            let zs = zs.clone();
            std::thread::spawn(move || {
                let mut replies = 0u64;
                match ScoreClient::connect(addr) {
                    Ok(client) => {
                        while !stop.load(Ordering::Relaxed) {
                            match client.score(&zs) {
                                Ok((dist2, _)) => {
                                    assert_eq!(dist2.len(), zs.rows());
                                    replies += 1;
                                }
                                Err(_) => {
                                    errors.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        }
                        client.close();
                    }
                    Err(_) => {
                        errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
                replies
            })
        })
        .collect();

    for _ in 0..20 {
        let (head, body) = http_get(addr, "/metrics");
        assert!(head.starts_with("HTTP/1.1 200 OK"), "scrape failed: {head}");
        assert!(head.contains("text/plain"), "wrong content type: {head}");
        assert!(body.contains("fastsvdd_rows_scored_total"));
        assert!(body.contains("fastsvdd_score_latency_seconds_bucket{le=\"+Inf\"}"));
        assert!(body.ends_with('\n'), "exposition must end with a newline");
    }

    stop.store(true, Ordering::Relaxed);
    let mut total_replies = 0u64;
    for t in clients {
        total_replies += t.join().unwrap();
    }
    assert_eq!(errors.load(Ordering::Relaxed), 0, "scoring errored during scrapes");
    assert!(total_replies > 0, "clients never scored");

    // counters are bumped before replies are delivered, so a scrape
    // after the clients joined must see every scored row
    let (_, body) = http_get(addr, "/metrics");
    let rows: u64 = body
        .lines()
        .find(|l| l.starts_with("fastsvdd_rows_scored_total"))
        .and_then(|l| l.split_whitespace().last())
        .unwrap()
        .parse()
        .unwrap();
    assert_eq!(rows, total_replies * zs.rows() as u64);

    server.stop();
}
