//! Cross-module integration tests: whole training/scoring/distribution
//! pipelines wired together the way the examples and benches use them
//! (no artifacts needed — the XLA paths have their own suite).

use fastsvdd::baselines::{train_full, train_kim, train_luo, KimConfig, LuoConfig};
use fastsvdd::config::{Method, RunConfig};
use fastsvdd::data::grid::{agreement, Grid};
use fastsvdd::data::polygon::Polygon;
use fastsvdd::data::shuttle::Shuttle;
use fastsvdd::data::tennessee::TennesseePlant;
use fastsvdd::data::{banana::Banana, donut::TwoDonut, star::Star, Generator};
use fastsvdd::distributed::{train_local_cluster, DistributedConfig};
use fastsvdd::engine::Engine;
use fastsvdd::incremental::{reduce_and_train, IncrementalSvdd};
use fastsvdd::sampling::{SamplingConfig, SamplingTrainer, StreamingConfig, StreamingSvdd};
use fastsvdd::scoring::{F1Score, Scorer};
use fastsvdd::svdd::{SvddModel, SvddParams, Wss};

/// The paper's central claim on a full pipeline: the sampling method's
/// grid decision map closely matches the full method's (Fig 8).
#[test]
fn sampling_grid_agreement_with_full() {
    let data = Star::default().generate(6000, 42);
    let params = SvddParams::gaussian(0.17, 0.001);
    let full = train_full(&data, &params).unwrap().model;
    let cfg = SamplingConfig { sample_size: 11, ..Default::default() };
    let samp = SamplingTrainer::new(params, cfg).train(&data, 7).unwrap().model;

    let grid = Grid::covering(&data, 100, 100, 0.15);
    let pts = grid.points();
    let a = Scorer::native(&full).inside_batch(&pts).unwrap();
    let b = Scorer::native(&samp).inside_batch(&pts).unwrap();
    let agr = agreement(&a, &b);
    assert!(agr > 0.95, "grid agreement only {agr}");
}

/// Model save -> load -> score must be bit-stable (the serve workflow).
#[test]
fn train_save_load_score_roundtrip() {
    let data = Banana::default().generate(2000, 1);
    let params = SvddParams::gaussian(0.35, 0.001);
    let cfg = SamplingConfig { sample_size: 6, ..Default::default() };
    let model = SamplingTrainer::new(params, cfg).train(&data, 3).unwrap().model;

    let dir = std::env::temp_dir().join("fastsvdd_pipeline_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.json");
    model.save(&path).unwrap();
    let loaded = SvddModel::load(&path).unwrap();

    let probes = Banana::default().generate(500, 2);
    let a = model.dist2_batch(&probes);
    let b = loaded.dist2_batch(&probes);
    assert_eq!(a, b);
    std::fs::remove_file(&path).ok();
}

/// Distributed == near-full quality on sharded data (paper section III-1).
#[test]
fn distributed_pipeline_quality() {
    let data = Banana::default().generate(12_000, 9);
    let params = SvddParams::gaussian(0.35, 0.001);
    let dcfg = DistributedConfig {
        workers: 4,
        sampling: SamplingConfig { sample_size: 6, ..Default::default() },
        seed: 5,
        ..Default::default()
    };
    let dist = train_local_cluster(&data, &params, &dcfg).unwrap();
    let full = train_full(&data, &params).unwrap();
    let rel = (dist.model.r2() - full.model.r2()).abs() / full.model.r2();
    assert!(rel < 0.05, "distributed R^2 off by {rel}");
    assert!(dist.union_rows < 400, "union unexpectedly large: {}", dist.union_rows);
}

/// Shuttle-like high-dimensional pipeline: F1 ratio ~ 1 (Fig 9).
#[test]
fn shuttle_f1_ratio_near_one() {
    let train_data = Shuttle.training(4000, 42);
    let scoring = Shuttle.scoring(6000, 99);
    let bw = fastsvdd::svdd::bandwidth::median_heuristic(&train_data, 10_000, 1);
    let params = SvddParams::gaussian(bw, 0.005);

    let full = train_full(&train_data, &params).unwrap().model;
    let f1_full = F1Score::compute(
        &scoring.labels,
        &Scorer::native(&full).inside_batch(&scoring.data).unwrap(),
    );
    let cfg = SamplingConfig { sample_size: 10, ..Default::default() };
    let samp = SamplingTrainer::new(params, cfg).train(&train_data, 7).unwrap().model;
    let f1_samp = F1Score::compute(
        &scoring.labels,
        &Scorer::native(&samp).inside_batch(&scoring.data).unwrap(),
    );
    let ratio = f1_samp.f1 / f1_full.f1;
    assert!(
        (0.9..=1.1).contains(&ratio),
        "F1 ratio {ratio}: full={} samp={}",
        f1_full.f1,
        f1_samp.f1
    );
    // and the models are actually good, not both degenerate
    assert!(f1_full.f1 > 0.8, "full F1 only {}", f1_full.f1);
}

/// Tennessee pipeline: faults are detected, normals mostly pass.
#[test]
fn tennessee_monitoring_pipeline() {
    let plant = TennesseePlant::default();
    let train_data = plant.training(5000, 42);
    let bw = fastsvdd::svdd::bandwidth::median_heuristic(&train_data, 10_000, 1);
    let params = SvddParams::gaussian(bw, 0.005);
    let cfg = SamplingConfig { sample_size: 42, ..Default::default() };
    let model = SamplingTrainer::new(params, cfg).train(&train_data, 7).unwrap().model;
    let scorer = Scorer::native(&model);

    let normal = plant.simulate(2000, None, 77);
    let fa = scorer
        .label_batch(&normal)
        .unwrap()
        .iter()
        .filter(|&&o| o)
        .count();
    assert!(fa < 200, "false alarm rate too high: {fa}/2000");

    // a strong step fault must be flagged most of the time
    let faulty = plant.simulate(500, Some(1), 78);
    let detected = scorer
        .label_batch(&faulty)
        .unwrap()[100..]
        .iter()
        .filter(|&&o| o)
        .count();
    assert!(detected > 200, "step fault barely detected: {detected}/400");
}

/// The two prior-art baselines produce full-quality models (they are
/// *slow*, not wrong — the paper's comparison).
#[test]
fn baselines_match_full_quality() {
    let data = Banana::default().generate(3000, 4);
    let params = SvddParams::gaussian(0.35, 0.001);
    let full = train_full(&data, &params).unwrap().model;
    let luo = train_luo(&data, &params, &LuoConfig::default()).unwrap();
    let kim = train_kim(&data, &params, &KimConfig::default()).unwrap();
    for (name, m) in [("luo", &luo.model), ("kim", &kim.model)] {
        let rel = (m.r2() - full.r2()).abs() / full.r2();
        assert!(rel < 0.1, "{name} R^2 off by {rel}");
    }
    assert!(luo.scoring_passes >= 1);
}

/// Config-driven run: the launcher workflow in library form.
#[test]
fn config_driven_training() {
    let cfg = RunConfig::from_json_text(
        r#"{"dataset": "banana", "rows": 2000, "bandwidth": 0.35,
            "outlier_fraction": 0.001, "method": "sampling",
            "sample_size": 6, "seed": 11}"#,
    )
    .unwrap();
    assert_eq!(cfg.method, Method::Sampling);
    let data = Banana::default().generate(cfg.rows, cfg.seed);
    let out = SamplingTrainer::new(cfg.params(), cfg.sampling())
        .train(&data, cfg.seed)
        .unwrap();
    assert!(out.model.r2() > 0.5);
}

// ---------------------------------------------------------------------
// Engine ↔ legacy equivalence: for every method, training through the
// unified `Engine::from_config` facade must be BYTE-identical to the
// pre-refactor entry point on the same seeded data — the engine is a
// pure re-plumbing, never a re-implementation.
// ---------------------------------------------------------------------

/// Bitwise model equality: thresholds, duals and SV rows must carry the
/// exact same bits (f64 compare via to_bits; content_id hashes them).
fn assert_models_identical(engine: &SvddModel, legacy: &SvddModel, what: &str) {
    assert_eq!(
        engine.r2().to_bits(),
        legacy.r2().to_bits(),
        "{what}: R^2 differs ({} vs {})",
        engine.r2(),
        legacy.r2()
    );
    assert_eq!(engine.w().to_bits(), legacy.w().to_bits(), "{what}: W differs");
    assert_eq!(engine.num_sv(), legacy.num_sv(), "{what}: #SV differs");
    let ea: Vec<u64> = engine.alpha().iter().map(|x| x.to_bits()).collect();
    let la: Vec<u64> = legacy.alpha().iter().map(|x| x.to_bits()).collect();
    assert_eq!(ea, la, "{what}: alpha differs");
    assert_eq!(engine.support_vectors(), legacy.support_vectors(), "{what}: SV rows differ");
    assert_eq!(engine.content_id(), legacy.content_id(), "{what}: content id differs");
}

fn banana_cfg(method: Method) -> RunConfig {
    RunConfig {
        dataset: "banana".into(),
        rows: 1500,
        bandwidth: 0.35,
        outlier_fraction: 0.001,
        method,
        sample_size: 6,
        seed: 11,
        ..RunConfig::default()
    }
}

#[test]
fn engine_full_matches_legacy() {
    let cfg = banana_cfg(Method::Full);
    let data = Banana::default().generate(cfg.rows, cfg.seed);
    let legacy = train_full(&data, &cfg.params()).unwrap();
    let report = Engine::from_config(&cfg).unwrap().train(&data).unwrap();
    assert_models_identical(&report.model, &legacy.model, "full");
    assert_eq!(report.solver.smo_iterations, legacy.solver.smo_iterations);
}

#[test]
fn engine_sampling_matches_legacy_k1_stream() {
    // the seeded K=1 stream is the paper's Algorithm 1 reference
    let cfg = banana_cfg(Method::Sampling);
    let data = Banana::default().generate(cfg.rows, cfg.seed);
    let legacy = SamplingTrainer::new(cfg.params(), cfg.sampling())
        .train(&data, cfg.seed)
        .unwrap();
    let report = Engine::from_config(&cfg).unwrap().train(&data).unwrap();
    assert_models_identical(&report.model, &legacy.model, "sampling K=1");
    assert_eq!(report.iterations, legacy.iterations);
    assert_eq!(report.converged, legacy.converged);
    assert_eq!(report.solver_calls, legacy.solver_calls);
    assert_eq!(report.rows_touched, legacy.rows_touched);
    assert_eq!(report.solver.smo_iterations, legacy.solver.smo_iterations);
}

#[test]
fn engine_sampling_matches_legacy_wss_legacy_golden() {
    // the frozen pre-Solver SMO loop must replay identically through
    // the engine (`--wss legacy`)
    let mut cfg = banana_cfg(Method::Sampling);
    cfg.wss = Wss::Legacy;
    cfg.shrinking = false;
    let data = Banana::default().generate(cfg.rows, cfg.seed);
    let legacy = SamplingTrainer::new(cfg.params(), cfg.sampling())
        .train(&data, cfg.seed)
        .unwrap();
    let report = Engine::from_config(&cfg).unwrap().train(&data).unwrap();
    assert_models_identical(&report.model, &legacy.model, "sampling wss=legacy");
    assert_eq!(report.iterations, legacy.iterations);
}

#[test]
fn engine_sampling_matches_legacy_candidates_and_warm_alpha() {
    let mut cfg = banana_cfg(Method::Sampling);
    cfg.candidates_per_iter = 4;
    cfg.warm_alpha = true;
    let data = Banana::default().generate(cfg.rows, cfg.seed);
    let legacy = SamplingTrainer::new(cfg.params(), cfg.sampling())
        .train(&data, cfg.seed)
        .unwrap();
    let report = Engine::from_config(&cfg).unwrap().train(&data).unwrap();
    assert_models_identical(&report.model, &legacy.model, "sampling K=4 warm_alpha");
    assert_eq!(report.solver_calls, legacy.solver_calls);
}

#[test]
fn engine_warm_start_matches_legacy_train_warm() {
    let cfg = banana_cfg(Method::Sampling);
    let data = Banana::default().generate(cfg.rows, cfg.seed);
    let trainer = SamplingTrainer::new(cfg.params(), cfg.sampling());
    let first = trainer.train(&data, cfg.seed).unwrap();
    let legacy = trainer.train_warm(&data, 99, &first.model).unwrap();
    let engine = Engine::from_config(&cfg).unwrap();
    let mut ctx = engine.context().with_warm_start(&first.model);
    ctx.seed = 99;
    let report = engine.train_with(&ctx, &data).unwrap();
    assert!(report.warm_start);
    assert_models_identical(&report.model, &legacy.model, "sampling warm start");
    assert_eq!(report.iterations, legacy.iterations);
}

#[test]
fn engine_luo_matches_legacy() {
    let mut cfg = banana_cfg(Method::Luo);
    cfg.dataset = "two-donut".into();
    cfg.bandwidth = 0.4;
    let data = TwoDonut::default().generate(cfg.rows, cfg.seed);
    let legacy = train_luo(&data, &cfg.params(), &LuoConfig::default()).unwrap();
    let report = Engine::from_config(&cfg).unwrap().train(&data).unwrap();
    assert_models_identical(&report.model, &legacy.model, "luo");
    assert_eq!(report.iterations, legacy.rounds);
    assert_eq!(report.solver_calls, legacy.solver_calls);
}

#[test]
fn engine_kim_matches_legacy() {
    let mut cfg = banana_cfg(Method::Kim);
    cfg.dataset = "two-donut".into();
    cfg.bandwidth = 0.4;
    let data = TwoDonut::default().generate(cfg.rows, cfg.seed);
    let legacy = train_kim(&data, &cfg.params(), &KimConfig::default()).unwrap();
    let report = Engine::from_config(&cfg).unwrap().train(&data).unwrap();
    assert_models_identical(&report.model, &legacy.model, "kim");
    assert_eq!(report.extras_line(), format!("pooled_svs={}", legacy.pooled_svs));
}

#[test]
fn engine_distributed_matches_legacy() {
    let mut cfg = banana_cfg(Method::Distributed);
    cfg.rows = 4000;
    cfg.workers = 3;
    let data = Banana::default().generate(cfg.rows, cfg.seed);
    let dcfg = DistributedConfig {
        workers: cfg.workers,
        sampling: cfg.sampling(),
        seed: cfg.seed,
        shuffle_seed: cfg.shuffle_seed,
        ..Default::default()
    };
    let legacy = train_local_cluster(&data, &cfg.params(), &dcfg).unwrap();
    let report = Engine::from_config(&cfg).unwrap().train(&data).unwrap();
    assert_models_identical(&report.model, &legacy.model, "distributed");
    assert_eq!(report.rows_touched, legacy.union_rows);
    assert_eq!(report.notes.len(), legacy.reports.len());
}

/// The default combine mode stays the paper's flat union solve, and an
/// explicit `--combine flat` is byte-identical to it — the pre-existing
/// seeded distributed trajectory is pinned across the fault-tolerance
/// rework.
#[test]
fn flat_combine_is_the_default_and_pinned() {
    use fastsvdd::distributed::CombineMode;
    let data = Banana::default().generate(4000, 5);
    let params = SvddParams::gaussian(0.35, 0.001);
    let dcfg = DistributedConfig {
        workers: 3,
        sampling: SamplingConfig { sample_size: 6, ..Default::default() },
        seed: 5,
        ..Default::default()
    };
    assert_eq!(dcfg.combine, CombineMode::Flat);
    let default_run = train_local_cluster(&data, &params, &dcfg).unwrap();
    let explicit = DistributedConfig { combine: CombineMode::Flat, ..dcfg };
    let explicit_run = train_local_cluster(&data, &params, &explicit).unwrap();
    assert_models_identical(&default_run.model, &explicit_run.model, "flat combine");
    assert_eq!(default_run.combine_solves, 1);
    // in-process workers cannot fail: the retry ledger stays zero
    assert_eq!(default_run.retry, fastsvdd::distributed::RetryStats::default());
}

#[test]
fn engine_streaming_matches_legacy_snapshot() {
    let mut cfg = banana_cfg(Method::Streaming);
    cfg.rows = 1024;
    let data = Banana::default().generate(cfg.rows, cfg.seed);
    // the manual spelling of the streaming snapshot (window 256 is the
    // StreamingConfig default the engine clamps to the data size)
    let scfg = StreamingConfig { sample_size: cfg.sample_size, ..Default::default() };
    let mut stream = StreamingSvdd::new(cfg.params(), scfg, cfg.seed);
    stream.push_batch(&data).unwrap();
    let legacy = stream.model().unwrap();
    let report = Engine::from_config(&cfg).unwrap().train(&data).unwrap();
    assert_models_identical(&report.model, legacy, "streaming");
    assert_eq!(report.iterations, stream.updates());
    assert_eq!(report.solver_calls, stream.solver_calls());
}

#[test]
fn engine_incremental_matches_legacy() {
    // the engine's Incremental trainer is a fixed seed-64-then-add
    // schedule over the online state machine; spelling that schedule
    // out by hand against `IncrementalSvdd` directly must carry the
    // exact same bits through every migration and resync
    let mut cfg = banana_cfg(Method::Incremental);
    cfg.rows = 400; // smaller than the streaming cases: per-point updates in debug tests
    let data = Banana::default().generate(cfg.rows, cfg.seed);
    let seed_n = data.rows().min(64);
    let seed_rows: Vec<usize> = (0..seed_n).collect();
    let mut inc =
        IncrementalSvdd::with_data(cfg.params(), cfg.incremental(), &data.gather(&seed_rows))
            .unwrap();
    for i in seed_n..data.rows() {
        inc.add_point(data.row(i)).unwrap();
    }
    let legacy = inc.model().unwrap();
    let report = Engine::from_config(&cfg).unwrap().train(&data).unwrap();
    assert_models_identical(&report.model, &legacy, "incremental");
    assert_eq!(report.iterations, inc.updates() as usize);
    assert_eq!(report.solver_calls, inc.resyncs() as usize);
    assert_eq!(report.sample_size, seed_n);
}

#[test]
fn engine_reduction_matches_legacy() {
    let mut cfg = banana_cfg(Method::Reduction);
    cfg.reduction_target = 120;
    let data = Banana::default().generate(cfg.rows, cfg.seed);
    let (legacy, _, out) =
        reduce_and_train(&data, &cfg.params(), &cfg.reduction(), cfg.seed).unwrap();
    let report = Engine::from_config(&cfg).unwrap().train(&data).unwrap();
    assert_models_identical(&report.model, &legacy, "reduction");
    assert_eq!(report.sample_size, out.kept.len());
    assert_eq!(out.kept.len(), 120);
    assert_eq!(report.rows_touched, out.pilot_size + out.kept.len());
}

/// Every method in `Method::ALL` — now including the two online-
/// learning entries — round-trips through config text and trains a
/// sane model via the unified engine facade.
#[test]
fn engine_trains_every_method_from_config_text() {
    for method in Method::ALL {
        let json = format!(
            r#"{{"dataset": "banana", "rows": 400, "bandwidth": 0.35,
                "outlier_fraction": 0.001, "method": "{}",
                "sample_size": 6, "workers": 2, "seed": 11,
                "reduction_target": 80}}"#,
            method.name()
        );
        let cfg = RunConfig::from_json_text(&json).unwrap();
        assert_eq!(cfg.method, method, "config round-trip for {method}");
        let data = Banana::default().generate(cfg.rows, cfg.seed);
        let report = Engine::from_config(&cfg).unwrap().train(&data).unwrap();
        assert_eq!(report.method, method);
        assert!(
            report.model.r2() > 0.0 && report.model.num_sv() > 0,
            "{method}: degenerate model (R^2={}, #SV={})",
            report.model.r2(),
            report.model.num_sv()
        );
    }
}

/// Polygon-study pipeline: ground truth from the polygon substrate,
/// F1 of the trained description against it (Fig 14-16 inner loop).
#[test]
fn polygon_f1_pipeline() {
    let poly = Polygon::random(10, 3.0, 5.0, 3);
    let train_pts = poly.sample_interior(600, 4);
    let params = SvddParams::gaussian(1.88, 0.01);
    let full = train_full(&train_pts, &params).unwrap().model;
    let ((x0, y0), (x1, y1)) = poly.bbox();
    let grid = Grid { nx: 80, ny: 80, x0, x1, y0, y1 };
    let truth = grid.labels_from(|x, y| poly.contains(x, y));
    let inside = Scorer::native(&full).inside_batch(&grid.points()).unwrap();
    let f1 = F1Score::compute(&truth, &inside);
    assert!(f1.f1 > 0.8, "polygon description F1 only {}", f1.f1);
}
