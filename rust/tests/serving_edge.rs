//! End-to-end serving-edge test: native-protocol and HTTP/JSON clients
//! hammer one edge server concurrently while the served model is
//! hot-swapped back and forth. Every reply must be internally
//! consistent — distances, threshold, epoch and content id all from the
//! *same* model (in-flight micro-batches finish on the pre-swap model),
//! with zero dropped connections and exact rows_scored accounting
//! across both ingresses.

use std::collections::HashSet;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use fastsvdd::data::{banana::Banana, Generator};
use fastsvdd::scoring::{BatchPolicy, ScoreClient, ScoreServer};
use fastsvdd::svdd::{train, SvddModel, SvddParams};
use fastsvdd::util::json::Json;
use fastsvdd::util::matrix::Matrix;

fn model(seed: u64, shift: f64) -> SvddModel {
    let mut data = Banana::default().generate(600, seed);
    for i in 0..data.rows() {
        data.row_mut(i)[0] += shift;
    }
    train(&data, &SvddParams::gaussian(0.35, 0.01)).unwrap()
}

/// `{"rows": [[..], ..]}` for `zs`. Rust's `{}` float formatting is
/// shortest-roundtrip, so the server parses back the exact same f64s
/// and its distances are bit-identical to a local `dist2_batch`.
fn rows_json(zs: &Matrix) -> String {
    let rows: Vec<String> = (0..zs.rows())
        .map(|i| {
            let vals: Vec<String> = zs.row(i).iter().map(|v| format!("{v}")).collect();
            format!("[{}]", vals.join(", "))
        })
        .collect();
    format!("{{\"rows\": [{}]}}", rows.join(", "))
}

/// One keep-alive POST /score exchange; returns (status, body JSON).
fn http_post_score(s: &mut TcpStream, body: &str) -> (u16, Json) {
    write!(
        s,
        "POST /score HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    read_response(s)
}

fn read_response(s: &mut TcpStream) -> (u16, Json) {
    let mut head = Vec::new();
    let mut byte = [0u8; 1];
    while !head.ends_with(b"\r\n\r\n") {
        s.read_exact(&mut byte).unwrap();
        head.push(byte[0]);
    }
    let head = String::from_utf8(head).unwrap();
    let status: u16 = head
        .lines()
        .next()
        .unwrap()
        .split_whitespace()
        .nth(1)
        .unwrap()
        .parse()
        .unwrap();
    let len: usize = head
        .lines()
        .find_map(|l| l.strip_prefix("Content-Length: "))
        .unwrap()
        .parse()
        .unwrap();
    let mut body = vec![0u8; len];
    s.read_exact(&mut body).unwrap();
    (status, Json::parse(std::str::from_utf8(&body).unwrap()).unwrap())
}

/// What one client thread saw: replies, distinct epochs.
type ClientLog = (u64, HashSet<u64>);

#[test]
fn swap_during_batch_keeps_replies_consistent_and_drops_nothing() {
    let m1 = model(1, 0.0);
    let m2 = model(2, 6.0);
    assert_ne!(m1.content_id(), m2.content_id());
    let policy = BatchPolicy {
        target_batch: 32,
        linger: Duration::from_millis(2),
        ..BatchPolicy::default()
    };
    let mut server = ScoreServer::builder("127.0.0.1:0")
        .model(m1.clone())
        .policy(policy)
        .http(true)
        .spawn(|m, zs| Ok(m.dist2_batch(zs)))
        .unwrap();
    let addr = server.addr();

    let zs = Banana::default().generate(8, 9);
    let e1 = m1.dist2_batch(&zs);
    let e2 = m2.dist2_batch(&zs);
    let (id1, id2) = (m1.content_id(), m2.content_id());
    let stop = Arc::new(AtomicBool::new(false));
    let errors = Arc::new(AtomicU64::new(0));

    // epochs alternate m1(even) / m2(odd): every reply's epoch must
    // agree with the model its content id names
    let check = {
        let (e1, e2) = (e1.clone(), e2.clone());
        let (id1, id2) = (id1.clone(), id2.clone());
        let (t1, t2) = (m1.r2(), m2.r2());
        move |dist2: &[f64], r2: f64, epoch: u64, model_id: &str| {
            if model_id == id1 {
                assert_eq!(dist2, e1.as_slice(), "m1 reply has foreign distances");
                assert_eq!(r2, t1, "m1 reply with m2 threshold");
                assert_eq!(epoch % 2, 0, "m1 reply with an m2 epoch");
            } else if model_id == id2 {
                assert_eq!(dist2, e2.as_slice(), "m2 reply has foreign distances");
                assert_eq!(r2, t2, "m2 reply with m1 threshold");
                assert_eq!(epoch % 2, 1, "m2 reply with an m1 epoch");
            } else {
                panic!("reply from unknown model {model_id}");
            }
        }
    };

    let native: Vec<_> = (0..3)
        .map(|_| {
            let stop = stop.clone();
            let errors = errors.clone();
            let zs = zs.clone();
            let check = check.clone();
            std::thread::spawn(move || -> ClientLog {
                let mut epochs = HashSet::new();
                let mut replies = 0u64;
                let client = ScoreClient::connect(addr).unwrap();
                while !stop.load(Ordering::Relaxed) {
                    match client.score_detailed(&zs) {
                        Ok(r) => {
                            check(&r.dist2, r.r2, r.epoch, &r.model_id);
                            epochs.insert(r.epoch);
                            replies += 1;
                        }
                        Err(_) => {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
                client.close();
                (replies, epochs)
            })
        })
        .collect();

    let http: Vec<_> = (0..2)
        .map(|_| {
            let stop = stop.clone();
            let errors = errors.clone();
            let body = rows_json(&zs);
            let check = check.clone();
            std::thread::spawn(move || -> ClientLog {
                let mut epochs = HashSet::new();
                let mut replies = 0u64;
                let mut s = TcpStream::connect(addr).unwrap();
                while !stop.load(Ordering::Relaxed) {
                    let (status, json) = http_post_score(&mut s, &body);
                    if status != 200 {
                        errors.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                    let dist2: Vec<f64> = json
                        .get("dist2")
                        .and_then(|v| v.as_arr())
                        .unwrap()
                        .iter()
                        .map(|v| v.as_f64().unwrap())
                        .collect();
                    let r2 = json.get("r2").and_then(|v| v.as_f64()).unwrap();
                    let epoch = json.get("epoch").and_then(|v| v.as_f64()).unwrap() as u64;
                    let model_id = json.get("model").and_then(|v| v.as_str()).unwrap();
                    check(&dist2, r2, epoch, model_id);
                    epochs.insert(epoch);
                    replies += 1;
                }
                (replies, epochs)
            })
        })
        .collect();

    // let everyone score the spawn-time model, then swap storm
    std::thread::sleep(Duration::from_millis(40));
    for i in 0..6u64 {
        let next = if i % 2 == 0 { m2.clone() } else { m1.clone() };
        assert_eq!(server.swap_model(next).unwrap(), i + 1);
        std::thread::sleep(Duration::from_millis(10));
    }
    std::thread::sleep(Duration::from_millis(40));
    stop.store(true, Ordering::Relaxed);

    let mut total_replies = 0u64;
    let mut epochs = HashSet::new();
    for t in native.into_iter().chain(http) {
        let (replies, seen) = t.join().unwrap();
        assert!(replies > 0, "a client never scored");
        total_replies += replies;
        epochs.extend(seen);
    }
    assert_eq!(errors.load(Ordering::Relaxed), 0, "a client saw an error");
    assert!(
        epochs.len() >= 2,
        "replies never spanned a swap: epochs {epochs:?}"
    );
    server.stop();
    // exact accounting: every scored row was counted exactly once, over
    // both ingresses — nothing dropped, nothing double-counted
    assert_eq!(
        server.metrics.rows_scored.get(),
        total_replies * zs.rows() as u64
    );
    assert_eq!(server.metrics.model_swaps.get(), 6);
    assert_eq!(server.metrics.shed_requests.get(), 0);
}

#[test]
fn http_ingress_gate_blocks_scoring_but_not_metrics() {
    let m = model(3, 0.0);
    let mut server = ScoreServer::builder("127.0.0.1:0")
        .model(m.clone())
        .http(false)
        .spawn(|m, zs| Ok(m.dist2_batch(zs)))
        .unwrap();
    let zs = Banana::default().generate(4, 7);
    let mut s = TcpStream::connect(server.addr()).unwrap();
    let (status, json) = http_post_score(&mut s, &rows_json(&zs));
    assert_eq!(status, 404);
    assert_eq!(
        json.get("error").and_then(|v| v.as_str()).unwrap(),
        "http_scoring_disabled"
    );
    // observability and native scoring stay on
    write!(s, "GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
    let mut head = Vec::new();
    let mut byte = [0u8; 1];
    while !head.ends_with(b"\r\n\r\n") {
        s.read_exact(&mut byte).unwrap();
        head.push(byte[0]);
    }
    assert!(head.starts_with(b"HTTP/1.1 200 OK"));
    let client = ScoreClient::connect(server.addr()).unwrap();
    let reply = client.score_detailed(&zs).unwrap();
    assert_eq!(reply.dist2, m.dist2_batch(&zs));
    client.close();
    server.stop();
}
