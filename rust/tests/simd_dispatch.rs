//! Cross-arm contracts of the kernel microkernel layer
//! (`linalg::isa` dispatch): every bit-identical arm must reproduce the
//! scalar reference **bit for bit** on ragged lengths, unaligned
//! slices and extreme magnitudes; the opt-in arms (FMA fusion, f32
//! panels) must stay inside their documented error bounds.
//!
//! These tests force arms explicitly through the `*_on` hooks, so they
//! never mutate the process-global selection and are safe under the
//! parallel test runner (and under `FASTSVDD_ISA=scalar`, which CI runs
//! as a second full pass).

use fastsvdd::linalg::{
    self, dot_block_f32, dot_block_on, dot_f32_on, dot_f32_scalar, dot_on, dot_scalar,
    isa, Isa, NormCache,
};
use fastsvdd::util::matrix::Matrix;

/// Ragged lengths around every boundary the arms care about: empty,
/// sub-lane, one f64x4 quad, quad+tail, one f32x8 oct, tile edges.
const LENGTHS: [usize; 11] = [0, 1, 3, 4, 7, 8, 63, 64, 65, 129, 200];

/// Deterministic xorshift stream in roughly [-3, 3].
fn stream(seed: u64) -> impl FnMut() -> f64 {
    let mut state = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
    move || {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        (state >> 11) as f64 / (1u64 << 53) as f64 * 6.0 - 3.0
    }
}

fn vecs(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut next = stream(seed);
    ((0..n).map(|_| next()).collect(), (0..n).map(|_| next()).collect())
}

/// Every concrete arm the host can run (always includes Scalar).
fn available_arms() -> Vec<Isa> {
    Isa::ALL.iter().copied().filter(|a| a.available()).collect()
}

/// The arms contracted to match [`dot_scalar`] bit for bit (everything
/// available except opt-in FMA).
fn bit_identical_arms() -> Vec<Isa> {
    available_arms().into_iter().filter(|&a| a != Isa::Fma).collect()
}

/// Documented f64 FMA closeness: fusing drops one rounding per madd, so
/// the divergence is bounded by a few ulps of the term-magnitude sum.
fn fma_tolerance(n: usize, abs_terms: f64) -> f64 {
    (n as f64 + 2.0) * (f64::EPSILON / 2.0) * abs_terms * 4.0 + 1e-300
}

/// Documented f32 panel bound: `(n + 2) * 2^-24 * sum_k |a_k * b_k|`
/// (times a safety margin — the bound is a worst case, not a promise of
/// tightness the other way).
fn f32_tolerance(n: usize, abs_terms: f64) -> f64 {
    (n as f64 + 2.0) * (0.5f64).powi(24) * abs_terms * 4.0 + 1e-30
}

#[test]
fn dot_bit_identity_across_arms_and_lengths() {
    for (i, &n) in LENGTHS.iter().enumerate() {
        let (a, b) = vecs(n, 11 + i as u64);
        let want = dot_scalar(&a, &b);
        for arm in bit_identical_arms() {
            let got = dot_on(arm, &a, &b);
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "n={n} arm={arm}: {got} != scalar {want}"
            );
        }
    }
}

#[test]
fn dot_bit_identity_on_unaligned_slices() {
    // one oversized buffer, sliced at every sub-lane offset: loadu must
    // make alignment irrelevant to both safety and the result bits
    let (a, b) = vecs(96, 99);
    for off in 0..5usize {
        for n in [0usize, 1, 4, 7, 33, 64] {
            let (sa, sb) = (&a[off..off + n], &b[off..off + n]);
            let want = dot_scalar(sa, sb);
            for arm in bit_identical_arms() {
                assert_eq!(
                    dot_on(arm, sa, sb).to_bits(),
                    want.to_bits(),
                    "off={off} n={n} arm={arm}"
                );
            }
        }
    }
}

#[test]
fn dot_bit_identity_at_extreme_magnitudes() {
    // +-1e150 coordinates: products are ~1e300 (near the f64 ceiling),
    // so any reassociation of the sum shows up immediately
    for n in [3usize, 8, 65] {
        let mut next = stream(7_000 + n as u64);
        let a: Vec<f64> = (0..n)
            .map(|k| if k % 2 == 0 { 1e150 } else { -1e150 } * (1.0 + next().abs()))
            .collect();
        let b: Vec<f64> = (0..n).map(|k| if k % 3 == 0 { -1e150 } else { 1e150 }).collect();
        let want = dot_scalar(&a, &b);
        assert!(want.is_finite(), "test vectors overflowed: {want}");
        for arm in bit_identical_arms() {
            assert_eq!(
                dot_on(arm, &a, &b).to_bits(),
                want.to_bits(),
                "n={n} arm={arm}"
            );
        }
    }
}

#[test]
fn dot_block_matches_per_pair_scalar_bitwise() {
    // ragged panels crossing the j-register-block (4) and TILE_J (8)
    // boundaries, including offset sub-ranges of both matrices
    for (rows_a, rows_b, cols) in
        [(1usize, 1usize, 1usize), (3, 5, 3), (7, 9, 41), (8, 8, 64), (9, 17, 5)]
    {
        let (flat_a, _) = vecs(rows_a * cols, 31 + cols as u64);
        let (flat_b, _) = vecs(rows_b * cols, 77 + cols as u64);
        let a = Matrix::from_vec(flat_a, rows_a, cols).unwrap();
        let b = Matrix::from_vec(flat_b, rows_b, cols).unwrap();
        let a0 = rows_a / 3;
        let b0 = rows_b / 2;
        let (na, nb) = (rows_a - a0, rows_b - b0);
        let mut want = vec![0.0f64; na * nb];
        for ia in 0..na {
            for ib in 0..nb {
                want[ia * nb + ib] = dot_scalar(a.row(a0 + ia), b.row(b0 + ib));
            }
        }
        for arm in bit_identical_arms() {
            let mut got = vec![0.0f64; na * nb];
            dot_block_on(arm, &a, a0..rows_a, &b, b0..rows_b, &mut got);
            for (k, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    w.to_bits(),
                    "panel {rows_a}x{rows_b}x{cols} entry {k} arm={arm}"
                );
            }
        }
    }
}

#[test]
fn norm_cache_is_arm_independent() {
    let (flat, _) = vecs(9 * 41, 123);
    let m = Matrix::from_vec(flat, 9, 41).unwrap();
    let cache = NormCache::new(&m);
    let arms = bit_identical_arms();
    for i in 0..m.rows() {
        let want = dot_scalar(m.row(i), m.row(i));
        // every bit-identical arm agrees on each norm...
        for &arm in &arms {
            assert_eq!(
                dot_on(arm, m.row(i), m.row(i)).to_bits(),
                want.to_bits(),
                "row {i} arm={arm}"
            );
        }
        // ...so the cache (built on the ambient dispatched arm) equals
        // the scalar reference unless FASTSVDD_ISA=fma opted out of
        // bit identity for this process
        if isa::selected() != Isa::Fma {
            assert_eq!(cache.get(i).to_bits(), want.to_bits(), "row {i}");
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[test]
fn fma_arm_stays_within_documented_closeness() {
    if !Isa::Fma.available() {
        return;
    }
    for (i, &n) in LENGTHS.iter().enumerate() {
        let (a, b) = vecs(n, 555 + i as u64);
        let want = dot_scalar(&a, &b);
        let got = dot_on(Isa::Fma, &a, &b);
        let abs_terms: f64 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum();
        assert!(
            (got - want).abs() <= fma_tolerance(n, abs_terms),
            "n={n}: fma {got} vs scalar {want} (terms {abs_terms})"
        );
    }
}

#[test]
fn f32_arms_are_mutually_bit_identical() {
    for (i, &n) in LENGTHS.iter().enumerate() {
        let (a64, b64) = vecs(n, 900 + i as u64);
        let a: Vec<f32> = a64.iter().map(|&x| x as f32).collect();
        let b: Vec<f32> = b64.iter().map(|&x| x as f32).collect();
        let want = dot_f32_scalar(&a, &b);
        for arm in bit_identical_arms() {
            assert_eq!(
                dot_f32_on(arm, &a, &b).to_bits(),
                want.to_bits(),
                "n={n} arm={arm}"
            );
        }
    }
}

#[test]
fn f32_dot_tracks_f64_within_analytic_bound() {
    // property sweep: many lengths x seeds against the documented bound
    for n in (1usize..40).chain([63, 64, 65, 127, 200, 333]) {
        for seed in 0..4u64 {
            let (a64, b64) = vecs(n, 40_000 + n as u64 * 7 + seed);
            let a: Vec<f32> = a64.iter().map(|&x| x as f32).collect();
            let b: Vec<f32> = b64.iter().map(|&x| x as f32).collect();
            // reference: exact f64 dot of the *narrowed* inputs (the
            // bound covers summation error, not input narrowing)
            let aw: Vec<f64> = a.iter().map(|&x| x as f64).collect();
            let bw: Vec<f64> = b.iter().map(|&x| x as f64).collect();
            let want = dot_scalar(&aw, &bw);
            let abs_terms: f64 = aw.iter().zip(&bw).map(|(x, y)| (x * y).abs()).sum();
            let tol = f32_tolerance(n, abs_terms);
            for arm in available_arms() {
                let got = dot_f32_on(arm, &a, &b) as f64;
                assert!(
                    (got - want).abs() <= tol,
                    "n={n} seed={seed} arm={arm}: f32 {got} vs f64 {want} (tol {tol:.3e})"
                );
            }
        }
    }
}

#[test]
fn f32_block_matches_per_pair_f32_bitwise() {
    for (rows_a, rows_b, cols) in [(1usize, 1usize, 1usize), (3, 5, 7), (9, 17, 41)] {
        let (fa, _) = vecs(rows_a * cols, 61);
        let (fb, _) = vecs(rows_b * cols, 62);
        let a: Vec<f32> = fa.iter().map(|&x| x as f32).collect();
        let b: Vec<f32> = fb.iter().map(|&x| x as f32).collect();
        let mut out = vec![0.0f32; rows_a * rows_b];
        dot_block_f32(&a, &b, cols, &mut out);
        if isa::selected() == Isa::Fma {
            continue; // explicit fused opt-in relaxes bit identity
        }
        for ia in 0..rows_a {
            for ib in 0..rows_b {
                let want =
                    dot_f32_scalar(&a[ia * cols..(ia + 1) * cols], &b[ib * cols..(ib + 1) * cols]);
                assert_eq!(
                    out[ia * rows_b + ib].to_bits(),
                    want.to_bits(),
                    "panel {rows_a}x{rows_b}x{cols} ({ia},{ib})"
                );
            }
        }
    }
}

#[test]
fn norms_and_sqdist_f32_follow_f64_semantics() {
    let (flat, _) = vecs(6 * 5, 321);
    let m = Matrix::from_vec(flat, 6, 5).unwrap();
    let f = m.to_f32();
    let norms = linalg::norms_f32(&f, 5);
    let cache = NormCache::new(&m);
    for i in 0..6 {
        let gap = (norms[i] as f64 - cache.get(i)).abs();
        assert!(gap <= f32_tolerance(5, cache.get(i).abs()), "row {i}");
    }
    // NaN/inf policy mirrors the f64 helper
    assert!(linalg::sqdist_from_norms_f32(f32::NAN, 1.0, 0.5).is_nan());
    assert_eq!(
        linalg::sqdist_from_norms_f32(f32::INFINITY, 1.0, f32::INFINITY),
        f32::INFINITY
    );
    assert_eq!(linalg::sqdist_from_norms_f32(1.0, 1.0, 1.0), 0.0);
}
