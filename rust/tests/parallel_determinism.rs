//! Determinism guarantees of the parallel execution subsystem.
//!
//! The pool's contract is that seeded runs are **bit-identical at any
//! thread count**: parallel Gram rows, parallel SMO kernel columns,
//! parallel batch scoring and multi-candidate training must all produce
//! exactly the single-thread path's bytes. Since the batched
//! kernel-compute layer landed, the bitwise anchor for kernel entries
//! is the **block path at one thread** (norm-cached `eval_block`
//! panels); the scalar `Kernel::eval` reference
//! (`DenseKernel::from_data_serial`) agrees to ULP-level relative
//! tolerance only — asserted here alongside the bit-identity checks.
//! These tests pin that contract across thread counts {1, 2, 8}, and
//! pin the K=1 sampling trainer to a golden re-implementation of the
//! pre-candidate sequential loop so the per-candidate RNG stream
//! derivation can never silently change seeded outputs.

use fastsvdd::data::banana::Banana;
use fastsvdd::data::tennessee::TennesseePlant;
use fastsvdd::data::Generator;
use fastsvdd::parallel::{gram, Pool, PooledGram};
use fastsvdd::sampling::{
    ConvergenceCriteria, ConvergenceTracker, SamplingConfig, SamplingTrainer,
};
use fastsvdd::svdd::smo::{self, DenseKernel, LazyKernel, SmoOptions};
use fastsvdd::svdd::{train, Kernel, SvddModel, SvddParams};
use fastsvdd::util::matrix::Matrix;
use fastsvdd::util::rng::Xoshiro256;

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

fn tennessee(rows: usize) -> Matrix {
    TennesseePlant::default().training(rows, 42)
}

#[test]
fn parallel_gram_bit_identical_across_thread_counts() {
    for (data, bw) in [
        (Banana::default().generate(301, 7), 0.35),
        (tennessee(97), 6.0),
    ] {
        let kernel = Kernel::gaussian(bw);
        // bitwise anchor: the block path at one thread
        let want = gram(&data, kernel, Pool::serial());
        for threads in THREAD_COUNTS {
            let got = gram(&data, kernel, Pool::new(threads));
            assert_eq!(
                got,
                want,
                "gram diverged at {threads} threads ({}x{})",
                data.rows(),
                data.cols()
            );
        }
        // the scalar reference agrees to tight tolerance (Gaussian
        // entries live in [0, 1], so absolute == relative scale here)
        let scalar = DenseKernel::from_data_serial(&data, kernel);
        for (b, s) in want.iter().zip(scalar.as_slice()) {
            assert!((b - s).abs() <= 1e-12, "block {b} vs scalar {s}");
        }
    }
}

#[test]
fn pooled_gram_backend_matches_single_thread_reference() {
    let data = tennessee(64);
    let kernel = Kernel::gaussian(4.0);
    let want = gram(&data, kernel, Pool::serial());
    for threads in THREAD_COUNTS {
        let be = PooledGram::with_pool(Pool::new(threads));
        let got = fastsvdd::sampling::GramBackend::gram(&be, &data, kernel).unwrap();
        assert_eq!(got, want);
    }
}

#[test]
fn parallel_scoring_bit_identical_across_thread_counts() {
    let data = Banana::default().generate(800, 1);
    let model = train(&data, &SvddParams::gaussian(0.35, 0.01)).unwrap();
    let zs = Banana::default().generate(4097, 2); // odd size: ragged last chunk
    let want = model.dist2_batch_pooled(&zs, Pool::serial());
    for threads in THREAD_COUNTS {
        let got = model.dist2_batch_pooled(&zs, Pool::new(threads));
        assert_eq!(got, want, "scoring diverged at {threads} threads");
    }
}

#[test]
fn parallel_lazy_columns_give_identical_smo_solution() {
    // An explicitly pinned pool bypasses the column work gate, so this
    // forces genuinely parallel column evaluation on a test-sized
    // problem and checks the full SMO solve is bit-identical to the
    // dense block-path solve (lazy columns and the block Gram produce
    // the same bits per entry — both are eval_block panels).
    let data = tennessee(800);
    let kernel = Kernel::gaussian(6.0);
    let c = 1.0 / (data.rows() as f64 * 0.05);
    let mut dense = DenseKernel::from_data_pooled(&data, kernel, Pool::serial());
    let want = smo::solve(&mut dense, c, &SmoOptions::default()).unwrap();
    for threads in THREAD_COUNTS {
        let mut lazy = LazyKernel::new(&data, kernel, 256 << 20).with_pool(Pool::new(threads));
        let got = smo::solve(&mut lazy, c, &SmoOptions::default()).unwrap();
        assert_eq!(got.r2.to_bits(), want.r2.to_bits());
        assert_eq!(got.iterations, want.iterations);
        for (a, b) in got.alpha.iter().zip(&want.alpha) {
            assert_eq!(a.to_bits(), b.to_bits(), "alpha diverged at {threads} threads");
        }
    }
}

/// Golden re-implementation of the sampling loop exactly as it existed
/// before `candidates_per_iter`: one sequential Xoshiro stream, one
/// sample + union solve per iteration. `SamplingTrainer` with K=1 must
/// reproduce this bit-for-bit — if stream derivation ever leaks into
/// the K=1 path, seeded historical runs change and this fails.
fn legacy_sampling_train(
    data: &Matrix,
    params: &SvddParams,
    cfg: &SamplingConfig,
    seed: u64,
) -> (SvddModel, usize, bool) {
    let n = cfg.sample_size.max(2).min(data.rows());
    let mut rng = Xoshiro256::new(seed);
    let s0 = data.gather(&rng.sample_with_replacement(data.rows(), n));
    let mut master = train(&s0.dedup_rows(), params).unwrap();

    let sv0 = master.support_vectors();
    let scale_floor = (0..sv0.rows())
        .map(|i| sv0.row(i).iter().map(|x| x * x).sum::<f64>().sqrt())
        .sum::<f64>()
        / sv0.rows() as f64;
    let mut tracker = ConvergenceTracker::new(ConvergenceCriteria {
        eps_center: cfg.eps_center,
        eps_r2: cfg.eps_r2,
        consecutive: cfg.consecutive,
        scale_floor,
    });
    tracker.observe(master.r2(), master.center());

    let mut iterations = 0;
    let mut converged = false;
    for i in 1..=cfg.max_iter {
        iterations = i;
        let si = data.gather(&rng.sample_with_replacement(data.rows(), n));
        let sv_i = train(&si.dedup_rows(), params).unwrap();
        let union = sv_i
            .support_vectors()
            .vstack(master.support_vectors())
            .unwrap()
            .dedup_rows();
        master = train(&union, params).unwrap();
        tracker.observe(master.r2(), master.center());
        if tracker.converged() {
            converged = true;
            break;
        }
    }
    (master, iterations, converged)
}

#[test]
fn k1_reproduces_legacy_sequential_outputs_exactly() {
    let data = Banana::default().generate(2500, 3);
    let params = SvddParams::gaussian(0.35, 0.001);
    let cfg = SamplingConfig { sample_size: 6, ..Default::default() };
    assert_eq!(cfg.candidates_per_iter, 1, "default K must stay 1");
    for seed in [7u64, 123, 9999] {
        let (want_model, want_iters, want_conv) =
            legacy_sampling_train(&data, &params, &cfg, seed);
        let got = SamplingTrainer::new(params, cfg).train(&data, seed).unwrap();
        assert_eq!(got.iterations, want_iters, "seed {seed}");
        assert_eq!(got.converged, want_conv, "seed {seed}");
        assert_eq!(got.model.r2().to_bits(), want_model.r2().to_bits(), "seed {seed}");
        assert_eq!(got.model.num_sv(), want_model.num_sv(), "seed {seed}");
        for (a, b) in got.model.alpha().iter().zip(want_model.alpha()) {
            assert_eq!(a.to_bits(), b.to_bits(), "seed {seed}");
        }
        assert_eq!(
            got.model.support_vectors().as_slice(),
            want_model.support_vectors().as_slice(),
            "seed {seed}"
        );
    }
}

#[test]
fn multi_candidate_training_identical_across_thread_counts() {
    let data = Banana::default().generate(3000, 5);
    let params = SvddParams::gaussian(0.35, 0.001);
    let cfg = SamplingConfig {
        sample_size: 6,
        candidates_per_iter: 3,
        max_iter: 60,
        ..Default::default()
    };
    let reference = SamplingTrainer::new(params, cfg)
        .with_pool(Pool::serial())
        .train(&data, 17)
        .unwrap();
    for threads in THREAD_COUNTS {
        let got = SamplingTrainer::new(params, cfg)
            .with_pool(Pool::new(threads))
            .train(&data, 17)
            .unwrap();
        assert_eq!(got.iterations, reference.iterations, "{threads} threads");
        let (a, b) = (got.model.r2().to_bits(), reference.model.r2().to_bits());
        assert_eq!(a, b, "{threads} threads");
        assert_eq!(got.model.alpha(), reference.model.alpha(), "{threads} threads");
        assert_eq!(got.solver_calls, reference.solver_calls, "{threads} threads");
        assert_eq!(got.rows_touched, reference.rows_touched, "{threads} threads");
    }
}

#[test]
fn dense_from_data_deterministic_and_near_scalar_reference() {
    // The default (pooled, global) constructor must equal the
    // single-thread block path bitwise for every kernel variant, and
    // sit within tight relative tolerance of the scalar triangle
    // reference (different summation order, same mathematics).
    let data = tennessee(83);
    for kernel in [
        Kernel::gaussian(3.0),
        Kernel::Linear,
        Kernel::Polynomial { degree: 3, coef: 0.5 },
    ] {
        let a = DenseKernel::from_data(&data, kernel);
        let b = DenseKernel::from_data_pooled(&data, kernel, Pool::serial());
        assert_eq!(a.as_slice(), b.as_slice(), "kernel {kernel}");
        let scalar = DenseKernel::from_data_serial(&data, kernel);
        for (x, y) in a.as_slice().iter().zip(scalar.as_slice()) {
            assert!(
                (x - y).abs() <= 1e-10 * y.abs().max(1.0),
                "kernel {kernel}: block {x} vs scalar {y}"
            );
        }
    }
}
