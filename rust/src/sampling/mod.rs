//! **The paper's contribution: Algorithm 1 — sampling-based iterative
//! SVDD training.**
//!
//! Each iteration draws a small random sample `S_i` (with replacement)
//! from the training data, computes its SVDD to get `SV_i`, unions it
//! with the master support-vector set `SV*`, re-solves SVDD on the
//! union, and promotes the result to the new `SV*`. Iteration stops at
//! `maxiter` or when both the threshold `R^2` and the center
//! `a = sum_i alpha_i x_i` are stable for `t` consecutive iterations:
//!
//! ```text
//! ||a_i - a_{i-1}||   <= eps1 * ||a_{i-1}||
//! |R2_i  - R2_{i-1}|  <= eps2 * R2_{i-1}
//! ```
//!
//! The trainer never scores the training set (the drawback of Luo et
//! al. [7] this method removes) and touches only the sampled rows.
//!
//! ## Lifecycle layer (drift → warm retrain → promote → swap)
//!
//! Because a sampling retrain is cheap, the system retrains
//! *continuously* in production: [`StreamingSvdd`] maintains the master
//! SV set online and raises [`DriftStatus::Drifted`] when the
//! description moves; the lifecycle driver
//! ([`crate::registry::Lifecycle`]) then calls
//! [`SamplingTrainer::train_warm`] — seeding `SV*` from the current
//! champion's support vectors, the incremental extension of Jiang et
//! al. (arXiv:1709.00139) — publishes the result to the versioned
//! [`crate::registry::Registry`], promotes it, and hot-swaps it into
//! the serving [`crate::scoring::ModelSlot`] with zero dropped
//! connections. A warm start typically converges in far fewer
//! iterations than a cold start because `R^2` and the center are
//! already near their fixed point; [`SamplingOutcome::warm_start`]
//! records which path produced a model so traces stay comparable.

pub mod adaptive;
pub mod convergence;
pub mod streaming;

use crate::error::Result;
use crate::svdd::kernel::Kernel;
use crate::svdd::model::SvddModel;
use crate::svdd::trainer::{train, train_with_gram, SvddParams};
use crate::util::matrix::Matrix;
use crate::util::rng::Xoshiro256;

pub use adaptive::{choose_sample_size, AdaptiveChoice, AdaptiveConfig};
pub use convergence::{ConvergenceCriteria, ConvergenceTracker};
pub use streaming::{DriftStatus, StreamingConfig, StreamingSvdd};

/// Pluggable gram-matrix backend: the XLA runtime implements this to
/// route the small union/sample solves through the AOT Pallas kernel;
/// `None` from [`GramBackend::gram`] falls back to native evaluation.
pub trait GramBackend: Send + Sync {
    /// Row-major `K(data, data)` (n*n) if this backend covers the shape.
    fn gram(&self, data: &Matrix, kernel: Kernel) -> Option<Vec<f64>>;
}

/// Algorithm-1 configuration (paper's notation in comments).
#[derive(Clone, Copy, Debug)]
pub struct SamplingConfig {
    /// `n` — random sample size per iteration. The paper's guidance:
    /// `m + 1` (dimension + 1) works well; its sweeps use 3..=20.
    pub sample_size: usize,
    /// `maxiter`.
    pub max_iter: usize,
    /// `eps1` — relative tolerance on the center.
    pub eps_center: f64,
    /// `eps2` — relative tolerance on `R^2`.
    pub eps_r2: f64,
    /// `t` — consecutive satisfied checks required.
    pub consecutive: usize,
    /// Record a per-iteration trace (Fig 7).
    pub record_trace: bool,
}

impl Default for SamplingConfig {
    fn default() -> Self {
        SamplingConfig {
            sample_size: 10,
            max_iter: 1000,
            eps_center: 3e-4,
            eps_r2: 3e-4,
            consecutive: 8,
            record_trace: false,
        }
    }
}

/// One point of the Fig-7 trace.
#[derive(Clone, Copy, Debug)]
pub struct TracePoint {
    pub iteration: usize,
    pub r2: f64,
    pub num_sv: usize,
    /// `||a_i - a_{i-1}|| / ||a_{i-1}||` (NaN on iteration 0).
    pub center_delta: f64,
}

/// Result of a sampling-trainer run.
#[derive(Clone, Debug)]
pub struct SamplingOutcome {
    pub model: SvddModel,
    /// Iterations executed (paper's "Iterations" column in Table II).
    pub iterations: usize,
    /// Whether the tolerance criterion fired (vs hitting `max_iter`).
    pub converged: bool,
    /// Total SMO solves (2 per iteration + 1 initial).
    pub solver_calls: usize,
    /// Total observations fed to solvers — the "fraction of the data
    /// the method ever looks at".
    pub rows_touched: usize,
    /// Whether `SV*` was seeded from a previous model
    /// ([`SamplingTrainer::train_warm`]) instead of a cold sample.
    pub warm_start: bool,
    pub trace: Vec<TracePoint>,
}

/// The Algorithm-1 trainer.
pub struct SamplingTrainer<'a> {
    params: SvddParams,
    cfg: SamplingConfig,
    backend: Option<&'a dyn GramBackend>,
}

impl<'a> SamplingTrainer<'a> {
    pub fn new(params: SvddParams, cfg: SamplingConfig) -> Self {
        SamplingTrainer { params, cfg, backend: None }
    }

    /// Route union/sample gram computations through an XLA backend.
    pub fn with_backend(mut self, backend: &'a dyn GramBackend) -> Self {
        self.backend = Some(backend);
        self
    }

    fn solve(&self, data: &Matrix, counters: &mut (usize, usize)) -> Result<SvddModel> {
        counters.0 += 1;
        counters.1 += data.rows();
        if let Some(be) = self.backend {
            if let Some(gram) = be.gram(data, self.params.kernel) {
                return train_with_gram(data, gram, &self.params);
            }
        }
        train(data, &self.params)
    }

    /// Run Algorithm 1 on `data` from a cold start.
    pub fn train(&self, data: &Matrix, seed: u64) -> Result<SamplingOutcome> {
        self.train_impl(data, seed, None)
    }

    /// Run Algorithm 1 on `data`, warm-starting the master set from a
    /// previously trained model: `SV*` is seeded with `initial_sv`'s
    /// support vectors (unioned with the first random sample) instead
    /// of a cold sample's SV set. When `initial_sv` described a similar
    /// regime, `R^2` and the center start near their fixed point and
    /// the run converges in far fewer iterations — this is what makes
    /// drift-triggered production retraining cheap (Jiang et al.,
    /// arXiv:1709.00139).
    pub fn train_warm(
        &self,
        data: &Matrix,
        seed: u64,
        initial_sv: &SvddModel,
    ) -> Result<SamplingOutcome> {
        if initial_sv.dim() != data.cols() {
            return Err(crate::error::Error::invalid(format!(
                "warm-start model is {}-d but data is {}-d",
                initial_sv.dim(),
                data.cols()
            )));
        }
        self.train_impl(data, seed, Some(initial_sv))
    }

    fn train_impl(
        &self,
        data: &Matrix,
        seed: u64,
        warm: Option<&SvddModel>,
    ) -> Result<SamplingOutcome> {
        let n = self.cfg.sample_size.max(2).min(data.rows());
        let mut rng = Xoshiro256::new(seed);
        let mut counters = (0usize, 0usize); // (solver calls, rows touched)

        // Step 1: S0 <- SAMPLE(T, n); SV* <- SV(delta S0).
        // Warm start: S0 is unioned with the previous model's SV set
        // first, so SV* begins at (a superset of) the old description.
        let s0 = data.gather(&rng.sample_with_replacement(data.rows(), n));
        let seed_set = match warm {
            None => s0.dedup_rows(),
            Some(init) => s0.vstack(init.support_vectors())?.dedup_rows(),
        };
        let mut master = self.solve(&seed_set, &mut counters)?;

        // Floor the center-criterion scale at the data scale (mean SV
        // norm) so symmetric data with ||a|| ~ 0 can still converge;
        // see ConvergenceCriteria::scale_floor.
        let sv0 = master.support_vectors();
        let scale_floor = (0..sv0.rows())
            .map(|i| sv0.row(i).iter().map(|x| x * x).sum::<f64>().sqrt())
            .sum::<f64>()
            / sv0.rows() as f64;
        let criteria = ConvergenceCriteria {
            eps_center: self.cfg.eps_center,
            eps_r2: self.cfg.eps_r2,
            consecutive: self.cfg.consecutive,
            scale_floor,
        };
        let mut tracker = ConvergenceTracker::new(criteria);
        tracker.observe(master.r2(), master.center());

        let mut trace = Vec::new();
        if self.cfg.record_trace {
            trace.push(TracePoint {
                iteration: 0,
                r2: master.r2(),
                num_sv: master.num_sv(),
                center_delta: f64::NAN,
            });
        }

        // Step 2: iterate until convergence.
        let mut iterations = 0;
        let mut converged = false;
        for i in 1..=self.cfg.max_iter {
            iterations = i;
            // 2.1 random sample + its SVDD
            let si = data.gather(&rng.sample_with_replacement(data.rows(), n));
            let sv_i = self.solve(&si.dedup_rows(), &mut counters)?;
            // 2.2 union with the master SV set
            let union = sv_i
                .support_vectors()
                .vstack(master.support_vectors())?
                .dedup_rows();
            // 2.3 SVDD of the union becomes the new master
            master = self.solve(&union, &mut counters)?;

            let delta = tracker.observe(master.r2(), master.center());
            if self.cfg.record_trace {
                trace.push(TracePoint {
                    iteration: i,
                    r2: master.r2(),
                    num_sv: master.num_sv(),
                    center_delta: delta,
                });
            }
            if tracker.converged() {
                converged = true;
                break;
            }
        }

        Ok(SamplingOutcome {
            model: master,
            iterations,
            converged,
            solver_calls: counters.0,
            rows_touched: counters.1,
            warm_start: warm.is_some(),
            trace,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::banana::Banana;
    use crate::data::donut::TwoDonut;
    use crate::data::Generator;

    fn banana(n: usize) -> Matrix {
        Banana::default().generate(n, 42)
    }

    #[test]
    fn converges_on_banana() {
        let data = banana(5000);
        let params = SvddParams::gaussian(0.35, 0.001);
        let cfg = SamplingConfig { sample_size: 6, ..Default::default() };
        let out = SamplingTrainer::new(params, cfg).train(&data, 7).unwrap();
        assert!(out.converged, "did not converge in {} iters", out.iterations);
        assert!(out.iterations >= 5);
        assert!(out.model.r2() > 0.0);
    }

    #[test]
    fn close_to_full_svdd() {
        // The headline claim: sampling R^2 ~= full R^2 on the same data.
        let data = banana(3000);
        let params = SvddParams::gaussian(0.35, 0.001);
        let full = crate::svdd::train(&data, &params).unwrap();
        let cfg = SamplingConfig { sample_size: 6, ..Default::default() };
        let out = SamplingTrainer::new(params, cfg).train(&data, 11).unwrap();
        let rel = (out.model.r2() - full.r2()).abs() / full.r2();
        assert!(rel < 0.08, "R^2 gap {rel}: {} vs {}", out.model.r2(), full.r2());
    }

    #[test]
    fn touches_small_fraction_of_data() {
        let data = banana(50_000);
        let params = SvddParams::gaussian(0.35, 0.001);
        let cfg = SamplingConfig { sample_size: 8, ..Default::default() };
        let out = SamplingTrainer::new(params, cfg).train(&data, 3).unwrap();
        assert!(
            out.rows_touched < data.rows() / 2,
            "touched {} of {}",
            out.rows_touched,
            data.rows()
        );
    }

    #[test]
    fn r2_trace_is_recorded_and_mostly_growing() {
        let data = banana(4000);
        let params = SvddParams::gaussian(0.35, 0.001);
        let cfg = SamplingConfig {
            sample_size: 6,
            record_trace: true,
            ..Default::default()
        };
        let out = SamplingTrainer::new(params, cfg).train(&data, 5).unwrap();
        assert_eq!(out.trace.len(), out.iterations + 1);
        // paper: "as SV* gets updated its threshold value typically
        // increases" — final R^2 far above the first sample's.
        assert!(out.trace.last().unwrap().r2 > out.trace[0].r2);
    }

    #[test]
    fn works_on_two_donut() {
        let data = TwoDonut::default().generate(20_000, 1);
        let params = SvddParams::gaussian(0.4, 0.001);
        let cfg = SamplingConfig { sample_size: 11, ..Default::default() };
        let out = SamplingTrainer::new(params, cfg).train(&data, 9).unwrap();
        assert!(out.converged);
        // description must cover both rings: SVs on both sides
        let sv = out.model.support_vectors();
        let left = (0..sv.rows()).filter(|&i| sv.get(i, 0) < 0.0).count();
        assert!(left > 0 && left < sv.rows(), "SVs one-sided: {left}/{}", sv.rows());
    }

    #[test]
    fn deterministic_given_seed() {
        let data = banana(2000);
        let params = SvddParams::gaussian(0.35, 0.001);
        let cfg = SamplingConfig { sample_size: 6, ..Default::default() };
        let a = SamplingTrainer::new(params, cfg).train(&data, 123).unwrap();
        let b = SamplingTrainer::new(params, cfg).train(&data, 123).unwrap();
        assert_eq!(a.iterations, b.iterations);
        assert_eq!(a.model.r2(), b.model.r2());
        assert_eq!(a.model.num_sv(), b.model.num_sv());
    }

    #[test]
    fn sample_size_clamped_to_data() {
        let data = banana(4);
        let params = SvddParams::gaussian(0.35, 0.01);
        let cfg = SamplingConfig { sample_size: 50, ..Default::default() };
        let out = SamplingTrainer::new(params, cfg).train(&data, 1).unwrap();
        assert!(out.model.num_sv() <= 4);
    }

    #[test]
    fn respects_max_iter() {
        let data = banana(3000);
        let params = SvddParams::gaussian(0.35, 0.001);
        let cfg = SamplingConfig {
            sample_size: 6,
            max_iter: 3,
            consecutive: 100, // unreachable
            ..Default::default()
        };
        let out = SamplingTrainer::new(params, cfg).train(&data, 2).unwrap();
        assert_eq!(out.iterations, 3);
        assert!(!out.converged);
    }

    #[test]
    fn warm_start_converges_faster_than_cold() {
        let data = banana(6000);
        let params = SvddParams::gaussian(0.35, 0.001);
        let cfg = SamplingConfig { sample_size: 6, ..Default::default() };
        let trainer = SamplingTrainer::new(params, cfg);
        let cold = trainer.train(&data, 7).unwrap();
        assert!(!cold.warm_start);
        // retrain on the same regime, seeded from the converged model:
        // R^2 starts at its fixed point, so the tolerance streak fills
        // almost immediately
        let warm = trainer.train_warm(&data, 13, &cold.model).unwrap();
        assert!(warm.warm_start);
        assert!(warm.converged);
        assert!(
            warm.iterations < cold.iterations,
            "warm start did not help: warm={} cold={}",
            warm.iterations,
            cold.iterations
        );
        // quality preserved
        let rel = (warm.model.r2() - cold.model.r2()).abs() / cold.model.r2();
        assert!(rel < 0.05, "warm/cold R^2 gap {rel}");
    }

    #[test]
    fn warm_start_dimension_mismatch_rejected() {
        let data = banana(500);
        let params = SvddParams::gaussian(0.35, 0.01);
        let cfg = SamplingConfig { sample_size: 6, ..Default::default() };
        let model = SamplingTrainer::new(params, cfg).train(&data, 1).unwrap().model;
        let odd = Matrix::from_rows(&[vec![0.0; 3], vec![1.0; 3], vec![0.5; 3]]).unwrap();
        assert!(SamplingTrainer::new(params, cfg)
            .train_warm(&odd, 2, &model)
            .is_err());
    }

    struct CountingBackend(std::sync::atomic::AtomicUsize);
    impl GramBackend for CountingBackend {
        fn gram(&self, data: &Matrix, kernel: Kernel) -> Option<Vec<f64>> {
            self.0.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let n = data.rows();
            let mut g = vec![0.0; n * n];
            for i in 0..n {
                for j in 0..n {
                    g[i * n + j] = kernel.eval(data.row(i), data.row(j));
                }
            }
            Some(g)
        }
    }

    #[test]
    fn backend_is_used_and_equivalent() {
        let data = banana(2000);
        let params = SvddParams::gaussian(0.35, 0.001);
        let cfg = SamplingConfig { sample_size: 6, ..Default::default() };
        let native = SamplingTrainer::new(params, cfg).train(&data, 77).unwrap();
        let be = CountingBackend(Default::default());
        let viabe = SamplingTrainer::new(params, cfg)
            .with_backend(&be)
            .train(&data, 77)
            .unwrap();
        assert!(be.0.load(std::sync::atomic::Ordering::Relaxed) > 0);
        assert_eq!(native.iterations, viabe.iterations);
        assert!((native.model.r2() - viabe.model.r2()).abs() < 1e-9);
    }
}
