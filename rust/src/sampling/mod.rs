//! **The paper's contribution: Algorithm 1 — sampling-based iterative
//! SVDD training.**
//!
//! Each iteration draws a small random sample `S_i` (with replacement)
//! from the training data, computes its SVDD to get `SV_i`, unions it
//! with the master support-vector set `SV*`, re-solves SVDD on the
//! union, and promotes the result to the new `SV*`. Iteration stops at
//! `maxiter` or when both the threshold `R^2` and the center
//! `a = sum_i alpha_i x_i` are stable for `t` consecutive iterations:
//!
//! ```text
//! ||a_i - a_{i-1}||   <= eps1 * ||a_{i-1}||
//! |R2_i  - R2_{i-1}|  <= eps2 * R2_{i-1}
//! ```
//!
//! The trainer never scores the training set (the drawback of Luo et
//! al. [7] this method removes) and touches only the sampled rows.
//!
//! ## Lifecycle layer (drift → warm retrain → promote → swap)
//!
//! Because a sampling retrain is cheap, the system retrains
//! *continuously* in production: [`StreamingSvdd`] maintains the master
//! SV set online and raises [`DriftStatus::Drifted`] when the
//! description moves; the lifecycle driver
//! ([`crate::registry::Lifecycle`]) then calls
//! [`SamplingTrainer::train_warm`] — seeding `SV*` from the current
//! champion's support vectors, the incremental extension of Jiang et
//! al. (arXiv:1709.00139) — publishes the result to the versioned
//! [`crate::registry::Registry`], promotes it, and hot-swaps it into
//! the serving [`crate::scoring::ModelSlot`] with zero dropped
//! connections. A warm start typically converges in far fewer
//! iterations than a cold start because `R^2` and the center are
//! already near their fixed point; [`SamplingOutcome::warm_start`]
//! records which path produced a model so traces stay comparable.

pub mod adaptive;
pub mod convergence;
pub mod streaming;

use std::collections::HashMap;

use crate::error::Result;
use crate::parallel::Pool;
use crate::svdd::kernel::Kernel;
use crate::svdd::model::SvddModel;
use crate::svdd::trainer::{
    train_detailed, train_with_gram_detailed, SolverStats, SvddParams,
};
use crate::util::matrix::Matrix;
use crate::util::rng::{derive_stream_seed, Xoshiro256};

pub use adaptive::{choose_sample_size, AdaptiveChoice, AdaptiveConfig};
pub use convergence::{ConvergenceCriteria, ConvergenceTracker};
pub use streaming::{DriftStatus, StreamingConfig, StreamingSvdd};

/// Pluggable gram-matrix backend: the XLA runtime implements this to
/// route the small union/sample solves through the AOT Pallas kernel;
/// `None` from [`GramBackend::gram`] falls back to native evaluation.
pub trait GramBackend: Send + Sync {
    /// Row-major `K(data, data)` (n*n) if this backend covers the shape.
    fn gram(&self, data: &Matrix, kernel: Kernel) -> Option<Vec<f64>>;
}

/// Algorithm-1 configuration (paper's notation in comments).
#[derive(Clone, Copy, Debug)]
pub struct SamplingConfig {
    /// `n` — random sample size per iteration. The paper's guidance:
    /// `m + 1` (dimension + 1) works well; its sweeps use 3..=20.
    pub sample_size: usize,
    /// `maxiter`.
    pub max_iter: usize,
    /// `eps1` — relative tolerance on the center.
    pub eps_center: f64,
    /// `eps2` — relative tolerance on `R^2`.
    pub eps_r2: f64,
    /// `t` — consecutive satisfied checks required.
    pub consecutive: usize,
    /// `K` — independent candidate samples drawn (and solved) per
    /// iteration. With `K = 1` this is exactly the paper's Algorithm 1
    /// on a single sequential RNG stream. With `K > 1` the iteration
    /// draws K samples on independent RNG streams (derived from
    /// `(seed, iter, candidate)`), solves sample + union for each
    /// concurrently on the pool, and promotes the candidate whose union
    /// solve has the largest `R^2` — a scenario the paper's independence
    /// structure directly licenses, trading parallel compute for fewer
    /// sequential iterations.
    pub candidates_per_iter: usize,
    /// Carry the previous iteration's dual solution into the next
    /// union solve: rows retained from `SV*` start at their previous
    /// `alpha` (projected back onto the simplex), new sample rows at
    /// zero mass, replacing the solver's cold `1/n` init. The union
    /// solve then starts next to its optimum and typically needs far
    /// fewer SMO iterations. Off by default: the cold-init trajectory
    /// is the seeded historical reference
    /// (`tests/parallel_determinism.rs` pins it byte-for-byte).
    pub warm_alpha: bool,
    /// Record a per-iteration trace (Fig 7).
    pub record_trace: bool,
}

impl Default for SamplingConfig {
    fn default() -> Self {
        SamplingConfig {
            sample_size: 10,
            max_iter: 1000,
            eps_center: 3e-4,
            eps_r2: 3e-4,
            consecutive: 8,
            candidates_per_iter: 1,
            warm_alpha: false,
            record_trace: false,
        }
    }
}

/// One point of the Fig-7 trace.
#[derive(Clone, Copy, Debug)]
pub struct TracePoint {
    pub iteration: usize,
    pub r2: f64,
    pub num_sv: usize,
    /// `||a_i - a_{i-1}|| / ||a_{i-1}||` (NaN on iteration 0).
    pub center_delta: f64,
}

/// Result of a sampling-trainer run.
#[derive(Clone, Debug)]
pub struct SamplingOutcome {
    pub model: SvddModel,
    /// Iterations executed (paper's "Iterations" column in Table II).
    pub iterations: usize,
    /// Whether the tolerance criterion fired (vs hitting `max_iter`).
    pub converged: bool,
    /// Total SMO solves (2 per iteration + 1 initial).
    pub solver_calls: usize,
    /// Total observations fed to solvers — the "fraction of the data
    /// the method ever looks at".
    pub rows_touched: usize,
    /// Whether `SV*` was seeded from a previous model
    /// ([`SamplingTrainer::train_warm`]) instead of a cold sample.
    pub warm_start: bool,
    /// Aggregated SMO telemetry across every solve of the run
    /// (sample + union solves; `gap` is from the last solve, cache
    /// hits/lookups sum exactly).
    pub solver: SolverStats,
    pub trace: Vec<TracePoint>,
}

/// Per-run work accounting threaded through every solve.
#[derive(Clone, Copy, Debug, Default)]
struct Counters {
    /// SMO solves issued.
    calls: usize,
    /// Observations fed to solvers.
    rows: usize,
    /// Aggregated solver telemetry.
    solver: SolverStats,
}

/// Initial dual guess for a union/seed solve: rows that came from
/// `prev`'s SV set carry its `alpha`, matched **bitwise** — the same
/// row equality [`Matrix::dedup_rows`] uses, so a sample row that
/// duplicates a master SV picks up the master's mass. New rows start
/// at zero; the solver's feasibility projection
/// ([`crate::svdd::smo::solve_with_init`]) rescales the result onto
/// the simplex `{sum = 1, 0 <= a <= C}`.
fn carried_alpha(union: &Matrix, prev: &SvddModel) -> Vec<f64> {
    carried_alpha_from(&sv_alpha_index(prev), union)
}

/// Bitwise row-key -> alpha index over a model's SV set. Built once
/// and reused across the K candidate unions of one iteration.
fn sv_alpha_index(prev: &SvddModel) -> HashMap<Vec<u64>, f64> {
    let sv = prev.support_vectors();
    let mut by_bits: HashMap<Vec<u64>, f64> = HashMap::with_capacity(sv.rows());
    for i in 0..sv.rows() {
        let key: Vec<u64> = sv.row(i).iter().map(|x| x.to_bits()).collect();
        by_bits.insert(key, prev.alpha()[i]);
    }
    by_bits
}

fn carried_alpha_from(by_bits: &HashMap<Vec<u64>, f64>, union: &Matrix) -> Vec<f64> {
    (0..union.rows())
        .map(|i| {
            let key: Vec<u64> = union.row(i).iter().map(|x| x.to_bits()).collect();
            by_bits.get(&key).copied().unwrap_or(0.0)
        })
        .collect()
}

/// The Algorithm-1 trainer.
pub struct SamplingTrainer<'a> {
    params: SvddParams,
    cfg: SamplingConfig,
    backend: Option<&'a dyn GramBackend>,
    pool: Option<Pool>,
}

impl<'a> SamplingTrainer<'a> {
    pub fn new(params: SvddParams, cfg: SamplingConfig) -> Self {
        SamplingTrainer { params, cfg, backend: None, pool: None }
    }

    /// Route union/sample gram computations through an XLA backend.
    pub fn with_backend(mut self, backend: &'a dyn GramBackend) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Train candidate models on an explicit pool instead of the global
    /// one (tests, benches).
    pub fn with_pool(mut self, pool: Pool) -> Self {
        self.pool = Some(pool);
        self
    }

    fn pool(&self) -> Pool {
        self.pool.unwrap_or_else(crate::parallel::global)
    }

    /// One SVDD solve of Algorithm 1. `stage` labels the solve's role
    /// (seed / sample / union) in the tracing span so `fastsvdd
    /// report` can break a run's time down per stage.
    fn solve(
        &self,
        data: &Matrix,
        init: Option<&[f64]>,
        counters: &mut Counters,
        stage: &'static str,
    ) -> Result<SvddModel> {
        counters.calls += 1;
        counters.rows += data.rows();
        let mut span = crate::obs::Span::enter("sampling.solve");
        if span.is_live() {
            span.str("stage", stage);
            span.u64("rows", data.rows() as u64);
        }
        if let Some(be) = self.backend {
            if let Some(gram) = be.gram(data, self.params.kernel) {
                let (model, stats) =
                    train_with_gram_detailed(data, gram, &self.params, init)?;
                counters.solver.absorb(&stats);
                return Ok(model);
            }
        }
        let (model, stats) = train_detailed(data, &self.params, init)?;
        counters.solver.absorb(&stats);
        Ok(model)
    }

    /// Run Algorithm 1 on `data` from a cold start.
    pub fn train(&self, data: &Matrix, seed: u64) -> Result<SamplingOutcome> {
        self.train_impl(data, seed, None)
    }

    /// Run Algorithm 1 on `data`, warm-starting the master set from a
    /// previously trained model: `SV*` is seeded with `initial_sv`'s
    /// support vectors (unioned with the first random sample) instead
    /// of a cold sample's SV set. When `initial_sv` described a similar
    /// regime, `R^2` and the center start near their fixed point and
    /// the run converges in far fewer iterations — this is what makes
    /// drift-triggered production retraining cheap (Jiang et al.,
    /// arXiv:1709.00139).
    pub fn train_warm(
        &self,
        data: &Matrix,
        seed: u64,
        initial_sv: &SvddModel,
    ) -> Result<SamplingOutcome> {
        if initial_sv.dim() != data.cols() {
            return Err(crate::error::Error::invalid(format!(
                "warm-start model is {}-d but data is {}-d",
                initial_sv.dim(),
                data.cols()
            )));
        }
        self.train_impl(data, seed, Some(initial_sv))
    }

    fn train_impl(
        &self,
        data: &Matrix,
        seed: u64,
        warm: Option<&SvddModel>,
    ) -> Result<SamplingOutcome> {
        // fail before the seed solve, not on the first union solve:
        // the legacy SMO mode rejects the warm starts alpha-carry
        // would pass it (RunConfig::validate catches the CLI spelling;
        // this catches direct library construction)
        if self.cfg.warm_alpha && self.params.smo.wss == crate::svdd::Wss::Legacy {
            return Err(crate::error::Error::invalid(
                "SamplingConfig::warm_alpha cannot be combined with the legacy SMO \
                 mode (it exists to replay cold-start trajectories)",
            ));
        }
        let n = self.cfg.sample_size.max(2).min(data.rows());
        let mut rng = Xoshiro256::new(seed);
        let mut counters = Counters::default();

        // Step 1: S0 <- SAMPLE(T, n); SV* <- SV(delta S0).
        // Warm start: S0 is unioned with the previous model's SV set
        // first, so SV* begins at (a superset of) the old description.
        let s0 = data.gather(&rng.sample_with_replacement(data.rows(), n));
        let seed_set = match warm {
            None => s0.dedup_rows(),
            Some(init) => s0.vstack(init.support_vectors())?.dedup_rows(),
        };
        // with warm_alpha the seed solve also starts from the previous
        // model's dual solution, not just its SV rows
        let init0 = match (warm, self.cfg.warm_alpha) {
            (Some(prev), true) => Some(carried_alpha(&seed_set, prev)),
            _ => None,
        };
        let mut master = self.solve(&seed_set, init0.as_deref(), &mut counters, "seed")?;

        // Floor the center-criterion scale at the data scale (mean SV
        // norm) so symmetric data with ||a|| ~ 0 can still converge;
        // see ConvergenceCriteria::scale_floor.
        let sv0 = master.support_vectors();
        let scale_floor = (0..sv0.rows())
            .map(|i| sv0.row(i).iter().map(|x| x * x).sum::<f64>().sqrt())
            .sum::<f64>()
            / sv0.rows() as f64;
        let criteria = ConvergenceCriteria {
            eps_center: self.cfg.eps_center,
            eps_r2: self.cfg.eps_r2,
            consecutive: self.cfg.consecutive,
            scale_floor,
        };
        let mut tracker = ConvergenceTracker::new(criteria);
        tracker.observe(master.r2(), master.center());

        let mut trace = Vec::new();
        if self.cfg.record_trace {
            trace.push(TracePoint {
                iteration: 0,
                r2: master.r2(),
                num_sv: master.num_sv(),
                center_delta: f64::NAN,
            });
        }

        // Step 2: iterate until convergence.
        let k_cands = self.cfg.candidates_per_iter.max(1);
        let mut iterations = 0;
        let mut converged = false;
        for i in 1..=self.cfg.max_iter {
            iterations = i;
            let mut iter_span = crate::obs::Span::enter("sampling.iter");
            master = if k_cands == 1 {
                // Single-candidate path: the paper's Algorithm 1 on one
                // sequential RNG stream. This branch is kept exactly as
                // it was before candidates existed so seeded K=1 runs
                // reproduce historical outputs bit-for-bit (regression
                // test in tests/parallel_determinism.rs).
                // 2.1 random sample + its SVDD (always a cold solve:
                // there is no previous solution on a fresh sample)
                let si = data.gather(&rng.sample_with_replacement(data.rows(), n));
                let sv_i = self.solve(&si.dedup_rows(), None, &mut counters, "sample")?;
                // 2.2 union with the master SV set
                let union = sv_i
                    .support_vectors()
                    .vstack(master.support_vectors())?
                    .dedup_rows();
                // 2.3 SVDD of the union becomes the new master,
                // warm-started from the master's alpha when enabled
                let init = self
                    .cfg
                    .warm_alpha
                    .then(|| carried_alpha(&union, &master));
                self.solve(&union, init.as_deref(), &mut counters, "union")?
            } else {
                self.best_candidate(data, seed, i, n, &master, &mut counters)?
            };
            if iter_span.is_live() {
                iter_span.u64("iteration", i as u64);
                iter_span.f64("r2", master.r2());
                iter_span.u64("num_sv", master.num_sv() as u64);
            }
            drop(iter_span);

            let delta = tracker.observe(master.r2(), master.center());
            if self.cfg.record_trace {
                trace.push(TracePoint {
                    iteration: i,
                    r2: master.r2(),
                    num_sv: master.num_sv(),
                    center_delta: delta,
                });
            }
            if tracker.converged() {
                converged = true;
                break;
            }
        }

        Ok(SamplingOutcome {
            model: master,
            iterations,
            converged,
            solver_calls: counters.calls,
            rows_touched: counters.rows,
            warm_start: warm.is_some(),
            solver: counters.solver,
            trace,
        })
    }

    /// One multi-candidate iteration: draw K independent samples on
    /// derived RNG streams, solve sample + union for each concurrently,
    /// keep the candidate whose union solve has the largest `R^2`
    /// (ties break to the lowest candidate index). Candidate results
    /// are collected in index order and the pick is a pure comparison,
    /// so the outcome is identical at every thread count.
    fn best_candidate(
        &self,
        data: &Matrix,
        seed: u64,
        iter: usize,
        n: usize,
        master: &SvddModel,
        counters: &mut Counters,
    ) -> Result<SvddModel> {
        let k = self.cfg.candidates_per_iter;
        // the alpha-carry index depends only on `master`: build it once
        // per iteration, not once per candidate
        let carry = self.cfg.warm_alpha.then(|| sv_alpha_index(master));
        let results = self.pool().map(k, |c| -> Result<(SvddModel, Counters)> {
            let mut crng = Xoshiro256::new(derive_stream_seed(seed, iter as u64, c as u64));
            let si = data.gather(&crng.sample_with_replacement(data.rows(), n));
            let mut cnt = Counters::default();
            let sv_c = self.solve(&si.dedup_rows(), None, &mut cnt, "sample")?;
            let union = sv_c
                .support_vectors()
                .vstack(master.support_vectors())?
                .dedup_rows();
            let init = carry.as_ref().map(|idx| carried_alpha_from(idx, &union));
            let cand = self.solve(&union, init.as_deref(), &mut cnt, "union")?;
            Ok((cand, cnt))
        });
        let mut best: Option<SvddModel> = None;
        for r in results {
            let (cand, cnt) = r?;
            counters.calls += cnt.calls;
            counters.rows += cnt.rows;
            counters.solver.absorb(&cnt.solver);
            if best.as_ref().map_or(true, |b| cand.r2() > b.r2()) {
                best = Some(cand);
            }
        }
        Ok(best.expect("candidates_per_iter >= 1"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::banana::Banana;
    use crate::data::donut::TwoDonut;
    use crate::data::Generator;

    fn banana(n: usize) -> Matrix {
        Banana::default().generate(n, 42)
    }

    #[test]
    fn converges_on_banana() {
        let data = banana(5000);
        let params = SvddParams::gaussian(0.35, 0.001);
        let cfg = SamplingConfig { sample_size: 6, ..Default::default() };
        let out = SamplingTrainer::new(params, cfg).train(&data, 7).unwrap();
        assert!(out.converged, "did not converge in {} iters", out.iterations);
        assert!(out.iterations >= 5);
        assert!(out.model.r2() > 0.0);
    }

    #[test]
    fn close_to_full_svdd() {
        // The headline claim: sampling R^2 ~= full R^2 on the same data.
        let data = banana(3000);
        let params = SvddParams::gaussian(0.35, 0.001);
        let full = crate::svdd::train(&data, &params).unwrap();
        let cfg = SamplingConfig { sample_size: 6, ..Default::default() };
        let out = SamplingTrainer::new(params, cfg).train(&data, 11).unwrap();
        let rel = (out.model.r2() - full.r2()).abs() / full.r2();
        assert!(rel < 0.08, "R^2 gap {rel}: {} vs {}", out.model.r2(), full.r2());
    }

    #[test]
    fn touches_small_fraction_of_data() {
        let data = banana(50_000);
        let params = SvddParams::gaussian(0.35, 0.001);
        let cfg = SamplingConfig { sample_size: 8, ..Default::default() };
        let out = SamplingTrainer::new(params, cfg).train(&data, 3).unwrap();
        assert!(
            out.rows_touched < data.rows() / 2,
            "touched {} of {}",
            out.rows_touched,
            data.rows()
        );
    }

    #[test]
    fn r2_trace_is_recorded_and_mostly_growing() {
        let data = banana(4000);
        let params = SvddParams::gaussian(0.35, 0.001);
        let cfg = SamplingConfig {
            sample_size: 6,
            record_trace: true,
            ..Default::default()
        };
        let out = SamplingTrainer::new(params, cfg).train(&data, 5).unwrap();
        assert_eq!(out.trace.len(), out.iterations + 1);
        // paper: "as SV* gets updated its threshold value typically
        // increases" — final R^2 far above the first sample's.
        assert!(out.trace.last().unwrap().r2 > out.trace[0].r2);
    }

    #[test]
    fn works_on_two_donut() {
        let data = TwoDonut::default().generate(20_000, 1);
        let params = SvddParams::gaussian(0.4, 0.001);
        let cfg = SamplingConfig { sample_size: 11, ..Default::default() };
        let out = SamplingTrainer::new(params, cfg).train(&data, 9).unwrap();
        assert!(out.converged);
        // description must cover both rings: SVs on both sides
        let sv = out.model.support_vectors();
        let left = (0..sv.rows()).filter(|&i| sv.get(i, 0) < 0.0).count();
        assert!(left > 0 && left < sv.rows(), "SVs one-sided: {left}/{}", sv.rows());
    }

    #[test]
    fn deterministic_given_seed() {
        let data = banana(2000);
        let params = SvddParams::gaussian(0.35, 0.001);
        let cfg = SamplingConfig { sample_size: 6, ..Default::default() };
        let a = SamplingTrainer::new(params, cfg).train(&data, 123).unwrap();
        let b = SamplingTrainer::new(params, cfg).train(&data, 123).unwrap();
        assert_eq!(a.iterations, b.iterations);
        assert_eq!(a.model.r2(), b.model.r2());
        assert_eq!(a.model.num_sv(), b.model.num_sv());
    }

    #[test]
    fn sample_size_clamped_to_data() {
        let data = banana(4);
        let params = SvddParams::gaussian(0.35, 0.01);
        let cfg = SamplingConfig { sample_size: 50, ..Default::default() };
        let out = SamplingTrainer::new(params, cfg).train(&data, 1).unwrap();
        assert!(out.model.num_sv() <= 4);
    }

    #[test]
    fn respects_max_iter() {
        let data = banana(3000);
        let params = SvddParams::gaussian(0.35, 0.001);
        let cfg = SamplingConfig {
            sample_size: 6,
            max_iter: 3,
            consecutive: 100, // unreachable
            ..Default::default()
        };
        let out = SamplingTrainer::new(params, cfg).train(&data, 2).unwrap();
        assert_eq!(out.iterations, 3);
        assert!(!out.converged);
    }

    #[test]
    fn warm_start_converges_faster_than_cold() {
        let data = banana(6000);
        let params = SvddParams::gaussian(0.35, 0.001);
        let cfg = SamplingConfig { sample_size: 6, ..Default::default() };
        let trainer = SamplingTrainer::new(params, cfg);
        let cold = trainer.train(&data, 7).unwrap();
        assert!(!cold.warm_start);
        // retrain on the same regime, seeded from the converged model:
        // R^2 starts at its fixed point, so the tolerance streak fills
        // almost immediately
        let warm = trainer.train_warm(&data, 13, &cold.model).unwrap();
        assert!(warm.warm_start);
        assert!(warm.converged);
        assert!(
            warm.iterations < cold.iterations,
            "warm start did not help: warm={} cold={}",
            warm.iterations,
            cold.iterations
        );
        // quality preserved
        let rel = (warm.model.r2() - cold.model.r2()).abs() / cold.model.r2();
        assert!(rel < 0.05, "warm/cold R^2 gap {rel}");
    }

    #[test]
    fn warm_start_dimension_mismatch_rejected() {
        let data = banana(500);
        let params = SvddParams::gaussian(0.35, 0.01);
        let cfg = SamplingConfig { sample_size: 6, ..Default::default() };
        let model = SamplingTrainer::new(params, cfg).train(&data, 1).unwrap().model;
        let odd = Matrix::from_rows(&[vec![0.0; 3], vec![1.0; 3], vec![0.5; 3]]).unwrap();
        assert!(SamplingTrainer::new(params, cfg)
            .train_warm(&odd, 2, &model)
            .is_err());
    }

    #[test]
    fn candidates_mode_converges_and_is_deterministic_across_pools() {
        let data = banana(4000);
        let params = SvddParams::gaussian(0.35, 0.001);
        let cfg = SamplingConfig {
            sample_size: 6,
            candidates_per_iter: 4,
            ..Default::default()
        };
        let serial = SamplingTrainer::new(params, cfg)
            .with_pool(crate::parallel::Pool::serial())
            .train(&data, 21)
            .unwrap();
        let wide = SamplingTrainer::new(params, cfg)
            .with_pool(crate::parallel::Pool::new(8))
            .train(&data, 21)
            .unwrap();
        assert!(serial.converged);
        // bit-identical promotion decisions at every thread count
        assert_eq!(serial.iterations, wide.iterations);
        assert_eq!(serial.model.r2().to_bits(), wide.model.r2().to_bits());
        assert_eq!(serial.model.alpha(), wide.model.alpha());
        assert_eq!(serial.solver_calls, wide.solver_calls);
        assert_eq!(serial.rows_touched, wide.rows_touched);
    }

    #[test]
    fn candidates_do_more_work_per_iteration() {
        let data = banana(3000);
        let params = SvddParams::gaussian(0.35, 0.001);
        let base = SamplingConfig {
            sample_size: 6,
            max_iter: 5,
            consecutive: 100,
            ..Default::default()
        };
        let k1 = SamplingTrainer::new(params, base).train(&data, 3).unwrap();
        let cfg4 = SamplingConfig { candidates_per_iter: 4, ..base };
        let k4 = SamplingTrainer::new(params, cfg4).train(&data, 3).unwrap();
        // 2 solves per candidate per iteration (+1 seed solve)
        assert_eq!(k1.solver_calls, 1 + 2 * 5);
        assert_eq!(k4.solver_calls, 1 + 4 * 2 * 5);
        assert!(k4.rows_touched > k1.rows_touched);
    }

    #[test]
    fn candidate_zero_stream_differs_from_sequential_stream() {
        // The K>1 path derives candidate streams rather than splitting
        // the sequential stream, so K=4 must not accidentally replay
        // the K=1 draw schedule.
        let data = banana(2000);
        let params = SvddParams::gaussian(0.35, 0.001);
        let base = SamplingConfig {
            sample_size: 6,
            max_iter: 4,
            consecutive: 100,
            ..Default::default()
        };
        let k1 = SamplingTrainer::new(params, base).train(&data, 11).unwrap();
        let cfg4 = SamplingConfig { candidates_per_iter: 4, ..base };
        let k4 = SamplingTrainer::new(params, cfg4).train(&data, 11).unwrap();
        assert_ne!(
            k1.model.r2().to_bits(),
            k4.model.r2().to_bits(),
            "K=4 replayed the K=1 stream"
        );
    }

    #[test]
    fn warm_alpha_cuts_total_smo_iterations() {
        // same seed => same draw schedule; pin the Algorithm-1
        // iteration count so the two runs do the same number of union
        // solves and only the solver init differs
        let data = banana(5000);
        let params = SvddParams::gaussian(0.35, 0.001);
        let base = SamplingConfig {
            sample_size: 6,
            max_iter: 15,
            consecutive: 100, // unreachable: run all 15 iterations
            ..Default::default()
        };
        let warm_cfg = SamplingConfig { warm_alpha: true, ..base };
        let cold = SamplingTrainer::new(params, base).train(&data, 7).unwrap();
        let warm = SamplingTrainer::new(params, warm_cfg).train(&data, 7).unwrap();
        assert_eq!(cold.solver_calls, warm.solver_calls);
        assert!(
            warm.solver.smo_iterations < cold.solver.smo_iterations,
            "alpha carry did not reduce SMO work: warm={} cold={}",
            warm.solver.smo_iterations,
            cold.solver.smo_iterations
        );
        // description quality preserved
        let rel = (warm.model.r2() - cold.model.r2()).abs() / cold.model.r2();
        assert!(rel < 0.05, "warm/cold R^2 gap {rel}");
    }

    #[test]
    fn warm_alpha_converges_and_composes_with_train_warm() {
        let data = banana(4000);
        let params = SvddParams::gaussian(0.35, 0.001);
        let cfg = SamplingConfig { sample_size: 6, warm_alpha: true, ..Default::default() };
        let trainer = SamplingTrainer::new(params, cfg);
        let first = trainer.train(&data, 11).unwrap();
        assert!(first.converged);
        let again = trainer.train_warm(&data, 12, &first.model).unwrap();
        assert!(again.warm_start);
        assert!(again.converged);
        assert!(
            again.iterations < first.iterations,
            "warm retrain did not converge faster: {} vs {}",
            again.iterations,
            first.iterations
        );
    }

    #[test]
    fn warm_alpha_with_legacy_mode_fails_fast() {
        let data = banana(200);
        let mut params = SvddParams::gaussian(0.35, 0.01);
        params.smo.wss = crate::svdd::Wss::Legacy;
        let cfg = SamplingConfig { sample_size: 6, warm_alpha: true, ..Default::default() };
        let err = SamplingTrainer::new(params, cfg).train(&data, 1);
        assert!(err.is_err(), "warm_alpha + legacy must be rejected upfront");
        // without the carry, legacy mode trains fine
        let ok_cfg = SamplingConfig { sample_size: 6, ..Default::default() };
        assert!(SamplingTrainer::new(params, ok_cfg).train(&data, 1).is_ok());
    }

    #[test]
    fn solver_telemetry_is_populated() {
        let data = banana(2000);
        let params = SvddParams::gaussian(0.35, 0.001);
        let cfg = SamplingConfig { sample_size: 6, ..Default::default() };
        let out = SamplingTrainer::new(params, cfg).train(&data, 5).unwrap();
        assert!(out.solver.smo_iterations > 0);
        assert!(out.solver.gap.is_finite());
        assert!(out.solver.cache_lookups > 0);
        assert!(out.solver.cache_hit_rate().is_some());
    }

    #[test]
    fn carried_alpha_maps_master_rows_bitwise() {
        let data = banana(300);
        let params = SvddParams::gaussian(0.35, 0.01);
        let cfg = SamplingConfig { sample_size: 6, ..Default::default() };
        let model = SamplingTrainer::new(params, cfg).train(&data, 4).unwrap().model;
        let extra = Matrix::from_rows(&[vec![9.0, 9.0], vec![-9.0, 3.0]]).unwrap();
        let union = extra.vstack(model.support_vectors()).unwrap().dedup_rows();
        let init = carried_alpha(&union, &model);
        assert_eq!(init.len(), union.rows());
        // the synthetic rows are not SVs: zero mass
        assert_eq!(init[0], 0.0);
        assert_eq!(init[1], 0.0);
        // every SV row carried its alpha => full mass present
        assert!((init.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    struct CountingBackend(std::sync::atomic::AtomicUsize);
    impl GramBackend for CountingBackend {
        fn gram(&self, data: &Matrix, kernel: Kernel) -> Option<Vec<f64>> {
            self.0.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            // one full-matrix block panel — the same per-entry values
            // the native (lazy) path computes, so the two runs stay on
            // identical SMO trajectories
            let n = data.rows();
            let norms = crate::linalg::NormCache::new(data);
            let mut g = vec![0.0; n * n];
            kernel.eval_block(data, &norms, 0..n, data, &norms, 0..n, &mut g);
            Some(g)
        }
    }

    #[test]
    fn backend_is_used_and_equivalent() {
        let data = banana(2000);
        let params = SvddParams::gaussian(0.35, 0.001);
        let cfg = SamplingConfig { sample_size: 6, ..Default::default() };
        let native = SamplingTrainer::new(params, cfg).train(&data, 77).unwrap();
        let be = CountingBackend(Default::default());
        let viabe = SamplingTrainer::new(params, cfg)
            .with_backend(&be)
            .train(&data, 77)
            .unwrap();
        assert!(be.0.load(std::sync::atomic::Ordering::Relaxed) > 0);
        assert_eq!(native.iterations, viabe.iterations);
        assert!((native.model.r2() - viabe.model.r2()).abs() < 1e-9);
    }
}
