//! Streaming SVDD — the extension the paper's conclusion motivates:
//! "many [IoT] applications will require fast periodic training using
//! large data sets".
//!
//! [`StreamingSvdd`] maintains the master SV set *online*: observations
//! arrive in windows; each full window triggers one Algorithm-1-style
//! update (sample from the window, union with SV*, re-solve). A drift
//! monitor tracks the relative R^2 movement across updates; a sustained
//! shift beyond the drift threshold reports [`DriftStatus::Drifted`] so
//! operators can trigger a full retrain (the paper's "separate operating
//! mode" scenario).

use crate::error::{Error, Result};
use crate::svdd::model::SvddModel;
use crate::svdd::trainer::{train_detailed, SolverStats, SvddParams};
use crate::util::matrix::Matrix;
use crate::util::rng::Xoshiro256;

/// Streaming trainer configuration.
#[derive(Clone, Copy, Debug)]
pub struct StreamingConfig {
    /// Observations buffered before an update fires.
    pub window: usize,
    /// Random rows drawn from each full window (Algorithm-1 `n`).
    pub sample_size: usize,
    /// Relative R^2 movement treated as drift evidence.
    pub drift_threshold: f64,
    /// Consecutive drift-evidence updates before `Drifted` is reported.
    pub drift_patience: usize,
}

impl Default for StreamingConfig {
    fn default() -> Self {
        StreamingConfig {
            window: 256,
            sample_size: 10,
            drift_threshold: 0.05,
            drift_patience: 3,
        }
    }
}

/// Drift verdict after an update.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DriftStatus {
    /// R^2 stable within the threshold.
    Stable,
    /// Movement observed; not yet sustained.
    Suspect,
    /// `drift_patience` consecutive movements — retrain recommended.
    Drifted,
}

/// Online maintainer of the master SV set.
pub struct StreamingSvdd {
    params: SvddParams,
    cfg: StreamingConfig,
    rng: Xoshiro256,
    buffer: Vec<Vec<f64>>,
    model: Option<SvddModel>,
    drift_streak: usize,
    updates: usize,
    rows_seen: usize,
    solver_calls: usize,
    solver: SolverStats,
}

impl StreamingSvdd {
    pub fn new(params: SvddParams, cfg: StreamingConfig, seed: u64) -> StreamingSvdd {
        StreamingSvdd {
            params,
            cfg,
            rng: Xoshiro256::new(seed),
            buffer: Vec::with_capacity(cfg.window),
            model: None,
            drift_streak: 0,
            updates: 0,
            rows_seen: 0,
            solver_calls: 0,
            solver: SolverStats::default(),
        }
    }

    /// Current description (None until the first window completes).
    pub fn model(&self) -> Option<&SvddModel> {
        self.model.as_ref()
    }

    pub fn updates(&self) -> usize {
        self.updates
    }

    pub fn rows_seen(&self) -> usize {
        self.rows_seen
    }

    pub fn buffered(&self) -> usize {
        self.buffer.len()
    }

    /// SMO solves issued so far (2 per window update).
    pub fn solver_calls(&self) -> usize {
        self.solver_calls
    }

    /// Aggregated SMO telemetry across every window update.
    pub fn solver_stats(&self) -> &SolverStats {
        &self.solver
    }

    /// Feed one observation; returns `Some(status)` when a window
    /// completed and the model was updated.
    pub fn push(&mut self, x: &[f64]) -> Result<Option<DriftStatus>> {
        self.rows_seen += 1;
        self.buffer.push(x.to_vec());
        if self.buffer.len() < self.cfg.window {
            return Ok(None);
        }
        let window = Matrix::from_rows(&std::mem::take(&mut self.buffer))?;
        let status = self.update(&window)?;
        Ok(Some(status))
    }

    /// Feed a batch (returns the last update status if any fired).
    pub fn push_batch(&mut self, xs: &Matrix) -> Result<Option<DriftStatus>> {
        let mut last = None;
        for i in 0..xs.rows() {
            if let Some(s) = self.push(xs.row(i))? {
                last = Some(s);
            }
        }
        Ok(last)
    }

    /// One Algorithm-1-style update from a full window.
    fn update(&mut self, window: &Matrix) -> Result<DriftStatus> {
        let n = self.cfg.sample_size.max(2).min(window.rows());
        let idx = self.rng.sample_with_replacement(window.rows(), n);
        let sample = window.gather(&idx).dedup_rows();
        let (sample_model, stats) = train_detailed(&sample, &self.params, None)?;
        self.solver.absorb(&stats);
        self.solver_calls += 1;

        let prev_r2 = self.model.as_ref().map(|m| m.r2());
        let union = match &self.model {
            Some(master) => sample_model
                .support_vectors()
                .vstack(master.support_vectors())?
                .dedup_rows(),
            None => sample_model.support_vectors().clone(),
        };
        let (new_model, stats) = train_detailed(&union, &self.params, None)?;
        self.solver.absorb(&stats);
        self.solver_calls += 1;
        let status = match prev_r2 {
            None => DriftStatus::Stable,
            Some(prev) => {
                let shift = (new_model.r2() - prev).abs() / prev.abs().max(1e-12);
                if shift > self.cfg.drift_threshold {
                    self.drift_streak += 1;
                } else {
                    self.drift_streak = 0;
                }
                if self.drift_streak >= self.cfg.drift_patience {
                    DriftStatus::Drifted
                } else if self.drift_streak > 0 {
                    DriftStatus::Suspect
                } else {
                    DriftStatus::Stable
                }
            }
        };
        self.model = Some(new_model);
        self.updates += 1;
        Ok(status)
    }

    /// Drop the learned description (e.g. after an operator-confirmed
    /// regime change) but keep the buffer.
    pub fn reset_model(&mut self) {
        self.model = None;
        self.drift_streak = 0;
    }

    /// Adopt an externally retrained description (the lifecycle driver
    /// calls this after a drift-triggered retrain was promoted) and
    /// clear the drift streak, so subsequent windows are judged against
    /// the fresh champion instead of re-reporting the same drift.
    /// Rejects a model whose dimension does not match the stream's
    /// (known from the current model or the buffered rows) — otherwise
    /// the mismatch would only surface as an opaque vstack error deep
    /// inside the next window update.
    pub fn adopt_model(&mut self, model: SvddModel) -> Result<()> {
        let stream_dim = self
            .model
            .as_ref()
            .map(|m| m.dim())
            .or_else(|| self.buffer.first().map(|r| r.len()));
        if let Some(dim) = stream_dim {
            if model.dim() != dim {
                return Err(Error::invalid(format!(
                    "adopted model is {}-d but the stream is {}-d",
                    model.dim(),
                    dim
                )));
            }
        }
        self.model = Some(model);
        self.drift_streak = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{banana::Banana, Generator};

    fn cfg() -> StreamingConfig {
        StreamingConfig { window: 128, sample_size: 6, ..Default::default() }
    }

    #[test]
    fn learns_from_stream_and_matches_batch_quality() {
        let data = Banana::default().generate(4096, 42);
        let params = SvddParams::gaussian(0.35, 0.001);
        let mut s = StreamingSvdd::new(params, cfg(), 7);
        s.push_batch(&data).unwrap();
        let model = s.model().expect("model after 32 windows");
        assert_eq!(s.updates(), 4096 / 128);
        // telemetry: a sample + a union solve per window update
        assert_eq!(s.solver_calls(), 2 * s.updates());
        assert!(s.solver_stats().smo_iterations > 0);
        let batch = crate::svdd::train(&data, &params).unwrap();
        let rel = (model.r2() - batch.r2()).abs() / batch.r2();
        assert!(rel < 0.1, "stream vs batch R^2 gap {rel}");
    }

    #[test]
    fn no_model_before_first_window() {
        let params = SvddParams::gaussian(0.35, 0.01);
        let mut s = StreamingSvdd::new(params, cfg(), 1);
        for i in 0..127 {
            assert!(s.push(&[i as f64 * 0.001, 0.0]).unwrap().is_none());
        }
        assert!(s.model().is_none());
        assert_eq!(s.buffered(), 127);
        assert!(s.push(&[0.0, 0.0]).unwrap().is_some());
        assert!(s.model().is_some());
        assert_eq!(s.buffered(), 0);
    }

    #[test]
    fn stable_stream_reports_stable() {
        let data = Banana::default().generate(2048, 3);
        let params = SvddParams::gaussian(0.35, 0.001);
        let mut s = StreamingSvdd::new(params, cfg(), 5);
        // after warm-up, statuses should settle to Stable
        let mut last = None;
        for i in 0..data.rows() {
            if let Some(st) = s.push(data.row(i)).unwrap() {
                last = Some(st);
            }
        }
        assert_eq!(last, Some(DriftStatus::Stable));
    }

    #[test]
    fn regime_change_triggers_drift() {
        let params = SvddParams::gaussian(0.35, 0.001);
        let mut s = StreamingSvdd::new(
            params,
            StreamingConfig {
                window: 128,
                sample_size: 6,
                drift_threshold: 0.02,
                drift_patience: 1,
            },
            9,
        );
        // regime A: banana at origin
        let a = Banana::default().generate(1024, 1);
        s.push_batch(&a).unwrap();
        // regime B: same shape shifted far away. The master set absorbs
        // the new region within a window or two, so R^2 jumps and then
        // re-stabilizes — drift must be reported on SOME update (the
        // last status may already be Stable again).
        let mut b = Banana::default().generate(1024, 2);
        for i in 0..b.rows() {
            b.row_mut(i)[0] += 8.0;
        }
        let mut saw_drift = false;
        for i in 0..b.rows() {
            if let Some(DriftStatus::Drifted) = s.push(b.row(i)).unwrap() {
                saw_drift = true;
            }
        }
        assert!(saw_drift, "no drift reported across the regime change");
    }

    #[test]
    fn adopt_model_clears_drift_streak() {
        let params = SvddParams::gaussian(0.35, 0.001);
        let mut s = StreamingSvdd::new(
            params,
            StreamingConfig {
                window: 128,
                sample_size: 6,
                drift_threshold: 0.02,
                drift_patience: 1,
            },
            4,
        );
        let a = Banana::default().generate(512, 1);
        s.push_batch(&a).unwrap();
        // push the stream into a drifted regime
        let mut b = Banana::default().generate(512, 2);
        for i in 0..b.rows() {
            b.row_mut(i)[0] += 8.0;
        }
        s.push_batch(&b).unwrap();
        // adopting a retrained description resets the streak and the
        // stream keeps running against the adopted model
        let retrained = crate::svdd::train(&b, &params).unwrap();
        let adopted_r2 = retrained.r2();
        s.adopt_model(retrained).unwrap();
        assert_eq!(s.model().unwrap().r2(), adopted_r2);
        // dimension mismatch is rejected up front, not on the next window
        let odd = crate::svdd::train(
            &Matrix::from_rows(&[vec![0.0; 3], vec![1.0; 3], vec![0.5; 3]]).unwrap(),
            &params,
        )
        .unwrap();
        assert!(s.adopt_model(odd).is_err());
        let more = {
            let mut m = Banana::default().generate(128, 3);
            for i in 0..m.rows() {
                m.row_mut(i)[0] += 8.0;
            }
            m
        };
        let status = s.push_batch(&more).unwrap();
        assert!(status.is_some(), "window update must fire");
    }

    #[test]
    fn reset_clears_model() {
        let data = Banana::default().generate(256, 4);
        let params = SvddParams::gaussian(0.35, 0.01);
        let mut s = StreamingSvdd::new(params, cfg(), 2);
        s.push_batch(&data).unwrap();
        assert!(s.model().is_some());
        s.reset_model();
        assert!(s.model().is_none());
        assert_eq!(s.rows_seen(), 256);
    }
}
