//! Streaming SVDD — the extension the paper's conclusion motivates:
//! "many [IoT] applications will require fast periodic training using
//! large data sets".
//!
//! [`StreamingSvdd`] maintains the master SV set *online*: observations
//! arrive in windows; each full window triggers one Algorithm-1-style
//! update (sample from the window, union with SV*, re-solve). A drift
//! monitor tracks the relative R^2 movement across updates; a sustained
//! shift beyond the drift threshold reports [`DriftStatus::Drifted`] so
//! operators can trigger a full retrain (the paper's "separate operating
//! mode" scenario).
//!
//! With [`StreamingConfig::incremental`] set, the window drives the
//! exact online state machine instead: once the first window seeds an
//! [`IncrementalSvdd`], every subsequent observation slides the window
//! by one point (`add_point` + `remove_point` of the oldest) and the
//! model is refreshed per event — no snapshot retrain per window, at
//! the cost of bounded resyncs governed by
//! [`StreamingConfig::stale_budget`]. Drift is judged at window-sized
//! checkpoints on the same relative-R^2 rule, so both modes report
//! through one [`DriftStatus`] contract.

use crate::error::{Error, Result};
use crate::incremental::{IncrementalConfig, IncrementalSvdd, InsertionOrder};
use crate::svdd::model::SvddModel;
use crate::svdd::trainer::{train_detailed, SolverStats, SvddParams};
use crate::util::matrix::Matrix;
use crate::util::rng::Xoshiro256;

/// Streaming trainer configuration.
#[derive(Clone, Copy, Debug)]
pub struct StreamingConfig {
    /// Observations buffered before an update fires.
    pub window: usize,
    /// Random rows drawn from each full window (Algorithm-1 `n`).
    pub sample_size: usize,
    /// Relative R^2 movement treated as drift evidence.
    pub drift_threshold: f64,
    /// Consecutive drift-evidence updates before `Drifted` is reported.
    pub drift_patience: usize,
    /// Drive the window through per-point [`IncrementalSvdd`] updates
    /// instead of per-window snapshot retrains.
    pub incremental: bool,
    /// Staleness budget handed to the incremental state machine
    /// (updates between forced resyncs; 0 = never resync on staleness).
    /// Ignored in snapshot mode.
    pub stale_budget: usize,
}

impl Default for StreamingConfig {
    fn default() -> Self {
        StreamingConfig {
            window: 256,
            sample_size: 10,
            drift_threshold: 0.05,
            drift_patience: 3,
            incremental: false,
            stale_budget: 64,
        }
    }
}

/// Drift verdict after an update.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DriftStatus {
    /// R^2 stable within the threshold.
    Stable,
    /// Movement observed; not yet sustained.
    Suspect,
    /// `drift_patience` consecutive movements — retrain recommended.
    Drifted,
}

/// Online maintainer of the master SV set.
pub struct StreamingSvdd {
    params: SvddParams,
    cfg: StreamingConfig,
    rng: Xoshiro256,
    buffer: Vec<Vec<f64>>,
    model: Option<SvddModel>,
    drift_streak: usize,
    updates: usize,
    rows_seen: usize,
    solver_calls: usize,
    solver: SolverStats,
    /// Incremental mode: the exact online state machine, seeded by the
    /// first full window.
    inc: Option<IncrementalSvdd>,
    /// FIFO view over the state machine's swap-remove slots.
    order: InsertionOrder,
    /// Slides since the last drift checkpoint (incremental mode).
    pushes_since_check: usize,
    /// R^2 at the last drift checkpoint (incremental mode).
    check_r2: Option<f64>,
}

impl StreamingSvdd {
    pub fn new(params: SvddParams, cfg: StreamingConfig, seed: u64) -> StreamingSvdd {
        StreamingSvdd {
            params,
            cfg,
            rng: Xoshiro256::new(seed),
            buffer: Vec::with_capacity(cfg.window),
            model: None,
            drift_streak: 0,
            updates: 0,
            rows_seen: 0,
            solver_calls: 0,
            solver: SolverStats::default(),
            inc: None,
            order: InsertionOrder::new(),
            pushes_since_check: 0,
            check_r2: None,
        }
    }

    /// Current description (None until the first window completes).
    pub fn model(&self) -> Option<&SvddModel> {
        self.model.as_ref()
    }

    pub fn updates(&self) -> usize {
        self.updates
    }

    pub fn rows_seen(&self) -> usize {
        self.rows_seen
    }

    pub fn buffered(&self) -> usize {
        self.buffer.len()
    }

    /// SMO solves issued so far (2 per window update in snapshot mode;
    /// the seed solve plus resyncs in incremental mode).
    pub fn solver_calls(&self) -> usize {
        self.solver_calls
    }

    /// The online state machine, once seeded (incremental mode only).
    pub fn incremental_state(&self) -> Option<&IncrementalSvdd> {
        self.inc.as_ref()
    }

    /// Aggregated SMO telemetry across every window update.
    pub fn solver_stats(&self) -> &SolverStats {
        &self.solver
    }

    /// Feed one observation; returns `Some(status)` when a window
    /// completed and the model was updated (snapshot mode), or at
    /// window-sized drift checkpoints (incremental mode — the model
    /// itself refreshes on every push once seeded).
    pub fn push(&mut self, x: &[f64]) -> Result<Option<DriftStatus>> {
        if self.cfg.incremental {
            return self.push_incremental(x);
        }
        self.rows_seen += 1;
        self.buffer.push(x.to_vec());
        if self.buffer.len() < self.cfg.window {
            return Ok(None);
        }
        let window = Matrix::from_rows(&std::mem::take(&mut self.buffer))?;
        let status = self.update(&window)?;
        Ok(Some(status))
    }

    /// One per-point slide of the incremental window: buffer until the
    /// first window seeds the state machine, then newest in, oldest
    /// out — the active set stays exactly one window wide.
    fn push_incremental(&mut self, x: &[f64]) -> Result<Option<DriftStatus>> {
        self.rows_seen += 1;
        if self.inc.is_none() {
            self.buffer.push(x.to_vec());
            if self.buffer.len() < self.cfg.window {
                return Ok(None);
            }
            let window = Matrix::from_rows(&std::mem::take(&mut self.buffer))?;
            let icfg = IncrementalConfig {
                stale_budget: self.cfg.stale_budget,
                ..IncrementalConfig::default()
            };
            let inc = IncrementalSvdd::with_data(self.params, icfg, &window)?;
            for i in 0..window.rows() {
                self.order.record_add(i);
            }
            self.model = Some(inc.model()?);
            self.check_r2 = Some(inc.r2());
            self.solver = *inc.solver_stats();
            self.solver_calls = inc.resyncs() as usize;
            self.inc = Some(inc);
            return Ok(Some(DriftStatus::Stable));
        }
        let inc = self.inc.as_mut().expect("checked above");
        inc.add_point(x)?;
        self.order.record_add(inc.len() - 1);
        let oldest = self.order.oldest().expect("seeded window is non-empty");
        let last = inc.len() - 1;
        inc.remove_point(oldest)?;
        self.order.record_swap_remove(oldest, last);
        self.updates += 1;
        self.pushes_since_check += 1;
        self.model = Some(inc.model()?);
        self.solver = *inc.solver_stats();
        self.solver_calls = inc.resyncs() as usize;
        if self.pushes_since_check < self.cfg.window {
            return Ok(None);
        }
        self.pushes_since_check = 0;
        let r2 = inc.r2();
        let prev = self.check_r2.replace(r2).unwrap_or(r2);
        let shift = (r2 - prev).abs() / prev.abs().max(1e-12);
        if shift > self.cfg.drift_threshold {
            self.drift_streak += 1;
        } else {
            self.drift_streak = 0;
        }
        let status = if self.drift_streak >= self.cfg.drift_patience {
            DriftStatus::Drifted
        } else if self.drift_streak > 0 {
            DriftStatus::Suspect
        } else {
            DriftStatus::Stable
        };
        Ok(Some(status))
    }

    /// Feed a batch (returns the last update status if any fired).
    pub fn push_batch(&mut self, xs: &Matrix) -> Result<Option<DriftStatus>> {
        let mut last = None;
        for i in 0..xs.rows() {
            if let Some(s) = self.push(xs.row(i))? {
                last = Some(s);
            }
        }
        Ok(last)
    }

    /// One Algorithm-1-style update from a full window.
    fn update(&mut self, window: &Matrix) -> Result<DriftStatus> {
        let n = self.cfg.sample_size.max(2).min(window.rows());
        let idx = self.rng.sample_with_replacement(window.rows(), n);
        let sample = window.gather(&idx).dedup_rows();
        let (sample_model, stats) = train_detailed(&sample, &self.params, None)?;
        self.solver.absorb(&stats);
        self.solver_calls += 1;

        let prev_r2 = self.model.as_ref().map(|m| m.r2());
        let union = match &self.model {
            Some(master) => sample_model
                .support_vectors()
                .vstack(master.support_vectors())?
                .dedup_rows(),
            None => sample_model.support_vectors().clone(),
        };
        let (new_model, stats) = train_detailed(&union, &self.params, None)?;
        self.solver.absorb(&stats);
        self.solver_calls += 1;
        let status = match prev_r2 {
            None => DriftStatus::Stable,
            Some(prev) => {
                let shift = (new_model.r2() - prev).abs() / prev.abs().max(1e-12);
                if shift > self.cfg.drift_threshold {
                    self.drift_streak += 1;
                } else {
                    self.drift_streak = 0;
                }
                if self.drift_streak >= self.cfg.drift_patience {
                    DriftStatus::Drifted
                } else if self.drift_streak > 0 {
                    DriftStatus::Suspect
                } else {
                    DriftStatus::Stable
                }
            }
        };
        self.model = Some(new_model);
        self.updates += 1;
        Ok(status)
    }

    /// Drop the learned description (e.g. after an operator-confirmed
    /// regime change) but keep the buffer. In incremental mode the
    /// state machine is dropped too; the next window re-seeds it.
    pub fn reset_model(&mut self) {
        self.model = None;
        self.drift_streak = 0;
        self.inc = None;
        self.order = InsertionOrder::new();
        self.pushes_since_check = 0;
        self.check_r2 = None;
    }

    /// Adopt an externally retrained description (the lifecycle driver
    /// calls this after a drift-triggered retrain was promoted) and
    /// clear the drift streak, so subsequent windows are judged against
    /// the fresh champion instead of re-reporting the same drift.
    /// Rejects a model whose dimension does not match the stream's
    /// (known from the current model or the buffered rows) — otherwise
    /// the mismatch would only surface as an opaque vstack error deep
    /// inside the next window update.
    pub fn adopt_model(&mut self, model: SvddModel) -> Result<()> {
        let stream_dim = self
            .model
            .as_ref()
            .map(|m| m.dim())
            .or_else(|| self.buffer.first().map(|r| r.len()));
        if let Some(dim) = stream_dim {
            if model.dim() != dim {
                return Err(Error::invalid(format!(
                    "adopted model is {}-d but the stream is {}-d",
                    model.dim(),
                    dim
                )));
            }
        }
        self.check_r2 = Some(model.r2());
        self.model = Some(model);
        self.drift_streak = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{banana::Banana, Generator};

    fn cfg() -> StreamingConfig {
        StreamingConfig { window: 128, sample_size: 6, ..Default::default() }
    }

    #[test]
    fn learns_from_stream_and_matches_batch_quality() {
        let data = Banana::default().generate(4096, 42);
        let params = SvddParams::gaussian(0.35, 0.001);
        let mut s = StreamingSvdd::new(params, cfg(), 7);
        s.push_batch(&data).unwrap();
        let model = s.model().expect("model after 32 windows");
        assert_eq!(s.updates(), 4096 / 128);
        // telemetry: a sample + a union solve per window update
        assert_eq!(s.solver_calls(), 2 * s.updates());
        assert!(s.solver_stats().smo_iterations > 0);
        let batch = crate::svdd::train(&data, &params).unwrap();
        let rel = (model.r2() - batch.r2()).abs() / batch.r2();
        assert!(rel < 0.1, "stream vs batch R^2 gap {rel}");
    }

    #[test]
    fn no_model_before_first_window() {
        let params = SvddParams::gaussian(0.35, 0.01);
        let mut s = StreamingSvdd::new(params, cfg(), 1);
        for i in 0..127 {
            assert!(s.push(&[i as f64 * 0.001, 0.0]).unwrap().is_none());
        }
        assert!(s.model().is_none());
        assert_eq!(s.buffered(), 127);
        assert!(s.push(&[0.0, 0.0]).unwrap().is_some());
        assert!(s.model().is_some());
        assert_eq!(s.buffered(), 0);
    }

    #[test]
    fn stable_stream_reports_stable() {
        let data = Banana::default().generate(2048, 3);
        let params = SvddParams::gaussian(0.35, 0.001);
        let mut s = StreamingSvdd::new(params, cfg(), 5);
        // after warm-up, statuses should settle to Stable
        let mut last = None;
        for i in 0..data.rows() {
            if let Some(st) = s.push(data.row(i)).unwrap() {
                last = Some(st);
            }
        }
        assert_eq!(last, Some(DriftStatus::Stable));
    }

    #[test]
    fn regime_change_triggers_drift() {
        let params = SvddParams::gaussian(0.35, 0.001);
        let mut s = StreamingSvdd::new(
            params,
            StreamingConfig {
                window: 128,
                sample_size: 6,
                drift_threshold: 0.02,
                drift_patience: 1,
                ..Default::default()
            },
            9,
        );
        // regime A: banana at origin
        let a = Banana::default().generate(1024, 1);
        s.push_batch(&a).unwrap();
        // regime B: same shape shifted far away. The master set absorbs
        // the new region within a window or two, so R^2 jumps and then
        // re-stabilizes — drift must be reported on SOME update (the
        // last status may already be Stable again).
        let mut b = Banana::default().generate(1024, 2);
        for i in 0..b.rows() {
            b.row_mut(i)[0] += 8.0;
        }
        let mut saw_drift = false;
        for i in 0..b.rows() {
            if let Some(DriftStatus::Drifted) = s.push(b.row(i)).unwrap() {
                saw_drift = true;
            }
        }
        assert!(saw_drift, "no drift reported across the regime change");
    }

    #[test]
    fn adopt_model_clears_drift_streak() {
        let params = SvddParams::gaussian(0.35, 0.001);
        let mut s = StreamingSvdd::new(
            params,
            StreamingConfig {
                window: 128,
                sample_size: 6,
                drift_threshold: 0.02,
                drift_patience: 1,
                ..Default::default()
            },
            4,
        );
        let a = Banana::default().generate(512, 1);
        s.push_batch(&a).unwrap();
        // push the stream into a drifted regime
        let mut b = Banana::default().generate(512, 2);
        for i in 0..b.rows() {
            b.row_mut(i)[0] += 8.0;
        }
        s.push_batch(&b).unwrap();
        // adopting a retrained description resets the streak and the
        // stream keeps running against the adopted model
        let retrained = crate::svdd::train(&b, &params).unwrap();
        let adopted_r2 = retrained.r2();
        s.adopt_model(retrained).unwrap();
        assert_eq!(s.model().unwrap().r2(), adopted_r2);
        // dimension mismatch is rejected up front, not on the next window
        let odd = crate::svdd::train(
            &Matrix::from_rows(&[vec![0.0; 3], vec![1.0; 3], vec![0.5; 3]]).unwrap(),
            &params,
        )
        .unwrap();
        assert!(s.adopt_model(odd).is_err());
        let more = {
            let mut m = Banana::default().generate(128, 3);
            for i in 0..m.rows() {
                m.row_mut(i)[0] += 8.0;
            }
            m
        };
        let status = s.push_batch(&more).unwrap();
        assert!(status.is_some(), "window update must fire");
    }

    #[test]
    fn incremental_window_matches_snapshot_retrain_on_drift() {
        // Property: after a banana regime shift, the per-point
        // incremental window's model agrees with a snapshot retrain on
        // the same (final) window rows within 5% relative R^2.
        let params = SvddParams::gaussian(0.35, 0.001);
        let window = 128;
        let mut s = StreamingSvdd::new(
            params,
            StreamingConfig {
                window,
                sample_size: 6,
                drift_threshold: 0.02,
                drift_patience: 1,
                incremental: true,
                stale_budget: 64,
            },
            7,
        );
        // 448 regime-A rows so a drift checkpoint (every 128 slides)
        // lands on a mixed A/B window mid-transition
        let a = Banana::default().generate(448, 1);
        s.push_batch(&a).unwrap();
        assert!(s.model().is_some(), "seeded after the first window");
        let mut b = Banana::default().generate(512, 2);
        for i in 0..b.rows() {
            b.row_mut(i)[0] += 8.0;
        }
        let mut saw_drift = false;
        for i in 0..b.rows() {
            if let Some(DriftStatus::Drifted) = s.push(b.row(i)).unwrap() {
                saw_drift = true;
            }
        }
        assert!(saw_drift, "regime shift must surface at a drift checkpoint");
        // per-point slides: every push after the seeding window
        assert_eq!(s.updates(), 448 + 512 - window);
        let inc = s.incremental_state().unwrap();
        assert_eq!(inc.len(), window, "active set stays one window wide");
        // snapshot retrain on the same rows the window currently holds:
        // the last `window` observations, all in regime B
        let last_rows: Vec<Vec<f64>> =
            (b.rows() - window..b.rows()).map(|i| b.row(i).to_vec()).collect();
        let snapshot = crate::svdd::train(&Matrix::from_rows(&last_rows).unwrap(), &params).unwrap();
        let stream_r2 = s.model().unwrap().r2();
        let rel = (stream_r2 - snapshot.r2()).abs() / snapshot.r2();
        assert!(rel < 0.05, "incremental {} vs snapshot retrain {} (rel {rel})", stream_r2, snapshot.r2());
    }

    #[test]
    fn reset_clears_model() {
        let data = Banana::default().generate(256, 4);
        let params = SvddParams::gaussian(0.35, 0.01);
        let mut s = StreamingSvdd::new(params, cfg(), 2);
        s.push_batch(&data).unwrap();
        assert!(s.model().is_some());
        s.reset_model();
        assert!(s.model().is_none());
        assert_eq!(s.rows_seen(), 256);
    }
}
