//! Adaptive sample-size selection.
//!
//! The paper observes that the right `n` is workload-dependent
//! ("establishing a right size, especially with high dimensional data,
//! is a challenge") and sweeps it by hand (Figs 4–6). This module
//! automates the choice: probe a small ladder of candidate sizes with
//! short budgeted runs, score each by time-to-stability, and return the
//! winner for the real run.

use crate::error::Result;
use crate::sampling::{SamplingConfig, SamplingTrainer};
use crate::svdd::trainer::SvddParams;
use crate::util::matrix::Matrix;
use crate::util::timer::Stopwatch;

/// Result of a probe ladder.
#[derive(Clone, Debug)]
pub struct AdaptiveChoice {
    /// The selected sample size.
    pub sample_size: usize,
    /// (candidate n, probe seconds, probe iterations, converged) rows.
    pub probes: Vec<(usize, f64, usize, bool)>,
}

/// Probe configuration.
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveConfig {
    /// Candidate ladder lower bound (paper sweeps from 3).
    pub min_n: usize,
    /// Upper bound; defaults to dimension-aware `max(20, m + 1)`.
    pub max_n: usize,
    /// Iteration cap per probe (keeps probes cheap).
    pub probe_iters: usize,
    /// Tolerances used during probes (looser than the real run).
    pub probe_eps: f64,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig { min_n: 3, max_n: 20, probe_iters: 120, probe_eps: 1e-3 }
    }
}

/// Choose a sample size for `data` by probing a geometric ladder of
/// candidates. Deterministic in `seed`.
pub fn choose_sample_size(
    data: &Matrix,
    params: &SvddParams,
    cfg: &AdaptiveConfig,
    seed: u64,
) -> Result<AdaptiveChoice> {
    let dim_guided = data.cols() + 1; // the paper's m+1 rule of thumb
    let max_n = cfg.max_n.max(dim_guided).min(data.rows().max(2));
    let min_n = cfg.min_n.clamp(2, max_n);

    // geometric ladder min_n .. max_n (≤ 6 probes)
    let mut ladder = vec![min_n];
    let mut v = min_n;
    while v < max_n {
        v = ((v as f64) * 1.8).ceil() as usize;
        ladder.push(v.min(max_n));
    }
    ladder.dedup();
    if !ladder.contains(&dim_guided) && dim_guided <= max_n {
        ladder.push(dim_guided);
        ladder.sort_unstable();
    }

    let mut probes = Vec::with_capacity(ladder.len());
    let mut best: Option<(f64, usize)> = None;
    for (k, &n) in ladder.iter().enumerate() {
        let scfg = SamplingConfig {
            sample_size: n,
            max_iter: cfg.probe_iters,
            eps_center: cfg.probe_eps,
            eps_r2: cfg.probe_eps,
            consecutive: 5,
            candidates_per_iter: 1,
            warm_alpha: false,
            record_trace: false,
        };
        let sw = Stopwatch::start();
        let out = SamplingTrainer::new(*params, scfg).train(data, seed ^ (k as u64) << 32)?;
        let secs = sw.elapsed_secs();
        probes.push((n, secs, out.iterations, out.converged));
        // score: rows touched (a deterministic work proxy ~ n * iters *
        // per-solve cost), with a stiff penalty for not stabilizing.
        // Wall-clock is reported in the probe rows but not used for the
        // decision so the choice is reproducible across machines.
        let work = out.rows_touched as f64;
        let score = if out.converged { work } else { work * 10.0 };
        if best.map(|(b, _)| score < b).unwrap_or(true) {
            best = Some((score, n));
        }
    }
    Ok(AdaptiveChoice {
        sample_size: best.map(|(_, n)| n).unwrap_or(dim_guided),
        probes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{banana::Banana, Generator};
    use crate::data::shuttle::Shuttle;

    #[test]
    fn picks_a_reasonable_size_for_2d() {
        let data = Banana::default().generate(8000, 42);
        let params = SvddParams::gaussian(0.35, 0.001);
        let choice =
            choose_sample_size(&data, &params, &AdaptiveConfig::default(), 7).unwrap();
        assert!((3..=20).contains(&choice.sample_size), "{:?}", choice);
        assert!(choice.probes.len() >= 3);
        // all probes converged on this easy geometry
        assert!(choice.probes.iter().any(|p| p.3));
    }

    #[test]
    fn ladder_respects_dimension_rule() {
        // 9-dim data: ladder must include m+1 = 10
        let data = Shuttle.training(3000, 1);
        let params = SvddParams::gaussian(8.0, 0.005);
        let choice =
            choose_sample_size(&data, &params, &AdaptiveConfig::default(), 3).unwrap();
        assert!(choice.probes.iter().any(|p| p.0 == 10), "{:?}", choice.probes);
    }

    #[test]
    fn deterministic_given_seed() {
        let data = Banana::default().generate(3000, 5);
        let params = SvddParams::gaussian(0.35, 0.001);
        let a = choose_sample_size(&data, &params, &AdaptiveConfig::default(), 11).unwrap();
        let b = choose_sample_size(&data, &params, &AdaptiveConfig::default(), 11).unwrap();
        assert_eq!(a.sample_size, b.sample_size);
    }

    #[test]
    fn tiny_data_clamps() {
        let data = Banana::default().generate(5, 2);
        let params = SvddParams::gaussian(0.35, 0.1);
        let choice =
            choose_sample_size(&data, &params, &AdaptiveConfig::default(), 1).unwrap();
        assert!(choice.sample_size <= 5);
    }
}
