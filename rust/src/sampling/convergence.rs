//! Convergence detection for Algorithm 1 (paper section III,
//! "Convergence Criteria"): both the center `a` and the threshold `R^2`
//! must be relatively stable for `t` consecutive iterations.

/// Tolerances + required streak length.
#[derive(Clone, Copy, Debug)]
pub struct ConvergenceCriteria {
    /// `eps1`: `||a_i - a_{i-1}|| <= eps1 * max(||a_{i-1}||, scale_floor)`.
    pub eps_center: f64,
    /// `eps2`: `|R2_i - R2_{i-1}| <= eps2 * R2_{i-1}`.
    pub eps_r2: f64,
    /// `t`: consecutive satisfied checks required.
    pub consecutive: usize,
    /// Lower bound on the center-norm denominator. The paper's raw
    /// criterion divides by `||a_{i-1}||`, which collapses to ~0 for
    /// symmetric data (e.g. Two-Donut) and then never fires; the paper
    /// acknowledges this by noting that "checking the convergence of
    /// just R^2 suffices" in many cases. We keep the center check but
    /// floor its scale at the data scale (the sampling trainer sets
    /// this to the mean SV norm). 0 reproduces the paper verbatim.
    pub scale_floor: f64,
}

impl Default for ConvergenceCriteria {
    fn default() -> Self {
        ConvergenceCriteria {
            eps_center: 1e-3,
            eps_r2: 1e-3,
            consecutive: 5,
            scale_floor: 0.0,
        }
    }
}

/// Streak tracker fed once per iteration with the new `(R^2, a)`.
#[derive(Clone, Debug)]
pub struct ConvergenceTracker {
    criteria: ConvergenceCriteria,
    prev_r2: Option<f64>,
    prev_center: Vec<f64>,
    streak: usize,
}

impl ConvergenceTracker {
    pub fn new(criteria: ConvergenceCriteria) -> Self {
        ConvergenceTracker {
            criteria,
            prev_r2: None,
            prev_center: Vec::new(),
            streak: 0,
        }
    }

    /// Record iteration `(r2, center)`; returns the relative center
    /// delta (NaN for the first observation).
    pub fn observe(&mut self, r2: f64, center: &[f64]) -> f64 {
        let delta = match self.prev_r2 {
            None => f64::NAN,
            Some(prev_r2) => {
                let prev_norm = norm(&self.prev_center)
                    .max(self.criteria.scale_floor)
                    .max(f64::MIN_POSITIVE);
                let mut diff = 0.0;
                for (a, b) in center.iter().zip(&self.prev_center) {
                    diff += (a - b) * (a - b);
                }
                let center_delta = diff.sqrt() / prev_norm;
                let r2_ok = (r2 - prev_r2).abs() <= self.criteria.eps_r2 * prev_r2.abs();
                let center_ok = diff.sqrt() <= self.criteria.eps_center * prev_norm;
                if r2_ok && center_ok {
                    self.streak += 1;
                } else {
                    self.streak = 0;
                }
                center_delta
            }
        };
        self.prev_r2 = Some(r2);
        self.prev_center = center.to_vec();
        delta
    }

    /// True once the streak reaches `t`.
    pub fn converged(&self) -> bool {
        self.streak >= self.criteria.consecutive
    }

    pub fn streak(&self) -> usize {
        self.streak
    }
}

fn norm(xs: &[f64]) -> f64 {
    xs.iter().map(|x| x * x).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tracker(t: usize) -> ConvergenceTracker {
        ConvergenceTracker::new(ConvergenceCriteria {
            eps_center: 1e-3,
            eps_r2: 1e-3,
            consecutive: t,
            scale_floor: 0.0,
        })
    }

    #[test]
    fn needs_t_consecutive_stable_steps() {
        let mut tr = tracker(3);
        let c = [1.0, 0.0];
        tr.observe(1.0, &c);
        assert!(!tr.converged());
        for _ in 0..2 {
            tr.observe(1.0, &c);
            assert!(!tr.converged());
        }
        tr.observe(1.0, &c);
        assert!(tr.converged());
    }

    #[test]
    fn unstable_step_resets_streak() {
        let mut tr = tracker(2);
        let c = [1.0, 0.0];
        tr.observe(1.0, &c);
        tr.observe(1.0, &c);
        assert_eq!(tr.streak(), 1);
        tr.observe(2.0, &c); // R^2 jump
        assert_eq!(tr.streak(), 0);
        tr.observe(2.0, &c);
        tr.observe(2.0, &c);
        assert!(tr.converged());
    }

    #[test]
    fn center_motion_blocks_convergence() {
        let mut tr = tracker(1);
        tr.observe(1.0, &[1.0, 0.0]);
        tr.observe(1.0, &[1.5, 0.0]); // big center move, same R^2
        assert!(!tr.converged());
        tr.observe(1.0, &[1.5, 0.0]);
        assert!(tr.converged());
    }

    #[test]
    fn relative_tolerance_scales() {
        // same absolute delta passes at large scale, fails at small
        let mut big = tracker(1);
        big.observe(1000.0, &[1000.0]);
        big.observe(1000.5, &[1000.0]); // 5e-4 relative
        assert!(big.converged());
        let mut small = tracker(1);
        small.observe(1.0, &[1.0]);
        small.observe(1.5, &[1.0]);
        assert!(!small.converged());
    }

    #[test]
    fn delta_reporting() {
        let mut tr = tracker(1);
        let d0 = tr.observe(1.0, &[1.0, 0.0]);
        assert!(d0.is_nan());
        let d1 = tr.observe(1.0, &[0.0, 1.0]);
        assert!((d1 - std::f64::consts::SQRT_2).abs() < 1e-12);
    }
}
