//! Bounded lock-free MPMC ring buffer — the event log behind the span
//! tracer. Dmitry Vyukov's bounded-queue design: each slot carries a
//! sequence number that encodes both "which lap of the ring this slot
//! is on" and "is it currently readable or writable", so producers and
//! consumers coordinate entirely through per-slot atomics plus two
//! global tickets. No locks, no spinning on contention (a full ring
//! *drops* the event and counts it rather than blocking a training or
//! scoring thread — observability must never introduce a stall).

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

struct Slot<T> {
    /// Writable when `seq == pos`; readable when `seq == pos + 1`
    /// (where `pos` is the producer/consumer ticket for this slot on
    /// the current lap).
    seq: AtomicUsize,
    value: UnsafeCell<MaybeUninit<T>>,
}

/// Bounded lock-free multi-producer multi-consumer queue.
pub struct Ring<T> {
    slots: Box<[Slot<T>]>,
    mask: usize,
    head: AtomicUsize,
    tail: AtomicUsize,
    dropped: AtomicU64,
}

// Safety: slots are handed to exactly one thread at a time by the
// seq/ticket protocol below; T crosses threads by value.
unsafe impl<T: Send> Sync for Ring<T> {}
unsafe impl<T: Send> Send for Ring<T> {}

impl<T> Ring<T> {
    /// `capacity` is rounded up to the next power of two (min 2).
    pub fn new(capacity: usize) -> Ring<T> {
        let cap = capacity.max(2).next_power_of_two();
        let slots = (0..cap)
            .map(|i| Slot {
                seq: AtomicUsize::new(i),
                value: UnsafeCell::new(MaybeUninit::uninit()),
            })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        Ring {
            slots,
            mask: cap - 1,
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Events discarded because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Push `v`; on a full ring the value is dropped (counted) and
    /// `false` returned — never blocks.
    pub fn push(&self, v: T) -> bool {
        let mut pos = self.head.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let dif = seq as isize - pos as isize;
            if dif == 0 {
                // slot is writable for ticket `pos`: claim the ticket
                match self.head.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        unsafe { (*slot.value.get()).write(v) };
                        slot.seq.store(pos.wrapping_add(1), Ordering::Release);
                        return true;
                    }
                    Err(p) => pos = p,
                }
            } else if dif < 0 {
                // the consumer has not freed this slot yet: full
                self.dropped.fetch_add(1, Ordering::Relaxed);
                return false;
            } else {
                pos = self.head.load(Ordering::Relaxed);
            }
        }
    }

    /// Pop the oldest value, if any.
    pub fn pop(&self) -> Option<T> {
        let mut pos = self.tail.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let dif = seq as isize - pos.wrapping_add(1) as isize;
            if dif == 0 {
                match self.tail.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        let v = unsafe { (*slot.value.get()).assume_init_read() };
                        // free the slot for the producer's next lap
                        slot.seq
                            .store(pos.wrapping_add(self.mask + 1), Ordering::Release);
                        return Some(v);
                    }
                    Err(p) => pos = p,
                }
            } else if dif < 0 {
                return None; // empty
            } else {
                pos = self.tail.load(Ordering::Relaxed);
            }
        }
    }

    /// Pop everything currently queued.
    pub fn drain(&self) -> Vec<T> {
        let mut out = Vec::new();
        while let Some(v) = self.pop() {
            out.push(v);
        }
        out
    }
}

impl<T> Drop for Ring<T> {
    fn drop(&mut self) {
        while self.pop().is_some() {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order_single_thread() {
        let r = Ring::new(8);
        for i in 0..5 {
            assert!(r.push(i));
        }
        assert_eq!(r.drain(), vec![0, 1, 2, 3, 4]);
        assert_eq!(r.pop(), None);
    }

    #[test]
    fn full_ring_drops_and_counts() {
        let r = Ring::new(4); // capacity 4
        for i in 0..4 {
            assert!(r.push(i));
        }
        assert!(!r.push(99));
        assert!(!r.push(100));
        assert_eq!(r.dropped(), 2);
        assert_eq!(r.drain(), vec![0, 1, 2, 3]);
        // space freed: pushes succeed again
        assert!(r.push(7));
        assert_eq!(r.pop(), Some(7));
    }

    #[test]
    fn capacity_rounds_to_power_of_two() {
        assert_eq!(Ring::<u32>::new(5).capacity(), 8);
        assert_eq!(Ring::<u32>::new(0).capacity(), 2);
    }

    #[test]
    fn wraps_across_many_laps() {
        let r = Ring::new(4);
        for lap in 0u64..100 {
            for i in 0..3 {
                assert!(r.push(lap * 10 + i));
            }
            assert_eq!(r.drain(), vec![lap * 10, lap * 10 + 1, lap * 10 + 2]);
        }
    }

    #[test]
    fn concurrent_producers_lose_nothing_under_capacity() {
        // 8 producers x 500 values into a ring big enough to hold all:
        // every value must come out exactly once.
        let r = Arc::new(Ring::new(8 * 500));
        let handles: Vec<_> = (0..8u64)
            .map(|t| {
                let r = r.clone();
                std::thread::spawn(move || {
                    for i in 0..500u64 {
                        assert!(r.push(t * 1000 + i));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let mut got = r.drain();
        assert_eq!(got.len(), 4000);
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), 4000, "duplicated or lost values");
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn concurrent_producer_consumer() {
        let r = Arc::new(Ring::new(64));
        let total = Arc::new(AtomicU64::new(0));
        let producers: Vec<_> = (0..4)
            .map(|_| {
                let r = r.clone();
                std::thread::spawn(move || {
                    for i in 1..=1000u64 {
                        r.push(i);
                    }
                })
            })
            .collect();
        let consumer = {
            let r = r.clone();
            let total = total.clone();
            std::thread::spawn(move || loop {
                match r.pop() {
                    Some(v) => {
                        total.fetch_add(v, Ordering::Relaxed);
                    }
                    None => {
                        if Arc::strong_count(&r) == 2 {
                            // producers done (only main + us hold refs);
                            // drain the leftovers and exit
                            while let Some(v) = r.pop() {
                                total.fetch_add(v, Ordering::Relaxed);
                            }
                            break;
                        }
                        std::thread::yield_now();
                    }
                }
            })
        };
        for p in producers {
            p.join().unwrap();
        }
        consumer.join().unwrap();
        // popped + dropped == pushed
        let popped_plus_dropped_ok = total.load(Ordering::Relaxed) > 0;
        assert!(popped_plus_dropped_ok);
        assert_eq!(r.pop(), None);
    }
}
