//! Render a `--log-json` run log back into the paper's evidence:
//! the per-stage timing breakdown (where did the training time go —
//! sample solves, union solves, scoring) and the Fig-7-style R²
//! convergence trace, reconstructed from the JSONL alone.

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::util::json::Json;
use crate::util::timer::fmt_duration;

/// Aggregated timing for one span label (span name, refined by the
/// `stage` field when present — e.g. `sampling.solve[union]`).
#[derive(Clone, Debug, PartialEq)]
pub struct StageRow {
    pub label: String,
    pub count: u64,
    pub total_secs: f64,
    pub max_secs: f64,
}

impl StageRow {
    pub fn mean_secs(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_secs / self.count as f64
        }
    }
}

/// One `sampling.iter` span: (iteration, r2, num_sv).
pub type TracePoint = (u64, f64, u64);

/// Everything the report verb extracts from a run log.
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    /// Per-label timing, sorted by total time descending.
    pub stages: Vec<StageRow>,
    /// R² convergence trace from `sampling.iter` spans, by iteration.
    pub trace: Vec<TracePoint>,
    /// `train.report` events, rendered one line each.
    pub trains: Vec<String>,
    /// Lines that failed to parse (reported, not fatal).
    pub skipped: usize,
}

/// Parse a JSONL run log (one event per line, as written by the
/// [`super`] sink). Unparseable lines are counted in `skipped` rather
/// than failing the whole report — a crashed run's truncated last line
/// must not make the log unreadable.
pub fn parse(text: &str) -> Result<RunReport> {
    let mut stages: BTreeMap<String, StageRow> = BTreeMap::new();
    let mut trace: Vec<TracePoint> = Vec::new();
    let mut trains: Vec<String> = Vec::new();
    let mut skipped = 0usize;
    let mut any = false;

    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let ev = match Json::parse(line) {
            Ok(j) => j,
            Err(_) => {
                skipped += 1;
                continue;
            }
        };
        let name = match ev.get("name").and_then(|n| n.as_str()) {
            Some(n) => n.to_string(),
            None => {
                skipped += 1;
                continue;
            }
        };
        any = true;
        let is_span = ev.get("type").and_then(|t| t.as_str()) == Some("span");

        if is_span {
            let dur_secs = ev
                .get("dur_us")
                .and_then(|d| d.as_f64())
                .unwrap_or(0.0)
                / 1e6;
            let label = match ev.get("stage").and_then(|s| s.as_str()) {
                Some(stage) => format!("{name}[{stage}]"),
                None => name.clone(),
            };
            let row = stages.entry(label.clone()).or_insert(StageRow {
                label,
                count: 0,
                total_secs: 0.0,
                max_secs: 0.0,
            });
            row.count += 1;
            row.total_secs += dur_secs;
            row.max_secs = row.max_secs.max(dur_secs);

            if name == "sampling.iter" {
                let it = ev.get("iteration").and_then(|v| v.as_f64()).unwrap_or(0.0);
                let r2 = ev.get("r2").and_then(|v| v.as_f64()).unwrap_or(0.0);
                let sv = ev.get("num_sv").and_then(|v| v.as_f64()).unwrap_or(0.0);
                trace.push((it as u64, r2, sv as u64));
            }
        } else if name == "train.report" {
            let method = ev.get("method").and_then(|v| v.as_str()).unwrap_or("?");
            let secs = ev.get("seconds").and_then(|v| v.as_f64()).unwrap_or(0.0);
            let iters = ev.get("iterations").and_then(|v| v.as_f64()).unwrap_or(0.0);
            let r2 = ev.get("r2").and_then(|v| v.as_f64()).unwrap_or(0.0);
            trains.push(format!(
                "method={method} time={} iterations={} r2={r2:.6}",
                fmt_duration(secs),
                iters as u64
            ));
        }
    }

    if !any {
        return Err(Error::invalid("run log contains no parseable events"));
    }
    trace.sort_by_key(|&(it, _, _)| it);
    let mut stages: Vec<StageRow> = stages.into_values().collect();
    stages.sort_by(|a, b| {
        b.total_secs
            .partial_cmp(&a.total_secs)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    Ok(RunReport { stages, trace, trains, skipped })
}

/// Render the report as the CLI prints it: training summary, the
/// per-stage timing table, and the R² trace with a proportional bar
/// per iteration (the Fig-7 shape, in a terminal).
pub fn render(r: &RunReport) -> String {
    let mut out = String::new();
    for t in &r.trains {
        out.push_str("train: ");
        out.push_str(t);
        out.push('\n');
    }
    if !r.trains.is_empty() {
        out.push('\n');
    }

    out.push_str("per-stage timing\n");
    out.push_str(&format!(
        "  {:<28} {:>7} {:>12} {:>12} {:>12}\n",
        "stage", "count", "total", "mean", "max"
    ));
    for row in &r.stages {
        out.push_str(&format!(
            "  {:<28} {:>7} {:>12} {:>12} {:>12}\n",
            row.label,
            row.count,
            fmt_duration(row.total_secs),
            fmt_duration(row.mean_secs()),
            fmt_duration(row.max_secs),
        ));
    }

    if !r.trace.is_empty() {
        out.push_str("\nR^2 convergence trace (paper Fig. 7)\n");
        let max_r2 = r
            .trace
            .iter()
            .map(|&(_, r2, _)| r2)
            .fold(f64::MIN, f64::max)
            .max(1e-300);
        for &(it, r2, sv) in &r.trace {
            let width = ((r2 / max_r2) * 40.0).round().max(0.0) as usize;
            out.push_str(&format!(
                "  iter {it:>4}  r2={r2:<12.6} sv={sv:<5} |{}\n",
                "#".repeat(width.min(40))
            ));
        }
    }

    if r.skipped > 0 {
        out.push_str(&format!("\n({} unparseable lines skipped)\n", r.skipped));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_log() -> String {
        [
            r#"{"type":"span","name":"sampling.solve","ts_us":10,"dur_us":2000,"thread":1,"stage":"seed","rows":6}"#,
            r#"{"type":"span","name":"sampling.solve","ts_us":20,"dur_us":1000,"thread":1,"stage":"sample","rows":6}"#,
            r#"{"type":"span","name":"sampling.solve","ts_us":30,"dur_us":3000,"thread":1,"stage":"union","rows":12}"#,
            r#"{"type":"span","name":"sampling.iter","ts_us":40,"dur_us":4500,"thread":1,"iteration":1,"r2":0.5,"num_sv":4}"#,
            r#"{"type":"span","name":"sampling.iter","ts_us":50,"dur_us":4000,"thread":1,"iteration":2,"r2":0.75,"num_sv":5}"#,
            r#"{"type":"event","name":"train.report","ts_us":60,"thread":1,"method":"sampling","seconds":0.012,"iterations":2,"r2":0.75}"#,
        ]
        .join("\n")
    }

    #[test]
    fn parse_groups_by_name_and_stage() {
        let rep = parse(&sample_log()).unwrap();
        assert_eq!(rep.skipped, 0);
        let labels: Vec<&str> = rep.stages.iter().map(|r| r.label.as_str()).collect();
        assert!(labels.contains(&"sampling.solve[seed]"));
        assert!(labels.contains(&"sampling.solve[sample]"));
        assert!(labels.contains(&"sampling.solve[union]"));
        let union = rep
            .stages
            .iter()
            .find(|r| r.label == "sampling.solve[union]")
            .unwrap();
        assert_eq!(union.count, 1);
        assert!((union.total_secs - 0.003).abs() < 1e-12);
        // stages sorted by total time descending: iter spans dominate
        assert_eq!(rep.stages[0].label, "sampling.iter");
        assert_eq!(rep.stages[0].count, 2);
    }

    #[test]
    fn parse_extracts_r2_trace_in_iteration_order() {
        let rep = parse(&sample_log()).unwrap();
        assert_eq!(rep.trace, vec![(1, 0.5, 4), (2, 0.75, 5)]);
        assert_eq!(rep.trains.len(), 1);
        assert!(rep.trains[0].contains("method=sampling"));
    }

    #[test]
    fn garbage_lines_are_skipped_not_fatal() {
        let text = format!("{}\nnot json at all\n{{\"truncat", sample_log());
        let rep = parse(&text).unwrap();
        assert_eq!(rep.skipped, 2);
        assert_eq!(rep.trace.len(), 2);
    }

    #[test]
    fn empty_log_is_an_error() {
        assert!(parse("").is_err());
        assert!(parse("garbage\nmore garbage").is_err());
    }

    #[test]
    fn render_contains_table_and_trace() {
        let rep = parse(&sample_log()).unwrap();
        let out = render(&rep);
        assert!(out.contains("per-stage timing"));
        assert!(out.contains("sampling.solve[union]"));
        assert!(out.contains("R^2 convergence trace"));
        assert!(out.contains("iter    2"));
        // the final iteration carries the longest bar
        let bar1 = out.lines().find(|l| l.contains("iter    1")).unwrap();
        let bar2 = out.lines().find(|l| l.contains("iter    2")).unwrap();
        let hashes = |l: &str| l.chars().filter(|&c| c == '#').count();
        assert!(hashes(bar2) > hashes(bar1));
        assert_eq!(hashes(bar2), 40);
    }
}
