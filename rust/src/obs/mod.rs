//! Observability: lightweight tracing spans, a bounded lock-free event
//! log, and an optional JSONL sink.
//!
//! Everything here is **opt-in and near-zero-cost when off**: the only
//! thing an instrumented code path pays while tracing is disabled (the
//! default) is one relaxed atomic load per [`Span::enter`] /
//! [`emit`] call — no clock read, no allocation, no queue traffic.
//! `benches/perf_obs.rs` pins that cost in CI.
//!
//! ## Span taxonomy
//!
//! | span / event            | fields                                   |
//! |-------------------------|------------------------------------------|
//! | `engine.train`          | `method`, `iterations`, `r2`, `converged`|
//! | `sampling.iter`         | `iteration`, `r2`, `num_sv`, `stage=iter`|
//! | `sampling.solve`        | `stage` (seed/sample/union), `rows`      |
//! | `smo.solve`             | `n`, `iterations`, `shrinks`, `gap`      |
//! | `gram.compute`          | `rows`, `entries`, `isa`                 |
//! | `score.dist2_batch`     | `rows`, `num_sv`, `isa`, `precision`     |
//! |                         | (`precision` only on the f32 panel path) |
//! | `batcher.batch`         | `rows`, `requests`                       |
//! | `server.request`        | `kind` (score/score_v2/info/swap/stats/  |
//! |                         | http), `path` (http only)                |
//! | `distributed.shard`     | `shard`, `attempt`, `worker`, `local`,   |
//! |                         | `ok` (one span per training attempt)     |
//! | `distributed.combine`   | `mode`, `sets`, `union_rows`, `solves`   |
//! | `distributed.retry` (ev)| `shard`, `attempt`, `delay_us`           |
//! | `distributed.worker_dead` (ev) | `worker`                          |
//! | `lifecycle.retrain`     | `version`, `warm`, `r2`                  |
//! | `lifecycle.respond`     | `version`, `slides`, `r2` (incremental   |
//! |                         | drift response)                          |
//! | `lifecycle.drift` (ev)  | `action` (retrain/incremental/watch/none)|
//! | `lifecycle.promote` (ev)| `version`                                |
//! | `lifecycle.swap` (ev)   | `version`, `epoch`                       |
//! | `incremental.update` (ev)| `op` (add/remove), `points`, `steps`,   |
//! |                         | `gap`                                    |
//! | `incremental.resync` (ev)| `reason` (seed/stale/divergence/manual),|
//! |                         | `points`, `iterations`                   |
//! | `train.report` (ev)     | `method`, `seconds`, `r2`, ...           |
//!
//! Spans record wall time on the process monotonic clock
//! ([`now_us`]); closing a span pushes one [`Event`] into a global
//! bounded [`Ring`] (full ring = drop + count, never block) and, when
//! a sink is installed ([`install_sink`]), appends one JSON line. Hot
//! paths (`gram`, `dist2_batch`) only open spans above
//! [`crate::parallel::MIN_PAR_WORK`] so the microkernels stay
//! untouched.
//!
//! `fastsvdd train --log-json run.jsonl` enables tracing plus the
//! sink; `fastsvdd report --log run.jsonl` renders the per-stage
//! timing table and the R² convergence trace from the file alone
//! ([`report`]).

pub mod report;
mod ring;

pub use ring::Ring;

use std::io::Write;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::error::Result;
use crate::util::json::{num, obj, s, Json};

/// Events the global ring retains (bounded memory: ~a few hundred
/// bytes per event).
const RING_CAPACITY: usize = 8192;

static ENABLED: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static RING: OnceLock<Ring<Event>> = OnceLock::new();
static SINK: Mutex<Option<std::io::BufWriter<std::fs::File>>> = Mutex::new(None);
static NEXT_THREAD: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static THREAD_ID: u64 = NEXT_THREAD.fetch_add(1, Ordering::Relaxed);
}

/// Is tracing on? One relaxed load — this is the entire disabled-path
/// cost of every instrumentation point.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn tracing on (idempotent). Pins the monotonic epoch on first use.
pub fn enable() {
    EPOCH.get_or_init(Instant::now);
    ENABLED.store(true, Ordering::Relaxed);
}

/// Turn tracing off. Already-open spans still record on close.
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

/// Microseconds since the tracing epoch (process-monotonic).
pub fn now_us() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

fn thread_id() -> u64 {
    THREAD_ID.with(|v| *v)
}

fn ring() -> &'static Ring<Event> {
    RING.get_or_init(|| Ring::new(RING_CAPACITY))
}

/// A recorded field value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    U64(u64),
    F64(f64),
    Str(String),
}

impl Value {
    fn to_json(&self) -> Json {
        match self {
            Value::U64(v) => num(*v as f64),
            Value::F64(v) => num(*v),
            Value::Str(v) => s(v.clone()),
        }
    }
}

/// One closed span or point event.
#[derive(Clone, Debug)]
pub struct Event {
    pub name: &'static str,
    /// `false` for point events ([`emit`]), which carry no duration.
    pub is_span: bool,
    pub start_us: u64,
    pub dur_us: u64,
    pub thread: u64,
    pub fields: Vec<(&'static str, Value)>,
}

impl Event {
    /// The compact JSONL line: span/event envelope with the fields
    /// flattened alongside it.
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("type", s(if self.is_span { "span" } else { "event" })),
            ("name", s(self.name)),
            ("ts_us", num(self.start_us as f64)),
            ("thread", num(self.thread as f64)),
        ];
        if self.is_span {
            pairs.push(("dur_us", num(self.dur_us as f64)));
        }
        for (k, v) in &self.fields {
            pairs.push((k, v.to_json()));
        }
        obj(pairs)
    }
}

/// An open span. Created by [`Span::enter`], recorded on drop. When
/// tracing is off the struct is an inert `None` and every method is a
/// no-op.
pub struct Span(Option<SpanInner>);

struct SpanInner {
    name: &'static str,
    start_us: u64,
    fields: Vec<(&'static str, Value)>,
}

impl Span {
    /// An inert span, for call sites that gate instrumentation on their
    /// own condition (e.g. work-size floors) and need a `Span` either way.
    #[inline]
    pub fn disabled() -> Span {
        Span(None)
    }

    #[inline]
    pub fn enter(name: &'static str) -> Span {
        if !enabled() {
            return Span(None);
        }
        Span(Some(SpanInner { name, start_us: now_us(), fields: Vec::new() }))
    }

    /// Is this span live (tracing was on when it was opened)? Lets
    /// callers skip computing expensive field values.
    #[inline]
    pub fn is_live(&self) -> bool {
        self.0.is_some()
    }

    #[inline]
    pub fn u64(&mut self, key: &'static str, v: u64) {
        if let Some(inner) = &mut self.0 {
            inner.fields.push((key, Value::U64(v)));
        }
    }

    #[inline]
    pub fn f64(&mut self, key: &'static str, v: f64) {
        if let Some(inner) = &mut self.0 {
            inner.fields.push((key, Value::F64(v)));
        }
    }

    #[inline]
    pub fn str(&mut self, key: &'static str, v: impl Into<String>) {
        if let Some(inner) = &mut self.0 {
            inner.fields.push((key, Value::Str(v.into())));
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(inner) = self.0.take() {
            let now = now_us();
            record(Event {
                name: inner.name,
                is_span: true,
                start_us: inner.start_us,
                dur_us: now.saturating_sub(inner.start_us),
                thread: thread_id(),
                fields: inner.fields,
            });
        }
    }
}

/// Record a point event (lifecycle transition, train report). No-op
/// while tracing is off.
pub fn emit(name: &'static str, fields: Vec<(&'static str, Value)>) {
    if !enabled() {
        return;
    }
    record(Event {
        name,
        is_span: false,
        start_us: now_us(),
        dur_us: 0,
        thread: thread_id(),
        fields,
    });
}

fn record(ev: Event) {
    // write the JSONL line first so the event can move into the ring
    // by value afterwards (no clone)
    if let Ok(mut g) = SINK.lock() {
        if let Some(w) = g.as_mut() {
            let _ = writeln!(w, "{}", ev.to_json());
        }
    }
    ring().push(ev);
}

/// Write every event (span close / lifecycle transition / train
/// report) as one JSON line to `path`, truncating any existing file.
/// Installing a sink does not enable tracing — call [`enable`] too.
pub fn install_sink(path: impl AsRef<std::path::Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())?;
    let mut g = SINK.lock().unwrap_or_else(|e| e.into_inner());
    *g = Some(std::io::BufWriter::new(f));
    Ok(())
}

/// Flush and detach the JSONL sink (events keep flowing to the ring).
pub fn remove_sink() {
    let mut g = SINK.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(w) = g.as_mut() {
        let _ = w.flush();
    }
    *g = None;
}

/// Flush the JSONL sink without detaching it.
pub fn flush_sink() {
    let mut g = SINK.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(w) = g.as_mut() {
        let _ = w.flush();
    }
}

/// Pop every event currently in the ring (oldest first).
pub fn drain() -> Vec<Event> {
    ring().drain()
}

/// Events discarded because the ring was full.
pub fn dropped() -> u64 {
    ring().dropped()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The global enable flag and ring are process-wide, so every test
    /// touching them runs under this lock to stay order-independent.
    static TEST_GUARD: Mutex<()> = Mutex::new(());

    fn locked() -> std::sync::MutexGuard<'static, ()> {
        let g = TEST_GUARD.lock().unwrap_or_else(|e| e.into_inner());
        disable();
        drain();
        g
    }

    #[test]
    fn disabled_span_records_nothing() {
        let _g = locked();
        {
            let mut sp = Span::enter("test.noop");
            assert!(!sp.is_live());
            sp.u64("k", 1);
        }
        emit("test.noop_event", vec![]);
        assert!(drain().is_empty());
    }

    #[test]
    fn enabled_span_records_fields_and_duration() {
        let _g = locked();
        enable();
        {
            let mut sp = Span::enter("test.span");
            assert!(sp.is_live());
            sp.u64("iteration", 3);
            sp.f64("r2", 0.5);
            sp.str("stage", "union");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        disable();
        let evs = drain();
        assert_eq!(evs.len(), 1);
        let ev = &evs[0];
        assert_eq!(ev.name, "test.span");
        assert!(ev.is_span);
        assert!(ev.dur_us >= 1000, "dur_us={}", ev.dur_us);
        assert_eq!(ev.fields[0], ("iteration", Value::U64(3)));
        assert_eq!(ev.fields[2], ("stage", Value::Str("union".into())));
    }

    #[test]
    fn emit_records_point_event() {
        let _g = locked();
        enable();
        emit("lifecycle.promote", vec![("version", Value::Str("v-abc".into()))]);
        disable();
        let evs = drain();
        assert_eq!(evs.len(), 1);
        assert!(!evs[0].is_span);
        assert_eq!(evs[0].dur_us, 0);
    }

    #[test]
    fn event_json_line_is_flat_and_single_line() {
        let _g = locked();
        let ev = Event {
            name: "sampling.iter",
            is_span: true,
            start_us: 10,
            dur_us: 5,
            thread: 1,
            fields: vec![("iteration", Value::U64(2)), ("r2", Value::F64(0.25))],
        };
        let line = ev.to_json().to_string();
        assert!(!line.contains('\n'));
        let parsed = Json::parse(&line).unwrap();
        assert_eq!(parsed.get("name").unwrap().as_str().unwrap(), "sampling.iter");
        assert_eq!(parsed.get("dur_us").unwrap().as_usize().unwrap(), 5);
        assert_eq!(parsed.get("iteration").unwrap().as_usize().unwrap(), 2);
        assert_eq!(parsed.get("r2").unwrap().as_f64().unwrap(), 0.25);
    }

    #[test]
    fn sink_writes_one_line_per_event() {
        let _g = locked();
        let path = std::env::temp_dir()
            .join(format!("fastsvdd_obs_sink_{}.jsonl", std::process::id()));
        install_sink(&path).unwrap();
        enable();
        {
            let mut sp = Span::enter("test.sink");
            sp.u64("rows", 42);
        }
        emit("test.sink_event", vec![("k", Value::U64(7))]);
        disable();
        remove_sink();
        drain();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.get("name").unwrap().as_str().unwrap(), "test.sink");
        assert_eq!(first.get("rows").unwrap().as_usize().unwrap(), 42);
        let second = Json::parse(lines[1]).unwrap();
        assert_eq!(second.get("type").unwrap().as_str().unwrap(), "event");
    }

    #[test]
    fn spans_from_many_threads_all_land() {
        let _g = locked();
        enable();
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    for i in 0..50u64 {
                        let mut sp = Span::enter("test.mt");
                        sp.u64("i", t * 100 + i);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        disable();
        let evs = drain();
        assert_eq!(evs.len(), 200);
        let threads: std::collections::HashSet<u64> =
            evs.iter().map(|e| e.thread).collect();
        assert!(threads.len() >= 2, "expected multiple thread ids");
    }
}
