//! # fastsvdd — sampling-based SVDD training
//!
//! A production-quality reproduction of *"Sampling Method for Fast
//! Training of Support Vector Data Description"* (Chaudhuri et al., SAS
//! Institute, 2016) as a three-layer Rust + JAX + Pallas system:
//!
//! - **Layer 3 (this crate)** — the paper's contribution: the iterative
//!   sampling trainer ([`sampling`]), master-SV-set state management,
//!   convergence detection, the distributed controller/worker topology
//!   ([`distributed`]) and the batch scoring service ([`scoring`]).
//! - **Layer 2/1 (build-time Python)** — the SVDD compute graphs
//!   (batched kernel-distance scoring, sample gram matrices) written in
//!   JAX on top of Pallas kernels, AOT-lowered once to HLO text and
//!   executed from Rust through the PJRT CPU client ([`runtime`]).
//!
//! Python never runs on the train/serve path: after `make artifacts`
//! the Rust binary is self-contained.
//!
//! ## Quick start
//!
//! ```no_run
//! use fastsvdd::data::{banana::Banana, Generator};
//! use fastsvdd::sampling::{SamplingConfig, SamplingTrainer};
//! use fastsvdd::svdd::SvddParams;
//!
//! let data = Banana::default().generate(11_016, 42);
//! let params = SvddParams::gaussian(0.8, 0.001);
//! let cfg = SamplingConfig { sample_size: 6, ..Default::default() };
//! let outcome = SamplingTrainer::new(params, cfg).train(&data, 7).unwrap();
//! println!("R^2 = {:.4}, #SV = {}", outcome.model.r2(), outcome.model.num_sv());
//! ```
//!
//! See `examples/` for end-to-end drivers and `rust/benches/` for the
//! harnesses that regenerate every table and figure of the paper.

pub mod baselines;
pub mod bench;
pub mod cli;
pub mod config;
pub mod data;
pub mod distributed;
pub mod error;
pub mod metrics;
pub mod runtime;
pub mod sampling;
pub mod scoring;
pub mod svdd;
pub mod testutil;
pub mod util;

pub use error::{Error, Result};
