//! # fastsvdd — sampling-based SVDD training
//!
//! A production-quality reproduction of *"Sampling Method for Fast
//! Training of Support Vector Data Description"* (Chaudhuri et al., SAS
//! Institute, 2016) as a three-layer Rust + JAX + Pallas system:
//!
//! - **Layer 3 (this crate)** — the paper's contribution: the iterative
//!   sampling trainer ([`sampling`]), master-SV-set state management,
//!   convergence detection, the distributed controller/worker topology
//!   ([`distributed`]) and the batch scoring service ([`scoring`]),
//!   all running over a shared chunked thread pool ([`parallel`]) that
//!   keeps seeded runs bit-identical at any thread count.
//! - **Layer 2/1 (build-time Python)** — the SVDD compute graphs
//!   (batched kernel-distance scoring, sample gram matrices) written in
//!   JAX on top of Pallas kernels, AOT-lowered once to HLO text and
//!   executed from Rust through the PJRT CPU client ([`runtime`]).
//!
//! Python never runs on the train/serve path: after `make artifacts`
//! the Rust binary is self-contained.
//!
//! ## Model lifecycle (drift → warm retrain → promote → swap)
//!
//! Because a sampling retrain is cheap, the system is built to retrain
//! *continuously* in production. The [`registry`] subsystem provides
//! the operational loop around the trainer:
//!
//! 1. [`sampling::StreamingSvdd`] maintains the master SV set online
//!    and raises [`sampling::DriftStatus::Drifted`] when the
//!    description moves;
//! 2. [`registry::Lifecycle`] retrains on the recent window —
//!    [`sampling::SamplingTrainer::train_warm`], seeding `SV*` from
//!    the current champion's support vectors so the run converges in
//!    far fewer iterations than a cold start;
//! 3. the result is published to the content-addressed, versioned
//!    [`registry::Registry`] (per-version `R^2`/`#SV`/sample-size/
//!    iteration/fingerprint metadata; atomic promote and rollback);
//! 4. the promoted model is hot-swapped into the serving
//!    [`scoring::ModelSlot`] — in-flight batches finish on the old
//!    model, new batches score on the new one, zero dropped
//!    connections (remotely: the v2 `SwapModel`/`ModelInfo` frames of
//!    [`distributed::message`]).
//!
//! See [`registry`] for the on-disk layout and the
//! `fastsvdd registry list|promote|rollback|gc` / `fastsvdd serve
//! --registry DIR --watch` CLI verbs, and
//! `examples/lifecycle_monitoring.rs` for the end-to-end loop on the
//! Tennessee-Eastman-like plant.
//!
//! ## Quick start
//!
//! Every training method — the paper's sampling Algorithm 1, the full
//! baseline, Luo, Kim, distributed, streaming-snapshot — runs through
//! the unified [`engine`]:
//!
//! ```no_run
//! use fastsvdd::config::RunConfig;
//! use fastsvdd::data::{banana::Banana, Generator};
//! use fastsvdd::engine::Engine;
//!
//! let cfg = RunConfig { rows: 11_016, sample_size: 6, ..Default::default() };
//! let data = Banana::default().generate(cfg.rows, cfg.seed);
//! let report = Engine::from_config(&cfg).unwrap().train(&data).unwrap();
//! println!("R^2 = {:.4}, #SV = {}", report.model.r2(), report.model.num_sv());
//! ```
//!
//! The method-specific entry points
//! ([`sampling::SamplingTrainer`], [`baselines::train_full`], ...)
//! remain available for direct use and produce byte-identical models.
//!
//! See `examples/` for end-to-end drivers and `rust/benches/` for the
//! harnesses that regenerate every table and figure of the paper.

pub mod baselines;
pub mod bench;
pub mod cli;
pub mod config;
pub mod data;
pub mod distributed;
pub mod engine;
pub mod error;
pub mod incremental;
pub mod linalg;
pub mod metrics;
pub mod obs;
pub mod parallel;
pub mod registry;
pub mod runtime;
pub mod sampling;
pub mod scoring;
pub mod svdd;
pub mod testutil;
pub mod util;

pub use error::{Error, Result};
