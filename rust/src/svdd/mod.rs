//! Core SVDD: kernels, the SMO dual solver, the trained model and the
//! training front-end. This is the substrate the paper builds on
//! (LIBSVM in the original; reimplemented from scratch here — see
//! DESIGN.md section 2).

pub mod bandwidth;
pub mod cache;
pub mod kernel;
pub mod model;
pub mod smo;
pub mod trainer;

pub use kernel::Kernel;
pub use model::{ModelF32, SvddModel};
pub use smo::{KernelProvider, SmoOptions, SmoSolution, Wss};
pub use trainer::{train, train_with_gram, SolverStats, SvddParams};
