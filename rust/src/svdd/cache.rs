//! LRU kernel-column cache, the same role as LIBSVM's `Cache` class.
//!
//! The SMO solver touches two kernel columns per iteration and revisits
//! the same (small) active set many times; caching columns converts the
//! per-iteration cost from O(n·m) kernel evaluations to an O(n) copy
//! for cached columns. The budget is expressed in bytes and evicts the
//! least-recently-used column. On a miss the `fill` closure provided by
//! [`crate::svdd::smo::LazyKernel`] computes the column as norm-cached
//! [`crate::svdd::Kernel::eval_block`] panels (in parallel chunks), so
//! cached and freshly computed columns carry identical bits regardless
//! of thread count.
//!
//! Recency is tracked with an intrusive doubly-linked list over the
//! slot arena (head = MRU, tail = LRU), so a hit, a miss and an
//! eviction are all O(1) — the eviction used to be an O(#cached)
//! min-scan over insertion ticks, which showed up once budgets grew to
//! thousands of columns.
//!
//! Mutable training sets (the online path in [`crate::incremental`])
//! remove and overwrite rows, which silently stales every cached column
//! that contains the touched row and the column keyed by it. Callers
//! that mutate rows must call [`ColumnCache::invalidate`] (single
//! column) or [`ColumnCache::invalidate_all`] (any row edit, since a
//! row change dirties one *entry* of every cached column); invalidated
//! slots park on a free list and are reused before any eviction, so
//! the arena never grows past the byte budget.

use std::collections::HashMap;

const NIL: usize = usize::MAX;

struct Slot {
    col: usize,
    prev: usize,
    next: usize,
    data: Vec<f64>,
}

/// LRU cache of `n`-length kernel columns keyed by column index.
pub struct ColumnCache {
    n: usize,
    capacity_cols: usize,
    map: HashMap<usize, usize>, // col index -> slot index
    slots: Vec<Slot>,
    /// Most-recently-used slot (NIL when empty).
    head: usize,
    /// Least-recently-used slot (NIL when empty) — the eviction victim.
    tail: usize,
    /// Slots parked by `invalidate*`, reused before any eviction.
    free: Vec<usize>,
    hits: u64,
    misses: u64,
}

impl ColumnCache {
    /// `budget_bytes` is rounded down to whole columns and clamped to
    /// `n` (there are only `n` distinct columns to cache); at least one
    /// column is always cached.
    pub fn new(n: usize, budget_bytes: usize) -> Self {
        let col_bytes = (n * std::mem::size_of::<f64>()).max(1);
        let capacity_cols = (budget_bytes / col_bytes).clamp(1, n.max(1));
        ColumnCache {
            n,
            capacity_cols,
            map: HashMap::with_capacity(capacity_cols),
            slots: Vec::with_capacity(capacity_cols),
            head: NIL,
            tail: NIL,
            free: Vec::new(),
            hits: 0,
            misses: 0,
        }
    }

    /// Unlink `slot` from the recency list.
    fn unlink(&mut self, slot: usize) {
        let (prev, next) = (self.slots[slot].prev, self.slots[slot].next);
        match prev {
            NIL => self.head = next,
            p => self.slots[p].next = next,
        }
        match next {
            NIL => self.tail = prev,
            x => self.slots[x].prev = prev,
        }
    }

    /// Link `slot` at the MRU end.
    fn push_front(&mut self, slot: usize) {
        self.slots[slot].prev = NIL;
        self.slots[slot].next = self.head;
        match self.head {
            NIL => self.tail = slot,
            h => self.slots[h].prev = slot,
        }
        self.head = slot;
    }

    fn touch(&mut self, slot: usize) {
        if self.head != slot {
            self.unlink(slot);
            self.push_front(slot);
        }
    }

    /// Borrow column `i` if cached, refreshing its recency. Used by
    /// ranged column fills, which evaluate only the requested rows on a
    /// miss instead of materializing a full column. Deliberately does
    /// NOT touch the hit/miss counters: a single logical column fetch
    /// over a shrunk active set arrives as one `lookup` per run of
    /// consecutive indices, so counting here would multiply one fetch
    /// into dozens of hits/misses and make `hit_rate()` meaningless.
    /// `hit_rate()` keeps its historical semantics: full-column
    /// fetches through [`ColumnCache::get_into`] only.
    pub fn lookup(&mut self, i: usize) -> Option<&[f64]> {
        match self.map.get(&i).copied() {
            Some(slot) => {
                self.touch(slot);
                Some(&self.slots[slot].data)
            }
            None => None,
        }
    }

    /// Fetch column `i` into `out`, computing it with `fill` on a miss.
    pub fn get_into(
        &mut self,
        i: usize,
        out: &mut [f64],
        fill: impl FnOnce(&mut [f64]),
    ) {
        debug_assert_eq!(out.len(), self.n);
        if let Some(slot) = self.map.get(&i).copied() {
            self.touch(slot);
            out.copy_from_slice(&self.slots[slot].data);
            self.hits += 1;
            return;
        }
        self.misses += 1;
        fill(out);
        self.insert(i, out);
    }

    /// Drop column `i` from the cache (e.g. the row it is keyed by was
    /// removed or overwritten). The slot parks on the free list and is
    /// reused by the next insert, so no allocation churn. Returns
    /// whether the column was cached.
    pub fn invalidate(&mut self, i: usize) -> bool {
        match self.map.remove(&i) {
            Some(slot) => {
                self.unlink(slot);
                self.slots[slot].data.clear();
                self.free.push(slot);
                true
            }
            None => false,
        }
    }

    /// Drop every cached column. Required after any in-place row edit:
    /// row `j` contributes entry `j` of *every* column, so no cached
    /// column survives a row update exactly. Hit/miss counters keep
    /// their history (the columns were served correctly at the time).
    pub fn invalidate_all(&mut self) {
        self.map.clear();
        self.free.clear();
        for (slot, s) in self.slots.iter_mut().enumerate() {
            s.data.clear();
            s.prev = NIL;
            s.next = NIL;
            self.free.push(slot);
        }
        self.head = NIL;
        self.tail = NIL;
    }

    /// Insert a freshly computed column, reusing a freed slot when one
    /// is parked, else evicting the LRU column when at capacity. The
    /// evicted slot's buffer is reused in place.
    fn insert(&mut self, i: usize, data: &[f64]) {
        debug_assert!(!self.map.contains_key(&i));
        let slot = if let Some(slot) = self.free.pop() {
            self.slots[slot].col = i;
            self.slots[slot].data.clear();
            self.slots[slot].data.extend_from_slice(data);
            slot
        } else if self.slots.len() >= self.capacity_cols {
            let victim = self.tail;
            debug_assert_ne!(victim, NIL);
            self.unlink(victim);
            self.map.remove(&self.slots[victim].col);
            self.slots[victim].col = i;
            self.slots[victim].data.clear();
            self.slots[victim].data.extend_from_slice(data);
            victim
        } else {
            self.slots.push(Slot {
                col: i,
                prev: NIL,
                next: NIL,
                data: data.to_vec(),
            });
            self.slots.len() - 1
        };
        self.push_front(slot);
        self.map.insert(i, slot);
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Full-column fetches served from the cache.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Full-column fetches total (hits + misses) — the denominator of
    /// [`ColumnCache::hit_rate`], exported so callers can aggregate
    /// exact counts across solves instead of averaging rates.
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity_cols(&self) -> usize {
        self.capacity_cols
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill_with(v: f64) -> impl FnOnce(&mut [f64]) {
        move |out| out.iter_mut().for_each(|x| *x = v)
    }

    #[test]
    fn hit_after_miss() {
        let mut c = ColumnCache::new(4, 1024);
        let mut buf = vec![0.0; 4];
        c.get_into(0, &mut buf, fill_with(1.0));
        assert_eq!(buf, vec![1.0; 4]);
        // second fetch must not call fill
        c.get_into(0, &mut buf, |_| panic!("fill on hit"));
        assert_eq!(buf, vec![1.0; 4]);
        assert!(c.hit_rate() > 0.4);
    }

    #[test]
    fn evicts_lru_not_mru() {
        // budget of exactly 2 columns of n=2
        let mut c = ColumnCache::new(2, 2 * 2 * 8);
        let mut buf = vec![0.0; 2];
        c.get_into(0, &mut buf, fill_with(0.0));
        c.get_into(1, &mut buf, fill_with(1.0));
        c.get_into(0, &mut buf, |_| panic!("0 should be cached")); // refresh 0
        c.get_into(2, &mut buf, fill_with(2.0)); // evicts 1 (LRU)
        c.get_into(0, &mut buf, |_| panic!("0 must survive eviction"));
        let mut filled = false;
        c.get_into(1, &mut buf, |out| {
            filled = true;
            out.iter_mut().for_each(|x| *x = 9.0);
        });
        assert!(filled, "column 1 must have been evicted");
    }

    #[test]
    fn eviction_order_is_exact_lru_over_long_sequences() {
        // Capacity 3 (of 8 possible columns); drive a known access
        // pattern and check the exact victim at every eviction (the
        // O(1) list must agree with a reference recency order, not
        // just "evicts something old").
        let n = 8;
        let mut c = ColumnCache::new(n, 3 * n * 8);
        let mut buf = vec![0.0; n];
        let mut reference: Vec<usize> = Vec::new(); // front = LRU
        let mut accesses: Vec<usize> = Vec::new();
        // deterministic pseudo-random walk over 8 column indices
        let mut x = 9_usize;
        for _ in 0..200 {
            x = (x * 31 + 17) % 8;
            accesses.push(x);
        }
        for &i in &accesses {
            let was_cached = reference.contains(&i);
            if was_cached {
                c.get_into(i, &mut buf, |_| panic!("unexpected fill for {i}"));
                reference.retain(|&k| k != i);
            } else {
                if reference.len() == 3 {
                    reference.remove(0); // the LRU column must be the victim
                }
                let mut filled = false;
                c.get_into(i, &mut buf, |out| {
                    filled = true;
                    out.iter_mut().for_each(|v| *v = i as f64);
                });
                assert!(filled, "expected fill for {i}");
            }
            reference.push(i); // MRU at the back
            // cached set must equal the reference set at every step
            assert_eq!(c.len(), reference.len());
            for &k in &reference {
                assert!(c.map.contains_key(&k), "reference col {k} missing");
            }
        }
    }

    #[test]
    fn lookup_refreshes_recency_without_counting() {
        let mut c = ColumnCache::new(2, 2 * 2 * 8);
        let mut buf = vec![0.0; 2];
        assert!(c.lookup(0).is_none()); // probe miss: not inserted...
        assert_eq!(c.len(), 0);
        assert_eq!(c.hit_rate(), 0.0); // ...and not counted
        c.get_into(0, &mut buf, fill_with(7.0));
        c.get_into(1, &mut buf, fill_with(8.0));
        let rate_before = c.hit_rate();
        // lookup(0) refreshes 0, so inserting 2 must evict 1
        assert_eq!(c.lookup(0).unwrap(), &[7.0, 7.0]);
        assert_eq!(c.hit_rate(), rate_before, "probe must not count");
        c.get_into(2, &mut buf, fill_with(9.0));
        c.get_into(0, &mut buf, |_| panic!("0 must survive (refreshed)"));
        assert!(c.lookup(1).is_none(), "1 was LRU and must be gone");
    }

    #[test]
    fn capacity_at_least_one() {
        let c = ColumnCache::new(1_000_000, 1);
        assert_eq!(c.capacity_cols(), 1);
    }

    #[test]
    fn single_column_capacity_replaces_in_place() {
        let mut c = ColumnCache::new(2, 1);
        let mut buf = vec![0.0; 2];
        for i in 0..5 {
            c.get_into(i, &mut buf, fill_with(i as f64));
            assert_eq!(c.len(), 1);
            c.get_into(i, &mut buf, |_| panic!("just-inserted column must hit"));
        }
    }

    #[test]
    fn invalidate_evicts_on_remove_exactly() {
        // A mutated training row must never be served from a stale
        // column: after invalidate, the next fetch re-fills fresh bits.
        let mut c = ColumnCache::new(2, 2 * 2 * 8);
        let mut buf = vec![0.0; 2];
        c.get_into(0, &mut buf, fill_with(1.0));
        c.get_into(1, &mut buf, fill_with(2.0));
        assert!(c.invalidate(0), "column 0 was cached");
        assert!(!c.invalidate(0), "already gone");
        assert_eq!(c.len(), 1);
        let mut filled = false;
        c.get_into(0, &mut buf, |out| {
            filled = true;
            out.iter_mut().for_each(|x| *x = 7.0);
        });
        assert!(filled, "invalidated column must be recomputed");
        assert_eq!(buf, vec![7.0; 2]);
        // the survivor was untouched and still hits
        c.get_into(1, &mut buf, |_| panic!("1 must still be cached"));
        assert_eq!(buf, vec![2.0; 2]);
    }

    #[test]
    fn invalidate_frees_slot_for_reuse_within_budget() {
        // capacity 2: invalidate one, insert two — the freed slot is
        // reused (no arena growth) and the survivor is the LRU victim.
        let mut c = ColumnCache::new(2, 2 * 2 * 8);
        let mut buf = vec![0.0; 2];
        c.get_into(0, &mut buf, fill_with(0.0));
        c.get_into(1, &mut buf, fill_with(1.0));
        assert!(c.invalidate(0));
        c.get_into(2, &mut buf, fill_with(2.0)); // reuses the freed slot
        assert_eq!(c.slots.len(), 2, "arena must not grow past capacity");
        assert_eq!(c.len(), 2);
        c.get_into(3, &mut buf, fill_with(3.0)); // now a real eviction: victim is 1 (LRU)
        assert!(c.lookup(1).is_none(), "1 was LRU and must be evicted");
        c.get_into(2, &mut buf, |_| panic!("2 must survive"));
        c.get_into(3, &mut buf, |_| panic!("3 must survive"));
        assert_eq!(c.slots.len(), 2);
    }

    #[test]
    fn invalidate_all_then_refill_keeps_lru_chain_intact() {
        let n = 4;
        let mut c = ColumnCache::new(n, 3 * n * 8);
        let mut buf = vec![0.0; n];
        for i in 0..3 {
            c.get_into(i, &mut buf, fill_with(i as f64));
        }
        c.invalidate_all();
        assert!(c.is_empty());
        // every prior column must re-fill...
        for i in 0..3 {
            let mut filled = false;
            c.get_into(i, &mut buf, |out| {
                filled = true;
                out.iter_mut().for_each(|x| *x = 10.0 + i as f64);
            });
            assert!(filled, "column {i} must be recomputed after invalidate_all");
            assert_eq!(buf, vec![10.0 + i as f64; n]);
        }
        // ...and the rebuilt chain still evicts exact LRU
        c.get_into(0, &mut buf, |_| panic!("0 cached")); // refresh 0
        c.get_into(3, &mut buf, fill_with(3.0)); // evicts 1 (LRU)
        assert!(c.lookup(1).is_none(), "1 must be the eviction victim");
        c.get_into(0, &mut buf, |_| panic!("0 must survive"));
        c.get_into(2, &mut buf, |_| panic!("2 must survive"));
    }

    #[test]
    fn invalidate_head_and_tail_relink_correctly() {
        // remove the MRU then the LRU of a 3-chain; the middle node
        // must become both head and tail and keep working.
        let n = 2;
        let mut c = ColumnCache::new(n, 3 * n * 8);
        let mut buf = vec![0.0; n];
        c.get_into(0, &mut buf, fill_with(0.0)); // LRU
        c.get_into(1, &mut buf, fill_with(1.0));
        c.get_into(2, &mut buf, fill_with(2.0)); // MRU
        assert!(c.invalidate(2)); // drop head
        assert!(c.invalidate(0)); // drop tail
        assert_eq!(c.len(), 1);
        c.get_into(1, &mut buf, |_| panic!("middle column must survive"));
        // refill to capacity through the free list and evict once more
        c.get_into(3, &mut buf, fill_with(3.0));
        c.get_into(4, &mut buf, fill_with(4.0));
        assert_eq!(c.slots.len(), 3);
        c.get_into(5, &mut buf, fill_with(5.0)); // evicts 1 (LRU)
        assert!(c.lookup(1).is_none());
        c.get_into(3, &mut buf, |_| panic!("3 must survive"));
    }

    #[test]
    fn len_tracks_inserts() {
        let mut c = ColumnCache::new(2, 1024);
        assert!(c.is_empty());
        let mut buf = vec![0.0; 2];
        c.get_into(5, &mut buf, fill_with(5.0));
        assert_eq!(c.len(), 1);
    }
}
