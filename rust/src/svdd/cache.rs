//! LRU kernel-column cache, the same role as LIBSVM's `Cache` class.
//!
//! The SMO solver touches two kernel columns per iteration and revisits
//! the same (small) active set many times; caching columns converts the
//! per-iteration cost from O(n·m) kernel evaluations to an O(n) copy
//! for cached columns. The budget is expressed in bytes and evicts the
//! least-recently-used column. On a miss the `fill` closure provided by
//! [`crate::svdd::smo::LazyKernel`] computes the column as norm-cached
//! [`crate::svdd::Kernel::eval_block`] panels (in parallel chunks), so
//! cached and freshly computed columns carry identical bits regardless
//! of thread count.

use std::collections::HashMap;

/// LRU cache of `n`-length kernel columns keyed by column index.
pub struct ColumnCache {
    n: usize,
    capacity_cols: usize,
    map: HashMap<usize, (u64, Vec<f64>)>, // col -> (last-use tick, data)
    tick: u64,
    hits: u64,
    misses: u64,
}

impl ColumnCache {
    /// `budget_bytes` is rounded down to whole columns; at least one
    /// column is always cached.
    pub fn new(n: usize, budget_bytes: usize) -> Self {
        let col_bytes = (n * std::mem::size_of::<f64>()).max(1);
        let capacity_cols = (budget_bytes / col_bytes).max(1);
        ColumnCache {
            n,
            capacity_cols,
            map: HashMap::with_capacity(capacity_cols.min(1 << 20)),
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Fetch column `i` into `out`, computing it with `fill` on a miss.
    pub fn get_into(
        &mut self,
        i: usize,
        out: &mut [f64],
        fill: impl FnOnce(&mut [f64]),
    ) {
        debug_assert_eq!(out.len(), self.n);
        self.tick += 1;
        if let Some((t, col)) = self.map.get_mut(&i) {
            *t = self.tick;
            out.copy_from_slice(col);
            self.hits += 1;
            return;
        }
        self.misses += 1;
        fill(out);
        if self.map.len() >= self.capacity_cols {
            // evict LRU
            if let Some((&lru, _)) = self.map.iter().min_by_key(|(_, (t, _))| *t) {
                self.map.remove(&lru);
            }
        }
        self.map.insert(i, (self.tick, out.to_vec()));
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity_cols(&self) -> usize {
        self.capacity_cols
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill_with(v: f64) -> impl FnOnce(&mut [f64]) {
        move |out| out.iter_mut().for_each(|x| *x = v)
    }

    #[test]
    fn hit_after_miss() {
        let mut c = ColumnCache::new(4, 1024);
        let mut buf = vec![0.0; 4];
        c.get_into(0, &mut buf, fill_with(1.0));
        assert_eq!(buf, vec![1.0; 4]);
        // second fetch must not call fill
        c.get_into(0, &mut buf, |_| panic!("fill on hit"));
        assert_eq!(buf, vec![1.0; 4]);
        assert!(c.hit_rate() > 0.4);
    }

    #[test]
    fn evicts_lru_not_mru() {
        // budget of exactly 2 columns of n=2
        let mut c = ColumnCache::new(2, 2 * 2 * 8);
        let mut buf = vec![0.0; 2];
        c.get_into(0, &mut buf, fill_with(0.0));
        c.get_into(1, &mut buf, fill_with(1.0));
        c.get_into(0, &mut buf, |_| panic!("0 should be cached")); // refresh 0
        c.get_into(2, &mut buf, fill_with(2.0)); // evicts 1 (LRU)
        c.get_into(0, &mut buf, |_| panic!("0 must survive eviction"));
        let mut filled = false;
        c.get_into(1, &mut buf, |out| {
            filled = true;
            out.iter_mut().for_each(|x| *x = 9.0);
        });
        assert!(filled, "column 1 must have been evicted");
    }

    #[test]
    fn capacity_at_least_one() {
        let c = ColumnCache::new(1_000_000, 1);
        assert_eq!(c.capacity_cols(), 1);
    }

    #[test]
    fn len_tracks_inserts() {
        let mut c = ColumnCache::new(2, 1024);
        assert!(c.is_empty());
        let mut buf = vec![0.0; 2];
        c.get_into(5, &mut buf, fill_with(5.0));
        assert_eq!(c.len(), 1);
    }
}
