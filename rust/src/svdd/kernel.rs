//! Kernel functions (paper eq. (13) uses the Gaussian; linear recovers
//! the plain hypersphere of eq. (4); polynomial is included for
//! completeness of the substrate).
//!
//! Two evaluation paths:
//! - [`Kernel::eval`] — the scalar per-pair **reference** (re-derives
//!   `||a-b||^2` directly); kept for single-pair callers, goldens and
//!   the serial reference Gram.
//! - [`Kernel::eval_block`] / [`Kernel::eval_cached`] — the batched
//!   compute path over [`crate::linalg`]: cached row norms + the
//!   tile-blocked panel-dot microkernel (`eval_cached` is the
//!   single-pair spelling of a panel entry, for accumulator callers).
//!   Every hot loop (Gram, SMO columns, batch scoring) goes through
//!   these; per-entry values are a pure function of the two rows, so
//!   block outputs are bit-identical across panel shapes, entry points
//!   and thread counts (and agree with the scalar reference to
//!   ULP-level relative tolerance).

use crate::linalg::{self, NormCache};
use crate::util::matrix::Matrix;

/// A positive-definite kernel K(a, b).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Kernel {
    /// `exp(-||a-b||^2 / (2 s^2))` — the paper's kernel. `bw` is the
    /// Gaussian bandwidth parameter `s`.
    Gaussian { bw: f64 },
    /// `a . b` — recovers the primal minimum-enclosing-ball description.
    Linear,
    /// `(a . b + coef)^degree`.
    Polynomial { degree: u32, coef: f64 },
}

impl Kernel {
    pub fn gaussian(bw: f64) -> Kernel {
        assert!(bw > 0.0, "bandwidth must be positive, got {bw}");
        Kernel::Gaussian { bw }
    }

    /// Validated polynomial-kernel constructor. The exponent is applied
    /// via `powi(degree as i32)`, so a degree above `i32::MAX` would
    /// silently wrap to a *negative* exponent — reject it here (along
    /// with the degenerate degree 0 and a non-finite coefficient), the
    /// same way [`Kernel::gaussian`] rejects a non-positive bandwidth.
    pub fn polynomial(degree: u32, coef: f64) -> Kernel {
        assert!(degree >= 1, "polynomial degree must be >= 1, got {degree}");
        assert!(
            degree <= i32::MAX as u32,
            "polynomial degree {degree} overflows the i32 exponent of powi"
        );
        assert!(coef.is_finite(), "polynomial coef must be finite, got {coef}");
        Kernel::Polynomial { degree, coef }
    }

    /// Evaluate K(a, b).
    #[inline]
    pub fn eval(&self, a: &[f64], b: &[f64]) -> f64 {
        match *self {
            Kernel::Gaussian { bw } => {
                let d2 = Matrix::sqdist(a, b);
                (-d2 / (2.0 * bw * bw)).exp()
            }
            Kernel::Linear => dot(a, b),
            Kernel::Polynomial { degree, coef } => (dot(a, b) + coef).powi(degree as i32),
        }
    }

    /// Batch-evaluate `K(a_i, b_j)` for `i` in `a_rows`, `j` in
    /// `b_rows` into `out` (row-major `a_rows.len() x b_rows.len()`),
    /// from cached squared row norms and a tile-blocked panel of dots.
    ///
    /// Per-entry values are a pure function of the two rows (see
    /// [`crate::linalg`]'s determinism policy): the same pair evaluates
    /// to the same bits in a 1x1 panel, a Gram row panel, an SMO column
    /// chunk or a scoring batch — which is what keeps parallel outputs
    /// bit-identical at any thread count. `eval_block(i, j)` equals
    /// `eval_block(j, i)` exactly; it matches the scalar [`Kernel::eval`]
    /// reference to ULP-level relative tolerance only.
    #[allow(clippy::too_many_arguments)]
    pub fn eval_block(
        &self,
        a: &Matrix,
        a_norms: &NormCache,
        a_rows: std::ops::Range<usize>,
        b: &Matrix,
        b_norms: &NormCache,
        b_rows: std::ops::Range<usize>,
        out: &mut [f64],
    ) {
        let (la, lb) = (a_rows.len(), b_rows.len());
        debug_assert_eq!(out.len(), la * lb);
        if la == 0 || lb == 0 {
            return;
        }
        linalg::dot_block(a, a_rows.clone(), b, b_rows.clone(), out);
        if matches!(self, Kernel::Linear) {
            return; // linear kernel IS the dot panel
        }
        for (ia, row) in out.chunks_mut(lb).enumerate() {
            let na = a_norms.get(a_rows.start + ia);
            for (jb, slot) in row.iter_mut().enumerate() {
                let nb = b_norms.get(b_rows.start + jb);
                *slot = self.finish(*slot, na, nb);
            }
        }
    }

    /// One pair on the block path: `K(a, z)` from cached squared norms
    /// — the scalar spelling of an [`Kernel::eval_block`] entry
    /// (identical bits: the same [`linalg::dot`] and the same
    /// norm-cache combination). For callers that fold kernel values
    /// into an accumulator and must not pay a panel buffer per
    /// observation (single-row [`crate::svdd::SvddModel::dist2`]
    /// scoring). Not a replacement for the scalar reference
    /// [`Kernel::eval`], which derives `||a-z||^2` without norms.
    #[inline]
    pub fn eval_cached(&self, a: &[f64], a_norm: f64, z: &[f64], z_norm: f64) -> f64 {
        let d = linalg::dot(a, z);
        match *self {
            Kernel::Linear => d,
            _ => self.finish(d, a_norm, z_norm),
        }
    }

    /// Map a panel dot (+ the two cached norms) to the kernel value —
    /// the single definition every block entry point shares.
    #[inline]
    fn finish(&self, d: f64, na: f64, nb: f64) -> f64 {
        match *self {
            Kernel::Gaussian { bw } => {
                let d2 = linalg::sqdist_from_norms(na, nb, d);
                (-d2 / (2.0 * bw * bw)).exp()
            }
            Kernel::Linear => d,
            Kernel::Polynomial { degree, coef } => (d + coef).powi(degree as i32),
        }
    }

    /// f32 mirror of the private `finish` combination — the opt-in
    /// `--precision f32` panel path (same algebraic form, kernel
    /// parameters narrowed once per call site). Tolerance-only contract
    /// vs the f64 path; see [`crate::linalg`]'s f32 section for the
    /// error bound.
    #[inline]
    pub fn finish_f32(&self, d: f32, na: f32, nb: f32) -> f32 {
        match *self {
            Kernel::Gaussian { bw } => {
                let d2 = linalg::sqdist_from_norms_f32(na, nb, d);
                let bw = bw as f32;
                (-d2 / (2.0 * bw * bw)).exp()
            }
            Kernel::Linear => d,
            Kernel::Polynomial { degree, coef } => (d + coef as f32).powi(degree as i32),
        }
    }

    /// f32 mirror of [`Kernel::eval_block`] over flat row-major buffers
    /// (`a`: `ra x cols`, `b`: `rb x cols`, full `ra x rb` product into
    /// `out`): [`linalg::dot_block_f32`] panels finished with
    /// [`Kernel::finish_f32`]. Per-entry purity (and so bit-identity
    /// across chunk shapes and thread counts *within* f32) holds
    /// exactly as on the f64 path.
    pub fn eval_block_f32(
        &self,
        a: &[f32],
        a_norms: &[f32],
        b: &[f32],
        b_norms: &[f32],
        cols: usize,
        out: &mut [f32],
    ) {
        if cols == 0 || a.is_empty() || b.is_empty() {
            return;
        }
        let rb = b.len() / cols;
        linalg::dot_block_f32(a, b, cols, out);
        if matches!(self, Kernel::Linear) {
            return; // linear kernel IS the dot panel
        }
        for (ia, row) in out.chunks_mut(rb).enumerate() {
            let na = a_norms[ia];
            for (jb, slot) in row.iter_mut().enumerate() {
                *slot = self.finish_f32(*slot, na, b_norms[jb]);
            }
        }
    }

    /// f32 mirror of [`Kernel::diag_from_norm`] (same Gaussian
    /// constant-1 policy).
    #[inline]
    pub fn diag_from_norm_f32(&self, norm: f32) -> f32 {
        match *self {
            Kernel::Gaussian { .. } => 1.0,
            _ => self.finish_f32(norm, norm, norm),
        }
    }

    /// K(x, x) without touching a second row.
    #[inline]
    pub fn diag(&self, x: &[f64]) -> f64 {
        match *self {
            Kernel::Gaussian { .. } => 1.0,
            Kernel::Linear => dot(x, x),
            Kernel::Polynomial { degree, coef } => (dot(x, x) + coef).powi(degree as i32),
        }
    }

    /// K(x, x) from the cached squared norm `||x||^2` — the block-path
    /// spelling of [`Kernel::diag`] (`dot(x, x) == ||x||^2`, so this is
    /// `finish(n, n, n)`). Block call sites use this so their diagonal
    /// agrees bitwise with their off-diagonal entries even for the
    /// linear/polynomial kernels, whose diag depends on the dot's
    /// summation order. The Gaussian diagonal is the constant 1 (like
    /// [`Kernel::diag`]) rather than `exp(-0)` — the same bits for
    /// every finite norm, and it keeps `K(z, z) = 1` even for a query
    /// row whose norm overflowed to infinity.
    #[inline]
    pub fn diag_from_norm(&self, norm: f64) -> f64 {
        match *self {
            Kernel::Gaussian { .. } => 1.0,
            _ => self.finish(norm, norm, norm),
        }
    }

    /// The Gaussian bandwidth, if this is a Gaussian kernel.
    pub fn bw(&self) -> Option<f64> {
        match *self {
            Kernel::Gaussian { bw } => Some(bw),
            _ => None,
        }
    }

    /// Whether K(x, x) is the constant 1 (lets the scorer skip work).
    pub fn unit_diag(&self) -> bool {
        matches!(self, Kernel::Gaussian { .. })
    }
}

#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

impl std::fmt::Display for Kernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            Kernel::Gaussian { bw } => write!(f, "gaussian(s={bw})"),
            Kernel::Linear => write!(f, "linear"),
            Kernel::Polynomial { degree, coef } => write!(f, "poly(d={degree},c={coef})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaussian_properties() {
        let k = Kernel::gaussian(1.0);
        let a = [0.0, 0.0];
        let b = [3.0, 4.0];
        assert_eq!(k.eval(&a, &a), 1.0);
        assert!((k.eval(&a, &b) - (-12.5f64).exp()).abs() < 1e-15);
        // symmetry
        assert_eq!(k.eval(&a, &b), k.eval(&b, &a));
        assert_eq!(k.diag(&b), 1.0);
        assert!(k.unit_diag());
    }

    #[test]
    fn gaussian_bandwidth_scales() {
        let near = Kernel::gaussian(0.5);
        let wide = Kernel::gaussian(5.0);
        let a = [0.0];
        let b = [1.0];
        assert!(near.eval(&a, &b) < wide.eval(&a, &b));
    }

    #[test]
    #[should_panic]
    fn gaussian_rejects_nonpositive_bw() {
        Kernel::gaussian(0.0);
    }

    #[test]
    fn linear_is_dot() {
        let k = Kernel::Linear;
        assert_eq!(k.eval(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert_eq!(k.diag(&[3.0, 4.0]), 25.0);
        assert!(!k.unit_diag());
    }

    #[test]
    fn polynomial_eval() {
        let k = Kernel::Polynomial { degree: 2, coef: 1.0 };
        assert_eq!(k.eval(&[1.0], &[2.0]), 9.0);
        assert_eq!(k.diag(&[2.0]), 25.0);
    }

    #[test]
    fn polynomial_constructor_accepts_valid() {
        let k = Kernel::polynomial(3, 0.5);
        assert_eq!(k, Kernel::Polynomial { degree: 3, coef: 0.5 });
        assert_eq!(Kernel::polynomial(i32::MAX as u32, 0.0).eval(&[1.0], &[1.0]), 1.0);
    }

    #[test]
    #[should_panic]
    fn polynomial_rejects_degree_zero() {
        Kernel::polynomial(0, 1.0);
    }

    #[test]
    #[should_panic]
    fn polynomial_rejects_degree_overflowing_i32() {
        // powi takes i32: degree > i32::MAX would wrap to a negative
        // exponent and silently invert the kernel
        Kernel::polynomial(i32::MAX as u32 + 1, 1.0);
    }

    #[test]
    #[should_panic]
    fn polynomial_rejects_non_finite_coef() {
        Kernel::polynomial(2, f64::NAN);
    }

    #[test]
    fn eval_block_matches_scalar_eval_closely() {
        let a = Matrix::from_rows(&[
            vec![0.3, -1.2, 0.8],
            vec![1.0, 0.0, -0.5],
            vec![-2.0, 0.7, 0.1],
        ])
        .unwrap();
        let b = Matrix::from_rows(&[vec![0.0, 0.1, 0.2], vec![1.5, -0.4, 0.9]]).unwrap();
        let (an, bn) = (NormCache::new(&a), NormCache::new(&b));
        for k in [
            Kernel::gaussian(0.7),
            Kernel::Linear,
            Kernel::polynomial(3, 1.0),
        ] {
            let mut out = vec![0.0; 6];
            k.eval_block(&a, &an, 0..3, &b, &bn, 0..2, &mut out);
            for i in 0..3 {
                for j in 0..2 {
                    let want = k.eval(a.row(i), b.row(j));
                    let got = out[i * 2 + j];
                    assert!(
                        (got - want).abs() <= 1e-12 * want.abs().max(1.0),
                        "{k} ({i},{j}): {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn eval_cached_matches_eval_block_column_bitwise() {
        let a = Matrix::from_rows(&[
            vec![0.3, -1.2, 0.8, 2.0],
            vec![1.0, 0.0, -0.5, -1.0],
            vec![-2.0, 0.7, 0.1, 0.4],
        ])
        .unwrap();
        let z = [0.9, -0.2, 1.1, 0.0];
        let zm = Matrix::from_rows(&[z.to_vec()]).unwrap();
        let (an, zn_cache) = (NormCache::new(&a), NormCache::new(&zm));
        let zn = crate::linalg::dot(&z, &z);
        assert_eq!(zn.to_bits(), zn_cache.get(0).to_bits());
        for k in [
            Kernel::gaussian(1.3),
            Kernel::Linear,
            Kernel::polynomial(2, 0.5),
        ] {
            let mut block = vec![0.0; 3];
            k.eval_block(&a, &an, 0..3, &zm, &zn_cache, 0..1, &mut block);
            for i in 0..3 {
                let got = k.eval_cached(a.row(i), an.get(i), &z, zn);
                assert_eq!(got.to_bits(), block[i].to_bits(), "{k} row {i}");
            }
        }
    }

    #[test]
    fn non_finite_inputs_never_score_as_identical() {
        // a finite row whose squared norm overflows to +inf must look
        // astronomically FAR on the block path (K -> 0), exactly like
        // the scalar reference — never K = 1 via a swallowed NaN
        let k = Kernel::gaussian(1.0);
        let huge = [1e200, -1e200];
        let normal = [1.0, 2.0];
        let (nh, nn) = (linalg::dot(&huge, &huge), linalg::dot(&normal, &normal));
        assert!(nh.is_infinite());
        let got = k.eval_cached(&huge, nh, &normal, nn);
        assert_eq!(got, 0.0);
        assert_eq!(got, k.eval(&huge, &normal));
        // K(z, z) of the huge row stays 1 on the diag path (scalar
        // semantics), so dist2 = 1 - 0 + w correctly lands outside
        assert_eq!(k.diag_from_norm(nh), 1.0);
        // true NaN input propagates rather than clamping to "identical"
        let nan_row = [f64::NAN, 1.0];
        let nnan = linalg::dot(&nan_row, &nan_row);
        assert!(k.eval_cached(&nan_row, nnan, &normal, nn).is_nan());
    }

    #[test]
    fn diag_from_norm_matches_block_self_eval() {
        let a = Matrix::from_rows(&[vec![1.0, -2.0, 0.5], vec![0.0, 0.0, 0.0]]).unwrap();
        let an = NormCache::new(&a);
        for k in [
            Kernel::gaussian(0.9),
            Kernel::Linear,
            Kernel::polynomial(4, 1.5),
        ] {
            for i in 0..2 {
                let mut out = [0.0];
                k.eval_block(&a, &an, i..i + 1, &a, &an, i..i + 1, &mut out);
                assert_eq!(
                    k.diag_from_norm(an.get(i)).to_bits(),
                    out[0].to_bits(),
                    "{k} row {i}"
                );
            }
        }
    }

    #[test]
    fn f32_block_path_tracks_f64_within_tolerance() {
        let a = Matrix::from_rows(&[
            vec![0.3, -1.2, 0.8, 0.1, 2.2],
            vec![1.0, 0.0, -0.5, 0.9, -1.1],
            vec![-2.0, 0.7, 0.1, -0.3, 0.6],
        ])
        .unwrap();
        let b = Matrix::from_rows(&[
            vec![0.0, 0.1, 0.2, -0.8, 1.4],
            vec![1.5, -0.4, 0.9, 0.2, -0.7],
        ])
        .unwrap();
        let (an, bn) = (NormCache::new(&a), NormCache::new(&b));
        let (af, bf) = (a.to_f32(), b.to_f32());
        let anf = linalg::norms_f32(&af, a.cols());
        let bnf = linalg::norms_f32(&bf, b.cols());
        for k in [
            Kernel::gaussian(0.7),
            Kernel::Linear,
            Kernel::polynomial(3, 1.0),
        ] {
            let mut want = vec![0.0f64; 6];
            k.eval_block(&a, &an, 0..3, &b, &bn, 0..2, &mut want);
            let mut got = vec![0.0f32; 6];
            k.eval_block_f32(&af, &anf, &bf, &bnf, a.cols(), &mut got);
            for (g, w) in got.iter().zip(&want) {
                assert!(
                    (*g as f64 - w).abs() <= 1e-5 * w.abs().max(1.0),
                    "{k}: {g} vs {w}"
                );
            }
            // Gaussian unit diagonal survives narrowing exactly
            if k.unit_diag() {
                assert_eq!(k.diag_from_norm_f32(anf[0]), 1.0);
            }
        }
    }

    #[test]
    fn gaussian_psd_on_random_points() {
        // 3x3 gram of distinct points must be PSD: check det of leading
        // minors > 0 (Sylvester) for a hand-picked configuration.
        let k = Kernel::gaussian(1.3);
        let pts = [[0.0, 0.0], [1.0, 0.2], [-0.4, 0.9]];
        let mut g = [[0.0; 3]; 3];
        for i in 0..3 {
            for j in 0..3 {
                g[i][j] = k.eval(&pts[i], &pts[j]);
            }
        }
        let d1 = g[0][0];
        let d2 = g[0][0] * g[1][1] - g[0][1] * g[1][0];
        let d3 = g[0][0] * (g[1][1] * g[2][2] - g[1][2] * g[2][1])
            - g[0][1] * (g[1][0] * g[2][2] - g[1][2] * g[2][0])
            + g[0][2] * (g[1][0] * g[2][1] - g[1][1] * g[2][0]);
        assert!(d1 > 0.0 && d2 > 0.0 && d3 > 0.0);
    }
}
