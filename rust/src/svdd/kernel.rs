//! Kernel functions (paper eq. (13) uses the Gaussian; linear recovers
//! the plain hypersphere of eq. (4); polynomial is included for
//! completeness of the substrate).

use crate::util::matrix::Matrix;

/// A positive-definite kernel K(a, b).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Kernel {
    /// `exp(-||a-b||^2 / (2 s^2))` — the paper's kernel. `bw` is the
    /// Gaussian bandwidth parameter `s`.
    Gaussian { bw: f64 },
    /// `a . b` — recovers the primal minimum-enclosing-ball description.
    Linear,
    /// `(a . b + coef)^degree`.
    Polynomial { degree: u32, coef: f64 },
}

impl Kernel {
    pub fn gaussian(bw: f64) -> Kernel {
        assert!(bw > 0.0, "bandwidth must be positive, got {bw}");
        Kernel::Gaussian { bw }
    }

    /// Evaluate K(a, b).
    #[inline]
    pub fn eval(&self, a: &[f64], b: &[f64]) -> f64 {
        match *self {
            Kernel::Gaussian { bw } => {
                let d2 = Matrix::sqdist(a, b);
                (-d2 / (2.0 * bw * bw)).exp()
            }
            Kernel::Linear => dot(a, b),
            Kernel::Polynomial { degree, coef } => (dot(a, b) + coef).powi(degree as i32),
        }
    }

    /// K(x, x) without touching a second row.
    #[inline]
    pub fn diag(&self, x: &[f64]) -> f64 {
        match *self {
            Kernel::Gaussian { .. } => 1.0,
            Kernel::Linear => dot(x, x),
            Kernel::Polynomial { degree, coef } => (dot(x, x) + coef).powi(degree as i32),
        }
    }

    /// The Gaussian bandwidth, if this is a Gaussian kernel.
    pub fn bw(&self) -> Option<f64> {
        match *self {
            Kernel::Gaussian { bw } => Some(bw),
            _ => None,
        }
    }

    /// Whether K(x, x) is the constant 1 (lets the scorer skip work).
    pub fn unit_diag(&self) -> bool {
        matches!(self, Kernel::Gaussian { .. })
    }
}

#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

impl std::fmt::Display for Kernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            Kernel::Gaussian { bw } => write!(f, "gaussian(s={bw})"),
            Kernel::Linear => write!(f, "linear"),
            Kernel::Polynomial { degree, coef } => write!(f, "poly(d={degree},c={coef})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaussian_properties() {
        let k = Kernel::gaussian(1.0);
        let a = [0.0, 0.0];
        let b = [3.0, 4.0];
        assert_eq!(k.eval(&a, &a), 1.0);
        assert!((k.eval(&a, &b) - (-12.5f64).exp()).abs() < 1e-15);
        // symmetry
        assert_eq!(k.eval(&a, &b), k.eval(&b, &a));
        assert_eq!(k.diag(&b), 1.0);
        assert!(k.unit_diag());
    }

    #[test]
    fn gaussian_bandwidth_scales() {
        let near = Kernel::gaussian(0.5);
        let wide = Kernel::gaussian(5.0);
        let a = [0.0];
        let b = [1.0];
        assert!(near.eval(&a, &b) < wide.eval(&a, &b));
    }

    #[test]
    #[should_panic]
    fn gaussian_rejects_nonpositive_bw() {
        Kernel::gaussian(0.0);
    }

    #[test]
    fn linear_is_dot() {
        let k = Kernel::Linear;
        assert_eq!(k.eval(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert_eq!(k.diag(&[3.0, 4.0]), 25.0);
        assert!(!k.unit_diag());
    }

    #[test]
    fn polynomial_eval() {
        let k = Kernel::Polynomial { degree: 2, coef: 1.0 };
        assert_eq!(k.eval(&[1.0], &[2.0]), 9.0);
        assert_eq!(k.diag(&[2.0]), 25.0);
    }

    #[test]
    fn gaussian_psd_on_random_points() {
        // 3x3 gram of distinct points must be PSD: check det of leading
        // minors > 0 (Sylvester) for a hand-picked configuration.
        let k = Kernel::gaussian(1.3);
        let pts = [[0.0, 0.0], [1.0, 0.2], [-0.4, 0.9]];
        let mut g = [[0.0; 3]; 3];
        for i in 0..3 {
            for j in 0..3 {
                g[i][j] = k.eval(&pts[i], &pts[j]);
            }
        }
        let d1 = g[0][0];
        let d2 = g[0][0] * g[1][1] - g[0][1] * g[1][0];
        let d3 = g[0][0] * (g[1][1] * g[2][2] - g[1][2] * g[2][1])
            - g[0][1] * (g[1][0] * g[2][2] - g[1][2] * g[2][0])
            + g[0][2] * (g[1][0] * g[2][1] - g[1][1] * g[2][0]);
        assert!(d1 > 0.0 && d2 > 0.0 && d3 > 0.0);
    }
}
