//! SMO solver for the SVDD dual (paper eqs. (14)–(16)):
//!
//! ```text
//! min  f(a) = a' K a - sum_i a_i K_ii
//! s.t. sum_i a_i = 1,   0 <= a_i <= C,   C = 1 / (n f)
//! ```
//!
//! (The paper states the equivalent maximization.) Working-set selection
//! is the classic maximal-violating-pair rule (LIBSVM WSS1): with
//! gradient `g_i = 2 (K a)_i - K_ii`, the KKT conditions say there is a
//! multiplier `lambda` with `g_i >= lambda` when `a_i = 0`,
//! `g_i <= lambda` when `a_i = C`, and `g_i = lambda` inside. The most
//! violating pair is `i = argmin{ g_i : a_i < C }`,
//! `j = argmax{ g_j : a_j > 0 }`; optimality gap is `g_j - g_i`.
//!
//! The pair sub-problem moves mass `delta` from `j` to `i`:
//! `delta = (g_j - g_i) / (2 (K_ii + K_jj - 2 K_ij))`, clipped to the
//! box `[0, min(C - a_i, a_j)]`, followed by a rank-1 gradient update
//! `g += 2 delta (K[:,i] - K[:,j])`.

use crate::error::{Error, Result};
use crate::linalg::NormCache;
use crate::parallel::Pool;
use crate::svdd::cache::ColumnCache;
use crate::svdd::kernel::Kernel;
use crate::util::matrix::Matrix;

/// Rows per parallel chunk when evaluating a kernel column.
const COL_CHUNK: usize = 512;

/// Column evaluation runs inside the SMO inner loop (up to three
/// columns per pair iteration on a cache miss), so a scoped-thread
/// spawn must be amortized over much more math than a one-shot region:
/// require ~0.5M scalar ops (roughly a millisecond of kernel
/// arithmetic) before going parallel. A 20k x 41 Tennessee solve
/// clears this; a 20k x 2 banana column stays serial, where it is
/// faster anyway.
const COL_PAR_MIN_WORK: usize = 1 << 19;

/// Abstract access to the kernel matrix so the solver runs both on
/// lazily computed kernels (large full-SVDD solves, LRU-cached) and on
/// dense gram matrices produced by the XLA `gram` artifact (the
/// Algorithm-1 sample solves).
pub trait KernelProvider {
    fn n(&self) -> usize;
    /// K(x_i, x_i).
    fn diag(&self, i: usize) -> f64;
    /// Copy column `i` (== row `i`; kernels are symmetric) into `out`.
    fn col_into(&mut self, i: usize, out: &mut [f64]);
}

/// Lazily evaluated kernel over a data matrix with an LRU column cache.
/// Column evaluation on a cache miss runs as [`Kernel::eval_block`]
/// panels (squared row norms cached once at construction) in parallel
/// chunks on the pool; each entry is a pure function of its two rows,
/// so the column is bit-identical to the serial evaluation at any
/// thread count, and bit-identical to the corresponding
/// [`DenseKernel::from_data`] Gram entries.
pub struct LazyKernel<'a> {
    data: &'a Matrix,
    kernel: Kernel,
    norms: NormCache,
    cache: ColumnCache,
    diag: Vec<f64>,
    pool: Option<Pool>,
}

impl<'a> LazyKernel<'a> {
    pub fn new(data: &'a Matrix, kernel: Kernel, cache_bytes: usize) -> Self {
        let norms = NormCache::new(data);
        // block-path diag, so K_ii agrees bitwise with the off-diagonal
        // entries the column panels produce
        let diag = norms.as_slice().iter().map(|&n| kernel.diag_from_norm(n)).collect();
        LazyKernel {
            data,
            kernel,
            norms,
            cache: ColumnCache::new(data.rows(), cache_bytes),
            diag,
            pool: None,
        }
    }

    /// Pin column evaluation to an explicit pool instead of the global
    /// one (tests, benches).
    pub fn with_pool(mut self, pool: Pool) -> Self {
        self.pool = Some(pool);
        self
    }

    pub fn cache_hit_rate(&self) -> f64 {
        self.cache.hit_rate()
    }
}

impl<'a> KernelProvider for LazyKernel<'a> {
    fn n(&self) -> usize {
        self.data.rows()
    }

    fn diag(&self, i: usize) -> f64 {
        self.diag[i]
    }

    fn col_into(&mut self, i: usize, out: &mut [f64]) {
        let data = self.data;
        let kernel = self.kernel;
        let norms = &self.norms;
        // An explicitly pinned pool (`with_pool`) is used as-is — the
        // caller took control, and the determinism tests rely on it to
        // force parallel columns on small problems. The global pool is
        // cost-gated at COL_PAR_MIN_WORK.
        let pool = match self.pool {
            Some(p) => p,
            None => crate::parallel::global(),
        };
        let gated = self.pool.is_none();
        self.cache.get_into(i, out, |buf| {
            let work = buf.len() * data.cols().max(1);
            let run = if gated && work < COL_PAR_MIN_WORK { Pool::serial() } else { pool };
            run.run_chunks(buf, COL_CHUNK, |start, chunk| {
                let end = start + chunk.len();
                kernel.eval_block(data, norms, i..i + 1, data, norms, start..end, chunk);
            });
        });
    }
}

/// Dense precomputed kernel matrix (row-major n*n). This is what the
/// XLA gram artifact feeds the sample solves with.
pub struct DenseKernel {
    n: usize,
    k: Vec<f64>,
}

impl DenseKernel {
    pub fn new(k: Vec<f64>, n: usize) -> Result<Self> {
        if k.len() != n * n {
            return Err(Error::invalid(format!(
                "dense kernel: {} values for n={n}",
                k.len()
            )));
        }
        Ok(DenseKernel { n, k })
    }

    /// Compute the full gram matrix natively on the batched kernel
    /// layer ([`crate::parallel::gram`]: norm-cached
    /// [`Kernel::eval_block`] row panels), in parallel on the global
    /// pool. Bit-identical at any thread count; agrees with the scalar
    /// reference [`DenseKernel::from_data_serial`] to ULP-level relative
    /// tolerance (the block path uses a different summation order).
    pub fn from_data(data: &Matrix, kernel: Kernel) -> Self {
        Self::from_data_pooled(data, kernel, crate::parallel::global())
    }

    /// [`DenseKernel::from_data`] on an explicit pool.
    pub fn from_data_pooled(data: &Matrix, kernel: Kernel, pool: Pool) -> Self {
        DenseKernel {
            n: data.rows(),
            k: crate::parallel::gram(data, kernel, pool),
        }
    }

    /// Single-threaded upper-triangle + mirror computation via the
    /// scalar [`Kernel::eval`] — the **scalar reference path** the
    /// block layer is property-tested against. Not used on any hot
    /// path; kept as the independent oracle.
    pub fn from_data_serial(data: &Matrix, kernel: Kernel) -> Self {
        let n = data.rows();
        let mut k = vec![0.0; n * n];
        for i in 0..n {
            for j in i..n {
                let v = kernel.eval(data.row(i), data.row(j));
                k[i * n + j] = v;
                k[j * n + i] = v;
            }
        }
        DenseKernel { n, k }
    }

    /// Row-major flat view of the kernel matrix.
    pub fn as_slice(&self) -> &[f64] {
        &self.k
    }
}

impl KernelProvider for DenseKernel {
    fn n(&self) -> usize {
        self.n
    }

    fn diag(&self, i: usize) -> f64 {
        self.k[i * self.n + i]
    }

    fn col_into(&mut self, i: usize, out: &mut [f64]) {
        out.copy_from_slice(&self.k[i * self.n..(i + 1) * self.n]);
    }
}

/// Solver options.
#[derive(Clone, Copy, Debug)]
pub struct SmoOptions {
    /// KKT violation tolerance (stopping threshold on `g_j - g_i`).
    pub tol: f64,
    /// Hard cap on pair iterations (scaled guard; the solver normally
    /// stops on the gap long before this).
    pub max_iter: usize,
    /// alpha values below this are treated as zero when extracting SVs.
    pub sv_eps: f64,
}

impl Default for SmoOptions {
    fn default() -> Self {
        SmoOptions { tol: 1e-6, max_iter: 0, sv_eps: 1e-9 }
    }
}

/// Solution of the dual problem.
#[derive(Clone, Debug)]
pub struct SmoSolution {
    /// Dual variables, length n, summing to 1.
    pub alpha: Vec<f64>,
    /// Final gradient `g_i = 2 (K a)_i - K_ii` (used for R^2).
    pub gradient: Vec<f64>,
    /// `a' K a` at the solution.
    pub quad: f64,
    /// Squared threshold radius (mean over boundary SVs; see below).
    pub r2: f64,
    /// Pair iterations executed.
    pub iterations: usize,
    /// Final optimality gap.
    pub gap: f64,
}

impl SmoSolution {
    /// Indices with `alpha > sv_eps` — the support vectors.
    pub fn sv_indices(&self, sv_eps: f64) -> Vec<usize> {
        (0..self.alpha.len())
            .filter(|&i| self.alpha[i] > sv_eps)
            .collect()
    }
}

/// Solve the SVDD dual by SMO. `c` is the box bound `C = 1/(n f)`.
pub fn solve(kp: &mut dyn KernelProvider, c: f64, opts: &SmoOptions) -> Result<SmoSolution> {
    let n = kp.n();
    if n == 0 {
        return Err(Error::invalid("SMO: empty problem"));
    }
    if c * (n as f64) < 1.0 - 1e-12 {
        return Err(Error::Solver(format!(
            "infeasible: n*C = {} < 1 (f > 1?)",
            c * n as f64
        )));
    }
    // Feasible start. Two regimes:
    // - small problems (the Algorithm-1 sample/union solves): uniform
    //   alpha = 1/n starts near the solution and the O(n^2 m) gradient
    //   init is trivial;
    // - large problems: concentrate the mass on the first ceil(1/C)
    //   points (the LIBSVM one-class init) so the initial gradient
    //   needs only those columns — O(k n m) instead of O(n^2 m), which
    //   otherwise dominates total time.
    const UNIFORM_INIT_MAX_N: usize = 256;
    let mut alpha = vec![0.0; n];
    if n <= UNIFORM_INIT_MAX_N {
        for a in &mut alpha {
            *a = 1.0 / n as f64;
        }
    } else {
        let mut remaining: f64 = 1.0;
        let mut i = 0;
        while remaining > 0.0 && i < n {
            let a = remaining.min(c);
            alpha[i] = a;
            remaining -= a;
            i += 1;
        }
    }

    // g_i = 2 (K a)_i - K_ii from the nonzero-alpha columns only (for
    // the uniform start that is every column; for the concentrated
    // start just the first ceil(1/C)).
    let mut g: Vec<f64> = (0..n).map(|i| -kp.diag(i)).collect();
    let mut col = vec![0.0; n];
    for j in 0..n {
        if alpha[j] <= 0.0 {
            continue;
        }
        kp.col_into(j, &mut col);
        let two_aj = 2.0 * alpha[j];
        for k in 0..n {
            g[k] += two_aj * col[k];
        }
    }

    // Index set { k : alpha_k > 0 }, maintained incrementally so the
    // second-order j-scan is O(|positive|), not O(n).
    let mut pos: Vec<usize> = (0..n).filter(|&k| alpha[k] > 0.0).collect();
    let mut pos_slot: Vec<usize> = vec![usize::MAX; n];
    for (slot, &k) in pos.iter().enumerate() {
        pos_slot[k] = slot;
    }

    let max_iter = if opts.max_iter > 0 {
        opts.max_iter
    } else {
        (100 * n).max(10_000)
    };

    let mut col_i = vec![0.0; n];
    let mut col_j = vec![0.0; n];
    let mut iterations = 0;
    let mut gap = f64::INFINITY;

    // i-candidate (argmin g over alpha < C) is maintained across
    // iterations by fusing the scan with the rank-1 gradient update.
    let mut i_sel = usize::MAX;
    let mut g_min = f64::INFINITY;
    for k in 0..n {
        if alpha[k] < c - 1e-14 && g[k] < g_min {
            g_min = g[k];
            i_sel = k;
        }
    }

    for it in 0..max_iter {
        iterations = it;
        // --- optimality gap: max g over the positive set ---
        let mut g_max = f64::NEG_INFINITY;
        for &k in &pos {
            if g[k] > g_max {
                g_max = g[k];
            }
        }
        gap = g_max - g_min;
        if i_sel == usize::MAX || pos.is_empty() || gap < opts.tol {
            break;
        }

        // --- second-order pick of j (LIBSVM WSS2): maximize the
        // objective decrease (g_j - g_i)^2 / (2 eta_j) over the positive
        // set. K[:, i] is needed for eta_j anyway, so fetch it first.
        kp.col_into(i_sel, &mut col_i);
        let diag_i = kp.diag(i_sel);
        let mut j_sel = usize::MAX;
        let mut best_gain = 0.0;
        for &k in &pos {
            if k == i_sel {
                continue;
            }
            let d = g[k] - g_min;
            if d <= 0.0 {
                continue;
            }
            let eta = (2.0 * (diag_i + kp.diag(k) - 2.0 * col_i[k])).max(1e-12);
            let gain = d * d / eta;
            if gain > best_gain {
                best_gain = gain;
                j_sel = k;
            }
        }
        if j_sel == usize::MAX {
            break;
        }

        // --- pair sub-problem ---
        kp.col_into(j_sel, &mut col_j);
        let eta = (2.0 * (diag_i + kp.diag(j_sel) - 2.0 * col_i[j_sel])).max(1e-12);
        let raw = (g[j_sel] - g_min) / eta;
        let delta = raw.min(c - alpha[i_sel]).min(alpha[j_sel]);
        if delta <= 0.0 {
            // numerically stuck pair; nothing can move
            break;
        }
        let was_zero = alpha[i_sel] <= 1e-14;
        alpha[i_sel] += delta;
        alpha[j_sel] -= delta;
        // maintain the positive set
        if was_zero {
            pos_slot[i_sel] = pos.len();
            pos.push(i_sel);
        }
        if alpha[j_sel] <= 1e-14 {
            alpha[j_sel] = 0.0;
            let slot = pos_slot[j_sel];
            let last = *pos.last().unwrap();
            pos.swap_remove(slot);
            if slot < pos.len() {
                pos_slot[last] = slot;
            }
            pos_slot[j_sel] = usize::MAX;
        }

        // --- rank-1 gradient update fused with the next i-scan ---
        let two_d = 2.0 * delta;
        g_min = f64::INFINITY;
        i_sel = usize::MAX;
        for k in 0..n {
            let gk = g[k] + two_d * (col_i[k] - col_j[k]);
            g[k] = gk;
            if gk < g_min && alpha[k] < c - 1e-14 {
                g_min = gk;
                i_sel = k;
            }
        }
    }

    // Renormalize tiny drift on the equality constraint.
    let sum: f64 = alpha.iter().sum();
    if (sum - 1.0).abs() > 1e-9 {
        for a in &mut alpha {
            *a /= sum;
        }
    }

    // quad = a' K a = sum_i a_i (K a)_i with (K a)_i = (g_i + K_ii)/2.
    let quad: f64 = (0..n)
        .map(|i| alpha[i] * (g[i] + kp.diag(i)) * 0.5)
        .sum();

    // R^2: dist^2(x_k) = K_kk - 2 (K a)_k + quad = quad - g_k.
    // Average over boundary SVs (0 < a_k < C); fall back to all SVs.
    let mut r2_sum = 0.0;
    let mut r2_cnt = 0usize;
    for k in 0..n {
        if alpha[k] > opts.sv_eps && alpha[k] < c - opts.sv_eps {
            r2_sum += quad - g[k];
            r2_cnt += 1;
        }
    }
    if r2_cnt == 0 {
        for k in 0..n {
            if alpha[k] > opts.sv_eps {
                r2_sum += quad - g[k];
                r2_cnt += 1;
            }
        }
    }
    let r2 = if r2_cnt > 0 { (r2_sum / r2_cnt as f64).max(0.0) } else { 0.0 };

    Ok(SmoSolution { alpha, gradient: g, quad, r2, iterations, gap })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gaussian_dense(pts: &[Vec<f64>], bw: f64) -> DenseKernel {
        let m = Matrix::from_rows(pts).unwrap();
        DenseKernel::from_data(&m, Kernel::gaussian(bw))
    }

    /// Brute-force reference: projected gradient descent on the simplex
    /// with box constraints, used to validate SMO on small problems.
    fn reference_objective(k: &DenseKernel, alpha: &[f64]) -> f64 {
        let n = k.n();
        let mut q = 0.0;
        for i in 0..n {
            for j in 0..n {
                q += alpha[i] * alpha[j] * k.k[i * n + j];
            }
        }
        let lin: f64 = (0..n).map(|i| alpha[i] * k.diag(i)).sum();
        q - lin
    }

    #[test]
    fn two_identical_points_split_mass() {
        // K = [[1,1],[1,1]]: any feasible alpha is optimal, f = 1 - 1 = 0.
        let k = gaussian_dense(&[vec![0.0], vec![0.0]], 1.0);
        let mut kp = k;
        let sol = solve(&mut kp, 1.0, &SmoOptions::default()).unwrap();
        assert!((sol.alpha.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(sol.r2.abs() < 1e-9, "r2={}", sol.r2);
    }

    #[test]
    fn two_distant_points_symmetric_solution() {
        // Symmetric problem: optimum is alpha = (1/2, 1/2) when C >= 1/2.
        let mut kp = gaussian_dense(&[vec![0.0], vec![2.0]], 1.0);
        let sol = solve(&mut kp, 1.0, &SmoOptions::default()).unwrap();
        assert!((sol.alpha[0] - 0.5).abs() < 1e-8, "{:?}", sol.alpha);
        assert!((sol.alpha[1] - 0.5).abs() < 1e-8);
        // R^2 = 1 - 2(a K)_k + quad with K12 = exp(-2)
        let k12 = (-2.0f64).exp();
        let quad = 0.5 * (1.0 + k12);
        let expect = 1.0 - (1.0 + k12) + quad;
        assert!((sol.r2 - expect).abs() < 1e-8, "r2={} expect={expect}", sol.r2);
    }

    #[test]
    fn interior_point_gets_zero_alpha() {
        // Three collinear points; the middle one is inside the description
        // and must end with alpha ~ 0 (duality condition eq. (8)).
        let mut kp = gaussian_dense(&[vec![-1.0], vec![0.0], vec![1.0]], 1.0);
        let sol = solve(&mut kp, 1.0, &SmoOptions::default()).unwrap();
        assert!(sol.alpha[1] < 1e-8, "middle alpha = {}", sol.alpha[1]);
        assert!((sol.alpha[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn box_constraint_caps_outlier() {
        // An extreme outlier with C < 1 must saturate at alpha = C
        // (duality condition eq. (10)).
        let pts = vec![
            vec![0.0],
            vec![0.1],
            vec![-0.1],
            vec![0.05],
            vec![100.0], // outlier
        ];
        // The outlier is kernel-orthogonal to the cluster, so without the
        // box it would take alpha ~ 1/2 (minimizing (1-a)^2 + a^2).
        // C = 0.4 < 1/2 therefore binds and the outlier pins at C
        // (duality condition eq. (10)).
        let c = 1.0 / (5.0 * 0.5); // f = 0.5 -> C = 0.4
        let mut kp = gaussian_dense(&pts, 1.0);
        let sol = solve(&mut kp, c, &SmoOptions::default()).unwrap();
        assert!((sol.alpha[4] - c).abs() < 1e-8, "alpha={:?}", sol.alpha);
    }

    #[test]
    fn kkt_conditions_hold() {
        let pts: Vec<Vec<f64>> = (0..20)
            .map(|i| vec![(i as f64 * 0.37).sin(), (i as f64 * 0.61).cos()])
            .collect();
        let c = 1.0 / (20.0 * 0.1);
        let mut kp = gaussian_dense(&pts, 0.8);
        let sol = solve(&mut kp, c, &SmoOptions::default()).unwrap();
        // lambda from any interior SV; check eps-KKT for all points.
        let interior: Vec<usize> = (0..20)
            .filter(|&i| sol.alpha[i] > 1e-8 && sol.alpha[i] < c - 1e-8)
            .collect();
        assert!(!interior.is_empty());
        let lambda = sol.gradient[interior[0]];
        for i in 0..20 {
            let gi = sol.gradient[i];
            if sol.alpha[i] < 1e-8 {
                assert!(gi >= lambda - 1e-5, "g[{i}]={gi} < lambda={lambda}");
            } else if sol.alpha[i] > c - 1e-8 {
                assert!(gi <= lambda + 1e-5);
            } else {
                assert!((gi - lambda).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn matches_projected_gradient_reference() {
        // Random-ish 12-point problem; compare objective to a dense
        // projected-gradient solve (simplex projection with box).
        let pts: Vec<Vec<f64>> = (0..12)
            .map(|i| {
                let t = i as f64;
                vec![(t * 1.3).sin() * 2.0, (t * 0.7).cos() * 1.5]
            })
            .collect();
        let c = 1.0 / (12.0 * 0.15);
        let dense = gaussian_dense(&pts, 1.1);
        let mut kp = gaussian_dense(&pts, 1.1);
        let sol = solve(&mut kp, c, &SmoOptions::default()).unwrap();
        let smo_obj = reference_objective(&dense, &sol.alpha);

        // crude projected gradient with many iterations
        let n = 12;
        let mut a = vec![1.0 / n as f64; n];
        for _ in 0..200_000 {
            // gradient
            let mut grad = vec![0.0; n];
            for i in 0..n {
                let mut s = 0.0;
                for j in 0..n {
                    s += dense.k[i * n + j] * a[j];
                }
                grad[i] = 2.0 * s - dense.diag(i);
            }
            for i in 0..n {
                a[i] -= 0.01 * grad[i];
            }
            // project to { sum = 1, 0 <= a <= C } by iterative clipping
            for _ in 0..50 {
                let free: Vec<usize> = (0..n).collect();
                let sum: f64 = a.iter().sum();
                let shift = (sum - 1.0) / free.len() as f64;
                for i in 0..n {
                    a[i] = (a[i] - shift).clamp(0.0, c);
                }
                if (a.iter().sum::<f64>() - 1.0).abs() < 1e-12 {
                    break;
                }
            }
        }
        let ref_obj = reference_objective(&dense, &a);
        assert!(
            smo_obj <= ref_obj + 1e-6,
            "SMO objective {smo_obj} worse than reference {ref_obj}"
        );
    }

    #[test]
    fn infeasible_c_rejected() {
        let mut kp = gaussian_dense(&[vec![0.0], vec![1.0]], 1.0);
        assert!(solve(&mut kp, 0.2, &SmoOptions::default()).is_err());
    }

    #[test]
    fn empty_problem_rejected() {
        let m = Matrix::zeros(0, 1);
        let mut kp = DenseKernel::from_data(&m, Kernel::gaussian(1.0));
        assert!(solve(&mut kp, 1.0, &SmoOptions::default()).is_err());
    }

    #[test]
    fn lazy_and_dense_agree() {
        let pts: Vec<Vec<f64>> = (0..30)
            .map(|i| {
                let t = i as f64 * 0.41;
                vec![t.sin() * 3.0, (t * 1.9).cos()]
            })
            .collect();
        let m = Matrix::from_rows(&pts).unwrap();
        let c = 1.0 / (30.0 * 0.1);
        let mut dense = DenseKernel::from_data(&m, Kernel::gaussian(1.0));
        let mut lazy = LazyKernel::new(&m, Kernel::gaussian(1.0), 1 << 20);
        let sd = solve(&mut dense, c, &SmoOptions::default()).unwrap();
        let sl = solve(&mut lazy, c, &SmoOptions::default()).unwrap();
        assert!((sd.r2 - sl.r2).abs() < 1e-10);
        for (a, b) in sd.alpha.iter().zip(&sl.alpha) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn tiny_cache_still_correct() {
        let pts: Vec<Vec<f64>> = (0..25)
            .map(|i| vec![(i as f64 * 0.77).sin(), (i as f64 * 0.31).cos()])
            .collect();
        let m = Matrix::from_rows(&pts).unwrap();
        let c = 1.0 / (25.0 * 0.2);
        let mut dense = DenseKernel::from_data(&m, Kernel::gaussian(0.9));
        // cache of a single column forces constant eviction
        let mut lazy = LazyKernel::new(&m, Kernel::gaussian(0.9), 1);
        let sd = solve(&mut dense, c, &SmoOptions::default()).unwrap();
        let sl = solve(&mut lazy, c, &SmoOptions::default()).unwrap();
        assert!((sd.r2 - sl.r2).abs() < 1e-10);
    }

    #[test]
    fn alpha_sums_to_one_and_in_box() {
        let pts: Vec<Vec<f64>> = (0..40)
            .map(|i| vec![(i as f64).sin(), (i as f64 * 2.0).cos(), i as f64 % 3.0])
            .collect();
        let c = 1.0 / (40.0 * 0.05);
        let mut kp = gaussian_dense(&pts, 1.5);
        let sol = solve(&mut kp, c, &SmoOptions::default()).unwrap();
        assert!((sol.alpha.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(sol.alpha.iter().all(|&a| (-1e-12..=c + 1e-12).contains(&a)));
        assert!(sol.gap < 1e-5);
    }
}
