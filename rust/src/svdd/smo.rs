//! SMO solver for the SVDD dual (paper eqs. (14)–(16)):
//!
//! ```text
//! min  f(a) = a' K a - sum_i a_i K_ii
//! s.t. sum_i a_i = 1,   0 <= a_i <= C,   C = 1 / (n f)
//! ```
//!
//! (The paper states the equivalent maximization.) With gradient
//! `g_i = 2 (K a)_i - K_ii`, the KKT conditions say there is a
//! multiplier `lambda` with `g_i >= lambda` when `a_i = 0`,
//! `g_i <= lambda` when `a_i = C`, and `g_i = lambda` inside; the
//! optimality gap is `max{g_j : a_j > 0} - min{g_i : a_i < C}`.
//!
//! The pair sub-problem moves mass `delta` from `j` to `i`:
//! `delta = (g_j - g_i) / (2 (K_ii + K_jj - 2 K_ij))`, clipped to the
//! box `[0, min(C - a_i, a_j)]`, followed by a rank-1 gradient update
//! `g += 2 delta (K[:,i] - K[:,j])`.
//!
//! The default path is a [`Solver`] with LIBSVM-style machinery
//! (Fan, Chen & Lin, JMLR 2005):
//!
//! - **second-order working-set selection** ([`Wss::Second`]): `i` is
//!   the maximal violator `argmin{ g_i : a_i < C }`; `j` maximizes the
//!   quadratic objective decrease `(g_j - g_i)^2 / (2 eta_j)` with
//!   `eta_j = 2 (K_ii + K_jj - 2 K_ij)`, using the already-fetched
//!   column `i`. [`Wss::First`] is the classic maximal-violating-pair
//!   rule (`j = argmax{ g_j : a_j > 0 }`), kept as the iteration-count
//!   ablation baseline;
//! - **active-set shrinking**: every `shrink_every` pair iterations,
//!   variables pinned at a bound whose KKT slack exceeds the current
//!   gap are dropped from the working index set, so the selection scan,
//!   the rank-1 gradient update and — via the ranged
//!   [`KernelProvider::col_into_range`] — the kernel-column evaluation
//!   all run over the (much smaller) active set only. Gradients of
//!   shrunk rows go stale by design; before the solver is allowed to
//!   declare convergence it reconstructs the full gradient exactly,
//!   re-activates everything, and re-checks the gap on the full set
//!   (the unshrink-and-recheck pass), so the returned [`SmoSolution`]
//!   satisfies the same `tol` as the unshrunk solver;
//! - **warm starts** ([`solve_with_init`]): an initial `alpha` (e.g.
//!   the previous sampling iteration's solution on the retained `SV*`
//!   rows) is projected onto the feasible set `{sum = 1, 0 <= a <= C}`
//!   and used instead of the cold start, which typically cuts the
//!   iteration count hard when the initial point is near the optimum.
//!
//! [`Wss::Legacy`] preserves the pre-Solver loop **verbatim** (its
//! first-order `i`-scan fused into the gradient update, gain-based `j`
//! pick over the positive set, no shrinking, cold init): a seeded solve
//! in legacy mode reproduces the historical trajectory byte-for-byte,
//! which is what the golden regression tests pin.

use std::ops::Range;

use crate::error::{Error, Result};
use crate::linalg::NormCache;
use crate::parallel::Pool;
use crate::svdd::cache::ColumnCache;
use crate::svdd::kernel::Kernel;
use crate::util::matrix::Matrix;

/// Rows per parallel chunk when evaluating a kernel column.
const COL_CHUNK: usize = 512;

/// Column evaluation runs inside the SMO inner loop (up to three
/// columns per pair iteration on a cache miss), so a scoped-thread
/// spawn must be amortized over much more math than a one-shot region:
/// require ~0.5M scalar ops (roughly a millisecond of kernel
/// arithmetic) before going parallel. A 20k x 41 Tennessee solve
/// clears this; a 20k x 2 banana column stays serial, where it is
/// faster anyway.
const COL_PAR_MIN_WORK: usize = 1 << 19;

/// Abstract access to the kernel matrix so the solver runs both on
/// lazily computed kernels (large full-SVDD solves, LRU-cached) and on
/// dense gram matrices produced by the XLA `gram` artifact (the
/// Algorithm-1 sample solves).
pub trait KernelProvider {
    fn n(&self) -> usize;
    /// K(x_i, x_i).
    fn diag(&self, i: usize) -> f64;
    /// Copy column `i` (== row `i`; kernels are symmetric) into `out`.
    fn col_into(&mut self, i: usize, out: &mut [f64]);
    /// Copy rows `rows` of column `i` into `out`
    /// (`out.len() == rows.len()`). The shrinking solver uses this to
    /// evaluate kernel entries only over the active index set; entries
    /// must carry the same bits as the corresponding [`col_into`] rows
    /// (both sides of the contract are [`Kernel::eval_block`] panels).
    ///
    /// [`col_into`]: KernelProvider::col_into
    fn col_into_range(&mut self, i: usize, rows: Range<usize>, out: &mut [f64]);
}

/// Lazily evaluated kernel over a data matrix with an LRU column cache.
/// Column evaluation on a cache miss runs as [`Kernel::eval_block`]
/// panels (squared row norms cached once at construction) in parallel
/// chunks on the pool; each entry is a pure function of its two rows,
/// so the column is bit-identical to the serial evaluation at any
/// thread count, and bit-identical to the corresponding
/// [`DenseKernel::from_data`] Gram entries.
pub struct LazyKernel<'a> {
    data: &'a Matrix,
    kernel: Kernel,
    norms: NormCache,
    cache: ColumnCache,
    diag: Vec<f64>,
    pool: Option<Pool>,
}

impl<'a> LazyKernel<'a> {
    pub fn new(data: &'a Matrix, kernel: Kernel, cache_bytes: usize) -> Self {
        let norms = NormCache::new(data);
        // block-path diag, so K_ii agrees bitwise with the off-diagonal
        // entries the column panels produce
        let diag = norms.as_slice().iter().map(|&n| kernel.diag_from_norm(n)).collect();
        LazyKernel {
            data,
            kernel,
            norms,
            cache: ColumnCache::new(data.rows(), cache_bytes),
            diag,
            pool: None,
        }
    }

    /// Pin column evaluation to an explicit pool instead of the global
    /// one (tests, benches).
    pub fn with_pool(mut self, pool: Pool) -> Self {
        self.pool = Some(pool);
        self
    }

    pub fn cache_hit_rate(&self) -> f64 {
        self.cache.hit_rate()
    }

    /// Full-column cache hits so far.
    pub fn cache_hits(&self) -> u64 {
        self.cache.hits()
    }

    /// Full-column cache lookups (hits + misses) so far.
    pub fn cache_lookups(&self) -> u64 {
        self.cache.lookups()
    }

    /// The pool a fill of `rows` kernel-column entries runs on. An
    /// explicitly pinned pool (`with_pool`) is used as-is — the caller
    /// took control, and the determinism tests rely on it to force
    /// parallel columns on small problems. The global pool is
    /// cost-gated at COL_PAR_MIN_WORK.
    fn fill_pool(&self, rows: usize) -> Pool {
        match self.pool {
            Some(p) => p,
            None => {
                let work = rows * self.data.cols().max(1);
                if work < COL_PAR_MIN_WORK {
                    Pool::serial()
                } else {
                    crate::parallel::global()
                }
            }
        }
    }
}

/// Evaluate rows `start_row..start_row + out.len()` of column `i` as
/// block panels on `run`, in COL_CHUNK chunks. The single evaluation
/// recipe behind both the cached full-column fill and the ranged fill
/// (a free function so [`ColumnCache::get_into`]'s fill closure can
/// use it without borrowing the whole `LazyKernel`).
fn eval_col_rows(
    data: &Matrix,
    kernel: Kernel,
    norms: &NormCache,
    run: Pool,
    i: usize,
    start_row: usize,
    out: &mut [f64],
) {
    run.run_chunks(out, COL_CHUNK, |off, chunk| {
        let lo = start_row + off;
        kernel.eval_block(data, norms, i..i + 1, data, norms, lo..lo + chunk.len(), chunk);
    });
}

impl<'a> KernelProvider for LazyKernel<'a> {
    fn n(&self) -> usize {
        self.data.rows()
    }

    fn diag(&self, i: usize) -> f64 {
        self.diag[i]
    }

    fn col_into(&mut self, i: usize, out: &mut [f64]) {
        let run = self.fill_pool(out.len());
        // borrow dance: get_into's fill closure cannot capture &self
        // while &mut self.cache is live, so evaluate via locals
        let data = self.data;
        let kernel = self.kernel;
        let norms = &self.norms;
        self.cache
            .get_into(i, out, |buf| eval_col_rows(data, kernel, norms, run, i, 0, buf));
    }

    fn col_into_range(&mut self, i: usize, rows: Range<usize>, out: &mut [f64]) {
        debug_assert_eq!(out.len(), rows.len());
        if rows.is_empty() {
            return;
        }
        // a full column cached earlier (by `col_into`, during the
        // unshrunk phase) serves every sub-range as a copy
        if let Some(col) = self.cache.lookup(i) {
            out.copy_from_slice(&col[rows]);
            return;
        }
        // evaluate just the requested rows. Partial columns are not
        // inserted into the cache (it stores full columns only); the
        // shrinking solver's active set is small enough that the
        // evaluation itself is the cheap path.
        let run = self.fill_pool(out.len());
        eval_col_rows(self.data, self.kernel, &self.norms, run, i, rows.start, out);
    }
}

/// Dense precomputed kernel matrix (row-major n*n). This is what the
/// XLA gram artifact feeds the sample solves with.
pub struct DenseKernel {
    n: usize,
    k: Vec<f64>,
}

impl DenseKernel {
    pub fn new(k: Vec<f64>, n: usize) -> Result<Self> {
        if k.len() != n * n {
            return Err(Error::invalid(format!(
                "dense kernel: {} values for n={n}",
                k.len()
            )));
        }
        Ok(DenseKernel { n, k })
    }

    /// Compute the full gram matrix natively on the batched kernel
    /// layer ([`crate::parallel::gram`]: norm-cached
    /// [`Kernel::eval_block`] row panels), in parallel on the global
    /// pool. Bit-identical at any thread count; agrees with the scalar
    /// reference [`DenseKernel::from_data_serial`] to ULP-level relative
    /// tolerance (the block path uses a different summation order).
    pub fn from_data(data: &Matrix, kernel: Kernel) -> Self {
        Self::from_data_pooled(data, kernel, crate::parallel::global())
    }

    /// [`DenseKernel::from_data`] on an explicit pool.
    pub fn from_data_pooled(data: &Matrix, kernel: Kernel, pool: Pool) -> Self {
        DenseKernel {
            n: data.rows(),
            k: crate::parallel::gram(data, kernel, pool),
        }
    }

    /// Single-threaded upper-triangle + mirror computation via the
    /// scalar [`Kernel::eval`] — the **scalar reference path** the
    /// block layer is property-tested against. Not used on any hot
    /// path; kept as the independent oracle.
    pub fn from_data_serial(data: &Matrix, kernel: Kernel) -> Self {
        let n = data.rows();
        let mut k = vec![0.0; n * n];
        for i in 0..n {
            for j in i..n {
                let v = kernel.eval(data.row(i), data.row(j));
                k[i * n + j] = v;
                k[j * n + i] = v;
            }
        }
        DenseKernel { n, k }
    }

    /// Row-major flat view of the kernel matrix.
    pub fn as_slice(&self) -> &[f64] {
        &self.k
    }
}

impl KernelProvider for DenseKernel {
    fn n(&self) -> usize {
        self.n
    }

    fn diag(&self, i: usize) -> f64 {
        self.k[i * self.n + i]
    }

    fn col_into(&mut self, i: usize, out: &mut [f64]) {
        out.copy_from_slice(&self.k[i * self.n..(i + 1) * self.n]);
    }

    fn col_into_range(&mut self, i: usize, rows: Range<usize>, out: &mut [f64]) {
        debug_assert_eq!(out.len(), rows.len());
        out.copy_from_slice(&self.k[i * self.n + rows.start..i * self.n + rows.end]);
    }
}

/// Working-set selection rule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Wss {
    /// Maximal violating pair (LIBSVM WSS1): `j = argmax g` over the
    /// positive set. The iteration-count baseline for ablations.
    First,
    /// Second-order selection (LIBSVM WSS2, Fan et al.): `j` maximizes
    /// `(g_j - g_i)^2 / (2 eta_j)` using the cached column for `i`.
    Second,
    /// The pre-Solver loop, preserved verbatim: fused first-order
    /// `i`-scan + gain-based `j` pick, no shrinking, cold init. A
    /// seeded legacy solve is byte-for-byte identical to the
    /// historical solver (golden-tested); warm starts are rejected and
    /// `shrinking` is ignored in this mode.
    Legacy,
}

impl Wss {
    pub fn parse(s: &str) -> Result<Wss> {
        Ok(match s {
            "first" => Wss::First,
            "second" => Wss::Second,
            "legacy" => Wss::Legacy,
            other => {
                return Err(Error::Config(format!(
                    "unknown working-set selection '{other}' (first | second | legacy)"
                )))
            }
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Wss::First => "first",
            Wss::Second => "second",
            Wss::Legacy => "legacy",
        }
    }
}

/// Solver options.
#[derive(Clone, Copy, Debug)]
pub struct SmoOptions {
    /// KKT violation tolerance (stopping threshold on `g_j - g_i`).
    pub tol: f64,
    /// Hard cap on pair iterations (scaled guard; the solver normally
    /// stops on the gap long before this).
    pub max_iter: usize,
    /// alpha values below this are treated as zero when extracting SVs.
    pub sv_eps: f64,
    /// Working-set selection rule (default: second-order).
    pub wss: Wss,
    /// Periodically drop bound-pinned variables from the working set
    /// (ignored in [`Wss::Legacy`] mode, which never shrinks).
    pub shrinking: bool,
    /// Pair iterations between shrink passes; 0 = auto
    /// (`min(n, 1000)`, the LIBSVM cadence).
    pub shrink_every: usize,
}

impl Default for SmoOptions {
    fn default() -> Self {
        SmoOptions {
            tol: 1e-6,
            max_iter: 0,
            sv_eps: 1e-9,
            wss: Wss::Second,
            shrinking: true,
            shrink_every: 0,
        }
    }
}

impl SmoOptions {
    /// The pre-Solver configuration: legacy selection, no shrinking.
    /// Seeded solves in this mode reproduce the historical trajectory
    /// byte-for-byte.
    pub fn legacy() -> SmoOptions {
        SmoOptions { wss: Wss::Legacy, shrinking: false, ..Default::default() }
    }
}

/// Solution of the dual problem.
#[derive(Clone, Debug)]
pub struct SmoSolution {
    /// Dual variables, length n, summing to 1.
    pub alpha: Vec<f64>,
    /// Final gradient `g_i = 2 (K a)_i - K_ii` (used for R^2). Always
    /// the full, exact gradient — shrunk rows are reconstructed before
    /// the solver returns.
    pub gradient: Vec<f64>,
    /// `a' K a` at the solution.
    pub quad: f64,
    /// Squared threshold radius (mean over boundary SVs; see below).
    pub r2: f64,
    /// Pair iterations executed.
    pub iterations: usize,
    /// Final optimality gap (over the full index set).
    pub gap: f64,
    /// Shrink passes that actually removed variables.
    pub shrink_events: usize,
    /// Unshrink-and-recheck passes (gradient reconstructions forced by
    /// apparent convergence on the shrunk set).
    pub unshrink_events: usize,
}

impl SmoSolution {
    /// Indices with `alpha > sv_eps` — the support vectors.
    pub fn sv_indices(&self, sv_eps: f64) -> Vec<usize> {
        (0..self.alpha.len())
            .filter(|&i| self.alpha[i] > sv_eps)
            .collect()
    }
}

/// Solve the SVDD dual by SMO. `c` is the box bound `C = 1/(n f)`.
pub fn solve(kp: &mut dyn KernelProvider, c: f64, opts: &SmoOptions) -> Result<SmoSolution> {
    solve_with_init(kp, c, opts, None)
}

/// [`solve`] from a warm initial `alpha` (projected onto the feasible
/// set; `None` = cold start). This is how the sampling trainer carries
/// the previous iteration's solution into the next union solve.
pub fn solve_with_init(
    kp: &mut dyn KernelProvider,
    c: f64,
    opts: &SmoOptions,
    init: Option<&[f64]>,
) -> Result<SmoSolution> {
    if opts.wss == Wss::Legacy {
        if init.is_some() {
            return Err(Error::Solver(
                "legacy SMO mode does not support warm starts (it exists to \
                 reproduce historical cold-start trajectories byte-for-byte)"
                    .into(),
            ));
        }
        return solve_legacy(kp, c, opts);
    }
    let n = kp.n();
    if n == 0 {
        return Err(Error::invalid("SMO: empty problem"));
    }
    if c * (n as f64) < 1.0 - 1e-12 {
        return Err(Error::Solver(format!(
            "infeasible: n*C = {} < 1 (f > 1?)",
            c * n as f64
        )));
    }
    if let Some(a0) = init {
        if a0.len() != n {
            return Err(Error::invalid(format!(
                "warm-start alpha has {} entries for n={n}",
                a0.len()
            )));
        }
    }
    Solver::new(kp, c, opts, init).run()
}

/// Cold feasible start. Two regimes:
/// - small problems (the Algorithm-1 sample/union solves): uniform
///   alpha = 1/n starts near the solution and the O(n^2 m) gradient
///   init is trivial;
/// - large problems: concentrate the mass on the first ceil(1/C)
///   points (the LIBSVM one-class init) so the initial gradient
///   needs only those columns — O(k n m) instead of O(n^2 m), which
///   otherwise dominates total time.
const UNIFORM_INIT_MAX_N: usize = 256;

fn cold_init(n: usize, c: f64) -> Vec<f64> {
    let mut alpha = vec![0.0; n];
    if n <= UNIFORM_INIT_MAX_N {
        for a in &mut alpha {
            *a = 1.0 / n as f64;
        }
    } else {
        let mut remaining: f64 = 1.0;
        let mut i = 0;
        while remaining > 0.0 && i < n {
            let a = remaining.min(c);
            alpha[i] = a;
            remaining -= a;
            i += 1;
        }
    }
    alpha
}

/// Project a warm-start guess onto `{sum = 1, 0 <= a <= C}`: clamp to
/// the box, scale down any excess mass, then distribute the remaining
/// deficit over the box headroom. Non-finite / negative entries are
/// zeroed; an all-zero guess falls back to the cold start.
fn feasible_init(init: &[f64], c: f64) -> Vec<f64> {
    let n = init.len();
    let mut a: Vec<f64> = init
        .iter()
        .map(|&x| if x.is_finite() && x > 0.0 { x.min(c) } else { 0.0 })
        .collect();
    let mut sum: f64 = a.iter().sum();
    if sum <= 0.0 {
        return cold_init(n, c);
    }
    if sum > 1.0 {
        // scaling down stays inside the box
        let s = 1.0 / sum;
        for x in &mut a {
            *x *= s;
        }
        sum = a.iter().sum();
    }
    // distribute the deficit proportionally to headroom; geometric
    // convergence, and n*C >= 1 guarantees enough headroom exists
    for _ in 0..64 {
        let deficit = 1.0 - sum;
        if deficit.abs() <= 1e-12 {
            break;
        }
        if deficit < 0.0 {
            let s = 1.0 / sum;
            for x in &mut a {
                *x *= s;
            }
        } else {
            let headroom: f64 = a.iter().map(|&x| c - x).sum();
            if headroom <= 0.0 {
                break;
            }
            let scale = (deficit / headroom).min(1.0);
            for x in &mut a {
                *x += scale * (c - *x);
            }
        }
        sum = a.iter().sum();
    }
    a
}

/// Invoke `f` on each maximal run of consecutive indices in `sorted`
/// (e.g. `[2,3,4,9,11,12]` -> `2..5`, `9..10`, `11..13`). The shrunk
/// column fills batch ranged kernel evaluation over these runs.
fn for_each_run(sorted: &[usize], mut f: impl FnMut(Range<usize>)) {
    let mut s = 0;
    while s < sorted.len() {
        let mut e = s + 1;
        while e < sorted.len() && sorted[e] == sorted[e - 1] + 1 {
            e += 1;
        }
        f(sorted[s]..sorted[e - 1] + 1);
        s = e;
    }
}

/// The default SMO engine: second-order (or first-order) working-set
/// selection over an actively shrunk index set, with exact
/// unshrink-and-recheck before convergence is declared.
struct Solver<'k> {
    kp: &'k mut dyn KernelProvider,
    c: f64,
    tol: f64,
    sv_eps: f64,
    wss: Wss,
    shrinking: bool,
    shrink_every: usize,
    max_iter: usize,
    n: usize,
    alpha: Vec<f64>,
    /// Gradient; rows outside `active` go stale while shrunk and are
    /// reconstructed exactly on unshrink / exit.
    g: Vec<f64>,
    /// `{ k : alpha_k > 0 }`, maintained incrementally (swap-removal),
    /// so the j-scan is O(|positive|), not O(n). Contains shrunk rows
    /// pinned at C too — they still carry mass.
    pos: Vec<usize>,
    pos_slot: Vec<usize>,
    /// Optimization-active indices, ascending.
    active: Vec<usize>,
    in_active: Vec<bool>,
    col_i: Vec<f64>,
    col_j: Vec<f64>,
    shrunk: bool,
    shrink_events: usize,
    unshrink_events: usize,
}

impl<'k> Solver<'k> {
    fn new(
        kp: &'k mut dyn KernelProvider,
        c: f64,
        opts: &SmoOptions,
        init: Option<&[f64]>,
    ) -> Solver<'k> {
        let n = kp.n();
        let mut alpha = match init {
            Some(a0) => feasible_init(a0, c),
            None => cold_init(n, c),
        };
        // Invariant the pair loop relies on: alpha is exactly 0 or
        // > 1e-14 (the same clamp the updates apply), so membership in
        // `pos` is unambiguous. A projected warm guess can carry
        // sub-threshold positives; zero them (the final renormalize
        // absorbs the <= n*1e-14 mass error).
        for a in &mut alpha {
            if *a <= 1e-14 {
                *a = 0.0;
            }
        }
        let max_iter = if opts.max_iter > 0 {
            opts.max_iter
        } else {
            (100 * n).max(10_000)
        };
        let shrink_every = if opts.shrink_every > 0 {
            opts.shrink_every
        } else {
            n.min(1000).max(1)
        };
        Solver {
            kp,
            c,
            tol: opts.tol,
            sv_eps: opts.sv_eps,
            wss: opts.wss,
            shrinking: opts.shrinking,
            shrink_every,
            max_iter,
            n,
            alpha,
            g: Vec::new(),
            pos: Vec::new(),
            pos_slot: vec![usize::MAX; n],
            active: (0..n).collect(),
            in_active: vec![true; n],
            col_i: vec![0.0; n],
            col_j: vec![0.0; n],
            shrunk: false,
            shrink_events: 0,
            unshrink_events: 0,
        }
    }

    /// g_i = 2 (K a)_i - K_ii from the nonzero-alpha columns only (for
    /// the uniform cold start that is every column; for the
    /// concentrated / warm start just the carrying rows).
    fn init_gradient(&mut self) {
        self.g = (0..self.n).map(|i| -self.kp.diag(i)).collect();
        let mut col = vec![0.0; self.n];
        for j in 0..self.n {
            if self.alpha[j] <= 0.0 {
                continue;
            }
            self.kp.col_into(j, &mut col);
            let two_aj = 2.0 * self.alpha[j];
            for k in 0..self.n {
                self.g[k] += two_aj * col[k];
            }
        }
        self.pos = (0..self.n).filter(|&k| self.alpha[k] > 0.0).collect();
        for (slot, &k) in self.pos.iter().enumerate() {
            self.pos_slot[k] = slot;
        }
    }

    /// Fill `buf` with column `i` over the active rows (full column
    /// when unshrunk — which also keeps the LRU cache warm — ranged
    /// runs when shrunk). Entries outside the active set are stale.
    fn fill_col_active(
        kp: &mut dyn KernelProvider,
        shrunk: bool,
        active: &[usize],
        i: usize,
        buf: &mut [f64],
    ) {
        if !shrunk {
            kp.col_into(i, buf);
        } else {
            for_each_run(active, |r| {
                let (lo, hi) = (r.start, r.end);
                kp.col_into_range(i, r, &mut buf[lo..hi]);
            });
        }
    }

    /// Reconstruct the exact gradient for every inactive row:
    /// `g_k = 2 sum_j alpha_j K_kj - K_kk`, evaluating kernel entries
    /// only over the inactive runs of each positive column.
    fn reconstruct_gradient(&mut self) {
        if !self.shrunk {
            return;
        }
        let inactive: Vec<usize> =
            (0..self.n).filter(|&k| !self.in_active[k]).collect();
        if inactive.is_empty() {
            self.shrunk = false;
            return;
        }
        for &k in &inactive {
            self.g[k] = -self.kp.diag(k);
        }
        // scratch column; refilled on the next pair iteration anyway.
        // (positive columns are few — |pos| ~ #SV — so this pass costs
        // O(|pos| * |inactive| * m) kernel work, not O(n^2 m))
        let mut buf = std::mem::take(&mut self.col_i);
        let pos = self.pos.clone();
        for j in pos {
            let aj = self.alpha[j];
            if aj <= 0.0 {
                continue;
            }
            for_each_run(&inactive, |r| {
                let (lo, hi) = (r.start, r.end);
                self.kp.col_into_range(j, r, &mut buf[lo..hi]);
            });
            let two_aj = 2.0 * aj;
            for &k in &inactive {
                self.g[k] += two_aj * buf[k];
            }
        }
        self.col_i = buf;
        self.shrunk = false;
    }

    /// Re-activate every index (used by the unshrink-and-recheck pass;
    /// call [`Solver::reconstruct_gradient`] first).
    fn activate_all(&mut self) {
        self.active.clear();
        self.active.extend(0..self.n);
        self.in_active.fill(true);
    }

    /// The unshrink-and-recheck pass, shared by every exit point of the
    /// pair loop (gap-converged, no `j` found, stuck pair): if rows
    /// were shrunk away, their gradients are stale and the exit verdict
    /// is optimistic — reconstruct the exact gradient, re-activate
    /// everything and return `true` so the loop re-checks on the full
    /// set. Returns `false` (really converged / stuck) when nothing
    /// was shrunk.
    fn try_unshrink(&mut self) -> bool {
        if !self.shrunk {
            return false;
        }
        self.reconstruct_gradient();
        self.activate_all();
        self.unshrink_events += 1;
        true
    }

    /// One shrink pass: drop active variables pinned at a bound whose
    /// gradient lies strictly outside the current violation window
    /// `[g_min, g_max]` — a zero-alpha row with `g > g_max` can never
    /// become the receiving `i`, and a C-pinned row with `g < g_min`
    /// can never become the giving `j`, until the window moves past
    /// them (caught by the unshrink-and-recheck pass).
    fn shrink_pass(&mut self, g_min: f64, g_max: f64) {
        if !g_min.is_finite() || !g_max.is_finite() {
            return;
        }
        let (c, alpha, g) = (self.c, &self.alpha, &self.g);
        let in_active = &mut self.in_active;
        let before = self.active.len();
        self.active.retain(|&k| {
            let pinned_low = alpha[k] <= 1e-14 && g[k] > g_max;
            let pinned_high = alpha[k] >= c - 1e-14 && g[k] < g_min;
            let keep = !(pinned_low || pinned_high);
            if !keep {
                in_active[k] = false;
            }
            keep
        });
        if self.active.len() < before {
            self.shrunk = true;
            self.shrink_events += 1;
        }
    }

    fn run(mut self) -> Result<SmoSolution> {
        self.init_gradient();
        // actual pair updates, NOT loop passes: unshrink-recheck
        // passes do no pair work and must not inflate the count (it
        // feeds the CI-gated iteration-reduction ratios against the
        // legacy solver, whose count equals its update count)
        let mut iterations = 0;
        let mut since_shrink = 0usize;
        // set once the unshrink-and-recheck pass has fired: from then
        // on the solver works on the full set so the convergence check
        // below is exact (the LIBSVM "unshrink once" policy)
        let mut final_phase = false;

        for _pass in 0..self.max_iter {
            // --- selection scan over the active set ---
            let mut i_sel = usize::MAX;
            let mut g_min = f64::INFINITY;
            let mut g_max = f64::NEG_INFINITY;
            for &k in &self.active {
                let gk = self.g[k];
                if self.alpha[k] < self.c - 1e-14 && gk < g_min {
                    g_min = gk;
                    i_sel = k;
                }
                if self.alpha[k] > 0.0 && gk > g_max {
                    g_max = gk;
                }
            }
            let gap = g_max - g_min;

            if i_sel == usize::MAX || gap < self.tol {
                // apparent convergence: only final once re-checked on
                // the full, exactly-reconstructed gradient
                if self.try_unshrink() {
                    final_phase = true;
                    continue;
                }
                break;
            }

            // --- working-set selection ---
            Self::fill_col_active(
                &mut *self.kp,
                self.shrunk,
                &self.active,
                i_sel,
                &mut self.col_i,
            );
            let diag_i = self.kp.diag(i_sel);
            let mut j_sel = usize::MAX;
            match self.wss {
                Wss::Second => {
                    // maximize the objective decrease (g_j - g_i)^2 /
                    // (2 eta_j) over the active positive set; K[:, i]
                    // is in col_i already.
                    let mut best_gain = 0.0;
                    for &k in &self.pos {
                        if k == i_sel || !self.in_active[k] {
                            continue;
                        }
                        let d = self.g[k] - g_min;
                        if d <= 0.0 {
                            continue;
                        }
                        let eta = (2.0 * (diag_i + self.kp.diag(k) - 2.0 * self.col_i[k]))
                            .max(1e-12);
                        let gain = d * d / eta;
                        if gain > best_gain {
                            best_gain = gain;
                            j_sel = k;
                        }
                    }
                }
                Wss::First => {
                    // maximal violating pair: j = argmax g over the
                    // active positive set
                    let mut best_d = 0.0;
                    for &k in &self.pos {
                        if k == i_sel || !self.in_active[k] {
                            continue;
                        }
                        let d = self.g[k] - g_min;
                        if d > best_d {
                            best_d = d;
                            j_sel = k;
                        }
                    }
                }
                Wss::Legacy => unreachable!("legacy mode dispatches to solve_legacy"),
            }
            if j_sel == usize::MAX {
                if self.try_unshrink() {
                    final_phase = true;
                    continue;
                }
                break;
            }

            // --- pair sub-problem ---
            Self::fill_col_active(
                &mut *self.kp,
                self.shrunk,
                &self.active,
                j_sel,
                &mut self.col_j,
            );
            let eta =
                (2.0 * (diag_i + self.kp.diag(j_sel) - 2.0 * self.col_i[j_sel])).max(1e-12);
            let raw = (self.g[j_sel] - g_min) / eta;
            let delta = raw.min(self.c - self.alpha[i_sel]).min(self.alpha[j_sel]);
            if delta <= 0.0 {
                // numerically stuck pair; nothing can move on this set
                if self.try_unshrink() {
                    final_phase = true;
                    continue;
                }
                break;
            }
            // exact membership test (not an alpha threshold): pushing
            // an index already in `pos` would leave a stale duplicate
            // behind after swap-removal
            let was_out = self.pos_slot[i_sel] == usize::MAX;
            self.alpha[i_sel] += delta;
            self.alpha[j_sel] -= delta;
            // maintain the positive set
            if was_out {
                self.pos_slot[i_sel] = self.pos.len();
                self.pos.push(i_sel);
            }
            if self.alpha[j_sel] <= 1e-14 {
                self.alpha[j_sel] = 0.0;
                let slot = self.pos_slot[j_sel];
                let last = *self.pos.last().unwrap();
                self.pos.swap_remove(slot);
                if slot < self.pos.len() {
                    self.pos_slot[last] = slot;
                }
                self.pos_slot[j_sel] = usize::MAX;
            }

            // --- rank-1 gradient update over the active rows only ---
            let two_d = 2.0 * delta;
            for &k in &self.active {
                self.g[k] += two_d * (self.col_i[k] - self.col_j[k]);
            }
            iterations += 1;

            // --- periodic shrinking ---
            since_shrink += 1;
            if self.shrinking && !final_phase && since_shrink >= self.shrink_every {
                since_shrink = 0;
                self.shrink_pass(g_min, g_max);
            }
        }

        // max_iter can land here while shrunk: make the gradient exact
        // before reporting anything derived from it.
        self.reconstruct_gradient();
        self.finish(iterations)
    }

    fn finish(self, iterations: usize) -> Result<SmoSolution> {
        let Solver { c, sv_eps, n, mut alpha, g, kp, shrink_events, unshrink_events, .. } = self;

        // Renormalize tiny drift on the equality constraint.
        let sum: f64 = alpha.iter().sum();
        if (sum - 1.0).abs() > 1e-9 {
            for a in &mut alpha {
                *a /= sum;
            }
        }

        // final gap over the full set, from the exact gradient
        let mut g_min = f64::INFINITY;
        let mut g_max = f64::NEG_INFINITY;
        for k in 0..n {
            if alpha[k] < c - 1e-14 && g[k] < g_min {
                g_min = g[k];
            }
            if alpha[k] > 0.0 && g[k] > g_max {
                g_max = g[k];
            }
        }
        let gap = g_max - g_min;

        // quad = a' K a = sum_i a_i (K a)_i with (K a)_i = (g_i + K_ii)/2.
        let quad: f64 = (0..n).map(|i| alpha[i] * (g[i] + kp.diag(i)) * 0.5).sum();

        // R^2: dist^2(x_k) = K_kk - 2 (K a)_k + quad = quad - g_k.
        // Average over boundary SVs (0 < a_k < C); fall back to all SVs.
        let mut r2_sum = 0.0;
        let mut r2_cnt = 0usize;
        for k in 0..n {
            if alpha[k] > sv_eps && alpha[k] < c - sv_eps {
                r2_sum += quad - g[k];
                r2_cnt += 1;
            }
        }
        if r2_cnt == 0 {
            for k in 0..n {
                if alpha[k] > sv_eps {
                    r2_sum += quad - g[k];
                    r2_cnt += 1;
                }
            }
        }
        let r2 = if r2_cnt > 0 { (r2_sum / r2_cnt as f64).max(0.0) } else { 0.0 };

        Ok(SmoSolution {
            alpha,
            gradient: g,
            quad,
            r2,
            iterations,
            gap,
            shrink_events,
            unshrink_events,
        })
    }
}

/// The pre-Solver loop, preserved **verbatim** (modulo the two
/// telemetry zeros appended to [`SmoSolution`]): first-order `i`-scan
/// fused into the rank-1 gradient update, gain-based `j` pick over the
/// positive set, full-length columns, no shrinking, cold init. Golden
/// regression tests pin its trajectory byte-for-byte — do not "improve"
/// this function; improvements belong in [`Solver`].
fn solve_legacy(kp: &mut dyn KernelProvider, c: f64, opts: &SmoOptions) -> Result<SmoSolution> {
    let n = kp.n();
    if n == 0 {
        return Err(Error::invalid("SMO: empty problem"));
    }
    if c * (n as f64) < 1.0 - 1e-12 {
        return Err(Error::Solver(format!(
            "infeasible: n*C = {} < 1 (f > 1?)",
            c * n as f64
        )));
    }
    let mut alpha = vec![0.0; n];
    if n <= UNIFORM_INIT_MAX_N {
        for a in &mut alpha {
            *a = 1.0 / n as f64;
        }
    } else {
        let mut remaining: f64 = 1.0;
        let mut i = 0;
        while remaining > 0.0 && i < n {
            let a = remaining.min(c);
            alpha[i] = a;
            remaining -= a;
            i += 1;
        }
    }

    // g_i = 2 (K a)_i - K_ii from the nonzero-alpha columns only (for
    // the uniform start that is every column; for the concentrated
    // start just the first ceil(1/C)).
    let mut g: Vec<f64> = (0..n).map(|i| -kp.diag(i)).collect();
    let mut col = vec![0.0; n];
    for j in 0..n {
        if alpha[j] <= 0.0 {
            continue;
        }
        kp.col_into(j, &mut col);
        let two_aj = 2.0 * alpha[j];
        for k in 0..n {
            g[k] += two_aj * col[k];
        }
    }

    // Index set { k : alpha_k > 0 }, maintained incrementally so the
    // second-order j-scan is O(|positive|), not O(n).
    let mut pos: Vec<usize> = (0..n).filter(|&k| alpha[k] > 0.0).collect();
    let mut pos_slot: Vec<usize> = vec![usize::MAX; n];
    for (slot, &k) in pos.iter().enumerate() {
        pos_slot[k] = slot;
    }

    let max_iter = if opts.max_iter > 0 {
        opts.max_iter
    } else {
        (100 * n).max(10_000)
    };

    let mut col_i = vec![0.0; n];
    let mut col_j = vec![0.0; n];
    let mut iterations = 0;
    let mut gap = f64::INFINITY;

    // i-candidate (argmin g over alpha < C) is maintained across
    // iterations by fusing the scan with the rank-1 gradient update.
    let mut i_sel = usize::MAX;
    let mut g_min = f64::INFINITY;
    for k in 0..n {
        if alpha[k] < c - 1e-14 && g[k] < g_min {
            g_min = g[k];
            i_sel = k;
        }
    }

    for it in 0..max_iter {
        iterations = it;
        // --- optimality gap: max g over the positive set ---
        let mut g_max = f64::NEG_INFINITY;
        for &k in &pos {
            if g[k] > g_max {
                g_max = g[k];
            }
        }
        gap = g_max - g_min;
        if i_sel == usize::MAX || pos.is_empty() || gap < opts.tol {
            break;
        }

        // --- second-order pick of j (LIBSVM WSS2): maximize the
        // objective decrease (g_j - g_i)^2 / (2 eta_j) over the positive
        // set. K[:, i] is needed for eta_j anyway, so fetch it first.
        kp.col_into(i_sel, &mut col_i);
        let diag_i = kp.diag(i_sel);
        let mut j_sel = usize::MAX;
        let mut best_gain = 0.0;
        for &k in &pos {
            if k == i_sel {
                continue;
            }
            let d = g[k] - g_min;
            if d <= 0.0 {
                continue;
            }
            let eta = (2.0 * (diag_i + kp.diag(k) - 2.0 * col_i[k])).max(1e-12);
            let gain = d * d / eta;
            if gain > best_gain {
                best_gain = gain;
                j_sel = k;
            }
        }
        if j_sel == usize::MAX {
            break;
        }

        // --- pair sub-problem ---
        kp.col_into(j_sel, &mut col_j);
        let eta = (2.0 * (diag_i + kp.diag(j_sel) - 2.0 * col_i[j_sel])).max(1e-12);
        let raw = (g[j_sel] - g_min) / eta;
        let delta = raw.min(c - alpha[i_sel]).min(alpha[j_sel]);
        if delta <= 0.0 {
            // numerically stuck pair; nothing can move
            break;
        }
        let was_zero = alpha[i_sel] <= 1e-14;
        alpha[i_sel] += delta;
        alpha[j_sel] -= delta;
        // maintain the positive set
        if was_zero {
            pos_slot[i_sel] = pos.len();
            pos.push(i_sel);
        }
        if alpha[j_sel] <= 1e-14 {
            alpha[j_sel] = 0.0;
            let slot = pos_slot[j_sel];
            let last = *pos.last().unwrap();
            pos.swap_remove(slot);
            if slot < pos.len() {
                pos_slot[last] = slot;
            }
            pos_slot[j_sel] = usize::MAX;
        }

        // --- rank-1 gradient update fused with the next i-scan ---
        let two_d = 2.0 * delta;
        g_min = f64::INFINITY;
        i_sel = usize::MAX;
        for k in 0..n {
            let gk = g[k] + two_d * (col_i[k] - col_j[k]);
            g[k] = gk;
            if gk < g_min && alpha[k] < c - 1e-14 {
                g_min = gk;
                i_sel = k;
            }
        }
    }

    // Renormalize tiny drift on the equality constraint.
    let sum: f64 = alpha.iter().sum();
    if (sum - 1.0).abs() > 1e-9 {
        for a in &mut alpha {
            *a /= sum;
        }
    }

    // quad = a' K a = sum_i a_i (K a)_i with (K a)_i = (g_i + K_ii)/2.
    let quad: f64 = (0..n)
        .map(|i| alpha[i] * (g[i] + kp.diag(i)) * 0.5)
        .sum();

    // R^2: dist^2(x_k) = K_kk - 2 (K a)_k + quad = quad - g_k.
    // Average over boundary SVs (0 < a_k < C); fall back to all SVs.
    let mut r2_sum = 0.0;
    let mut r2_cnt = 0usize;
    for k in 0..n {
        if alpha[k] > opts.sv_eps && alpha[k] < c - opts.sv_eps {
            r2_sum += quad - g[k];
            r2_cnt += 1;
        }
    }
    if r2_cnt == 0 {
        for k in 0..n {
            if alpha[k] > opts.sv_eps {
                r2_sum += quad - g[k];
                r2_cnt += 1;
            }
        }
    }
    let r2 = if r2_cnt > 0 { (r2_sum / r2_cnt as f64).max(0.0) } else { 0.0 };

    Ok(SmoSolution {
        alpha,
        gradient: g,
        quad,
        r2,
        iterations,
        gap,
        shrink_events: 0,
        unshrink_events: 0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gaussian_dense(pts: &[Vec<f64>], bw: f64) -> DenseKernel {
        let m = Matrix::from_rows(pts).unwrap();
        DenseKernel::from_data(&m, Kernel::gaussian(bw))
    }

    /// Brute-force reference: projected gradient descent on the simplex
    /// with box constraints, used to validate SMO on small problems.
    fn reference_objective(k: &DenseKernel, alpha: &[f64]) -> f64 {
        let n = k.n();
        let mut q = 0.0;
        for i in 0..n {
            for j in 0..n {
                q += alpha[i] * alpha[j] * k.k[i * n + j];
            }
        }
        let lin: f64 = (0..n).map(|i| alpha[i] * k.diag(i)).sum();
        q - lin
    }

    #[test]
    fn two_identical_points_split_mass() {
        // K = [[1,1],[1,1]]: any feasible alpha is optimal, f = 1 - 1 = 0.
        let k = gaussian_dense(&[vec![0.0], vec![0.0]], 1.0);
        let mut kp = k;
        let sol = solve(&mut kp, 1.0, &SmoOptions::default()).unwrap();
        assert!((sol.alpha.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(sol.r2.abs() < 1e-9, "r2={}", sol.r2);
    }

    #[test]
    fn two_distant_points_symmetric_solution() {
        // Symmetric problem: optimum is alpha = (1/2, 1/2) when C >= 1/2.
        let mut kp = gaussian_dense(&[vec![0.0], vec![2.0]], 1.0);
        let sol = solve(&mut kp, 1.0, &SmoOptions::default()).unwrap();
        assert!((sol.alpha[0] - 0.5).abs() < 1e-8, "{:?}", sol.alpha);
        assert!((sol.alpha[1] - 0.5).abs() < 1e-8);
        // R^2 = 1 - 2(a K)_k + quad with K12 = exp(-2)
        let k12 = (-2.0f64).exp();
        let quad = 0.5 * (1.0 + k12);
        let expect = 1.0 - (1.0 + k12) + quad;
        assert!((sol.r2 - expect).abs() < 1e-8, "r2={} expect={expect}", sol.r2);
    }

    #[test]
    fn interior_point_gets_zero_alpha() {
        // Three collinear points; the middle one is inside the description
        // and must end with alpha ~ 0 (duality condition eq. (8)).
        let mut kp = gaussian_dense(&[vec![-1.0], vec![0.0], vec![1.0]], 1.0);
        let sol = solve(&mut kp, 1.0, &SmoOptions::default()).unwrap();
        assert!(sol.alpha[1] < 1e-8, "middle alpha = {}", sol.alpha[1]);
        assert!((sol.alpha[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn box_constraint_caps_outlier() {
        // An extreme outlier with C < 1 must saturate at alpha = C
        // (duality condition eq. (10)).
        let pts = vec![
            vec![0.0],
            vec![0.1],
            vec![-0.1],
            vec![0.05],
            vec![100.0], // outlier
        ];
        // The outlier is kernel-orthogonal to the cluster, so without the
        // box it would take alpha ~ 1/2 (minimizing (1-a)^2 + a^2).
        // C = 0.4 < 1/2 therefore binds and the outlier pins at C
        // (duality condition eq. (10)).
        let c = 1.0 / (5.0 * 0.5); // f = 0.5 -> C = 0.4
        let mut kp = gaussian_dense(&pts, 1.0);
        let sol = solve(&mut kp, c, &SmoOptions::default()).unwrap();
        assert!((sol.alpha[4] - c).abs() < 1e-8, "alpha={:?}", sol.alpha);
    }

    #[test]
    fn kkt_conditions_hold() {
        let pts: Vec<Vec<f64>> = (0..20)
            .map(|i| vec![(i as f64 * 0.37).sin(), (i as f64 * 0.61).cos()])
            .collect();
        let c = 1.0 / (20.0 * 0.1);
        let mut kp = gaussian_dense(&pts, 0.8);
        let sol = solve(&mut kp, c, &SmoOptions::default()).unwrap();
        // lambda from any interior SV; check eps-KKT for all points.
        let interior: Vec<usize> = (0..20)
            .filter(|&i| sol.alpha[i] > 1e-8 && sol.alpha[i] < c - 1e-8)
            .collect();
        assert!(!interior.is_empty());
        let lambda = sol.gradient[interior[0]];
        for i in 0..20 {
            let gi = sol.gradient[i];
            if sol.alpha[i] < 1e-8 {
                assert!(gi >= lambda - 1e-5, "g[{i}]={gi} < lambda={lambda}");
            } else if sol.alpha[i] > c - 1e-8 {
                assert!(gi <= lambda + 1e-5);
            } else {
                assert!((gi - lambda).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn matches_projected_gradient_reference() {
        // Random-ish 12-point problem; compare objective to a dense
        // projected-gradient solve (simplex projection with box).
        let pts: Vec<Vec<f64>> = (0..12)
            .map(|i| {
                let t = i as f64;
                vec![(t * 1.3).sin() * 2.0, (t * 0.7).cos() * 1.5]
            })
            .collect();
        let c = 1.0 / (12.0 * 0.15);
        let dense = gaussian_dense(&pts, 1.1);
        let mut kp = gaussian_dense(&pts, 1.1);
        let sol = solve(&mut kp, c, &SmoOptions::default()).unwrap();
        let smo_obj = reference_objective(&dense, &sol.alpha);

        // crude projected gradient with many iterations
        let n = 12;
        let mut a = vec![1.0 / n as f64; n];
        for _ in 0..200_000 {
            // gradient
            let mut grad = vec![0.0; n];
            for i in 0..n {
                let mut s = 0.0;
                for j in 0..n {
                    s += dense.k[i * n + j] * a[j];
                }
                grad[i] = 2.0 * s - dense.diag(i);
            }
            for i in 0..n {
                a[i] -= 0.01 * grad[i];
            }
            // project to { sum = 1, 0 <= a <= C } by iterative clipping
            for _ in 0..50 {
                let free: Vec<usize> = (0..n).collect();
                let sum: f64 = a.iter().sum();
                let shift = (sum - 1.0) / free.len() as f64;
                for i in 0..n {
                    a[i] = (a[i] - shift).clamp(0.0, c);
                }
                if (a.iter().sum::<f64>() - 1.0).abs() < 1e-12 {
                    break;
                }
            }
        }
        let ref_obj = reference_objective(&dense, &a);
        assert!(
            smo_obj <= ref_obj + 1e-6,
            "SMO objective {smo_obj} worse than reference {ref_obj}"
        );
    }

    #[test]
    fn infeasible_c_rejected() {
        let mut kp = gaussian_dense(&[vec![0.0], vec![1.0]], 1.0);
        assert!(solve(&mut kp, 0.2, &SmoOptions::default()).is_err());
        assert!(solve(&mut kp, 0.2, &SmoOptions::legacy()).is_err());
    }

    #[test]
    fn empty_problem_rejected() {
        let m = Matrix::zeros(0, 1);
        let mut kp = DenseKernel::from_data(&m, Kernel::gaussian(1.0));
        assert!(solve(&mut kp, 1.0, &SmoOptions::default()).is_err());
        let mut kp2 = DenseKernel::from_data(&m, Kernel::gaussian(1.0));
        assert!(solve(&mut kp2, 1.0, &SmoOptions::legacy()).is_err());
    }

    #[test]
    fn lazy_and_dense_agree() {
        let pts: Vec<Vec<f64>> = (0..30)
            .map(|i| {
                let t = i as f64 * 0.41;
                vec![t.sin() * 3.0, (t * 1.9).cos()]
            })
            .collect();
        let m = Matrix::from_rows(&pts).unwrap();
        let c = 1.0 / (30.0 * 0.1);
        let mut dense = DenseKernel::from_data(&m, Kernel::gaussian(1.0));
        let mut lazy = LazyKernel::new(&m, Kernel::gaussian(1.0), 1 << 20);
        let sd = solve(&mut dense, c, &SmoOptions::default()).unwrap();
        let sl = solve(&mut lazy, c, &SmoOptions::default()).unwrap();
        assert!((sd.r2 - sl.r2).abs() < 1e-10);
        for (a, b) in sd.alpha.iter().zip(&sl.alpha) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn tiny_cache_still_correct() {
        let pts: Vec<Vec<f64>> = (0..25)
            .map(|i| vec![(i as f64 * 0.77).sin(), (i as f64 * 0.31).cos()])
            .collect();
        let m = Matrix::from_rows(&pts).unwrap();
        let c = 1.0 / (25.0 * 0.2);
        let mut dense = DenseKernel::from_data(&m, Kernel::gaussian(0.9));
        // cache of a single column forces constant eviction
        let mut lazy = LazyKernel::new(&m, Kernel::gaussian(0.9), 1);
        let sd = solve(&mut dense, c, &SmoOptions::default()).unwrap();
        let sl = solve(&mut lazy, c, &SmoOptions::default()).unwrap();
        assert!((sd.r2 - sl.r2).abs() < 1e-10);
    }

    #[test]
    fn alpha_sums_to_one_and_in_box() {
        let pts: Vec<Vec<f64>> = (0..40)
            .map(|i| vec![(i as f64).sin(), (i as f64 * 2.0).cos(), i as f64 % 3.0])
            .collect();
        let c = 1.0 / (40.0 * 0.05);
        let mut kp = gaussian_dense(&pts, 1.5);
        let sol = solve(&mut kp, c, &SmoOptions::default()).unwrap();
        assert!((sol.alpha.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(sol.alpha.iter().all(|&a| (-1e-12..=c + 1e-12).contains(&a)));
        assert!(sol.gap < 1e-5);
    }

    // ---- Solver-path specifics: WSS modes, shrinking, warm starts ----

    fn wavy(n: usize) -> Vec<Vec<f64>> {
        (0..n)
            .map(|i| {
                let t = i as f64 * 0.37;
                vec![t.sin() * 2.0, (t * 1.7).cos()]
            })
            .collect()
    }

    #[test]
    fn wss_modes_agree_within_tolerance() {
        let pts = wavy(120);
        let c = 1.0 / (120.0 * 0.1);
        let mut a = gaussian_dense(&pts, 0.8);
        let mut b = gaussian_dense(&pts, 0.8);
        let mut l = gaussian_dense(&pts, 0.8);
        let second = solve(&mut a, c, &SmoOptions::default()).unwrap();
        let first = solve(
            &mut b,
            c,
            &SmoOptions { wss: Wss::First, shrinking: false, ..Default::default() },
        )
        .unwrap();
        let legacy = solve(&mut l, c, &SmoOptions::legacy()).unwrap();
        for s in [&second, &first, &legacy] {
            assert!(s.gap < 1e-5, "gap={}", s.gap);
        }
        // solutions are each eps-KKT; derived quantities agree to the
        // KKT tolerance scale (not bitwise — the trajectories differ)
        assert!((second.r2 - first.r2).abs() < 1e-5);
        assert!((second.r2 - legacy.r2).abs() < 1e-5);
        assert!((second.quad - first.quad).abs() < 1e-5);
    }

    #[test]
    fn shrinking_matches_unshrunk_solution() {
        let pts = wavy(300);
        let c = 1.0 / (300.0 * 0.05);
        // aggressive cadence so shrinking actually fires on a test-size
        // problem
        let shrunk_opts = SmoOptions { shrink_every: 20, ..Default::default() };
        let plain_opts = SmoOptions { shrinking: false, ..Default::default() };
        let mut a = gaussian_dense(&pts, 0.6);
        let mut b = gaussian_dense(&pts, 0.6);
        let with = solve(&mut a, c, &shrunk_opts).unwrap();
        let without = solve(&mut b, c, &plain_opts).unwrap();
        assert!(with.gap < 1e-5, "shrunk gap={}", with.gap);
        assert!((with.r2 - without.r2).abs() < 1e-5, "{} vs {}", with.r2, without.r2);
        assert!((with.quad - without.quad).abs() < 1e-5);
        // per-index alpha comparison is deliberately absent: the wavy
        // curve has near-duplicate rows, where eps-KKT solutions can
        // split mass between twins differently; the SV-set agreement
        // property lives in tests/smo_solver.rs on well-posed clouds
    }

    #[test]
    fn shrinking_fires_and_is_reported() {
        // big enough that the auto cadence (min(n,1000)) fires several
        // times before convergence
        let pts = wavy(500);
        let c = 1.0 / (500.0 * 0.02);
        let mut kp = gaussian_dense(&pts, 0.4);
        let sol = solve(&mut kp, c, &SmoOptions { shrink_every: 25, ..Default::default() })
            .unwrap();
        assert!(sol.gap < 1e-5);
        assert!(sol.shrink_events > 0, "expected shrinking on a 500-pt problem");
        // apparent convergence on the shrunk set must have been
        // re-checked at least once
        assert!(sol.unshrink_events >= 1);
    }

    #[test]
    fn warm_start_from_solution_converges_immediately() {
        let pts = wavy(150);
        let c = 1.0 / (150.0 * 0.1);
        let mut a = gaussian_dense(&pts, 0.9);
        let cold = solve(&mut a, c, &SmoOptions::default()).unwrap();
        let mut b = gaussian_dense(&pts, 0.9);
        let warm =
            solve_with_init(&mut b, c, &SmoOptions::default(), Some(&cold.alpha[..])).unwrap();
        assert!(
            warm.iterations <= cold.iterations / 5 + 3,
            "warm {} vs cold {}",
            warm.iterations,
            cold.iterations
        );
        assert!((warm.r2 - cold.r2).abs() < 1e-8);
    }

    #[test]
    fn warm_start_infeasible_guess_is_projected() {
        let pts = wavy(40);
        let c = 1.0 / (40.0 * 0.2);
        // mass 5x too large, some entries negative/NaN, some above C
        let mut guess = vec![0.0; 40];
        for (i, v) in guess.iter_mut().enumerate() {
            *v = match i % 4 {
                0 => 1.0,
                1 => -3.0,
                2 => f64::NAN,
                _ => 0.01,
            };
        }
        let mut kp = gaussian_dense(&pts, 0.8);
        let sol = solve_with_init(&mut kp, c, &SmoOptions::default(), Some(&guess[..])).unwrap();
        assert!((sol.alpha.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(sol.alpha.iter().all(|&a| (-1e-12..=c + 1e-12).contains(&a)));
        assert!(sol.gap < 1e-5);
    }

    #[test]
    fn warm_start_subthreshold_alpha_cannot_corrupt_pos_set() {
        // a guess summing to 1 with one entry below the 1e-14 zero
        // clamp: the projection keeps it, and before the entry-zeroing
        // in Solver::new it entered `pos` while still being "zero" to
        // the pair updates — a later re-push would leave a stale
        // duplicate that could stall the solver. The solve must reach
        // full tolerance.
        let pts = wavy(25);
        let c = 1.0 / (25.0 * 0.2);
        let mut guess = vec![0.0; 25];
        for g in guess.iter_mut().take(5) {
            *g = c; // 5 * 0.2 = exactly 1.0
        }
        guess[10] = 1e-20; // vanishes into the sum; survives projection
        let mut kp = gaussian_dense(&pts, 0.8);
        let sol =
            solve_with_init(&mut kp, c, &SmoOptions::default(), Some(&guess[..])).unwrap();
        assert!(sol.gap < 1e-5, "gap={}", sol.gap);
        assert!((sol.alpha.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(sol.alpha.iter().all(|&a| (-1e-12..=c + 1e-12).contains(&a)));
    }

    #[test]
    fn warm_start_all_zero_falls_back_to_cold() {
        let pts = wavy(30);
        let c = 1.0 / (30.0 * 0.2);
        let mut a = gaussian_dense(&pts, 0.8);
        let mut b = gaussian_dense(&pts, 0.8);
        let cold = solve(&mut a, c, &SmoOptions::default()).unwrap();
        let zeros = vec![0.0; 30];
        let warm =
            solve_with_init(&mut b, c, &SmoOptions::default(), Some(&zeros[..])).unwrap();
        // identical trajectory: the zero guess falls back to cold init
        assert_eq!(warm.iterations, cold.iterations);
        assert_eq!(warm.r2.to_bits(), cold.r2.to_bits());
    }

    #[test]
    fn warm_start_wrong_length_rejected() {
        let pts = wavy(10);
        let mut kp = gaussian_dense(&pts, 1.0);
        let bad = vec![0.1; 7];
        assert!(solve_with_init(&mut kp, 1.0, &SmoOptions::default(), Some(&bad[..])).is_err());
    }

    #[test]
    fn legacy_mode_rejects_warm_start() {
        let pts = wavy(10);
        let mut kp = gaussian_dense(&pts, 1.0);
        let init = vec![0.1; 10];
        assert!(solve_with_init(&mut kp, 1.0, &SmoOptions::legacy(), Some(&init[..])).is_err());
    }

    #[test]
    fn single_point_problem() {
        for opts in [SmoOptions::default(), SmoOptions::legacy()] {
            let mut kp = gaussian_dense(&[vec![3.0, 4.0]], 1.0);
            let sol = solve(&mut kp, 1.0, &opts).unwrap();
            assert_eq!(sol.alpha, vec![1.0]);
            assert!(sol.r2.abs() < 1e-12, "r2={}", sol.r2);
        }
    }

    #[test]
    fn ranged_col_matches_full_col() {
        let pts = wavy(64);
        let m = Matrix::from_rows(&pts).unwrap();
        for kernel in [Kernel::gaussian(0.7), Kernel::Linear, Kernel::polynomial(2, 1.0)] {
            let mut dense = DenseKernel::from_data(&m, kernel);
            let mut lazy = LazyKernel::new(&m, kernel, 1 << 20);
            let mut full = vec![0.0; 64];
            let mut part = vec![0.0; 17];
            for kp in [&mut dense as &mut dyn KernelProvider, &mut lazy] {
                kp.col_into(5, &mut full);
                kp.col_into_range(5, 20..37, &mut part);
                assert_eq!(&full[20..37], &part[..], "uncached range mismatch");
            }
            // lazy: a second ranged read is served from the now-cached
            // full column and must carry identical bits
            let mut part2 = vec![0.0; 17];
            lazy.col_into_range(5, 20..37, &mut part2);
            assert_eq!(part, part2);
        }
    }

    #[test]
    fn for_each_run_batches_consecutive_indices() {
        let mut runs: Vec<(usize, usize)> = Vec::new();
        for_each_run(&[2, 3, 4, 9, 11, 12], |r| runs.push((r.start, r.end)));
        assert_eq!(runs, vec![(2, 5), (9, 10), (11, 13)]);
        runs.clear();
        for_each_run(&[], |r| runs.push((r.start, r.end)));
        assert!(runs.is_empty());
        for_each_run(&[7], |r| runs.push((r.start, r.end)));
        assert_eq!(runs, vec![(7, 8)]);
    }

    #[test]
    fn feasible_init_handles_degenerate_guesses() {
        // saturating guess: everything wants C
        let a = feasible_init(&[9.0, 9.0, 9.0, 9.0], 0.3);
        assert!((a.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(a.iter().all(|&x| x <= 0.3 + 1e-12));
        // tiny mass gets scaled up
        let b = feasible_init(&[1e-9, 2e-9], 1.0);
        assert!((b.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        // all-zero falls back to cold init
        let z = feasible_init(&[0.0; 5], 1.0);
        assert!((z.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn wss_parse_roundtrip() {
        for w in [Wss::First, Wss::Second, Wss::Legacy] {
            assert_eq!(Wss::parse(w.as_str()).unwrap(), w);
        }
        assert!(Wss::parse("zeroth").is_err());
    }
}
