//! Gaussian bandwidth selection heuristics.
//!
//! The paper treats `s` as given (and sweeps it in the simulation
//! study); a practical library needs a default. Two standard choices:
//! the **median heuristic** (median pairwise distance of a subsample)
//! and a **mean-distance** variant; both are cheap and deterministic
//! given a seed.

use crate::linalg::{self, NormCache};
use crate::util::matrix::Matrix;
use crate::util::rng::Xoshiro256;

/// Median pairwise euclidean distance over at most `max_pairs` sampled
/// pairs. The classic kernel-method default.
pub fn median_heuristic(data: &Matrix, max_pairs: usize, seed: u64) -> f64 {
    pairwise_stat(data, max_pairs, seed, |mut d| {
        d.sort_by(|a, b| a.partial_cmp(b).unwrap());
        d[d.len() / 2]
    })
}

/// Root-mean-square pairwise distance / sqrt(2) — matches the scale at
/// which the Gaussian exponent `||a-b||^2 / (2 s^2)` is O(1).
pub fn mean_heuristic(data: &Matrix, max_pairs: usize, seed: u64) -> f64 {
    pairwise_stat(data, max_pairs, seed, |d| {
        let ms = d.iter().map(|x| x * x).sum::<f64>() / d.len() as f64;
        (ms / 2.0).sqrt()
    })
}

fn pairwise_stat(
    data: &Matrix,
    max_pairs: usize,
    seed: u64,
    reduce: impl FnOnce(Vec<f64>) -> f64,
) -> f64 {
    let n = data.rows();
    assert!(n >= 2, "need at least two observations");
    let mut rng = Xoshiro256::new(seed);
    let total_pairs = n * (n - 1) / 2;
    let mut dists = Vec::with_capacity(max_pairs.min(total_pairs));
    // distances via the norm-cache formulation the kernel layer uses:
    // ||a - b||^2 = (||a||^2 - a.b) + (||b||^2 - a.b)
    if total_pairs <= max_pairs {
        // exact: every row participates, so cache all norms once and
        // batch each row's dots against all later rows
        let norms = NormCache::new(data);
        let mut dots = vec![0.0; n.saturating_sub(1)];
        for i in 0..n {
            let row_dots = &mut dots[..n - i - 1];
            linalg::dot_block(data, i..i + 1, data, i + 1..n, row_dots);
            for (off, &d) in row_dots.iter().enumerate() {
                let j = i + 1 + off;
                dists.push(linalg::sqdist_from_norms(norms.get(i), norms.get(j), d).sqrt());
            }
        }
    } else {
        // sampled: only ~2*max_pairs rows are ever touched, so an
        // O(n*d) all-row norm pass would dominate on huge data —
        // compute the two norms per drawn pair instead
        while dists.len() < max_pairs {
            let i = rng.index(n);
            let j = rng.index(n);
            if i != j {
                let (ri, rj) = (data.row(i), data.row(j));
                let d = linalg::dot(ri, rj);
                let (ni, nj) = (linalg::dot(ri, ri), linalg::dot(rj, rj));
                dists.push(linalg::sqdist_from_norms(ni, nj, d).sqrt());
            }
        }
    }
    let v = reduce(dists);
    if v > 0.0 {
        v
    } else {
        1.0 // degenerate data (all points identical): any bw works
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cloud(scale: f64, n: usize) -> Matrix {
        let mut rng = Xoshiro256::new(9);
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|_| vec![rng.normal() * scale, rng.normal() * scale])
            .collect();
        Matrix::from_rows(&rows).unwrap()
    }

    #[test]
    fn median_scales_with_data() {
        let small = median_heuristic(&cloud(1.0, 200), 5000, 1);
        let big = median_heuristic(&cloud(10.0, 200), 5000, 1);
        assert!(big > 5.0 * small, "small={small} big={big}");
    }

    #[test]
    fn exact_vs_sampled_close() {
        let data = cloud(2.0, 120);
        let exact = median_heuristic(&data, usize::MAX, 1);
        let sampled = median_heuristic(&data, 2000, 2);
        assert!((exact - sampled).abs() / exact < 0.15);
    }

    #[test]
    fn mean_heuristic_positive_and_sane() {
        let data = cloud(1.0, 100);
        let s = mean_heuristic(&data, 4000, 3);
        // std ~1 per axis -> typical pairwise distance ~2; s ~ sqrt(2)
        assert!(s > 0.5 && s < 4.0, "s={s}");
    }

    #[test]
    fn degenerate_data_falls_back() {
        let data = Matrix::from_rows(&vec![vec![1.0, 1.0]; 5]).unwrap();
        assert_eq!(median_heuristic(&data, 100, 1), 1.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let data = cloud(1.5, 500);
        assert_eq!(
            median_heuristic(&data, 1000, 42),
            median_heuristic(&data, 1000, 42)
        );
    }
}
