//! Gaussian bandwidth selection heuristics.
//!
//! The paper treats `s` as given (and sweeps it in the simulation
//! study); a practical library needs a default. Two standard choices:
//! the **median heuristic** (median pairwise distance of a subsample)
//! and a **mean-distance** variant; both are cheap and deterministic
//! given a seed.
//!
//! On top of the pair-sampling heuristics, [`mean_criterion`] and
//! [`median_criterion`] are the *closed-form* mean/median criteria of
//! Chaudhuri et al. (arXiv 1708.05106): over iid pairs `(a, b)`,
//! `E||a-b||^2 = 2 * sum_j var_j` exactly, so the mean-distance scale
//! needs only one pass over column moments — no pairs, no seed. The
//! median variant approximates the median of `||a-b||^2` (a
//! variance-weighted chi-square sum) with the Wilson–Hilferty cube.
//! These are the hands-off `--bandwidth auto:mean|auto:median` modes:
//! deterministic, O(n·d), and cheap enough to re-run at every
//! incremental resync.

use crate::error::{Error, Result};
use crate::linalg::{self, NormCache};
use crate::util::matrix::Matrix;
use crate::util::rng::Xoshiro256;

/// Which closed-form criterion resolves the bandwidth.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AutoBandwidth {
    /// Closed-form mean criterion: `sqrt(sum_j var_j)`.
    Mean,
    /// Wilson–Hilferty approximation of the median pairwise distance.
    Median,
}

impl AutoBandwidth {
    pub fn parse(s: &str) -> Result<AutoBandwidth> {
        Ok(match s {
            "mean" => AutoBandwidth::Mean,
            "median" => AutoBandwidth::Median,
            other => {
                return Err(Error::Config(format!(
                    "unknown bandwidth criterion '{other}' (expected mean|median)"
                )))
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            AutoBandwidth::Mean => "mean",
            AutoBandwidth::Median => "median",
        }
    }

    /// Resolve a bandwidth from `data` with this criterion.
    pub fn resolve(&self, data: &Matrix) -> f64 {
        match self {
            AutoBandwidth::Mean => mean_criterion(data),
            AutoBandwidth::Median => median_criterion(data),
        }
    }
}

/// Per-column population variances, one pass over the rows.
fn column_variances(data: &Matrix) -> Vec<f64> {
    let (n, d) = (data.rows(), data.cols());
    let mut mean = vec![0.0; d];
    for i in 0..n {
        for (m, &x) in mean.iter_mut().zip(data.row(i)) {
            *m += x;
        }
    }
    for m in &mut mean {
        *m /= n as f64;
    }
    let mut var = vec![0.0; d];
    for i in 0..n {
        for j in 0..d {
            let c = data.row(i)[j] - mean[j];
            var[j] += c * c;
        }
    }
    for v in &mut var {
        *v /= n as f64;
    }
    var
}

/// Closed-form mean criterion (Chaudhuri et al., arXiv 1708.05106):
/// `E||a-b||^2 = 2 * sum_j var_j` exactly for iid pairs, so the
/// RMS-distance/sqrt(2) scale of [`mean_heuristic`] collapses to
/// `sqrt(sum_j var_j)` — no pair sampling, no seed.
pub fn mean_criterion(data: &Matrix) -> f64 {
    let s1: f64 = column_variances(data).iter().sum();
    if s1 > 0.0 && s1.is_finite() {
        s1.sqrt()
    } else {
        1.0 // degenerate data (all points identical): any bw works
    }
}

/// Closed-form median criterion: `||a-b||^2 = sum_j 2 var_j z_j^2`
/// with `z_j` standard-normal-ish, a variance-weighted chi-square sum
/// with mean `mu = 2 s1` and effective degrees of freedom
/// `k = s1^2 / s2` (`s1 = sum var_j`, `s2 = sum var_j^2`). The
/// Wilson–Hilferty cube approximates its median as
/// `mu * (1 - 2/(9k))^3`; the returned bandwidth is the matching
/// median *distance*, `sqrt(median of ||a-b||^2)` — the same scale
/// [`median_heuristic`] estimates by sampling.
pub fn median_criterion(data: &Matrix) -> f64 {
    let var = column_variances(data);
    let s1: f64 = var.iter().sum();
    let s2: f64 = var.iter().map(|v| v * v).sum();
    if !(s1 > 0.0) || !s1.is_finite() || !(s2 > 0.0) {
        return 1.0;
    }
    // k >= 1 always (Cauchy–Schwarz on nonnegative variances), so the
    // cube's base 1 - 2/(9k) stays positive.
    let k = s1 * s1 / s2;
    let med_sq = 2.0 * s1 * (1.0 - 2.0 / (9.0 * k)).powi(3);
    if med_sq > 0.0 {
        med_sq.sqrt()
    } else {
        1.0
    }
}

/// Median pairwise euclidean distance over at most `max_pairs` sampled
/// pairs. The classic kernel-method default.
pub fn median_heuristic(data: &Matrix, max_pairs: usize, seed: u64) -> f64 {
    pairwise_stat(data, max_pairs, seed, |mut d| {
        d.sort_by(|a, b| a.partial_cmp(b).unwrap());
        d[d.len() / 2]
    })
}

/// Root-mean-square pairwise distance / sqrt(2) — matches the scale at
/// which the Gaussian exponent `||a-b||^2 / (2 s^2)` is O(1).
pub fn mean_heuristic(data: &Matrix, max_pairs: usize, seed: u64) -> f64 {
    pairwise_stat(data, max_pairs, seed, |d| {
        let ms = d.iter().map(|x| x * x).sum::<f64>() / d.len() as f64;
        (ms / 2.0).sqrt()
    })
}

fn pairwise_stat(
    data: &Matrix,
    max_pairs: usize,
    seed: u64,
    reduce: impl FnOnce(Vec<f64>) -> f64,
) -> f64 {
    let n = data.rows();
    assert!(n >= 2, "need at least two observations");
    let mut rng = Xoshiro256::new(seed);
    let total_pairs = n * (n - 1) / 2;
    let mut dists = Vec::with_capacity(max_pairs.min(total_pairs));
    // distances via the norm-cache formulation the kernel layer uses:
    // ||a - b||^2 = (||a||^2 - a.b) + (||b||^2 - a.b)
    if total_pairs <= max_pairs {
        // exact: every row participates, so cache all norms once and
        // batch each row's dots against all later rows
        let norms = NormCache::new(data);
        let mut dots = vec![0.0; n.saturating_sub(1)];
        for i in 0..n {
            let row_dots = &mut dots[..n - i - 1];
            linalg::dot_block(data, i..i + 1, data, i + 1..n, row_dots);
            for (off, &d) in row_dots.iter().enumerate() {
                let j = i + 1 + off;
                dists.push(linalg::sqdist_from_norms(norms.get(i), norms.get(j), d).sqrt());
            }
        }
    } else {
        // sampled: only ~2*max_pairs rows are ever touched, so an
        // O(n*d) all-row norm pass would dominate on huge data —
        // compute the two norms per drawn pair instead
        while dists.len() < max_pairs {
            let i = rng.index(n);
            let j = rng.index(n);
            if i != j {
                let (ri, rj) = (data.row(i), data.row(j));
                let d = linalg::dot(ri, rj);
                let (ni, nj) = (linalg::dot(ri, ri), linalg::dot(rj, rj));
                dists.push(linalg::sqdist_from_norms(ni, nj, d).sqrt());
            }
        }
    }
    let v = reduce(dists);
    if v > 0.0 {
        v
    } else {
        1.0 // degenerate data (all points identical): any bw works
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cloud(scale: f64, n: usize) -> Matrix {
        let mut rng = Xoshiro256::new(9);
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|_| vec![rng.normal() * scale, rng.normal() * scale])
            .collect();
        Matrix::from_rows(&rows).unwrap()
    }

    #[test]
    fn median_scales_with_data() {
        let small = median_heuristic(&cloud(1.0, 200), 5000, 1);
        let big = median_heuristic(&cloud(10.0, 200), 5000, 1);
        assert!(big > 5.0 * small, "small={small} big={big}");
    }

    #[test]
    fn exact_vs_sampled_close() {
        let data = cloud(2.0, 120);
        let exact = median_heuristic(&data, usize::MAX, 1);
        let sampled = median_heuristic(&data, 2000, 2);
        assert!((exact - sampled).abs() / exact < 0.15);
    }

    #[test]
    fn mean_heuristic_positive_and_sane() {
        let data = cloud(1.0, 100);
        let s = mean_heuristic(&data, 4000, 3);
        // std ~1 per axis -> typical pairwise distance ~2; s ~ sqrt(2)
        assert!(s > 0.5 && s < 4.0, "s={s}");
    }

    #[test]
    fn degenerate_data_falls_back() {
        let data = Matrix::from_rows(&vec![vec![1.0, 1.0]; 5]).unwrap();
        assert_eq!(median_heuristic(&data, 100, 1), 1.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let data = cloud(1.5, 500);
        assert_eq!(
            median_heuristic(&data, 1000, 42),
            median_heuristic(&data, 1000, 42)
        );
    }

    #[test]
    fn mean_criterion_matches_exact_pair_statistic() {
        // closed form vs the exhaustive-pair estimate of the same
        // quantity: E||a-b||^2 = 2 sum var_j is exact only over iid
        // pairs *with* replacement; the all-distinct-pairs estimator
        // differs by the n/(n-1) bias factor, so compare loosely.
        let data = cloud(1.0, 400);
        let closed = mean_criterion(&data);
        let sampled = mean_heuristic(&data, usize::MAX, 1);
        let rel = (closed - sampled).abs() / sampled;
        assert!(rel < 0.02, "closed={closed} sampled={sampled}");
    }

    #[test]
    fn median_criterion_tracks_sampled_median() {
        // Wilson–Hilferty is an approximation; on a gaussian cloud it
        // should land within ~10% of the sampled median heuristic.
        let data = cloud(2.0, 400);
        let closed = median_criterion(&data);
        let sampled = median_heuristic(&data, usize::MAX, 1);
        let rel = (closed - sampled).abs() / sampled;
        assert!(rel < 0.10, "closed={closed} sampled={sampled}");
    }

    #[test]
    fn criteria_scale_with_data_and_need_no_seed() {
        let small = mean_criterion(&cloud(1.0, 300));
        let big = mean_criterion(&cloud(10.0, 300));
        assert!((big / small - 10.0).abs() < 1.0, "small={small} big={big}");
        // same data, same answer — no sampling anywhere
        assert_eq!(median_criterion(&cloud(1.0, 300)), median_criterion(&cloud(1.0, 300)));
    }

    #[test]
    fn criteria_degenerate_fallback() {
        let data = Matrix::from_rows(&vec![vec![3.0, -1.0]; 8]).unwrap();
        assert_eq!(mean_criterion(&data), 1.0);
        assert_eq!(median_criterion(&data), 1.0);
    }

    #[test]
    fn auto_bandwidth_parse_and_resolve() {
        assert_eq!(AutoBandwidth::parse("mean").unwrap(), AutoBandwidth::Mean);
        assert_eq!(AutoBandwidth::parse("median").unwrap(), AutoBandwidth::Median);
        assert!(AutoBandwidth::parse("mode").is_err());
        for w in [AutoBandwidth::Mean, AutoBandwidth::Median] {
            assert_eq!(AutoBandwidth::parse(w.name()).unwrap(), w);
            let bw = w.resolve(&cloud(1.0, 100));
            assert!(bw > 0.0 && bw.is_finite());
        }
    }
}
