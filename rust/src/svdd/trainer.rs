//! Training front-end: data + parameters -> [`SvddModel`].
//!
//! Two entry points:
//! - [`train`] — computes kernel entries natively (lazily, LRU-cached);
//!   used for the full-SVDD baseline on large data. Kernel columns are
//!   evaluated in parallel chunks on the global [`crate::parallel`]
//!   pool once the problem is large enough to pay for it — the result
//!   is bit-identical to the serial path at any thread count.
//! - [`train_with_gram`] — consumes a precomputed dense gram matrix;
//!   this is how the XLA `gram` artifact (L1 Pallas kernel) feeds the
//!   sample solves inside Algorithm 1 (and how
//!   [`crate::parallel::PooledGram`] feeds them on the native
//!   multi-core path).

use crate::error::{Error, Result};
use crate::svdd::kernel::Kernel;
use crate::svdd::model::SvddModel;
use crate::svdd::smo::{self, DenseKernel, LazyKernel, SmoOptions};
use crate::util::matrix::Matrix;

/// Everything the solver needs besides the data.
#[derive(Clone, Copy, Debug)]
pub struct SvddParams {
    pub kernel: Kernel,
    /// Expected outlier fraction `f`; the box bound is `C = 1/(n f)`.
    pub outlier_fraction: f64,
    pub smo: SmoOptions,
    /// LRU kernel cache budget for the lazy path.
    pub cache_bytes: usize,
}

impl SvddParams {
    /// Gaussian kernel with bandwidth `bw`, outlier fraction `f`.
    pub fn gaussian(bw: f64, f: f64) -> SvddParams {
        SvddParams {
            kernel: Kernel::gaussian(bw),
            outlier_fraction: f,
            smo: SmoOptions::default(),
            cache_bytes: 256 << 20,
        }
    }

    pub fn with_bandwidth(mut self, bw: f64) -> SvddParams {
        self.kernel = Kernel::gaussian(bw);
        self
    }

    /// `C = 1/(n f)` for a given training size.
    pub fn c_for(&self, n: usize) -> Result<f64> {
        if !(0.0..=1.0).contains(&self.outlier_fraction) || self.outlier_fraction == 0.0 {
            return Err(Error::invalid(format!(
                "outlier fraction must be in (0, 1], got {}",
                self.outlier_fraction
            )));
        }
        if n == 0 {
            return Err(Error::invalid("empty training set"));
        }
        Ok(1.0 / (n as f64 * self.outlier_fraction))
    }
}

impl Default for SvddParams {
    fn default() -> Self {
        SvddParams::gaussian(1.0, 0.001)
    }
}

/// Per-solve solver telemetry, surfaced so the sampling trainer, the
/// metrics registry and `fastsvdd train -v` can report what the SMO
/// engine actually did instead of dropping it on the floor.
#[derive(Clone, Copy, Debug, Default)]
pub struct SolverStats {
    /// SMO pair iterations.
    pub smo_iterations: usize,
    /// Shrink passes that removed at least one variable.
    pub shrink_events: usize,
    /// Unshrink-and-recheck passes (exact gradient reconstructions).
    pub unshrink_events: usize,
    /// Final optimality gap.
    pub gap: f64,
    /// Kernel-column LRU cache hits / full-column lookups (both 0 on
    /// the dense gram path, which has no cache). Carried as raw counts
    /// — not a stored rate — so folding many solves together (and
    /// summing across workers) stays exact instead of averaging away.
    pub cache_hits: u64,
    pub cache_lookups: u64,
}

impl SolverStats {
    pub(crate) fn from_solution(
        sol: &smo::SmoSolution,
        cache_hits: u64,
        cache_lookups: u64,
    ) -> SolverStats {
        SolverStats {
            smo_iterations: sol.iterations,
            shrink_events: sol.shrink_events,
            unshrink_events: sol.unshrink_events,
            gap: sol.gap,
            cache_hits,
            cache_lookups,
        }
    }

    /// Kernel-column cache hit rate over every absorbed solve (`None`
    /// when no cached-path lookups happened, e.g. pure gram solves).
    pub fn cache_hit_rate(&self) -> Option<f64> {
        if self.cache_lookups == 0 {
            None
        } else {
            Some(self.cache_hits as f64 / self.cache_lookups as f64)
        }
    }

    /// Fold another solve's telemetry into this aggregate (gap keeps
    /// the latest value; cache counts sum exactly).
    pub fn absorb(&mut self, other: &SolverStats) {
        self.smo_iterations += other.smo_iterations;
        self.shrink_events += other.shrink_events;
        self.unshrink_events += other.unshrink_events;
        self.gap = other.gap;
        self.cache_hits += other.cache_hits;
        self.cache_lookups += other.cache_lookups;
    }
}

/// Train on `data` with natively computed kernels.
pub fn train(data: &Matrix, params: &SvddParams) -> Result<SvddModel> {
    Ok(train_detailed(data, params, None)?.0)
}

/// [`train`] with solver telemetry, optionally warm-started from an
/// initial dual guess `init` (length `data.rows()`; projected onto the
/// feasible set — see [`smo::solve_with_init`]).
pub fn train_detailed(
    data: &Matrix,
    params: &SvddParams,
    init: Option<&[f64]>,
) -> Result<(SvddModel, SolverStats)> {
    let c = params.c_for(data.rows())?;
    let mut kp = LazyKernel::new(data, params.kernel, params.cache_bytes);
    let mut span = crate::obs::Span::enter("smo.solve");
    let sol = smo::solve_with_init(&mut kp, c, &params.smo, init)?;
    if span.is_live() {
        span.u64("n", data.rows() as u64);
        span.u64("iterations", sol.iterations as u64);
        span.u64("shrinks", sol.shrink_events as u64);
        span.f64("gap", sol.gap);
    }
    drop(span);
    let stats = SolverStats::from_solution(&sol, kp.cache_hits(), kp.cache_lookups());
    Ok((finalize(data, params, sol)?, stats))
}

/// Train on `data` whose gram matrix `K(data, data)` was computed
/// elsewhere (the XLA artifact path). `gram` is row-major n*n.
pub fn train_with_gram(data: &Matrix, gram: Vec<f64>, params: &SvddParams) -> Result<SvddModel> {
    Ok(train_with_gram_detailed(data, gram, params, None)?.0)
}

/// [`train_with_gram`] with solver telemetry and an optional warm
/// start.
pub fn train_with_gram_detailed(
    data: &Matrix,
    gram: Vec<f64>,
    params: &SvddParams,
    init: Option<&[f64]>,
) -> Result<(SvddModel, SolverStats)> {
    let c = params.c_for(data.rows())?;
    let mut kp = DenseKernel::new(gram, data.rows())?;
    let mut span = crate::obs::Span::enter("smo.solve");
    let sol = smo::solve_with_init(&mut kp, c, &params.smo, init)?;
    if span.is_live() {
        span.u64("n", data.rows() as u64);
        span.u64("iterations", sol.iterations as u64);
        span.u64("shrinks", sol.shrink_events as u64);
        span.f64("gap", sol.gap);
    }
    drop(span);
    let stats = SolverStats::from_solution(&sol, 0, 0);
    Ok((finalize(data, params, sol)?, stats))
}

fn finalize(data: &Matrix, params: &SvddParams, sol: smo::SmoSolution) -> Result<SvddModel> {
    let idx = sol.sv_indices(params.smo.sv_eps);
    if idx.is_empty() {
        return Err(Error::Solver("no support vectors extracted".into()));
    }
    let sv = data.gather(&idx);
    let mut alpha: Vec<f64> = idx.iter().map(|&i| sol.alpha[i]).collect();
    // Dropping alphas <= sv_eps loses a sliver of mass; renormalize so
    // the model invariant sum(alpha) == 1 holds exactly.
    let total: f64 = alpha.iter().sum();
    for a in &mut alpha {
        *a /= total;
    }
    // W = alpha' K alpha over the retained SVs (recomputed exactly on
    // the reduced set rather than reusing sol.quad, so the scoring
    // identity dist2(sv_boundary) == R^2 holds for the *stored* model).
    // K(SV, SV) comes from the same block layer the scorer uses, so the
    // identity holds bitwise against the stored model's kernel values.
    let nsv = sv.rows();
    let norms = crate::linalg::NormCache::new(&sv);
    let mut kmat = vec![0.0; nsv * nsv];
    params.kernel.eval_block(&sv, &norms, 0..nsv, &sv, &norms, 0..nsv, &mut kmat);
    let mut w = 0.0;
    for (i, &ai) in alpha.iter().enumerate() {
        for (j, &aj) in alpha.iter().enumerate() {
            w += ai * aj * kmat[i * nsv + j];
        }
    }
    SvddModel::new(sv, alpha, params.kernel, sol.r2, w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn ring_data(n: usize, seed: u64) -> Matrix {
        // points on an annulus radius ~[0.8, 1.2]
        let mut rng = Xoshiro256::new(seed);
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|_| {
                let th = rng.range(0.0, std::f64::consts::TAU);
                let r = rng.range(0.8, 1.2);
                vec![r * th.cos(), r * th.sin()]
            })
            .collect();
        Matrix::from_rows(&rows).unwrap()
    }

    #[test]
    fn train_produces_valid_model() {
        let data = ring_data(200, 1);
        let params = SvddParams::gaussian(0.5, 0.05);
        let m = train(&data, &params).unwrap();
        assert!(m.num_sv() >= 3);
        assert!(m.num_sv() < 200, "all points became SVs");
        assert!(m.r2() > 0.0);
        assert!((m.alpha().iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn training_points_mostly_inside() {
        let data = ring_data(300, 2);
        let params = SvddParams::gaussian(0.6, 0.02);
        let m = train(&data, &params).unwrap();
        let inside = (0..data.rows())
            .filter(|&i| !m.is_outlier(data.row(i)))
            .count();
        // at most ~f fraction may fall outside (plus margin slack)
        assert!(
            inside as f64 >= 0.9 * data.rows() as f64,
            "only {inside}/300 inside"
        );
    }

    #[test]
    fn center_of_ring_is_inside_far_point_outside() {
        let data = ring_data(300, 3);
        let params = SvddParams::gaussian(0.8, 0.02);
        let m = train(&data, &params).unwrap();
        assert!(!m.is_outlier(&[0.0, 0.0])); // bw .8 bridges the hole
        assert!(m.is_outlier(&[5.0, 5.0]));
    }

    #[test]
    fn gram_path_matches_native_path() {
        let data = ring_data(64, 4);
        let params = SvddParams::gaussian(0.7, 0.05);
        let native = train(&data, &params).unwrap();
        // gram from the same block layer the backends use (a real
        // backend — XLA artifact or PooledGram — feeds these bytes)
        let gram = crate::parallel::gram(&data, params.kernel, crate::parallel::Pool::serial());
        let viagram = train_with_gram(&data, gram, &params).unwrap();
        assert_eq!(native.num_sv(), viagram.num_sv());
        assert!((native.r2() - viagram.r2()).abs() < 1e-10);
    }

    #[test]
    fn boundary_sv_scores_at_r2() {
        let data = ring_data(150, 5);
        let params = SvddParams::gaussian(0.5, 0.05);
        let m = train(&data, &params).unwrap();
        // at least one retained SV must sit on the boundary:
        // |dist2(sv) - R^2| small
        let min_gap = (0..m.num_sv())
            .map(|i| (m.dist2(m.support_vectors().row(i)) - m.r2()).abs())
            .fold(f64::INFINITY, f64::min);
        assert!(min_gap < 1e-4, "closest SV gap to boundary: {min_gap}");
    }

    #[test]
    fn bad_fraction_rejected() {
        let data = ring_data(10, 6);
        let mut params = SvddParams::gaussian(1.0, 0.0);
        assert!(train(&data, &params).is_err());
        params.outlier_fraction = 1.5;
        assert!(train(&data, &params).is_err());
    }

    #[test]
    fn c_for_formula() {
        let p = SvddParams::gaussian(1.0, 0.001);
        assert!((p.c_for(1000).unwrap() - 1.0).abs() < 1e-12);
        assert!(p.c_for(0).is_err());
    }

    #[test]
    fn single_point_training() {
        let data = Matrix::from_rows(&[vec![1.0, 2.0]]).unwrap();
        let params = SvddParams::gaussian(1.0, 0.5);
        let m = train(&data, &params).unwrap();
        assert_eq!(m.num_sv(), 1);
        assert!(m.dist2(&[1.0, 2.0]).abs() < 1e-9);
    }

    #[test]
    fn smaller_fraction_allows_fewer_outliers() {
        // with tiny f (huge C) the description must cover everything,
        // including a mild outlier; with big f it may exclude it.
        let mut rows: Vec<Vec<f64>> = (0..100)
            .map(|i| {
                let t = i as f64 * 0.0628;
                vec![t.cos() * 0.2, t.sin() * 0.2]
            })
            .collect();
        rows.push(vec![1.5, 0.0]);
        let data = Matrix::from_rows(&rows).unwrap();
        let tight = train(&data, &SvddParams::gaussian(0.4, 0.001)).unwrap();
        // With C > 1 the box never binds, so the isolated point becomes a
        // *boundary* SV: dist2 == R^2 up to solver tolerance.
        let gap = tight.dist2(&[1.5, 0.0]) - tight.r2();
        assert!(gap < 1e-5, "C>1 must keep the point on/inside the boundary, gap={gap}");
        let loose = train(&data, &SvddParams::gaussian(0.4, 0.2)).unwrap();
        assert!(loose.is_outlier(&[1.5, 0.0]));
    }
}
