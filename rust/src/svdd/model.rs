//! The trained SVDD model: master support-vector set, dual weights,
//! threshold radius and the input-space center the convergence test
//! tracks (paper defines `a = sum_i alpha_i x_i` even under a kernel).

use crate::error::{Error, Result};
use crate::linalg::{self, NormCache};
use crate::svdd::kernel::Kernel;
use crate::util::hash::Fnv1a;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::matrix::Matrix;

/// A fitted data description. Scoring (paper eq. (18)) is
/// `dist2(z) = K(z,z) - 2 sum_i alpha_i K(x_i, z) + W`,
/// outlier iff `dist2(z) > R^2`.
#[derive(Clone, Debug)]
pub struct SvddModel {
    sv: Matrix,
    alpha: Vec<f64>,
    kernel: Kernel,
    r2: f64,
    /// W = alpha' K(SV, SV) alpha — precomputed model constant.
    w: f64,
    center: Vec<f64>,
    /// Cached `||sv_i||^2` for the batched scoring path (derived from
    /// `sv`, recomputed on construction — never serialized).
    sv_norms: NormCache,
}

impl SvddModel {
    pub fn new(
        sv: Matrix,
        alpha: Vec<f64>,
        kernel: Kernel,
        r2: f64,
        w: f64,
    ) -> Result<SvddModel> {
        if sv.rows() != alpha.len() {
            return Err(Error::invalid(format!(
                "{} SVs but {} alphas",
                sv.rows(),
                alpha.len()
            )));
        }
        if sv.is_empty() {
            return Err(Error::invalid("model with no support vectors"));
        }
        // Non-finite guard: a NaN/inf threshold or weight silently
        // poisons every score downstream (and round-trips through JSON
        // as garbage), so refuse to construct such a model at all.
        if !r2.is_finite() || !w.is_finite() {
            return Err(Error::invalid(format!(
                "non-finite model constants: r2={r2}, w={w}"
            )));
        }
        if alpha.iter().any(|a| !a.is_finite()) {
            return Err(Error::invalid("non-finite alpha coefficient"));
        }
        if sv.as_slice().iter().any(|v| !v.is_finite()) {
            return Err(Error::invalid("non-finite support vector coordinate"));
        }
        let mut center = vec![0.0; sv.cols()];
        for (i, &a) in alpha.iter().enumerate() {
            for (c, x) in center.iter_mut().zip(sv.row(i)) {
                *c += a * x;
            }
        }
        let sv_norms = NormCache::new(&sv);
        Ok(SvddModel { sv, alpha, kernel, r2, w, center, sv_norms })
    }

    // ------------------------------------------------------- accessors

    pub fn num_sv(&self) -> usize {
        self.sv.rows()
    }

    pub fn dim(&self) -> usize {
        self.sv.cols()
    }

    pub fn support_vectors(&self) -> &Matrix {
        &self.sv
    }

    pub fn alpha(&self) -> &[f64] {
        &self.alpha
    }

    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    pub fn r2(&self) -> f64 {
        self.r2
    }

    pub fn w(&self) -> f64 {
        self.w
    }

    /// Input-space center `sum_i alpha_i x_i` (the `a` of the paper's
    /// convergence criterion).
    pub fn center(&self) -> &[f64] {
        &self.center
    }

    // -------------------------------------------------------- identity

    /// Stable content hash over everything that affects scoring: two
    /// models that score identically hash identically, independent of
    /// where or when they were trained. The registry derives
    /// content-addressed version ids from this.
    pub fn content_hash(&self) -> u64 {
        let mut h = Fnv1a::new();
        match self.kernel {
            Kernel::Gaussian { bw } => {
                h.write_u8(0);
                h.write_f64(bw);
            }
            Kernel::Linear => h.write_u8(1),
            Kernel::Polynomial { degree, coef } => {
                h.write_u8(2);
                h.write_u64(degree as u64);
                h.write_f64(coef);
            }
        }
        h.write_u64(self.sv.rows() as u64);
        h.write_u64(self.sv.cols() as u64);
        h.write_f64(self.r2);
        h.write_f64(self.w);
        for &a in &self.alpha {
            h.write_f64(a);
        }
        for &v in self.sv.as_slice() {
            h.write_f64(v);
        }
        h.finish()
    }

    /// Human-readable content-addressed id (`v-` + 16 hex digits of
    /// [`SvddModel::content_hash`]) — the spelling used for registry
    /// version ids and `Message::ModelInfo`.
    pub fn content_id(&self) -> String {
        format!("v-{:016x}", self.content_hash())
    }

    // --------------------------------------------------------- scoring

    /// Kernel distance-to-center squared for a single observation, on
    /// the batched kernel layer: each `K(sv_i, z)` comes from the
    /// cached SV norms ([`Kernel::eval_cached`], the scalar spelling of
    /// an `eval_block` column) and is folded into the alpha-weighted
    /// sum in SV order — no per-call buffer. Bit-identical to the
    /// corresponding [`SvddModel::dist2_batch`] entry (same per-pair
    /// values, same accumulation order).
    pub fn dist2(&self, z: &[f64]) -> f64 {
        let z_norm = linalg::dot(z, z);
        let mut k_sum = 0.0;
        for (i, &a) in self.alpha.iter().enumerate() {
            let k = self.kernel.eval_cached(self.sv.row(i), self.sv_norms.get(i), z, z_norm);
            k_sum += a * k;
        }
        self.kernel.diag_from_norm(z_norm) - 2.0 * k_sum + self.w
    }

    /// `dist2(z) > R^2`.
    pub fn is_outlier(&self, z: &[f64]) -> bool {
        self.dist2(z) > self.r2
    }

    /// Native batch scoring (the XLA-backed path lives in
    /// [`crate::scoring`]; this is the reference it is checked against).
    /// The batch's squared row norms are cached once, then rows are
    /// scored in parallel 64-row chunks on the global pool when the
    /// batch is large enough to pay for it; each chunk evaluates one
    /// `#SV x chunk` [`Kernel::eval_block`] panel and reduces it with
    /// alpha weights in SV order. Per-entry kernel values and the
    /// reduction order are independent of chunking, so the output is
    /// bit-identical to [`SvddModel::dist2`] per row at any thread
    /// count.
    pub fn dist2_batch(&self, zs: &Matrix) -> Vec<f64> {
        self.dist2_batch_pooled(zs, crate::parallel::global())
    }

    /// [`SvddModel::dist2_batch`] on an explicit pool.
    pub fn dist2_batch_pooled(&self, zs: &Matrix, pool: crate::parallel::Pool) -> Vec<f64> {
        let n = zs.rows();
        let nsv = self.sv.rows();
        let mut out = vec![0.0; n];
        let zs_norms = NormCache::new(zs);
        let work = n * nsv * self.sv.cols().max(1);
        // span only above the parallel-work floor so small-batch scoring
        // (the latency-sensitive path) never touches the clock
        let mut span = if work >= crate::parallel::MIN_PAR_WORK {
            crate::obs::Span::enter("score.dist2_batch")
        } else {
            crate::obs::Span::disabled()
        };
        if span.is_live() {
            span.u64("rows", n as u64);
            span.u64("num_sv", nsv as u64);
            span.str("isa", linalg::isa::selected_name());
        }
        pool.for_work(work).run_chunks(&mut out, 64, |start, chunk| {
            let cols = chunk.len();
            // K(sv, z) panel for this chunk of z rows (column-major per
            // z row: entry (i, off) at [i * cols + off])
            let mut panel = vec![0.0; nsv * cols];
            self.kernel.eval_block(
                &self.sv,
                &self.sv_norms,
                0..nsv,
                zs,
                &zs_norms,
                start..start + cols,
                &mut panel,
            );
            for (off, slot) in chunk.iter_mut().enumerate() {
                let mut k_sum = 0.0;
                for (i, &a) in self.alpha.iter().enumerate() {
                    k_sum += a * panel[i * cols + off];
                }
                let diag = self.kernel.diag_from_norm(zs_norms.get(start + off));
                *slot = diag - 2.0 * k_sum + self.w;
            }
        });
        out
    }

    /// One-time f32 narrowing of everything batch scoring needs — the
    /// opt-in `--precision f32` panel path (see [`ModelF32`]).
    pub fn to_f32(&self) -> ModelF32 {
        let sv = self.sv.to_f32();
        // norms are recomputed IN f32 (not narrowed from the f64 cache)
        // so they combine with the f32 dot panels the way the f64 norms
        // combine with f64 panels — one consistent precision per path
        let sv_norms = linalg::norms_f32(&sv, self.sv.cols());
        ModelF32 {
            sv,
            cols: self.sv.cols(),
            alpha: self.alpha.iter().map(|&a| a as f32).collect(),
            sv_norms,
            kernel: self.kernel,
            w: self.w as f32,
            r2: self.r2,
        }
    }

    // --------------------------------------------------- serialization

    pub fn to_json(&self) -> Json {
        let kernel = match self.kernel {
            Kernel::Gaussian { bw } => obj(vec![("type", s("gaussian")), ("bw", num(bw))]),
            Kernel::Linear => obj(vec![("type", s("linear"))]),
            Kernel::Polynomial { degree, coef } => obj(vec![
                ("type", s("polynomial")),
                ("degree", num(degree as f64)),
                ("coef", num(coef)),
            ]),
        };
        obj(vec![
            ("format", s("fastsvdd-model-v1")),
            ("kernel", kernel),
            ("r2", num(self.r2)),
            ("w", num(self.w)),
            ("dim", num(self.sv.cols() as f64)),
            ("alpha", arr(self.alpha.iter().map(|&a| num(a)).collect())),
            (
                "sv",
                arr(self
                    .sv
                    .as_slice()
                    .iter()
                    .map(|&v| num(v))
                    .collect()),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<SvddModel> {
        if v.req("format")?.as_str() != Some("fastsvdd-model-v1") {
            return Err(Error::invalid("unknown model format"));
        }
        let kj = v.req("kernel")?;
        let kernel = match kj.req("type")?.as_str() {
            Some("gaussian") => Kernel::gaussian(
                kj.req("bw")?
                    .as_f64()
                    .ok_or_else(|| Error::invalid("bw not a number"))?,
            ),
            Some("linear") => Kernel::Linear,
            Some("polynomial") => {
                // validate here and return Err — this is untrusted file
                // input, so the panicking constructor is out of place
                let degree = kj
                    .req("degree")?
                    .as_f64()
                    .ok_or_else(|| Error::invalid("polynomial degree not a number"))?;
                let coef = kj
                    .req("coef")?
                    .as_f64()
                    .ok_or_else(|| Error::invalid("polynomial coef not a number"))?;
                if !(1.0..=i32::MAX as f64).contains(&degree) || degree.fract() != 0.0 {
                    return Err(Error::invalid(format!(
                        "polynomial degree must be an integer in [1, {}], got {degree}",
                        i32::MAX
                    )));
                }
                if !coef.is_finite() {
                    return Err(Error::invalid(format!(
                        "polynomial coef must be finite, got {coef}"
                    )));
                }
                Kernel::polynomial(degree as u32, coef)
            }
            other => return Err(Error::invalid(format!("bad kernel type {other:?}"))),
        };
        let r2 = v.req("r2")?.as_f64().ok_or_else(|| Error::invalid("r2"))?;
        let w = v.req("w")?.as_f64().ok_or_else(|| Error::invalid("w"))?;
        let dim = v.req("dim")?.as_usize().ok_or_else(|| Error::invalid("dim"))?;
        let alpha: Vec<f64> = v
            .req("alpha")?
            .as_arr()
            .ok_or_else(|| Error::invalid("alpha"))?
            .iter()
            .filter_map(|x| x.as_f64())
            .collect();
        let flat: Vec<f64> = v
            .req("sv")?
            .as_arr()
            .ok_or_else(|| Error::invalid("sv"))?
            .iter()
            .filter_map(|x| x.as_f64())
            .collect();
        let rows = alpha.len();
        let sv = Matrix::from_vec(flat, rows, dim)?;
        SvddModel::new(sv, alpha, kernel, r2, w)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<SvddModel> {
        let text = std::fs::read_to_string(path)?;
        SvddModel::from_json(&Json::parse(&text)?)
    }
}

/// f32 batch-scoring view of a model — the opt-in `--precision f32`
/// panel path ([`SvddModel::to_f32`] narrows once, then every batch
/// scores through [`crate::linalg::dot_block_f32`] panels). This is the
/// same precision the XLA/AOT scoring boundary runs at, as a native
/// engine.
///
/// Results are **not** bit-comparable to the f64 path: the contract is
/// the relative-error bound documented in [`crate::linalg`]'s f32
/// section (property-tested in `tests/simd_dispatch.rs`). Within f32
/// the usual determinism policy holds — per-entry purity makes output
/// bit-identical across chunk shapes and thread counts, on every
/// non-fused arm. Distances are widened back to f64 at the end so
/// thresholding (`dist2 > R^2`) uses the model's exact f64 threshold.
#[derive(Clone, Debug)]
pub struct ModelF32 {
    sv: Vec<f32>,
    cols: usize,
    alpha: Vec<f32>,
    sv_norms: Vec<f32>,
    kernel: Kernel,
    w: f32,
    r2: f64,
}

impl ModelF32 {
    /// Decision threshold (kept in f64 — narrowing the threshold would
    /// move the decision boundary, narrowing distances only blurs it).
    pub fn r2(&self) -> f64 {
        self.r2
    }

    /// f32-path `dist2` for every row of `zs`, widened to f64.
    pub fn dist2_batch(&self, zs: &Matrix) -> Vec<f64> {
        self.dist2_batch_pooled(zs, crate::parallel::global())
    }

    /// [`ModelF32::dist2_batch`] on an explicit pool — the f32 mirror
    /// of [`SvddModel::dist2_batch_pooled`]: narrow the batch once,
    /// cache f32 row norms, then `#SV x chunk` f32 panels reduced with
    /// f32 alpha weights in SV order.
    pub fn dist2_batch_pooled(&self, zs: &Matrix, pool: crate::parallel::Pool) -> Vec<f64> {
        let n = zs.rows();
        let nsv = self.sv_norms.len();
        let m = self.cols;
        let mut out = vec![0.0; n];
        let zf = zs.to_f32();
        let z_norms = linalg::norms_f32(&zf, m);
        let work = n * nsv * m.max(1);
        let mut span = if work >= crate::parallel::MIN_PAR_WORK {
            crate::obs::Span::enter("score.dist2_batch")
        } else {
            crate::obs::Span::disabled()
        };
        if span.is_live() {
            span.u64("rows", n as u64);
            span.u64("num_sv", nsv as u64);
            span.str("isa", linalg::isa::selected_name());
            span.str("precision", "f32");
        }
        pool.for_work(work).run_chunks(&mut out, 64, |start, chunk| {
            let cols = chunk.len();
            let zchunk = &zf[start * m..(start + cols) * m];
            let mut panel = vec![0.0f32; nsv * cols];
            self.kernel.eval_block_f32(
                &self.sv,
                &self.sv_norms,
                zchunk,
                &z_norms[start..start + cols],
                m,
                &mut panel,
            );
            for (off, slot) in chunk.iter_mut().enumerate() {
                let mut k_sum = 0.0f32;
                for (i, &a) in self.alpha.iter().enumerate() {
                    k_sum += a * panel[i * cols + off];
                }
                let diag = self.kernel.diag_from_norm_f32(z_norms[start + off]);
                *slot = (diag - 2.0 * k_sum + self.w) as f64;
            }
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_model() -> SvddModel {
        // Two symmetric SVs around the origin, bw 1.
        let sv = Matrix::from_rows(&[vec![-1.0, 0.0], vec![1.0, 0.0]]).unwrap();
        let alpha = vec![0.5, 0.5];
        let kernel = Kernel::gaussian(1.0);
        let k12 = (-2.0f64).exp();
        let w = 0.5 * (1.0 + k12);
        // boundary point = an SV: dist2 = 1 - 2*(0.5*1 + 0.5*k12) + w
        let r2 = 1.0 - (1.0 + k12) + w;
        SvddModel::new(sv, alpha, kernel, r2, w).unwrap()
    }

    #[test]
    fn center_is_alpha_weighted_mean() {
        let m = toy_model();
        assert_eq!(m.center(), &[0.0, 0.0]);
        assert_eq!(m.dim(), 2);
        assert_eq!(m.num_sv(), 2);
    }

    #[test]
    fn svs_are_on_boundary() {
        let m = toy_model();
        assert!((m.dist2(&[1.0, 0.0]) - m.r2()).abs() < 1e-12);
        assert!((m.dist2(&[-1.0, 0.0]) - m.r2()).abs() < 1e-12);
    }

    #[test]
    fn origin_inside_far_outside() {
        let m = toy_model();
        assert!(!m.is_outlier(&[0.0, 0.0]));
        assert!(m.is_outlier(&[10.0, 10.0]));
    }

    #[test]
    fn far_point_dist2_approaches_one_plus_w() {
        let m = toy_model();
        let d = m.dist2(&[100.0, 0.0]);
        assert!((d - (1.0 + m.w())).abs() < 1e-12);
    }

    #[test]
    fn batch_matches_single() {
        let m = toy_model();
        let zs = Matrix::from_rows(&[vec![0.0, 0.0], vec![2.0, 1.0], vec![-3.0, 0.5]])
            .unwrap();
        let batch = m.dist2_batch(&zs);
        for i in 0..zs.rows() {
            assert_eq!(batch[i], m.dist2(zs.row(i)));
        }
    }

    #[test]
    fn f32_view_tracks_f64_scoring_within_tolerance() {
        let m = toy_model();
        let rows: Vec<Vec<f64>> = (0..150)
            .map(|i| {
                vec![
                    (i as f64) * 0.02 - 1.5,
                    ((i * 7) % 13) as f64 * 0.1 - 0.6,
                ]
            })
            .collect();
        let zs = Matrix::from_rows(&rows).unwrap();
        let f = m.to_f32();
        assert_eq!(f.r2(), m.r2());
        let got = f.dist2_batch(&zs);
        let want = m.dist2_batch(&zs);
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() <= 5e-5 * w.abs().max(1.0),
                "row {i}: f32 {g} vs f64 {w}"
            );
        }
    }

    #[test]
    fn json_roundtrip() {
        let m = toy_model();
        let j = m.to_json();
        let back = SvddModel::from_json(&j).unwrap();
        assert_eq!(back.num_sv(), m.num_sv());
        assert!((back.r2() - m.r2()).abs() < 1e-15);
        assert!((back.w() - m.w()).abs() < 1e-15);
        assert_eq!(back.alpha(), m.alpha());
        assert_eq!(back.support_vectors(), m.support_vectors());
        // scoring identical
        let z = [0.3, -0.7];
        assert!((back.dist2(&z) - m.dist2(&z)).abs() < 1e-15);
    }

    #[test]
    fn content_hash_stable_and_discriminating() {
        let m = toy_model();
        assert_eq!(m.content_hash(), m.clone().content_hash());
        assert_eq!(m.content_id(), format!("v-{:016x}", m.content_hash()));
        // JSON roundtrip preserves identity bit-for-bit
        let back = SvddModel::from_json(&m.to_json()).unwrap();
        assert_eq!(back.content_hash(), m.content_hash());
        // any scoring-relevant change moves the hash
        let other = SvddModel::new(
            m.support_vectors().clone(),
            m.alpha().to_vec(),
            m.kernel(),
            m.r2() * 1.01,
            m.w(),
        )
        .unwrap();
        assert_ne!(other.content_hash(), m.content_hash());
    }

    #[test]
    fn bad_polynomial_kernel_json_is_an_error_not_a_panic() {
        // untrusted model files must surface Err, never abort
        let with_degree = |degree: f64| {
            let mut j = toy_model().to_json();
            if let Json::Obj(fields) = &mut j {
                fields.insert(
                    "kernel".into(),
                    obj(vec![
                        ("type", s("polynomial")),
                        ("degree", num(degree)),
                        ("coef", num(1.0)),
                    ]),
                );
            }
            j
        };
        assert!(SvddModel::from_json(&with_degree(0.0)).is_err());
        assert!(SvddModel::from_json(&with_degree(-3.0)).is_err());
        assert!(SvddModel::from_json(&with_degree(1e12)).is_err());
        assert!(SvddModel::from_json(&with_degree(2.5)).is_err());
        assert!(SvddModel::from_json(&with_degree(2.0)).is_ok());
    }

    #[test]
    fn non_finite_models_rejected() {
        let sv = Matrix::from_rows(&[vec![0.0, 1.0]]).unwrap();
        let k = Kernel::gaussian(1.0);
        assert!(SvddModel::new(sv.clone(), vec![1.0], k, f64::NAN, 0.5).is_err());
        assert!(SvddModel::new(sv.clone(), vec![1.0], k, 0.5, f64::INFINITY).is_err());
        assert!(SvddModel::new(sv.clone(), vec![f64::NAN], k, 0.5, 0.5).is_err());
        let bad_sv = Matrix::from_rows(&[vec![0.0, f64::NEG_INFINITY]]).unwrap();
        assert!(SvddModel::new(bad_sv, vec![1.0], k, 0.5, 0.5).is_err());
        assert!(SvddModel::new(sv, vec![1.0], k, 0.5, 0.5).is_ok());
    }

    #[test]
    fn mismatched_construction_rejected() {
        let sv = Matrix::from_rows(&[vec![0.0]]).unwrap();
        assert!(SvddModel::new(sv, vec![0.5, 0.5], Kernel::Linear, 1.0, 1.0).is_err());
    }

    #[test]
    fn save_load_roundtrip() {
        let m = toy_model();
        let dir = std::env::temp_dir().join("fastsvdd_model_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.json");
        m.save(&path).unwrap();
        let back = SvddModel::load(&path).unwrap();
        assert_eq!(back.num_sv(), 2);
        std::fs::remove_file(&path).ok();
    }
}
