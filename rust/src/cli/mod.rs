//! Command-line argument parsing (clap is not in the vendored crate
//! set). Supports `--key value`, `--flag`, positional subcommands and
//! generated help text.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed arguments: command (+ optional action) + options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    /// Second positional, for verbs with actions
    /// (`fastsvdd registry list|promote|rollback|gc`). Empty otherwise.
    pub action: String,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. The first non-flag token is the command, the
    /// second (if any) the action; a third positional is an error.
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err(Error::Config("bare '--' not supported".into()));
                }
                // --key=value or --key value or --flag
                if let Some((k, v)) = name.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    args.opts.insert(name.to_string(), it.next().unwrap().clone());
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.command.is_empty() {
                args.command = tok.clone();
            } else if args.action.is_empty() {
                args.action = tok.clone();
            } else {
                return Err(Error::Config(format!("unexpected positional '{tok}'")));
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name}: '{v}' is not an integer"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name}: '{v}' is not a number"))),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name}: '{v}' is not an integer"))),
        }
    }

    /// Reject any option/flag not in the allowed list (typo guard).
    pub fn expect_only(&self, allowed: &[&str]) -> Result<()> {
        for k in self.opts.keys().chain(self.flags.iter()) {
            if !allowed.contains(&k.as_str()) {
                return Err(Error::Config(format!(
                    "unknown option '--{k}' for '{}' (allowed: {})",
                    self.command,
                    allowed.join(", ")
                )));
            }
        }
        Ok(())
    }
}

/// Top-level help text for the launcher.
pub const HELP: &str = "\
fastsvdd — sampling-based SVDD training (Chaudhuri et al., SAS 2016)

USAGE:
    fastsvdd <COMMAND> [OPTIONS]

COMMANDS:
    train        Train a model (sampling | full | luo | kim | distributed)
    score        Score data against a saved model
    grid         Score a 200x200 grid, write a PGM + agreement stats
    worker       Run a TCP worker daemon for distributed training
    serve        Run a TCP scoring server (dynamic batching over the
                 native or XLA engine; hot-swappable model)
    registry     Manage a versioned model registry
                 (list | promote | rollback | gc)
    artifacts    Inspect the AOT artifact manifest
    help         Show this help

COMMON OPTIONS (train):
    --config <file.json>      load a RunConfig (CLI overrides apply on top)
    --data <name>             banana | star | two-donut | shuttle | tennessee
    --rows <n>                training rows to generate
    --method <m>              sampling | full | luo | kim | distributed
    --bw <s>                  Gaussian bandwidth
    --f <frac>                expected outlier fraction
    --sample-size <n>         Algorithm-1 sample size
    --candidates <k>          independent candidate samples per iteration,
                              solved concurrently; best R^2 wins (default 1)
    --workers <p>             distributed worker count
    --shuffle-seed <s>        seeded pre-shuffle of the row order before
                              distributed sharding (for ordered datasets;
                              default: shard rows as given)
    --threads <auto|n>        worker threads for the shared parallel pool
                              (Gram rows, SMO kernel columns, batch scoring;
                              default auto = all cores). Results are
                              bit-identical at any thread count.
    --seed <u64>              RNG seed
    --out <model.json>        save the trained model
    --trace <csv>             write the R^2 iteration trace (Fig 7)
    --registry <dir>          publish the trained model to a registry
    --promote                 also promote it to champion

score:
    --model <model.json> --data <name> --rows <n> [--xla] [--artifacts <dir>]
    [--threads auto|n]

worker:
    --listen <addr:port>

serve:
    --model <model.json> --listen <addr:port> [--xla] [--batch <rows>]
    [--linger-ms <ms>] [--threads auto|n]
    --registry <dir>          serve the registry champion instead of a file
    --watch                   poll the registry; hot-swap on promote
                              (zero dropped connections)
    --watch-interval-ms <ms>  champion poll interval (default 1000)
    --allow-remote-swap       accept the unauthenticated v2 SwapModel
                              frame from clients (off by default)

registry (directory layout: manifest.json + models/v-<16 hex>.json,
content-addressed; see src/registry/):
    list      --dir <dir>                    all versions + champion marker
    promote   --dir <dir> --version <v-...>  make a version the champion
    rollback  --dir <dir>                    restore the previous champion
    gc        --dir <dir> [--keep <n>]       prune old versions (default 5)

EXAMPLES:
    fastsvdd train --data banana --rows 11016 --method sampling --sample-size 6
    fastsvdd train --data two-donut --rows 1333334 --method distributed --workers 8
    fastsvdd score --model m.json --data shuttle --rows 10000 --xla
    fastsvdd train --data tennessee --rows 20000 --registry reg/ --promote
    fastsvdd serve --registry reg/ --watch --listen 0.0.0.0:7800
    fastsvdd registry list --dir reg/
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse(&tokens.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parses_command_options_flags() {
        let a = parse(&["train", "--data", "banana", "--rows", "100", "--xla"]);
        assert_eq!(a.command, "train");
        assert_eq!(a.get("data"), Some("banana"));
        assert_eq!(a.get_usize("rows", 0).unwrap(), 100);
        assert!(a.flag("xla"));
        assert!(!a.flag("other"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse(&["score", "--rows=42", "--bw=0.5"]);
        assert_eq!(a.get_usize("rows", 0).unwrap(), 42);
        assert_eq!(a.get_f64("bw", 0.0).unwrap(), 0.5);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["train"]);
        assert_eq!(a.get_or("data", "banana"), "banana");
        assert_eq!(a.get_u64("seed", 7).unwrap(), 7);
    }

    #[test]
    fn bad_number_rejected() {
        let a = parse(&["train", "--rows", "abc"]);
        assert!(a.get_usize("rows", 0).is_err());
    }

    #[test]
    fn action_positional_parsed() {
        let a = parse(&["registry", "promote", "--dir", "reg", "--version", "v-1"]);
        assert_eq!(a.command, "registry");
        assert_eq!(a.action, "promote");
        assert_eq!(a.get("dir"), Some("reg"));
        let b = parse(&["train"]);
        assert!(b.action.is_empty());
    }

    #[test]
    fn triple_positional_rejected() {
        let argv: Vec<String> = ["registry", "list", "extra"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert!(Args::parse(&argv).is_err());
    }

    #[test]
    fn expect_only_guards_typos() {
        let a = parse(&["train", "--rowz", "5"]);
        assert!(a.expect_only(&["rows"]).is_err());
        let b = parse(&["train", "--rows", "5"]);
        assert!(b.expect_only(&["rows"]).is_ok());
    }

    #[test]
    fn trailing_flag_then_option() {
        let a = parse(&["train", "--xla", "--rows", "9"]);
        assert!(a.flag("xla"));
        assert_eq!(a.get_usize("rows", 0).unwrap(), 9);
    }
}
