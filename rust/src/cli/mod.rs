//! Command-line argument parsing (clap is not in the vendored crate
//! set). Supports `--key value`, `--flag`, positional subcommands and
//! generated help text.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed arguments: command (+ optional action) + options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    /// Second positional, for verbs with actions
    /// (`fastsvdd registry list|promote|rollback|gc`). Empty otherwise.
    pub action: String,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. The first non-flag token is the command, the
    /// second (if any) the action; a third positional is an error.
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err(Error::Config("bare '--' not supported".into()));
                }
                // --key=value or --key value or --flag
                if let Some((k, v)) = name.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| value_like(n.as_str())).unwrap_or(false) {
                    args.opts.insert(name.to_string(), it.next().unwrap().clone());
                } else {
                    args.flags.push(name.to_string());
                }
            } else if short_flag(tok) {
                // single-letter short flag (`-v`); never takes a value
                args.flags.push(tok[1..].to_string());
            } else if args.command.is_empty() {
                args.command = tok.clone();
            } else if args.action.is_empty() {
                args.action = tok.clone();
            } else {
                return Err(Error::Config(format!("unexpected positional '{tok}'")));
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name}: '{v}' is not an integer"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name}: '{v}' is not a number"))),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name}: '{v}' is not an integer"))),
        }
    }

    /// Reject any option/flag not in the allowed list (typo guard).
    pub fn expect_only(&self, allowed: &[&str]) -> Result<()> {
        for k in self.opts.keys().chain(self.flags.iter()) {
            if !allowed.contains(&k.as_str()) {
                return Err(Error::Config(format!(
                    "unknown option '--{k}' for '{}' (allowed: {})",
                    self.command,
                    allowed.join(", ")
                )));
            }
        }
        Ok(())
    }
}

/// A `-x` token with a single ASCII letter: a short flag. A negative
/// number (`-1`, `-0.5`) is not — it stays consumable as an option
/// value (`--bw -1`), while a boolean flag followed by a short flag
/// (`--warm-alpha -v`) parses as two flags instead of silently eating
/// `-v` as the boolean's "value".
fn short_flag(tok: &str) -> bool {
    tok.len() == 2 && tok.starts_with('-') && tok.as_bytes()[1].is_ascii_alphabetic()
}

/// Whether a peeked token may serve as an option value.
fn value_like(tok: &str) -> bool {
    !tok.starts_with("--") && !short_flag(tok)
}

/// Top-level help text for the launcher.
pub const HELP: &str = "\
fastsvdd — sampling-based SVDD training (Chaudhuri et al., SAS 2016)

USAGE:
    fastsvdd <COMMAND> [OPTIONS]

COMMANDS:
    train        Train a model (sampling | full | luo | kim | distributed |
                 streaming | incremental | reduction) — every method runs
                 through the unified training engine
    score        Score data against a saved model
    grid         Score a 200x200 grid, write a PGM + agreement stats
    worker       Run a TCP worker daemon for distributed training
    serve        Run a TCP scoring server (dynamic batching over the
                 native or XLA engine; hot-swappable model)
    registry     Manage a versioned model registry
                 (list | promote | rollback | gc)
    artifacts    Inspect the AOT artifact manifest
    report       Render per-stage timings + the R^2 convergence trace
                 from a --log-json run log
    help         Show this help

COMMON OPTIONS (train):
    --config <file.json>      load a RunConfig (CLI overrides apply on top)
    --data <name>             banana | star | two-donut | shuttle | tennessee
    --rows <n>                training rows to generate
    --method <m>              sampling | full | luo | kim | distributed |
                              streaming (windowed snapshot) |
                              incremental (exact online add/remove) |
                              reduction (boundary-preserving sample
                              reduction, then one solve on the kept rows)
    --bw <s>                  Gaussian bandwidth
    --bandwidth <v>           a number sets the bandwidth directly;
                              auto:mean | auto:median resolve it from the
                              training data with the closed-form
                              mean/median pairwise-distance criterion
    --stale-budget <n>        incremental: add/remove updates tolerated
                              before a full re-solve resync of the active
                              set (default 64; 0 = resync only on
                              divergence)
    --divergence <tol>        incremental: KKT gap that forces an early
                              resync when the adjust loop stalls above it
                              (default 1e-3)
    --reduction-target <n>    reduction: rows kept for the final solve
                              (default 0 = auto, max(50, n/10))
    --stream-incremental      streaming: slide the window with per-point
                              incremental updates instead of snapshot
                              retrains (drift judged at window-sized
                              checkpoints)
    --f <frac>                expected outlier fraction
    --sample-size <n>         Algorithm-1 sample size
    --candidates <k>          independent candidate samples per iteration,
                              solved concurrently; best R^2 wins (default 1)
    --warm-alpha              carry each union solve's dual solution into
                              the next iteration (warm-started SMO; off by
                              default — cold init is the seeded historical
                              reference)
    --wss <rule>              SMO working-set selection: second (default) |
                              first (max violating pair) | legacy (the
                              pre-Solver loop, byte-for-byte reproducible;
                              implies no shrinking and cold init)
    --no-shrinking            disable SMO active-set shrinking
    -v                        verbose training output (solver telemetry:
                              SMO iterations, shrink/unshrink events,
                              final gap, kernel-cache hit rate)
    --workers <p>             distributed worker count
    --shuffle-seed <s>        seeded pre-shuffle of the row order before
                              distributed sharding (for ordered datasets;
                              default: shard rows as given)
    --addrs <a:p,a:p,...>     TCP worker addresses for distributed
                              training (default: in-process workers)
    --combine <mode>          distributed SV-set combine: flat (one union
                              solve, the paper's scheme; default) | tree |
                              tree:<fanout> (hierarchical solves; same
                              description within tolerance, smaller root
                              solve)
    --max-retries <n>         extra attempts a failed shard is granted
                              before the run fails (default 2)
    --worker-timeout-ms <ms>  per-attempt socket deadline and heartbeat
                              probe window for TCP workers (default 30000)
    --min-workers <n>         degrade to in-controller training when fewer
                              than this many TCP workers remain alive
                              (default 1; zero live workers always fails)
    --stream-chunk <rows>     with --method distributed + --addrs and a
                              CSV --data: stream the file to workers in
                              chunks of this many rows (one chunk = one
                              shard) instead of materialising it (0 = off)
    --threads <auto|n>        worker threads for the shared parallel pool
                              (Gram rows, SMO kernel columns, batch scoring;
                              default auto = all cores). Results are
                              bit-identical at any thread count.
    --isa <arm>               kernel microkernel ISA: auto (default) |
                              avx2 | fma | neon | scalar. auto picks the
                              best bit-identical arm for the host (AVX2
                              on x86-64, NEON on aarch64); avx2/neon/
                              scalar are bit-identical to each other,
                              fma is opt-in only (fused rounding changes
                              low bits). FASTSVDD_ISA=<arm> sets the
                              same knob; an explicit unavailable --isa
                              is an error.
    --seed <u64>              RNG seed
    --out <model.json>        save the trained model
    --trace <csv>             write the R^2 iteration trace (Fig 7)
    --log-json <file.jsonl>   enable tracing and stream every span/event
                              as one JSON line (render: fastsvdd report)
    --registry <dir>          publish the trained model to a registry
    --promote                 also promote it to champion

score:
    --model <model.json> --data <name> --rows <n> [--xla] [--artifacts <dir>]
    [--threads auto|n] [--isa <arm>] [--precision f64|f32]
    [--config <file.json>]
    (data/rows/seed/scorer default to the RunConfig defaults, so score
    and train share one config file)
    --precision f32           score through the narrowed f32 panel path
                              (same precision as the XLA boundary,
                              without the runtime). Distances carry a
                              documented relative-error bound vs the
                              f64 reference; thresholding still uses
                              the exact f64 R^2. Default f64.

worker:
    --listen <addr:port>
    --faults <spec>           deterministic fault injection for chaos
                              tests: comma-separated kill_after=<n>,
                              delay_ms=<ms>, corrupt_at=<n>, drop_at=<n>
                              (n counts Train replies; also readable from
                              FASTSVDD_FAULTS)

serve:
    --model <model.json> --listen <addr:port> [--xla] [--batch <rows>]
    [--linger-ms <ms>] [--threads auto|n] [--config <file.json>]
    --registry <dir>          serve the registry champion instead of a file
    --watch                   poll the registry; hot-swap on promote
                              (zero dropped connections)
    --watch-interval-ms <ms>  champion poll interval (default 1000)
    --allow-remote-swap       accept the unauthenticated v2 SwapModel
                              frame from clients (off by default)
    --http                    enable the POST /score HTTP/JSON ingress on
                              the same port (off by default):
                                curl -d '{"rows": [[0.1, 0.2]]}' \
                                  http://<addr>/score
    --batch-window-us <us>    micro-batch linger ceiling in microseconds
                              (default 2000; the window adapts below it
                              under light load; overrides --linger-ms)
    --max-inflight <rows>     rows in flight to the batcher before the
                              edge sheds with 503 / an Overloaded frame
                              (default 65536)
    --max-conns <n>           concurrent-connection cap (default 1024)
    The listener multiplexes native frames, HTTP scoring and Prometheus
    scrapes on one port:
        curl http://<addr>/metrics

report:
    --log <file.jsonl>        a train --log-json run log; prints the
                              per-stage timing table and the R^2 trace

registry (directory layout: manifest.json + models/v-<16 hex>.json,
content-addressed; see src/registry/):
    list      --dir <dir>                    all versions + champion marker
    promote   --dir <dir> --version <v-...>  make a version the champion
    rollback  --dir <dir>                    restore the previous champion
    gc        --dir <dir> [--keep <n>]       prune old versions (default 5)

EXAMPLES:
    fastsvdd train --data banana --rows 11016 --method sampling --sample-size 6
    fastsvdd train --data two-donut --rows 1333334 --method distributed --workers 8
    fastsvdd score --model m.json --data shuttle --rows 10000 --xla
    fastsvdd train --data tennessee --rows 20000 --registry reg/ --promote
    fastsvdd serve --registry reg/ --watch --listen 0.0.0.0:7800
    fastsvdd registry list --dir reg/
    fastsvdd train --data banana --rows 50000 --log-json run.jsonl
    fastsvdd report --log run.jsonl
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse(&tokens.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parses_command_options_flags() {
        let a = parse(&["train", "--data", "banana", "--rows", "100", "--xla"]);
        assert_eq!(a.command, "train");
        assert_eq!(a.get("data"), Some("banana"));
        assert_eq!(a.get_usize("rows", 0).unwrap(), 100);
        assert!(a.flag("xla"));
        assert!(!a.flag("other"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse(&["score", "--rows=42", "--bw=0.5"]);
        assert_eq!(a.get_usize("rows", 0).unwrap(), 42);
        assert_eq!(a.get_f64("bw", 0.0).unwrap(), 0.5);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["train"]);
        assert_eq!(a.get_or("data", "banana"), "banana");
        assert_eq!(a.get_u64("seed", 7).unwrap(), 7);
    }

    #[test]
    fn bad_number_rejected() {
        let a = parse(&["train", "--rows", "abc"]);
        assert!(a.get_usize("rows", 0).is_err());
    }

    #[test]
    fn action_positional_parsed() {
        let a = parse(&["registry", "promote", "--dir", "reg", "--version", "v-1"]);
        assert_eq!(a.command, "registry");
        assert_eq!(a.action, "promote");
        assert_eq!(a.get("dir"), Some("reg"));
        let b = parse(&["train"]);
        assert!(b.action.is_empty());
    }

    #[test]
    fn triple_positional_rejected() {
        let argv: Vec<String> = ["registry", "list", "extra"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert!(Args::parse(&argv).is_err());
    }

    #[test]
    fn expect_only_guards_typos() {
        let a = parse(&["train", "--rowz", "5"]);
        assert!(a.expect_only(&["rows"]).is_err());
        let b = parse(&["train", "--rows", "5"]);
        assert!(b.expect_only(&["rows"]).is_ok());
    }

    #[test]
    fn trailing_flag_then_option() {
        let a = parse(&["train", "--xla", "--rows", "9"]);
        assert!(a.flag("xla"));
        assert_eq!(a.get_usize("rows", 0).unwrap(), 9);
    }

    #[test]
    fn short_flag_parses() {
        let a = parse(&["train", "-v", "--rows", "9"]);
        assert_eq!(a.command, "train");
        assert!(a.flag("v"));
        assert_eq!(a.get_usize("rows", 0).unwrap(), 9);
        // a negative option value is still consumed as a value
        let b = parse(&["train", "--bw", "-1"]);
        assert_eq!(b.get_f64("bw", 0.0).unwrap(), -1.0);
        assert!(!b.flag("1"));
    }

    #[test]
    fn boolean_flag_does_not_eat_short_flag() {
        let a = parse(&["train", "--warm-alpha", "-v", "--rows", "9"]);
        assert!(a.flag("warm-alpha"), "--warm-alpha swallowed by -v");
        assert!(a.flag("v"));
        assert_eq!(a.get("warm-alpha"), None);
        assert_eq!(a.get_usize("rows", 0).unwrap(), 9);
    }
}
