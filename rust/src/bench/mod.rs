//! Measurement harness for the paper-reproduction benches (criterion is
//! not in the vendored crate set, so this provides the same core loop:
//! warmup, timed iterations, robust summary stats) plus a results sink
//! that writes each bench's table as text + CSV under `results/`.

pub mod paper;

use std::path::{Path, PathBuf};

use crate::util::stats;
use crate::util::tables::Table;
use crate::util::timer::Stopwatch;

/// Summary of repeated timed runs.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    pub mean: f64,
    pub std_dev: f64,
    pub min: f64,
    pub median: f64,
    pub max: f64,
    pub iters: usize,
}

/// Time `f` with `warmup` unmeasured runs and `iters` measured runs.
pub fn measure<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Measurement {
    assert!(iters > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let sw = Stopwatch::start();
        std::hint::black_box(f());
        times.push(sw.elapsed_secs());
    }
    Measurement {
        mean: stats::mean(&times),
        std_dev: stats::std_dev(&times),
        min: stats::quantile(&times, 0.0),
        median: stats::quantile(&times, 0.5),
        max: stats::quantile(&times, 1.0),
        iters,
    }
}

/// Time a single run (for expensive end-to-end cells where repeating is
/// wasteful — the paper's own tables are single runs).
pub fn measure_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let sw = Stopwatch::start();
    let out = std::hint::black_box(f());
    (out, sw.elapsed_secs())
}

/// Where bench outputs land: `$FASTSVDD_RESULTS` or `./results`.
pub fn results_dir() -> PathBuf {
    let dir = std::env::var_os("FASTSVDD_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("results"));
    std::fs::create_dir_all(&dir).ok();
    dir
}

/// Print a table and persist it (text + CSV) under the results dir.
pub fn emit(name: &str, table: &Table) {
    let rendered = table.render();
    println!("{rendered}");
    let dir = results_dir();
    let _ = std::fs::write(dir.join(format!("{name}.txt")), &rendered);
    let _ = std::fs::write(dir.join(format!("{name}.csv")), table.to_csv());
}

/// Persist an arbitrary text blob alongside the tables.
pub fn emit_text(name: &str, text: &str) {
    let _ = std::fs::write(results_dir().join(name), text);
}

/// Quick/full switch: benches honour `FASTSVDD_BENCH_SCALE` in (0, 1]
/// to shrink workloads for smoke runs (1.0 = paper scale).
pub fn bench_scale() -> f64 {
    std::env::var("FASTSVDD_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .filter(|&s| s > 0.0 && s <= 1.0)
        .unwrap_or(1.0)
}

/// Scale an observation count by [`bench_scale`], keeping a floor.
pub fn scaled(n: usize, floor: usize) -> usize {
    ((n as f64 * bench_scale()) as usize).max(floor)
}

/// True when a path looks like a built artifact dir (skip-with-message
/// guard for benches that need `make artifacts`).
pub fn artifacts_available(dir: &Path) -> bool {
    dir.join("manifest.json").exists()
}

/// Provenance pairs every perf bench stamps into its JSON artifact: the
/// dispatched kernel ISA arm and the build's target arch. The CI gate
/// reads these to prove SIMD actually engaged on the runner
/// (`ci/check_perf.py --forbid-scalar-isa`).
pub fn isa_provenance() -> Vec<(&'static str, crate::util::json::Json)> {
    use crate::util::json::s;
    vec![
        ("isa", s(crate::linalg::isa::selected_name())),
        ("arch", s(std::env::consts::ARCH)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_returns_sane_stats() {
        let m = measure(1, 5, || {
            std::thread::sleep(std::time::Duration::from_millis(2));
        });
        assert_eq!(m.iters, 5);
        assert!(m.min >= 0.002);
        assert!(m.mean >= m.min && m.mean <= m.max);
        assert!(m.median >= m.min && m.median <= m.max);
    }

    #[test]
    fn measure_once_returns_value() {
        let (v, t) = measure_once(|| 7);
        assert_eq!(v, 7);
        assert!(t >= 0.0);
    }

    #[test]
    fn scale_defaults_to_one() {
        // (cannot set env safely in parallel tests; just check default path)
        assert!(bench_scale() > 0.0 && bench_scale() <= 1.0);
        assert_eq!(scaled(100, 10).max(10), scaled(100, 10));
    }
}
