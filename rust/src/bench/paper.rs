//! Paper-experiment constants shared by all bench harnesses: the three
//! evaluation data sets with bandwidths calibrated so the full-SVDD
//! baseline lands near the paper's Table I (R^2, #SV), plus the paper's
//! reported values for side-by-side comparison in the bench output.

use crate::config::{Method, RunConfig};
use crate::data::shape_by_name;
use crate::svdd::trainer::SvddParams;
use crate::util::matrix::Matrix;

/// One row of Table I / Table II with our calibrated parameters.
#[derive(Clone, Copy, Debug)]
pub struct PaperDataset {
    pub name: &'static str,
    /// Paper's full-method training size.
    pub full_rows: usize,
    /// Our calibrated Gaussian bandwidth (the paper never states s).
    pub bw: f64,
    /// Outlier fraction f.
    pub f: f64,
    /// Table II sample size (in parentheses in the paper).
    pub sample_size: usize,
    /// Paper-reported values for the comparison columns.
    pub paper_r2_full: f64,
    pub paper_sv_full: usize,
    pub paper_time_full: &'static str,
    pub paper_iters_sampling: usize,
    pub paper_r2_sampling: f64,
    pub paper_sv_sampling: usize,
    pub paper_time_sampling: &'static str,
}

pub const BANANA: PaperDataset = PaperDataset {
    name: "banana",
    full_rows: 11_016,
    bw: 0.35,
    f: 0.001,
    sample_size: 6,
    paper_r2_full: 0.8789,
    paper_sv_full: 21,
    paper_time_full: "1.98 sec",
    paper_iters_sampling: 119,
    paper_r2_sampling: 0.872,
    paper_sv_sampling: 19,
    paper_time_sampling: "0.32 sec",
};

pub const TWO_DONUT: PaperDataset = PaperDataset {
    name: "two-donut",
    full_rows: 1_333_334,
    bw: 0.5,
    f: 0.001,
    sample_size: 11,
    paper_r2_full: 0.8982,
    paper_sv_full: 178,
    paper_time_full: "32 min",
    paper_iters_sampling: 157,
    paper_r2_sampling: 0.897,
    paper_sv_sampling: 37,
    paper_time_sampling: "0.29 sec",
};

pub const STAR: PaperDataset = PaperDataset {
    name: "star",
    full_rows: 64_000,
    bw: 0.17,
    f: 0.001,
    sample_size: 11,
    paper_r2_full: 0.9362,
    paper_sv_full: 76,
    paper_time_full: "11.55 sec",
    paper_iters_sampling: 141,
    paper_r2_sampling: 0.932,
    paper_sv_sampling: 44,
    paper_time_sampling: "0.28 sec",
};

pub const ALL: [PaperDataset; 3] = [BANANA, TWO_DONUT, STAR];

impl PaperDataset {
    pub fn params(&self) -> SvddParams {
        SvddParams::gaussian(self.bw, self.f)
    }

    /// A [`RunConfig`] for training this dataset with `method` — the
    /// benches' uniform entry into [`crate::engine::Engine`], so a
    /// harness iterates methods generically instead of calling each
    /// method's own function.
    pub fn run_config(&self, method: Method, rows: usize, seed: u64) -> RunConfig {
        RunConfig {
            dataset: self.name.into(),
            rows,
            bandwidth: self.bw,
            outlier_fraction: self.f,
            method,
            sample_size: self.sample_size,
            seed,
            ..RunConfig::default()
        }
    }

    pub fn generate(&self, rows: usize, seed: u64) -> Matrix {
        shape_by_name(self.name)
            .expect("paper dataset name must resolve")
            .generate(rows, seed)
    }

    /// The full-method training size, shrunk by the bench scale and
    /// capped (full SVDD at the paper's 1.33 M rows would take hours on
    /// this solver; DESIGN.md section 2 documents the substitution —
    /// Fig 1's power-law fit extrapolates the full curve instead).
    pub fn full_rows_scaled(&self, cap: usize) -> usize {
        super::scaled(self.full_rows.min(cap), 1000)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn datasets_resolve_and_generate() {
        for d in ALL {
            let m = d.generate(100, 1);
            assert_eq!(m.rows(), 100);
            assert_eq!(m.cols(), 2);
            assert!(d.params().kernel.bw().unwrap() > 0.0);
        }
    }

    #[test]
    fn scaled_full_rows_capped() {
        assert!(TWO_DONUT.full_rows_scaled(200_000) <= 200_000);
        assert!(BANANA.full_rows_scaled(200_000) <= 11_016);
    }

    #[test]
    fn run_config_valid_for_every_dataset_and_method() {
        for d in ALL {
            for m in Method::ALL {
                let cfg = d.run_config(m, 1000, 7);
                cfg.validate().unwrap_or_else(|e| panic!("{}/{m}: {e}", d.name));
                assert_eq!(cfg.method, m);
                assert_eq!(cfg.sample_size, d.sample_size);
            }
        }
    }
}
