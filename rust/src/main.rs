//! `fastsvdd` — the launcher binary: train/score/serve entry points
//! over the library (see `cli::HELP`).

use std::path::Path;

use fastsvdd::cli::{Args, HELP};
use fastsvdd::config::RunConfig;
use fastsvdd::data::grid::Grid;
use fastsvdd::data::shuttle::Shuttle;
use fastsvdd::data::tennessee::TennesseePlant;
use fastsvdd::data::{shape_by_name, LabeledData};
use fastsvdd::distributed::tcp::WorkerServer;
use fastsvdd::engine::Engine;
use fastsvdd::error::{Error, Result};
use fastsvdd::parallel::{self, ParallelismConfig, ThreadCount};
use fastsvdd::registry::{sync_champion, Registry, VersionId, VersionMeta};
use fastsvdd::runtime::SharedRuntime;
use fastsvdd::scoring::{F1Score, Scorer};
use fastsvdd::svdd::{SolverStats, SvddModel};
use fastsvdd::util::matrix::Matrix;
use fastsvdd::util::tables::{f, Table};
use fastsvdd::util::timer::{fmt_duration, Stopwatch};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    if args.command != "registry" && !args.action.is_empty() {
        return Err(Error::Config(format!(
            "unexpected positional '{}'",
            args.action
        )));
    }
    match args.command.as_str() {
        "train" => cmd_train(&args),
        "score" => cmd_score(&args),
        "grid" => cmd_grid(&args),
        "worker" => cmd_worker(&args),
        "serve" => cmd_serve(&args),
        "registry" => cmd_registry(&args),
        "artifacts" => cmd_artifacts(&args),
        "report" => cmd_report(&args),
        "" | "help" => {
            print!("{HELP}");
            Ok(())
        }
        other => Err(Error::Config(format!("unknown command '{other}'; try help"))),
    }
}

/// `train -v`: one line of SMO telemetry (iterations, shrink/unshrink
/// events, final gap, kernel-cache hit rate) instead of dropping it.
fn print_solver_stats(stats: &SolverStats) {
    let hit = match stats.cache_hit_rate() {
        Some(r) => format!("{:.1}%", r * 100.0),
        None => "n/a (dense gram)".into(),
    };
    println!(
        "  solver: smo_iters={} shrinks={} unshrinks={} final_gap={:.3e} cache_hits={hit}",
        stats.smo_iterations, stats.shrink_events, stats.unshrink_events, stats.gap
    );
}

/// Install the global thread pool from a bare `--threads` flag (the
/// commands that don't go through `RunConfig`).
fn install_threads_arg(args: &Args) -> Result<()> {
    if let Some(v) = args.get("threads") {
        parallel::install(ParallelismConfig { threads: ThreadCount::parse(v)? });
    }
    Ok(())
}

/// Materialize a named training set.
fn training_data(name: &str, rows: usize, seed: u64) -> Result<Matrix> {
    if let Some(g) = shape_by_name(name) {
        return Ok(g.generate(rows, seed));
    }
    match name {
        "shuttle" => Ok(Shuttle.training(rows, seed)),
        "tennessee" => Ok(TennesseePlant::default().training(rows, seed)),
        path if Path::new(path).exists() => {
            let (m, _) = fastsvdd::data::csv::read_matrix(Path::new(path), true)?;
            Ok(m)
        }
        other => Err(Error::Config(format!("unknown dataset '{other}'"))),
    }
}

/// Labeled scoring set for the F1 data sets.
fn scoring_data(name: &str, rows: usize, seed: u64) -> Result<LabeledData> {
    match name {
        "shuttle" => Ok(Shuttle.scoring(rows, seed)),
        "tennessee" => {
            let normal = rows / 2;
            Ok(TennesseePlant::default().scoring(normal, rows - normal, seed))
        }
        other => {
            // geometric sets: every generated point is a true inside point
            let data = training_data(other, rows, seed)?;
            let labels = vec![true; data.rows()];
            Ok(LabeledData::new(data, labels))
        }
    }
}

/// Parse a comma-separated `--addrs` list of worker socket addresses.
fn parse_addrs(spec: &str) -> Result<Vec<std::net::SocketAddr>> {
    spec.split(',')
        .map(|a| {
            a.parse()
                .map_err(|_| Error::Config(format!("bad worker address '{a}'")))
        })
        .collect()
}

fn cmd_train(args: &Args) -> Result<()> {
    args.expect_only(&[
        "config", "data", "rows", "method", "bw", "f", "sample-size", "max-iter",
        "candidates", "workers", "shuffle-seed", "threads", "isa", "seed", "out",
        "trace", "xla", "artifacts", "addrs", "registry", "promote", "warm-alpha",
        "wss", "no-shrinking", "v", "log-json", "combine", "max-retries",
        "worker-timeout-ms", "min-workers", "stream-chunk", "bandwidth",
        "stale-budget", "divergence", "reduction-target", "stream-incremental",
    ])?;
    let mut cfg = RunConfig::from_args(args)?;
    parallel::install(cfg.parallelism());
    fastsvdd::linalg::isa::install(cfg.isa)?;
    // tracing is opt-in: --log-json turns the span layer on and streams
    // every event as one JSON line (render later with `fastsvdd report`)
    if let Some(path) = args.get("log-json") {
        fastsvdd::obs::install_sink(Path::new(path))?;
        fastsvdd::obs::enable();
    }
    if cfg.stream_chunk > 0 {
        let result = train_streaming_distributed(args, &cfg);
        if let Some(path) = args.get("log-json") {
            fastsvdd::obs::disable();
            fastsvdd::obs::remove_sink();
            println!("run log written to {path} (render with: fastsvdd report --log {path})");
        }
        return result;
    }
    let data = training_data(&cfg.dataset, cfg.rows, cfg.seed)?;
    // --bandwidth auto:mean|auto:median: resolve sigma from the data
    // with the closed-form criterion before the engine is built
    if let Some(crit) = cfg.bandwidth_auto {
        cfg.bandwidth = crit.resolve(&data);
        println!("bandwidth auto:{} resolved to s={:.6}", crit.name(), cfg.bandwidth);
    }
    let engine = Engine::from_config(&cfg)?;
    println!(
        "training: data={} rows={} method={} kernel={} f={} threads={} isa={}",
        cfg.dataset,
        data.rows(),
        cfg.method,
        cfg.params().kernel,
        cfg.outlier_fraction,
        parallel::global().threads(),
        fastsvdd::linalg::isa::selected_name(),
    );

    // One uniform path for every method: sample/union grams go through
    // the shared pool (bit-identical to the lazy path; trainers that
    // precompute no grams ignore the backend), traces are recorded when
    // asked for, TCP worker addresses ride along for the distributed
    // trainer.
    let pooled = fastsvdd::parallel::PooledGram::new();
    let mut ctx = engine.context().with_backend(&pooled);
    ctx.sampling.record_trace = args.get("trace").is_some();
    if let Some(addrs) = args.get("addrs") {
        ctx.addrs = parse_addrs(addrs)?;
    }
    let report = engine.train_with(&ctx, &data)?;
    for note in &report.notes {
        println!("  {note}");
    }
    if args.flag("v") {
        println!(
            "  solver config: wss={} shrinking={} warm_alpha={}",
            cfg.wss.as_str(),
            cfg.shrinking,
            cfg.warm_alpha
        );
        print_solver_stats(&report.solver);
    }
    if let Some(path) = args.get("trace") {
        if report.trace.is_empty() {
            println!("trace: method '{}' records no per-iteration trace", cfg.method);
        } else {
            let mut csv = String::from("iteration,r2,num_sv,center_delta\n");
            for t in &report.trace {
                csv.push_str(&format!(
                    "{},{},{},{}\n",
                    t.iteration, t.r2, t.num_sv, t.center_delta
                ));
            }
            std::fs::write(path, csv)?;
        }
    }
    println!(
        "done in {}: R^2={:.4} #SV={} {}",
        fmt_duration(report.seconds),
        report.model.r2(),
        report.model.num_sv(),
        report.extras_line(),
    );
    if let Some(path) = args.get("out") {
        report.model.save(Path::new(path))?;
        println!("model saved to {path}");
    }
    if let Some(dir) = args.get("registry") {
        let reg = Registry::open(dir)?;
        let meta = VersionMeta::from_report(&report, &data);
        let id = reg.publish(&report.model, meta)?;
        println!("published {id} to registry {dir}");
        if args.flag("promote") {
            reg.promote(&id)?;
            println!("{id} is now the champion");
        }
    }
    if let Some(path) = args.get("log-json") {
        fastsvdd::obs::disable();
        fastsvdd::obs::remove_sink();
        println!("run log written to {path} (render with: fastsvdd report --log {path})");
    }
    Ok(())
}

/// `train --method distributed --addrs ... --stream-chunk N` on a CSV
/// dataset: the controller reads the file in bounded chunks and ships
/// each chunk to a worker as one shard, so the dataset is never fully
/// resident in the controller.
fn train_streaming_distributed(args: &Args, cfg: &RunConfig) -> Result<()> {
    if cfg.method != fastsvdd::config::Method::Distributed {
        return Err(Error::Config("--stream-chunk requires --method distributed".into()));
    }
    let addrs = parse_addrs(args.get("addrs").ok_or_else(|| {
        Error::Config("--stream-chunk requires --addrs (TCP workers)".into())
    })?)?;
    let path = Path::new(&cfg.dataset);
    if !path.exists() {
        return Err(Error::Config(format!(
            "--stream-chunk needs a CSV dataset path, got '{}'",
            cfg.dataset
        )));
    }
    let sw = Stopwatch::start();
    let out = fastsvdd::distributed::train_tcp_cluster_stream(
        path,
        true,
        cfg.stream_chunk,
        &cfg.params(),
        &cfg.distributed(),
        &addrs,
    )?;
    for r in &out.reports {
        println!(
            "  worker {}: shard={} svs={} iters={} converged={}",
            r.worker, r.shard_rows, r.sv_count, r.iterations, r.converged
        );
    }
    println!(
        "done in {}: R^2={:.4} #SV={} shards={} union_rows={} combine={} \
         combine_solves={} shard_retries={} workers_lost={}",
        fmt_duration(sw.elapsed_secs()),
        out.model.r2(),
        out.model.num_sv(),
        out.reports.len(),
        out.union_rows,
        cfg.combine,
        out.combine_solves,
        out.retry.shard_retries,
        out.retry.workers_lost,
    );
    if let Some(path) = args.get("out") {
        out.model.save(Path::new(path))?;
        println!("model saved to {path}");
    }
    Ok(())
}

/// `fastsvdd report --log run.jsonl`: render the per-stage timing table
/// and the R^2 convergence trace (paper Fig. 7) from a `--log-json` run
/// log alone — no model or data needed.
fn cmd_report(args: &Args) -> Result<()> {
    args.expect_only(&["log"])?;
    let path = args
        .get("log")
        .ok_or_else(|| Error::Config("--log required (a train --log-json file)".into()))?;
    let text = std::fs::read_to_string(Path::new(path))?;
    let report = fastsvdd::obs::report::parse(&text)?;
    print!("{}", fastsvdd::obs::report::render(&report));
    Ok(())
}

fn cmd_score(args: &Args) -> Result<()> {
    args.expect_only(&[
        "config", "model", "data", "rows", "seed", "xla", "artifacts", "out",
        "threads", "isa", "precision",
    ])?;
    let cfg = RunConfig::from_args(args)?;
    parallel::install(cfg.parallelism());
    fastsvdd::linalg::isa::install(cfg.isa)?;
    let model_path = args
        .get("model")
        .ok_or_else(|| Error::Config("--model required".into()))?;
    let model = SvddModel::load(Path::new(model_path))?;
    let rows = cfg.rows;
    let labeled = scoring_data(&cfg.dataset, rows, cfg.seed)?;

    let runtime;
    let scorer = if cfg.scorer == "xla" {
        runtime = SharedRuntime::new(Path::new(&cfg.artifact_dir))?;
        Scorer::xla(&model, &runtime)
    } else if cfg.precision == "f32" {
        Scorer::native_f32(&model)
    } else {
        Scorer::native(&model)
    };
    let sw = Stopwatch::start();
    let inside = scorer.inside_batch(&labeled.data)?;
    let secs = sw.elapsed_secs();
    let f1 = F1Score::compute(&labeled.labels, &inside);
    let outliers = inside.iter().filter(|&&i| !i).count();
    println!(
        "scored {} rows in {} ({:.0} rows/s, engine={} precision={} isa={}): outliers={} precision={:.4} recall={:.4} F1={:.4}",
        rows,
        fmt_duration(secs),
        rows as f64 / secs,
        if scorer.is_accelerated() { "xla" } else { "native" },
        scorer.precision(),
        fastsvdd::linalg::isa::selected_name(),
        outliers,
        f1.precision,
        f1.recall,
        f1.f1,
    );
    if let Some(path) = args.get("out") {
        let dist2 = scorer.dist2_batch(&labeled.data)?;
        let mut csv = String::from("dist2,inside,label\n");
        for i in 0..dist2.len() {
            csv.push_str(&format!("{},{},{}\n", dist2[i], inside[i], labeled.labels[i]));
        }
        std::fs::write(path, csv)?;
    }
    Ok(())
}

fn cmd_grid(args: &Args) -> Result<()> {
    args.expect_only(&[
        "config", "model", "out", "xla", "artifacts", "nx", "ny", "margin",
        "threads", "isa",
    ])?;
    let cfg = RunConfig::from_args(args)?;
    parallel::install(cfg.parallelism());
    fastsvdd::linalg::isa::install(cfg.isa)?;
    let model_path = args
        .get("model")
        .ok_or_else(|| Error::Config("--model required".into()))?;
    let model = SvddModel::load(Path::new(model_path))?;
    if model.dim() != 2 {
        return Err(Error::Config("grid scoring needs a 2-d model".into()));
    }
    let nx = args.get_usize("nx", 200)?;
    let ny = args.get_usize("ny", 200)?;
    let margin = args.get_f64("margin", 0.2)?;
    let grid = Grid::covering(model.support_vectors(), nx, ny, margin);
    let runtime;
    let scorer = if cfg.scorer == "xla" {
        runtime = SharedRuntime::new(Path::new(&cfg.artifact_dir))?;
        Scorer::xla(&model, &runtime)
    } else {
        Scorer::native(&model)
    };
    let inside = scorer.inside_batch(&grid.points())?;
    let frac = inside.iter().filter(|&&b| b).count() as f64 / inside.len() as f64;
    let out = args.get_or("out", "grid.pgm");
    grid.write_pgm(&inside, Path::new(out))?;
    println!(
        "grid {}x{} scored (engine={}): {:.1}% inside -> {out}",
        nx,
        ny,
        if scorer.is_accelerated() { "xla" } else { "native" },
        frac * 100.0
    );
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    args.expect_only(&["listen", "faults"])?;
    let addr = args.get_or("listen", "127.0.0.1:7700");
    // deterministic misbehaviour for chaos tests: --faults beats the
    // FASTSVDD_FAULTS environment variable
    let plan = match args.get("faults") {
        Some(spec) => Some(fastsvdd::distributed::FaultPlan::parse(spec)?),
        None => fastsvdd::distributed::FaultPlan::from_env()?,
    };
    if let Some(p) = plan {
        println!("fault injection active: {p:?}");
    }
    let server = WorkerServer::spawn_with_faults(addr, plan)?;
    println!("worker listening on {} (ctrl-c to stop)", server.addr());
    // park forever; the accept loop runs on its own thread
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.expect_only(&[
        "model", "listen", "xla", "artifacts", "batch", "linger-ms", "registry",
        "watch", "watch-interval-ms", "allow-remote-swap", "threads", "isa",
        "config", "http", "batch-window-us", "max-inflight", "max-conns",
    ])?;
    install_threads_arg(args)?;
    // serving knobs: config file < CLI overrides (RunConfig::from_args)
    let cfg = RunConfig::from_args(args)?;
    fastsvdd::linalg::isa::install(cfg.isa)?;
    let registry = match args.get("registry") {
        Some(dir) => Some(Registry::open(dir)?),
        None => None,
    };
    if args.flag("watch") && registry.is_none() {
        return Err(Error::Config(
            "--watch requires --registry (there is nothing to watch)".into(),
        ));
    }
    // initial model: --model file wins; otherwise the registry champion.
    // When a file wins *and* a registry is watched, seed last_id with
    // the current champion so the file is only swapped away by a new
    // promote, not by the first poll re-asserting the stale champion.
    let (model, mut last_id) = match (args.get("model"), &registry) {
        (Some(path), reg) => {
            let current = match reg {
                Some(r) => r.champion()?.map(|e| e.id),
                None => None,
            };
            (SvddModel::load(Path::new(path))?, current)
        }
        (None, Some(reg)) => {
            let (id, m) = reg.champion_model()?.ok_or_else(|| {
                Error::Config(
                    "registry has no champion; promote one or pass --model".into(),
                )
            })?;
            (m, Some(id))
        }
        (None, None) => {
            return Err(Error::Config("--model or --registry required".into()));
        }
    };
    let addr = args.get_or("listen", "127.0.0.1:7800");
    // window precedence: --batch-window-us > --linger-ms (legacy
    // spelling) > config file / default
    let linger = if args.get("batch-window-us").is_none() && args.get("linger-ms").is_some()
    {
        std::time::Duration::from_millis(args.get_u64("linger-ms", 2)?)
    } else {
        std::time::Duration::from_micros(cfg.batch_window_us)
    };
    let policy = fastsvdd::scoring::BatchPolicy {
        target_batch: args.get_usize("batch", 256)?,
        linger,
        ..Default::default()
    };
    // the wire protocol is unauthenticated: remote SwapModel frames are
    // refused unless the operator opts in
    let builder = fastsvdd::scoring::ScoreServer::builder(addr)
        .model(model.clone())
        .policy(policy)
        .http(cfg.http)
        .max_conns(cfg.max_conns)
        .max_inflight(cfg.max_inflight)
        .remote_swap(args.flag("allow-remote-swap"));
    // engine: XLA when requested + artifacts are present, else native.
    // The closure receives the model snapshot its batch was pinned to,
    // so both engines keep scoring correctly across hot-swaps.
    let server = if args.flag("xla") {
        let dir = args.get_or("artifacts", "artifacts").to_string();
        let rt = std::sync::Arc::new(SharedRuntime::new(Path::new(&dir))?);
        builder.spawn(move |m, zs| Scorer::xla(m, &rt).dist2_batch(zs))?
    } else {
        builder.spawn(|m, zs| Ok(m.dist2_batch(zs)))?
    };
    println!(
        "scoring server on {} (model {}: {} SVs, R^2={:.4}; engine={}; \
         http ingress {}; remote swap {})",
        server.addr(),
        model.content_id(),
        model.num_sv(),
        model.r2(),
        if args.flag("xla") { "xla" } else { "native" },
        if cfg.http { "enabled" } else { "disabled" },
        if args.flag("allow-remote-swap") { "enabled" } else { "disabled" }
    );
    let watch = args.flag("watch");
    if watch {
        println!("watching registry for champion changes (hot-swap on promote)");
    }
    let interval_ms = args.get_u64("watch-interval-ms", 1000)?;
    if interval_ms == 0 {
        return Err(Error::Config(
            "--watch-interval-ms must be >= 1 (0 would busy-spin)".into(),
        ));
    }
    let interval = std::time::Duration::from_millis(interval_ms);
    let slot = server.slot();
    let mut since_metrics = std::time::Duration::ZERO;
    loop {
        std::thread::sleep(interval);
        if watch {
            match sync_champion(registry.as_ref().unwrap(), &slot, last_id.as_ref()) {
                Ok(Some(id)) => {
                    server.metrics.model_swaps.inc();
                    println!(
                        "hot-swapped to {id} (epoch {}, R^2={:.4})",
                        slot.epoch(),
                        slot.current().r2()
                    );
                    last_id = Some(id);
                }
                Ok(None) => {}
                Err(e) => eprintln!("watch: {e} (still serving the old model)"),
            }
        }
        since_metrics += interval;
        if since_metrics >= std::time::Duration::from_secs(60) {
            println!("metrics: {}", server.metrics.render());
            since_metrics = std::time::Duration::ZERO;
        }
    }
}

fn cmd_registry(args: &Args) -> Result<()> {
    args.expect_only(&["dir", "version", "keep"])?;
    let dir = args
        .get("dir")
        .ok_or_else(|| Error::Config("--dir required".into()))?;
    let reg = Registry::open(dir)?;
    match args.action.as_str() {
        "" | "list" => {
            let champion = reg.champion()?.map(|e| e.id);
            let entries = reg.list()?;
            if entries.is_empty() {
                println!("registry {dir}: no versions (train with --registry to publish)");
                return Ok(());
            }
            let mut t = Table::new(
                &format!("registry {dir}"),
                &["version", "champ", "r2", "#sv", "rows", "n", "iters", "warm", "created_unix"],
            );
            for e in &entries {
                t.row(vec![
                    e.id.to_string(),
                    if Some(&e.id) == champion.as_ref() { "*".into() } else { "".into() },
                    f(e.meta.r2, 4),
                    e.meta.num_sv.to_string(),
                    e.meta.rows.to_string(),
                    e.meta.sample_size.to_string(),
                    e.meta.iterations.to_string(),
                    if e.meta.warm_start { "warm".into() } else { "cold".into() },
                    e.meta.created_unix.to_string(),
                ]);
            }
            println!("{}", t.render());
            Ok(())
        }
        "promote" => {
            let v = args
                .get("version")
                .ok_or_else(|| Error::Config("--version required".into()))?;
            let id = VersionId::parse(v)?;
            reg.promote(&id)?;
            println!("{id} is now the champion");
            Ok(())
        }
        "rollback" => {
            let id = reg.rollback()?;
            println!("rolled back; {id} is the champion again");
            Ok(())
        }
        "gc" => {
            let keep = args.get_usize("keep", 5)?;
            let pruned = reg.gc(keep)?;
            if pruned.is_empty() {
                println!("nothing to prune (keep={keep})");
            } else {
                for id in &pruned {
                    println!("pruned {id}");
                }
                println!("{} versions pruned", pruned.len());
            }
            Ok(())
        }
        other => Err(Error::Config(format!(
            "unknown registry action '{other}' (list | promote | rollback | gc)"
        ))),
    }
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    args.expect_only(&["artifacts"])?;
    let dir = args.get_or("artifacts", "artifacts");
    let manifest = fastsvdd::runtime::Manifest::load(Path::new(dir))?;
    println!(
        "manifest: {} artifacts (sv_pad={}, gram_n={})",
        manifest.entries.len(),
        manifest.sv_pad,
        manifest.gram_n
    );
    for e in &manifest.entries {
        println!("  {:30} {:?}", e.name, e.kind);
    }
    Ok(())
}
