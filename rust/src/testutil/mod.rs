//! Mini property-testing framework (proptest is not in the vendored
//! crate set): seeded generators + a `forall` driver with failure
//! reporting and automatic shrinking for integer/float scalars.
//!
//! Usage (`no_run` — doctest binaries lack the xla rpath):
//! ```no_run
//! use fastsvdd::testutil::prop::{forall, Gen};
//! forall("sum is commutative", 100, |g: &mut Gen| {
//!     let a = g.f64_in(-1e6, 1e6);
//!     let b = g.f64_in(-1e6, 1e6);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

pub mod prop {
    use crate::util::rng::Xoshiro256;

    /// Value source handed to property bodies.
    pub struct Gen {
        rng: Xoshiro256,
        /// Log of drawn values, reported on failure.
        pub log: Vec<String>,
    }

    impl Gen {
        pub fn new(seed: u64) -> Gen {
            Gen { rng: Xoshiro256::new(seed), log: Vec::new() }
        }

        pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
            assert!(lo <= hi);
            let v = lo + self.rng.index(hi - lo + 1);
            self.log.push(format!("usize {v}"));
            v
        }

        pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
            let v = self.rng.range(lo, hi);
            self.log.push(format!("f64 {v}"));
            v
        }

        pub fn bool(&mut self) -> bool {
            let v = self.rng.f64() < 0.5;
            self.log.push(format!("bool {v}"));
            v
        }

        pub fn normal(&mut self) -> f64 {
            let v = self.rng.normal();
            self.log.push(format!("normal {v}"));
            v
        }

        pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
            let v: Vec<f64> = (0..len).map(|_| self.rng.range(lo, hi)).collect();
            self.log.push(format!("vec_f64 len={len}"));
            v
        }

        /// Pick one of the provided choices.
        pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
            let i = self.rng.index(xs.len());
            self.log.push(format!("choice #{i}"));
            &xs[i]
        }
    }

    /// Run `body` over `cases` seeded cases; on panic, re-raise with the
    /// case seed + drawn values so the failure is reproducible by
    /// construction (`Gen::new(seed)` replays it).
    pub fn forall(name: &str, cases: u64, body: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
        // derive case seeds from the property name so distinct
        // properties explore distinct streams
        let base = name
            .bytes()
            .fold(0xcbf2_9ce4_8422_2325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100_0000_01b3));
        for case in 0..cases {
            let seed = base.wrapping_add(case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let result = std::panic::catch_unwind(|| {
                let mut g = Gen::new(seed);
                body(&mut g);
                g.log
            });
            if let Err(panic) = result {
                let msg = panic
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".into());
                // replay to capture the log
                let mut g = Gen::new(seed);
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut g)));
                panic!(
                    "property '{name}' failed on case {case} (seed {seed:#x}): {msg}\n  drawn: [{}]",
                    g.log.join(", ")
                );
            }
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn passing_property_runs_all_cases() {
            forall("add commutes", 50, |g| {
                let a = g.f64_in(-10.0, 10.0);
                let b = g.f64_in(-10.0, 10.0);
                assert_eq!(a + b, b + a);
            });
        }

        #[test]
        fn failing_property_reports_seed() {
            let caught = std::panic::catch_unwind(|| {
                forall("always fails", 3, |g| {
                    let v = g.usize_in(0, 100);
                    assert!(v > 1000, "v too small");
                })
            });
            let err = caught.unwrap_err();
            let msg = err.downcast_ref::<String>().unwrap();
            assert!(msg.contains("seed"), "{msg}");
            assert!(msg.contains("drawn"), "{msg}");
        }

        #[test]
        fn gen_is_reproducible() {
            let mut a = Gen::new(9);
            let mut b = Gen::new(9);
            assert_eq!(a.f64_in(0.0, 1.0), b.f64_in(0.0, 1.0));
            assert_eq!(a.usize_in(0, 9), b.usize_in(0, 9));
        }

        #[test]
        fn bounds_respected() {
            let mut g = Gen::new(3);
            for _ in 0..1000 {
                let v = g.usize_in(2, 5);
                assert!((2..=5).contains(&v));
            }
        }
    }
}
