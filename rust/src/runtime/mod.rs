//! PJRT runtime: load the AOT HLO-text artifacts and execute them on
//! the request path.
//!
//! This is the only place the `xla` crate is touched. The flow per
//! artifact (see `/opt/xla-example/load_hlo` and DESIGN.md section 7):
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `PjRtClient::compile` (cached) -> `execute`. HLO *text* is the
//! interchange format — serialized protos from jax >= 0.5 carry 64-bit
//! instruction ids that xla_extension 0.5.1 rejects.

pub mod artifacts;

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

pub use artifacts::{ArtifactInfo, ArtifactKind, Manifest};

use crate::error::{Error, Result};
use crate::sampling::GramBackend;
use crate::svdd::kernel::Kernel;
use crate::svdd::model::SvddModel;
use crate::util::matrix::Matrix;

/// A PJRT CPU runtime holding compiled executables for every artifact
/// it has been asked for (compile once, execute many).
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Executions performed, by artifact name (perf observability).
    exec_counts: HashMap<String, u64>,
}

impl Runtime {
    /// Create a CPU PJRT client over the artifact directory.
    pub fn new(artifact_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            manifest,
            cache: HashMap::new(),
            exec_counts: HashMap::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn exec_count(&self, name: &str) -> u64 {
        self.exec_counts.get(name).copied().unwrap_or(0)
    }

    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let info = self
                .manifest
                .entries
                .iter()
                .find(|e| e.name == name)
                .ok_or_else(|| Error::Runtime(format!("unknown artifact '{name}'")))?;
            let proto = xla::HloModuleProto::from_text_file(&info.path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(self.cache.get(name).unwrap())
    }

    fn run1(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        // split borrow: bump the counter first
        *self.exec_counts.entry(name.to_string()).or_insert(0) += 1;
        let exe = self.executable(name)?;
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        // AOT modules are lowered with return_tuple=True
        Ok(result.to_tuple1()?)
    }

    // ----------------------------------------------------------- score

    /// Score `z` (rows x m, f32 flattened) against a padded model.
    /// `z` must exactly match the bucket shape `(b, m)`; the higher-level
    /// [`crate::scoring::Scorer`] handles padding/chunking.
    #[allow(clippy::too_many_arguments)]
    pub fn score_bucket(
        &mut self,
        artifact: &str,
        b: usize,
        m: usize,
        s: usize,
        z: &[f32],
        sv: &[f32],
        alpha: &[f32],
        bw: f32,
        w: f32,
    ) -> Result<Vec<f32>> {
        if z.len() != b * m || sv.len() != s * m || alpha.len() != s {
            return Err(Error::Runtime(format!(
                "score_bucket shape mismatch: z={} sv={} alpha={} for b={b} m={m} s={s}",
                z.len(),
                sv.len(),
                alpha.len()
            )));
        }
        let zl = xla::Literal::vec1(z).reshape(&[b as i64, m as i64])?;
        let svl = xla::Literal::vec1(sv).reshape(&[s as i64, m as i64])?;
        let al = xla::Literal::vec1(alpha);
        let bwl = xla::Literal::vec1(&[bw]);
        let wl = xla::Literal::vec1(&[w]);
        let out = self.run1(artifact, &[zl, svl, al, bwl, wl])?;
        Ok(out.to_vec::<f32>()?)
    }

    // ------------------------------------------------------------ gram

    /// K(X, X) through the gram artifact: pads `data` (n x m, n <= bucket)
    /// with zero rows, executes, and returns the top-left n*n block as f64.
    pub fn gram_padded(&mut self, data: &Matrix, bw: f64) -> Result<Option<Vec<f64>>> {
        let n = data.rows();
        let m = data.cols();
        let info = match self.manifest.find_gram(n, m) {
            Some(i) => i.clone(),
            None => return Ok(None),
        };
        let bucket_n = match info.kind {
            ArtifactKind::Gram { n, .. } => n,
            _ => unreachable!(),
        };
        let mut x = vec![0.0f32; bucket_n * m];
        for i in 0..n {
            for (j, &v) in data.row(i).iter().enumerate() {
                x[i * m + j] = v as f32;
            }
        }
        let xl = xla::Literal::vec1(&x).reshape(&[bucket_n as i64, m as i64])?;
        let bwl = xla::Literal::vec1(&[bw as f32]);
        let out = self.run1(&info.name, &[xl, bwl])?;
        let full = out.to_vec::<f32>()?;
        let mut gram = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                gram[i * n + j] = full[i * bucket_n + j] as f64;
            }
        }
        Ok(Some(gram))
    }
}

/// Thread-shareable runtime handle.
///
/// SAFETY: the `xla` crate's types wrap raw C++ pointers without Send /
/// Sync markers. The PJRT CPU client is internally synchronized, and we
/// additionally serialize *all* access through the `Mutex`, so no two
/// threads ever touch the underlying objects concurrently.
pub struct SharedRuntime(Mutex<Runtime>);

unsafe impl Send for SharedRuntime {}
unsafe impl Sync for SharedRuntime {}

impl SharedRuntime {
    pub fn new(artifact_dir: &Path) -> Result<SharedRuntime> {
        Ok(SharedRuntime(Mutex::new(Runtime::new(artifact_dir)?)))
    }

    pub fn with<T>(&self, f: impl FnOnce(&mut Runtime) -> T) -> T {
        let mut rt = self.0.lock().expect("runtime mutex poisoned");
        f(&mut rt)
    }

    /// Pad `model`'s SVs/alphas to the manifest's SV bucket; returns
    /// `(sv, alpha, s)` as f32 or None if the model exceeds the bucket.
    pub fn pad_model(&self, model: &SvddModel) -> Option<(Vec<f32>, Vec<f32>, usize)> {
        let s = self.with(|rt| rt.manifest.sv_pad);
        if model.num_sv() > s {
            return None;
        }
        let m = model.dim();
        let mut sv = vec![0.0f32; s * m];
        let mut alpha = vec![0.0f32; s];
        for i in 0..model.num_sv() {
            for (j, &v) in model.support_vectors().row(i).iter().enumerate() {
                sv[i * m + j] = v as f32;
            }
            alpha[i] = model.alpha()[i] as f32;
        }
        Some((sv, alpha, s))
    }
}

impl GramBackend for SharedRuntime {
    fn gram(&self, data: &Matrix, kernel: Kernel) -> Option<Vec<f64>> {
        let bw = kernel.bw()?; // only the Gaussian artifact exists
        self.with(|rt| rt.gram_padded(data, bw).ok().flatten())
    }
}

/// Default artifact directory: `$FASTSVDD_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> std::path::PathBuf {
    std::env::var_os("FASTSVDD_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
