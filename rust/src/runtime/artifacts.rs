//! AOT artifact manifest: the contract between `python/compile/aot.py`
//! and the Rust runtime. The Python side writes `manifest.json` next to
//! the `*.hlo.txt` modules; this module parses and indexes it.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Shape bucket of one AOT module.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Batched scoring: z[b,m], sv[s,m], alpha[s], bw[1], w[1] -> dist2[b].
    Score { m: usize, s: usize, b: usize },
    /// Sample gram: x[n,m], bw[1] -> k[n,n].
    Gram { n: usize, m: usize },
}

/// One entry of the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub kind: ArtifactKind,
    pub path: PathBuf,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub entries: Vec<ArtifactInfo>,
    pub sv_pad: usize,
    pub gram_n: usize,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            Error::Runtime(format!(
                "no artifact manifest in {} (run `make artifacts`): {e}",
                dir.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let v = Json::parse(text)?;
        let version = v.req("version")?.as_usize().unwrap_or(0);
        if version != 1 {
            return Err(Error::Runtime(format!("manifest version {version} != 1")));
        }
        let sv_pad = v.req("sv_pad")?.as_usize().unwrap_or(0);
        let gram_n = v.req("gram_n")?.as_usize().unwrap_or(0);
        let mut entries = Vec::new();
        for e in v
            .req("entries")?
            .as_arr()
            .ok_or_else(|| Error::Json("entries not an array".into()))?
        {
            let name = e.req("name")?.as_str().unwrap_or_default().to_string();
            let file = e.req("file")?.as_str().unwrap_or_default().to_string();
            let kind = match e.req("kind")?.as_str() {
                Some("score") => ArtifactKind::Score {
                    m: e.req("m")?.as_usize().unwrap_or(0),
                    s: e.req("s")?.as_usize().unwrap_or(0),
                    b: e.req("b")?.as_usize().unwrap_or(0),
                },
                Some("gram") => ArtifactKind::Gram {
                    n: e.req("n")?.as_usize().unwrap_or(0),
                    m: e.req("m")?.as_usize().unwrap_or(0),
                },
                other => {
                    return Err(Error::Runtime(format!("unknown artifact kind {other:?}")))
                }
            };
            entries.push(ArtifactInfo { name, kind, path: dir.join(file) });
        }
        Ok(Manifest { entries, sv_pad, gram_n })
    }

    /// Smallest score bucket that fits `(m, needed_s, needed_b)`.
    pub fn find_score(&self, m: usize, needed_s: usize, needed_b: usize) -> Option<&ArtifactInfo> {
        self.entries
            .iter()
            .filter(|e| match e.kind {
                ArtifactKind::Score { m: am, s, b } => am == m && s >= needed_s && b >= needed_b,
                _ => false,
            })
            .min_by_key(|e| match e.kind {
                ArtifactKind::Score { b, .. } => b,
                _ => usize::MAX,
            })
    }

    /// Largest score bucket for `(m, needed_s)` — used when a batch
    /// exceeds every bucket and must be chunked.
    pub fn find_score_largest(&self, m: usize, needed_s: usize) -> Option<&ArtifactInfo> {
        self.entries
            .iter()
            .filter(|e| match e.kind {
                ArtifactKind::Score { m: am, s, .. } => am == m && s >= needed_s,
                _ => false,
            })
            .max_by_key(|e| match e.kind {
                ArtifactKind::Score { b, .. } => b,
                _ => 0,
            })
    }

    /// Gram bucket for `(n, m)` if any.
    pub fn find_gram(&self, needed_n: usize, m: usize) -> Option<&ArtifactInfo> {
        self.entries.iter().find(|e| match e.kind {
            ArtifactKind::Gram { n, m: am } => am == m && n >= needed_n,
            _ => false,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "sv_pad": 512, "gram_n": 64,
      "entries": [
        {"name": "score_m2_s512_b256", "kind": "score", "file": "a.hlo.txt",
         "sha256_16": "x", "m": 2, "s": 512, "b": 256},
        {"name": "score_m2_s512_b4096", "kind": "score", "file": "b.hlo.txt",
         "sha256_16": "x", "m": 2, "s": 512, "b": 4096},
        {"name": "gram_n64_m2", "kind": "gram", "file": "c.hlo.txt",
         "sha256_16": "x", "n": 64, "m": 2}
      ]
    }"#;

    #[test]
    fn parses_entries() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.entries.len(), 3);
        assert_eq!(m.sv_pad, 512);
        assert_eq!(m.gram_n, 64);
        assert_eq!(m.entries[0].path, Path::new("/tmp/a/a.hlo.txt"));
    }

    #[test]
    fn find_score_picks_smallest_sufficient_bucket() {
        let m = Manifest::parse(SAMPLE, Path::new("/")).unwrap();
        let e = m.find_score(2, 40, 200).unwrap();
        assert_eq!(e.name, "score_m2_s512_b256");
        let e = m.find_score(2, 40, 1000).unwrap();
        assert_eq!(e.name, "score_m2_s512_b4096");
        assert!(m.find_score(2, 1000, 10).is_none()); // too many SVs
        assert!(m.find_score(9, 10, 10).is_none()); // no such dim
    }

    #[test]
    fn find_gram_checks_capacity() {
        let m = Manifest::parse(SAMPLE, Path::new("/")).unwrap();
        assert!(m.find_gram(64, 2).is_some());
        assert!(m.find_gram(65, 2).is_none());
        assert!(m.find_gram(10, 9).is_none());
    }

    #[test]
    fn rejects_bad_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(&bad, Path::new("/")).is_err());
    }
}
