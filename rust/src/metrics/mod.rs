//! Lightweight metrics for the serve/train paths: monotonic counters
//! and fixed-bucket latency histograms, all lock-free (atomics) so the
//! hot path never blocks on observability.
//!
//! Exposition: [`Metrics::render`] is the one-line human form the CLI
//! prints; [`Metrics::render_prometheus`] is the full Prometheus text
//! format (counters, the cache-hit-rate gauge, and complete histogram
//! bucket series) served by the `GET /metrics` responder on a
//! [`crate::scoring::ScoreServer`] and carried by the `StatsReply`
//! frame; [`Metrics::snapshot`] / [`aggregate`] are the numeric form
//! the distributed controller sums across workers.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge (e.g. the rows currently queued at the
/// batcher). Relaxed atomics: the value is a point-in-time reading, not
/// an accumulator, so torn ordering across threads is acceptable.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }

    #[inline]
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Latency histogram with exponential bucket edges (microseconds):
/// 1us, 2us, 4us, ... ~ 1hr, plus a running sum/count for the mean and
/// exact min/max for quantile clamping.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    sum_us: AtomicU64,
    count: AtomicU64,
    /// Exact extremes (`u64::MAX` / `0` while empty): quantiles are
    /// clamped into `[min, max]` so interpolation never reports a
    /// latency that was not actually observed.
    min_us: AtomicU64,
    max_us: AtomicU64,
}

const BUCKETS: usize = 42;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
            min_us: AtomicU64::new(u64::MAX),
            max_us: AtomicU64::new(0),
        }
    }

    /// Record a duration in seconds.
    pub fn observe(&self, secs: f64) {
        // `as` saturates (NaN -> 0, inf -> u64::MAX), so a pathological
        // duration cannot wrap the cast.
        self.observe_raw((secs * 1e6).max(0.0) as u64);
    }

    /// Record a raw integral value (same exponential buckets, but the
    /// unit is whatever the caller says it is — e.g. *rows per batch*
    /// for the batch-fill histogram rather than microseconds). The
    /// seconds-based accessors divide by 1e6, so raw histograms should
    /// be read through [`Histogram::sum_raw`] / [`Histogram::count`] /
    /// [`Histogram::bucket_counts`] instead.
    pub fn observe_raw(&self, us: u64) {
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        // ...and the accumulator saturates instead of overflowing when
        // such durations pile up (a pegged mean beats a wrapped one).
        let _ = self
            .sum_us
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |s| {
                Some(s.saturating_add(us))
            });
        self.count.fetch_add(1, Ordering::Relaxed);
        self.min_us.fetch_min(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_secs(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / c as f64 / 1e6
    }

    /// Smallest observed duration (0 while empty).
    pub fn min_secs(&self) -> f64 {
        let m = self.min_us.load(Ordering::Relaxed);
        if m == u64::MAX {
            0.0
        } else {
            m as f64 / 1e6
        }
    }

    /// Largest observed duration (0 while empty).
    pub fn max_secs(&self) -> f64 {
        self.max_us.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Approximate quantile: find the bucket holding the target rank,
    /// interpolate linearly by rank *within* it (bucket `i` covers
    /// `[2^i, 2^(i+1))` us), and clamp to the exact observed
    /// `[min, max]` — so `q=0`/`q=1` are exact and no estimate falls
    /// outside the data.
    pub fn quantile_secs(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil().max(1.0) as u64;
        let min = self.min_secs();
        let max = self.max_secs();
        // the extreme ranks are known exactly
        if target <= 1 {
            return min;
        }
        if target >= total {
            return max;
        }
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            let c = b.load(Ordering::Relaxed);
            if c == 0 {
                continue;
            }
            if acc + c >= target {
                let lo = (1u64 << i) as f64;
                let hi = (1u64 << (i + 1).min(63)) as f64;
                let frac = (target - acc) as f64 / c as f64;
                let est = (lo + frac * (hi - lo)) / 1e6;
                return est.clamp(min, max);
            }
            acc += c;
        }
        max
    }

    /// Per-bucket counts snapshot (non-cumulative), for exposition.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    pub fn sum_secs(&self) -> f64 {
        self.sum_us.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Sum of raw observed values (for [`Histogram::observe_raw`]
    /// histograms, where the unit is not microseconds).
    pub fn sum_raw(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }
}

/// Metrics registry for a serve/train process.
#[derive(Debug, Default)]
pub struct Metrics {
    pub batches_scored: Counter,
    pub rows_scored: Counter,
    pub xla_executions: Counter,
    pub solver_calls: Counter,
    pub train_iterations: Counter,
    /// SMO pair iterations across every solve (the inner-loop cost the
    /// WSS2/shrinking/warm-start machinery exists to cut).
    pub smo_iterations: Counter,
    /// SMO shrink passes that removed variables from the working set.
    pub smo_shrink_events: Counter,
    /// SMO unshrink-and-recheck passes (exact gradient rebuilds).
    pub smo_unshrink_events: Counter,
    /// Kernel-column cache hits / lookups across every LazyKernel
    /// solve. Kept as two counters (not a stored rate) so aggregation
    /// over many solves — and over many workers — stays exact.
    pub smo_cache_hits: Counter,
    pub smo_cache_lookups: Counter,
    pub score_latency: Histogram,
    /// Serving edge: how long each micro-batch window lingered between
    /// the first queued request and dispatch (seconds).
    pub window_wait: Histogram,
    /// Serving edge: rows per dispatched micro-batch (raw-valued
    /// histogram — read via [`Histogram::sum_raw`], not `sum_secs`).
    pub batch_fill: Histogram,
    /// Serving edge: rows sitting in the batcher queue (point-in-time).
    pub queue_depth: Gauge,
    /// Serving edge: requests shed under overload (bounded queue /
    /// in-flight cap) with an explicit overload reply.
    pub shed_requests: Counter,
    /// Serving edge: HTTP requests handled on the shared listener
    /// (scores, scrapes and error replies alike).
    pub edge_http_requests: Counter,
    /// Serving edge: connections accepted by the multiplexer.
    pub edge_conns_opened: Counter,
    /// Serving edge: connections refused at the `max_conns` cap.
    pub edge_conns_rejected: Counter,
    /// Lifecycle: hot-swaps applied to a serving model slot.
    pub model_swaps: Counter,
    /// Lifecycle: retrains seeded from the champion's SV set.
    pub retrains_warm: Counter,
    /// Lifecycle: retrains from scratch (no champion available).
    pub retrains_cold: Counter,
    /// Lifecycle: wall time of each drift-triggered retrain.
    pub retrain_latency: Histogram,
    /// Incremental path: per-point add/remove updates applied to an
    /// online state machine (lifecycle drift responses and streaming
    /// window slides alike).
    pub incremental_updates: Counter,
    /// Incremental path: full re-solves of the online active set
    /// (seeds/reseeds, staleness-budget trips, divergence recoveries).
    pub incremental_resyncs: Counter,
    /// Distributed controller: shard attempts that failed and
    /// re-entered the work queue (bounded by `max_retries` per shard).
    pub shard_retries: Counter,
    /// Distributed controller: retried shards that ran on a different
    /// worker than the attempt that failed.
    pub shards_reassigned: Counter,
    /// Distributed controller: individual worker-attempt failures
    /// (timeouts, dropped connections, corrupt frames, TrainFailed).
    pub worker_failures: Counter,
    /// Distributed controller: workers declared dead by the
    /// healthy -> suspect -> dead state machine.
    pub workers_lost: Counter,
    /// Distributed controller: shards trained locally after the live
    /// worker set fell below `min_workers`.
    pub shards_local_fallback: Counter,
    /// Distributed worker: heartbeat probes answered.
    pub heartbeats_served: Counter,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Record one run's aggregated SMO telemetry.
    pub fn record_solver(&self, stats: &crate::svdd::SolverStats) {
        self.smo_iterations.add(stats.smo_iterations as u64);
        self.smo_shrink_events.add(stats.shrink_events as u64);
        self.smo_unshrink_events.add(stats.unshrink_events as u64);
        self.smo_cache_hits.add(stats.cache_hits);
        self.smo_cache_lookups.add(stats.cache_lookups);
    }

    /// Record one training run's uniform telemetry: SMO solve count,
    /// outer (method) iterations, and the aggregated SMO counters. This
    /// is the single sink every [`crate::engine::TrainReport`] lands in
    /// regardless of method.
    pub fn record_training(
        &self,
        solver_calls: usize,
        iterations: usize,
        stats: &crate::svdd::SolverStats,
    ) {
        self.solver_calls.add(solver_calls as u64);
        self.train_iterations.add(iterations as u64);
        self.record_solver(stats);
    }

    /// Kernel-column cache hit rate across every recorded solve
    /// (0 while no lookups have happened).
    pub fn cache_hit_rate(&self) -> f64 {
        let lookups = self.smo_cache_lookups.get();
        if lookups == 0 {
            0.0
        } else {
            self.smo_cache_hits.get() as f64 / lookups as f64
        }
    }

    /// One-line render for logs / CLI output.
    pub fn render(&self) -> String {
        format!(
            "batches={} rows={} xla_execs={} solves={} iters={} smo_iters={} \
             shrinks={} unshrinks={} cache_hit_rate={:.3} swaps={} \
             retrains_warm={} retrains_cold={} sheds={} \
             score_mean={:.3}ms score_p99={:.3}ms",
            self.batches_scored.get(),
            self.rows_scored.get(),
            self.xla_executions.get(),
            self.solver_calls.get(),
            self.train_iterations.get(),
            self.smo_iterations.get(),
            self.smo_shrink_events.get(),
            self.smo_unshrink_events.get(),
            self.cache_hit_rate(),
            self.model_swaps.get(),
            self.retrains_warm.get(),
            self.retrains_cold.get(),
            self.shed_requests.get(),
            self.score_latency.mean_secs() * 1e3,
            self.score_latency.quantile_secs(0.99) * 1e3,
        )
    }

    /// The counters by stable name. This is what `StatsReply` carries
    /// on the wire and what [`aggregate`] sums cluster-wide; histogram
    /// sums ride along in microseconds so they stay integral.
    pub fn snapshot(&self) -> Vec<(String, u64)> {
        let pairs: [(&str, u64); 34] = [
            ("batches_scored", self.batches_scored.get()),
            ("rows_scored", self.rows_scored.get()),
            ("xla_executions", self.xla_executions.get()),
            ("solver_calls", self.solver_calls.get()),
            ("train_iterations", self.train_iterations.get()),
            ("smo_iterations", self.smo_iterations.get()),
            ("smo_shrink_events", self.smo_shrink_events.get()),
            ("smo_unshrink_events", self.smo_unshrink_events.get()),
            ("smo_cache_hits", self.smo_cache_hits.get()),
            ("smo_cache_lookups", self.smo_cache_lookups.get()),
            ("model_swaps", self.model_swaps.get()),
            ("retrains_warm", self.retrains_warm.get()),
            ("retrains_cold", self.retrains_cold.get()),
            ("score_latency_count", self.score_latency.count()),
            ("score_latency_sum_us", self.score_latency.sum_us()),
            ("retrain_latency_count", self.retrain_latency.count()),
            ("retrain_latency_sum_us", self.retrain_latency.sum_us()),
            ("shed_requests", self.shed_requests.get()),
            ("edge_http_requests", self.edge_http_requests.get()),
            ("edge_conns_opened", self.edge_conns_opened.get()),
            ("edge_conns_rejected", self.edge_conns_rejected.get()),
            ("queue_depth_rows", self.queue_depth.get()),
            ("window_wait_count", self.window_wait.count()),
            ("window_wait_sum_us", self.window_wait.sum_us()),
            ("batch_fill_count", self.batch_fill.count()),
            ("batch_fill_sum_rows", self.batch_fill.sum_raw()),
            ("shard_retries", self.shard_retries.get()),
            ("shards_reassigned", self.shards_reassigned.get()),
            ("worker_failures", self.worker_failures.get()),
            ("workers_lost", self.workers_lost.get()),
            ("shards_local_fallback", self.shards_local_fallback.get()),
            ("heartbeats_served", self.heartbeats_served.get()),
            ("incremental_updates", self.incremental_updates.get()),
            ("incremental_resyncs", self.incremental_resyncs.get()),
        ];
        pairs.iter().map(|&(k, v)| (k.to_string(), v)).collect()
    }

    /// Prometheus text exposition (format version 0.0.4): every
    /// counter, the cache-hit-rate gauge, and the full cumulative
    /// bucket series of both latency histograms.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        let counters: [(&str, &str, u64); 25] = [
            ("fastsvdd_batches_scored_total", "Scoring batches executed", self.batches_scored.get()),
            ("fastsvdd_rows_scored_total", "Rows scored", self.rows_scored.get()),
            ("fastsvdd_xla_executions_total", "XLA artifact executions", self.xla_executions.get()),
            ("fastsvdd_solver_calls_total", "SMO solver invocations", self.solver_calls.get()),
            ("fastsvdd_train_iterations_total", "Outer training iterations", self.train_iterations.get()),
            ("fastsvdd_smo_iterations_total", "SMO pair iterations", self.smo_iterations.get()),
            ("fastsvdd_smo_shrink_events_total", "SMO shrink passes that removed variables", self.smo_shrink_events.get()),
            ("fastsvdd_smo_unshrink_events_total", "SMO unshrink-and-recheck passes", self.smo_unshrink_events.get()),
            ("fastsvdd_smo_cache_hits_total", "Kernel column cache hits", self.smo_cache_hits.get()),
            ("fastsvdd_smo_cache_lookups_total", "Kernel column cache lookups", self.smo_cache_lookups.get()),
            ("fastsvdd_model_swaps_total", "Model hot-swaps applied to the serving slot", self.model_swaps.get()),
            ("fastsvdd_retrains_warm_total", "Warm-start retrains", self.retrains_warm.get()),
            ("fastsvdd_retrains_cold_total", "Cold-start retrains", self.retrains_cold.get()),
            ("fastsvdd_shed_requests_total", "Requests shed under overload with an explicit overload reply", self.shed_requests.get()),
            ("fastsvdd_edge_http_requests_total", "HTTP requests handled on the serving listener", self.edge_http_requests.get()),
            ("fastsvdd_edge_conns_opened_total", "Connections accepted by the serving edge", self.edge_conns_opened.get()),
            ("fastsvdd_edge_conns_rejected_total", "Connections refused at the max_conns cap", self.edge_conns_rejected.get()),
            ("fastsvdd_shard_retries_total", "Distributed shard attempts that re-entered the work queue", self.shard_retries.get()),
            ("fastsvdd_shards_reassigned_total", "Retried shards moved to a different worker", self.shards_reassigned.get()),
            ("fastsvdd_worker_failures_total", "Distributed worker-attempt failures", self.worker_failures.get()),
            ("fastsvdd_workers_lost_total", "Workers declared dead by the controller", self.workers_lost.get()),
            ("fastsvdd_shards_local_fallback_total", "Shards trained locally below min_workers", self.shards_local_fallback.get()),
            ("fastsvdd_heartbeats_served_total", "Heartbeat probes answered by this worker", self.heartbeats_served.get()),
            ("fastsvdd_incremental_updates_total", "Per-point add/remove updates on online state machines", self.incremental_updates.get()),
            ("fastsvdd_incremental_resyncs_total", "Full re-solves of online active sets", self.incremental_resyncs.get()),
        ];
        for (name, help, v) in counters {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"
            ));
        }
        out.push_str(&format!(
            "# HELP fastsvdd_smo_cache_hit_rate Kernel column cache hit rate \
             (hits / lookups)\n# TYPE fastsvdd_smo_cache_hit_rate gauge\n\
             fastsvdd_smo_cache_hit_rate {}\n",
            self.cache_hit_rate()
        ));
        out.push_str(&format!(
            "# HELP fastsvdd_queue_depth_rows Rows queued at the batcher \
             (point-in-time)\n# TYPE fastsvdd_queue_depth_rows gauge\n\
             fastsvdd_queue_depth_rows {}\n",
            self.queue_depth.get()
        ));
        // info-style gauge: which kernel microkernel ISA this process
        // dispatches to (the value is always 1; the label is the datum)
        out.push_str(&format!(
            "# HELP fastsvdd_isa_info Selected kernel microkernel ISA \
             arm\n# TYPE fastsvdd_isa_info gauge\n\
             fastsvdd_isa_info{{isa=\"{}\"}} 1\n",
            crate::linalg::isa::selected_name()
        ));
        prom_histogram(
            &mut out,
            "fastsvdd_score_latency_seconds",
            "Batch scoring latency",
            &self.score_latency,
        );
        prom_histogram(
            &mut out,
            "fastsvdd_window_wait_seconds",
            "Micro-batch window linger before dispatch",
            &self.window_wait,
        );
        prom_histogram_raw(
            &mut out,
            "fastsvdd_batch_fill_rows",
            "Rows per dispatched micro-batch",
            &self.batch_fill,
        );
        prom_histogram(
            &mut out,
            "fastsvdd_retrain_latency_seconds",
            "Drift-triggered retrain latency",
            &self.retrain_latency,
        );
        out
    }
}

/// Append one histogram in Prometheus exposition form: cumulative
/// `_bucket{le=...}` lines up to the last non-empty bucket, the
/// mandatory `+Inf` bucket, `_sum` and `_count`.
fn prom_histogram(out: &mut String, name: &str, help: &str, h: &Histogram) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} histogram\n"));
    let counts = h.bucket_counts();
    let last = counts.iter().rposition(|&c| c > 0).map(|i| i + 1).unwrap_or(0);
    let mut cum = 0u64;
    for (i, &c) in counts.iter().enumerate().take(last) {
        cum += c;
        // bucket i covers [2^i, 2^(i+1)) us -> upper edge in seconds
        let le = (1u64 << (i + 1)) as f64 / 1e6;
        out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cum}\n"));
    }
    out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count()));
    out.push_str(&format!("{name}_sum {}\n", h.sum_secs()));
    out.push_str(&format!("{name}_count {}\n", h.count()));
}

/// [`prom_histogram`] for raw-valued histograms
/// ([`Histogram::observe_raw`]): bucket edges and the sum stay in the
/// caller's unit (e.g. rows) instead of being scaled to seconds.
fn prom_histogram_raw(out: &mut String, name: &str, help: &str, h: &Histogram) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} histogram\n"));
    let counts = h.bucket_counts();
    let last = counts.iter().rposition(|&c| c > 0).map(|i| i + 1).unwrap_or(0);
    let mut cum = 0u64;
    for (i, &c) in counts.iter().enumerate().take(last) {
        cum += c;
        // bucket i covers [2^i, 2^(i+1)) raw units -> integral upper edge
        let le = 1u64 << (i + 1);
        out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cum}\n"));
    }
    out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count()));
    out.push_str(&format!("{name}_sum {}\n", h.sum_raw()));
    out.push_str(&format!("{name}_count {}\n", h.count()));
}

/// Sum per-worker [`Metrics::snapshot`]s key-by-key — the cluster-wide
/// view the distributed controller reports after pulling `StatsReply`
/// from every worker.
pub fn aggregate(snapshots: &[Vec<(String, u64)>]) -> Vec<(String, u64)> {
    let mut sums: BTreeMap<String, u64> = BTreeMap::new();
    for snap in snapshots {
        for (k, v) in snap {
            *sums.entry(k.clone()).or_insert(0) += v;
        }
    }
    sums.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::new();
        c.inc();
        c.add(5);
        assert_eq!(c.get(), 6);
    }

    #[test]
    fn histogram_mean_and_count() {
        let h = Histogram::new();
        h.observe(0.001);
        h.observe(0.003);
        assert_eq!(h.count(), 2);
        assert!((h.mean_secs() - 0.002).abs() < 1e-4);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let h = Histogram::new();
        for i in 1..=1000 {
            h.observe(i as f64 * 1e-5);
        }
        let p50 = h.quantile_secs(0.5);
        let p99 = h.quantile_secs(0.99);
        assert!(p50 <= p99);
        assert!(p50 > 1e-4 && p99 < 0.1, "p50={p50} p99={p99}");
    }

    #[test]
    fn histogram_tracks_exact_min_max() {
        let h = Histogram::new();
        h.observe(0.0031);
        h.observe(0.00017);
        h.observe(0.92);
        assert!((h.min_secs() - 0.00017).abs() < 2e-6);
        assert!((h.max_secs() - 0.92).abs() < 2e-6);
        // q=0 / q=1 are clamped to the exact extremes
        assert_eq!(h.quantile_secs(0.0), h.min_secs());
        assert_eq!(h.quantile_secs(1.0), h.max_secs());
    }

    #[test]
    fn quantile_interpolates_within_bucket() {
        // 100 observations spread across one bucket [1024, 2048) us:
        // the midpoint rule would answer 1536us for *every* quantile;
        // interpolation must separate p10 from p90.
        let h = Histogram::new();
        for i in 0..100 {
            h.observe((1024.0 + i as f64 * 10.0) / 1e6);
        }
        let p10 = h.quantile_secs(0.10);
        let p90 = h.quantile_secs(0.90);
        assert!(p90 > p10 + 5e-4, "p10={p10} p90={p90} not separated");
        assert!((p10 - 0.001126).abs() < 2e-4, "p10={p10}");
        assert!((p90 - 0.001945).abs() < 2e-4, "p90={p90}");
    }

    #[test]
    fn pathological_durations_saturate_not_wrap() {
        let h = Histogram::new();
        // each observation saturates the cast to u64::MAX microseconds;
        // two of them would wrap a naive fetch_add
        h.observe(f64::INFINITY);
        h.observe(1e300);
        h.observe(0.001);
        assert_eq!(h.count(), 3);
        // a wrapped accumulator would make the mean tiny; saturation
        // keeps it pegged enormous
        assert!(h.mean_secs() > 1e12, "mean={} (sum wrapped?)", h.mean_secs());
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.mean_secs(), 0.0);
        assert_eq!(h.quantile_secs(0.5), 0.0);
        assert_eq!(h.min_secs(), 0.0);
        assert_eq!(h.max_secs(), 0.0);
    }

    #[test]
    fn metrics_render_contains_fields() {
        let m = Metrics::new();
        m.rows_scored.add(7);
        m.model_swaps.inc();
        m.retrains_warm.add(2);
        let s = m.render();
        assert!(s.contains("rows=7"));
        assert!(s.contains("swaps=1"));
        assert!(s.contains("retrains_warm=2"));
        assert!(s.contains("smo_iters=0"));
        assert!(s.contains("cache_hit_rate=0.000"));
    }

    #[test]
    fn record_solver_accumulates() {
        let m = Metrics::new();
        let stats = crate::svdd::SolverStats {
            smo_iterations: 120,
            shrink_events: 3,
            unshrink_events: 1,
            gap: 1e-7,
            cache_hits: 90,
            cache_lookups: 100,
        };
        m.record_solver(&stats);
        m.record_solver(&stats);
        assert_eq!(m.smo_iterations.get(), 240);
        assert_eq!(m.smo_shrink_events.get(), 6);
        assert_eq!(m.smo_unshrink_events.get(), 2);
        assert_eq!(m.smo_cache_hits.get(), 180);
        assert_eq!(m.smo_cache_lookups.get(), 200);
        let s = m.render();
        assert!(s.contains("smo_iters=240"));
        assert!(s.contains("shrinks=6"));
        assert!(s.contains("unshrinks=2"));
        // exact hits/lookups aggregation, not an average of rates
        assert!(s.contains("cache_hit_rate=0.900"), "{s}");
    }

    #[test]
    fn prometheus_exposition_is_well_formed() {
        let m = Metrics::new();
        m.rows_scored.add(12);
        m.smo_cache_hits.add(3);
        m.smo_cache_lookups.add(4);
        m.score_latency.observe(0.002);
        m.score_latency.observe(0.004);
        let text = m.render_prometheus();
        assert!(text.contains("# TYPE fastsvdd_rows_scored_total counter"));
        assert!(text.contains("fastsvdd_rows_scored_total 12"));
        assert!(text.contains("# TYPE fastsvdd_smo_cache_hit_rate gauge"));
        assert!(text.contains("fastsvdd_smo_cache_hit_rate 0.75"));
        // the ISA info gauge always reports exactly one selected arm
        assert!(text.contains("# TYPE fastsvdd_isa_info gauge"));
        assert!(
            text.contains(&format!(
                "fastsvdd_isa_info{{isa=\"{}\"}} 1",
                crate::linalg::isa::selected_name()
            )),
            "{text}"
        );
        assert!(text.contains("# TYPE fastsvdd_score_latency_seconds histogram"));
        assert!(text.contains("fastsvdd_score_latency_seconds_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("fastsvdd_score_latency_seconds_count 2"));
        // cumulative buckets: the last finite bucket carries the total
        let cum: Vec<&str> = text
            .lines()
            .filter(|l| l.starts_with("fastsvdd_score_latency_seconds_bucket"))
            .collect();
        assert!(cum.len() >= 2, "expected bucket series, got {cum:?}");
        assert!(cum[cum.len() - 2].ends_with(" 2"), "{cum:?}");
        // every line is either a comment or "name[{labels}] value"
        for line in text.lines() {
            assert!(!line.is_empty());
            if !line.starts_with('#') {
                let mut parts = line.rsplitn(2, ' ');
                let value = parts.next().unwrap();
                assert!(value.parse::<f64>().is_ok(), "bad value in {line}");
            }
        }
    }

    #[test]
    fn snapshot_and_aggregate_sum_per_key() {
        let a = Metrics::new();
        a.rows_scored.add(10);
        a.smo_cache_hits.add(5);
        let b = Metrics::new();
        b.rows_scored.add(7);
        b.smo_cache_lookups.add(2);
        let total = aggregate(&[a.snapshot(), b.snapshot()]);
        let get = |k: &str| {
            total
                .iter()
                .find(|(name, _)| name == k)
                .map(|(_, v)| *v)
                .unwrap()
        };
        assert_eq!(get("rows_scored"), 17);
        assert_eq!(get("smo_cache_hits"), 5);
        assert_eq!(get("smo_cache_lookups"), 2);
        assert_eq!(get("model_swaps"), 0);
    }

    #[test]
    fn gauge_is_last_write_wins() {
        let g = Gauge::new();
        assert_eq!(g.get(), 0);
        g.set(42);
        g.set(7);
        assert_eq!(g.get(), 7);
    }

    #[test]
    fn raw_histogram_keeps_raw_units() {
        let h = Histogram::new();
        h.observe_raw(3); // rows, not microseconds
        h.observe_raw(300);
        assert_eq!(h.count(), 2);
        assert_eq!(h.sum_raw(), 303);
        let counts = h.bucket_counts();
        assert_eq!(counts.iter().sum::<u64>(), 2);
        // 3 lands in bucket [2,4), 300 in [256,512)
        assert_eq!(counts[1], 1);
        assert_eq!(counts[8], 1);
    }

    #[test]
    fn edge_metrics_flow_to_exposition_and_snapshot() {
        let m = Metrics::new();
        m.shed_requests.add(3);
        m.edge_http_requests.add(9);
        m.edge_conns_opened.add(5);
        m.queue_depth.set(17);
        m.window_wait.observe(0.0015);
        m.batch_fill.observe_raw(128);
        let text = m.render_prometheus();
        assert!(text.contains("# TYPE fastsvdd_shed_requests_total counter"));
        assert!(text.contains("fastsvdd_shed_requests_total 3"));
        assert!(text.contains("fastsvdd_edge_http_requests_total 9"));
        assert!(text.contains("# TYPE fastsvdd_queue_depth_rows gauge"));
        assert!(text.contains("fastsvdd_queue_depth_rows 17"));
        assert!(text.contains("# TYPE fastsvdd_window_wait_seconds histogram"));
        assert!(text.contains("# TYPE fastsvdd_batch_fill_rows histogram"));
        // raw bucket edges are integral (128 lands in [128,256) -> le=256)
        assert!(text.contains("fastsvdd_batch_fill_rows_bucket{le=\"256\"} 1"));
        assert!(text.contains("fastsvdd_batch_fill_rows_sum 128"));
        let snap = m.snapshot();
        let get = |k: &str| snap.iter().find(|(n, _)| n == k).unwrap().1;
        assert_eq!(get("shed_requests"), 3);
        assert_eq!(get("edge_conns_opened"), 5);
        assert_eq!(get("queue_depth_rows"), 17);
        assert_eq!(get("window_wait_count"), 1);
        assert_eq!(get("batch_fill_sum_rows"), 128);
        assert!(m.render().contains("sheds=3"));
        // every exposition line still parses as "name value"
        for line in text.lines() {
            if !line.starts_with('#') {
                let value = line.rsplitn(2, ' ').next().unwrap();
                assert!(value.parse::<f64>().is_ok(), "bad value in {line}");
            }
        }
    }

    #[test]
    fn fault_tolerance_metrics_flow_to_exposition_and_snapshot() {
        let m = Metrics::new();
        m.shard_retries.add(2);
        m.shards_reassigned.inc();
        m.worker_failures.add(3);
        m.workers_lost.inc();
        m.shards_local_fallback.add(4);
        m.heartbeats_served.add(5);
        let text = m.render_prometheus();
        assert!(text.contains("# TYPE fastsvdd_shard_retries_total counter"));
        assert!(text.contains("fastsvdd_shard_retries_total 2"));
        assert!(text.contains("fastsvdd_shards_reassigned_total 1"));
        assert!(text.contains("fastsvdd_worker_failures_total 3"));
        assert!(text.contains("fastsvdd_workers_lost_total 1"));
        assert!(text.contains("fastsvdd_shards_local_fallback_total 4"));
        assert!(text.contains("fastsvdd_heartbeats_served_total 5"));
        let snap = m.snapshot();
        let get = |k: &str| snap.iter().find(|(n, _)| n == k).unwrap().1;
        assert_eq!(get("shard_retries"), 2);
        assert_eq!(get("shards_reassigned"), 1);
        assert_eq!(get("worker_failures"), 3);
        assert_eq!(get("workers_lost"), 1);
        assert_eq!(get("shards_local_fallback"), 4);
        assert_eq!(get("heartbeats_served"), 5);
        // the new counters aggregate cluster-wide like every other key
        let total = aggregate(&[m.snapshot(), m.snapshot()]);
        let t = |k: &str| total.iter().find(|(n, _)| n == k).unwrap().1;
        assert_eq!(t("shard_retries"), 4);
        assert_eq!(t("heartbeats_served"), 10);
    }

    #[test]
    fn incremental_metrics_flow_to_exposition_and_snapshot() {
        let m = Metrics::new();
        m.incremental_updates.add(512);
        m.incremental_resyncs.add(3);
        let text = m.render_prometheus();
        assert!(text.contains("# TYPE fastsvdd_incremental_updates_total counter"));
        assert!(text.contains("fastsvdd_incremental_updates_total 512"));
        assert!(text.contains("fastsvdd_incremental_resyncs_total 3"));
        let snap = m.snapshot();
        let get = |k: &str| snap.iter().find(|(n, _)| n == k).unwrap().1;
        assert_eq!(get("incremental_updates"), 512);
        assert_eq!(get("incremental_resyncs"), 3);
        let total = aggregate(&[m.snapshot(), m.snapshot()]);
        let t = |k: &str| total.iter().find(|(n, _)| n == k).unwrap().1;
        assert_eq!(t("incremental_updates"), 1024);
    }

    #[test]
    fn counters_are_thread_safe() {
        let c = std::sync::Arc::new(Counter::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 8000);
    }
}
