//! Lightweight metrics for the serve/train paths: monotonic counters
//! and fixed-bucket latency histograms, all lock-free (atomics) so the
//! hot path never blocks on observability.

use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Latency histogram with exponential bucket edges (microseconds):
/// 1us, 2us, 4us, ... ~ 1hr, plus a running sum/count for the mean.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    sum_us: AtomicU64,
    count: AtomicU64,
}

const BUCKETS: usize = 42;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    /// Record a duration in seconds.
    pub fn observe(&self, secs: f64) {
        let us = (secs * 1e6).max(0.0) as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_secs(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / c as f64 / 1e6
    }

    /// Approximate quantile from the bucket midpoints.
    pub fn quantile_secs(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                // bucket i covers [2^i, 2^(i+1)) us; report midpoint
                return (3 << i) as f64 / 2.0 / 1e6;
            }
        }
        (1u64 << (BUCKETS - 1)) as f64 / 1e6
    }
}

/// Metrics registry for a serve/train process.
#[derive(Debug, Default)]
pub struct Metrics {
    pub batches_scored: Counter,
    pub rows_scored: Counter,
    pub xla_executions: Counter,
    pub solver_calls: Counter,
    pub train_iterations: Counter,
    /// SMO pair iterations across every solve (the inner-loop cost the
    /// WSS2/shrinking/warm-start machinery exists to cut).
    pub smo_iterations: Counter,
    /// SMO shrink passes that removed variables from the working set.
    pub smo_shrink_events: Counter,
    /// SMO unshrink-and-recheck passes (exact gradient rebuilds).
    pub smo_unshrink_events: Counter,
    pub score_latency: Histogram,
    /// Lifecycle: hot-swaps applied to a serving model slot.
    pub model_swaps: Counter,
    /// Lifecycle: retrains seeded from the champion's SV set.
    pub retrains_warm: Counter,
    /// Lifecycle: retrains from scratch (no champion available).
    pub retrains_cold: Counter,
    /// Lifecycle: wall time of each drift-triggered retrain.
    pub retrain_latency: Histogram,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Record one run's aggregated SMO telemetry.
    pub fn record_solver(&self, stats: &crate::svdd::SolverStats) {
        self.smo_iterations.add(stats.smo_iterations as u64);
        self.smo_shrink_events.add(stats.shrink_events as u64);
        self.smo_unshrink_events.add(stats.unshrink_events as u64);
    }

    /// Record one training run's uniform telemetry: SMO solve count,
    /// outer (method) iterations, and the aggregated SMO counters. This
    /// is the single sink every [`crate::engine::TrainReport`] lands in
    /// regardless of method.
    pub fn record_training(
        &self,
        solver_calls: usize,
        iterations: usize,
        stats: &crate::svdd::SolverStats,
    ) {
        self.solver_calls.add(solver_calls as u64);
        self.train_iterations.add(iterations as u64);
        self.record_solver(stats);
    }

    /// One-line render for logs / CLI output.
    pub fn render(&self) -> String {
        format!(
            "batches={} rows={} xla_execs={} solves={} iters={} smo_iters={} \
             shrinks={} unshrinks={} swaps={} \
             retrains_warm={} retrains_cold={} score_mean={:.3}ms score_p99={:.3}ms",
            self.batches_scored.get(),
            self.rows_scored.get(),
            self.xla_executions.get(),
            self.solver_calls.get(),
            self.train_iterations.get(),
            self.smo_iterations.get(),
            self.smo_shrink_events.get(),
            self.smo_unshrink_events.get(),
            self.model_swaps.get(),
            self.retrains_warm.get(),
            self.retrains_cold.get(),
            self.score_latency.mean_secs() * 1e3,
            self.score_latency.quantile_secs(0.99) * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::new();
        c.inc();
        c.add(5);
        assert_eq!(c.get(), 6);
    }

    #[test]
    fn histogram_mean_and_count() {
        let h = Histogram::new();
        h.observe(0.001);
        h.observe(0.003);
        assert_eq!(h.count(), 2);
        assert!((h.mean_secs() - 0.002).abs() < 1e-4);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let h = Histogram::new();
        for i in 1..=1000 {
            h.observe(i as f64 * 1e-5);
        }
        let p50 = h.quantile_secs(0.5);
        let p99 = h.quantile_secs(0.99);
        assert!(p50 <= p99);
        assert!(p50 > 1e-4 && p99 < 0.1, "p50={p50} p99={p99}");
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.mean_secs(), 0.0);
        assert_eq!(h.quantile_secs(0.5), 0.0);
    }

    #[test]
    fn metrics_render_contains_fields() {
        let m = Metrics::new();
        m.rows_scored.add(7);
        m.model_swaps.inc();
        m.retrains_warm.add(2);
        let s = m.render();
        assert!(s.contains("rows=7"));
        assert!(s.contains("swaps=1"));
        assert!(s.contains("retrains_warm=2"));
        assert!(s.contains("smo_iters=0"));
    }

    #[test]
    fn record_solver_accumulates() {
        let m = Metrics::new();
        let stats = crate::svdd::SolverStats {
            smo_iterations: 120,
            shrink_events: 3,
            unshrink_events: 1,
            gap: 1e-7,
            cache_hit_rate: Some(0.9),
        };
        m.record_solver(&stats);
        m.record_solver(&stats);
        assert_eq!(m.smo_iterations.get(), 240);
        assert_eq!(m.smo_shrink_events.get(), 6);
        assert_eq!(m.smo_unshrink_events.get(), 2);
        let s = m.render();
        assert!(s.contains("smo_iters=240"));
        assert!(s.contains("shrinks=6"));
        assert!(s.contains("unshrinks=2"));
    }

    #[test]
    fn counters_are_thread_safe() {
        let c = std::sync::Arc::new(Counter::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 8000);
    }
}
