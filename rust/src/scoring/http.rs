//! Minimal HTTP/1.1 support for the serving edge.
//!
//! The edge ([`super::edge`]) shares one listener between native frames
//! and HTTP; this module is the HTTP half: an **incremental** request
//! parser that works on a growing receive buffer (the edge is
//! non-blocking, so a request may arrive in arbitrary fragments), plus
//! response builders for the three routes the edge serves:
//!
//! - `GET /metrics` — Prometheus exposition (as in the threaded server);
//! - `GET /model`   — active model identity as JSON;
//! - `POST /score`  — JSON scoring ingress: `{"rows": [[f64, ...], ...]}`
//!   in, `{"dist2": [...], "r2": .., "epoch": .., "model": ".."}` out.
//!
//! Errors are structured JSON bodies (`{"error": code, "detail": ..}`)
//! with the status the ISSUE contract names: 400 for malformed
//! requests/bodies, 413 for oversized heads/bodies, 503 when the
//! batcher sheds under load.
//!
//! Deliberately small: no chunked transfer encoding (rejected with
//! 400), no compression, no TLS. `Content-Length` bodies only — every
//! mainstream HTTP client sends exactly that for small JSON POSTs.

use crate::scoring::ScoreReply;
use crate::util::json::{self, Json};
use crate::util::matrix::Matrix;

/// Cap on the request head (request line + headers).
pub const MAX_HEAD: usize = 16 * 1024;
/// Cap on a request body. 8 MiB of JSON is ~100k 2-d rows — far beyond
/// a sane single scoring call; bigger clients should batch requests.
pub const MAX_BODY: usize = 8 * 1024 * 1024;

/// One parsed HTTP request.
#[derive(Debug, Clone, PartialEq)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
    /// Whether the connection should stay open after the response
    /// (HTTP/1.1 default yes, HTTP/1.0 default no, `Connection`
    /// header overrides either way).
    pub keep_alive: bool,
}

/// Outcome of trying to parse one request off the front of a buffer.
#[derive(Debug)]
pub enum HttpParse {
    /// Not enough bytes yet — read more and retry.
    Incomplete,
    /// One full request; the first `consumed` buffer bytes are its.
    Ready { req: HttpRequest, consumed: usize },
    /// Unrecoverable syntax problem — answer 400 and close.
    Bad(&'static str),
    /// Head or declared body over the caps — answer 413 and close.
    TooLarge,
}

/// Incrementally parse one request from the front of `buf`.
pub fn parse_request(buf: &[u8]) -> HttpParse {
    let head_end = match find_head_end(buf) {
        Some(i) => i,
        None => {
            return if buf.len() >= MAX_HEAD {
                HttpParse::TooLarge
            } else {
                HttpParse::Incomplete
            };
        }
    };
    if head_end > MAX_HEAD {
        return HttpParse::TooLarge;
    }
    let head = match std::str::from_utf8(&buf[..head_end]) {
        Ok(h) => h,
        Err(_) => return HttpParse::Bad("non-UTF-8 request head"),
    };
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let (method, path, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v), None) if !m.is_empty() && p.starts_with('/') => (m, p, v),
        _ => return HttpParse::Bad("malformed request line"),
    };
    let http11 = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        _ => return HttpParse::Bad("unsupported HTTP version"),
    };
    let mut content_length = 0usize;
    let mut keep_alive = http11;
    for line in lines {
        let Some((name, value)) = line.split_once(':') else { continue };
        let value = value.trim();
        if name.eq_ignore_ascii_case("content-length") {
            content_length = match value.parse() {
                Ok(n) => n,
                Err(_) => return HttpParse::Bad("bad Content-Length"),
            };
        } else if name.eq_ignore_ascii_case("transfer-encoding") {
            return HttpParse::Bad("chunked transfer encoding unsupported");
        } else if name.eq_ignore_ascii_case("connection") {
            if value.eq_ignore_ascii_case("close") {
                keep_alive = false;
            } else if value.eq_ignore_ascii_case("keep-alive") {
                keep_alive = true;
            }
        }
    }
    if content_length > MAX_BODY {
        return HttpParse::TooLarge;
    }
    let body_start = head_end + 4;
    if buf.len() < body_start + content_length {
        return HttpParse::Incomplete;
    }
    HttpParse::Ready {
        req: HttpRequest {
            method: method.to_string(),
            path: path.to_string(),
            body: buf[body_start..body_start + content_length].to_vec(),
            keep_alive,
        },
        consumed: body_start + content_length,
    }
}

/// Index of the `\r\n\r\n` head terminator, if present.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Serialize a complete response.
pub fn response(status: &str, content_type: &str, body: &str, keep_alive: bool) -> Vec<u8> {
    format!(
        "HTTP/1.1 {status}\r\n\
         Content-Type: {content_type}\r\n\
         Content-Length: {}\r\n\
         Connection: {}\r\n\r\n{body}",
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    )
    .into_bytes()
}

/// A structured JSON error response: `{"error": code, "detail": ..}`.
pub fn json_error(status: &str, code: &str, detail: &str, keep_alive: bool) -> Vec<u8> {
    let body = json::obj(vec![("error", json::s(code)), ("detail", json::s(detail))]);
    response(status, "application/json", &body.to_string(), keep_alive)
}

/// Decode a `POST /score` body — `{"rows": [[f64, ...], ...]}` with
/// `dim`-wide rows — into a matrix. The error string is the `detail`
/// of the resulting 400.
pub fn parse_score_body(body: &[u8], dim: usize) -> std::result::Result<Matrix, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    let parsed = Json::parse(text).map_err(|e| e.to_string())?;
    let rows = parsed
        .get("rows")
        .and_then(|r| r.as_arr())
        .ok_or_else(|| "expected object with a \"rows\" array".to_string())?;
    if rows.is_empty() {
        return Err("\"rows\" is empty".to_string());
    }
    let mut flat = Vec::with_capacity(rows.len() * dim);
    for (i, row) in rows.iter().enumerate() {
        let vals = row
            .as_arr()
            .ok_or_else(|| format!("row {i} is not an array"))?;
        if vals.len() != dim {
            return Err(format!(
                "row {i} has {} values, model expects {dim}",
                vals.len()
            ));
        }
        for v in vals {
            flat.push(v.as_f64().ok_or_else(|| format!("row {i} has a non-number"))?);
        }
    }
    let n = rows.len();
    Matrix::from_vec(flat, n, dim).map_err(|e| e.to_string())
}

/// Encode a [`ScoreReply`] as the `POST /score` response body.
pub fn score_reply_json(reply: &ScoreReply) -> String {
    json::obj(vec![
        ("dist2", json::arr(reply.dist2.iter().map(|&d| json::num(d)).collect())),
        ("r2", json::num(reply.r2)),
        ("epoch", json::num(reply.epoch as f64)),
        ("model", json::s(reply.model_id.clone())),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ready(buf: &[u8]) -> (HttpRequest, usize) {
        match parse_request(buf) {
            HttpParse::Ready { req, consumed } => (req, consumed),
            other => panic!("expected Ready, got {other:?}"),
        }
    }

    #[test]
    fn parses_get_and_defaults_keep_alive_by_version() {
        let (req, used) = ready(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/metrics");
        assert!(req.body.is_empty());
        assert!(req.keep_alive);
        assert_eq!(used, b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n".len());

        let (req, _) = ready(b"GET /metrics HTTP/1.0\r\n\r\n");
        assert!(!req.keep_alive);
        let (req, _) = ready(b"GET /m HTTP/1.1\r\nConnection: close\r\n\r\n");
        assert!(!req.keep_alive);
        let (req, _) = ready(b"GET /m HTTP/1.0\r\nConnection: Keep-Alive\r\n\r\n");
        assert!(req.keep_alive);
    }

    #[test]
    fn parses_post_body_and_reports_consumed_for_pipelining() {
        let raw = b"POST /score HTTP/1.1\r\nContent-Length: 5\r\n\r\nhelloGET /x";
        let (req, used) = ready(raw);
        assert_eq!(req.method, "POST");
        assert_eq!(req.body, b"hello");
        // the next pipelined request's bytes are not consumed
        assert_eq!(&raw[used..], b"GET /x");
    }

    #[test]
    fn incomplete_until_head_then_body_arrive() {
        assert!(matches!(parse_request(b"POST /sco"), HttpParse::Incomplete));
        assert!(matches!(
            parse_request(b"POST /score HTTP/1.1\r\nContent-Length: 5\r\n\r\nhel"),
            HttpParse::Incomplete
        ));
    }

    #[test]
    fn rejects_malformed_and_oversized() {
        assert!(matches!(parse_request(b"NONSENSE\r\n\r\n"), HttpParse::Bad(_)));
        assert!(matches!(
            parse_request(b"GET /x HTTP/2\r\n\r\n"),
            HttpParse::Bad(_)
        ));
        assert!(matches!(
            parse_request(b"GET /x HTTP/1.1 extra\r\n\r\n"),
            HttpParse::Bad(_)
        ));
        assert!(matches!(
            parse_request(b"POST /s HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"),
            HttpParse::Bad(_)
        ));
        // declared body over the cap
        let huge = format!("POST /s HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY + 1);
        assert!(matches!(parse_request(huge.as_bytes()), HttpParse::TooLarge));
        // head that never terminates
        let run_on = vec![b'a'; MAX_HEAD];
        assert!(matches!(parse_request(&run_on), HttpParse::TooLarge));
    }

    #[test]
    fn response_builder_frames_body_exactly() {
        let bytes = response("200 OK", "application/json", "{\"x\":1}", true);
        let text = String::from_utf8(bytes).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Length: 7\r\n"));
        assert!(text.contains("Connection: keep-alive\r\n"));
        assert!(text.ends_with("\r\n\r\n{\"x\":1}"));
        let closed = String::from_utf8(json_error("400 Bad Request", "bad_request", "no", false))
            .unwrap();
        assert!(closed.contains("Connection: close\r\n"));
        assert!(closed.contains("\"error\":\"bad_request\""));
        assert!(closed.contains("\"detail\":\"no\""));
    }

    #[test]
    fn score_body_roundtrip_and_errors() {
        let m = parse_score_body(br#"{"rows": [[1.0, 2.0], [3.5, -4.0]]}"#, 2).unwrap();
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 2);
        assert_eq!(m.row(1), &[3.5, -4.0]);

        assert!(parse_score_body(b"not json", 2).is_err());
        assert!(parse_score_body(br#"{"cols": []}"#, 2).is_err());
        assert!(parse_score_body(br#"{"rows": []}"#, 2).is_err());
        assert!(parse_score_body(br#"{"rows": [[1.0]]}"#, 2)
            .unwrap_err()
            .contains("model expects 2"));
        assert!(parse_score_body(br#"{"rows": [[1.0, "x"]]}"#, 2).is_err());
    }

    #[test]
    fn score_reply_json_shape() {
        let reply = ScoreReply {
            dist2: vec![0.5, 1.25],
            r2: 0.75,
            epoch: 3,
            model_id: "v-00ff".into(),
        };
        let text = score_reply_json(&reply);
        let back = Json::parse(&text).unwrap();
        let dist2: Vec<f64> = back
            .get("dist2")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        assert_eq!(dist2, vec![0.5, 1.25]);
        assert_eq!(back.get("r2").unwrap().as_f64().unwrap(), 0.75);
        assert_eq!(back.get("epoch").unwrap().as_usize().unwrap(), 3);
        assert_eq!(back.get("model").unwrap().as_str().unwrap(), "v-00ff");
    }
}
