//! TCP scoring service: the serve-path daemon.
//!
//! `fastsvdd serve --model m.json --listen addr` runs a [`ScoreServer`]:
//! one accept loop, one connection thread per client, all connections
//! feeding a single [`super::batcher::Batcher`] so concurrent clients'
//! rows coalesce into bucket-sized XLA (or native) scoring executions.
//! Protocol: framed [`Message::ScoreRequest`] / [`Message::ScoreReply`]
//! (shared with the distributed trainer; version-checked handshake).

use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::distributed::message::{Message, PROTOCOL_VERSION};
use crate::error::{Error, Result};
use crate::metrics::Metrics;
use crate::scoring::batcher::{BatchPolicy, Batcher, BatcherHandle};
use crate::svdd::model::SvddModel;
use crate::util::matrix::Matrix;

/// A running scoring server.
pub struct ScoreServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    batcher: Batcher,
    pub metrics: Arc<Metrics>,
}

impl ScoreServer {
    /// Bind and serve. `score_fn` is the batch engine (wrap
    /// `Scorer::native` or `Scorer::xla` — the latter cannot be moved
    /// across threads directly, so wrap a `SharedRuntime` call).
    pub fn spawn<F>(
        addr: impl ToSocketAddrs,
        model: SvddModel,
        policy: BatchPolicy,
        score_fn: F,
    ) -> Result<ScoreServer>
    where
        F: Fn(&Matrix) -> Result<Vec<f64>> + Send + 'static,
    {
        let metrics = Arc::new(Metrics::new());
        let (batcher, handle) = Batcher::spawn(&model, policy, metrics.clone(), score_fn);
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let r2 = model.r2();
        let accept_thread = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        stream.set_nonblocking(false).ok();
                        let h = handle.clone();
                        std::thread::spawn(move || {
                            let _ = serve_connection(stream, h, r2);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(ScoreServer {
            addr: local,
            stop,
            accept_thread: Some(accept_thread),
            batcher,
            metrics,
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_thread.take() {
            h.join().ok();
        }
        self.batcher.shutdown();
    }
}

impl Drop for ScoreServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_connection(mut stream: TcpStream, handle: BatcherHandle, r2: f64) -> Result<()> {
    match Message::read_from(&mut stream)? {
        Message::Hello { version } if version == PROTOCOL_VERSION => {
            Message::HelloAck { version: PROTOCOL_VERSION }.write_to(&mut stream)?;
        }
        other => {
            return Err(Error::Distributed(format!("expected Hello, got {other:?}")));
        }
    }
    loop {
        match Message::read_from(&mut stream) {
            Ok(Message::ScoreRequest { rows }) => {
                let dist2 = handle.score(&rows)?;
                Message::ScoreReply { dist2, r2 }.write_to(&mut stream)?;
            }
            Ok(Message::Shutdown) | Err(_) => return Ok(()),
            Ok(other) => {
                return Err(Error::Distributed(format!("unexpected {other:?}")));
            }
        }
    }
}

/// Blocking client for the scoring service.
pub struct ScoreClient {
    stream: TcpStream,
}

impl ScoreClient {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<ScoreClient> {
        let mut stream = TcpStream::connect(addr)?;
        Message::Hello { version: PROTOCOL_VERSION }.write_to(&mut stream)?;
        match Message::read_from(&mut stream)? {
            Message::HelloAck { version } if version == PROTOCOL_VERSION => {}
            other => {
                return Err(Error::Distributed(format!("bad handshake: {other:?}")));
            }
        }
        Ok(ScoreClient { stream })
    }

    /// Score a batch; returns (dist2 per row, model R^2).
    pub fn score(&mut self, rows: &Matrix) -> Result<(Vec<f64>, f64)> {
        Message::ScoreRequest { rows: rows.clone() }.write_to(&mut self.stream)?;
        match Message::read_from(&mut self.stream)? {
            Message::ScoreReply { dist2, r2 } => Ok((dist2, r2)),
            other => Err(Error::Distributed(format!("unexpected {other:?}"))),
        }
    }

    pub fn close(mut self) {
        Message::Shutdown.write_to(&mut self.stream).ok();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{banana::Banana, Generator};
    use crate::svdd::{train, SvddParams};

    fn model() -> SvddModel {
        let data = Banana::default().generate(600, 1);
        train(&data, &SvddParams::gaussian(0.35, 0.01)).unwrap()
    }

    #[test]
    fn serve_score_roundtrip() {
        let m = model();
        let m2 = m.clone();
        let mut server = ScoreServer::spawn(
            "127.0.0.1:0",
            m.clone(),
            BatchPolicy::default(),
            move |zs| Ok(m2.dist2_batch(zs)),
        )
        .unwrap();
        let mut client = ScoreClient::connect(server.addr()).unwrap();
        let zs = Banana::default().generate(33, 2);
        let (dist2, r2) = client.score(&zs).unwrap();
        assert_eq!(dist2, m.dist2_batch(&zs));
        assert_eq!(r2, m.r2());
        client.close();
        server.stop();
        assert_eq!(server.metrics.rows_scored.get(), 33);
    }

    #[test]
    fn concurrent_clients_coalesce() {
        let m = model();
        let m2 = m.clone();
        let policy = BatchPolicy {
            target_batch: 64,
            linger: std::time::Duration::from_millis(20),
            capacity: 1 << 16,
        };
        let mut server = ScoreServer::spawn("127.0.0.1:0", m.clone(), policy, move |zs| {
            Ok(m2.dist2_batch(zs))
        })
        .unwrap();
        let addr = server.addr();
        let threads: Vec<_> = (0..6)
            .map(|i| {
                let m = m.clone();
                std::thread::spawn(move || {
                    let mut c = ScoreClient::connect(addr).unwrap();
                    let zs = Banana::default().generate(16, 50 + i);
                    let (dist2, _) = c.score(&zs).unwrap();
                    assert_eq!(dist2, m.dist2_batch(&zs), "client {i} mismatch");
                    c.close();
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(server.metrics.rows_scored.get(), 96);
        assert!(
            server.metrics.batches_scored.get() <= 4,
            "no coalescing: {} batches",
            server.metrics.batches_scored.get()
        );
        server.stop();
    }

    #[test]
    fn multiple_requests_per_connection() {
        let m = model();
        let m2 = m.clone();
        let mut server = ScoreServer::spawn(
            "127.0.0.1:0",
            m.clone(),
            BatchPolicy::default(),
            move |zs| Ok(m2.dist2_batch(zs)),
        )
        .unwrap();
        let mut client = ScoreClient::connect(server.addr()).unwrap();
        for seed in 0..5 {
            let zs = Banana::default().generate(8, seed);
            let (dist2, _) = client.score(&zs).unwrap();
            assert_eq!(dist2, m.dist2_batch(&zs));
        }
        client.close();
        server.stop();
        assert_eq!(server.metrics.rows_scored.get(), 40);
    }
}
