//! TCP scoring service: the serve-path daemon.
//!
//! `fastsvdd serve --model m.json --listen addr` runs a [`ScoreServer`]:
//! one accept loop, one connection thread per client, all connections
//! feeding a single [`super::batcher::Batcher`] so concurrent clients'
//! rows coalesce into bucket-sized XLA (or native) scoring executions.
//! Protocol: framed [`Message::ScoreRequest`] / [`Message::ScoreReply`]
//! (shared with the distributed trainer; version-negotiated handshake).
//!
//! The active model lives in a [`ModelSlot`], so it can be hot-swapped
//! with zero downtime: [`ScoreServer::swap_model`] (local, used by the
//! lifecycle driver and `serve --registry --watch`) or the v2
//! [`Message::SwapModel`] frame (remote). In-flight batches finish on
//! the old model; no connection is dropped. [`Message::ModelInfoRequest`]
//! reports the active model's content id, threshold and swap epoch.
//!
//! The wire protocol carries no authentication, so the mutating
//! `SwapModel` frame is gated by
//! [`ScoreServer::set_remote_swap_enabled`]: run the port on a trusted
//! network, and leave remote swap off (the `fastsvdd serve` default)
//! unless the peers are trusted operators.

use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::distributed::message::{negotiate, Message, PROTOCOL_VERSION};
use crate::error::{Error, Result};
use crate::metrics::Metrics;
use crate::scoring::batcher::{BatchPolicy, Batcher, BatcherHandle, ModelSlot};
use crate::svdd::model::SvddModel;
use crate::util::json::Json;
use crate::util::matrix::Matrix;

/// A running scoring server.
pub struct ScoreServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    batcher: Batcher,
    slot: ModelSlot,
    remote_swap: Arc<AtomicBool>,
    pub metrics: Arc<Metrics>,
}

impl ScoreServer {
    /// Bind and serve. `score_fn` is the batch engine: it receives the
    /// model snapshot the batch is pinned to plus the rows (wrap
    /// `Scorer::native` or `Scorer::xla` — the latter cannot be moved
    /// across threads directly, so wrap a `SharedRuntime` call).
    pub fn spawn<F>(
        addr: impl ToSocketAddrs,
        model: SvddModel,
        policy: BatchPolicy,
        score_fn: F,
    ) -> Result<ScoreServer>
    where
        F: Fn(&SvddModel, &Matrix) -> Result<Vec<f64>> + Send + 'static,
    {
        let metrics = Arc::new(Metrics::new());
        let slot = ModelSlot::new(model);
        let (batcher, handle) = Batcher::spawn(&slot, policy, metrics.clone(), score_fn);
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let remote_swap = Arc::new(AtomicBool::new(true));
        let accept_swap = remote_swap.clone();
        let accept_slot = slot.clone();
        let accept_metrics = metrics.clone();
        let accept_thread = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        stream.set_nonblocking(false).ok();
                        let h = handle.clone();
                        let sl = accept_slot.clone();
                        let mx = accept_metrics.clone();
                        let sw = accept_swap.clone();
                        std::thread::spawn(move || {
                            let _ = serve_connection(stream, h, sl, mx, sw);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(ScoreServer {
            addr: local,
            stop,
            accept_thread: Some(accept_thread),
            batcher,
            slot,
            remote_swap,
            metrics,
        })
    }

    /// Allow or refuse the remote v2 `SwapModel` frame (refused frames
    /// get a `SwapAck { swapped: false }`; the connection survives and
    /// local swaps via [`ScoreServer::swap_model`] / the lifecycle
    /// driver are unaffected). The frame is *enabled* by default for
    /// library/embedded use, but the wire protocol carries no
    /// authentication, so `fastsvdd serve` keeps it disabled unless
    /// `--allow-remote-swap` is passed.
    pub fn set_remote_swap_enabled(&self, enabled: bool) {
        self.remote_swap.store(enabled, Ordering::Relaxed);
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Snapshot of the model currently being served.
    pub fn model(&self) -> Arc<SvddModel> {
        self.slot.current()
    }

    /// Clone of the server's model slot — hand this to a
    /// [`crate::registry::Lifecycle`] so drift-triggered retrains swap
    /// straight into the serve path.
    pub fn slot(&self) -> ModelSlot {
        self.slot.clone()
    }

    /// Hot-swap the served model; returns the new epoch. In-flight
    /// batches finish on the old model, later batches use the new one;
    /// no client connection is interrupted.
    pub fn swap_model(&self, model: SvddModel) -> Result<u64> {
        let epoch = self.slot.swap(model)?;
        self.metrics.model_swaps.inc();
        Ok(epoch)
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_thread.take() {
            h.join().ok();
        }
        self.batcher.shutdown();
    }
}

impl Drop for ScoreServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_connection(
    mut stream: TcpStream,
    handle: BatcherHandle,
    slot: ModelSlot,
    metrics: Arc<Metrics>,
    remote_swap: Arc<AtomicBool>,
) -> Result<()> {
    match Message::read_from(&mut stream)? {
        Message::Hello { version } => match negotiate(version) {
            Some(v) => Message::HelloAck { version: v }.write_to(&mut stream)?,
            None => {
                return Err(Error::Distributed(format!(
                    "peer version {version} too old"
                )));
            }
        },
        other => {
            return Err(Error::Distributed(format!("expected Hello, got {other:?}")));
        }
    }
    loop {
        match Message::read_from(&mut stream) {
            Ok(Message::ScoreRequest { rows }) => {
                let (dist2, r2) = handle.score_with_r2(&rows)?;
                Message::ScoreReply { dist2, r2 }.write_to(&mut stream)?;
            }
            Ok(Message::ModelInfoRequest) => {
                let m = slot.current();
                Message::ModelInfo {
                    version: m.content_id(),
                    r2: m.r2(),
                    num_sv: m.num_sv() as u32,
                    dim: m.dim() as u32,
                    epoch: slot.epoch(),
                }
                .write_to(&mut stream)?;
            }
            Ok(Message::SwapModel { model_json }) => {
                let reply = if !remote_swap.load(Ordering::Relaxed) {
                    Message::SwapAck {
                        epoch: slot.epoch(),
                        swapped: false,
                        r2: slot.current().r2(),
                        reason: "remote model swap is disabled on this server".into(),
                    }
                } else {
                    let outcome = Json::parse(&model_json)
                        .and_then(|j| SvddModel::from_json(&j))
                        .and_then(|m| slot.swap(m));
                    match outcome {
                        Ok(epoch) => {
                            metrics.model_swaps.inc();
                            Message::SwapAck {
                                epoch,
                                swapped: true,
                                r2: slot.current().r2(),
                                reason: String::new(),
                            }
                        }
                        Err(e) => Message::SwapAck {
                            epoch: slot.epoch(),
                            swapped: false,
                            r2: slot.current().r2(),
                            reason: e.to_string(),
                        },
                    }
                };
                reply.write_to(&mut stream)?;
            }
            Ok(Message::Shutdown) | Err(_) => return Ok(()),
            Ok(other) => {
                return Err(Error::Distributed(format!("unexpected {other:?}")));
            }
        }
    }
}

/// What the server reports about its active model (v2 `ModelInfo`).
#[derive(Clone, Debug, PartialEq)]
pub struct RemoteModelInfo {
    /// Content-addressed id (`SvddModel::content_id` spelling).
    pub version: String,
    pub r2: f64,
    pub num_sv: usize,
    pub dim: usize,
    /// Hot-swaps applied since the server started.
    pub epoch: u64,
}

/// Blocking client for the scoring service.
pub struct ScoreClient {
    stream: TcpStream,
}

impl ScoreClient {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<ScoreClient> {
        let mut stream = TcpStream::connect(addr)?;
        Message::Hello { version: PROTOCOL_VERSION }.write_to(&mut stream)?;
        match Message::read_from(&mut stream)? {
            Message::HelloAck { version } if negotiate(version).is_some() => {}
            other => {
                return Err(Error::Distributed(format!("bad handshake: {other:?}")));
            }
        }
        Ok(ScoreClient { stream })
    }

    /// Score a batch; returns (dist2 per row, model R^2).
    pub fn score(&mut self, rows: &Matrix) -> Result<(Vec<f64>, f64)> {
        Message::ScoreRequest { rows: rows.clone() }.write_to(&mut self.stream)?;
        match Message::read_from(&mut self.stream)? {
            Message::ScoreReply { dist2, r2 } => Ok((dist2, r2)),
            other => Err(Error::Distributed(format!("unexpected {other:?}"))),
        }
    }

    /// Ask the server about its active model (v2).
    pub fn model_info(&mut self) -> Result<RemoteModelInfo> {
        Message::ModelInfoRequest.write_to(&mut self.stream)?;
        match Message::read_from(&mut self.stream)? {
            Message::ModelInfo { version, r2, num_sv, dim, epoch } => Ok(RemoteModelInfo {
                version,
                r2,
                num_sv: num_sv as usize,
                dim: dim as usize,
                epoch,
            }),
            other => Err(Error::Distributed(format!("unexpected {other:?}"))),
        }
    }

    /// Hot-swap the server's model (v2); returns the new epoch.
    pub fn swap_model(&mut self, model: &SvddModel) -> Result<u64> {
        Message::SwapModel { model_json: model.to_json().to_string() }
            .write_to(&mut self.stream)?;
        match Message::read_from(&mut self.stream)? {
            Message::SwapAck { epoch, swapped: true, .. } => Ok(epoch),
            Message::SwapAck { swapped: false, reason, .. } => {
                Err(Error::Distributed(format!("swap rejected: {reason}")))
            }
            other => Err(Error::Distributed(format!("unexpected {other:?}"))),
        }
    }

    pub fn close(mut self) {
        Message::Shutdown.write_to(&mut self.stream).ok();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{banana::Banana, Generator};
    use crate::svdd::{train, SvddParams};

    fn model() -> SvddModel {
        let data = Banana::default().generate(600, 1);
        train(&data, &SvddParams::gaussian(0.35, 0.01)).unwrap()
    }

    fn shifted_model() -> SvddModel {
        let mut data = Banana::default().generate(600, 2);
        for i in 0..data.rows() {
            data.row_mut(i)[0] += 6.0;
        }
        train(&data, &SvddParams::gaussian(0.35, 0.01)).unwrap()
    }

    fn spawn_native(model: SvddModel, policy: BatchPolicy) -> ScoreServer {
        ScoreServer::spawn("127.0.0.1:0", model, policy, |m, zs| Ok(m.dist2_batch(zs)))
            .unwrap()
    }

    #[test]
    fn serve_score_roundtrip() {
        let m = model();
        let mut server = spawn_native(m.clone(), BatchPolicy::default());
        let mut client = ScoreClient::connect(server.addr()).unwrap();
        let zs = Banana::default().generate(33, 2);
        let (dist2, r2) = client.score(&zs).unwrap();
        assert_eq!(dist2, m.dist2_batch(&zs));
        assert_eq!(r2, m.r2());
        client.close();
        server.stop();
        assert_eq!(server.metrics.rows_scored.get(), 33);
    }

    #[test]
    fn concurrent_clients_coalesce() {
        let m = model();
        let policy = BatchPolicy {
            target_batch: 64,
            linger: std::time::Duration::from_millis(20),
            capacity: 1 << 16,
        };
        let mut server = spawn_native(m.clone(), policy);
        let addr = server.addr();
        let threads: Vec<_> = (0..6)
            .map(|i| {
                let m = m.clone();
                std::thread::spawn(move || {
                    let mut c = ScoreClient::connect(addr).unwrap();
                    let zs = Banana::default().generate(16, 50 + i);
                    let (dist2, _) = c.score(&zs).unwrap();
                    assert_eq!(dist2, m.dist2_batch(&zs), "client {i} mismatch");
                    c.close();
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(server.metrics.rows_scored.get(), 96);
        assert!(
            server.metrics.batches_scored.get() <= 4,
            "no coalescing: {} batches",
            server.metrics.batches_scored.get()
        );
        server.stop();
    }

    #[test]
    fn multiple_requests_per_connection() {
        let m = model();
        let mut server = spawn_native(m.clone(), BatchPolicy::default());
        let mut client = ScoreClient::connect(server.addr()).unwrap();
        for seed in 0..5 {
            let zs = Banana::default().generate(8, seed);
            let (dist2, _) = client.score(&zs).unwrap();
            assert_eq!(dist2, m.dist2_batch(&zs));
        }
        client.close();
        server.stop();
        assert_eq!(server.metrics.rows_scored.get(), 40);
    }

    #[test]
    fn model_info_reports_active_model() {
        let m = model();
        let mut server = spawn_native(m.clone(), BatchPolicy::default());
        let mut client = ScoreClient::connect(server.addr()).unwrap();
        let info = client.model_info().unwrap();
        assert_eq!(info.version, m.content_id());
        assert_eq!(info.r2, m.r2());
        assert_eq!(info.num_sv, m.num_sv());
        assert_eq!(info.dim, 2);
        assert_eq!(info.epoch, 0);
        client.close();
        server.stop();
    }

    #[test]
    fn remote_swap_changes_served_model_without_reconnect() {
        let m1 = model();
        let m2 = shifted_model();
        let mut server = spawn_native(m1.clone(), BatchPolicy::default());
        let mut client = ScoreClient::connect(server.addr()).unwrap();
        let zs = Banana::default().generate(12, 9);

        let (before, r2_before) = client.score(&zs).unwrap();
        assert_eq!(before, m1.dist2_batch(&zs));
        assert_eq!(r2_before, m1.r2());

        // swap over a *second* connection while the first stays open
        let mut admin = ScoreClient::connect(server.addr()).unwrap();
        assert_eq!(admin.swap_model(&m2).unwrap(), 1);
        admin.close();

        // v2 scores close to the original (JSON roundtrip of the model
        // reproduces dist2 almost exactly; shortest-roundtrip float
        // printing makes it bit-exact)
        let (after, r2_after) = client.score(&zs).unwrap();
        assert_eq!(after, m2.dist2_batch(&zs));
        assert_eq!(r2_after, m2.r2());

        let info = client.model_info().unwrap();
        assert_eq!(info.epoch, 1);
        assert_eq!(info.version, m2.content_id());
        client.close();
        server.stop();
        assert_eq!(server.metrics.model_swaps.get(), 1);
    }

    #[test]
    fn bad_swap_payload_rejected_connection_survives() {
        let m = model();
        let mut server = spawn_native(m.clone(), BatchPolicy::default());
        let mut client = ScoreClient::connect(server.addr()).unwrap();

        // hand-roll a bogus SwapModel frame
        Message::SwapModel { model_json: "{not json".into() }
            .write_to(&mut client.stream)
            .unwrap();
        match Message::read_from(&mut client.stream).unwrap() {
            Message::SwapAck { swapped, epoch, .. } => {
                assert!(!swapped);
                assert_eq!(epoch, 0);
            }
            other => panic!("unexpected {other:?}"),
        }

        // the same connection still scores fine on the original model
        let zs = Banana::default().generate(5, 3);
        let (dist2, r2) = client.score(&zs).unwrap();
        assert_eq!(dist2, m.dist2_batch(&zs));
        assert_eq!(r2, m.r2());
        client.close();
        server.stop();
        assert_eq!(server.metrics.model_swaps.get(), 0);
    }

    #[test]
    fn remote_swap_can_be_disabled() {
        let m1 = model();
        let m2 = shifted_model();
        let mut server = spawn_native(m1.clone(), BatchPolicy::default());
        server.set_remote_swap_enabled(false);
        let mut client = ScoreClient::connect(server.addr()).unwrap();
        let err = client.swap_model(&m2).unwrap_err();
        assert!(err.to_string().contains("disabled"), "{err}");
        // the connection survives, still serving the original model,
        // and local (lifecycle) swaps keep working
        let zs = Banana::default().generate(6, 11);
        let (dist2, r2) = client.score(&zs).unwrap();
        assert_eq!(dist2, m1.dist2_batch(&zs));
        assert_eq!(r2, m1.r2());
        assert_eq!(server.swap_model(m2.clone()).unwrap(), 1);
        let (after, _) = client.score(&zs).unwrap();
        assert_eq!(after, m2.dist2_batch(&zs));
        client.close();
        server.stop();
    }

    #[test]
    fn v1_client_still_served() {
        // A v1 peer sends Hello{1} and only ever uses v1 frames.
        let m = model();
        let mut server = spawn_native(m.clone(), BatchPolicy::default());
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        Message::Hello { version: 1 }.write_to(&mut stream).unwrap();
        match Message::read_from(&mut stream).unwrap() {
            Message::HelloAck { version } => assert_eq!(version, 1),
            other => panic!("unexpected {other:?}"),
        }
        let zs = Banana::default().generate(4, 4);
        Message::ScoreRequest { rows: zs.clone() }.write_to(&mut stream).unwrap();
        match Message::read_from(&mut stream).unwrap() {
            Message::ScoreReply { dist2, r2 } => {
                assert_eq!(dist2, m.dist2_batch(&zs));
                assert_eq!(r2, m.r2());
            }
            other => panic!("unexpected {other:?}"),
        }
        Message::Shutdown.write_to(&mut stream).ok();
        server.stop();
    }
}
