//! TCP scoring service: the serve-path daemon.
//!
//! `fastsvdd serve --model m.json --listen addr` runs a [`ScoreServer`]
//! built via [`ScoreServer::builder`], in one of two modes:
//!
//! - **edge** (default): the single-threaded readiness-loop multiplexer
//!   of [`super::edge`] — thousands of connections on one thread, HTTP
//!   JSON ingress, explicit overload shedding;
//! - **threaded** ([`ScoreServerBuilder::edge`]`(false)`, and what the
//!   legacy [`ScoreServer::spawn`] wrapper picks): one accept loop plus
//!   one blocking connection thread per client — simpler, and the
//!   baseline `benches/perf_serving.rs` compares the edge against.
//!
//! Either way all connections feed a single
//! [`super::batcher::Batcher`], so concurrent clients' rows coalesce
//! into bucket-sized XLA (or native) scoring executions. Protocol:
//! framed [`Message::ScoreRequest`] / [`Message::ScoreReply`] (shared
//! with the distributed trainer; version-negotiated handshake), plus
//! the v3 [`Message::ScoreRequestV2`] round trip carrying full model
//! provenance per reply.
//!
//! The active model lives in a [`ModelSlot`], so it can be hot-swapped
//! with zero downtime: [`ScoreServer::swap_model`] (local, used by the
//! lifecycle driver and `serve --registry --watch`) or the v2
//! [`Message::SwapModel`] frame (remote). In-flight batches finish on
//! the old model; no connection is dropped. [`Message::ModelInfoRequest`]
//! reports the active model's content id, threshold and swap epoch.
//!
//! The wire protocol carries no authentication, so the mutating
//! `SwapModel` frame is gated by
//! [`ScoreServer::set_remote_swap_enabled`]: run the port on a trusted
//! network, and leave remote swap off (the `fastsvdd serve` default)
//! unless the peers are trusted operators.
//!
//! The same listener also answers Prometheus scrapes: a connection
//! whose first bytes spell an HTTP request line (`GET /metrics …`)
//! gets the [`Metrics::render_prometheus`] exposition and is closed —
//! no native frame starts with those bytes (`b"GET "` as a
//! little-endian length would exceed the frame cap), so scrapers and
//! native clients share the port without ambiguity. Native peers pull
//! the same numbers via the v2 [`Message::StatsRequest`] frame, which
//! additionally carries exact counters for cluster-wide aggregation.

use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::distributed::message::{negotiate, Message, PROTOCOL_VERSION};
use crate::error::{Error, Result};
use crate::metrics::Metrics;
use crate::scoring::batcher::{BatchPolicy, Batcher, BatcherHandle, ModelSlot};
use crate::scoring::edge::{run_edge_loop, EdgeConfig};
use crate::scoring::{ScoreReply, ScoreService};
use crate::svdd::model::SvddModel;
use crate::util::json::Json;
use crate::util::matrix::Matrix;

/// A running scoring server.
pub struct ScoreServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    batcher: Batcher,
    slot: ModelSlot,
    remote_swap: Arc<AtomicBool>,
    handle: BatcherHandle,
    pub metrics: Arc<Metrics>,
}

/// Where a server's initial model comes from.
enum ModelSource {
    Model(SvddModel),
    Slot(ModelSlot),
}

/// Configures and spawns a [`ScoreServer`] — the one construction
/// surface for every serve-path knob (the old positional
/// [`ScoreServer::spawn`] survives as a thin wrapper over this).
pub struct ScoreServerBuilder<A: ToSocketAddrs> {
    addr: A,
    source: Option<ModelSource>,
    policy: BatchPolicy,
    edge: bool,
    http_ingress: bool,
    max_conns: usize,
    max_inflight_rows: usize,
    remote_swap_enabled: bool,
}

impl<A: ToSocketAddrs> ScoreServerBuilder<A> {
    /// Serve this model (a fresh private [`ModelSlot`] is created).
    pub fn model(mut self, model: SvddModel) -> Self {
        self.source = Some(ModelSource::Model(model));
        self
    }

    /// Serve an existing slot — share it with a
    /// [`crate::registry::Lifecycle`] so drift-triggered retrains swap
    /// straight into the serve path.
    pub fn slot(mut self, slot: ModelSlot) -> Self {
        self.source = Some(ModelSource::Slot(slot));
        self
    }

    /// Micro-batching policy (window, target batch, queue capacity).
    pub fn policy(mut self, policy: BatchPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// `true` (default): the single-threaded readiness-loop edge.
    /// `false`: the legacy thread-per-connection accept loop.
    pub fn edge(mut self, edge: bool) -> Self {
        self.edge = edge;
        self
    }

    /// Serve the `POST /score` HTTP/JSON ingress (edge mode only;
    /// `GET /metrics` stays on regardless). Default on.
    pub fn http(mut self, http: bool) -> Self {
        self.http_ingress = http;
        self
    }

    /// Connection cap (edge mode only). Default 1024.
    pub fn max_conns(mut self, n: usize) -> Self {
        self.max_conns = n;
        self
    }

    /// Cap on rows in flight to the batcher (edge mode only).
    /// Default 65536.
    pub fn max_inflight(mut self, n: usize) -> Self {
        self.max_inflight_rows = n;
        self
    }

    /// Allow the remote v2 `SwapModel` frame (default `true`; see
    /// [`ScoreServer::set_remote_swap_enabled`] for the security
    /// trade-off — `fastsvdd serve` passes `false` unless
    /// `--allow-remote-swap`).
    pub fn remote_swap(mut self, enabled: bool) -> Self {
        self.remote_swap_enabled = enabled;
        self
    }

    /// Bind and serve. `score_fn` is the batch engine: it receives the
    /// model snapshot the batch is pinned to plus the rows (wrap
    /// `Scorer::native` or `Scorer::xla` — the latter cannot be moved
    /// across threads directly, so wrap a `SharedRuntime` call).
    pub fn spawn<F>(self, score_fn: F) -> Result<ScoreServer>
    where
        F: Fn(&SvddModel, &Matrix) -> Result<Vec<f64>> + Send + 'static,
    {
        let slot = match self.source {
            Some(ModelSource::Model(m)) => ModelSlot::new(m),
            Some(ModelSource::Slot(s)) => s,
            None => {
                return Err(Error::invalid(
                    "ScoreServer::builder needs .model(..) or .slot(..)",
                ));
            }
        };
        let metrics = Arc::new(Metrics::new());
        let (batcher, handle) = Batcher::spawn(&slot, self.policy, metrics.clone(), score_fn);
        let listener = TcpListener::bind(&self.addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let remote_swap = Arc::new(AtomicBool::new(self.remote_swap_enabled));
        let accept_thread = if self.edge {
            let cfg = EdgeConfig {
                http_ingress: self.http_ingress,
                max_conns: self.max_conns,
                max_inflight_rows: self.max_inflight_rows,
            };
            let stop2 = stop.clone();
            let h = handle.clone();
            let sl = slot.clone();
            let mx = metrics.clone();
            let sw = remote_swap.clone();
            std::thread::spawn(move || run_edge_loop(listener, stop2, h, sl, mx, sw, cfg))
        } else {
            let stop2 = stop.clone();
            let accept_handle = handle.clone();
            let accept_slot = slot.clone();
            let accept_metrics = metrics.clone();
            let accept_swap = remote_swap.clone();
            std::thread::spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            stream.set_nonblocking(false).ok();
                            let h = accept_handle.clone();
                            let sl = accept_slot.clone();
                            let mx = accept_metrics.clone();
                            let sw = accept_swap.clone();
                            std::thread::spawn(move || {
                                let _ = serve_connection(stream, h, sl, mx, sw);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })
        };
        Ok(ScoreServer {
            addr: local,
            stop,
            accept_thread: Some(accept_thread),
            batcher,
            slot,
            remote_swap,
            handle,
            metrics,
        })
    }
}

impl ScoreServer {
    /// Start configuring a server on `addr`. Defaults: edge mode, HTTP
    /// ingress on, 1024 connections, 65536 in-flight rows, default
    /// [`BatchPolicy`], remote swap allowed.
    pub fn builder<A: ToSocketAddrs>(addr: A) -> ScoreServerBuilder<A> {
        ScoreServerBuilder {
            addr,
            source: None,
            policy: BatchPolicy::default(),
            edge: true,
            http_ingress: true,
            max_conns: 1024,
            max_inflight_rows: 1 << 16,
            remote_swap_enabled: true,
        }
    }

    /// Bind and serve in the legacy thread-per-connection mode.
    ///
    /// Deprecated spelling: prefer
    /// `ScoreServer::builder(addr).model(model).policy(policy).spawn(score_fn)`,
    /// which also unlocks the readiness-loop edge, the HTTP ingress and
    /// the backpressure caps. Kept as a thin wrapper so existing
    /// callers compile unchanged.
    pub fn spawn<F>(
        addr: impl ToSocketAddrs,
        model: SvddModel,
        policy: BatchPolicy,
        score_fn: F,
    ) -> Result<ScoreServer>
    where
        F: Fn(&SvddModel, &Matrix) -> Result<Vec<f64>> + Send + 'static,
    {
        ScoreServer::builder(addr)
            .model(model)
            .policy(policy)
            .edge(false)
            .spawn(score_fn)
    }

    /// Allow or refuse the remote v2 `SwapModel` frame (refused frames
    /// get a `SwapAck { swapped: false }`; the connection survives and
    /// local swaps via [`ScoreServer::swap_model`] / the lifecycle
    /// driver are unaffected). The frame is *enabled* by default for
    /// library/embedded use, but the wire protocol carries no
    /// authentication, so `fastsvdd serve` keeps it disabled unless
    /// `--allow-remote-swap` is passed.
    pub fn set_remote_swap_enabled(&self, enabled: bool) {
        self.remote_swap.store(enabled, Ordering::Relaxed);
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Snapshot of the model currently being served.
    pub fn model(&self) -> Arc<SvddModel> {
        self.slot.current()
    }

    /// Clone of the server's model slot — hand this to a
    /// [`crate::registry::Lifecycle`] so drift-triggered retrains swap
    /// straight into the serve path.
    pub fn slot(&self) -> ModelSlot {
        self.slot.clone()
    }

    /// Hot-swap the served model; returns the new epoch. In-flight
    /// batches finish on the old model, later batches use the new one;
    /// no client connection is interrupted.
    pub fn swap_model(&self, model: SvddModel) -> Result<u64> {
        let epoch = self.slot.swap(model)?;
        self.metrics.model_swaps.inc();
        Ok(epoch)
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_thread.take() {
            h.join().ok();
        }
        self.batcher.shutdown();
    }
}

impl Drop for ScoreServer {
    fn drop(&mut self) {
        self.stop();
    }
}

impl ScoreService for ScoreServer {
    /// In-process scoring through the server's own batcher — shares the
    /// micro-batching window (and metrics) with network clients.
    fn score(&self, zs: &Matrix) -> Result<ScoreReply> {
        self.handle.score_reply(zs)
    }
}

/// Does the first 4 bytes of a connection look like an HTTP request
/// line rather than a native frame's length prefix? `b"GET "` read as a
/// little-endian u32 is ~0x20544547 (>500 MiB), far beyond
/// [`crate::distributed::message::MAX_FRAME`], so the two protocols
/// cannot collide: any real frame's prefix fails this test.
pub(crate) fn looks_like_http(first: &[u8; 4]) -> bool {
    matches!(first, b"GET " | b"HEAD" | b"POST" | b"PUT " | b"DELE" | b"PATC" | b"OPTI")
}

/// Minimal `GET /metrics` responder on the scoring listener. `first` is
/// the 4 bytes already peeked off the stream. One request per
/// connection; always closes after responding (Prometheus scrapers
/// reconnect per scrape).
fn serve_http(mut stream: TcpStream, first: &[u8; 4], metrics: &Metrics) -> Result<()> {
    use std::io::Read;
    // slow readers must not pin a connection thread forever
    stream.set_read_timeout(Some(std::time::Duration::from_secs(2))).ok();
    let mut buf = first.to_vec();
    let mut byte = [0u8; 1];
    // read to end-of-headers (tiny request; byte reads keep this simple)
    while !buf.ends_with(b"\r\n\r\n") && buf.len() < 8192 {
        match stream.read(&mut byte) {
            Ok(1) => buf.push(byte[0]),
            _ => break,
        }
    }
    let text = String::from_utf8_lossy(&buf);
    let request_line = text.lines().next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let (method, path, version) = (parts.next(), parts.next(), parts.next());
    let (status, body) = match (method, path, version) {
        (Some(m), Some(p), Some(v)) if v.starts_with("HTTP/") && parts.next().is_none() => {
            match (m, p) {
                ("GET", "/metrics") => ("200 OK", metrics.render_prometheus()),
                ("GET", _) => ("404 Not Found", "not found\n".to_string()),
                _ => ("405 Method Not Allowed", "only GET is supported\n".to_string()),
            }
        }
        _ => ("400 Bad Request", "malformed request line\n".to_string()),
    };
    let response = format!(
        "HTTP/1.1 {status}\r\n\
         Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    );
    use std::io::Write;
    stream.write_all(response.as_bytes())?;
    stream.flush()?;
    Ok(())
}

fn serve_connection(
    mut stream: TcpStream,
    handle: BatcherHandle,
    slot: ModelSlot,
    metrics: Arc<Metrics>,
    remote_swap: Arc<AtomicBool>,
) -> Result<()> {
    // One listener, two protocols: peek the first 4 bytes to tell an
    // HTTP request line from a native frame's length prefix.
    let mut first = [0u8; 4];
    {
        use std::io::Read;
        stream.read_exact(&mut first)?;
    }
    if looks_like_http(&first) {
        return serve_http(stream, &first, &metrics);
    }
    let session_version = match Message::read_after_len(first, &mut stream)? {
        Message::Hello { version } => match negotiate(version) {
            Some(v) => {
                Message::HelloAck { version: v }.write_to(&mut stream)?;
                v
            }
            None => {
                return Err(Error::Distributed(format!(
                    "peer version {version} too old"
                )));
            }
        },
        other => {
            return Err(Error::Distributed(format!("expected Hello, got {other:?}")));
        }
    };
    loop {
        let msg = match Message::read_from(&mut stream) {
            Ok(m) => m,
            Err(_) => return Ok(()),
        };
        // a session negotiated down must never carry frames newer than
        // it agreed to — drop the connection rather than answer with a
        // frame the peer cannot decode
        if msg.min_version() > session_version {
            return Err(Error::Distributed(format!(
                "v{} frame on a v{session_version} session: {msg:?}",
                msg.min_version()
            )));
        }
        let mut span = crate::obs::Span::enter("server.request");
        if span.is_live() {
            span.str(
                "kind",
                match &msg {
                    Message::ScoreRequest { .. } => "score",
                    Message::ScoreRequestV2 { .. } => "score_v2",
                    Message::ModelInfoRequest => "info",
                    Message::SwapModel { .. } => "swap",
                    Message::StatsRequest => "stats",
                    _ => "other",
                },
            );
        }
        match msg {
            Message::ScoreRequest { rows } => {
                match handle.score_with_r2(&rows) {
                    Ok((dist2, r2)) => {
                        Message::ScoreReply { dist2, r2 }.write_to(&mut stream)?;
                    }
                    Err(Error::Overloaded(reason)) if session_version >= 3 => {
                        Message::Overloaded { reason }.write_to(&mut stream)?;
                    }
                    // pre-v3 peers can't decode an Overloaded frame;
                    // dropping the connection is the only honest signal
                    Err(e) => return Err(e),
                }
            }
            Message::ScoreRequestV2 { rows } => {
                match handle.score_reply(&rows) {
                    Ok(reply) => {
                        Message::ScoreReplyV2 {
                            dist2: reply.dist2,
                            r2: reply.r2,
                            epoch: reply.epoch,
                            model_id: reply.model_id,
                        }
                        .write_to(&mut stream)?;
                    }
                    Err(Error::Overloaded(reason)) => {
                        // v2 score frames imply a v3 session (the gate
                        // above), which always understands Overloaded
                        Message::Overloaded { reason }.write_to(&mut stream)?;
                    }
                    Err(e) => return Err(e),
                }
            }
            Message::ModelInfoRequest => {
                let m = slot.current();
                Message::ModelInfo {
                    version: m.content_id(),
                    r2: m.r2(),
                    num_sv: m.num_sv() as u32,
                    dim: m.dim() as u32,
                    epoch: slot.epoch(),
                }
                .write_to(&mut stream)?;
            }
            Message::SwapModel { model_json } => {
                let reply = if !remote_swap.load(Ordering::Relaxed) {
                    Message::SwapAck {
                        epoch: slot.epoch(),
                        swapped: false,
                        r2: slot.current().r2(),
                        reason: "remote model swap is disabled on this server".into(),
                    }
                } else {
                    let outcome = Json::parse(&model_json)
                        .and_then(|j| SvddModel::from_json(&j))
                        .and_then(|m| slot.swap(m));
                    match outcome {
                        Ok(epoch) => {
                            metrics.model_swaps.inc();
                            Message::SwapAck {
                                epoch,
                                swapped: true,
                                r2: slot.current().r2(),
                                reason: String::new(),
                            }
                        }
                        Err(e) => Message::SwapAck {
                            epoch: slot.epoch(),
                            swapped: false,
                            r2: slot.current().r2(),
                            reason: e.to_string(),
                        },
                    }
                };
                reply.write_to(&mut stream)?;
            }
            Message::StatsRequest => {
                Message::StatsReply {
                    text: metrics.render_prometheus(),
                    counters: metrics.snapshot(),
                }
                .write_to(&mut stream)?;
            }
            Message::Shutdown => return Ok(()),
            other => {
                return Err(Error::Distributed(format!("unexpected {other:?}")));
            }
        }
    }
}

/// What the server reports about its active model (v2 `ModelInfo`).
#[derive(Clone, Debug, PartialEq)]
pub struct RemoteModelInfo {
    /// Content-addressed id (`SvddModel::content_id` spelling).
    pub version: String,
    pub r2: f64,
    pub num_sv: usize,
    pub dim: usize,
    /// Hot-swaps applied since the server started.
    pub epoch: u64,
}

/// Blocking client for the scoring service. Methods take `&self` (the
/// stream sits behind a mutex), so one client can be shared across
/// threads; each request/reply exchange holds the lock end to end.
pub struct ScoreClient {
    stream: Mutex<TcpStream>,
    /// Protocol version this session negotiated.
    version: u32,
}

impl ScoreClient {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<ScoreClient> {
        let mut stream = TcpStream::connect(addr)?;
        Message::Hello { version: PROTOCOL_VERSION }.write_to(&mut stream)?;
        let version = match Message::read_from(&mut stream)? {
            Message::HelloAck { version } if negotiate(version).is_some() => version,
            other => {
                return Err(Error::Distributed(format!("bad handshake: {other:?}")));
            }
        };
        Ok(ScoreClient { stream: Mutex::new(stream), version })
    }

    /// Protocol version negotiated with the server.
    pub fn version(&self) -> u32 {
        self.version
    }

    fn stream(&self) -> std::sync::MutexGuard<'_, TcpStream> {
        // a poisoned lock means a panic mid-exchange; the stream is
        // desynchronized either way, so just take it
        self.stream.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Score a batch; returns (dist2 per row, model R^2).
    pub fn score(&self, rows: &Matrix) -> Result<(Vec<f64>, f64)> {
        let mut stream = self.stream();
        Message::ScoreRequest { rows: rows.clone() }.write_to(&mut *stream)?;
        match Message::read_from(&mut *stream)? {
            Message::ScoreReply { dist2, r2 } => Ok((dist2, r2)),
            Message::Overloaded { reason } => Err(Error::Overloaded(reason)),
            other => Err(Error::Distributed(format!("unexpected {other:?}"))),
        }
    }

    /// Score a batch with full model provenance (v3): distances plus
    /// the R^2, swap epoch and content id of the exact model that
    /// scored it.
    pub fn score_detailed(&self, rows: &Matrix) -> Result<ScoreReply> {
        if self.version < 3 {
            return Err(Error::Distributed(format!(
                "score_detailed needs a v3 session, negotiated v{}",
                self.version
            )));
        }
        let mut stream = self.stream();
        Message::ScoreRequestV2 { rows: rows.clone() }.write_to(&mut *stream)?;
        match Message::read_from(&mut *stream)? {
            Message::ScoreReplyV2 { dist2, r2, epoch, model_id } => {
                Ok(ScoreReply { dist2, r2, epoch, model_id })
            }
            Message::Overloaded { reason } => Err(Error::Overloaded(reason)),
            other => Err(Error::Distributed(format!("unexpected {other:?}"))),
        }
    }

    /// Ask the server about its active model (v2).
    pub fn model_info(&self) -> Result<RemoteModelInfo> {
        let mut stream = self.stream();
        Message::ModelInfoRequest.write_to(&mut *stream)?;
        match Message::read_from(&mut *stream)? {
            Message::ModelInfo { version, r2, num_sv, dim, epoch } => Ok(RemoteModelInfo {
                version,
                r2,
                num_sv: num_sv as usize,
                dim: dim as usize,
                epoch,
            }),
            other => Err(Error::Distributed(format!("unexpected {other:?}"))),
        }
    }

    /// Pull the server's metrics (v2): the Prometheus exposition text
    /// plus the exact named-counter snapshot
    /// ([`crate::metrics::Metrics::snapshot`]) for cluster aggregation.
    pub fn stats(&self) -> Result<(String, Vec<(String, u64)>)> {
        let mut stream = self.stream();
        Message::StatsRequest.write_to(&mut *stream)?;
        match Message::read_from(&mut *stream)? {
            Message::StatsReply { text, counters } => Ok((text, counters)),
            other => Err(Error::Distributed(format!("unexpected {other:?}"))),
        }
    }

    /// Hot-swap the server's model (v2); returns the new epoch.
    pub fn swap_model(&self, model: &SvddModel) -> Result<u64> {
        let mut stream = self.stream();
        Message::SwapModel { model_json: model.to_json().to_string() }
            .write_to(&mut *stream)?;
        match Message::read_from(&mut *stream)? {
            Message::SwapAck { epoch, swapped: true, .. } => Ok(epoch),
            Message::SwapAck { swapped: false, reason, .. } => {
                Err(Error::Distributed(format!("swap rejected: {reason}")))
            }
            other => Err(Error::Distributed(format!("unexpected {other:?}"))),
        }
    }

    pub fn close(self) {
        Message::Shutdown.write_to(&mut *self.stream()).ok();
    }
}

impl ScoreService for ScoreClient {
    /// Remote scoring with provenance — requires a v3 server.
    fn score(&self, zs: &Matrix) -> Result<ScoreReply> {
        self.score_detailed(zs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{banana::Banana, Generator};
    use crate::svdd::{train, SvddParams};

    fn model() -> SvddModel {
        let data = Banana::default().generate(600, 1);
        train(&data, &SvddParams::gaussian(0.35, 0.01)).unwrap()
    }

    fn shifted_model() -> SvddModel {
        let mut data = Banana::default().generate(600, 2);
        for i in 0..data.rows() {
            data.row_mut(i)[0] += 6.0;
        }
        train(&data, &SvddParams::gaussian(0.35, 0.01)).unwrap()
    }

    fn spawn_native(model: SvddModel, policy: BatchPolicy) -> ScoreServer {
        ScoreServer::spawn("127.0.0.1:0", model, policy, |m, zs| Ok(m.dist2_batch(zs)))
            .unwrap()
    }

    #[test]
    fn serve_score_roundtrip() {
        let m = model();
        let mut server = spawn_native(m.clone(), BatchPolicy::default());
        let client = ScoreClient::connect(server.addr()).unwrap();
        let zs = Banana::default().generate(33, 2);
        let (dist2, r2) = client.score(&zs).unwrap();
        assert_eq!(dist2, m.dist2_batch(&zs));
        assert_eq!(r2, m.r2());
        client.close();
        server.stop();
        assert_eq!(server.metrics.rows_scored.get(), 33);
    }

    #[test]
    fn concurrent_clients_coalesce() {
        let m = model();
        let policy = BatchPolicy {
            target_batch: 64,
            linger: std::time::Duration::from_millis(20),
            capacity: 1 << 16,
            // timing-sensitive: keep the window fixed
            adaptive: false,
        };
        let mut server = spawn_native(m.clone(), policy);
        let addr = server.addr();
        let threads: Vec<_> = (0..6)
            .map(|i| {
                let m = m.clone();
                std::thread::spawn(move || {
                    let c = ScoreClient::connect(addr).unwrap();
                    let zs = Banana::default().generate(16, 50 + i);
                    let (dist2, _) = c.score(&zs).unwrap();
                    assert_eq!(dist2, m.dist2_batch(&zs), "client {i} mismatch");
                    c.close();
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(server.metrics.rows_scored.get(), 96);
        assert!(
            server.metrics.batches_scored.get() <= 4,
            "no coalescing: {} batches",
            server.metrics.batches_scored.get()
        );
        server.stop();
    }

    #[test]
    fn multiple_requests_per_connection() {
        let m = model();
        let mut server = spawn_native(m.clone(), BatchPolicy::default());
        let client = ScoreClient::connect(server.addr()).unwrap();
        for seed in 0..5 {
            let zs = Banana::default().generate(8, seed);
            let (dist2, _) = client.score(&zs).unwrap();
            assert_eq!(dist2, m.dist2_batch(&zs));
        }
        client.close();
        server.stop();
        assert_eq!(server.metrics.rows_scored.get(), 40);
    }

    #[test]
    fn model_info_reports_active_model() {
        let m = model();
        let mut server = spawn_native(m.clone(), BatchPolicy::default());
        let client = ScoreClient::connect(server.addr()).unwrap();
        let info = client.model_info().unwrap();
        assert_eq!(info.version, m.content_id());
        assert_eq!(info.r2, m.r2());
        assert_eq!(info.num_sv, m.num_sv());
        assert_eq!(info.dim, 2);
        assert_eq!(info.epoch, 0);
        client.close();
        server.stop();
    }

    #[test]
    fn remote_swap_changes_served_model_without_reconnect() {
        let m1 = model();
        let m2 = shifted_model();
        let mut server = spawn_native(m1.clone(), BatchPolicy::default());
        let client = ScoreClient::connect(server.addr()).unwrap();
        let zs = Banana::default().generate(12, 9);

        let (before, r2_before) = client.score(&zs).unwrap();
        assert_eq!(before, m1.dist2_batch(&zs));
        assert_eq!(r2_before, m1.r2());

        // swap over a *second* connection while the first stays open
        let admin = ScoreClient::connect(server.addr()).unwrap();
        assert_eq!(admin.swap_model(&m2).unwrap(), 1);
        admin.close();

        // v2 scores close to the original (JSON roundtrip of the model
        // reproduces dist2 almost exactly; shortest-roundtrip float
        // printing makes it bit-exact)
        let (after, r2_after) = client.score(&zs).unwrap();
        assert_eq!(after, m2.dist2_batch(&zs));
        assert_eq!(r2_after, m2.r2());

        let info = client.model_info().unwrap();
        assert_eq!(info.epoch, 1);
        assert_eq!(info.version, m2.content_id());
        client.close();
        server.stop();
        assert_eq!(server.metrics.model_swaps.get(), 1);
    }

    #[test]
    fn bad_swap_payload_rejected_connection_survives() {
        let m = model();
        let mut server = spawn_native(m.clone(), BatchPolicy::default());
        let client = ScoreClient::connect(server.addr()).unwrap();

        // hand-roll a bogus SwapModel frame
        Message::SwapModel { model_json: "{not json".into() }
            .write_to(&mut *client.stream())
            .unwrap();
        match Message::read_from(&mut *client.stream()).unwrap() {
            Message::SwapAck { swapped, epoch, .. } => {
                assert!(!swapped);
                assert_eq!(epoch, 0);
            }
            other => panic!("unexpected {other:?}"),
        }

        // the same connection still scores fine on the original model
        let zs = Banana::default().generate(5, 3);
        let (dist2, r2) = client.score(&zs).unwrap();
        assert_eq!(dist2, m.dist2_batch(&zs));
        assert_eq!(r2, m.r2());
        client.close();
        server.stop();
        assert_eq!(server.metrics.model_swaps.get(), 0);
    }

    #[test]
    fn remote_swap_can_be_disabled() {
        let m1 = model();
        let m2 = shifted_model();
        let mut server = spawn_native(m1.clone(), BatchPolicy::default());
        server.set_remote_swap_enabled(false);
        let client = ScoreClient::connect(server.addr()).unwrap();
        let err = client.swap_model(&m2).unwrap_err();
        assert!(err.to_string().contains("disabled"), "{err}");
        // the connection survives, still serving the original model,
        // and local (lifecycle) swaps keep working
        let zs = Banana::default().generate(6, 11);
        let (dist2, r2) = client.score(&zs).unwrap();
        assert_eq!(dist2, m1.dist2_batch(&zs));
        assert_eq!(r2, m1.r2());
        assert_eq!(server.swap_model(m2.clone()).unwrap(), 1);
        let (after, _) = client.score(&zs).unwrap();
        assert_eq!(after, m2.dist2_batch(&zs));
        client.close();
        server.stop();
    }

    /// Send raw bytes, read the whole response (server closes after
    /// responding).
    fn http_exchange(addr: std::net::SocketAddr, request: &[u8]) -> String {
        use std::io::{Read, Write};
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(request).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn http_get_metrics_returns_prometheus_text() {
        let m = model();
        let mut server = spawn_native(m.clone(), BatchPolicy::default());
        // score something first so the latency histogram has a sample
        let client = ScoreClient::connect(server.addr()).unwrap();
        client.score(&Banana::default().generate(10, 2)).unwrap();
        client.close();
        let resp = http_exchange(
            server.addr(),
            b"GET /metrics HTTP/1.1\r\nHost: x\r\nAccept: */*\r\n\r\n",
        );
        assert!(resp.starts_with("HTTP/1.1 200 OK\r\n"), "{resp}");
        assert!(resp.contains("Content-Type: text/plain; version=0.0.4"));
        let body = resp.split("\r\n\r\n").nth(1).unwrap();
        assert!(body.contains("# TYPE fastsvdd_rows_scored_total counter"));
        assert!(body.contains("fastsvdd_rows_scored_total 10"));
        assert!(body.contains("fastsvdd_score_latency_seconds_bucket"));
        assert!(body.contains("le=\"+Inf\""));
        // advertised length matches the body exactly
        let len: usize = resp
            .lines()
            .find_map(|l| l.strip_prefix("Content-Length: "))
            .unwrap()
            .parse()
            .unwrap();
        assert_eq!(len, body.len());
        server.stop();
    }

    #[test]
    fn http_unknown_path_is_404_and_malformed_line_is_400() {
        let m = model();
        let mut server = spawn_native(m, BatchPolicy::default());
        let resp = http_exchange(server.addr(), b"GET /nope HTTP/1.0\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 404"), "{resp}");
        // request line with no HTTP version token
        let resp = http_exchange(server.addr(), b"GET /metrics\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
        // non-GET method
        let resp = http_exchange(server.addr(), b"POST /metrics HTTP/1.1\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 405"), "{resp}");
        // native scoring still works after the HTTP traffic
        let client = ScoreClient::connect(server.addr()).unwrap();
        client.score(&Banana::default().generate(3, 8)).unwrap();
        client.close();
        server.stop();
    }

    #[test]
    fn stats_frame_returns_text_and_exact_counters() {
        let m = model();
        let mut server = spawn_native(m, BatchPolicy::default());
        let client = ScoreClient::connect(server.addr()).unwrap();
        client.score(&Banana::default().generate(7, 5)).unwrap();
        let (text, counters) = client.stats().unwrap();
        assert!(text.contains("fastsvdd_rows_scored_total 7"));
        let get = |k: &str| {
            counters
                .iter()
                .find(|(name, _)| name == k)
                .unwrap_or_else(|| panic!("counter {k} missing"))
                .1
        };
        assert_eq!(get("rows_scored"), 7);
        assert_eq!(get("score_latency_count"), 1);
        client.close();
        server.stop();
    }

    #[test]
    fn v1_session_never_sees_stats_frames() {
        // A peer that negotiated v1 and then sends a v2 StatsRequest
        // must get its connection dropped, not a StatsReply it cannot
        // decode.
        let m = model();
        let mut server = spawn_native(m, BatchPolicy::default());
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        Message::Hello { version: 1 }.write_to(&mut stream).unwrap();
        match Message::read_from(&mut stream).unwrap() {
            Message::HelloAck { version } => assert_eq!(version, 1),
            other => panic!("unexpected {other:?}"),
        }
        Message::StatsRequest.write_to(&mut stream).unwrap();
        assert!(
            Message::read_from(&mut stream).is_err(),
            "v1 session must be dropped on a v2 frame, not answered"
        );
        server.stop();
    }

    #[test]
    fn v1_client_still_served() {
        // A v1 peer sends Hello{1} and only ever uses v1 frames.
        let m = model();
        let mut server = spawn_native(m.clone(), BatchPolicy::default());
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        Message::Hello { version: 1 }.write_to(&mut stream).unwrap();
        match Message::read_from(&mut stream).unwrap() {
            Message::HelloAck { version } => assert_eq!(version, 1),
            other => panic!("unexpected {other:?}"),
        }
        let zs = Banana::default().generate(4, 4);
        Message::ScoreRequest { rows: zs.clone() }.write_to(&mut stream).unwrap();
        match Message::read_from(&mut stream).unwrap() {
            Message::ScoreReply { dist2, r2 } => {
                assert_eq!(dist2, m.dist2_batch(&zs));
                assert_eq!(r2, m.r2());
            }
            other => panic!("unexpected {other:?}"),
        }
        Message::Shutdown.write_to(&mut stream).ok();
        server.stop();
    }

    #[test]
    fn builder_without_model_errors() {
        let err = ScoreServer::builder("127.0.0.1:0")
            .spawn(|m: &SvddModel, zs: &Matrix| Ok(m.dist2_batch(zs)))
            .unwrap_err();
        assert!(err.to_string().contains("builder needs"), "{err}");
    }

    #[test]
    fn builder_edge_server_serves_native_with_provenance() {
        let m = model();
        let mut server = ScoreServer::builder("127.0.0.1:0")
            .model(m.clone())
            .spawn(|mo, zs| Ok(mo.dist2_batch(zs)))
            .unwrap();
        let client = ScoreClient::connect(server.addr()).unwrap();
        assert_eq!(client.version(), 3);
        let zs = Banana::default().generate(9, 21);
        let reply = client.score_detailed(&zs).unwrap();
        assert_eq!(reply.dist2, m.dist2_batch(&zs));
        assert_eq!(reply.r2, m.r2());
        assert_eq!(reply.epoch, 0);
        assert_eq!(reply.model_id, m.content_id());
        // the in-process ScoreService path shares the same batcher
        let local = ScoreService::score(&server, &zs).unwrap();
        assert_eq!(local.dist2, reply.dist2);
        client.close();
        server.stop();
        assert_eq!(server.metrics.rows_scored.get(), 18);
    }

    #[test]
    fn score_detailed_works_on_threaded_server() {
        let m = model();
        let mut server = spawn_native(m.clone(), BatchPolicy::default());
        let client = ScoreClient::connect(server.addr()).unwrap();
        let zs = Banana::default().generate(5, 33);
        let reply = client.score_detailed(&zs).unwrap();
        assert_eq!(reply.dist2, m.dist2_batch(&zs));
        assert_eq!(reply.model_id, m.content_id());
        assert_eq!(reply.epoch, 0);
        client.close();
        server.stop();
    }
}
