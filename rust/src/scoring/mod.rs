//! Batch scoring service: the serve-path component that evaluates
//! `dist2(z)` for streams of observations (paper eq. (18)) and labels
//! outliers against the model threshold.
//!
//! Two interchangeable engines:
//! - [`Scorer::native`] — pure-Rust evaluation (the reference);
//! - [`Scorer::xla`] — batches through the AOT Pallas scoring artifact
//!   via [`crate::runtime::SharedRuntime`], padding the final chunk and
//!   picking the smallest bucket (256 latency / 4096 throughput) per
//!   chunk.
//!
//! Integration tests cross-check the two engines on every bucket.
//!
//! The serve path ([`batcher`], [`server`], the [`edge`] multiplexer
//! and its [`http`] ingress) runs over a hot-swappable [`ModelSlot`],
//! so the model-lifecycle layer ([`crate::registry`]) can promote a
//! freshly retrained model into a live server with zero dropped
//! connections.

pub mod batcher;
pub mod edge;
pub mod f1;
pub mod http;
pub mod server;

pub use batcher::{BatchPolicy, Batcher, BatcherHandle, ModelSlot};
pub use edge::EdgeConfig;
pub use server::{RemoteModelInfo, ScoreClient, ScoreServer};
pub use f1::{confusion, F1Score};

use crate::error::Result;
use crate::runtime::SharedRuntime;
use crate::svdd::model::SvddModel;
use crate::util::matrix::Matrix;

/// Uniform reply from every scoring entry point: the distances, the
/// threshold they compare against, and exactly which model produced
/// them — so a caller can correlate replies across hot-swaps.
#[derive(Clone, Debug, PartialEq)]
pub struct ScoreReply {
    /// `dist2(z)` per input row (paper eq. (18)).
    pub dist2: Vec<f64>,
    /// Decision threshold R^2 of the model that scored this batch.
    pub r2: f64,
    /// Hot-swap epoch of that model (0 = spawn-time model; in-process
    /// scorers with no [`ModelSlot`] always report 0).
    pub epoch: u64,
    /// Content id of that model ([`SvddModel::content_id`]).
    pub model_id: String,
}

impl ScoreReply {
    /// Outlier labels for this reply (`dist2 > R^2`), guaranteed to use
    /// the same model's threshold that produced the distances.
    pub fn labels(&self) -> Vec<bool> {
        self.dist2.iter().map(|&d| d > self.r2).collect()
    }
}

/// One scoring API over all three entry points — the in-process engine
/// ([`Scorer`]), the dynamic batcher ([`BatcherHandle`]) and the remote
/// client ([`ScoreClient`]) — the serving mirror of the training-side
/// `Trainer` trait. Callers generic over `S: ScoreService` can move
/// between local, batched and remote scoring without code changes.
///
/// `BatcherHandle` and `ScoreClient` also keep inherent `score` methods
/// with their historical signatures; those shadow the trait method on a
/// concrete receiver, so reach the trait through a generic bound or
/// `ScoreService::score(&svc, zs)`.
pub trait ScoreService {
    /// Score every row of `zs`, reporting which model did it.
    fn score(&self, zs: &Matrix) -> Result<ScoreReply>;
}

impl ScoreService for Scorer<'_> {
    /// In-process scoring. `epoch` is always 0 (no slot to swap);
    /// `model_id` is recomputed per call — prefer
    /// [`Scorer::dist2_batch`] on hot paths that don't need provenance.
    fn score(&self, zs: &Matrix) -> Result<ScoreReply> {
        Ok(ScoreReply {
            dist2: self.dist2_batch(zs)?,
            r2: self.model.r2(),
            epoch: 0,
            model_id: self.model.content_id(),
        })
    }
}

/// Scoring engine over a fitted model.
pub struct Scorer<'a> {
    model: &'a SvddModel,
    runtime: Option<&'a SharedRuntime>,
    /// Model data padded for the XLA path (computed lazily once).
    padded: Option<(Vec<f32>, Vec<f32>, usize)>,
    /// f32 view for the opt-in native-f32 panel path
    /// ([`Scorer::native_f32`]; never set together with `runtime`).
    f32_model: Option<crate::svdd::ModelF32>,
}

impl<'a> Scorer<'a> {
    /// Pure-Rust scorer.
    pub fn native(model: &'a SvddModel) -> Scorer<'a> {
        Scorer { model, runtime: None, padded: None, f32_model: None }
    }

    /// Pure-Rust scorer on the opt-in f32 panel path (`--precision
    /// f32`): the model is narrowed once, batches score through
    /// [`crate::linalg::dot_block_f32`] panels, and distances widen
    /// back to f64 for thresholding. Same precision as the XLA/AOT
    /// boundary, without the runtime — tolerance-only contract vs
    /// [`Scorer::native`] (see [`crate::svdd::ModelF32`]).
    pub fn native_f32(model: &'a SvddModel) -> Scorer<'a> {
        Scorer {
            model,
            runtime: None,
            padded: None,
            f32_model: Some(model.to_f32()),
        }
    }

    /// XLA-backed scorer (falls back to native when no bucket fits —
    /// e.g. a model with more SVs than the bucket, or a non-Gaussian
    /// kernel, which the artifacts don't cover).
    pub fn xla(model: &'a SvddModel, runtime: &'a SharedRuntime) -> Scorer<'a> {
        let padded = if model.kernel().bw().is_some() {
            runtime.pad_model(model)
        } else {
            None
        };
        Scorer { model, runtime: Some(runtime), padded, f32_model: None }
    }

    /// True when scores go through the PJRT executable.
    pub fn is_accelerated(&self) -> bool {
        self.runtime.is_some() && self.padded.is_some()
    }

    /// `"f32"` when this scorer runs the narrowed panel path (either
    /// the native-f32 engine or the XLA artifact, which is f32 end to
    /// end); `"f64"` for the native reference.
    pub fn precision(&self) -> &'static str {
        if self.f32_model.is_some() || self.is_accelerated() {
            "f32"
        } else {
            "f64"
        }
    }

    /// `dist2` for every row of `zs`.
    pub fn dist2_batch(&self, zs: &Matrix) -> Result<Vec<f64>> {
        if let Some(f32m) = &self.f32_model {
            return Ok(f32m.dist2_batch(zs));
        }
        match (&self.runtime, &self.padded) {
            (Some(rt), Some((sv, alpha, s))) => {
                self.dist2_xla(rt, sv, alpha, *s, zs)
            }
            _ => Ok(self.model.dist2_batch(zs)),
        }
    }

    /// Outlier labels (`dist2 > R^2`) for every row.
    pub fn label_batch(&self, zs: &Matrix) -> Result<Vec<bool>> {
        let r2 = self.model.r2();
        Ok(self.dist2_batch(zs)?.into_iter().map(|d| d > r2).collect())
    }

    /// Inside labels (`dist2 <= R^2`) — the "belongs to the target
    /// class" predicate the F1 experiments use.
    pub fn inside_batch(&self, zs: &Matrix) -> Result<Vec<bool>> {
        let r2 = self.model.r2();
        Ok(self.dist2_batch(zs)?.into_iter().map(|d| d <= r2).collect())
    }

    fn dist2_xla(
        &self,
        rt: &SharedRuntime,
        sv: &[f32],
        alpha: &[f32],
        s: usize,
        zs: &Matrix,
    ) -> Result<Vec<f64>> {
        let m = self.model.dim();
        let bw = self.model.kernel().bw().expect("xla scorer requires gaussian") as f32;
        let w = self.model.w() as f32;
        let n = zs.rows();
        let mut out = Vec::with_capacity(n);
        let flat = zs.to_f32();
        let mut offset = 0usize;
        while offset < n {
            let remaining = n - offset;
            // smallest bucket that covers the remainder, else the largest
            // bucket repeatedly
            let (artifact, b) = {
                let info = rt.with(|r| {
                    r.manifest()
                        .find_score(m, self.model.num_sv(), remaining)
                        .or_else(|| r.manifest().find_score_largest(m, self.model.num_sv()))
                        .map(|i| (i.name.clone(), i.kind))
                });
                match info {
                    Some((name, crate::runtime::ArtifactKind::Score { b, .. })) => (name, b),
                    _ => {
                        // no artifact for this dim: native fallback for the rest
                        for i in offset..n {
                            out.push(self.model.dist2(zs.row(i)));
                        }
                        return Ok(out);
                    }
                }
            };
            let take = remaining.min(b);
            let mut z = vec![0.0f32; b * m];
            z[..take * m].copy_from_slice(&flat[offset * m..(offset + take) * m]);
            let scores = rt.with(|r| {
                r.score_bucket(&artifact, b, m, s, &z, sv, alpha, bw, w)
            })?;
            out.extend(scores[..take].iter().map(|&x| x as f64));
            offset += take;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{banana::Banana, Generator};
    use crate::svdd::{train, SvddParams};

    #[test]
    fn native_scorer_matches_model() {
        let data = Banana::default().generate(300, 1);
        let model = train(&data, &SvddParams::gaussian(0.35, 0.01)).unwrap();
        let scorer = Scorer::native(&model);
        assert!(!scorer.is_accelerated());
        let zs = Banana::default().generate(64, 2);
        let got = scorer.dist2_batch(&zs).unwrap();
        let want = model.dist2_batch(&zs);
        assert_eq!(got, want);
    }

    #[test]
    fn native_f32_scorer_tracks_native_within_tolerance() {
        let data = Banana::default().generate(300, 7);
        let model = train(&data, &SvddParams::gaussian(0.35, 0.01)).unwrap();
        let f64_scorer = Scorer::native(&model);
        let f32_scorer = Scorer::native_f32(&model);
        assert_eq!(f64_scorer.precision(), "f64");
        assert_eq!(f32_scorer.precision(), "f32");
        assert!(!f32_scorer.is_accelerated());
        let zs = Banana::default().generate(200, 8);
        let want = f64_scorer.dist2_batch(&zs).unwrap();
        let got = f32_scorer.dist2_batch(&zs).unwrap();
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() <= 5e-5 * w.abs().max(1.0),
                "row {i}: f32 {g} vs f64 {w}"
            );
        }
        // labels use the exact f64 threshold on both engines
        let lf64 = f64_scorer.label_batch(&zs).unwrap();
        let lf32 = f32_scorer.label_batch(&zs).unwrap();
        let disagreements = lf64.iter().zip(&lf32).filter(|(a, b)| a != b).count();
        // only rows within f32 noise of the boundary may flip
        assert!(disagreements <= 2, "{disagreements} label flips");
    }

    #[test]
    fn label_and_inside_are_complementary() {
        let data = Banana::default().generate(300, 3);
        let model = train(&data, &SvddParams::gaussian(0.35, 0.01)).unwrap();
        let scorer = Scorer::native(&model);
        let zs = Banana::default().generate(128, 4);
        let out = scorer.label_batch(&zs).unwrap();
        let ins = scorer.inside_batch(&zs).unwrap();
        for (o, i) in out.iter().zip(&ins) {
            assert_ne!(o, i);
        }
    }

    #[test]
    fn score_service_over_scorer_reports_provenance() {
        let data = Banana::default().generate(300, 5);
        let model = train(&data, &SvddParams::gaussian(0.35, 0.01)).unwrap();
        let scorer = Scorer::native(&model);
        let zs = Banana::default().generate(32, 6);
        let reply = ScoreService::score(&scorer, &zs).unwrap();
        assert_eq!(reply.dist2, model.dist2_batch(&zs));
        assert_eq!(reply.r2, model.r2());
        assert_eq!(reply.epoch, 0);
        assert_eq!(reply.model_id, model.content_id());
        assert_eq!(reply.labels(), scorer.label_batch(&zs).unwrap());
    }
}
