//! Dynamic batching for the serve path, over a hot-swappable model.
//!
//! The XLA scoring artifact runs at fixed bucket shapes (256 / 4096
//! rows); single-observation requests would waste 255/256 of every
//! execution. [`Batcher`] coalesces concurrent score requests into
//! bucket-sized batches: requests enqueue rows and block on a receiver;
//! the dispatch loop drains the queue when either the target batch
//! fills or the linger deadline passes, scores once, and fans results
//! back out. This is the standard dynamic-batching coordinator of
//! serving systems (vLLM-style), applied to SVDD scoring.
//!
//! Coalescing composes with the parallel execution subsystem: the
//! native engine's [`SvddModel::dist2_batch`] scores a drained batch in
//! row chunks on the shared [`crate::parallel`] pool, so one large
//! coalesced batch uses every core while tiny batches stay on the
//! dispatch thread (cost gate) — and either way the scores are
//! bit-identical to the serial path.
//!
//! The active model lives in a [`ModelSlot`] — a swappable slot the
//! model-lifecycle layer replaces on promote (`fastsvdd serve
//! --registry --watch`, `Message::SwapModel`). The dispatch loop takes
//! an `Arc` snapshot of the slot per batch, so a swap never tears a
//! batch: in-flight batches finish on the model they started with, the
//! next drained batch scores on the new one, and no request is ever
//! dropped or errored by a swap.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::metrics::Metrics;
use crate::svdd::model::SvddModel;
use crate::util::matrix::Matrix;

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Dispatch as soon as this many rows are queued.
    pub target_batch: usize,
    /// Dispatch a partial batch after this long (latency bound).
    pub linger: Duration,
    /// Queue capacity in rows (backpressure: enqueue errors beyond it).
    pub capacity: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            target_batch: 256,
            linger: Duration::from_millis(2),
            capacity: 1 << 16,
        }
    }
}

/// The hot-swappable model slot shared by the batcher, the connection
/// handlers and the lifecycle driver. Cloning is cheap (Arc handles);
/// all clones observe the same slot.
///
/// Readers call [`ModelSlot::current`] and get an `Arc` snapshot that
/// stays valid for as long as they hold it — swapping never invalidates
/// a reader mid-batch. The write lock is held only for the pointer
/// replacement, so swap latency is independent of model size.
#[derive(Clone)]
pub struct ModelSlot {
    current: Arc<RwLock<Arc<SvddModel>>>,
    epoch: Arc<AtomicU64>,
    dim: usize,
}

impl ModelSlot {
    pub fn new(model: SvddModel) -> ModelSlot {
        let dim = model.dim();
        ModelSlot {
            current: Arc::new(RwLock::new(Arc::new(model))),
            epoch: Arc::new(AtomicU64::new(0)),
            dim,
        }
    }

    /// Snapshot of the active model.
    pub fn current(&self) -> Arc<SvddModel> {
        self.current.read().expect("model slot poisoned").clone()
    }

    /// Replace the active model; returns the new epoch. The input
    /// dimension is pinned at slot creation — clients hold open
    /// connections that keep sending `dim`-wide rows, so a swap to a
    /// model of another dimension is refused rather than letting every
    /// subsequent request fail.
    pub fn swap(&self, model: SvddModel) -> Result<u64> {
        if model.dim() != self.dim {
            return Err(Error::invalid(format!(
                "hot-swap dimension mismatch: slot serves {}-d rows, new model is {}-d",
                self.dim,
                model.dim()
            )));
        }
        let next = Arc::new(model);
        let mut slot = self.current.write().expect("model slot poisoned");
        *slot = next;
        Ok(self.epoch.fetch_add(1, Ordering::AcqRel) + 1)
    }

    /// Number of swaps applied so far (0 for the spawn-time model).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    pub fn dim(&self) -> usize {
        self.dim
    }
}

struct Request {
    rows: Vec<f64>, // flattened
    n: usize,
    /// Scores plus the R^2 of the model that produced them, so each
    /// reply is internally consistent across a swap.
    reply: mpsc::Sender<(Vec<f64>, f64)>,
}

struct Queue {
    requests: Vec<Request>,
    queued_rows: usize,
    shutdown: bool,
}

/// A dynamic-batching scoring front end. Clone the handle freely; call
/// [`BatcherHandle::score`] from any thread.
pub struct Batcher {
    shared: Arc<(Mutex<Queue>, Condvar)>,
    worker: Option<std::thread::JoinHandle<()>>,
}

#[derive(Clone)]
pub struct BatcherHandle {
    shared: Arc<(Mutex<Queue>, Condvar)>,
    dim: usize,
    capacity: usize,
}

impl Batcher {
    /// Spawn the dispatch loop over a scoring closure and a model slot.
    /// The closure receives the model snapshot the batch was pinned to
    /// and a `(rows, dim)` matrix, and returns dist^2 per row; it runs
    /// on the dispatch thread (e.g. wraps `Scorer::xla`).
    pub fn spawn<F>(
        slot: &ModelSlot,
        policy: BatchPolicy,
        metrics: Arc<Metrics>,
        score_fn: F,
    ) -> (Batcher, BatcherHandle)
    where
        F: Fn(&SvddModel, &Matrix) -> Result<Vec<f64>> + Send + 'static,
    {
        let dim = slot.dim();
        let shared = Arc::new((
            Mutex::new(Queue { requests: Vec::new(), queued_rows: 0, shutdown: false }),
            Condvar::new(),
        ));
        let shared2 = shared.clone();
        let slot2 = slot.clone();
        let worker = std::thread::spawn(move || {
            dispatch_loop(shared2, policy, slot2, metrics, score_fn);
        });
        let handle = BatcherHandle {
            shared: shared.clone(),
            dim,
            capacity: policy.capacity,
        };
        (Batcher { shared, worker: Some(worker) }, handle)
    }

    /// Stop the dispatch loop after draining the queue.
    pub fn shutdown(&mut self) {
        {
            let (lock, cv) = &*self.shared;
            lock.lock().unwrap().shutdown = true;
            cv.notify_all();
        }
        if let Some(h) = self.worker.take() {
            h.join().ok();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl BatcherHandle {
    /// Score a batch of observations; blocks until the dispatch loop
    /// returns this request's scores.
    pub fn score(&self, zs: &Matrix) -> Result<Vec<f64>> {
        self.score_with_r2(zs).map(|(dist2, _)| dist2)
    }

    /// Like [`BatcherHandle::score`], also returning the R^2 threshold
    /// of the model snapshot that scored this batch (the pair a
    /// `ScoreReply` needs to stay consistent across hot-swaps).
    pub fn score_with_r2(&self, zs: &Matrix) -> Result<(Vec<f64>, f64)> {
        if zs.cols() != self.dim {
            return Err(Error::invalid(format!(
                "batcher expects dim {}, got {}",
                self.dim,
                zs.cols()
            )));
        }
        let (tx, rx) = mpsc::channel();
        {
            let (lock, cv) = &*self.shared;
            let mut q = lock.lock().unwrap();
            if q.shutdown {
                return Err(Error::invalid("batcher is shut down"));
            }
            if q.queued_rows + zs.rows() > self.capacity {
                return Err(Error::invalid("scoring queue full (backpressure)"));
            }
            q.queued_rows += zs.rows();
            q.requests.push(Request {
                rows: zs.as_slice().to_vec(),
                n: zs.rows(),
                reply: tx,
            });
            cv.notify_all();
        }
        rx.recv()
            .map_err(|_| Error::invalid("batcher dropped the request"))
    }
}

fn dispatch_loop<F>(
    shared: Arc<(Mutex<Queue>, Condvar)>,
    policy: BatchPolicy,
    slot: ModelSlot,
    metrics: Arc<Metrics>,
    score_fn: F,
) where
    F: Fn(&SvddModel, &Matrix) -> Result<Vec<f64>>,
{
    let dim = slot.dim();
    let (lock, cv) = &*shared;
    loop {
        // wait until there is work (or shutdown)
        let mut q = lock.lock().unwrap();
        while q.requests.is_empty() && !q.shutdown {
            q = cv.wait(q).unwrap();
        }
        if q.requests.is_empty() && q.shutdown {
            return;
        }
        // linger for more work up to the deadline or the target batch
        let deadline = Instant::now() + policy.linger;
        while q.queued_rows < policy.target_batch && !q.shutdown {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (nq, timeout) = cv.wait_timeout(q, deadline - now).unwrap();
            q = nq;
            if timeout.timed_out() {
                break;
            }
        }
        let batch: Vec<Request> = std::mem::take(&mut q.requests);
        q.queued_rows = 0;
        drop(q);

        // pin the model for this whole batch: a swap landing mid-score
        // takes effect from the *next* drained batch
        let model = slot.current();

        // assemble one matrix for the whole batch
        let total: usize = batch.iter().map(|r| r.n).sum();
        let mut flat = Vec::with_capacity(total * dim);
        for r in &batch {
            flat.extend_from_slice(&r.rows);
        }
        let zs = Matrix::from_vec(flat, total, dim).expect("batch assembly");
        let mut span = crate::obs::Span::enter("batcher.batch");
        if span.is_live() {
            span.u64("rows", total as u64);
            span.u64("requests", batch.len() as u64);
        }
        let sw = crate::util::timer::Stopwatch::start();
        let scores = score_fn(&model, &zs).unwrap_or_else(|_| vec![f64::NAN; total]);
        drop(span);
        metrics.score_latency.observe(sw.elapsed_secs());
        metrics.batches_scored.inc();
        metrics.rows_scored.add(total as u64);

        // fan out
        let r2 = model.r2();
        let mut offset = 0;
        for r in batch {
            let slice = scores[offset..offset + r.n].to_vec();
            offset += r.n;
            let _ = r.reply.send((slice, r2)); // receiver may have gone away
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{banana::Banana, Generator};
    use crate::svdd::{train, SvddParams};

    fn model() -> SvddModel {
        let data = Banana::default().generate(500, 1);
        train(&data, &SvddParams::gaussian(0.35, 0.01)).unwrap()
    }

    fn shifted_model() -> SvddModel {
        let mut data = Banana::default().generate(500, 2);
        for i in 0..data.rows() {
            data.row_mut(i)[0] += 6.0;
        }
        train(&data, &SvddParams::gaussian(0.35, 0.01)).unwrap()
    }

    fn spawn_native(
        slot: &ModelSlot,
        policy: BatchPolicy,
        metrics: Arc<Metrics>,
    ) -> (Batcher, BatcherHandle) {
        Batcher::spawn(slot, policy, metrics, |m, zs| Ok(m.dist2_batch(zs)))
    }

    #[test]
    fn single_request_roundtrip() {
        let m = model();
        let metrics = Arc::new(Metrics::new());
        let slot = ModelSlot::new(m.clone());
        let (_b, h) = spawn_native(&slot, BatchPolicy::default(), metrics.clone());
        let zs = Banana::default().generate(17, 2);
        let (got, r2) = h.score_with_r2(&zs).unwrap();
        assert_eq!(got, m.dist2_batch(&zs));
        assert_eq!(r2, m.r2());
        assert_eq!(metrics.rows_scored.get(), 17);
    }

    #[test]
    fn concurrent_requests_coalesce_and_return_correctly() {
        let m = model();
        let metrics = Arc::new(Metrics::new());
        let policy = BatchPolicy {
            target_batch: 64,
            linger: Duration::from_millis(20),
            capacity: 1 << 16,
        };
        let slot = ModelSlot::new(m.clone());
        let (_b, h) = spawn_native(&slot, policy, metrics.clone());
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let h = h.clone();
                let m = m.clone();
                std::thread::spawn(move || {
                    let zs = Banana::default().generate(16, 100 + i);
                    let got = h.score(&zs).unwrap();
                    assert_eq!(got, m.dist2_batch(&zs), "thread {i} got wrong rows");
                })
            })
            .collect();
        for t in handles {
            t.join().unwrap();
        }
        // 8 * 16 = 128 rows; with a 64-row target they must have been
        // dispatched in >= 1 but << 8 executions
        assert_eq!(metrics.rows_scored.get(), 128);
        assert!(
            metrics.batches_scored.get() <= 4,
            "coalescing failed: {} batches",
            metrics.batches_scored.get()
        );
    }

    #[test]
    fn dimension_mismatch_rejected() {
        let m = model();
        let metrics = Arc::new(Metrics::new());
        let slot = ModelSlot::new(m);
        let (_b, h) = spawn_native(&slot, BatchPolicy::default(), metrics);
        let bad = Matrix::zeros(4, 5);
        assert!(h.score(&bad).is_err());
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let m = model();
        let metrics = Arc::new(Metrics::new());
        let policy = BatchPolicy {
            target_batch: 1 << 20,              // never fills
            linger: Duration::from_millis(200), // long linger holds the queue
            capacity: 32,
        };
        let slot = ModelSlot::new(m);
        let (_b, h) = spawn_native(&slot, policy, metrics);
        // first request parks in the queue
        let h2 = h.clone();
        let t = std::thread::spawn(move || {
            let zs = Banana::default().generate(30, 3);
            h2.score(&zs).unwrap()
        });
        std::thread::sleep(Duration::from_millis(30));
        // second request overflows the 32-row capacity while the first lingers
        let zs = Banana::default().generate(10, 4);
        assert!(h.score(&zs).is_err(), "backpressure did not trip");
        t.join().unwrap();
    }

    #[test]
    fn shutdown_rejects_new_work() {
        let m = model();
        let metrics = Arc::new(Metrics::new());
        let slot = ModelSlot::new(m);
        let (mut b, h) = spawn_native(&slot, BatchPolicy::default(), metrics);
        b.shutdown();
        assert!(h.score(&Banana::default().generate(1, 5)).is_err());
    }

    #[test]
    fn slot_swap_bumps_epoch_and_changes_scores() {
        let m1 = model();
        let m2 = shifted_model();
        let metrics = Arc::new(Metrics::new());
        let slot = ModelSlot::new(m1.clone());
        assert_eq!(slot.epoch(), 0);
        let (_b, h) = spawn_native(&slot, BatchPolicy::default(), metrics);
        let zs = Banana::default().generate(9, 6);
        let (before, r2_before) = h.score_with_r2(&zs).unwrap();
        assert_eq!(before, m1.dist2_batch(&zs));
        assert_eq!(r2_before, m1.r2());

        assert_eq!(slot.swap(m2.clone()).unwrap(), 1);
        assert_eq!(slot.epoch(), 1);
        let (after, r2_after) = h.score_with_r2(&zs).unwrap();
        assert_eq!(after, m2.dist2_batch(&zs));
        assert_eq!(r2_after, m2.r2());
    }

    #[test]
    fn slot_swap_rejects_dimension_change() {
        let m = model(); // 2-d
        let slot = ModelSlot::new(m);
        let sv = Matrix::from_rows(&[vec![0.0, 1.0, 2.0]]).unwrap();
        let odd = SvddModel::new(sv, vec![1.0], crate::svdd::Kernel::gaussian(1.0), 0.5, 1.0)
            .unwrap();
        assert!(slot.swap(odd).is_err());
        assert_eq!(slot.epoch(), 0, "failed swap must not bump the epoch");
    }

    #[test]
    fn replies_are_model_consistent_under_swap_storm() {
        // Clients hammer the batcher while the slot flips between two
        // models; every reply must be *exactly* one model's scores with
        // that same model's R^2 — never a torn mix.
        let m1 = model();
        let m2 = shifted_model();
        let metrics = Arc::new(Metrics::new());
        let policy = BatchPolicy {
            target_batch: 32,
            linger: Duration::from_micros(200),
            capacity: 1 << 16,
        };
        let slot = ModelSlot::new(m1.clone());
        let (_b, h) = spawn_native(&slot, policy, metrics);

        let zs = Banana::default().generate(8, 7);
        let want1 = (m1.dist2_batch(&zs), m1.r2());
        let want2 = (m2.dist2_batch(&zs), m2.r2());

        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let clients: Vec<_> = (0..4)
            .map(|_| {
                let h = h.clone();
                let zs = zs.clone();
                let stop = stop.clone();
                let want1 = want1.clone();
                let want2 = want2.clone();
                std::thread::spawn(move || {
                    let mut checked = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        let got = h.score_with_r2(&zs).unwrap();
                        assert!(
                            got == want1 || got == want2,
                            "torn reply: r2={} (v1 r2={}, v2 r2={})",
                            got.1,
                            want1.1,
                            want2.1
                        );
                        checked += 1;
                    }
                    checked
                })
            })
            .collect();

        for i in 0..50 {
            let next = if i % 2 == 0 { m2.clone() } else { m1.clone() };
            slot.swap(next).unwrap();
            std::thread::sleep(Duration::from_micros(500));
        }
        stop.store(true, Ordering::Relaxed);
        let total: u64 = clients.into_iter().map(|t| t.join().unwrap()).sum();
        assert!(total > 0, "clients never scored");
        assert_eq!(slot.epoch(), 50);
    }
}
