//! Dynamic batching for the serve path.
//!
//! The XLA scoring artifact runs at fixed bucket shapes (256 / 4096
//! rows); single-observation requests would waste 255/256 of every
//! execution. [`Batcher`] coalesces concurrent score requests into
//! bucket-sized batches: requests enqueue rows and block on a receiver;
//! the dispatch loop drains the queue when either the target batch
//! fills or the linger deadline passes, scores once, and fans results
//! back out. This is the standard dynamic-batching coordinator of
//! serving systems (vLLM-style), applied to SVDD scoring.

use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::metrics::Metrics;
use crate::svdd::model::SvddModel;
use crate::util::matrix::Matrix;

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Dispatch as soon as this many rows are queued.
    pub target_batch: usize,
    /// Dispatch a partial batch after this long (latency bound).
    pub linger: Duration,
    /// Queue capacity in rows (backpressure: enqueue errors beyond it).
    pub capacity: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            target_batch: 256,
            linger: Duration::from_millis(2),
            capacity: 1 << 16,
        }
    }
}

struct Request {
    rows: Vec<f64>, // flattened
    n: usize,
    reply: mpsc::Sender<Vec<f64>>,
}

struct Queue {
    requests: Vec<Request>,
    queued_rows: usize,
    shutdown: bool,
}

/// A dynamic-batching scoring front end. Clone the handle freely; call
/// [`BatcherHandle::score`] from any thread.
pub struct Batcher {
    shared: Arc<(Mutex<Queue>, Condvar)>,
    worker: Option<std::thread::JoinHandle<()>>,
}

#[derive(Clone)]
pub struct BatcherHandle {
    shared: Arc<(Mutex<Queue>, Condvar)>,
    dim: usize,
    capacity: usize,
}

impl Batcher {
    /// Spawn the dispatch loop over a scoring closure. The closure
    /// receives a `(rows, dim)` matrix and returns dist^2 per row; it
    /// runs on the dispatch thread (e.g. wraps `Scorer::xla`).
    pub fn spawn<F>(
        model: &SvddModel,
        policy: BatchPolicy,
        metrics: Arc<Metrics>,
        score_fn: F,
    ) -> (Batcher, BatcherHandle)
    where
        F: Fn(&Matrix) -> Result<Vec<f64>> + Send + 'static,
    {
        let dim = model.dim();
        let shared = Arc::new((
            Mutex::new(Queue { requests: Vec::new(), queued_rows: 0, shutdown: false }),
            Condvar::new(),
        ));
        let shared2 = shared.clone();
        let worker = std::thread::spawn(move || {
            dispatch_loop(shared2, policy, dim, metrics, score_fn);
        });
        let handle = BatcherHandle {
            shared: shared.clone(),
            dim,
            capacity: policy.capacity,
        };
        (Batcher { shared, worker: Some(worker) }, handle)
    }

    /// Stop the dispatch loop after draining the queue.
    pub fn shutdown(&mut self) {
        {
            let (lock, cv) = &*self.shared;
            lock.lock().unwrap().shutdown = true;
            cv.notify_all();
        }
        if let Some(h) = self.worker.take() {
            h.join().ok();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl BatcherHandle {
    /// Score a batch of observations; blocks until the dispatch loop
    /// returns this request's scores.
    pub fn score(&self, zs: &Matrix) -> Result<Vec<f64>> {
        if zs.cols() != self.dim {
            return Err(Error::invalid(format!(
                "batcher expects dim {}, got {}",
                self.dim,
                zs.cols()
            )));
        }
        let (tx, rx) = mpsc::channel();
        {
            let (lock, cv) = &*self.shared;
            let mut q = lock.lock().unwrap();
            if q.shutdown {
                return Err(Error::invalid("batcher is shut down"));
            }
            if q.queued_rows + zs.rows() > self.capacity {
                return Err(Error::invalid("scoring queue full (backpressure)"));
            }
            q.queued_rows += zs.rows();
            q.requests.push(Request {
                rows: zs.as_slice().to_vec(),
                n: zs.rows(),
                reply: tx,
            });
            cv.notify_all();
        }
        rx.recv()
            .map_err(|_| Error::invalid("batcher dropped the request"))
    }
}

fn dispatch_loop<F>(
    shared: Arc<(Mutex<Queue>, Condvar)>,
    policy: BatchPolicy,
    dim: usize,
    metrics: Arc<Metrics>,
    score_fn: F,
) where
    F: Fn(&Matrix) -> Result<Vec<f64>>,
{
    let (lock, cv) = &*shared;
    loop {
        // wait until there is work (or shutdown)
        let mut q = lock.lock().unwrap();
        while q.requests.is_empty() && !q.shutdown {
            q = cv.wait(q).unwrap();
        }
        if q.requests.is_empty() && q.shutdown {
            return;
        }
        // linger for more work up to the deadline or the target batch
        let deadline = Instant::now() + policy.linger;
        while q.queued_rows < policy.target_batch && !q.shutdown {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (nq, timeout) = cv.wait_timeout(q, deadline - now).unwrap();
            q = nq;
            if timeout.timed_out() {
                break;
            }
        }
        let batch: Vec<Request> = std::mem::take(&mut q.requests);
        q.queued_rows = 0;
        drop(q);

        // assemble one matrix for the whole batch
        let total: usize = batch.iter().map(|r| r.n).sum();
        let mut flat = Vec::with_capacity(total * dim);
        for r in &batch {
            flat.extend_from_slice(&r.rows);
        }
        let zs = Matrix::from_vec(flat, total, dim).expect("batch assembly");
        let sw = crate::util::timer::Stopwatch::start();
        let scores = score_fn(&zs).unwrap_or_else(|_| vec![f64::NAN; total]);
        metrics.score_latency.observe(sw.elapsed_secs());
        metrics.batches_scored.inc();
        metrics.rows_scored.add(total as u64);

        // fan out
        let mut offset = 0;
        for r in batch {
            let slice = scores[offset..offset + r.n].to_vec();
            offset += r.n;
            let _ = r.reply.send(slice); // receiver may have gone away
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{banana::Banana, Generator};
    use crate::svdd::{train, SvddParams};

    fn model() -> SvddModel {
        let data = Banana::default().generate(500, 1);
        train(&data, &SvddParams::gaussian(0.35, 0.01)).unwrap()
    }

    #[test]
    fn single_request_roundtrip() {
        let m = model();
        let metrics = Arc::new(Metrics::new());
        let m2 = m.clone();
        let (_b, h) = Batcher::spawn(&m, BatchPolicy::default(), metrics.clone(), move |zs| {
            Ok(m2.dist2_batch(zs))
        });
        let zs = Banana::default().generate(17, 2);
        let got = h.score(&zs).unwrap();
        assert_eq!(got, m.dist2_batch(&zs));
        assert_eq!(metrics.rows_scored.get(), 17);
    }

    #[test]
    fn concurrent_requests_coalesce_and_return_correctly() {
        let m = model();
        let metrics = Arc::new(Metrics::new());
        let m2 = m.clone();
        let policy = BatchPolicy {
            target_batch: 64,
            linger: Duration::from_millis(20),
            capacity: 1 << 16,
        };
        let (_b, h) = Batcher::spawn(&m, policy, metrics.clone(), move |zs| {
            Ok(m2.dist2_batch(zs))
        });
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let h = h.clone();
                let m = m.clone();
                std::thread::spawn(move || {
                    let zs = Banana::default().generate(16, 100 + i);
                    let got = h.score(&zs).unwrap();
                    assert_eq!(got, m.dist2_batch(&zs), "thread {i} got wrong rows");
                })
            })
            .collect();
        for t in handles {
            t.join().unwrap();
        }
        // 8 * 16 = 128 rows; with a 64-row target they must have been
        // dispatched in >= 1 but << 8 executions
        assert_eq!(metrics.rows_scored.get(), 128);
        assert!(
            metrics.batches_scored.get() <= 4,
            "coalescing failed: {} batches",
            metrics.batches_scored.get()
        );
    }

    #[test]
    fn dimension_mismatch_rejected() {
        let m = model();
        let metrics = Arc::new(Metrics::new());
        let m2 = m.clone();
        let (_b, h) = Batcher::spawn(&m, BatchPolicy::default(), metrics, move |zs| {
            Ok(m2.dist2_batch(zs))
        });
        let bad = Matrix::zeros(4, 5);
        assert!(h.score(&bad).is_err());
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let m = model();
        let metrics = Arc::new(Metrics::new());
        let m2 = m.clone();
        let policy = BatchPolicy {
            target_batch: 1 << 20,              // never fills
            linger: Duration::from_millis(200), // long linger holds the queue
            capacity: 32,
        };
        let (_b, h) = Batcher::spawn(&m, policy, metrics, move |zs| Ok(m2.dist2_batch(zs)));
        // first request parks in the queue
        let h2 = h.clone();
        let t = std::thread::spawn(move || {
            let zs = Banana::default().generate(30, 3);
            h2.score(&zs).unwrap()
        });
        std::thread::sleep(Duration::from_millis(30));
        // second request overflows the 32-row capacity while the first lingers
        let zs = Banana::default().generate(10, 4);
        assert!(h.score(&zs).is_err(), "backpressure did not trip");
        t.join().unwrap();
    }

    #[test]
    fn shutdown_rejects_new_work() {
        let m = model();
        let metrics = Arc::new(Metrics::new());
        let m2 = m.clone();
        let (mut b, h) = Batcher::spawn(&m, BatchPolicy::default(), metrics, move |zs| {
            Ok(m2.dist2_batch(zs))
        });
        b.shutdown();
        assert!(h.score(&Banana::default().generate(1, 5)).is_err());
    }
}
