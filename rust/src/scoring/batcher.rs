//! Dynamic batching for the serve path, over a hot-swappable model.
//!
//! The XLA scoring artifact runs at fixed bucket shapes (256 / 4096
//! rows); single-observation requests would waste 255/256 of every
//! execution. [`Batcher`] coalesces concurrent score requests into
//! bucket-sized batches: requests enqueue rows and block on a receiver;
//! the dispatch loop drains the queue when either the target batch
//! fills or the linger deadline passes, scores once, and fans results
//! back out. This is the standard dynamic-batching coordinator of
//! serving systems (vLLM-style), applied to SVDD scoring.
//!
//! Coalescing composes with the parallel execution subsystem: the
//! native engine's [`SvddModel::dist2_batch`] scores a drained batch in
//! row chunks on the shared [`crate::parallel`] pool, so one large
//! coalesced batch uses every core while tiny batches stay on the
//! dispatch thread (cost gate) — and either way the scores are
//! bit-identical to the serial path.
//!
//! The active model lives in a [`ModelSlot`] — a swappable slot the
//! model-lifecycle layer replaces on promote (`fastsvdd serve
//! --registry --watch`, `Message::SwapModel`). The dispatch loop takes
//! an `Arc` snapshot of the slot per batch, so a swap never tears a
//! batch: in-flight batches finish on the model they started with, the
//! next drained batch scores on the new one, and no request is ever
//! dropped or errored by a swap.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::metrics::Metrics;
use crate::scoring::ScoreReply;
use crate::svdd::model::SvddModel;
use crate::util::matrix::Matrix;

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Dispatch as soon as this many rows are queued.
    pub target_batch: usize,
    /// Dispatch a partial batch after this long (latency bound). With
    /// [`BatchPolicy::adaptive`] this is the *ceiling* of the window.
    pub linger: Duration,
    /// Queue capacity in rows (backpressure: enqueue errors beyond it).
    pub capacity: usize,
    /// Adapt the linger window to observed concurrency: a batch that
    /// coalesced ≥ 2 requests doubles the window back toward `linger`
    /// (waiting pays off), a solo batch halves it down to a floor of
    /// `linger / 16` (≥ 50µs), so lone-client latency approaches the
    /// raw scoring cost instead of always eating the full linger.
    pub adaptive: bool,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            target_batch: 256,
            linger: Duration::from_millis(2),
            capacity: 1 << 16,
            adaptive: true,
        }
    }
}

/// Next linger window after a batch that coalesced `requests` requests.
fn next_window(window: Duration, requests: usize, policy: &BatchPolicy) -> Duration {
    if !policy.adaptive {
        return policy.linger;
    }
    let floor = (policy.linger / 16)
        .max(Duration::from_micros(50))
        .min(policy.linger);
    if requests >= 2 {
        (window * 2).min(policy.linger)
    } else {
        (window / 2).max(floor)
    }
}

/// The hot-swappable model slot shared by the batcher, the connection
/// handlers and the lifecycle driver. Cloning is cheap (Arc handles);
/// all clones observe the same slot.
///
/// Readers call [`ModelSlot::current`] and get an `Arc` snapshot that
/// stays valid for as long as they hold it — swapping never invalidates
/// a reader mid-batch. The write lock is held only for the pointer
/// replacement, so swap latency is independent of model size.
#[derive(Clone)]
pub struct ModelSlot {
    current: Arc<RwLock<Arc<SvddModel>>>,
    epoch: Arc<AtomicU64>,
    dim: usize,
}

impl ModelSlot {
    pub fn new(model: SvddModel) -> ModelSlot {
        let dim = model.dim();
        ModelSlot {
            current: Arc::new(RwLock::new(Arc::new(model))),
            epoch: Arc::new(AtomicU64::new(0)),
            dim,
        }
    }

    /// Snapshot of the active model.
    pub fn current(&self) -> Arc<SvddModel> {
        self.current.read().expect("model slot poisoned").clone()
    }

    /// Consistent `(model, epoch)` snapshot. [`ModelSlot::swap`] bumps
    /// the epoch while still holding the write lock, so reading both
    /// under the read lock can never pair a new model with the old
    /// epoch (or vice versa).
    pub fn snapshot(&self) -> (Arc<SvddModel>, u64) {
        let guard = self.current.read().expect("model slot poisoned");
        let model = guard.clone();
        let epoch = self.epoch.load(Ordering::Acquire);
        (model, epoch)
    }

    /// Replace the active model; returns the new epoch. The input
    /// dimension is pinned at slot creation — clients hold open
    /// connections that keep sending `dim`-wide rows, so a swap to a
    /// model of another dimension is refused rather than letting every
    /// subsequent request fail.
    pub fn swap(&self, model: SvddModel) -> Result<u64> {
        if model.dim() != self.dim {
            return Err(Error::invalid(format!(
                "hot-swap dimension mismatch: slot serves {}-d rows, new model is {}-d",
                self.dim,
                model.dim()
            )));
        }
        let next = Arc::new(model);
        let mut slot = self.current.write().expect("model slot poisoned");
        *slot = next;
        Ok(self.epoch.fetch_add(1, Ordering::AcqRel) + 1)
    }

    /// Number of swaps applied so far (0 for the spawn-time model).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    pub fn dim(&self) -> usize {
        self.dim
    }
}

struct Request {
    rows: Vec<f64>, // flattened
    n: usize,
    /// Caller-chosen id echoed back with the reply, so many requests
    /// can share one completion channel (the serving edge funnels every
    /// connection's completions into a single non-blocking receiver and
    /// demultiplexes by tag).
    tag: u64,
    /// The full reply is built from one model snapshot, so distances,
    /// R^2, epoch and model id are internally consistent across swaps.
    reply: mpsc::Sender<(u64, ScoreReply)>,
}

struct Queue {
    requests: Vec<Request>,
    queued_rows: usize,
    shutdown: bool,
}

/// A dynamic-batching scoring front end. Clone the handle freely; call
/// [`BatcherHandle::score`] from any thread.
pub struct Batcher {
    shared: Arc<(Mutex<Queue>, Condvar)>,
    worker: Option<std::thread::JoinHandle<()>>,
}

#[derive(Clone)]
pub struct BatcherHandle {
    shared: Arc<(Mutex<Queue>, Condvar)>,
    dim: usize,
    capacity: usize,
    metrics: Arc<Metrics>,
}

impl Batcher {
    /// Spawn the dispatch loop over a scoring closure and a model slot.
    /// The closure receives the model snapshot the batch was pinned to
    /// and a `(rows, dim)` matrix, and returns dist^2 per row; it runs
    /// on the dispatch thread (e.g. wraps `Scorer::xla`).
    pub fn spawn<F>(
        slot: &ModelSlot,
        policy: BatchPolicy,
        metrics: Arc<Metrics>,
        score_fn: F,
    ) -> (Batcher, BatcherHandle)
    where
        F: Fn(&SvddModel, &Matrix) -> Result<Vec<f64>> + Send + 'static,
    {
        let dim = slot.dim();
        let shared = Arc::new((
            Mutex::new(Queue { requests: Vec::new(), queued_rows: 0, shutdown: false }),
            Condvar::new(),
        ));
        let shared2 = shared.clone();
        let slot2 = slot.clone();
        let metrics2 = metrics.clone();
        let worker = std::thread::spawn(move || {
            dispatch_loop(shared2, policy, slot2, metrics2, score_fn);
        });
        let handle = BatcherHandle {
            shared: shared.clone(),
            dim,
            capacity: policy.capacity,
            metrics,
        };
        (Batcher { shared, worker: Some(worker) }, handle)
    }

    /// Stop the dispatch loop after draining the queue.
    pub fn shutdown(&mut self) {
        {
            let (lock, cv) = &*self.shared;
            lock.lock().unwrap().shutdown = true;
            cv.notify_all();
        }
        if let Some(h) = self.worker.take() {
            h.join().ok();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl BatcherHandle {
    /// Input dimension this batcher serves (pinned at slot creation).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Non-blocking enqueue: `(tag, reply)` lands on `reply` once the
    /// dispatch loop scores the batch containing these rows. This is
    /// the edge's entry point — it never blocks the readiness loop.
    ///
    /// Backpressure: beyond `capacity` queued rows the request is shed
    /// with [`Error::Overloaded`] (counted in `shed_requests`) so the
    /// caller can send an explicit overload reply instead of stalling.
    pub(crate) fn submit(
        &self,
        rows: Vec<f64>,
        n: usize,
        tag: u64,
        reply: mpsc::Sender<(u64, ScoreReply)>,
    ) -> Result<()> {
        debug_assert_eq!(rows.len(), n * self.dim);
        let (lock, cv) = &*self.shared;
        let mut q = lock.lock().unwrap();
        if q.shutdown {
            return Err(Error::invalid("batcher is shut down"));
        }
        if q.queued_rows + n > self.capacity {
            self.metrics.shed_requests.inc();
            return Err(Error::Overloaded(format!(
                "scoring queue full: {} rows queued + {n} new > {} capacity",
                q.queued_rows, self.capacity
            )));
        }
        q.queued_rows += n;
        self.metrics.queue_depth.set(q.queued_rows as u64);
        q.requests.push(Request { rows, n, tag, reply });
        cv.notify_all();
        Ok(())
    }

    /// Score a batch of observations; blocks until the dispatch loop
    /// returns this request's scores.
    pub fn score(&self, zs: &Matrix) -> Result<Vec<f64>> {
        self.score_reply(zs).map(|r| r.dist2)
    }

    /// Like [`BatcherHandle::score`], also returning the R^2 threshold
    /// of the model snapshot that scored this batch.
    pub fn score_with_r2(&self, zs: &Matrix) -> Result<(Vec<f64>, f64)> {
        self.score_reply(zs).map(|r| (r.dist2, r.r2))
    }

    /// Blocking scoring with full provenance ([`ScoreReply`]): the
    /// distances plus the R^2 / epoch / content id of the one model
    /// snapshot that produced them.
    pub fn score_reply(&self, zs: &Matrix) -> Result<ScoreReply> {
        if zs.cols() != self.dim {
            return Err(Error::invalid(format!(
                "batcher expects dim {}, got {}",
                self.dim,
                zs.cols()
            )));
        }
        let (tx, rx) = mpsc::channel();
        self.submit(zs.as_slice().to_vec(), zs.rows(), 0, tx)?;
        rx.recv()
            .map(|(_, reply)| reply)
            .map_err(|_| Error::invalid("batcher dropped the request"))
    }
}

impl crate::scoring::ScoreService for BatcherHandle {
    fn score(&self, zs: &Matrix) -> Result<ScoreReply> {
        self.score_reply(zs)
    }
}

fn dispatch_loop<F>(
    shared: Arc<(Mutex<Queue>, Condvar)>,
    policy: BatchPolicy,
    slot: ModelSlot,
    metrics: Arc<Metrics>,
    score_fn: F,
) where
    F: Fn(&SvddModel, &Matrix) -> Result<Vec<f64>>,
{
    let dim = slot.dim();
    let (lock, cv) = &*shared;
    let mut window = policy.linger;
    loop {
        // wait until there is work (or shutdown)
        let mut q = lock.lock().unwrap();
        while q.requests.is_empty() && !q.shutdown {
            q = cv.wait(q).unwrap();
        }
        if q.requests.is_empty() && q.shutdown {
            return;
        }
        // linger for more work up to the deadline or the target batch
        let woke = Instant::now();
        let deadline = woke + window;
        while q.queued_rows < policy.target_batch && !q.shutdown {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (nq, timeout) = cv.wait_timeout(q, deadline - now).unwrap();
            q = nq;
            if timeout.timed_out() {
                break;
            }
        }
        let batch: Vec<Request> = std::mem::take(&mut q.requests);
        q.queued_rows = 0;
        metrics.queue_depth.set(0);
        drop(q);
        metrics.window_wait.observe(woke.elapsed().as_secs_f64());
        window = next_window(window, batch.len(), &policy);

        // pin the model for this whole batch: a swap landing mid-score
        // takes effect from the *next* drained batch
        let (model, epoch) = slot.snapshot();

        // assemble one matrix for the whole batch
        let total: usize = batch.iter().map(|r| r.n).sum();
        metrics.batch_fill.observe_raw(total as u64);
        let mut flat = Vec::with_capacity(total * dim);
        for r in &batch {
            flat.extend_from_slice(&r.rows);
        }
        let zs = Matrix::from_vec(flat, total, dim).expect("batch assembly");
        let mut span = crate::obs::Span::enter("batcher.batch");
        if span.is_live() {
            span.u64("rows", total as u64);
            span.u64("requests", batch.len() as u64);
        }
        let sw = crate::util::timer::Stopwatch::start();
        let scores = score_fn(&model, &zs).unwrap_or_else(|_| vec![f64::NAN; total]);
        drop(span);
        metrics.score_latency.observe(sw.elapsed_secs());
        metrics.batches_scored.inc();
        metrics.rows_scored.add(total as u64);

        // fan out, with the provenance of the one snapshot that scored
        let r2 = model.r2();
        let model_id = model.content_id();
        let mut offset = 0;
        for r in batch {
            let slice = scores[offset..offset + r.n].to_vec();
            offset += r.n;
            let reply = ScoreReply {
                dist2: slice,
                r2,
                epoch,
                model_id: model_id.clone(),
            };
            let _ = r.reply.send((r.tag, reply)); // receiver may have gone away
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{banana::Banana, Generator};
    use crate::svdd::{train, SvddParams};

    fn model() -> SvddModel {
        let data = Banana::default().generate(500, 1);
        train(&data, &SvddParams::gaussian(0.35, 0.01)).unwrap()
    }

    fn shifted_model() -> SvddModel {
        let mut data = Banana::default().generate(500, 2);
        for i in 0..data.rows() {
            data.row_mut(i)[0] += 6.0;
        }
        train(&data, &SvddParams::gaussian(0.35, 0.01)).unwrap()
    }

    fn spawn_native(
        slot: &ModelSlot,
        policy: BatchPolicy,
        metrics: Arc<Metrics>,
    ) -> (Batcher, BatcherHandle) {
        Batcher::spawn(slot, policy, metrics, |m, zs| Ok(m.dist2_batch(zs)))
    }

    #[test]
    fn single_request_roundtrip() {
        let m = model();
        let metrics = Arc::new(Metrics::new());
        let slot = ModelSlot::new(m.clone());
        let (_b, h) = spawn_native(&slot, BatchPolicy::default(), metrics.clone());
        let zs = Banana::default().generate(17, 2);
        let (got, r2) = h.score_with_r2(&zs).unwrap();
        assert_eq!(got, m.dist2_batch(&zs));
        assert_eq!(r2, m.r2());
        assert_eq!(metrics.rows_scored.get(), 17);
    }

    #[test]
    fn concurrent_requests_coalesce_and_return_correctly() {
        let m = model();
        let metrics = Arc::new(Metrics::new());
        let policy = BatchPolicy {
            target_batch: 64,
            linger: Duration::from_millis(20),
            capacity: 1 << 16,
            adaptive: false, // timing-sensitive: keep the window fixed
        };
        let slot = ModelSlot::new(m.clone());
        let (_b, h) = spawn_native(&slot, policy, metrics.clone());
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let h = h.clone();
                let m = m.clone();
                std::thread::spawn(move || {
                    let zs = Banana::default().generate(16, 100 + i);
                    let got = h.score(&zs).unwrap();
                    assert_eq!(got, m.dist2_batch(&zs), "thread {i} got wrong rows");
                })
            })
            .collect();
        for t in handles {
            t.join().unwrap();
        }
        // 8 * 16 = 128 rows; with a 64-row target they must have been
        // dispatched in >= 1 but << 8 executions
        assert_eq!(metrics.rows_scored.get(), 128);
        assert!(
            metrics.batches_scored.get() <= 4,
            "coalescing failed: {} batches",
            metrics.batches_scored.get()
        );
    }

    #[test]
    fn dimension_mismatch_rejected() {
        let m = model();
        let metrics = Arc::new(Metrics::new());
        let slot = ModelSlot::new(m);
        let (_b, h) = spawn_native(&slot, BatchPolicy::default(), metrics);
        let bad = Matrix::zeros(4, 5);
        assert!(h.score(&bad).is_err());
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let m = model();
        let metrics = Arc::new(Metrics::new());
        let policy = BatchPolicy {
            target_batch: 1 << 20,              // never fills
            linger: Duration::from_millis(200), // long linger holds the queue
            capacity: 32,
            adaptive: false, // timing-sensitive: keep the window fixed
        };
        let slot = ModelSlot::new(m);
        let (_b, h) = spawn_native(&slot, policy, metrics.clone());
        // first request parks in the queue
        let h2 = h.clone();
        let t = std::thread::spawn(move || {
            let zs = Banana::default().generate(30, 3);
            h2.score(&zs).unwrap()
        });
        std::thread::sleep(Duration::from_millis(30));
        // second request overflows the 32-row capacity while the first lingers
        let zs = Banana::default().generate(10, 4);
        let err = h.score(&zs).unwrap_err();
        assert!(
            matches!(err, Error::Overloaded(_)),
            "backpressure must shed with Overloaded, got: {err}"
        );
        assert_eq!(metrics.shed_requests.get(), 1);
        t.join().unwrap();
    }

    #[test]
    fn shutdown_rejects_new_work() {
        let m = model();
        let metrics = Arc::new(Metrics::new());
        let slot = ModelSlot::new(m);
        let (mut b, h) = spawn_native(&slot, BatchPolicy::default(), metrics);
        b.shutdown();
        assert!(h.score(&Banana::default().generate(1, 5)).is_err());
    }

    #[test]
    fn slot_swap_bumps_epoch_and_changes_scores() {
        let m1 = model();
        let m2 = shifted_model();
        let metrics = Arc::new(Metrics::new());
        let slot = ModelSlot::new(m1.clone());
        assert_eq!(slot.epoch(), 0);
        let (_b, h) = spawn_native(&slot, BatchPolicy::default(), metrics);
        let zs = Banana::default().generate(9, 6);
        let (before, r2_before) = h.score_with_r2(&zs).unwrap();
        assert_eq!(before, m1.dist2_batch(&zs));
        assert_eq!(r2_before, m1.r2());

        assert_eq!(slot.swap(m2.clone()).unwrap(), 1);
        assert_eq!(slot.epoch(), 1);
        let (after, r2_after) = h.score_with_r2(&zs).unwrap();
        assert_eq!(after, m2.dist2_batch(&zs));
        assert_eq!(r2_after, m2.r2());
    }

    #[test]
    fn slot_swap_rejects_dimension_change() {
        let m = model(); // 2-d
        let slot = ModelSlot::new(m);
        let sv = Matrix::from_rows(&[vec![0.0, 1.0, 2.0]]).unwrap();
        let odd = SvddModel::new(sv, vec![1.0], crate::svdd::Kernel::gaussian(1.0), 0.5, 1.0)
            .unwrap();
        assert!(slot.swap(odd).is_err());
        assert_eq!(slot.epoch(), 0, "failed swap must not bump the epoch");
    }

    #[test]
    fn replies_are_model_consistent_under_swap_storm() {
        // Clients hammer the batcher while the slot flips between two
        // models; every reply must be *exactly* one model's scores with
        // that same model's R^2 — never a torn mix.
        let m1 = model();
        let m2 = shifted_model();
        let metrics = Arc::new(Metrics::new());
        let policy = BatchPolicy {
            target_batch: 32,
            linger: Duration::from_micros(200),
            ..BatchPolicy::default()
        };
        let slot = ModelSlot::new(m1.clone());
        let (_b, h) = spawn_native(&slot, policy, metrics);

        let zs = Banana::default().generate(8, 7);
        let want1 = (m1.dist2_batch(&zs), m1.r2());
        let want2 = (m2.dist2_batch(&zs), m2.r2());

        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let clients: Vec<_> = (0..4)
            .map(|_| {
                let h = h.clone();
                let zs = zs.clone();
                let stop = stop.clone();
                let want1 = want1.clone();
                let want2 = want2.clone();
                std::thread::spawn(move || {
                    let mut checked = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        let got = h.score_with_r2(&zs).unwrap();
                        assert!(
                            got == want1 || got == want2,
                            "torn reply: r2={} (v1 r2={}, v2 r2={})",
                            got.1,
                            want1.1,
                            want2.1
                        );
                        checked += 1;
                    }
                    checked
                })
            })
            .collect();

        for i in 0..50 {
            let next = if i % 2 == 0 { m2.clone() } else { m1.clone() };
            slot.swap(next).unwrap();
            std::thread::sleep(Duration::from_micros(500));
        }
        stop.store(true, Ordering::Relaxed);
        let total: u64 = clients.into_iter().map(|t| t.join().unwrap()).sum();
        assert!(total > 0, "clients never scored");
        assert_eq!(slot.epoch(), 50);
    }

    #[test]
    fn next_window_adapts_between_floor_and_linger() {
        let policy = BatchPolicy {
            linger: Duration::from_millis(2),
            ..BatchPolicy::default()
        };
        let floor = Duration::from_micros(125); // 2ms / 16
        // solo batches halve down to the floor, never below
        let mut w = policy.linger;
        for _ in 0..10 {
            w = next_window(w, 1, &policy);
        }
        assert_eq!(w, floor);
        // a coalesced batch doubles back up, capped at linger
        w = next_window(w, 2, &policy);
        assert_eq!(w, floor * 2);
        for _ in 0..10 {
            w = next_window(w, 5, &policy);
        }
        assert_eq!(w, policy.linger);
        // tiny linger: the 50µs floor is clamped to linger itself
        let tiny = BatchPolicy {
            linger: Duration::from_micros(20),
            ..BatchPolicy::default()
        };
        assert_eq!(next_window(tiny.linger, 1, &tiny), tiny.linger);
        // adaptive off: window is always the configured linger
        let fixed = BatchPolicy { adaptive: false, ..BatchPolicy::default() };
        assert_eq!(next_window(Duration::from_micros(1), 1, &fixed), fixed.linger);
        assert_eq!(next_window(Duration::from_secs(9), 7, &fixed), fixed.linger);
    }

    #[test]
    fn adaptive_window_shrinks_solo_latency() {
        // A lone client pays the full linger on its first request; the
        // window then halves per solo batch, so a short train of
        // sequential requests finishes well under requests × linger.
        let m = model();
        let metrics = Arc::new(Metrics::new());
        let policy = BatchPolicy {
            target_batch: 1 << 20, // never fills: every batch is linger-bound
            linger: Duration::from_millis(60),
            capacity: 1 << 16,
            adaptive: true,
        };
        let slot = ModelSlot::new(m);
        let (_b, h) = spawn_native(&slot, policy, metrics);
        let zs = Banana::default().generate(4, 8);
        let sw = Instant::now();
        for _ in 0..6 {
            h.score(&zs).unwrap();
        }
        let elapsed = sw.elapsed();
        // fixed window would take ≥ 6 × 60ms = 360ms; adaptive decay
        // (60 + 30 + 15 + 7.5 + ...) stays near 2 × linger
        assert!(
            elapsed < Duration::from_millis(300),
            "adaptive window did not shrink: 6 solo requests took {elapsed:?}"
        );
    }

    #[test]
    fn score_reply_carries_swap_provenance() {
        let m1 = model();
        let m2 = shifted_model();
        let metrics = Arc::new(Metrics::new());
        let slot = ModelSlot::new(m1.clone());
        let (_b, h) = spawn_native(&slot, BatchPolicy::default(), metrics.clone());
        let zs = Banana::default().generate(5, 9);

        let before = h.score_reply(&zs).unwrap();
        assert_eq!(before.dist2, m1.dist2_batch(&zs));
        assert_eq!(before.r2, m1.r2());
        assert_eq!(before.epoch, 0);
        assert_eq!(before.model_id, m1.content_id());

        slot.swap(m2.clone()).unwrap();
        let after = h.score_reply(&zs).unwrap();
        assert_eq!(after.dist2, m2.dist2_batch(&zs));
        assert_eq!(after.r2, m2.r2());
        assert_eq!(after.epoch, 1);
        assert_eq!(after.model_id, m2.content_id());

        // the new serving metrics observed both batches
        assert_eq!(metrics.batch_fill.sum_raw(), 10);
        assert_eq!(metrics.window_wait.count(), 2);
        assert_eq!(metrics.queue_depth.get(), 0);
    }

    #[test]
    fn snapshot_is_consistent_with_swap() {
        let m1 = model();
        let m2 = shifted_model();
        let slot = ModelSlot::new(m1.clone());
        let (model, epoch) = slot.snapshot();
        assert_eq!(epoch, 0);
        assert_eq!(model.content_id(), m1.content_id());
        slot.swap(m2.clone()).unwrap();
        let (model, epoch) = slot.snapshot();
        assert_eq!(epoch, 1);
        assert_eq!(model.content_id(), m2.content_id());
    }

    #[test]
    fn tagged_submit_demultiplexes_on_one_channel() {
        // Edge-style use: several requests share one completion channel
        // and are told apart by tag.
        let m = model();
        let metrics = Arc::new(Metrics::new());
        let slot = ModelSlot::new(m.clone());
        let (_b, h) = spawn_native(&slot, BatchPolicy::default(), metrics);
        let (tx, rx) = mpsc::channel();
        let z1 = Banana::default().generate(3, 10);
        let z2 = Banana::default().generate(2, 11);
        h.submit(z1.as_slice().to_vec(), 3, 101, tx.clone()).unwrap();
        h.submit(z2.as_slice().to_vec(), 2, 202, tx).unwrap();
        let mut got: Vec<(u64, ScoreReply)> = vec![rx.recv().unwrap(), rx.recv().unwrap()];
        got.sort_by_key(|(tag, _)| *tag);
        assert_eq!(got[0].0, 101);
        assert_eq!(got[0].1.dist2, m.dist2_batch(&z1));
        assert_eq!(got[1].0, 202);
        assert_eq!(got[1].1.dist2, m.dist2_batch(&z2));
    }
}
