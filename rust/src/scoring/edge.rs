//! The async serving edge: one thread, many connections.
//!
//! The threaded [`super::server`] path spends a thread per connection;
//! at high fan-in (the paper's big-data process-monitoring setting)
//! thousands of mostly-idle threads cost stacks, context switches and
//! scheduler pressure. The edge replaces them with a single
//! **readiness loop** over non-blocking sockets — a dependency-free
//! `poll`-style multiplexer: every tick it accepts new connections,
//! drains scoring completions, and advances each connection's
//! read → parse → reply state machine, sleeping briefly only when a
//! whole tick made no progress.
//!
//! Scoring itself never happens on the loop thread. Requests are handed
//! to the shared [`Batcher`] via the non-blocking
//! [`BatcherHandle::submit`], tagged with the connection id; the
//! dispatch thread coalesces rows from *all* connections into one
//! `dist2_batch` panel call per adaptive linger window and sends
//! completions back over a channel. Replies are therefore naturally
//! micro-batched: more concurrent clients → bigger panels → better
//! throughput, exactly the fan-in curve `benches/perf_serving.rs`
//! measures against the thread-per-connection baseline.
//!
//! Backpressure never stalls the accept loop. Three bounded stages shed
//! explicitly instead:
//! - connection cap (`max_conns`): excess connections get a best-effort
//!   HTTP 503 and are closed (counted in `edge_conns_rejected`);
//! - edge in-flight cap (`max_inflight_rows`): rows submitted but not
//!   yet replied;
//! - batcher queue cap (`BatchPolicy::capacity`): rows queued for the
//!   next window.
//!
//! The last two shed per-request with an explicit overload reply — HTTP
//! 503, or the v3 [`Message::Overloaded`] frame; sessions negotiated
//! below v3 cannot decode that frame, so they are closed instead —
//! and count into `shed_requests`.
//!
//! Hot-swap semantics are inherited unchanged from the batcher: every
//! micro-batch pins one `(model, epoch)` snapshot, so in-flight batches
//! finish on the model they started with and each reply carries the
//! epoch/content-id that actually scored it.

use std::collections::{HashMap, VecDeque};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use crate::distributed::message::{negotiate, Message, MAX_FRAME};
use crate::error::Error;
use crate::metrics::Metrics;
use crate::scoring::batcher::{BatcherHandle, ModelSlot};
use crate::scoring::http::{self, HttpParse, HttpRequest};
use crate::scoring::server::looks_like_http;
use crate::scoring::ScoreReply;
use crate::svdd::model::SvddModel;
use crate::util::json::{self, Json};
use crate::util::matrix::Matrix;

/// Edge tunables (the serve-path knobs `--max-conns`, `--max-inflight`
/// and `--http` map onto).
#[derive(Clone, Copy, Debug)]
pub struct EdgeConfig {
    /// Serve the `POST /score` JSON ingress. `GET /metrics` and
    /// `GET /model` are always on (Prometheus scrape parity with the
    /// threaded listener).
    pub http_ingress: bool,
    /// Maximum simultaneously open connections; beyond it, new
    /// connections get a best-effort 503 and are closed immediately.
    pub max_conns: usize,
    /// Maximum rows submitted to the batcher and not yet replied to;
    /// beyond it, score requests are shed with an overload reply.
    pub max_inflight_rows: usize,
}

impl Default for EdgeConfig {
    fn default() -> Self {
        EdgeConfig {
            http_ingress: true,
            max_conns: 1024,
            max_inflight_rows: 1 << 16,
        }
    }
}

/// Everything a connection needs from the edge to process a request.
struct Ctx<'a> {
    handle: &'a BatcherHandle,
    slot: &'a ModelSlot,
    metrics: &'a Metrics,
    remote_swap: &'a AtomicBool,
    cfg: &'a EdgeConfig,
    done_tx: &'a mpsc::Sender<(u64, ScoreReply)>,
    /// Rows submitted to the batcher whose completions have not been
    /// drained yet, across all connections.
    inflight_rows: &'a mut usize,
}

/// The readiness loop. Runs on one thread until `stop` is set; the
/// listener must already be non-blocking.
pub(crate) fn run_edge_loop(
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    handle: BatcherHandle,
    slot: ModelSlot,
    metrics: Arc<Metrics>,
    remote_swap: Arc<AtomicBool>,
    cfg: EdgeConfig,
) {
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_id: u64 = 0;
    let mut inflight_rows: usize = 0;
    let (done_tx, done_rx) = mpsc::channel::<(u64, ScoreReply)>();
    while !stop.load(Ordering::Relaxed) {
        let mut progressed = false;

        // 1. accept everything pending; never block, never stall
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    progressed = true;
                    if conns.len() >= cfg.max_conns {
                        metrics.edge_conns_rejected.inc();
                        shed_connection(stream);
                        continue;
                    }
                    metrics.edge_conns_opened.inc();
                    stream.set_nonblocking(true).ok();
                    stream.set_nodelay(true).ok();
                    next_id += 1;
                    conns.insert(next_id, Conn::new(next_id, stream));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(_) => return, // listener died
            }
        }

        // 2. drain scoring completions into their connections' queues
        while let Ok((id, reply)) = done_rx.try_recv() {
            progressed = true;
            inflight_rows = inflight_rows.saturating_sub(reply.dist2.len());
            if let Some(conn) = conns.get_mut(&id) {
                conn.complete(reply);
            } // else: connection died while its batch was in flight
        }

        // 3. advance every connection's state machine
        let ids: Vec<u64> = conns.keys().copied().collect();
        for id in ids {
            let mut ctx = Ctx {
                handle: &handle,
                slot: &slot,
                metrics: &metrics,
                remote_swap: &remote_swap,
                cfg: &cfg,
                done_tx: &done_tx,
                inflight_rows: &mut inflight_rows,
            };
            let conn = conns.get_mut(&id).expect("conn id from keys");
            let dead = match conn.tick(&mut ctx) {
                Ok(ticked) => {
                    progressed |= ticked;
                    conn.finished()
                }
                Err(()) => true,
            };
            if dead {
                conns.remove(&id);
            }
        }

        if !progressed {
            std::thread::sleep(Duration::from_micros(500));
        }
    }
}

/// Over the connection cap: tell the peer why, best-effort, and close.
/// An HTTP client sees a proper 503; a native client fails its
/// handshake with "frame too large" (the status line read as a length
/// prefix) — either way an immediate, explicit error instead of a hang.
fn shed_connection(stream: TcpStream) {
    use std::io::Write;
    let mut stream = stream;
    stream.set_nonblocking(true).ok();
    let resp = http::json_error(
        "503 Service Unavailable",
        "overloaded",
        "connection limit reached; retry later",
        false,
    );
    let _ = stream.write_all(&resp);
}

/// Serialize a length-prefixed frame (the buffer form of
/// [`Message::write_to`], for non-blocking writes).
fn frame_bytes(msg: &Message) -> Vec<u8> {
    let body = msg.encode();
    let mut out = Vec::with_capacity(4 + body.len());
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(&body);
    out
}

/// Pop one complete frame off the front of `rbuf`, if buffered.
/// `Err` means the stream is unrecoverable (oversized or undecodable
/// frame) and the connection must be dropped.
fn take_frame(rbuf: &mut Vec<u8>) -> std::result::Result<Option<Message>, ()> {
    if rbuf.len() < 4 {
        return Ok(None);
    }
    let len = u32::from_le_bytes([rbuf[0], rbuf[1], rbuf[2], rbuf[3]]) as usize;
    if len > MAX_FRAME {
        return Err(());
    }
    if rbuf.len() < 4 + len {
        return Ok(None);
    }
    let msg = Message::decode(&rbuf[4..4 + len]).map_err(|_| ())?;
    rbuf.drain(..4 + len);
    Ok(Some(msg))
}

/// What protocol a connection turned out to speak.
enum Proto {
    /// Fewer than 4 bytes seen — protocol unknown.
    Sniff,
    /// HTTP/1.1 session (keep-alive honored).
    Http,
    /// Native framing, Hello not yet received.
    NativeHello,
    /// Native framing, handshake done at this session version.
    Native { version: u32 },
}

/// How to serialize a batcher completion for this request.
#[derive(Clone, Copy)]
enum ReplyKind {
    /// v1 `ScoreReply { dist2, r2 }` frame.
    NativeV1,
    /// v3 `ScoreReplyV2` frame with full provenance.
    NativeV2,
    /// HTTP 200 with the JSON reply body.
    HttpScore,
}

/// One slot in a connection's FIFO reply queue. Completions arrive in
/// submit order (single dispatch thread), so each fills the earliest
/// `Awaiting` slot; `Ready` slots flush strictly in order, preserving
/// per-connection reply ordering under pipelining.
enum Pending {
    Ready { bytes: Vec<u8>, close_after: bool },
    Awaiting { kind: ReplyKind, close_after: bool },
}

struct Conn {
    id: u64,
    stream: TcpStream,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    wpos: usize,
    proto: Proto,
    pending: VecDeque<Pending>,
    /// Peer closed its write side; serve out pending replies, then close.
    peer_eof: bool,
    /// Stop reading/parsing; close once pending replies are flushed.
    closing: bool,
}

impl Conn {
    fn new(id: u64, stream: TcpStream) -> Conn {
        Conn {
            id,
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            proto: Proto::Sniff,
            pending: VecDeque::new(),
            peer_eof: false,
            closing: false,
        }
    }

    /// One scheduling quantum: read what's there, advance the protocol,
    /// stage and flush replies. `Ok(true)` if anything moved; `Err` if
    /// the connection must be dropped immediately.
    fn tick(&mut self, ctx: &mut Ctx) -> std::result::Result<bool, ()> {
        let mut progressed = false;
        if !self.closing && !self.peer_eof {
            progressed |= self.read_some()?;
        }
        if !self.closing {
            progressed |= self.advance(ctx)?;
        }
        progressed |= self.fill_wbuf();
        progressed |= self.flush()?;
        Ok(progressed)
    }

    /// Nothing left to do: every reply flushed and no more input coming.
    fn finished(&self) -> bool {
        (self.closing || self.peer_eof)
            && self.pending.is_empty()
            && self.wpos == self.wbuf.len()
    }

    /// One bounded read (≤ 16 KiB per tick per connection, so a single
    /// fast writer cannot monopolize the loop).
    fn read_some(&mut self) -> std::result::Result<bool, ()> {
        use std::io::Read;
        let mut tmp = [0u8; 16384];
        loop {
            match self.stream.read(&mut tmp) {
                Ok(0) => {
                    self.peer_eof = true;
                    return Ok(true);
                }
                Ok(n) => {
                    self.rbuf.extend_from_slice(&tmp[..n]);
                    return Ok(true);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(false),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return Err(()),
            }
        }
    }

    /// Parse and dispatch everything buffered so far.
    fn advance(&mut self, ctx: &mut Ctx) -> std::result::Result<bool, ()> {
        let mut progressed = false;
        loop {
            if self.closing {
                return Ok(progressed);
            }
            match self.proto {
                Proto::Sniff => {
                    if self.rbuf.len() < 4 {
                        return Ok(progressed);
                    }
                    let first = [self.rbuf[0], self.rbuf[1], self.rbuf[2], self.rbuf[3]];
                    self.proto = if looks_like_http(&first) {
                        Proto::Http
                    } else {
                        Proto::NativeHello
                    };
                    progressed = true;
                }
                Proto::NativeHello => match take_frame(&mut self.rbuf)? {
                    None => return Ok(progressed),
                    Some(Message::Hello { version }) => match negotiate(version) {
                        Some(v) => {
                            self.push_ready(
                                frame_bytes(&Message::HelloAck { version: v }),
                                false,
                            );
                            self.proto = Proto::Native { version: v };
                            progressed = true;
                        }
                        None => return Err(()),
                    },
                    Some(_) => return Err(()),
                },
                Proto::Native { version } => match take_frame(&mut self.rbuf)? {
                    None => return Ok(progressed),
                    Some(msg) => {
                        progressed = true;
                        self.handle_native(msg, version, ctx)?;
                    }
                },
                Proto::Http => match http::parse_request(&self.rbuf) {
                    HttpParse::Incomplete => return Ok(progressed),
                    HttpParse::Ready { req, consumed } => {
                        self.rbuf.drain(..consumed);
                        progressed = true;
                        self.handle_http(req, ctx);
                    }
                    HttpParse::Bad(detail) => {
                        self.push_ready(
                            http::json_error("400 Bad Request", "bad_request", detail, false),
                            true,
                        );
                        return Ok(true);
                    }
                    HttpParse::TooLarge => {
                        self.push_ready(
                            http::json_error(
                                "413 Payload Too Large",
                                "too_large",
                                "request exceeds size limits",
                                false,
                            ),
                            true,
                        );
                        return Ok(true);
                    }
                },
            }
        }
    }

    /// Queue an already-serialized response in FIFO order.
    /// `close_after` marks it as the connection's last response; the
    /// connection stops reading now and closes once it flushes.
    fn push_ready(&mut self, bytes: Vec<u8>, close_after: bool) {
        self.pending.push_back(Pending::Ready { bytes, close_after });
        if close_after {
            self.closing = true;
        }
    }

    /// Fill the earliest awaiting reply slot with a completed score.
    fn complete(&mut self, reply: ScoreReply) {
        for p in self.pending.iter_mut() {
            let (kind, close_after) = match *p {
                Pending::Awaiting { kind, close_after } => (kind, close_after),
                Pending::Ready { .. } => continue,
            };
            let bytes = match kind {
                ReplyKind::NativeV1 => frame_bytes(&Message::ScoreReply {
                    dist2: reply.dist2,
                    r2: reply.r2,
                }),
                ReplyKind::NativeV2 => frame_bytes(&Message::ScoreReplyV2 {
                    dist2: reply.dist2,
                    r2: reply.r2,
                    epoch: reply.epoch,
                    model_id: reply.model_id,
                }),
                ReplyKind::HttpScore => http::response(
                    "200 OK",
                    "application/json",
                    &http::score_reply_json(&reply),
                    !close_after,
                ),
            };
            *p = Pending::Ready { bytes, close_after };
            return;
        }
        // no awaiting slot: the connection errored after submitting —
        // the reply has nowhere to go (rows were already accounted)
    }

    /// Move consecutive ready replies into the write buffer.
    fn fill_wbuf(&mut self) -> bool {
        let mut progressed = false;
        while let Some(Pending::Ready { .. }) = self.pending.front() {
            if let Some(Pending::Ready { bytes, close_after }) = self.pending.pop_front() {
                self.wbuf.extend_from_slice(&bytes);
                progressed = true;
                if close_after {
                    // last response: drop anything queued behind it
                    self.pending.clear();
                    self.closing = true;
                    break;
                }
            }
        }
        progressed
    }

    /// Write as much of the buffer as the socket accepts.
    fn flush(&mut self) -> std::result::Result<bool, ()> {
        use std::io::Write;
        let mut progressed = false;
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => return Err(()),
                Ok(n) => {
                    self.wpos += n;
                    progressed = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return Err(()),
            }
        }
        if self.wpos == self.wbuf.len() && self.wpos > 0 {
            self.wbuf.clear();
            self.wpos = 0;
        }
        Ok(progressed)
    }

    // ------------------------------------------------- native protocol

    fn handle_native(
        &mut self,
        msg: Message,
        version: u32,
        ctx: &mut Ctx,
    ) -> std::result::Result<(), ()> {
        // never answer with (or act on) frames beyond the negotiated
        // vocabulary — drop the connection like the threaded server
        if msg.min_version() > version {
            return Err(());
        }
        let mut span = crate::obs::Span::enter("server.request");
        if span.is_live() {
            span.str(
                "kind",
                match &msg {
                    Message::ScoreRequest { .. } | Message::ScoreRequestV2 { .. } => "score",
                    Message::ModelInfoRequest => "info",
                    Message::SwapModel { .. } => "swap",
                    Message::StatsRequest => "stats",
                    _ => "other",
                },
            );
        }
        match msg {
            Message::ScoreRequest { rows } => {
                self.submit_score(rows, ReplyKind::NativeV1, version, ctx)
            }
            Message::ScoreRequestV2 { rows } => {
                self.submit_score(rows, ReplyKind::NativeV2, version, ctx)
            }
            Message::ModelInfoRequest => {
                let (m, epoch) = ctx.slot.snapshot();
                self.push_ready(
                    frame_bytes(&Message::ModelInfo {
                        version: m.content_id(),
                        r2: m.r2(),
                        num_sv: m.num_sv() as u32,
                        dim: m.dim() as u32,
                        epoch,
                    }),
                    false,
                );
                Ok(())
            }
            Message::SwapModel { model_json } => {
                let reply = if !ctx.remote_swap.load(Ordering::Relaxed) {
                    Message::SwapAck {
                        epoch: ctx.slot.epoch(),
                        swapped: false,
                        r2: ctx.slot.current().r2(),
                        reason: "remote model swap is disabled on this server".into(),
                    }
                } else {
                    let outcome = Json::parse(&model_json)
                        .and_then(|j| SvddModel::from_json(&j))
                        .and_then(|m| ctx.slot.swap(m));
                    match outcome {
                        Ok(epoch) => {
                            ctx.metrics.model_swaps.inc();
                            Message::SwapAck {
                                epoch,
                                swapped: true,
                                r2: ctx.slot.current().r2(),
                                reason: String::new(),
                            }
                        }
                        Err(e) => Message::SwapAck {
                            epoch: ctx.slot.epoch(),
                            swapped: false,
                            r2: ctx.slot.current().r2(),
                            reason: e.to_string(),
                        },
                    }
                };
                self.push_ready(frame_bytes(&reply), false);
                Ok(())
            }
            Message::StatsRequest => {
                self.push_ready(
                    frame_bytes(&Message::StatsReply {
                        text: ctx.metrics.render_prometheus(),
                        counters: ctx.metrics.snapshot(),
                    }),
                    false,
                );
                Ok(())
            }
            Message::Shutdown => {
                self.closing = true;
                Ok(())
            }
            _ => Err(()),
        }
    }

    /// Hand a native score request to the batcher, or shed it.
    fn submit_score(
        &mut self,
        rows: Matrix,
        kind: ReplyKind,
        version: u32,
        ctx: &mut Ctx,
    ) -> std::result::Result<(), ()> {
        if rows.cols() != ctx.handle.dim() {
            return Err(()); // protocol error: drop (threaded-server parity)
        }
        let n = rows.rows();
        if *ctx.inflight_rows + n > ctx.cfg.max_inflight_rows {
            ctx.metrics.shed_requests.inc();
            return self.shed_native(version, "serving edge at max in-flight rows");
        }
        match ctx
            .handle
            .submit(rows.as_slice().to_vec(), n, self.id, ctx.done_tx.clone())
        {
            Ok(()) => {
                *ctx.inflight_rows += n;
                self.pending.push_back(Pending::Awaiting { kind, close_after: false });
                Ok(())
            }
            // the batcher queue already counted the shed
            Err(Error::Overloaded(reason)) => self.shed_native(version, &reason),
            Err(_) => Err(()),
        }
    }

    /// Shed with an explicit overload reply where the protocol allows:
    /// v3 sessions get the `Overloaded` frame; older sessions cannot
    /// decode it, so their connection is closed instead.
    fn shed_native(&mut self, version: u32, reason: &str) -> std::result::Result<(), ()> {
        if version >= 3 {
            self.push_ready(
                frame_bytes(&Message::Overloaded { reason: reason.to_string() }),
                false,
            );
            Ok(())
        } else {
            Err(())
        }
    }

    // --------------------------------------------------- http protocol

    fn handle_http(&mut self, req: HttpRequest, ctx: &mut Ctx) {
        ctx.metrics.edge_http_requests.inc();
        let mut span = crate::obs::Span::enter("server.request");
        if span.is_live() {
            span.str("kind", "http");
            span.str("path", req.path.clone());
        }
        let keep = req.keep_alive;
        let HttpRequest { method, path, body, .. } = req;
        match (method.as_str(), path.as_str()) {
            ("GET", "/metrics") => self.push_http(
                http::response(
                    "200 OK",
                    "text/plain; version=0.0.4; charset=utf-8",
                    &ctx.metrics.render_prometheus(),
                    keep,
                ),
                keep,
            ),
            ("GET", "/model") => {
                let (m, epoch) = ctx.slot.snapshot();
                let body = json::obj(vec![
                    ("model", json::s(m.content_id())),
                    ("r2", json::num(m.r2())),
                    ("num_sv", json::num(m.num_sv() as f64)),
                    ("dim", json::num(m.dim() as f64)),
                    ("epoch", json::num(epoch as f64)),
                ])
                .to_string();
                self.push_http(http::response("200 OK", "application/json", &body, keep), keep)
            }
            ("POST", "/score") if ctx.cfg.http_ingress => self.submit_http_score(body, keep, ctx),
            ("POST", "/score") => self.push_http(
                http::json_error(
                    "404 Not Found",
                    "http_scoring_disabled",
                    "start the server with --http to enable the JSON scoring ingress",
                    keep,
                ),
                keep,
            ),
            ("GET", _) | ("HEAD", _) => self.push_http(
                http::json_error("404 Not Found", "not_found", "unknown path", keep),
                keep,
            ),
            _ => self.push_http(
                http::json_error(
                    "405 Method Not Allowed",
                    "method_not_allowed",
                    "supported: GET /metrics, GET /model, POST /score",
                    keep,
                ),
                keep,
            ),
        }
    }

    fn push_http(&mut self, bytes: Vec<u8>, keep_alive: bool) {
        self.push_ready(bytes, !keep_alive);
    }

    /// Hand an HTTP score request to the batcher, or shed it with 503.
    fn submit_http_score(&mut self, body: Vec<u8>, keep: bool, ctx: &mut Ctx) {
        let rows = match http::parse_score_body(&body, ctx.handle.dim()) {
            Ok(m) => m,
            Err(detail) => {
                return self.push_http(
                    http::json_error("400 Bad Request", "bad_request", &detail, keep),
                    keep,
                );
            }
        };
        let n = rows.rows();
        if *ctx.inflight_rows + n > ctx.cfg.max_inflight_rows {
            ctx.metrics.shed_requests.inc();
            return self.push_http(
                http::json_error(
                    "503 Service Unavailable",
                    "overloaded",
                    "serving edge at max in-flight rows; retry later",
                    keep,
                ),
                keep,
            );
        }
        match ctx
            .handle
            .submit(rows.as_slice().to_vec(), n, self.id, ctx.done_tx.clone())
        {
            Ok(()) => {
                *ctx.inflight_rows += n;
                self.pending.push_back(Pending::Awaiting {
                    kind: ReplyKind::HttpScore,
                    close_after: !keep,
                });
                if !keep {
                    self.closing = true; // no further requests after this one
                }
            }
            Err(Error::Overloaded(reason)) => self.push_http(
                http::json_error("503 Service Unavailable", "overloaded", &reason, keep),
                keep,
            ),
            Err(e) => self.push_http(
                http::json_error("400 Bad Request", "bad_request", &e.to_string(), keep),
                keep,
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{banana::Banana, Generator};
    use crate::scoring::batcher::{BatchPolicy, Batcher};
    use crate::svdd::{train, SvddParams};

    #[test]
    fn frame_bytes_matches_write_to() {
        let msg = Message::ScoreReplyV2 {
            dist2: vec![1.0, 2.5],
            r2: 0.5,
            epoch: 7,
            model_id: "v-1234".into(),
        };
        let mut via_write = Vec::new();
        msg.write_to(&mut via_write).unwrap();
        assert_eq!(frame_bytes(&msg), via_write);
    }

    #[test]
    fn take_frame_handles_fragments_and_rejects_oversized() {
        let msg = Message::Hello { version: 3 };
        let wire = frame_bytes(&msg);
        // fragment: nothing until the full frame is buffered
        let mut buf = wire[..3].to_vec();
        assert!(matches!(take_frame(&mut buf), Ok(None)));
        buf.extend_from_slice(&wire[3..wire.len() - 1]);
        assert!(matches!(take_frame(&mut buf), Ok(None)));
        buf.push(wire[wire.len() - 1]);
        assert_eq!(take_frame(&mut buf), Ok(Some(msg)));
        assert!(buf.is_empty(), "frame bytes must be consumed");
        // an oversized length prefix is fatal
        let mut huge = ((MAX_FRAME + 1) as u32).to_le_bytes().to_vec();
        huge.extend_from_slice(&[0u8; 8]);
        assert!(take_frame(&mut huge).is_err());
    }

    /// Spin up a bare edge loop (no ScoreServer wrapper) around a
    /// native batcher.
    struct TestEdge {
        addr: std::net::SocketAddr,
        stop: Arc<AtomicBool>,
        thread: Option<std::thread::JoinHandle<()>>,
        _batcher: Batcher,
        metrics: Arc<Metrics>,
        slot: ModelSlot,
    }

    impl TestEdge {
        fn spawn(model: SvddModel, policy: BatchPolicy, cfg: EdgeConfig) -> TestEdge {
            let metrics = Arc::new(Metrics::new());
            let slot = ModelSlot::new(model);
            let (batcher, handle) =
                Batcher::spawn(&slot, policy, metrics.clone(), |m, zs| Ok(m.dist2_batch(zs)));
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            listener.set_nonblocking(true).unwrap();
            let stop = Arc::new(AtomicBool::new(false));
            let remote_swap = Arc::new(AtomicBool::new(true));
            let thread = {
                let stop = stop.clone();
                let slot = slot.clone();
                let metrics = metrics.clone();
                std::thread::spawn(move || {
                    run_edge_loop(listener, stop, handle, slot, metrics, remote_swap, cfg)
                })
            };
            TestEdge { addr, stop, thread: Some(thread), _batcher: batcher, metrics, slot }
        }
    }

    impl Drop for TestEdge {
        fn drop(&mut self) {
            self.stop.store(true, Ordering::Relaxed);
            if let Some(t) = self.thread.take() {
                t.join().ok();
            }
        }
    }

    fn model() -> SvddModel {
        let data = Banana::default().generate(500, 1);
        train(&data, &SvddParams::gaussian(0.35, 0.01)).unwrap()
    }

    fn http_exchange(addr: std::net::SocketAddr, request: &[u8]) -> String {
        use std::io::{Read, Write};
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(request).unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn edge_serves_native_and_http_on_one_port() {
        let m = model();
        let edge = TestEdge::spawn(m.clone(), BatchPolicy::default(), EdgeConfig::default());

        // native framed client (raw, v3 handshake)
        let mut s = TcpStream::connect(edge.addr).unwrap();
        Message::Hello { version: 3 }.write_to(&mut s).unwrap();
        match Message::read_from(&mut s).unwrap() {
            Message::HelloAck { version } => assert_eq!(version, 3),
            other => panic!("unexpected {other:?}"),
        }
        let zs = Banana::default().generate(6, 2);
        Message::ScoreRequestV2 { rows: zs.clone() }.write_to(&mut s).unwrap();
        match Message::read_from(&mut s).unwrap() {
            Message::ScoreReplyV2 { dist2, r2, epoch, model_id } => {
                assert_eq!(dist2, m.dist2_batch(&zs));
                assert_eq!(r2, m.r2());
                assert_eq!(epoch, 0);
                assert_eq!(model_id, m.content_id());
            }
            other => panic!("unexpected {other:?}"),
        }
        Message::Shutdown.write_to(&mut s).ok();

        // HTTP JSON client on the same port
        let resp = http_exchange(
            edge.addr,
            b"POST /score HTTP/1.1\r\nContent-Length: 27\r\n\r\n{\"rows\": [[0.25, -1.5000]]}",
        );
        assert!(resp.starts_with("HTTP/1.1 200 OK\r\n"), "{resp}");
        let body = resp.split("\r\n\r\n").nth(1).unwrap();
        let parsed = Json::parse(body).unwrap();
        let want = m.dist2(&[0.25, -1.5]);
        let got = parsed.get("dist2").unwrap().as_arr().unwrap()[0].as_f64().unwrap();
        assert_eq!(got, want, "HTTP score must be bit-identical to the model");
        assert_eq!(parsed.get("model").unwrap().as_str().unwrap(), m.content_id());

        // metrics scrape still works, and counted the edge traffic
        let resp = http_exchange(edge.addr, b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(resp.contains("fastsvdd_rows_scored_total 7"), "{resp}");
        assert!(edge.metrics.edge_http_requests.get() >= 2);
        assert_eq!(edge.metrics.edge_conns_rejected.get(), 0);
    }

    #[test]
    fn http_errors_are_structured() {
        let m = model();
        let edge = TestEdge::spawn(m, BatchPolicy::default(), EdgeConfig::default());
        // bad JSON body → 400 with a JSON error object
        let resp = http_exchange(
            edge.addr,
            b"POST /score HTTP/1.1\r\nContent-Length: 8\r\n\r\nnot json",
        );
        assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
        assert!(resp.contains("\"error\":\"bad_request\""), "{resp}");
        // wrong row width → 400 naming the model dimension
        let resp = http_exchange(
            edge.addr,
            b"POST /score HTTP/1.1\r\nContent-Length: 21\r\n\r\n{\"rows\": [[1, 2, 3]]}",
        );
        assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
        assert!(resp.contains("model expects 2"), "{resp}");
        // unknown path → 404
        let resp = http_exchange(edge.addr, b"GET /nope HTTP/1.1\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 404"), "{resp}");
        assert!(resp.contains("\"error\":\"not_found\""));
        // oversized declared body → 413
        let req = format!(
            "POST /score HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            http::MAX_BODY + 1
        );
        let resp = http_exchange(edge.addr, req.as_bytes());
        assert!(resp.starts_with("HTTP/1.1 413"), "{resp}");
    }

    #[test]
    fn inflight_cap_sheds_with_503_and_overloaded_frame() {
        let m = model();
        // max_inflight_rows = 2: the second concurrent request (1 row
        // queued + 3 new) must be shed
        let policy = BatchPolicy {
            target_batch: 1 << 20,
            linger: Duration::from_millis(150), // hold the first rows in flight
            capacity: 1 << 16,
            adaptive: false,
        };
        let cfg = EdgeConfig { max_inflight_rows: 2, ..EdgeConfig::default() };
        let edge = TestEdge::spawn(m.clone(), policy, cfg);

        // park one row in the batcher window via a native v3 client
        let mut s = TcpStream::connect(edge.addr).unwrap();
        Message::Hello { version: 3 }.write_to(&mut s).unwrap();
        Message::read_from(&mut s).unwrap();
        let one = Banana::default().generate(1, 3);
        Message::ScoreRequestV2 { rows: one.clone() }.write_to(&mut s).unwrap();
        std::thread::sleep(Duration::from_millis(30));

        // HTTP request for 3 rows: 1 + 3 > 2 → 503
        let resp = http_exchange(
            edge.addr,
            b"POST /score HTTP/1.1\r\nContent-Length: 46\r\n\r\n{\"rows\": [[0, 0], [1.0, 1.0], [2.25, -0.125]]}",
        );
        assert!(resp.starts_with("HTTP/1.1 503"), "{resp}");
        assert!(resp.contains("\"error\":\"overloaded\""), "{resp}");

        // native v3 request over the cap: explicit Overloaded frame,
        // connection survives
        Message::ScoreRequestV2 { rows: Banana::default().generate(4, 5) }
            .write_to(&mut s)
            .unwrap();
        // first the original (parked) request's reply arrives, then the
        // overload notice for the second
        match Message::read_from(&mut s).unwrap() {
            Message::ScoreReplyV2 { dist2, .. } => assert_eq!(dist2, m.dist2_batch(&one)),
            other => panic!("unexpected {other:?}"),
        }
        match Message::read_from(&mut s).unwrap() {
            Message::Overloaded { reason } => assert!(reason.contains("in-flight")),
            other => panic!("unexpected {other:?}"),
        }
        assert!(edge.metrics.shed_requests.get() >= 2);
        // the shed cleared with the batch: scoring works again
        Message::ScoreRequestV2 { rows: one.clone() }.write_to(&mut s).unwrap();
        match Message::read_from(&mut s).unwrap() {
            Message::ScoreReplyV2 { dist2, .. } => assert_eq!(dist2, m.dist2_batch(&one)),
            other => panic!("unexpected {other:?}"),
        }
        Message::Shutdown.write_to(&mut s).ok();
    }

    #[test]
    fn conn_cap_rejects_excess_connections_without_stalling() {
        let m = model();
        let cfg = EdgeConfig { max_conns: 2, ..EdgeConfig::default() };
        let edge = TestEdge::spawn(m.clone(), BatchPolicy::default(), cfg);

        // two connections fill the cap
        let mut keep: Vec<TcpStream> = Vec::new();
        for _ in 0..2 {
            let mut s = TcpStream::connect(edge.addr).unwrap();
            Message::Hello { version: 3 }.write_to(&mut s).unwrap();
            Message::read_from(&mut s).unwrap();
            keep.push(s);
        }
        // the third is rejected with a best-effort 503 and closed
        {
            use std::io::Read;
            let mut s = TcpStream::connect(edge.addr).unwrap();
            let mut out = String::new();
            s.read_to_string(&mut out).unwrap();
            assert!(out.starts_with("HTTP/1.1 503"), "{out}");
        }
        assert_eq!(edge.metrics.edge_conns_rejected.get(), 1);
        // existing connections still score
        let zs = Banana::default().generate(2, 9);
        let s = &mut keep[0];
        Message::ScoreRequestV2 { rows: zs.clone() }.write_to(s).unwrap();
        match Message::read_from(s).unwrap() {
            Message::ScoreReplyV2 { dist2, .. } => assert_eq!(dist2, m.dist2_batch(&zs)),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn legacy_v2_session_is_closed_on_shed_not_answered() {
        let m = model();
        let policy = BatchPolicy {
            target_batch: 1 << 20,
            linger: Duration::from_millis(150),
            capacity: 1 << 16,
            adaptive: false,
        };
        let cfg = EdgeConfig { max_inflight_rows: 1, ..EdgeConfig::default() };
        let edge = TestEdge::spawn(m, policy, cfg);

        // park a row from one v2 client
        let mut a = TcpStream::connect(edge.addr).unwrap();
        Message::Hello { version: 2 }.write_to(&mut a).unwrap();
        Message::read_from(&mut a).unwrap();
        Message::ScoreRequest { rows: Banana::default().generate(1, 4) }
            .write_to(&mut a)
            .unwrap();
        std::thread::sleep(Duration::from_millis(30));

        // a second v2 client over the cap: no Overloaded frame exists
        // in its vocabulary → connection is dropped
        let mut b = TcpStream::connect(edge.addr).unwrap();
        Message::Hello { version: 2 }.write_to(&mut b).unwrap();
        Message::read_from(&mut b).unwrap();
        Message::ScoreRequest { rows: Banana::default().generate(1, 5) }
            .write_to(&mut b)
            .unwrap();
        assert!(
            Message::read_from(&mut b).is_err(),
            "legacy session must be closed on shed"
        );
        // the parked client still gets its reply
        assert!(matches!(
            Message::read_from(&mut a).unwrap(),
            Message::ScoreReply { .. }
        ));
    }
}
