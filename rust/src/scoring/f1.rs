//! Classification accuracy metrics (paper eqs. (19)–(21)).
//!
//! The paper's convention for the high-dimensional experiments: the
//! *positive* class is the target (normal) class, a prediction is
//! positive when the observation scores **inside** the description, and
//! quality is summarized by the F1-measure. The headline metric of
//! Figs 9/11/14–16 is the ratio `F1_sampling / F1_full`.

/// Confusion counts for the positive ("normal / inside") class.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Confusion {
    pub tp: usize,
    pub fp: usize,
    pub tn: usize,
    pub fn_: usize,
}

/// Build confusion counts from ground-truth labels (`true` = normal)
/// and predictions (`true` = predicted normal / inside).
pub fn confusion(truth: &[bool], predicted: &[bool]) -> Confusion {
    assert_eq!(truth.len(), predicted.len());
    let mut c = Confusion::default();
    for (&t, &p) in truth.iter().zip(predicted) {
        match (t, p) {
            (true, true) => c.tp += 1,
            (false, true) => c.fp += 1,
            (false, false) => c.tn += 1,
            (true, false) => c.fn_ += 1,
        }
    }
    c
}

/// Precision / recall / F1 (paper eqs. (19)–(21)).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct F1Score {
    pub precision: f64,
    pub recall: f64,
    pub f1: f64,
}

impl F1Score {
    pub fn from_confusion(c: Confusion) -> F1Score {
        let precision = if c.tp + c.fp == 0 {
            0.0
        } else {
            c.tp as f64 / (c.tp + c.fp) as f64
        };
        let recall = if c.tp + c.fn_ == 0 {
            0.0
        } else {
            c.tp as f64 / (c.tp + c.fn_) as f64
        };
        let f1 = if precision + recall == 0.0 {
            0.0
        } else {
            2.0 * precision * recall / (precision + recall)
        };
        F1Score { precision, recall, f1 }
    }

    pub fn compute(truth: &[bool], predicted: &[bool]) -> F1Score {
        Self::from_confusion(confusion(truth, predicted))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_prediction() {
        let truth = [true, true, false, false];
        let s = F1Score::compute(&truth, &truth);
        assert_eq!(s.precision, 1.0);
        assert_eq!(s.recall, 1.0);
        assert_eq!(s.f1, 1.0);
    }

    #[test]
    fn textbook_counts() {
        // tp=2 fp=1 fn=1 tn=1 -> P=2/3, R=2/3, F1=2/3
        let truth = [true, true, true, false, false];
        let pred = [true, true, false, true, false];
        let c = confusion(&truth, &pred);
        assert_eq!(c, Confusion { tp: 2, fp: 1, tn: 1, fn_: 1 });
        let s = F1Score::from_confusion(c);
        assert!((s.f1 - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn degenerate_all_negative_prediction() {
        let truth = [true, false];
        let pred = [false, false];
        let s = F1Score::compute(&truth, &pred);
        assert_eq!(s.precision, 0.0);
        assert_eq!(s.recall, 0.0);
        assert_eq!(s.f1, 0.0);
    }

    #[test]
    fn precision_recall_asymmetry() {
        // predict everything positive: recall 1, precision = base rate
        let truth = [true, false, false, false];
        let pred = [true, true, true, true];
        let s = F1Score::compute(&truth, &pred);
        assert_eq!(s.recall, 1.0);
        assert_eq!(s.precision, 0.25);
        assert!((s.f1 - 0.4).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn mismatched_lengths_panic() {
        confusion(&[true], &[true, false]);
    }
}
