//! Two-Donut data (paper Fig. 3c): two disjoint annuli.
//!
//! The paper's largest workload (1,333,334 observations) is this shape;
//! the full-SVDD cost curve of Fig. 1 is measured on it.

use crate::data::Generator;
use crate::util::matrix::Matrix;
use crate::util::rng::Xoshiro256;

#[derive(Clone, Copy, Debug)]
pub struct TwoDonut {
    /// Centers of the two donuts.
    pub c1: (f64, f64),
    pub c2: (f64, f64),
    /// Ring radius.
    pub radius: f64,
    /// Radial half-thickness.
    pub thickness: f64,
}

impl Default for TwoDonut {
    fn default() -> Self {
        TwoDonut {
            c1: (-1.5, 0.0),
            c2: (1.5, 0.0),
            radius: 1.0,
            thickness: 0.25,
        }
    }
}

impl Generator for TwoDonut {
    fn generate(&self, n: usize, seed: u64) -> Matrix {
        let mut rng = Xoshiro256::new(seed);
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|i| {
                let (cx, cy) = if i % 2 == 0 { self.c1 } else { self.c2 };
                let th = rng.range(0.0, std::f64::consts::TAU);
                // uniform over the annulus area: r = sqrt(U(r0^2, r1^2))
                let r0 = self.radius - self.thickness;
                let r1 = self.radius + self.thickness;
                let r = rng.range(r0 * r0, r1 * r1).sqrt();
                vec![cx + r * th.cos(), cy + r * th.sin()]
            })
            .collect();
        Matrix::from_rows(&rows).unwrap()
    }

    fn dim(&self) -> usize {
        2
    }

    fn name(&self) -> &'static str {
        "two-donut"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_shaped() {
        let g = TwoDonut::default();
        let a = g.generate(1000, 9);
        assert_eq!(a, g.generate(1000, 9));
        assert_eq!(a.cols(), 2);
    }

    #[test]
    fn points_on_one_of_two_rings() {
        let g = TwoDonut::default();
        let m = g.generate(3000, 11);
        for i in 0..m.rows() {
            let d1 = ((m.get(i, 0) - g.c1.0).powi(2) + (m.get(i, 1) - g.c1.1).powi(2)).sqrt();
            let d2 = ((m.get(i, 0) - g.c2.0).powi(2) + (m.get(i, 1) - g.c2.1).powi(2)).sqrt();
            let lo = g.radius - g.thickness - 1e-9;
            let hi = g.radius + g.thickness + 1e-9;
            let on1 = (lo..=hi).contains(&d1);
            let on2 = (lo..=hi).contains(&d2);
            assert!(on1 || on2, "point {i} off both rings: d1={d1} d2={d2}");
        }
    }

    #[test]
    fn both_rings_populated_evenly() {
        let g = TwoDonut::default();
        let m = g.generate(2000, 13);
        let left = (0..m.rows()).filter(|&i| m.get(i, 0) < 0.0).count();
        // alternating assignment -> exact half (centers are symmetric and
        // rings don't overlap x=0)
        assert!((left as i64 - 1000).abs() < 50, "left={left}");
    }

    #[test]
    fn hole_is_empty() {
        let g = TwoDonut::default();
        let m = g.generate(5000, 17);
        for i in 0..m.rows() {
            let d1 = ((m.get(i, 0) - g.c1.0).powi(2) + (m.get(i, 1) - g.c1.1).powi(2)).sqrt();
            let d2 = ((m.get(i, 0) - g.c2.0).powi(2) + (m.get(i, 1) - g.c2.1).powi(2)).sqrt();
            assert!(d1.min(d2) > g.radius - g.thickness - 1e-9);
        }
    }
}
