//! Shuttle-like data (paper section V-A).
//!
//! The paper uses the UCI Statlog (Shuttle) set: 58 000 observations,
//! nine numeric attributes, ~80 % belonging to class 1. This environment
//! has no network access, so we generate a seeded synthetic equivalent
//! that preserves what the experiment exercises (DESIGN.md section 2):
//! a dominant class occupying a structured region of R^9 (mixture of
//! three operating modes with correlated, integer-rounded features —
//! the UCI attributes are integer telemetry counts) and six minority
//! classes offset from it. Train on class-1 rows, score on a mix,
//! measure F1 of "is class 1".

use crate::data::LabeledData;
use crate::util::matrix::Matrix;
use crate::util::rng::Xoshiro256;

pub const DIM: usize = 9;

/// Fraction of class-1 (normal) rows in the scoring mix, matching the
/// UCI class balance.
pub const NORMAL_FRACTION: f64 = 0.8;

/// Seed salts so training and scoring streams never collide even with
/// equal user seeds.
const TRAIN_SALT: u64 = 0x5331_7454_7261_494e; // "S1tTraIN"
const SCORE_SALT: u64 = 0x5331_7453_436f_5245; // "S1tSCoRE"

#[derive(Clone, Copy, Debug, Default)]
pub struct Shuttle;

/// The three class-1 "operating modes": (mean, per-axis scale).
const MODES: [([f64; DIM], f64); 3] = [
    ([40.0, 0.0, 80.0, 0.0, 28.0, 0.0, 40.0, 52.0, 12.0], 3.0),
    ([42.0, -2.0, 84.0, 2.0, 24.0, 2.0, 44.0, 56.0, 8.0], 2.5),
    ([36.0, 2.0, 76.0, -2.0, 32.0, -2.0, 36.0, 48.0, 16.0], 3.5),
];

/// Offsets that define the six anomaly classes (class ids 2..=7).
const ANOMALY_SHIFTS: [[f64; DIM]; 6] = [
    [18.0, 0.0, 0.0, 9.0, 0.0, 0.0, -14.0, 0.0, 0.0],
    [0.0, 16.0, -16.0, 0.0, 9.0, 0.0, 0.0, 11.0, 0.0],
    [-15.0, 0.0, 12.0, 0.0, -16.0, 7.0, 0.0, 0.0, 12.0],
    [0.0, -9.0, 0.0, 18.0, 0.0, -12.0, 9.0, 0.0, -9.0],
    [11.0, 11.0, 0.0, 0.0, 13.0, 0.0, 0.0, -16.0, 7.0],
    [0.0, 0.0, -18.0, -9.0, 0.0, 14.0, -9.0, 9.0, 0.0],
];

impl Shuttle {
    fn class1_row(rng: &mut Xoshiro256) -> Vec<f64> {
        let mode = &MODES[rng.index(MODES.len())];
        (0..DIM)
            .map(|j| (mode.0[j] + rng.normal() * mode.1).round())
            .collect()
    }

    fn anomaly_row(rng: &mut Xoshiro256) -> Vec<f64> {
        let mode = &MODES[rng.index(MODES.len())];
        let shift = &ANOMALY_SHIFTS[rng.index(ANOMALY_SHIFTS.len())];
        (0..DIM)
            .map(|j| (mode.0[j] + shift[j] + rng.normal() * mode.1 * 1.4).round())
            .collect()
    }

    /// `n` rows of class-1 data — the training set of the experiment.
    pub fn training(&self, n: usize, seed: u64) -> Matrix {
        let mut rng = Xoshiro256::new(seed ^ TRAIN_SALT);
        let rows: Vec<Vec<f64>> = (0..n).map(|_| Self::class1_row(&mut rng)).collect();
        Matrix::from_rows(&rows).unwrap()
    }

    /// `n` rows mixing class 1 (label true, ~[`NORMAL_FRACTION`]) and
    /// anomaly classes (label false) — the scoring set.
    pub fn scoring(&self, n: usize, seed: u64) -> LabeledData {
        let mut rng = Xoshiro256::new(seed ^ SCORE_SALT);
        let mut rows = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            if rng.f64() < NORMAL_FRACTION {
                rows.push(Self::class1_row(&mut rng));
                labels.push(true);
            } else {
                rows.push(Self::anomaly_row(&mut rng));
                labels.push(false);
            }
        }
        LabeledData::new(Matrix::from_rows(&rows).unwrap(), labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mean;

    #[test]
    fn shapes_and_determinism() {
        let s = Shuttle;
        let t = s.training(500, 1);
        assert_eq!(t.rows(), 500);
        assert_eq!(t.cols(), DIM);
        assert_eq!(t, s.training(500, 1));
        let sc = s.scoring(400, 1);
        assert_eq!(sc.len(), 400);
        assert_eq!(sc.data, s.scoring(400, 1).data);
    }

    #[test]
    fn class_balance_near_eighty_percent() {
        let sc = Shuttle.scoring(20_000, 2);
        let frac = sc.num_normal() as f64 / sc.len() as f64;
        assert!((frac - NORMAL_FRACTION).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn features_are_integers() {
        let t = Shuttle.training(200, 3);
        for v in t.as_slice() {
            assert_eq!(v.fract(), 0.0);
        }
    }

    #[test]
    fn anomalies_are_shifted_away() {
        // mean distance of anomaly rows to the class-1 centroid is larger
        let sc = Shuttle.scoring(5000, 4);
        let t = Shuttle.training(5000, 4);
        let centroid = t.col_means();
        let mut d_norm = Vec::new();
        let mut d_anom = Vec::new();
        for i in 0..sc.len() {
            let d = Matrix::sqdist(sc.data.row(i), &centroid).sqrt();
            if sc.labels[i] {
                d_norm.push(d);
            } else {
                d_anom.push(d);
            }
        }
        assert!(
            mean(&d_anom) > mean(&d_norm) + 5.0,
            "norm={} anom={}",
            mean(&d_norm),
            mean(&d_anom)
        );
    }

    #[test]
    fn train_and_score_streams_are_distinct() {
        let s = Shuttle;
        let t = s.training(10, 7);
        let sc = s.scoring(10, 7);
        assert_ne!(t.row(0), sc.data.row(0));
    }
}
