//! Banana-shaped data (paper Fig. 3a): a thick crescent.
//!
//! Points are drawn along a circular arc with radial thickness — the
//! standard "banana" one-class benchmark geometry. Defaults match the
//! visual of the paper's scatter plot: an arc spanning ~3/4 of a circle
//! of radius 1 with +-0.2 thickness, axis-aligned like a banana.

use crate::data::Generator;
use crate::util::matrix::Matrix;
use crate::util::rng::Xoshiro256;

#[derive(Clone, Copy, Debug)]
pub struct Banana {
    /// Arc radius.
    pub radius: f64,
    /// Radial half-thickness.
    pub thickness: f64,
    /// Arc span in radians.
    pub span: f64,
    /// Arc start angle.
    pub start: f64,
}

impl Default for Banana {
    fn default() -> Self {
        Banana {
            radius: 1.0,
            thickness: 0.2,
            span: 0.75 * std::f64::consts::TAU,
            start: -0.1 * std::f64::consts::TAU,
        }
    }
}

impl Generator for Banana {
    fn generate(&self, n: usize, seed: u64) -> Matrix {
        let mut rng = Xoshiro256::new(seed);
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|_| {
                let th = self.start + self.span * rng.f64();
                // triangular-ish radial profile: denser mid-band, like the
                // paper's scatter
                let dr = self.thickness * (rng.f64() + rng.f64() - 1.0);
                let r = self.radius + dr;
                vec![r * th.cos(), r * th.sin()]
            })
            .collect();
        Matrix::from_rows(&rows).unwrap()
    }

    fn dim(&self) -> usize {
        2
    }

    fn name(&self) -> &'static str {
        "banana"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_determinism() {
        let g = Banana::default();
        let a = g.generate(500, 3);
        let b = g.generate(500, 3);
        assert_eq!(a, b);
        assert_eq!(a.rows(), 500);
        assert_eq!(a.cols(), 2);
    }

    #[test]
    fn points_live_on_the_annulus_band() {
        let g = Banana::default();
        let m = g.generate(2000, 5);
        for i in 0..m.rows() {
            let r = (m.get(i, 0).powi(2) + m.get(i, 1).powi(2)).sqrt();
            assert!(
                (g.radius - g.thickness - 1e-9..=g.radius + g.thickness + 1e-9)
                    .contains(&r),
                "r={r}"
            );
        }
    }

    #[test]
    fn crescent_is_not_a_full_circle() {
        // with span 0.75 tau there must be an angular gap: no point in the
        // missing quarter (centered opposite the arc midpoint)
        let g = Banana::default();
        let m = g.generate(4000, 7);
        let gap_mid = g.start + g.span + 0.125 * std::f64::consts::TAU;
        let in_gap = (0..m.rows())
            .filter(|&i| {
                let th = m.get(i, 1).atan2(m.get(i, 0));
                let mut d = (th - gap_mid).rem_euclid(std::f64::consts::TAU);
                if d > std::f64::consts::PI {
                    d = std::f64::consts::TAU - d;
                }
                d < 0.1 * std::f64::consts::PI
            })
            .count();
        assert_eq!(in_gap, 0, "points leaked into the angular gap");
    }

    #[test]
    fn different_seeds_differ() {
        let g = Banana::default();
        assert_ne!(g.generate(10, 1), g.generate(10, 2));
    }
}
