//! Random polygons (paper section VI) and the polygon substrate the
//! Star data set and the simulation study both build on:
//!
//! - random polygon generation: vertices `r_i exp(i theta_(i))` with
//!   `theta_(i)` the order statistics of a uniform sample on `(0, 2pi)`
//!   and `r_i ~ U[r_min, r_max]` (exactly the paper's construction);
//! - **ear-clipping triangulation** (simple polygons, no holes) so we
//!   can sample the interior uniformly by area-weighted triangles;
//! - point-in-polygon (ray casting) for labeling the 200x200 grid.

use crate::util::matrix::Matrix;
use crate::util::rng::Xoshiro256;

/// A simple polygon (counter-clockwise vertex order).
#[derive(Clone, Debug)]
pub struct Polygon {
    verts: Vec<(f64, f64)>,
}

impl Polygon {
    pub fn new(verts: Vec<(f64, f64)>) -> Polygon {
        assert!(verts.len() >= 3, "polygon needs >= 3 vertices");
        Polygon { verts }
    }

    /// The paper's random polygon: `k` vertices, angles sorted uniform
    /// order statistics, radii uniform in `[r_min, r_max]`.
    ///
    /// The raw construction can self-intersect when the largest angular
    /// gap exceeds pi (the chord across the gap sweeps other sectors),
    /// which happens with noticeable probability at small `k`. The
    /// paper's polygons (Fig. 13) are simple, so we rejection-sample:
    /// redraw (deterministically, seed+attempt) until simple.
    pub fn random(k: usize, r_min: f64, r_max: f64, seed: u64) -> Polygon {
        assert!(k >= 3);
        for attempt in 0..1000u64 {
            let mut rng = Xoshiro256::new(seed ^ attempt.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let mut thetas: Vec<f64> = (0..k)
                .map(|_| rng.range(0.0, std::f64::consts::TAU))
                .collect();
            thetas.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let verts: Vec<(f64, f64)> = thetas
                .into_iter()
                .map(|th| {
                    let r = rng.range(r_min, r_max);
                    (r * th.cos(), r * th.sin())
                })
                .collect();
            let p = Polygon { verts };
            if p.is_simple() {
                return p;
            }
        }
        unreachable!("1000 consecutive self-intersecting polygons (k={k})");
    }

    /// True iff no two non-adjacent edges intersect (simple polygon).
    pub fn is_simple(&self) -> bool {
        let n = self.verts.len();
        let edge = |i: usize| (self.verts[i], self.verts[(i + 1) % n]);
        for i in 0..n {
            for j in (i + 1)..n {
                // skip adjacent edges (they share a vertex)
                if j == i + 1 || (i == 0 && j == n - 1) {
                    continue;
                }
                let (a, b) = edge(i);
                let (c, d) = edge(j);
                if segments_intersect(a, b, c, d) {
                    return false;
                }
            }
        }
        true
    }

    pub fn vertices(&self) -> &[(f64, f64)] {
        &self.verts
    }

    pub fn num_vertices(&self) -> usize {
        self.verts.len()
    }

    /// Signed area (positive for CCW). Star-shaped-by-construction
    /// polygons from [`Polygon::random`] are always CCW.
    pub fn signed_area(&self) -> f64 {
        let n = self.verts.len();
        let mut s = 0.0;
        for i in 0..n {
            let (x1, y1) = self.verts[i];
            let (x2, y2) = self.verts[(i + 1) % n];
            s += x1 * y2 - x2 * y1;
        }
        s / 2.0
    }

    pub fn area(&self) -> f64 {
        self.signed_area().abs()
    }

    /// Axis-aligned bounding box `((min_x, min_y), (max_x, max_y))`.
    pub fn bbox(&self) -> ((f64, f64), (f64, f64)) {
        let mut lo = (f64::INFINITY, f64::INFINITY);
        let mut hi = (f64::NEG_INFINITY, f64::NEG_INFINITY);
        for &(x, y) in &self.verts {
            lo.0 = lo.0.min(x);
            lo.1 = lo.1.min(y);
            hi.0 = hi.0.max(x);
            hi.1 = hi.1.max(y);
        }
        (lo, hi)
    }

    /// Ray-casting point-in-polygon (boundary counts as inside-ish; exact
    /// boundary behaviour is irrelevant for measure-zero grid points).
    pub fn contains(&self, x: f64, y: f64) -> bool {
        let n = self.verts.len();
        let mut inside = false;
        let mut j = n - 1;
        for i in 0..n {
            let (xi, yi) = self.verts[i];
            let (xj, yj) = self.verts[j];
            if ((yi > y) != (yj > y))
                && (x < (xj - xi) * (y - yi) / (yj - yi) + xi)
            {
                inside = !inside;
            }
            j = i;
        }
        inside
    }

    /// Ear-clipping triangulation of a simple polygon. Returns triangles
    /// as vertex triples. O(n^2), fine for n <= a few hundred.
    pub fn triangulate(&self) -> Vec<[(f64, f64); 3]> {
        let ccw = self.signed_area() > 0.0;
        let mut idx: Vec<usize> = if ccw {
            (0..self.verts.len()).collect()
        } else {
            (0..self.verts.len()).rev().collect()
        };
        let v = &self.verts;
        let mut tris = Vec::with_capacity(v.len().saturating_sub(2));

        let cross = |a: (f64, f64), b: (f64, f64), c: (f64, f64)| -> f64 {
            (b.0 - a.0) * (c.1 - a.1) - (b.1 - a.1) * (c.0 - a.0)
        };
        let in_tri = |p: (f64, f64), a: (f64, f64), b: (f64, f64), c: (f64, f64)| -> bool {
            let d1 = cross(a, b, p);
            let d2 = cross(b, c, p);
            let d3 = cross(c, a, p);
            let has_neg = d1 < 0.0 || d2 < 0.0 || d3 < 0.0;
            let has_pos = d1 > 0.0 || d2 > 0.0 || d3 > 0.0;
            !(has_neg && has_pos)
        };

        let mut guard = 0usize;
        while idx.len() > 3 {
            let n = idx.len();
            let mut clipped = false;
            for k in 0..n {
                let ia = idx[(k + n - 1) % n];
                let ib = idx[k];
                let ic = idx[(k + 1) % n];
                let (a, b, c) = (v[ia], v[ib], v[ic]);
                if cross(a, b, c) <= 1e-14 {
                    continue; // reflex or degenerate corner
                }
                // no other active vertex inside the candidate ear
                let blocked = idx.iter().any(|&m| {
                    m != ia && m != ib && m != ic && in_tri(v[m], a, b, c)
                });
                if blocked {
                    continue;
                }
                tris.push([a, b, c]);
                idx.remove(k);
                clipped = true;
                break;
            }
            guard += 1;
            if !clipped || guard > 10 * self.verts.len() {
                // numerically degenerate input: fall back to a fan, which
                // is correct for the star-shaped polygons Polygon::random
                // produces.
                tris.clear();
                for k in 1..self.verts.len() - 1 {
                    tris.push([v[0], v[k], v[k + 1]]);
                }
                return tris;
            }
        }
        tris.push([v[idx[0]], v[idx[1]], v[idx[2]]]);
        tris
    }

    /// `n` points uniform over the interior: pick a triangle with
    /// probability proportional to area, then a uniform point inside it.
    pub fn sample_interior(&self, n: usize, seed: u64) -> Matrix {
        let tris = self.triangulate();
        let areas: Vec<f64> = tris
            .iter()
            .map(|t| {
                0.5 * ((t[1].0 - t[0].0) * (t[2].1 - t[0].1)
                    - (t[1].1 - t[0].1) * (t[2].0 - t[0].0))
                    .abs()
            })
            .collect();
        let total: f64 = areas.iter().sum();
        let mut cum = Vec::with_capacity(areas.len());
        let mut acc = 0.0;
        for a in &areas {
            acc += a / total;
            cum.push(acc);
        }
        let mut rng = Xoshiro256::new(seed);
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|_| {
                let u = rng.f64();
                let ti = cum.partition_point(|&c| c < u).min(tris.len() - 1);
                let t = &tris[ti];
                // uniform in triangle via sqrt trick
                let r1 = rng.f64().sqrt();
                let r2 = rng.f64();
                let x = (1.0 - r1) * t[0].0 + r1 * (1.0 - r2) * t[1].0 + r1 * r2 * t[2].0;
                let y = (1.0 - r1) * t[0].1 + r1 * (1.0 - r2) * t[1].1 + r1 * r2 * t[2].1;
                vec![x, y]
            })
            .collect();
        Matrix::from_rows(&rows).unwrap()
    }
}

/// Segment intersection including touching/collinear-overlap (any
/// contact counts — used to *reject* polygons, so conservative is good).
fn segments_intersect(a: (f64, f64), b: (f64, f64), c: (f64, f64), d: (f64, f64)) -> bool {
    let orient = |p: (f64, f64), q: (f64, f64), r: (f64, f64)| -> f64 {
        (q.0 - p.0) * (r.1 - p.1) - (q.1 - p.1) * (r.0 - p.0)
    };
    let on_seg = |p: (f64, f64), q: (f64, f64), r: (f64, f64)| -> bool {
        r.0 >= p.0.min(q.0) && r.0 <= p.0.max(q.0) && r.1 >= p.1.min(q.1) && r.1 <= p.1.max(q.1)
    };
    let d1 = orient(a, b, c);
    let d2 = orient(a, b, d);
    let d3 = orient(c, d, a);
    let d4 = orient(c, d, b);
    if ((d1 > 0.0 && d2 < 0.0) || (d1 < 0.0 && d2 > 0.0))
        && ((d3 > 0.0 && d4 < 0.0) || (d3 < 0.0 && d4 > 0.0))
    {
        return true;
    }
    (d1 == 0.0 && on_seg(a, b, c))
        || (d2 == 0.0 && on_seg(a, b, d))
        || (d3 == 0.0 && on_seg(c, d, a))
        || (d4 == 0.0 && on_seg(c, d, b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segment_intersection_cases() {
        let o = (0.0, 0.0);
        assert!(segments_intersect(o, (2.0, 2.0), (0.0, 2.0), (2.0, 0.0)));
        assert!(!segments_intersect(o, (1.0, 0.0), (0.0, 1.0), (1.0, 1.0)));
        // touching endpoint counts
        assert!(segments_intersect(o, (1.0, 0.0), (1.0, 0.0), (2.0, 5.0)));
        // collinear overlap counts
        assert!(segments_intersect(o, (2.0, 0.0), (1.0, 0.0), (3.0, 0.0)));
    }

    #[test]
    fn random_polygons_are_simple() {
        for k in [5, 8, 12, 30] {
            for seed in 0..10 {
                assert!(Polygon::random(k, 3.0, 5.0, seed).is_simple(), "k={k} seed={seed}");
            }
        }
    }

    fn square() -> Polygon {
        Polygon::new(vec![(0.0, 0.0), (2.0, 0.0), (2.0, 2.0), (0.0, 2.0)])
    }

    /// Non-convex "L" shape.
    fn ell() -> Polygon {
        Polygon::new(vec![
            (0.0, 0.0),
            (2.0, 0.0),
            (2.0, 1.0),
            (1.0, 1.0),
            (1.0, 2.0),
            (0.0, 2.0),
        ])
    }

    #[test]
    fn area_of_square_and_ell() {
        assert!((square().area() - 4.0).abs() < 1e-12);
        assert!((ell().area() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn contains_basic() {
        let sq = square();
        assert!(sq.contains(1.0, 1.0));
        assert!(!sq.contains(3.0, 1.0));
        assert!(!sq.contains(-0.1, 1.0));
        let l = ell();
        assert!(l.contains(0.5, 1.5));
        assert!(!l.contains(1.5, 1.5)); // the notch
    }

    #[test]
    fn triangulation_preserves_area() {
        for poly in [square(), ell()] {
            let tris = poly.triangulate();
            assert_eq!(tris.len(), poly.num_vertices() - 2);
            let sum: f64 = tris
                .iter()
                .map(|t| {
                    0.5 * ((t[1].0 - t[0].0) * (t[2].1 - t[0].1)
                        - (t[1].1 - t[0].1) * (t[2].0 - t[0].0))
                        .abs()
                })
                .sum();
            assert!((sum - poly.area()).abs() < 1e-9, "area {} != {}", sum, poly.area());
        }
    }

    #[test]
    fn triangulation_of_random_polygons_preserves_area() {
        for k in [5, 9, 17, 30] {
            for seed in 0..5 {
                let p = Polygon::random(k, 3.0, 5.0, seed);
                let tris = p.triangulate();
                let sum: f64 = tris
                    .iter()
                    .map(|t| {
                        0.5 * ((t[1].0 - t[0].0) * (t[2].1 - t[0].1)
                            - (t[1].1 - t[0].1) * (t[2].0 - t[0].0))
                            .abs()
                    })
                    .sum();
                assert!(
                    (sum - p.area()).abs() < 1e-6 * p.area().max(1.0),
                    "k={k} seed={seed}: {sum} vs {}",
                    p.area()
                );
            }
        }
    }

    #[test]
    fn random_polygon_matches_paper_construction() {
        let p = Polygon::random(12, 3.0, 5.0, 7);
        assert_eq!(p.num_vertices(), 12);
        // radii within [3, 5]
        for &(x, y) in p.vertices() {
            let r = (x * x + y * y).sqrt();
            assert!((3.0 - 1e-9..=5.0 + 1e-9).contains(&r), "r={r}");
        }
        // angles strictly increasing (order statistics)
        let angles: Vec<f64> = p
            .vertices()
            .iter()
            .map(|&(x, y)| y.atan2(x).rem_euclid(std::f64::consts::TAU))
            .collect();
        for w in angles.windows(2) {
            assert!(w[1] >= w[0], "angles not sorted: {angles:?}");
        }
    }

    #[test]
    fn interior_samples_are_inside() {
        for poly in [square(), ell(), Polygon::random(15, 3.0, 5.0, 3)] {
            let pts = poly.sample_interior(600, 4);
            for i in 0..pts.rows() {
                assert!(
                    poly.contains(pts.get(i, 0), pts.get(i, 1)),
                    "sample {i} escaped the polygon"
                );
            }
        }
    }

    #[test]
    fn interior_sampling_is_uniform_ish() {
        // square [0,2]^2: quadrant counts should be ~ n/4 each
        let pts = square().sample_interior(8000, 5);
        let mut counts = [0usize; 4];
        for i in 0..pts.rows() {
            let qx = (pts.get(i, 0) >= 1.0) as usize;
            let qy = (pts.get(i, 1) >= 1.0) as usize;
            counts[2 * qy + qx] += 1;
        }
        for c in counts {
            assert!((c as f64 - 2000.0).abs() < 200.0, "counts={counts:?}");
        }
    }

    #[test]
    fn bbox_is_tight() {
        let ((lx, ly), (hx, hy)) = ell().bbox();
        assert_eq!((lx, ly, hx, hy), (0.0, 0.0, 2.0, 2.0));
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Polygon::random(8, 3.0, 5.0, 11);
        let b = Polygon::random(8, 3.0, 5.0, 11);
        assert_eq!(a.vertices(), b.vertices());
        assert_eq!(
            a.sample_interior(50, 2).as_slice(),
            b.sample_interior(50, 2).as_slice()
        );
    }

    #[test]
    fn clockwise_polygon_still_triangulates() {
        let cw = Polygon::new(vec![(0.0, 2.0), (2.0, 2.0), (2.0, 0.0), (0.0, 0.0)]);
        let tris = cw.triangulate();
        let sum: f64 = tris
            .iter()
            .map(|t| {
                0.5 * ((t[1].0 - t[0].0) * (t[2].1 - t[0].1)
                    - (t[1].1 - t[0].1) * (t[2].0 - t[0].0))
                    .abs()
            })
            .sum();
        assert!((sum - 4.0).abs() < 1e-9);
    }
}
