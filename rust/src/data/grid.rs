//! The 200x200 scoring grid the paper uses for Figs 8 and the polygon
//! study: a regular lattice over a bounding box, plus a PGM writer so
//! grid scorings can be eyeballed (Fig 8's black/gray images).

use crate::error::Result;
use crate::util::matrix::Matrix;

/// A regular `nx` x `ny` lattice over `[x0, x1] x [y0, y1]`.
#[derive(Clone, Copy, Debug)]
pub struct Grid {
    pub nx: usize,
    pub ny: usize,
    pub x0: f64,
    pub x1: f64,
    pub y0: f64,
    pub y1: f64,
}

impl Grid {
    /// The paper's 200x200 grid.
    pub fn square200(x0: f64, x1: f64, y0: f64, y1: f64) -> Grid {
        Grid { nx: 200, ny: 200, x0, x1, y0, y1 }
    }

    /// Grid over the bounding box of `data` expanded by `margin`
    /// (relative to the box size).
    pub fn covering(data: &Matrix, nx: usize, ny: usize, margin: f64) -> Grid {
        assert_eq!(data.cols(), 2, "grid covers 2-d data only");
        let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
        for i in 0..data.rows() {
            x0 = x0.min(data.get(i, 0));
            x1 = x1.max(data.get(i, 0));
            y0 = y0.min(data.get(i, 1));
            y1 = y1.max(data.get(i, 1));
        }
        let (dx, dy) = ((x1 - x0) * margin, (y1 - y0) * margin);
        Grid { nx, ny, x0: x0 - dx, x1: x1 + dx, y0: y0 - dy, y1: y1 + dy }
    }

    pub fn len(&self) -> usize {
        self.nx * self.ny
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The grid point at lattice index `(i, j)` (row i along y, col j
    /// along x).
    pub fn point(&self, i: usize, j: usize) -> (f64, f64) {
        let fx = if self.nx > 1 { j as f64 / (self.nx - 1) as f64 } else { 0.5 };
        let fy = if self.ny > 1 { i as f64 / (self.ny - 1) as f64 } else { 0.5 };
        (self.x0 + fx * (self.x1 - self.x0), self.y0 + fy * (self.y1 - self.y0))
    }

    /// All lattice points as an `(nx*ny) x 2` matrix, row-major in `i`.
    pub fn points(&self) -> Matrix {
        let mut data = Vec::with_capacity(self.len() * 2);
        for i in 0..self.ny {
            for j in 0..self.nx {
                let (x, y) = self.point(i, j);
                data.push(x);
                data.push(y);
            }
        }
        Matrix::from_vec(data, self.len(), 2).unwrap()
    }

    /// Label every lattice point with `f(x, y)` (e.g. polygon membership
    /// for the simulation study's ground truth).
    pub fn labels_from(&self, f: impl Fn(f64, f64) -> bool) -> Vec<bool> {
        let mut labels = Vec::with_capacity(self.len());
        for i in 0..self.ny {
            for j in 0..self.nx {
                let (x, y) = self.point(i, j);
                labels.push(f(x, y));
            }
        }
        labels
    }

    /// Write a binary inside/outside map as a PGM image (Fig 8 style:
    /// black = inside, light gray = outside).
    pub fn write_pgm(&self, labels: &[bool], path: &std::path::Path) -> Result<()> {
        assert_eq!(labels.len(), self.len());
        let mut buf = format!("P5\n{} {}\n255\n", self.nx, self.ny).into_bytes();
        // flip vertically so +y is up in the image
        for i in (0..self.ny).rev() {
            for j in 0..self.nx {
                buf.push(if labels[i * self.nx + j] { 0 } else { 200 });
            }
        }
        std::fs::write(path, buf)?;
        Ok(())
    }
}

/// Fraction of positions where the two label maps agree — the metric we
/// report for Fig 8's "full vs sampling boundary similarity".
pub fn agreement(a: &[bool], b: &[bool]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 1.0;
    }
    a.iter().zip(b).filter(|(x, y)| x == y).count() as f64 / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_corners() {
        let g = Grid::square200(-1.0, 1.0, 0.0, 2.0);
        assert_eq!(g.len(), 40_000);
        assert_eq!(g.point(0, 0), (-1.0, 0.0));
        assert_eq!(g.point(199, 199), (1.0, 2.0));
    }

    #[test]
    fn points_matrix_layout() {
        let g = Grid { nx: 3, ny: 2, x0: 0.0, x1: 2.0, y0: 0.0, y1: 1.0 };
        let m = g.points();
        assert_eq!(m.rows(), 6);
        assert_eq!(m.row(0), &[0.0, 0.0]);
        assert_eq!(m.row(2), &[2.0, 0.0]);
        assert_eq!(m.row(3), &[0.0, 1.0]);
    }

    #[test]
    fn covering_box_includes_margin() {
        let data = Matrix::from_rows(&[vec![0.0, 0.0], vec![10.0, 4.0]]).unwrap();
        let g = Grid::covering(&data, 50, 50, 0.1);
        assert_eq!(g.x0, -1.0);
        assert_eq!(g.x1, 11.0);
        assert_eq!(g.y0, -0.4);
        assert_eq!(g.y1, 4.4);
    }

    #[test]
    fn labels_and_agreement() {
        let g = Grid { nx: 10, ny: 10, x0: 0.0, x1: 1.0, y0: 0.0, y1: 1.0 };
        let a = g.labels_from(|x, _| x < 0.5);
        let b = g.labels_from(|x, _| x < 0.5);
        assert_eq!(agreement(&a, &b), 1.0);
        let c = g.labels_from(|x, _| x >= 0.5);
        assert!(agreement(&a, &c) < 0.2);
    }

    #[test]
    fn pgm_writes_header_and_pixels() {
        let g = Grid { nx: 4, ny: 3, x0: 0.0, x1: 1.0, y0: 0.0, y1: 1.0 };
        let labels = vec![true; 12];
        let dir = std::env::temp_dir().join("fastsvdd_grid_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.pgm");
        g.write_pgm(&labels, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert!(bytes.starts_with(b"P5\n4 3\n255\n"));
        assert_eq!(bytes.len(), b"P5\n4 3\n255\n".len() + 12);
        std::fs::remove_file(&path).ok();
    }
}
