//! Tennessee-Eastman-like process simulator (paper section V-B).
//!
//! The paper generates data from Ricker's MATLAB simulation of the
//! Tennessee Eastman chemical plant (Downs & Vogel 1993): 41 measured
//! variables (22 continuous process measurements + 19 sampled analyzer
//! compositions), one normal operating mode and twenty fault modes.
//! That simulator is MATLAB-only, so per the substitution rule we build
//! the closest synthetic equivalent exercising the same code path: a
//! stable linear state-space plant
//!
//! ```text
//! x[k+1] = A x[k] + B u + w[k]        (8 internal states)
//! y[k]   = C x[k] + y0 + v[k]         (41 measurements)
//! ```
//!
//! with seeded random (A, B, C), zero-order-hold resampling of the 19
//! analyzer channels (the paper's 0.1 h / 0.25 h sampled variables),
//! and twenty fault families grouped exactly like TE's documented
//! faults: step disturbances (1–7), slow drifts (8–12), measurement
//! bias/sticking (13–16), oscillations (17–18) and variance inflation
//! (19–20).

use crate::data::LabeledData;
use crate::util::matrix::Matrix;
use crate::util::rng::Xoshiro256;

/// Total measured variables (22 continuous + 19 sampled).
pub const DIM: usize = 41;
/// Continuous channels y[0..22); analyzer channels y[22..41).
pub const CONTINUOUS: usize = 22;
/// Internal plant state dimension.
const STATE: usize = 8;
/// Analyzer channels update every HOLD steps (zero-order hold).
const HOLD: usize = 10;
/// Number of fault modes.
pub const NUM_FAULTS: usize = 20;

/// The synthetic plant. Construction is deterministic in `plant_seed`
/// (the paper uses one plant; keep the default).
#[derive(Clone, Debug)]
pub struct TennesseePlant {
    a: [[f64; STATE]; STATE],
    b: [f64; STATE],
    c: Vec<[f64; STATE]>, // DIM rows
    y0: Vec<f64>,         // operating-point offsets
    noise_y: f64,
    noise_x: f64,
}

impl Default for TennesseePlant {
    fn default() -> Self {
        TennesseePlant::new(0x7E55EE)
    }
}

/// Which fault family a fault id belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    Step,
    Drift,
    Bias,
    Oscillation,
    Variance,
}

/// Fault family of fault `id` (1-based, 1..=20).
pub fn fault_kind(id: usize) -> FaultKind {
    match id {
        1..=7 => FaultKind::Step,
        8..=12 => FaultKind::Drift,
        13..=16 => FaultKind::Bias,
        17..=18 => FaultKind::Oscillation,
        19..=20 => FaultKind::Variance,
        _ => panic!("fault id {id} out of 1..=20"),
    }
}

impl TennesseePlant {
    pub fn new(plant_seed: u64) -> Self {
        let mut rng = Xoshiro256::new(plant_seed);
        // Stable A = 0.6 I + R with zero-diagonal R whose absolute row
        // sums are 0.3: Gershgorin discs are centered at 0.6 with radius
        // 0.3, so every eigenvalue satisfies |lambda| <= 0.9 < 1.
        let mut a = [[0.0; STATE]; STATE];
        for (i, row) in a.iter_mut().enumerate() {
            let mut sum = 0.0;
            for (j, v) in row.iter_mut().enumerate() {
                if j != i {
                    *v = rng.normal();
                    sum += v.abs();
                }
            }
            for (j, v) in row.iter_mut().enumerate() {
                if j != i {
                    *v *= 0.3 / sum;
                }
            }
            row[i] = 0.6;
        }
        let mut b = [0.0; STATE];
        for v in &mut b {
            *v = rng.range(0.5, 1.5);
        }
        let c: Vec<[f64; STATE]> = (0..DIM)
            .map(|_| {
                let mut row = [0.0; STATE];
                for v in &mut row {
                    *v = rng.normal();
                }
                row
            })
            .collect();
        let y0: Vec<f64> = (0..DIM).map(|_| rng.range(-5.0, 5.0)).collect();
        TennesseePlant { a, b, c, y0, noise_y: 0.25, noise_x: 0.05 }
    }

    fn steady_state(&self) -> [f64; STATE] {
        // iterate x = A x + B u to convergence (u = 1)
        let mut x = [0.0; STATE];
        for _ in 0..500 {
            x = self.step_state(&x, 1.0, None);
        }
        x
    }

    fn step_state(&self, x: &[f64; STATE], u: f64, rng: Option<&mut Xoshiro256>) -> [f64; STATE] {
        let mut nx = [0.0; STATE];
        for i in 0..STATE {
            let mut s = self.b[i] * u;
            for j in 0..STATE {
                s += self.a[i][j] * x[j];
            }
            nx[i] = s;
        }
        if let Some(r) = rng {
            for v in &mut nx {
                *v += r.normal() * self.noise_x;
            }
        }
        nx
    }

    fn measure(&self, x: &[f64; STATE], rng: &mut Xoshiro256, noise_scale: f64) -> Vec<f64> {
        (0..DIM)
            .map(|i| {
                let mut s = self.y0[i];
                for j in 0..STATE {
                    s += self.c[i][j] * x[j];
                }
                s + rng.normal() * self.noise_y * noise_scale
            })
            .collect()
    }

    /// Simulate `n` observations of a run. `fault = None` for normal
    /// operation, `Some(1..=20)` for a fault mode active from step 0.
    pub fn simulate(&self, n: usize, fault: Option<usize>, seed: u64) -> Matrix {
        let mut rng = Xoshiro256::new(seed ^ 0x7EA5_0000);
        let mut x = self.steady_state();
        // fault configuration, deterministic in the fault id
        let (kind, mag, chan, freq) = match fault {
            None => (None, 0.0, 0, 0.0),
            Some(id) => {
                let mut frng = Xoshiro256::new(0xFA17 + id as u64);
                (
                    Some(fault_kind(id)),
                    frng.range(2.0, 5.0),
                    frng.index(DIM),
                    frng.range(0.05, 0.3),
                )
            }
        };
        let mut held = vec![0.0; DIM]; // analyzer ZOH register
        let mut rows = Vec::with_capacity(n);
        for k in 0..n {
            let u = match kind {
                Some(FaultKind::Step) => 1.0 + 0.4 * mag / 3.0,
                Some(FaultKind::Drift) => 1.0 + 0.002 * mag * k as f64 / 10.0,
                Some(FaultKind::Oscillation) => 1.0 + 0.3 * (freq * k as f64).sin(),
                _ => 1.0,
            };
            x = self.step_state(&x, u, Some(&mut rng));
            let noise_scale = match kind {
                Some(FaultKind::Variance) => 1.0 + mag,
                _ => 1.0,
            };
            let mut y = self.measure(&x, &mut rng, noise_scale);
            if let Some(FaultKind::Bias) = kind {
                y[chan] += mag * 2.0;
                y[(chan + 7) % DIM] -= mag;
            }
            // zero-order hold on analyzer channels
            if k % HOLD == 0 {
                held[CONTINUOUS..DIM].copy_from_slice(&y[CONTINUOUS..DIM]);
            }
            y[CONTINUOUS..DIM].copy_from_slice(&held[CONTINUOUS..DIM]);
            rows.push(y);
        }
        Matrix::from_rows(&rows).unwrap()
    }

    /// Training set: `n` normal-operation observations.
    pub fn training(&self, n: usize, seed: u64) -> Matrix {
        self.simulate(n, None, seed)
    }

    /// Scoring set: `n_normal` normal rows (label true) + `n_fault`
    /// rows spread across all twenty faults (label false), shuffled.
    pub fn scoring(&self, n_normal: usize, n_fault: usize, seed: u64) -> LabeledData {
        let normal = self.simulate(n_normal, None, seed ^ 0x0bb5);
        let per_fault = (n_fault / NUM_FAULTS).max(1);
        let mut rows: Vec<(Vec<f64>, bool)> = Vec::with_capacity(n_normal + n_fault);
        for i in 0..n_normal {
            rows.push((normal.row(i).to_vec(), true));
        }
        let mut added = 0;
        'outer: for id in 1..=NUM_FAULTS {
            let m = self.simulate(per_fault, Some(id), seed ^ (0xF000 + id as u64));
            for i in 0..m.rows() {
                rows.push((m.row(i).to_vec(), false));
                added += 1;
                if added >= n_fault {
                    break 'outer;
                }
            }
        }
        let mut rng = Xoshiro256::new(seed ^ 0x5473_F1E5); // shuffle salt
        let mut order: Vec<usize> = (0..rows.len()).collect();
        rng.shuffle(&mut order);
        let data = Matrix::from_rows(&order.iter().map(|&i| rows[i].0.clone()).collect::<Vec<_>>())
            .unwrap();
        let labels = order.iter().map(|&i| rows[i].1).collect();
        LabeledData::new(data, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{mean, std_dev};

    #[test]
    fn shapes_and_determinism() {
        let p = TennesseePlant::default();
        let t = p.training(200, 1);
        assert_eq!(t.rows(), 200);
        assert_eq!(t.cols(), DIM);
        assert_eq!(t, p.training(200, 1));
    }

    #[test]
    fn plant_is_stable() {
        // normal-run measurements stay bounded over a long horizon
        let p = TennesseePlant::default();
        let t = p.training(5000, 2);
        for v in t.as_slice() {
            assert!(v.is_finite() && v.abs() < 1e3, "unstable plant: {v}");
        }
    }

    #[test]
    fn analyzer_channels_are_zero_order_held() {
        let p = TennesseePlant::default();
        let t = p.training(40, 3);
        // within a hold window, analyzer channels are constant
        for k in 0..HOLD - 1 {
            for j in CONTINUOUS..DIM {
                assert_eq!(t.get(k, j), t.get(k + 1, j), "step {k} chan {j}");
            }
        }
        // continuous channels do change step to step
        assert_ne!(t.get(0, 0), t.get(1, 0));
        // and a new hold window latches new analyzer values
        assert_ne!(t.get(HOLD - 1, CONTINUOUS), t.get(HOLD, CONTINUOUS));
    }

    #[test]
    fn fault_kinds_partition_ids() {
        let mut counts = [0usize; 5];
        for id in 1..=NUM_FAULTS {
            counts[match fault_kind(id) {
                FaultKind::Step => 0,
                FaultKind::Drift => 1,
                FaultKind::Bias => 2,
                FaultKind::Oscillation => 3,
                FaultKind::Variance => 4,
            }] += 1;
        }
        assert_eq!(counts, [7, 5, 4, 2, 2]);
    }

    #[test]
    #[should_panic]
    fn fault_zero_rejected() {
        fault_kind(0);
    }

    #[test]
    fn every_fault_shifts_the_distribution() {
        let p = TennesseePlant::default();
        let normal = p.training(800, 4);
        let centroid = normal.col_means();
        let d_norm: Vec<f64> = (0..normal.rows())
            .map(|i| Matrix::sqdist(normal.row(i), &centroid).sqrt())
            .collect();
        let thresh = mean(&d_norm) + 2.0 * std_dev(&d_norm);
        for id in 1..=NUM_FAULTS {
            let m = p.simulate(300, Some(id), 5);
            // drop the first 50 rows: drifts take time to develop
            let d_fault: Vec<f64> = (50..m.rows())
                .map(|i| Matrix::sqdist(m.row(i), &centroid).sqrt())
                .collect();
            let frac_far = d_fault.iter().filter(|&&d| d > thresh).count() as f64
                / d_fault.len() as f64;
            assert!(
                frac_far > 0.10,
                "fault {id} ({:?}) indistinguishable: frac_far={frac_far}",
                fault_kind(id)
            );
        }
    }

    #[test]
    fn scoring_mix_has_both_labels() {
        let p = TennesseePlant::default();
        let sc = p.scoring(500, 400, 6);
        assert_eq!(sc.len(), 900);
        let n_norm = sc.num_normal();
        assert_eq!(n_norm, 500);
    }

    #[test]
    fn variance_fault_inflates_spread() {
        let p = TennesseePlant::default();
        let normal = p.training(1000, 7);
        let noisy = p.simulate(1000, Some(19), 7);
        let col = |m: &Matrix, j: usize| -> Vec<f64> {
            (0..m.rows()).map(|i| m.get(i, j)).collect()
        };
        // averaged over continuous channels, std must inflate clearly
        let mut ratio = 0.0;
        for j in 0..CONTINUOUS {
            ratio += std_dev(&col(&noisy, j)) / std_dev(&col(&normal, j));
        }
        ratio /= CONTINUOUS as f64;
        assert!(ratio > 1.5, "ratio={ratio}");
    }
}
