//! Data sets: seeded generators for every workload in the paper's
//! evaluation (Banana / Star / Two-Donut, random polygons, a Shuttle-like
//! 9-dim classification set and a Tennessee-Eastman-like process
//! simulator), a 200x200 scoring grid, and CSV I/O.
//!
//! All generators are deterministic in `(n, seed)` so every table and
//! figure regenerates bit-identically.

pub mod banana;
pub mod csv;
pub mod donut;
pub mod grid;
pub mod polygon;
pub mod shuttle;
pub mod star;
pub mod tennessee;

use crate::util::matrix::Matrix;

/// A deterministic data generator.
pub trait Generator {
    /// `n` observations with the given seed.
    fn generate(&self, n: usize, seed: u64) -> Matrix;
    /// Feature dimension of the generated data.
    fn dim(&self) -> usize;
    /// Stable name used by the CLI / config / bench registry.
    fn name(&self) -> &'static str;
}

/// Observations plus a normal/anomaly label (true = normal), for the
/// F1 experiments.
#[derive(Clone, Debug)]
pub struct LabeledData {
    pub data: Matrix,
    pub labels: Vec<bool>,
}

impl LabeledData {
    pub fn new(data: Matrix, labels: Vec<bool>) -> Self {
        assert_eq!(data.rows(), labels.len());
        LabeledData { data, labels }
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn num_normal(&self) -> usize {
        self.labels.iter().filter(|&&l| l).count()
    }
}

/// Look up a 2-d shape generator by name (CLI/bench registry).
pub fn shape_by_name(name: &str) -> Option<Box<dyn Generator + Send + Sync>> {
    match name {
        "banana" => Some(Box::new(banana::Banana::default())),
        "star" => Some(Box::new(star::Star::default())),
        "two-donut" | "twodonut" | "donut" => Some(Box::new(donut::TwoDonut::default())),
        _ => None,
    }
}

/// Names accepted by [`shape_by_name`], for help text.
pub const SHAPE_NAMES: &[&str] = &["banana", "star", "two-donut"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_all_names() {
        for name in SHAPE_NAMES {
            let g = shape_by_name(name).unwrap();
            assert_eq!(g.dim(), 2);
            let m = g.generate(50, 1);
            assert_eq!(m.rows(), 50);
        }
        assert!(shape_by_name("nope").is_none());
    }

    #[test]
    fn labeled_data_counts() {
        let m = Matrix::zeros(3, 1);
        let d = LabeledData::new(m, vec![true, false, true]);
        assert_eq!(d.len(), 3);
        assert_eq!(d.num_normal(), 2);
    }
}
