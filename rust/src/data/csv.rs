//! Tiny CSV reader/writer for result sinks and external data exchange.
//!
//! Deliberately minimal: numeric matrices with an optional header row.
//! Quoted fields are supported on read for robustness; writes never
//! need quoting (numbers only).

use crate::error::{Error, Result};
use crate::util::matrix::Matrix;
use std::io::Write;
use std::path::Path;

/// Write `data` as CSV with the given header row.
pub fn write_matrix(path: &Path, headers: &[&str], data: &Matrix) -> Result<()> {
    if !headers.is_empty() && headers.len() != data.cols() {
        return Err(Error::invalid(format!(
            "{} headers for {} columns",
            headers.len(),
            data.cols()
        )));
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    if !headers.is_empty() {
        writeln!(f, "{}", headers.join(","))?;
    }
    for i in 0..data.rows() {
        let row: Vec<String> = data.row(i).iter().map(|v| format!("{v}")).collect();
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Read a numeric CSV. `has_header` skips the first line. Returns the
/// matrix and the header names (empty if none).
pub fn read_matrix(path: &Path, has_header: bool) -> Result<(Matrix, Vec<String>)> {
    let text = std::fs::read_to_string(path)?;
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let headers: Vec<String> = if has_header {
        match lines.next() {
            Some(h) => split_line(h).into_iter().collect(),
            None => return Err(Error::invalid("empty csv")),
        }
    } else {
        Vec::new()
    };
    let mut rows: Vec<Vec<f64>> = Vec::new();
    for (ln, line) in lines.enumerate() {
        let mut row = Vec::new();
        for cell in split_line(line) {
            row.push(
                cell.trim()
                    .parse::<f64>()
                    .map_err(|_| Error::invalid(format!("line {}: bad number '{cell}'", ln + 1)))?,
            );
        }
        rows.push(row);
    }
    if rows.is_empty() {
        return Err(Error::invalid("csv has no data rows"));
    }
    Ok((Matrix::from_rows(&rows)?, headers))
}

fn split_line(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes && chars.peek() == Some(&'"') => {
                cur.push('"');
                chars.next();
            }
            '"' => in_quotes = !in_quotes,
            ',' if !in_quotes => {
                out.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    out.push(cur);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("fastsvdd_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_with_header() {
        let m = Matrix::from_rows(&[vec![1.0, -2.5], vec![3.25, 4.0]]).unwrap();
        let p = tmp("a.csv");
        write_matrix(&p, &["x", "y"], &m).unwrap();
        let (back, headers) = read_matrix(&p, true).unwrap();
        assert_eq!(back, m);
        assert_eq!(headers, vec!["x", "y"]);
    }

    #[test]
    fn roundtrip_without_header() {
        let m = Matrix::from_rows(&[vec![1e-7, 2e9]]).unwrap();
        let p = tmp("b.csv");
        write_matrix(&p, &[], &m).unwrap();
        let (back, headers) = read_matrix(&p, false).unwrap();
        assert_eq!(back, m);
        assert!(headers.is_empty());
    }

    #[test]
    fn header_count_mismatch_rejected() {
        let m = Matrix::zeros(1, 3);
        assert!(write_matrix(&tmp("c.csv"), &["only-one"], &m).is_err());
    }

    #[test]
    fn quoted_cells_parse() {
        let p = tmp("d.csv");
        std::fs::write(&p, "a,b\n\"1.5\",2\n").unwrap();
        let (m, h) = read_matrix(&p, true).unwrap();
        assert_eq!(h, vec!["a", "b"]);
        assert_eq!(m.row(0), &[1.5, 2.0]);
    }

    #[test]
    fn bad_number_rejected() {
        let p = tmp("e.csv");
        std::fs::write(&p, "1,hello\n").unwrap();
        assert!(read_matrix(&p, false).is_err());
    }

    #[test]
    fn empty_rejected() {
        let p = tmp("f.csv");
        std::fs::write(&p, "\n\n").unwrap();
        assert!(read_matrix(&p, false).is_err());
    }
}
