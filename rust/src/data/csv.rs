//! Tiny CSV reader/writer for result sinks and external data exchange.
//!
//! Deliberately minimal: numeric matrices with an optional header row.
//! Quoted fields are supported on read for robustness; writes never
//! need quoting (numbers only).

use crate::error::{Error, Result};
use crate::util::matrix::Matrix;
use std::io::{BufRead, Write};
use std::path::Path;

/// Write `data` as CSV with the given header row.
pub fn write_matrix(path: &Path, headers: &[&str], data: &Matrix) -> Result<()> {
    if !headers.is_empty() && headers.len() != data.cols() {
        return Err(Error::invalid(format!(
            "{} headers for {} columns",
            headers.len(),
            data.cols()
        )));
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    if !headers.is_empty() {
        writeln!(f, "{}", headers.join(","))?;
    }
    for i in 0..data.rows() {
        let row: Vec<String> = data.row(i).iter().map(|v| format!("{v}")).collect();
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Read a numeric CSV. `has_header` skips the first line. Returns the
/// matrix and the header names (empty if none).
pub fn read_matrix(path: &Path, has_header: bool) -> Result<(Matrix, Vec<String>)> {
    let text = std::fs::read_to_string(path)?;
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let headers: Vec<String> = if has_header {
        match lines.next() {
            Some(h) => split_line(h).into_iter().collect(),
            None => return Err(Error::invalid("empty csv")),
        }
    } else {
        Vec::new()
    };
    let mut rows: Vec<Vec<f64>> = Vec::new();
    for (ln, line) in lines.enumerate() {
        let mut row = Vec::new();
        for cell in split_line(line) {
            row.push(
                cell.trim()
                    .parse::<f64>()
                    .map_err(|_| Error::invalid(format!("line {}: bad number '{cell}'", ln + 1)))?,
            );
        }
        rows.push(row);
    }
    if rows.is_empty() {
        return Err(Error::invalid("csv has no data rows"));
    }
    Ok((Matrix::from_rows(&rows)?, headers))
}

/// Bounded streaming CSV reader: yields the numeric rows in chunks of
/// at most `chunk_rows`, so the distributed controller can ship shards
/// to workers without materialising the full dataset in memory. Blank
/// lines are skipped; cells parse exactly like [`read_matrix`], and a
/// row whose column count diverges from the first row's is rejected
/// (the whole-file reader catches that in `Matrix::from_rows`).
pub struct CsvChunks {
    lines: std::io::Lines<std::io::BufReader<std::fs::File>>,
    chunk_rows: usize,
    cols: Option<usize>,
    line_no: usize,
}

impl CsvChunks {
    /// Open `path`; `has_header` consumes the first non-blank line.
    pub fn open(path: &Path, has_header: bool, chunk_rows: usize) -> Result<CsvChunks> {
        if chunk_rows == 0 {
            return Err(Error::invalid("chunk_rows must be >= 1"));
        }
        let mut lines = std::io::BufReader::new(std::fs::File::open(path)?).lines();
        let mut line_no = 0;
        if has_header {
            loop {
                line_no += 1;
                match lines.next() {
                    Some(l) => {
                        if !l?.trim().is_empty() {
                            break;
                        }
                    }
                    None => return Err(Error::invalid("empty csv")),
                }
            }
        }
        Ok(CsvChunks { lines, chunk_rows, cols: None, line_no })
    }

    /// The next chunk of at most `chunk_rows` rows; `None` once the
    /// file is drained.
    pub fn next_chunk(&mut self) -> Result<Option<Matrix>> {
        let mut rows: Vec<Vec<f64>> = Vec::new();
        while rows.len() < self.chunk_rows {
            let line = match self.lines.next() {
                Some(l) => l?,
                None => break,
            };
            self.line_no += 1;
            if line.trim().is_empty() {
                continue;
            }
            let mut row = Vec::new();
            for cell in split_line(&line) {
                row.push(cell.trim().parse::<f64>().map_err(|_| {
                    Error::invalid(format!("line {}: bad number '{cell}'", self.line_no))
                })?);
            }
            if *self.cols.get_or_insert(row.len()) != row.len() {
                return Err(Error::invalid(format!(
                    "line {}: {} columns, expected {}",
                    self.line_no,
                    row.len(),
                    self.cols.unwrap_or(0)
                )));
            }
            rows.push(row);
        }
        if rows.is_empty() {
            return Ok(None);
        }
        Ok(Some(Matrix::from_rows(&rows)?))
    }
}

fn split_line(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes && chars.peek() == Some(&'"') => {
                cur.push('"');
                chars.next();
            }
            '"' => in_quotes = !in_quotes,
            ',' if !in_quotes => {
                out.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    out.push(cur);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("fastsvdd_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_with_header() {
        let m = Matrix::from_rows(&[vec![1.0, -2.5], vec![3.25, 4.0]]).unwrap();
        let p = tmp("a.csv");
        write_matrix(&p, &["x", "y"], &m).unwrap();
        let (back, headers) = read_matrix(&p, true).unwrap();
        assert_eq!(back, m);
        assert_eq!(headers, vec!["x", "y"]);
    }

    #[test]
    fn roundtrip_without_header() {
        let m = Matrix::from_rows(&[vec![1e-7, 2e9]]).unwrap();
        let p = tmp("b.csv");
        write_matrix(&p, &[], &m).unwrap();
        let (back, headers) = read_matrix(&p, false).unwrap();
        assert_eq!(back, m);
        assert!(headers.is_empty());
    }

    #[test]
    fn header_count_mismatch_rejected() {
        let m = Matrix::zeros(1, 3);
        assert!(write_matrix(&tmp("c.csv"), &["only-one"], &m).is_err());
    }

    #[test]
    fn quoted_cells_parse() {
        let p = tmp("d.csv");
        std::fs::write(&p, "a,b\n\"1.5\",2\n").unwrap();
        let (m, h) = read_matrix(&p, true).unwrap();
        assert_eq!(h, vec!["a", "b"]);
        assert_eq!(m.row(0), &[1.5, 2.0]);
    }

    #[test]
    fn bad_number_rejected() {
        let p = tmp("e.csv");
        std::fs::write(&p, "1,hello\n").unwrap();
        assert!(read_matrix(&p, false).is_err());
    }

    #[test]
    fn empty_rejected() {
        let p = tmp("f.csv");
        std::fs::write(&p, "\n\n").unwrap();
        assert!(read_matrix(&p, false).is_err());
    }

    #[test]
    fn chunked_read_matches_whole_file_read() {
        let rows: Vec<Vec<f64>> = (0..23).map(|i| vec![i as f64, -0.5 * i as f64]).collect();
        let m = Matrix::from_rows(&rows).unwrap();
        let p = tmp("g.csv");
        write_matrix(&p, &["x", "y"], &m).unwrap();

        let mut chunks = CsvChunks::open(&p, true, 5).unwrap();
        let mut sizes = Vec::new();
        let mut all: Vec<Vec<f64>> = Vec::new();
        while let Some(c) = chunks.next_chunk().unwrap() {
            sizes.push(c.rows());
            for i in 0..c.rows() {
                all.push(c.row(i).to_vec());
            }
        }
        assert_eq!(sizes, vec![5, 5, 5, 5, 3], "bounded chunks of at most chunk_rows");
        assert_eq!(Matrix::from_rows(&all).unwrap(), m);
        // drained: stays None
        assert!(chunks.next_chunk().unwrap().is_none());
    }

    #[test]
    fn chunked_read_skips_blank_lines_and_header() {
        let p = tmp("h.csv");
        std::fs::write(&p, "x,y\n\n1,2\n\n3,4\n").unwrap();
        let mut chunks = CsvChunks::open(&p, true, 10).unwrap();
        let c = chunks.next_chunk().unwrap().unwrap();
        assert_eq!(c.rows(), 2);
        assert_eq!(c.row(1), &[3.0, 4.0]);
        assert!(chunks.next_chunk().unwrap().is_none());
    }

    #[test]
    fn chunked_read_rejects_bad_input() {
        assert!(CsvChunks::open(&tmp("g.csv"), true, 0).is_err(), "zero chunk size");
        let p = tmp("i.csv");
        std::fs::write(&p, "1,2\n3,oops\n").unwrap();
        let mut chunks = CsvChunks::open(&p, false, 10).unwrap();
        assert!(chunks.next_chunk().is_err(), "bad number surfaces");
        let p = tmp("j.csv");
        std::fs::write(&p, "1,2\n3\n").unwrap();
        let mut chunks = CsvChunks::open(&p, false, 10).unwrap();
        assert!(chunks.next_chunk().is_err(), "ragged row surfaces");
    }
}
