//! Star-shaped data (paper Fig. 3b): uniform samples from the interior
//! of a five-pointed star polygon, built on the [`crate::data::polygon`]
//! substrate.

use crate::data::polygon::Polygon;
use crate::data::Generator;
use crate::util::matrix::Matrix;

#[derive(Clone, Copy, Debug)]
pub struct Star {
    /// Number of star points.
    pub points: usize,
    /// Outer vertex radius.
    pub r_outer: f64,
    /// Inner (concave) vertex radius.
    pub r_inner: f64,
}

impl Default for Star {
    fn default() -> Self {
        Star { points: 5, r_outer: 1.0, r_inner: 0.45 }
    }
}

impl Star {
    pub fn polygon(&self) -> Polygon {
        let k = self.points;
        let mut verts = Vec::with_capacity(2 * k);
        for i in 0..2 * k {
            let th = std::f64::consts::FRAC_PI_2 + i as f64 * std::f64::consts::PI / k as f64;
            let r = if i % 2 == 0 { self.r_outer } else { self.r_inner };
            verts.push((r * th.cos(), r * th.sin()));
        }
        Polygon::new(verts)
    }
}

impl Generator for Star {
    fn generate(&self, n: usize, seed: u64) -> Matrix {
        self.polygon().sample_interior(n, seed)
    }

    fn dim(&self) -> usize {
        2
    }

    fn name(&self) -> &'static str {
        "star"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_inside_star() {
        let g = Star::default();
        let poly = g.polygon();
        let m = g.generate(1500, 21);
        for i in 0..m.rows() {
            assert!(poly.contains(m.get(i, 0), m.get(i, 1)));
        }
    }

    #[test]
    fn star_is_concave() {
        // midpoint between two adjacent outer tips lies outside the star
        let g = Star::default();
        let poly = g.polygon();
        let v = poly.vertices();
        let mid = ((v[0].0 + v[2].0) / 2.0, (v[0].1 + v[2].1) / 2.0);
        assert!(!poly.contains(mid.0, mid.1), "star is not concave?");
    }

    #[test]
    fn ten_vertices_for_five_points() {
        assert_eq!(Star::default().polygon().num_vertices(), 10);
    }

    #[test]
    fn deterministic() {
        let g = Star::default();
        assert_eq!(g.generate(100, 1), g.generate(100, 1));
    }
}
