//! Batched kernel-compute substrate: cached squared row norms and a
//! tile-blocked panel-dot microkernel.
//!
//! Every kernel the library evaluates reduces to row dot products:
//!
//! ```text
//! gaussian    K_ij = exp(-||a_i - b_j||^2 / (2 s^2))
//!                  = exp(-((||a_i||^2 - a_i.b_j) + (||b_j||^2 - a_i.b_j)) / (2 s^2))
//! linear      K_ij = a_i . b_j
//! polynomial  K_ij = (a_i . b_j + c)^d
//! ```
//!
//! The per-pair scalar path ([`crate::svdd::Kernel::eval`]) re-derives
//! `||a - b||^2` with a latency-bound subtract-square-accumulate loop on
//! every call. This module instead caches `||x||^2` per row once
//! ([`NormCache`]) and evaluates whole panels of pairwise dots with a
//! fixed-order unrolled kernel ([`dot_block`]) the compiler can
//! vectorize — turning Gram construction, SMO kernel columns and batch
//! scoring into GEMM-shaped row-panel sweeps.
//!
//! ## Determinism policy
//!
//! Every entry a block path produces is a **pure function of the two
//! rows involved**, independent of panel shape, tile boundaries, thread
//! count or which entry point asked:
//!
//! - [`dot`] fixes the per-pair summation order (4 interleaved
//!   accumulators combined as `(s0+s1)+(s2+s3)`, then the tail in
//!   order). [`dot_block`] and [`NormCache`] are defined in terms of it,
//!   so a dot computed inside a 1x1 panel equals the same dot inside a
//!   512-row panel, bit for bit.
//! - `dot(a, b) == dot(b, a)` exactly (per-term products commute, the
//!   summation order is positional), and the Gaussian combination
//!   `(na - d) + (nb - d)` is an IEEE addition of the same two values in
//!   either role — so block-path kernels are exactly symmetric, which is
//!   what lets the Gram triangle mirror and the SMO column path agree
//!   bitwise.
//! - The block value differs from the scalar [`crate::svdd::Kernel::eval`]
//!   reference only in summation order / algebraic form (ULP-level;
//!   property-tested to tight relative tolerance in
//!   `tests/property_tests.rs`). The scalar path remains the reference
//!   implementation and is never mixed into block-path outputs.
//!
//! The norm-cache form never squares a coordinate *difference*, so its
//! intermediates stay finite wherever the row norms do (coordinates up
//! to ~1e150 are exercised by the property tests); catastrophic
//! cancellation for near-identical rows is clamped at zero, which the
//! Gaussian maps to `K = 1` — the correct limit.

use crate::util::matrix::Matrix;

/// Rows of the `b` panel evaluated per register tile in [`dot_block`].
/// Small enough that a tile of `TILE_J` rows x 64 features stays in L1
/// alongside the streaming `a` row, large enough to amortize the loop
/// overhead.
pub const TILE_J: usize = 8;

/// Fixed-order unrolled dot product — **the** per-pair summation order
/// of the block compute layer. Four interleaved accumulators break the
/// add dependency chain (the scalar bottleneck), combined as
/// `(s0 + s1) + (s2 + s3)` plus an in-order tail; the order depends only
/// on the row length, never on panel or tile geometry.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len().min(b.len());
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let quads = n / 4;
    for q in 0..quads {
        let k = q * 4;
        s0 += a[k] * b[k];
        s1 += a[k + 1] * b[k + 1];
        s2 += a[k + 2] * b[k + 2];
        s3 += a[k + 3] * b[k + 3];
    }
    let mut tail = 0.0;
    for k in quads * 4..n {
        tail += a[k] * b[k];
    }
    ((s0 + s1) + (s2 + s3)) + tail
}

/// Squared distance from cached norms and a dot:
/// `||a - b||^2 = (||a||^2 - a.b) + (||b||^2 - a.b)`, clamped at zero
/// (cancellation for near-identical rows can go epsilon-negative).
/// Grouping the subtraction per operand keeps every intermediate within
/// a factor ~4 of the largest norm, so nothing overflows before the
/// true distance would.
///
/// Non-finite inputs are handled conservatively, not swallowed: a NaN
/// input propagates (`f64::max(NaN, 0.0)` would silently return 0,
/// i.e. "identical rows" — a corrupt row must never score as a deep
/// inlier), and an `inf - inf` from an overflowed norm resolves to
/// `+inf`, matching the scalar `||a-b||^2 = +inf` for distinct rows
/// (for the degenerate overflowed-norm *self*-pair, where the scalar
/// form gives 0, this errs on the saturate-to-outlier side).
#[inline]
pub fn sqdist_from_norms(na: f64, nb: f64, d: f64) -> f64 {
    let s = (na - d) + (nb - d);
    if s.is_nan() {
        if na.is_nan() || nb.is_nan() || d.is_nan() {
            return f64::NAN;
        }
        return f64::INFINITY;
    }
    s.max(0.0)
}

/// Cached squared euclidean norms `||x_i||^2` of every row of a matrix,
/// computed with [`dot`] so they combine bit-consistently with
/// [`dot_block`] panels.
#[derive(Clone, Debug, PartialEq)]
pub struct NormCache {
    norms: Vec<f64>,
}

impl NormCache {
    /// Compute all row norms of `m` (one pass, O(rows x cols)).
    pub fn new(m: &Matrix) -> NormCache {
        NormCache {
            norms: (0..m.rows()).map(|i| dot(m.row(i), m.row(i))).collect(),
        }
    }

    /// `||x_i||^2`.
    #[inline]
    pub fn get(&self, i: usize) -> f64 {
        self.norms[i]
    }

    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.norms
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.norms.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.norms.is_empty()
    }
}

/// Panel of pairwise dots: `out[ia * b_rows.len() + ib] =
/// dot(a.row(a_rows.start + ia), b.row(b_rows.start + ib))`, row-major
/// over the panel. Blocked over `b` in [`TILE_J`]-row tiles so a tile
/// stays cache-hot while the `a` rows stream past it; per-entry values
/// are exactly [`dot`] regardless of tiling (see the module's
/// determinism policy). Ragged shapes (1x1, 1xn, non-multiples of the
/// tile size, empty ranges) are all fine.
pub fn dot_block(
    a: &Matrix,
    a_rows: std::ops::Range<usize>,
    b: &Matrix,
    b_rows: std::ops::Range<usize>,
    out: &mut [f64],
) {
    let (a0, la) = (a_rows.start, a_rows.len());
    let (b0, lb) = (b_rows.start, b_rows.len());
    debug_assert_eq!(a.cols(), b.cols());
    debug_assert_eq!(out.len(), la * lb);
    let mut jt = 0;
    while jt < lb {
        let jt_end = (jt + TILE_J).min(lb);
        for ia in 0..la {
            let arow = a.row(a0 + ia);
            let row_out = &mut out[ia * lb..(ia + 1) * lb];
            for (jb, slot) in row_out.iter_mut().enumerate().take(jt_end).skip(jt) {
                *slot = dot(arow, b.row(b0 + jb));
            }
        }
        jt = jt_end;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn random(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Xoshiro256::new(seed);
        let r: Vec<Vec<f64>> = (0..rows)
            .map(|_| (0..cols).map(|_| rng.normal() * 2.0).collect())
            .collect();
        Matrix::from_rows(&r).unwrap()
    }

    /// Straight sequential dot — the order-free oracle (tolerance only).
    fn naive_dot(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    #[test]
    fn dot_matches_naive_to_tolerance() {
        let m = random(4, 23, 1);
        for i in 0..4 {
            for j in 0..4 {
                let got = dot(m.row(i), m.row(j));
                let want = naive_dot(m.row(i), m.row(j));
                assert!((got - want).abs() <= 1e-12 * want.abs().max(1.0));
            }
        }
    }

    #[test]
    fn dot_is_exactly_symmetric() {
        // every lengths class: multiple of 4, remainder 1..3, tiny
        for cols in [1usize, 2, 3, 4, 5, 7, 8, 41] {
            let m = random(6, cols, cols as u64);
            for i in 0..6 {
                for j in 0..6 {
                    let ab = dot(m.row(i), m.row(j));
                    let ba = dot(m.row(j), m.row(i));
                    assert_eq!(ab.to_bits(), ba.to_bits(), "cols={cols} i={i} j={j}");
                }
            }
        }
    }

    #[test]
    fn norm_cache_equals_self_dot() {
        let m = random(17, 5, 3);
        let nc = NormCache::new(&m);
        assert_eq!(nc.len(), 17);
        for i in 0..17 {
            assert_eq!(nc.get(i).to_bits(), dot(m.row(i), m.row(i)).to_bits());
            assert!(nc.get(i) >= 0.0);
        }
    }

    #[test]
    fn norm_cache_empty_matrix() {
        let m = Matrix::zeros(0, 3);
        let nc = NormCache::new(&m);
        assert!(nc.is_empty());
        assert_eq!(nc.as_slice().len(), 0);
    }

    #[test]
    fn dot_block_matches_per_pair_dot_bitwise() {
        let a = random(13, 7, 5);
        let b = random(21, 7, 6);
        // ragged panels around the tile size, including 1x1 and empty
        for (ar, br) in [
            (0..13, 0..21),
            (2..3, 0..1),
            (0..1, 0..21),
            (5..13, 3..20),
            (0..0, 0..21),
            (0..13, 4..4),
        ] {
            let mut out = vec![f64::NAN; ar.len() * br.len()];
            dot_block(&a, ar.clone(), &b, br.clone(), &mut out);
            for (ia, i) in ar.clone().enumerate() {
                for (jb, j) in br.clone().enumerate() {
                    let want = dot(a.row(i), b.row(j));
                    assert_eq!(
                        out[ia * br.len() + jb].to_bits(),
                        want.to_bits(),
                        "panel ({ar:?},{br:?}) entry ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn sqdist_from_norms_matches_difference_form() {
        let m = random(9, 6, 9);
        let nc = NormCache::new(&m);
        for i in 0..9 {
            for j in 0..9 {
                let d = dot(m.row(i), m.row(j));
                let got = sqdist_from_norms(nc.get(i), nc.get(j), d);
                let want = Matrix::sqdist(m.row(i), m.row(j));
                assert!(
                    (got - want).abs() <= 1e-10 * want.max(1.0),
                    "({i},{j}): {got} vs {want}"
                );
                assert!(got >= 0.0);
            }
        }
    }

    #[test]
    fn sqdist_from_norms_identical_rows_exactly_zero() {
        let m = random(4, 11, 13);
        let nc = NormCache::new(&m);
        for i in 0..4 {
            let d = dot(m.row(i), m.row(i));
            assert_eq!(sqdist_from_norms(nc.get(i), nc.get(i), d), 0.0);
        }
    }

    #[test]
    fn sqdist_from_norms_extreme_coordinates_stay_finite() {
        // +-1e150 coordinates: ||x||^2 ~ 4e300 is representable; the
        // grouped form never exceeds ~4x the largest norm.
        let m = Matrix::from_rows(&[
            vec![1e150, -1e150, 1e150, -1e150],
            vec![-1e150, 1e150, -1e150, 1e150],
            vec![1e150, 1e150, 1e150, 1e150],
        ])
        .unwrap();
        let nc = NormCache::new(&m);
        for i in 0..3 {
            assert!(nc.get(i).is_finite());
            for j in 0..3 {
                let d = dot(m.row(i), m.row(j));
                let s = sqdist_from_norms(nc.get(i), nc.get(j), d);
                assert!(s.is_finite(), "({i},{j}) overflowed: {s}");
                assert!(s >= 0.0);
            }
        }
    }
}
