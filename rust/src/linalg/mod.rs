//! Batched kernel-compute substrate: cached squared row norms and a
//! tile-blocked panel-dot microkernel with runtime-dispatched
//! explicit-SIMD arms.
//!
//! Every kernel the library evaluates reduces to row dot products:
//!
//! ```text
//! gaussian    K_ij = exp(-||a_i - b_j||^2 / (2 s^2))
//!                  = exp(-((||a_i||^2 - a_i.b_j) + (||b_j||^2 - a_i.b_j)) / (2 s^2))
//! linear      K_ij = a_i . b_j
//! polynomial  K_ij = (a_i . b_j + c)^d
//! ```
//!
//! The per-pair scalar path ([`crate::svdd::Kernel::eval`]) re-derives
//! `||a - b||^2` with a latency-bound subtract-square-accumulate loop on
//! every call. This module instead caches `||x||^2` per row once
//! ([`NormCache`]) and evaluates whole panels of pairwise dots with a
//! fixed-order microkernel ([`dot_block`]) — turning Gram construction,
//! SMO kernel columns and batch scoring into GEMM-shaped row-panel
//! sweeps.
//!
//! ## ISA dispatch
//!
//! [`dot`], [`dot_block`], [`NormCache`] and the f32 panel path
//! dispatch at runtime (see [`isa`]) to one of: the portable unrolled
//! scalar loop (the reference), an x86_64 AVX2 arm, an x86_64 AVX2+FMA
//! arm, or an aarch64 NEON arm. The AVX2 and NEON f64 arms reproduce
//! the scalar summation order **bit for bit** (see `simd.rs` for the
//! lane mapping), so auto-dispatch never changes a result — only FMA
//! (explicitly requested) relaxes bit-identity by fusing each
//! multiply-add into one rounding. Arm-forcing entry points
//! ([`dot_on`], [`dot_block_on`], [`dot_f32_on`]) exist so tests and
//! benches can pin arms regardless of the global selection.
//!
//! ## Determinism policy
//!
//! Every entry a block path produces is a **pure function of the two
//! rows involved**, independent of panel shape, tile boundaries, thread
//! count or which entry point asked:
//!
//! - [`dot`] fixes the per-pair summation order (4 interleaved
//!   accumulators combined as `(s0+s1)+(s2+s3)`, then the tail in
//!   order). [`dot_block`] and [`NormCache`] are defined in terms of it,
//!   so a dot computed inside a 1x1 panel equals the same dot inside a
//!   512-row panel, bit for bit — on every bit-identical arm.
//! - `dot(a, b) == dot(b, a)` exactly (per-term products commute, the
//!   summation order is positional), and the Gaussian combination
//!   `(na - d) + (nb - d)` is an IEEE addition of the same two values in
//!   either role — so block-path kernels are exactly symmetric, which is
//!   what lets the Gram triangle mirror and the SMO column path agree
//!   bitwise.
//! - The block value differs from the scalar [`crate::svdd::Kernel::eval`]
//!   reference only in summation order / algebraic form (ULP-level;
//!   property-tested to tight relative tolerance in
//!   `tests/property_tests.rs`). The scalar path remains the reference
//!   implementation and is never mixed into block-path outputs.
//!
//! The norm-cache form never squares a coordinate *difference*, so its
//! intermediates stay finite wherever the row norms do (coordinates up
//! to ~1e150 are exercised by the property tests); catastrophic
//! cancellation for near-identical rows is clamped at zero, which the
//! Gaussian maps to `K = 1` — the correct limit.
//!
//! ## Opt-in f32 panels
//!
//! [`dot_f32`] / [`dot_block_f32`] / [`norms_f32`] mirror the f64 API
//! over flat `f32` buffers for the `--precision f32` scoring path and
//! the XLA/AOT boundary (which is f32 end to end). f32 results are
//! **never** bit-compared against f64 — the contract is a relative
//! error bound only: for rows of length `n`, the dot error is at most
//! `(n + 2) * 2^-24 * sum_k |a_k * b_k|` (n−1 adds + 1 product rounding
//! per term + the f64→f32 input conversions), property-tested in
//! `tests/simd_dispatch.rs`. Within f32, all mul+add arms (scalar
//! 8-accumulator reference, AVX2, NEON) share one summation order and
//! stay bit-identical to each other.

use crate::util::matrix::Matrix;

pub mod isa;
pub(crate) mod simd;

pub use isa::Isa;

/// Rows of the `b` panel evaluated per register tile in [`dot_block`].
/// Small enough that a tile of `TILE_J` rows x 64 features stays in L1
/// alongside the streaming `a` row, large enough to amortize the loop
/// overhead.
pub const TILE_J: usize = 8;

/// Portable unrolled dot product — **the** per-pair summation order of
/// the block compute layer and the reference every SIMD arm is measured
/// against. Four interleaved accumulators break the add dependency
/// chain (the scalar bottleneck), combined as `(s0 + s1) + (s2 + s3)`
/// plus an in-order tail; the order depends only on the row length,
/// never on panel or tile geometry.
#[inline]
pub fn dot_scalar(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len().min(b.len());
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let quads = n / 4;
    for q in 0..quads {
        let k = q * 4;
        s0 += a[k] * b[k];
        s1 += a[k + 1] * b[k + 1];
        s2 += a[k + 2] * b[k + 2];
        s3 += a[k + 3] * b[k + 3];
    }
    let mut tail = 0.0;
    for k in quads * 4..n {
        tail += a[k] * b[k];
    }
    ((s0 + s1) + (s2 + s3)) + tail
}

/// Runtime-dispatched fixed-order dot product (see [`dot_scalar`] for
/// the summation order, [`isa`] for arm selection).
///
/// # Length contract
///
/// `a` and `b` must be the same length: mismatched rows are a caller
/// bug and **panic in debug builds**. Release builds do not pay for the
/// check; they truncate to the shorter row (every arm clamps its reads
/// to `min(a.len(), b.len())`, so the release behavior is memory-safe
/// and deterministic — but still a bug upstream).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(
        a.len(),
        b.len(),
        "linalg::dot: row length mismatch ({} vs {}); release builds truncate to the shorter row",
        a.len(),
        b.len()
    );
    match isa::selected() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: isa::selected() only returns Avx2/Fma after runtime
        // CPU feature detection confirmed them on this host.
        Isa::Avx2 => unsafe { simd::avx2::dot(a, b) },
        #[cfg(target_arch = "x86_64")]
        Isa::Fma => unsafe { simd::fma::dot(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is part of the aarch64 baseline.
        Isa::Neon => unsafe { simd::neon::dot(a, b) },
        _ => dot_scalar(a, b),
    }
}

/// [`dot`] forced onto a specific arm — test/bench hook, bypassing the
/// global selection. `Auto` means "whatever [`isa::selected`] says".
///
/// # Panics
///
/// If `which` is not available on this host ([`Isa::available`]).
pub fn dot_on(which: Isa, a: &[f64], b: &[f64]) -> f64 {
    assert!(
        which.available(),
        "isa '{which}' is not available on this host"
    );
    match which {
        Isa::Scalar => dot_scalar(a, b),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: availability asserted above.
        Isa::Avx2 => unsafe { simd::avx2::dot(a, b) },
        #[cfg(target_arch = "x86_64")]
        Isa::Fma => unsafe { simd::fma::dot(a, b) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { simd::neon::dot(a, b) },
        _ => dot(a, b),
    }
}

/// Squared distance from cached norms and a dot:
/// `||a - b||^2 = (||a||^2 - a.b) + (||b||^2 - a.b)`, clamped at zero
/// (cancellation for near-identical rows can go epsilon-negative).
/// Grouping the subtraction per operand keeps every intermediate within
/// a factor ~4 of the largest norm, so nothing overflows before the
/// true distance would.
///
/// Non-finite inputs are handled conservatively, not swallowed: a NaN
/// input propagates (`f64::max(NaN, 0.0)` would silently return 0,
/// i.e. "identical rows" — a corrupt row must never score as a deep
/// inlier), and an `inf - inf` from an overflowed norm resolves to
/// `+inf`, matching the scalar `||a-b||^2 = +inf` for distinct rows
/// (for the degenerate overflowed-norm *self*-pair, where the scalar
/// form gives 0, this errs on the saturate-to-outlier side).
#[inline]
pub fn sqdist_from_norms(na: f64, nb: f64, d: f64) -> f64 {
    let s = (na - d) + (nb - d);
    if s.is_nan() {
        if na.is_nan() || nb.is_nan() || d.is_nan() {
            return f64::NAN;
        }
        return f64::INFINITY;
    }
    s.max(0.0)
}

/// Cached squared euclidean norms `||x_i||^2` of every row of a matrix,
/// computed with [`dot`] so they combine bit-consistently with
/// [`dot_block`] panels (and, because every bit-identical arm agrees
/// with the scalar reference, identically under any dispatched arm).
#[derive(Clone, Debug, PartialEq)]
pub struct NormCache {
    norms: Vec<f64>,
}

impl NormCache {
    /// Compute all row norms of `m` (one pass, O(rows x cols)).
    pub fn new(m: &Matrix) -> NormCache {
        NormCache {
            norms: (0..m.rows()).map(|i| dot(m.row(i), m.row(i))).collect(),
        }
    }

    /// `||x_i||^2`.
    #[inline]
    pub fn get(&self, i: usize) -> f64 {
        self.norms[i]
    }

    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.norms
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.norms.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.norms.is_empty()
    }
}

/// Panel of pairwise dots: `out[ia * b_rows.len() + ib] =
/// dot(a.row(a_rows.start + ia), b.row(b_rows.start + ib))`, row-major
/// over the panel. Blocked over `b` in [`TILE_J`]-row tiles so a tile
/// stays cache-hot while the `a` rows stream past it; per-entry values
/// are exactly [`dot`] regardless of tiling or dispatched arm (see the
/// module's determinism policy). Ragged shapes (1x1, 1xn, non-multiples
/// of the tile size, empty ranges) are all fine.
///
/// Dispatches once per panel, so the SIMD arms keep their whole inner
/// loop inside one `#[target_feature]` region.
pub fn dot_block(
    a: &Matrix,
    a_rows: std::ops::Range<usize>,
    b: &Matrix,
    b_rows: std::ops::Range<usize>,
    out: &mut [f64],
) {
    debug_assert_eq!(a.cols(), b.cols());
    debug_assert_eq!(out.len(), a_rows.len() * b_rows.len());
    match isa::selected() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: isa::selected() only returns Avx2/Fma after runtime
        // CPU feature detection confirmed them on this host.
        Isa::Avx2 => unsafe { simd::avx2::dot_block(a, a_rows, b, b_rows, out) },
        #[cfg(target_arch = "x86_64")]
        Isa::Fma => unsafe { simd::fma::dot_block(a, a_rows, b, b_rows, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is part of the aarch64 baseline.
        Isa::Neon => unsafe { simd::neon::dot_block(a, a_rows, b, b_rows, out) },
        _ => dot_block_scalar(a, a_rows, b, b_rows, out),
    }
}

/// [`dot_block`] forced onto a specific arm — test/bench hook. `Auto`
/// means "whatever [`isa::selected`] says".
///
/// # Panics
///
/// If `which` is not available on this host ([`Isa::available`]).
pub fn dot_block_on(
    which: Isa,
    a: &Matrix,
    a_rows: std::ops::Range<usize>,
    b: &Matrix,
    b_rows: std::ops::Range<usize>,
    out: &mut [f64],
) {
    assert!(
        which.available(),
        "isa '{which}' is not available on this host"
    );
    match which {
        Isa::Scalar => dot_block_scalar(a, a_rows, b, b_rows, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: availability asserted above.
        Isa::Avx2 => unsafe { simd::avx2::dot_block(a, a_rows, b, b_rows, out) },
        #[cfg(target_arch = "x86_64")]
        Isa::Fma => unsafe { simd::fma::dot_block(a, a_rows, b, b_rows, out) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { simd::neon::dot_block(a, a_rows, b, b_rows, out) },
        _ => dot_block(a, a_rows, b, b_rows, out),
    }
}

/// The portable panel walk (also the body of the `scalar` arm): tiles
/// `b` in [`TILE_J`]-row blocks, evaluates each pair with
/// [`dot_scalar`].
fn dot_block_scalar(
    a: &Matrix,
    a_rows: std::ops::Range<usize>,
    b: &Matrix,
    b_rows: std::ops::Range<usize>,
    out: &mut [f64],
) {
    let (a0, la) = (a_rows.start, a_rows.len());
    let (b0, lb) = (b_rows.start, b_rows.len());
    let mut jt = 0;
    while jt < lb {
        let jt_end = (jt + TILE_J).min(lb);
        for ia in 0..la {
            let arow = a.row(a0 + ia);
            let row_out = &mut out[ia * lb..(ia + 1) * lb];
            for (jb, slot) in row_out.iter_mut().enumerate().take(jt_end).skip(jt) {
                *slot = dot_scalar(arow, b.row(b0 + jb));
            }
        }
        jt = jt_end;
    }
}

// ---------------------------------------------------------------------
// Opt-in f32 panel path (`--precision f32`; also the layout the XLA/AOT
// boundary consumes). Tolerance-only contract vs f64 — see the module
// docs for the error bound.
// ---------------------------------------------------------------------

/// Fixed-order f32 reference dot: eight interleaved accumulators
/// combined `((s0+s1)+(s2+s3)) + ((s4+s5)+(s6+s7))` plus an in-order
/// tail — the lane layout of one AVX2 `f32x8` accumulator (or two NEON
/// `f32x4`), so the non-fused SIMD f32 arms are bit-identical to this
/// reference.
#[inline]
pub fn dot_f32_scalar(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let mut s = [0.0f32; 8];
    let octs = n / 8;
    for o in 0..octs {
        let k = o * 8;
        for (l, sl) in s.iter_mut().enumerate() {
            *sl += a[k + l] * b[k + l];
        }
    }
    let mut tail = 0.0f32;
    for k in octs * 8..n {
        tail += a[k] * b[k];
    }
    (((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7]))) + tail
}

/// Runtime-dispatched f32 dot (same length contract as [`dot`]).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(
        a.len(),
        b.len(),
        "linalg::dot_f32: row length mismatch ({} vs {}); release builds truncate",
        a.len(),
        b.len()
    );
    match isa::selected() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: isa::selected() only returns Avx2/Fma after runtime
        // CPU feature detection confirmed them on this host.
        Isa::Avx2 => unsafe { simd::avx2::dot_f32(a, b) },
        #[cfg(target_arch = "x86_64")]
        Isa::Fma => unsafe { simd::fma::dot_f32(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is part of the aarch64 baseline.
        Isa::Neon => unsafe { simd::neon::dot_f32(a, b) },
        _ => dot_f32_scalar(a, b),
    }
}

/// [`dot_f32`] forced onto a specific arm — test/bench hook.
///
/// # Panics
///
/// If `which` is not available on this host ([`Isa::available`]).
pub fn dot_f32_on(which: Isa, a: &[f32], b: &[f32]) -> f32 {
    assert!(
        which.available(),
        "isa '{which}' is not available on this host"
    );
    match which {
        Isa::Scalar => dot_f32_scalar(a, b),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: availability asserted above.
        Isa::Avx2 => unsafe { simd::avx2::dot_f32(a, b) },
        #[cfg(target_arch = "x86_64")]
        Isa::Fma => unsafe { simd::fma::dot_f32(a, b) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { simd::neon::dot_f32(a, b) },
        _ => dot_f32(a, b),
    }
}

/// f32 panel of pairwise dots over flat row-major buffers: `a` is
/// `ra x cols`, `b` is `rb x cols`, `out[ia * rb + ib] =
/// dot_f32(a_row(ia), b_row(ib))`. Same tiling and per-entry purity as
/// [`dot_block`]; dispatches once per panel.
pub fn dot_block_f32(a: &[f32], b: &[f32], cols: usize, out: &mut [f32]) {
    if cols == 0 {
        debug_assert!(out.is_empty());
        return;
    }
    debug_assert_eq!(a.len() % cols, 0);
    debug_assert_eq!(b.len() % cols, 0);
    debug_assert_eq!(out.len(), (a.len() / cols) * (b.len() / cols));
    match isa::selected() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: isa::selected() only returns Avx2/Fma after runtime
        // CPU feature detection confirmed them on this host.
        Isa::Avx2 => unsafe { simd::avx2::dot_block_f32(a, b, cols, out) },
        #[cfg(target_arch = "x86_64")]
        Isa::Fma => unsafe { simd::fma::dot_block_f32(a, b, cols, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is part of the aarch64 baseline.
        Isa::Neon => unsafe { simd::neon::dot_block_f32(a, b, cols, out) },
        _ => dot_block_f32_scalar(a, b, cols, out),
    }
}

/// The portable f32 panel walk (the `scalar` arm of
/// [`dot_block_f32`]).
fn dot_block_f32_scalar(a: &[f32], b: &[f32], cols: usize, out: &mut [f32]) {
    let ra = a.len() / cols;
    let rb = b.len() / cols;
    let mut jt = 0;
    while jt < rb {
        let jt_end = (jt + TILE_J).min(rb);
        for ia in 0..ra {
            let arow = &a[ia * cols..(ia + 1) * cols];
            let row_out = &mut out[ia * rb..(ia + 1) * rb];
            for (j, slot) in row_out.iter_mut().enumerate().take(jt_end).skip(jt) {
                *slot = dot_f32_scalar(arow, &b[j * cols..(j + 1) * cols]);
            }
        }
        jt = jt_end;
    }
}

/// Row norms `||x_i||^2` of a flat row-major f32 buffer, computed with
/// [`dot_f32`] so they combine consistently with [`dot_block_f32`]
/// panels.
pub fn norms_f32(data: &[f32], cols: usize) -> Vec<f32> {
    if cols == 0 {
        return Vec::new();
    }
    debug_assert_eq!(data.len() % cols, 0);
    (0..data.len() / cols)
        .map(|i| {
            let row = &data[i * cols..(i + 1) * cols];
            dot_f32(row, row)
        })
        .collect()
}

/// f32 mirror of [`sqdist_from_norms`]: same grouping, same clamp, same
/// NaN / `inf - inf` policy.
#[inline]
pub fn sqdist_from_norms_f32(na: f32, nb: f32, d: f32) -> f32 {
    let s = (na - d) + (nb - d);
    if s.is_nan() {
        if na.is_nan() || nb.is_nan() || d.is_nan() {
            return f32::NAN;
        }
        return f32::INFINITY;
    }
    s.max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn random(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Xoshiro256::new(seed);
        let r: Vec<Vec<f64>> = (0..rows)
            .map(|_| (0..cols).map(|_| rng.normal() * 2.0).collect())
            .collect();
        Matrix::from_rows(&r).unwrap()
    }

    /// Straight sequential dot — the order-free oracle (tolerance only).
    fn naive_dot(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    #[test]
    fn dot_matches_naive_to_tolerance() {
        let m = random(4, 23, 1);
        for i in 0..4 {
            for j in 0..4 {
                let got = dot(m.row(i), m.row(j));
                let want = naive_dot(m.row(i), m.row(j));
                assert!((got - want).abs() <= 1e-12 * want.abs().max(1.0));
            }
        }
    }

    #[test]
    fn dot_is_exactly_symmetric() {
        // every lengths class: multiple of 4, remainder 1..3, tiny
        for cols in [1usize, 2, 3, 4, 5, 7, 8, 41] {
            let m = random(6, cols, cols as u64);
            for i in 0..6 {
                for j in 0..6 {
                    let ab = dot(m.row(i), m.row(j));
                    let ba = dot(m.row(j), m.row(i));
                    assert_eq!(ab.to_bits(), ba.to_bits(), "cols={cols} i={i} j={j}");
                }
            }
        }
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "row length mismatch")]
    fn dot_mismatched_lengths_panics_in_debug() {
        let a = [1.0, 2.0, 3.0];
        let b = [1.0, 2.0];
        let _ = dot(&a, &b);
    }

    #[test]
    fn dot_scalar_release_contract_truncates_to_shorter_row() {
        // The documented release behavior of the length contract: every
        // arm clamps reads to min(len). Exercised via the scalar
        // reference directly (the dispatched `dot` debug-panics first
        // in test builds, by design).
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [2.0, 2.0, 2.0];
        assert_eq!(dot_scalar(&a, &b), 12.0);
        assert_eq!(dot_scalar(&b, &a), 12.0);
    }

    #[test]
    fn dispatched_dot_matches_scalar_reference_bitwise() {
        // Whatever arm the host auto-selects (never FMA) must agree
        // with the scalar reference bit for bit, on every length class.
        for cols in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 41, 64, 65] {
            let m = random(4, cols.max(1), 7 + cols as u64);
            for i in 0..4 {
                for j in 0..4 {
                    let a = &m.row(i)[..cols.min(m.cols())];
                    let b = &m.row(j)[..cols.min(m.cols())];
                    let want = dot_scalar(a, b);
                    let got = dot(a, b);
                    assert_eq!(got.to_bits(), want.to_bits(), "cols={cols} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn norm_cache_equals_self_dot() {
        let m = random(17, 5, 3);
        let nc = NormCache::new(&m);
        assert_eq!(nc.len(), 17);
        for i in 0..17 {
            assert_eq!(nc.get(i).to_bits(), dot(m.row(i), m.row(i)).to_bits());
            assert!(nc.get(i) >= 0.0);
        }
    }

    #[test]
    fn norm_cache_empty_matrix() {
        let m = Matrix::zeros(0, 3);
        let nc = NormCache::new(&m);
        assert!(nc.is_empty());
        assert_eq!(nc.as_slice().len(), 0);
    }

    #[test]
    fn dot_block_matches_per_pair_dot_bitwise() {
        let a = random(13, 7, 5);
        let b = random(21, 7, 6);
        // ragged panels around the tile size, including 1x1 and empty
        for (ar, br) in [
            (0..13, 0..21),
            (2..3, 0..1),
            (0..1, 0..21),
            (5..13, 3..20),
            (0..0, 0..21),
            (0..13, 4..4),
        ] {
            let mut out = vec![f64::NAN; ar.len() * br.len()];
            dot_block(&a, ar.clone(), &b, br.clone(), &mut out);
            for (ia, i) in ar.clone().enumerate() {
                for (jb, j) in br.clone().enumerate() {
                    let want = dot(a.row(i), b.row(j));
                    assert_eq!(
                        out[ia * br.len() + jb].to_bits(),
                        want.to_bits(),
                        "panel ({ar:?},{br:?}) entry ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn sqdist_from_norms_matches_difference_form() {
        let m = random(9, 6, 9);
        let nc = NormCache::new(&m);
        for i in 0..9 {
            for j in 0..9 {
                let d = dot(m.row(i), m.row(j));
                let got = sqdist_from_norms(nc.get(i), nc.get(j), d);
                let want = Matrix::sqdist(m.row(i), m.row(j));
                assert!(
                    (got - want).abs() <= 1e-10 * want.max(1.0),
                    "({i},{j}): {got} vs {want}"
                );
                assert!(got >= 0.0);
            }
        }
    }

    #[test]
    fn sqdist_from_norms_identical_rows_exactly_zero() {
        let m = random(4, 11, 13);
        let nc = NormCache::new(&m);
        for i in 0..4 {
            let d = dot(m.row(i), m.row(i));
            assert_eq!(sqdist_from_norms(nc.get(i), nc.get(i), d), 0.0);
        }
    }

    #[test]
    fn sqdist_from_norms_extreme_coordinates_stay_finite() {
        // +-1e150 coordinates: ||x||^2 ~ 4e300 is representable; the
        // grouped form never exceeds ~4x the largest norm.
        let m = Matrix::from_rows(&[
            vec![1e150, -1e150, 1e150, -1e150],
            vec![-1e150, 1e150, -1e150, 1e150],
            vec![1e150, 1e150, 1e150, 1e150],
        ])
        .unwrap();
        let nc = NormCache::new(&m);
        for i in 0..3 {
            assert!(nc.get(i).is_finite());
            for j in 0..3 {
                let d = dot(m.row(i), m.row(j));
                let s = sqdist_from_norms(nc.get(i), nc.get(j), d);
                assert!(s.is_finite(), "({i},{j}) overflowed: {s}");
                assert!(s >= 0.0);
            }
        }
    }

    #[test]
    fn dot_f32_dispatch_matches_f32_reference_bitwise() {
        let mut rng = Xoshiro256::new(42);
        for cols in [0usize, 1, 3, 7, 8, 9, 16, 41, 65] {
            let a: Vec<f32> = (0..cols).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..cols).map(|_| rng.normal() as f32).collect();
            let want = dot_f32_scalar(&a, &b);
            let got = dot_f32(&a, &b);
            assert_eq!(got.to_bits(), want.to_bits(), "cols={cols}");
        }
    }

    #[test]
    fn dot_block_f32_matches_per_pair_bitwise() {
        let mut rng = Xoshiro256::new(43);
        let (ra, rb, cols) = (5, 11, 9);
        let a: Vec<f32> = (0..ra * cols).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..rb * cols).map(|_| rng.normal() as f32).collect();
        let mut out = vec![f32::NAN; ra * rb];
        dot_block_f32(&a, &b, cols, &mut out);
        for i in 0..ra {
            for j in 0..rb {
                let want = dot_f32(&a[i * cols..(i + 1) * cols], &b[j * cols..(j + 1) * cols]);
                assert_eq!(out[i * rb + j].to_bits(), want.to_bits(), "({i},{j})");
            }
        }
    }

    #[test]
    fn norms_f32_and_sqdist_f32_mirror_f64_semantics() {
        let mut rng = Xoshiro256::new(44);
        let (rows, cols) = (6, 5);
        let data: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32).collect();
        let norms = norms_f32(&data, cols);
        assert_eq!(norms.len(), rows);
        for (i, &nrm) in norms.iter().enumerate() {
            let row = &data[i * cols..(i + 1) * cols];
            assert_eq!(nrm.to_bits(), dot_f32(row, row).to_bits());
            // identical rows -> exactly zero, same clamp as f64
            assert_eq!(sqdist_from_norms_f32(nrm, nrm, nrm), 0.0);
        }
        assert!(sqdist_from_norms_f32(f32::NAN, 1.0, 0.5).is_nan());
        assert_eq!(
            sqdist_from_norms_f32(f32::INFINITY, f32::INFINITY, f32::INFINITY),
            f32::INFINITY
        );
        assert!(norms_f32(&[], 0).is_empty());
    }
}
