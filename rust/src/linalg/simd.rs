//! Explicit-SIMD arms of the kernel microkernel (`core::arch`), chosen
//! at runtime by [`super::isa`]. Everything here is `unsafe fn` gated
//! on `#[target_feature]`; the safe dispatchers in [`super`] only call
//! an arm after [`super::isa::Isa::available`] confirmed the host
//! supports it.
//!
//! ## Bit-identity mapping (f64, mul+add arms)
//!
//! The scalar reference [`super::dot_scalar`] keeps four interleaved
//! accumulators `s0..s3` (stride-4 lanes) combined `(s0+s1)+(s2+s3)`
//! plus an in-order tail. That is exactly one AVX2 `f64x4` accumulator
//! updated with separate `mul`/`add` per quad — lane `l` of the vector
//! IS `s_l` — or two NEON `f64x2` accumulators (lanes `s0,s1` and
//! `s2,s3`). Extracting lanes and combining in the same tree therefore
//! reproduces the scalar result **bit for bit**, IEEE-exactly, for
//! every input including NaN/±inf/±1e150 (same multiplies, same adds,
//! same order). The FMA arm replaces mul+add with `fmadd` (one rounding
//! instead of two) so it is *not* bit-identical — it is opt-in via
//! `--isa fma` and never auto-selected.
//!
//! The f32 reference ([`super::dot_f32_scalar`]) uses eight stride-8
//! accumulators combined `((s0+s1)+(s2+s3)) + ((s4+s5)+(s6+s7))` — one
//! AVX2 `f32x8` accumulator or two NEON `f32x4` — so the non-fused f32
//! arms are likewise bit-identical to their scalar reference (accuracy
//! vs f64 is a separate, tolerance-only contract).
//!
//! ## Panel microkernel
//!
//! The x86 block arms register-block the inner loop 4 wide over `b`
//! rows: one shared `a`-row vector load feeds four *independent*
//! per-pair accumulators ([`dot4`] inside each arm). Blocking never
//! mixes accumulators across pairs, so per-entry bits are exactly the
//! single-pair `dot` of the same arm; it exists purely to cut `a`-row
//! load traffic 4x and keep four add chains in flight.

// Fused vs separate multiply-add, selected per arm at expansion time.
// `madd_*_sep` is two roundings (bit-identical to scalar); the fused
// variants are one rounding (FMA arm only).
#[cfg(target_arch = "x86_64")]
macro_rules! madd_pd_sep {
    ($acc:expr, $va:expr, $vb:expr) => {
        _mm256_add_pd($acc, _mm256_mul_pd($va, $vb))
    };
}
#[cfg(target_arch = "x86_64")]
macro_rules! madd_pd_fused {
    ($acc:expr, $va:expr, $vb:expr) => {
        _mm256_fmadd_pd($va, $vb, $acc)
    };
}
#[cfg(target_arch = "x86_64")]
macro_rules! madd_ps_sep {
    ($acc:expr, $va:expr, $vb:expr) => {
        _mm256_add_ps($acc, _mm256_mul_ps($va, $vb))
    };
}
#[cfg(target_arch = "x86_64")]
macro_rules! madd_ps_fused {
    ($acc:expr, $va:expr, $vb:expr) => {
        _mm256_fmadd_ps($va, $vb, $acc)
    };
}
// Scalar-tail multiply-add, same fused/separate split (works for both
// f32 and f64 operands).
#[cfg(target_arch = "x86_64")]
macro_rules! tail_sep {
    ($t:ident, $x:expr, $y:expr) => {
        $t += $x * $y;
    };
}
#[cfg(target_arch = "x86_64")]
macro_rules! tail_fused {
    ($t:ident, $x:expr, $y:expr) => {
        $t = ($x).mul_add($y, $t);
    };
}

/// Expands to one complete x86_64 arm module (`avx2` or `fma`): the
/// two bodies differ only in the multiply-add idiom and the enabled
/// target features.
#[cfg(target_arch = "x86_64")]
macro_rules! x86_arm {
    ($arm:ident, $feat:literal, $madd_pd:ident, $madd_ps:ident, $tail:ident) => {
        pub(crate) mod $arm {
            use crate::linalg::TILE_J;
            use crate::util::matrix::Matrix;
            use core::arch::x86_64::*;
            use std::ops::Range;

            /// Lane extract + fixed combine `(l0+l1)+(l2+l3)`.
            #[inline]
            #[target_feature(enable = $feat)]
            unsafe fn hsum4(v: __m256d) -> f64 {
                let mut l = [0.0f64; 4];
                _mm256_storeu_pd(l.as_mut_ptr(), v);
                (l[0] + l[1]) + (l[2] + l[3])
            }

            /// Lane extract + fixed combine
            /// `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`.
            #[inline]
            #[target_feature(enable = $feat)]
            unsafe fn hsum8(v: __m256) -> f32 {
                let mut l = [0.0f32; 8];
                _mm256_storeu_ps(l.as_mut_ptr(), v);
                ((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]))
            }

            /// Single-pair dot. Safety: caller must have verified the
            /// arm's CPU features; reads are bounded by
            /// `min(a.len(), b.len())`, so any slice pair is fine.
            #[target_feature(enable = $feat)]
            pub(crate) unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
                let n = a.len().min(b.len());
                let quads = n / 4;
                let (pa, pb) = (a.as_ptr(), b.as_ptr());
                let mut acc = _mm256_setzero_pd();
                for q in 0..quads {
                    let k = q * 4;
                    let va = _mm256_loadu_pd(pa.add(k));
                    let vb = _mm256_loadu_pd(pb.add(k));
                    acc = $madd_pd!(acc, va, vb);
                }
                let mut t = 0.0f64;
                for k in quads * 4..n {
                    $tail!(t, *a.get_unchecked(k), *b.get_unchecked(k));
                }
                hsum4(acc) + t
            }

            /// Four pairs sharing one `a`-row load stream; accumulator
            /// `j` is bit-for-bit the single-pair [`dot`] of
            /// `(a, b_j)`. Safety: features checked by caller; all four
            /// `b` rows must be at least `a.len()` long.
            #[target_feature(enable = $feat)]
            unsafe fn dot4(
                a: &[f64],
                b0: &[f64],
                b1: &[f64],
                b2: &[f64],
                b3: &[f64],
            ) -> [f64; 4] {
                let n = a.len();
                let quads = n / 4;
                let pa = a.as_ptr();
                let (p0, p1, p2, p3) = (b0.as_ptr(), b1.as_ptr(), b2.as_ptr(), b3.as_ptr());
                let mut a0 = _mm256_setzero_pd();
                let mut a1 = _mm256_setzero_pd();
                let mut a2 = _mm256_setzero_pd();
                let mut a3 = _mm256_setzero_pd();
                for q in 0..quads {
                    let k = q * 4;
                    let va = _mm256_loadu_pd(pa.add(k));
                    a0 = $madd_pd!(a0, va, _mm256_loadu_pd(p0.add(k)));
                    a1 = $madd_pd!(a1, va, _mm256_loadu_pd(p1.add(k)));
                    a2 = $madd_pd!(a2, va, _mm256_loadu_pd(p2.add(k)));
                    a3 = $madd_pd!(a3, va, _mm256_loadu_pd(p3.add(k)));
                }
                let (mut t0, mut t1, mut t2, mut t3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
                for k in quads * 4..n {
                    let av = *a.get_unchecked(k);
                    $tail!(t0, av, *b0.get_unchecked(k));
                    $tail!(t1, av, *b1.get_unchecked(k));
                    $tail!(t2, av, *b2.get_unchecked(k));
                    $tail!(t3, av, *b3.get_unchecked(k));
                }
                [
                    hsum4(a0) + t0,
                    hsum4(a1) + t1,
                    hsum4(a2) + t2,
                    hsum4(a3) + t3,
                ]
            }

            /// Panel kernel: the scalar path's [`TILE_J`] tiling with a
            /// 4-wide register-blocked inner microkernel. Safety:
            /// features checked by caller; `out` indexing is
            /// bounds-checked, row reads are clamped to the shorter of
            /// the two matrices' widths.
            #[target_feature(enable = $feat)]
            pub(crate) unsafe fn dot_block(
                a: &Matrix,
                a_rows: Range<usize>,
                b: &Matrix,
                b_rows: Range<usize>,
                out: &mut [f64],
            ) {
                let (a0, la) = (a_rows.start, a_rows.len());
                let (b0, lb) = (b_rows.start, b_rows.len());
                let n = a.cols().min(b.cols());
                let mut jt = 0;
                while jt < lb {
                    let jt_end = (jt + TILE_J).min(lb);
                    for ia in 0..la {
                        let arow = &a.row(a0 + ia)[..n];
                        let row_out = &mut out[ia * lb..(ia + 1) * lb];
                        let mut j = jt;
                        while j + 4 <= jt_end {
                            let d = dot4(
                                arow,
                                &b.row(b0 + j)[..n],
                                &b.row(b0 + j + 1)[..n],
                                &b.row(b0 + j + 2)[..n],
                                &b.row(b0 + j + 3)[..n],
                            );
                            row_out[j..j + 4].copy_from_slice(&d);
                            j += 4;
                        }
                        while j < jt_end {
                            row_out[j] = dot(arow, &b.row(b0 + j)[..n]);
                            j += 1;
                        }
                    }
                    jt = jt_end;
                }
            }

            /// Single-pair f32 dot (one f32x8 accumulator). Safety:
            /// features checked by caller; reads bounded by the shorter
            /// slice.
            #[target_feature(enable = $feat)]
            pub(crate) unsafe fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
                let n = a.len().min(b.len());
                let octs = n / 8;
                let (pa, pb) = (a.as_ptr(), b.as_ptr());
                let mut acc = _mm256_setzero_ps();
                for o in 0..octs {
                    let k = o * 8;
                    let va = _mm256_loadu_ps(pa.add(k));
                    let vb = _mm256_loadu_ps(pb.add(k));
                    acc = $madd_ps!(acc, va, vb);
                }
                let mut t = 0.0f32;
                for k in octs * 8..n {
                    $tail!(t, *a.get_unchecked(k), *b.get_unchecked(k));
                }
                hsum8(acc) + t
            }

            /// f32 panel over flat row-major buffers (`a`: `ra x cols`,
            /// `b`: `rb x cols`, `out`: `ra x rb`). Safety: features
            /// checked by caller; all slice access is bounds-checked.
            #[target_feature(enable = $feat)]
            pub(crate) unsafe fn dot_block_f32(
                a: &[f32],
                b: &[f32],
                cols: usize,
                out: &mut [f32],
            ) {
                if cols == 0 {
                    return;
                }
                let ra = a.len() / cols;
                let rb = b.len() / cols;
                let mut jt = 0;
                while jt < rb {
                    let jt_end = (jt + TILE_J).min(rb);
                    for ia in 0..ra {
                        let arow = &a[ia * cols..(ia + 1) * cols];
                        let row_out = &mut out[ia * rb..(ia + 1) * rb];
                        for (j, slot) in
                            row_out.iter_mut().enumerate().take(jt_end).skip(jt)
                        {
                            *slot = dot_f32(arow, &b[j * cols..(j + 1) * cols]);
                        }
                    }
                    jt = jt_end;
                }
            }
        }
    };
}

#[cfg(target_arch = "x86_64")]
x86_arm!(avx2, "avx2", madd_pd_sep, madd_ps_sep, tail_sep);
#[cfg(target_arch = "x86_64")]
x86_arm!(fma, "avx2,fma", madd_pd_fused, madd_ps_fused, tail_fused);

/// aarch64 NEON arm: NEON is part of the aarch64 baseline, so this arm
/// is unconditionally available there. Two `f64x2` accumulators carry
/// lanes `(s0,s1)` / `(s2,s3)` — bit-identical to the scalar reference.
#[cfg(target_arch = "aarch64")]
pub(crate) mod neon {
    use crate::linalg::TILE_J;
    use crate::util::matrix::Matrix;
    use core::arch::aarch64::*;
    use std::ops::Range;

    /// Single-pair dot. Safety: NEON is baseline on aarch64; reads are
    /// bounded by `min(a.len(), b.len())`.
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len().min(b.len());
        let quads = n / 4;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc01 = vdupq_n_f64(0.0);
        let mut acc23 = vdupq_n_f64(0.0);
        for q in 0..quads {
            let k = q * 4;
            acc01 = vaddq_f64(acc01, vmulq_f64(vld1q_f64(pa.add(k)), vld1q_f64(pb.add(k))));
            acc23 = vaddq_f64(
                acc23,
                vmulq_f64(vld1q_f64(pa.add(k + 2)), vld1q_f64(pb.add(k + 2))),
            );
        }
        let mut t = 0.0f64;
        for k in quads * 4..n {
            t += *a.get_unchecked(k) * *b.get_unchecked(k);
        }
        let s01 = vgetq_lane_f64::<0>(acc01) + vgetq_lane_f64::<1>(acc01);
        let s23 = vgetq_lane_f64::<0>(acc23) + vgetq_lane_f64::<1>(acc23);
        (s01 + s23) + t
    }

    /// Panel kernel: scalar tiling, per-pair NEON dot. Safety: NEON is
    /// baseline on aarch64; `out` indexing is bounds-checked, row reads
    /// clamped to the shorter width.
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn dot_block(
        a: &Matrix,
        a_rows: Range<usize>,
        b: &Matrix,
        b_rows: Range<usize>,
        out: &mut [f64],
    ) {
        let (a0, la) = (a_rows.start, a_rows.len());
        let (b0, lb) = (b_rows.start, b_rows.len());
        let n = a.cols().min(b.cols());
        let mut jt = 0;
        while jt < lb {
            let jt_end = (jt + TILE_J).min(lb);
            for ia in 0..la {
                let arow = &a.row(a0 + ia)[..n];
                let row_out = &mut out[ia * lb..(ia + 1) * lb];
                for (j, slot) in row_out.iter_mut().enumerate().take(jt_end).skip(jt) {
                    *slot = dot(arow, &b.row(b0 + j)[..n]);
                }
            }
            jt = jt_end;
        }
    }

    /// Single-pair f32 dot: two `f32x4` accumulators carrying lanes
    /// `s0..s3` / `s4..s7` of the f32 reference order. Safety: NEON is
    /// baseline on aarch64; reads bounded by the shorter slice.
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let octs = n / 8;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc03 = vdupq_n_f32(0.0);
        let mut acc47 = vdupq_n_f32(0.0);
        for o in 0..octs {
            let k = o * 8;
            acc03 = vaddq_f32(acc03, vmulq_f32(vld1q_f32(pa.add(k)), vld1q_f32(pb.add(k))));
            acc47 = vaddq_f32(
                acc47,
                vmulq_f32(vld1q_f32(pa.add(k + 4)), vld1q_f32(pb.add(k + 4))),
            );
        }
        let mut t = 0.0f32;
        for k in octs * 8..n {
            t += *a.get_unchecked(k) * *b.get_unchecked(k);
        }
        let s03 = (vgetq_lane_f32::<0>(acc03) + vgetq_lane_f32::<1>(acc03))
            + (vgetq_lane_f32::<2>(acc03) + vgetq_lane_f32::<3>(acc03));
        let s47 = (vgetq_lane_f32::<0>(acc47) + vgetq_lane_f32::<1>(acc47))
            + (vgetq_lane_f32::<2>(acc47) + vgetq_lane_f32::<3>(acc47));
        (s03 + s47) + t
    }

    /// f32 panel over flat row-major buffers. Safety: NEON is baseline
    /// on aarch64; all slice access is bounds-checked.
    #[target_feature(enable = "neon")]
    pub(crate) unsafe fn dot_block_f32(a: &[f32], b: &[f32], cols: usize, out: &mut [f32]) {
        if cols == 0 {
            return;
        }
        let ra = a.len() / cols;
        let rb = b.len() / cols;
        let mut jt = 0;
        while jt < rb {
            let jt_end = (jt + TILE_J).min(rb);
            for ia in 0..ra {
                let arow = &a[ia * cols..(ia + 1) * cols];
                let row_out = &mut out[ia * rb..(ia + 1) * rb];
                for (j, slot) in row_out.iter_mut().enumerate().take(jt_end).skip(jt) {
                    *slot = dot_f32(arow, &b[j * cols..(j + 1) * cols]);
                }
            }
            jt = jt_end;
        }
    }
}
