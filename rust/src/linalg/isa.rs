//! Runtime ISA dispatch for the kernel microkernel.
//!
//! The block compute layer ([`crate::linalg`]) has one portable scalar
//! implementation plus explicit-SIMD arms written against `core::arch`.
//! Which arm runs is a **process-global** selection resolved once and
//! cached in an atomic, so the per-[`crate::linalg::dot`] dispatch cost
//! is a single relaxed load:
//!
//! | arm      | arch     | availability            | f64 bits vs scalar |
//! |----------|----------|-------------------------|--------------------|
//! | `scalar` | any      | always                  | reference          |
//! | `avx2`   | x86_64   | runtime-detected        | **bit-identical**  |
//! | `fma`    | x86_64   | runtime-detected        | differs (fused)    |
//! | `neon`   | aarch64  | baseline (always)       | **bit-identical**  |
//!
//! `avx2` and `neon` keep the fixed-summation-order contract bit for bit
//! (see [`crate::linalg::dot`]); `fma` fuses multiply-add (one rounding
//! per term instead of two) and is therefore **never auto-selected** —
//! it must be requested explicitly via `--isa fma` / `FASTSVDD_ISA=fma`.
//!
//! ## Resolution precedence
//!
//! 1. explicit [`install`] (CLI `--isa` / config `"isa"`), when not
//!    `auto` — an unavailable explicit request is a hard error;
//! 2. the `FASTSVDD_ISA` environment variable (test / CI escape hatch,
//!    e.g. `FASTSVDD_ISA=scalar cargo test`) — an unrecognized or
//!    unavailable value falls back to detection rather than erroring,
//!    so a stale env var can never take a host down;
//! 3. auto-detection: best *bit-identical* arm for the host
//!    (x86_64 + AVX2 → `avx2`, aarch64 → `neon`, else `scalar`).

use crate::error::Error;
use std::sync::atomic::{AtomicU8, Ordering};

/// A dispatchable microkernel arm (or `Auto`, the "let the library
/// pick" request value used by config / CLI — [`selected`] never
/// resolves to it).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Resolve via `FASTSVDD_ISA` then hardware detection.
    Auto,
    /// Portable unrolled loop — the reference summation order.
    Scalar,
    /// x86_64 AVX2, mul+add (bit-identical to scalar).
    Avx2,
    /// x86_64 AVX2+FMA, fused multiply-add (opt-in, relaxes bits).
    Fma,
    /// aarch64 NEON, mul+add (bit-identical to scalar).
    Neon,
}

/// All concrete (non-`Auto`) arms, in display order.
pub const ALL: [Isa; 4] = [Isa::Scalar, Isa::Avx2, Isa::Fma, Isa::Neon];

impl Isa {
    /// Canonical lowercase name (the `--isa` / `FASTSVDD_ISA` spelling).
    pub fn name(self) -> &'static str {
        match self {
            Isa::Auto => "auto",
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Fma => "fma",
            Isa::Neon => "neon",
        }
    }

    /// Parse a `--isa` / config / env spelling.
    pub fn parse(s: &str) -> Result<Isa, Error> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Ok(Isa::Auto),
            "scalar" => Ok(Isa::Scalar),
            "avx2" => Ok(Isa::Avx2),
            "fma" => Ok(Isa::Fma),
            "neon" => Ok(Isa::Neon),
            other => Err(Error::InvalidInput(format!(
                "unknown isa '{other}' (expected auto|avx2|fma|neon|scalar)"
            ))),
        }
    }

    /// Can this arm run on the current host? `Auto` and `Scalar` always
    /// can; SIMD arms require the right architecture and (on x86_64)
    /// runtime CPU feature detection. NEON is part of the aarch64
    /// baseline, so on aarch64 it is unconditionally available.
    pub fn available(self) -> bool {
        match self {
            Isa::Auto | Isa::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "x86_64")]
            Isa::Fma => {
                is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
            }
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => true,
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }
}

impl std::fmt::Display for Isa {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Cached selection. 0 = unresolved; otherwise `encode(arm) `.
static SELECTED: AtomicU8 = AtomicU8::new(0);

fn encode(isa: Isa) -> u8 {
    match isa {
        Isa::Scalar => 1,
        Isa::Avx2 => 2,
        Isa::Fma => 3,
        Isa::Neon => 4,
        Isa::Auto => 0,
    }
}

fn decode(v: u8) -> Option<Isa> {
    match v {
        1 => Some(Isa::Scalar),
        2 => Some(Isa::Avx2),
        3 => Some(Isa::Fma),
        4 => Some(Isa::Neon),
        _ => None,
    }
}

/// Best bit-identical arm for this host (never `Fma` — fused rounding
/// must be opted into explicitly).
pub fn detect() -> Isa {
    detect_impl()
}

#[cfg(target_arch = "x86_64")]
fn detect_impl() -> Isa {
    if Isa::Avx2.available() {
        Isa::Avx2
    } else {
        Isa::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn detect_impl() -> Isa {
    Isa::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_impl() -> Isa {
    Isa::Scalar
}

/// `FASTSVDD_ISA`, if set to a recognized **and available** arm.
/// Anything else (unset, unknown spelling, arm the host cannot run)
/// yields `None` so resolution falls through to [`detect`].
fn from_env() -> Option<Isa> {
    let raw = std::env::var("FASTSVDD_ISA").ok()?;
    match Isa::parse(&raw) {
        Ok(Isa::Auto) => None,
        Ok(isa) if isa.available() => Some(isa),
        _ => None,
    }
}

fn resolve_auto() -> Isa {
    from_env().unwrap_or_else(detect)
}

/// Install the microkernel arm for this process. `Auto` re-runs the
/// env-then-detect resolution; a concrete arm must be available on this
/// host or the call fails with [`Error::InvalidInput`] (an explicit
/// `--isa avx2` on a machine without AVX2 is a misconfiguration, not
/// something to paper over). Returns the arm actually selected.
///
/// Benches call this repeatedly to force specific arms; production
/// callers install once at startup ([`crate::config::RunConfig::isa`]).
pub fn install(requested: Isa) -> Result<Isa, Error> {
    let arm = match requested {
        Isa::Auto => resolve_auto(),
        isa if isa.available() => isa,
        isa => {
            return Err(Error::InvalidInput(format!(
                "isa '{isa}' is not available on this host \
                 (arch {}; use --isa auto)",
                std::env::consts::ARCH
            )))
        }
    };
    SELECTED.store(encode(arm), Ordering::Relaxed);
    Ok(arm)
}

/// The currently selected arm, resolving lazily on first use (so
/// library consumers that never touch config still dispatch to the best
/// bit-identical arm, and `FASTSVDD_ISA=scalar cargo test` covers the
/// fallback path with zero plumbing).
#[inline]
pub fn selected() -> Isa {
    match decode(SELECTED.load(Ordering::Relaxed)) {
        Some(isa) => isa,
        None => {
            let isa = resolve_auto();
            SELECTED.store(encode(isa), Ordering::Relaxed);
            isa
        }
    }
}

/// [`selected`]'s canonical name — what obs spans, metrics and
/// `BENCH_*.json` record.
#[inline]
pub fn selected_name() -> &'static str {
    selected().name()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_all_names() {
        for isa in ALL.iter().copied().chain([Isa::Auto]) {
            assert_eq!(Isa::parse(isa.name()).unwrap(), isa);
        }
        assert_eq!(Isa::parse(" AVX2 ").unwrap(), Isa::Avx2);
        assert!(Isa::parse("sse9").is_err());
    }

    #[test]
    fn scalar_and_auto_always_available() {
        assert!(Isa::Scalar.available());
        assert!(Isa::Auto.available());
    }

    #[test]
    fn detect_never_returns_fma_or_auto() {
        let d = detect();
        assert_ne!(d, Isa::Fma);
        assert_ne!(d, Isa::Auto);
        assert!(d.available());
    }

    #[test]
    fn install_scalar_then_best_roundtrips() {
        // Serialize against other tests via the global: install is
        // process-global, so leave the best arm behind when done.
        assert_eq!(install(Isa::Scalar).unwrap(), Isa::Scalar);
        assert_eq!(selected(), Isa::Scalar);
        let best = install(Isa::Auto).unwrap();
        assert_eq!(selected(), best);
        assert_ne!(best, Isa::Fma);
    }

    #[test]
    fn install_unavailable_arm_is_an_error() {
        // At least one of avx2/neon is foreign on any single host.
        let foreign = if cfg!(target_arch = "x86_64") {
            Isa::Neon
        } else {
            Isa::Avx2
        };
        assert!(!foreign.available());
        assert!(install(foreign).is_err());
        // The failed install must not clobber the selection.
        assert!(selected().available());
    }
}
